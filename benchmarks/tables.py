"""One benchmark per paper table/figure (offline protocol, §5.2).

Each function returns a list of CSV-able dicts; run.py prints them.
The experiment world is scaled down (DESIGN.md §8) but follows the
paper's split/protocol; the budget axis stays in paper FLOPs units
(Table 1 per-item costs).
"""
from __future__ import annotations

import os
import pickle

import numpy as np

from repro.core.budget import BudgetController
from repro.core.pfec import pfec_report
from repro.data.synthetic import WorldConfig
from repro.experiments import (ExperimentConfig, budget_at, build_experiment,
                               cras_stage_rewards, evaluate_methods,
                               predicted_rewards, reward_model_metrics,
                               train_reward_model)

CACHE = os.path.join(os.path.dirname(__file__), "..", "results", "cache")

BENCH_CFG = ExperimentConfig(
    world=WorldConfig(n_users=2500, n_items=400, hist_len=12, seed=7),
    expose=10, n_scales=6, cascade_steps=220, reward_steps=500, batch=64)


def get_experiment(cfg: ExperimentConfig = BENCH_CFG, *, verbose=True):
    """Build (or load cached) the benchmark experiment."""
    os.makedirs(CACHE, exist_ok=True)
    key = (f"exp_u{cfg.world.n_users}_i{cfg.world.n_items}"
           f"_h{cfg.world.hist_len}_s{cfg.seed}_c{cfg.cascade_steps}.pkl")
    path = os.path.join(CACHE, key)
    if os.path.exists(path):
        with open(path, "rb") as f:
            return pickle.load(f)
    exp = build_experiment(cfg, verbose=verbose)
    with open(path, "wb") as f:
        pickle.dump(exp, f)
    return exp


# ---------------------------------------------------------------------------
# Figure 4: revenue vs budget, all methods
# ---------------------------------------------------------------------------


def fig4_budget_curves(exp, reward_params, reward_cfg) -> list[dict]:
    pred = predicted_rewards(exp, reward_params, reward_cfg, exp.ctx_eval)
    sr = cras_stage_rewards(exp)
    rows = evaluate_methods(exp, budgets_frac=(0.3, 0.45, 0.6, 0.75, 0.9),
                            rewards_pred=pred, stage_rewards=sr)
    out = []
    for r in rows:
        out.append({"name": f"fig4_budget_{r['budget_frac']:.2f}",
                    "greenflow": r["greenflow"], "oracle": r["oracle"],
                    "cras_din": r["cras_din"], "cras_dien": r["cras_dien"],
                    "equal_din": r["equal_din"],
                    "equal_dien": r["equal_dien"],
                    "budget_flops": r["budget_flops"]})
    return out


# ---------------------------------------------------------------------------
# Table 2: single-stage vs multi-stage allocation
# ---------------------------------------------------------------------------


def table2_stage_ablation(exp, reward_params, reward_cfg) -> list[dict]:
    """Single-stage = only the ranking action varies (prerank fixed at its
    median scale); multi-stage = full chain space."""
    chains = exp.chains
    pred = predicted_rewards(exp, reward_params, reward_cfg, exp.ctx_eval)
    sr = cras_stage_rewards(exp)

    # single-stage subset: n2 fixed to the median scale
    k_pre = 1
    med_scale = chains.stages[k_pre].n_scales // 2
    sub = np.where(chains.chain_idx[:, k_pre, 1] == med_scale)[0]

    out = []
    for frac in (0.45, 0.6, 0.75):
        n = exp.revenue_eval.shape[0]
        budget = budget_at(exp, frac)
        from repro.core.primal_dual import allocate, dual_bisect
        import jax.numpy as jnp

        # multi-stage (full space)
        lam = dual_bisect(jnp.asarray(pred), jnp.asarray(chains.costs,
                                                         jnp.float32), budget)
        dec = np.asarray(allocate(jnp.asarray(pred),
                                  jnp.asarray(chains.costs, jnp.float32), lam))
        multi = exp.revenue_eval[np.arange(n), dec].sum()

        # single-stage (restricted chain subset)
        lam = dual_bisect(jnp.asarray(pred[:, sub]),
                          jnp.asarray(chains.costs[sub], jnp.float32), budget)
        dec_s = np.asarray(allocate(jnp.asarray(pred[:, sub]),
                                    jnp.asarray(chains.costs[sub],
                                                jnp.float32), lam))
        single = exp.revenue_eval[np.arange(n), sub[dec_s]].sum()

        from repro.core.baselines import StageActionSpace, cras_allocation
        spaces = [StageActionSpace.from_chains(chains, k) for k in range(3)]
        dec_c = cras_allocation(sr, spaces, chains, budget)
        cras = exp.revenue_eval[np.arange(n), dec_c].sum()

        out.append({"name": f"table2_budget_{frac:.2f}",
                    "ours_multi_stage": float(multi),
                    "ours_single_stage": float(single),
                    "cras": float(cras)})
    return out


# ---------------------------------------------------------------------------
# Table 3: single-model vs multi-model ranking pools
# ---------------------------------------------------------------------------


def table3_model_ablation(exp, reward_params, reward_cfg) -> list[dict]:
    import jax.numpy as jnp
    from repro.core.primal_dual import allocate, dual_bisect

    chains = exp.chains
    pred = predicted_rewards(exp, reward_params, reward_cfg, exp.ctx_eval)
    k_rank = chains.n_stages - 1
    names = [m.name for m in chains.stages[k_rank].models]
    subsets = {
        "only_din": np.where(chains.chain_idx[:, k_rank, 0]
                             == names.index("DIN"))[0],
        "only_dien": np.where(chains.chain_idx[:, k_rank, 0]
                              == names.index("DIEN"))[0],
        "both": np.arange(chains.n_chains),
    }
    out = []
    n = exp.revenue_eval.shape[0]
    for frac in (0.4, 0.55, 0.7, 0.85):
        budget = budget_at(exp, frac)
        row = {"name": f"table3_budget_{frac:.2f}"}
        for label, sub in subsets.items():
            lam = dual_bisect(jnp.asarray(pred[:, sub]),
                              jnp.asarray(chains.costs[sub], jnp.float32),
                              budget)
            dec = np.asarray(allocate(jnp.asarray(pred[:, sub]),
                                      jnp.asarray(chains.costs[sub],
                                                  jnp.float32), lam))
            row[label] = float(exp.revenue_eval[np.arange(n),
                                                sub[dec]].sum())
        out.append(row)
    return out


# ---------------------------------------------------------------------------
# Table 4: reward-model ablation (recursive x multi-basis)
# ---------------------------------------------------------------------------


def table4_reward_ablation(exp) -> list[dict]:
    import jax.numpy as jnp
    from repro.core.primal_dual import allocate, dual_bisect

    out = []
    n = exp.revenue_eval.shape[0]
    budget = budget_at(exp, 0.6)
    for recursive in (True, False):
        for multi_basis in (True, False):
            params, rcfg = train_reward_model(
                exp, recursive=recursive, multi_basis=multi_basis)
            m = reward_model_metrics(exp, params, rcfg)
            pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)
            lam = dual_bisect(jnp.asarray(pred),
                              jnp.asarray(exp.chains.costs, jnp.float32),
                              budget)
            dec = np.asarray(allocate(jnp.asarray(pred),
                                      jnp.asarray(exp.chains.costs,
                                                  jnp.float32), lam))
            rev = float(exp.revenue_eval[np.arange(n), dec].sum())
            out.append({
                "name": f"table4_rec{int(recursive)}_mb{int(multi_basis)}",
                "recursive": recursive, "multi_basis": multi_basis,
                "field_rce": round(m["field_rce"], 4),
                "revenue": rev})
    return out


# ---------------------------------------------------------------------------
# Figure 5: budget adherence through traffic spikes
# ---------------------------------------------------------------------------


def fig5_traffic_spikes(exp, reward_params, reward_cfg) -> list[dict]:
    chains = exp.chains
    rng = np.random.default_rng(5)
    n_eval = exp.ctx_eval.shape[0]
    base_req = max(64, n_eval // 2)
    budget = budget_at(exp, 0.6, n=base_req)
    ctl = BudgetController(chains, budget)
    pred_eval = predicted_rewards(exp, reward_params, reward_cfg,
                                  exp.ctx_eval)

    traffic = [1.0, 1.0, 1.0, 2.5, 3.0, 2.5, 1.0, 1.0]  # spike windows
    floor_per_req = float(chains.costs[chains.cheapest()])
    out = []
    for t, mult in enumerate(traffic):
        n_t = int(base_req * mult)
        idx = rng.integers(0, n_eval, n_t)
        ctl.step_window(pred_eval[idx])
        s = ctl.stats[-1]
        # the guard's guarantee: spend <= max(budget, n_t * cheapest) -
        # Eq. 3b serves every request, so the floor scales with traffic
        cap = max(s.budget, n_t * floor_per_req)
        out.append({"name": f"fig5_window_{t}", "traffic_mult": mult,
                    "spend": s.spend, "budget": s.budget,
                    "cap_incl_floor": cap,
                    "overshoot_vs_cap": max(0.0, s.spend / cap - 1.0),
                    "lam": round(s.lam, 6), "downgraded": s.downgraded})
    return out


# ---------------------------------------------------------------------------
# PFEC summary (paper §3.2) at the paper's operating point
# ---------------------------------------------------------------------------


def pfec_summary(exp, reward_params, reward_cfg) -> list[dict]:
    import jax.numpy as jnp
    from repro.core.primal_dual import allocate, dual_bisect

    chains = exp.chains
    n = exp.revenue_eval.shape[0]
    pred = predicted_rewards(exp, reward_params, reward_cfg, exp.ctx_eval)
    rows = []
    # EQUAL at full budget vs GreenFlow at 59% (paper: -41% computation)
    j_eq = np.argmax(chains.costs)
    eq_rev = exp.revenue_eval[:, j_eq].sum()
    eq_flops = chains.costs[j_eq] * n
    rows.append(pfec_report(clicks=float(eq_rev), flops=float(eq_flops),
                            name="pfec_equal_full").as_row())
    budget = 0.59 * eq_flops
    lam = dual_bisect(jnp.asarray(pred), jnp.asarray(chains.costs,
                                                     jnp.float32), budget)
    dec = np.asarray(allocate(jnp.asarray(pred),
                              jnp.asarray(chains.costs, jnp.float32), lam))
    gf_rev = exp.revenue_eval[np.arange(n), dec].sum()
    gf_flops = chains.costs[dec].sum()
    rows.append(pfec_report(clicks=float(gf_rev), flops=float(gf_flops),
                            name="pfec_greenflow_59pct").as_row())
    r0, r1 = rows
    rows.append({"name": "pfec_delta",
                 "clicks_delta_pct": 100 * (r1["performance"]
                                            / max(r0["performance"], 1e-9)
                                            - 1),
                 "flops_delta_pct": 100 * (r1["flops"] / r0["flops"] - 1),
                 "energy_delta_kwh": r1["energy_kwh"] - r0["energy_kwh"],
                 "carbon_delta_g": r1["carbon_g"] - r0["carbon_g"]})
    return rows
