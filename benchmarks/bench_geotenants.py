"""Combined tenant x region benchmark: the ConstraintSpec headline.

    PYTHONPATH=src python benchmarks/bench_geotenants.py [--json PATH]

Protocol mirrors ``bench_geo.py`` (deterministic, decision-level): one
diurnal traffic day is sampled once - requests arrive in T equal tenant
blocks per window - and every arm sees the SAME requests, the same
reward-model predictions and the same pair of grid-intensity traces
(regions a/b share the diurnal CI shape ``--region-offset-h`` hours
apart), at several traffic-vs-grid phase offsets.  Each tenant t has
its own daily gCO2e budget g_t (distinct tightness).  Allocation uses
exact dual oracles (bisection), so the comparison measures the value
of COMPOSING the two constraint axes, not nearline lag.

Because every tenant's constraint only involves its own requests, the
day-level problem decouples per tenant; each arm solves T independent
problems and sums clicks:

  * ``tenants_only``  - per-tenant budgets, NO region choice: tenant
    t's requests are pinned to a single region (the better of the two
    for that tenant), exact scalar dual on its gram budget g_t.  Its
    REALIZED daily grams then anchor the equal-grams comparison.
  * ``regions_only``  - region choice WITHOUT cross-region gram
    flexibility: tenant t's equal-grams allowance is rigidly split in
    half per region (each region owns a fixed share) and a
    2-constraint exact dual (nested bisection) routes (chain, region)
    under both caps.
  * ``combined``      - the ConstraintSpec pipeline's problem: the
    same grams spend FREELY across both regions under one per-tenant
    budget, exact scalar dual over the J*R (chain, region) option
    space, primal rounded with the pipeline's green tie-break.

At the equal-grams anchor both baseline arms are restrictions of the
combined feasible set, so the exact dual can only gain clicks - the CI
gate asserts combined >= best(tenants_only, regions_only) for every
tested phase offset.

The benchmark also gates the PIPELINE against the oracle: a
``ServingPipeline.from_spec([TenantAxis(priced=True), RegionAxis(2,
split="argmax"), GlobalAxis(pricing="carbon")])`` day served with the
entry prices pinned to the oracle's per-tenant duals (region prices 0,
guard off - the oracle has no region caps) must reproduce the oracle's
decisions on every f32-DECIDED request (>= 99.5%; requests whose top-2
option gap only a float64 oracle can resolve - duplicate sampled users
with exactly tied rewards - legitimately tie-break by index in the f32
pipeline) and clicks (rel. error <= 1e-3) - the acceptance gate that
the fused combined pass prices exactly what the oracle prices.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _exact_alloc(r_opt: np.ndarray, eff: np.ndarray, budget: float,
                 *, iters: int = 80):
    """Smallest-price exact-dual decisions fitting ``budget`` (cf.
    bench_geo._exact_alloc) - returns (decisions, lam)."""
    ridx = np.arange(r_opt.shape[0])

    def alloc(lam):
        return np.argmax(r_opt - lam * eff, axis=1)

    def spend(dec):
        return float(eff[ridx, dec].sum())

    if spend(alloc(0.0)) <= budget:
        return alloc(0.0), 0.0
    lo, hi = 0.0, 1.0
    while spend(alloc(hi)) > budget and hi < 1e30:
        hi *= 2.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if spend(alloc(mid)) <= budget:
            hi = mid
        else:
            lo = mid
    return alloc(hi), hi


def _exact_alloc_2(r_opt: np.ndarray, eff_a: np.ndarray,
                   eff_b: np.ndarray, bud_a: float, bud_b: float,
                   *, iters: int = 40):
    """Exact dual for TWO per-region budgets over the (chain, region)
    option space, by nested bisection: the inner loop finds the
    smallest region-b price fitting bud_b at a given region-a price
    (region-b spend is non-increasing in its own price), the outer loop
    the smallest region-a price whose inner solution fits bud_a.
    Returns (option decisions, (lam_a, lam_b)); the result is always
    FEASIBLE (both caps respected), which is all the dominance gate
    needs from a baseline arm.
    """
    ridx = np.arange(r_opt.shape[0])
    j_n = eff_a.shape[1]

    def alloc(la, lb):
        return np.argmax(
            r_opt - np.concatenate([la * eff_a, lb * eff_b], axis=1),
            axis=1)

    def spends(dec):
        in_b = dec >= j_n
        ca = eff_a[ridx, np.minimum(dec, j_n - 1)]
        cb = eff_b[ridx, np.maximum(dec - j_n, 0)]
        return (float(np.sum(np.where(in_b, 0.0, ca))),
                float(np.sum(np.where(in_b, cb, 0.0))))

    def inner(la):
        if spends(alloc(la, 0.0))[1] <= bud_b:
            return 0.0
        lo, hi = 0.0, 1.0
        while spends(alloc(la, hi))[1] > bud_b and hi < 1e30:
            hi *= 2.0
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            if spends(alloc(la, mid))[1] <= bud_b:
                hi = mid
            else:
                lo = mid
        return hi

    def fits_a(la):
        return spends(alloc(la, inner(la)))[0] <= bud_a

    if fits_a(0.0):
        la = 0.0
    else:
        lo, hi = 0.0, 1.0
        while not fits_a(hi) and hi < 1e30:
            hi *= 2.0
        for _ in range(iters):
            mid = 0.5 * (lo + hi)
            if fits_a(mid):
                hi = mid
            else:
                lo = mid
        la = hi
    lb = inner(la)
    return alloc(la, lb), (la, lb)


def _green_alloc(r_sel: np.ndarray, s_sel: np.ndarray,
                 costs: np.ndarray, lam: float,
                 eps_rel: float = 1e-6) -> np.ndarray:
    """Factored exact-dual decisions with the pipeline's green
    tie-break: region = argmin_r (lam + eps) * s_r (ties - and the
    whole lam = 0 slack case - resolve to the GREENER region, exactly
    the fused pass's eps_green floor), then chain = the Eq. 10 argmax
    at the chosen region's price.  Mathematically the same allocation
    as the joint argmax over the J*R option space (the per-flop price
    factors out of the chain choice); only the degenerate tie is
    pinned down.  s_sel: (N, R) per-request per-region gram scales.
    """
    j_n = len(costs)
    n = len(r_sel)
    eps = eps_rel * float(np.abs(r_sel).max()) \
        / max(float(np.mean(s_sel) * np.mean(costs)), 1e-30)
    r0 = np.argmin((lam + eps) * s_sel, axis=1)
    price = (lam * s_sel[np.arange(n), r0])[:, None] * costs[None, :]
    dec = np.argmax(r_sel - price, axis=1)
    return r0 * j_n + dec


def run(*, windows: int = 24, requests: int = 48, n_tenants: int = 3,
        band_fracs=(0.35, 0.55, 0.75), ci_mean: float = 450.0,
        ci_amplitude: float = 0.45, region_offset_h: float = 8.0,
        phases=(0.0, 6.0, 12.0, 18.0), small: bool = True,
        json_path: str | None = None, check_dominance: bool = True,
        check_pipeline: bool = True) -> dict:
    from repro.carbon.controller import grams_per_flop
    from repro.carbon.intensity import two_region_traces
    from repro.carbon.ledger import DAY_S
    from repro.experiments import (build_serving_stack, predicted_rewards,
                                   serve_config)
    from repro.serving.stream import TrafficScenario, scenario_windows

    assert len(band_fracs) == n_tenants
    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=small), verbose=True)
    chains = exp.chains
    costs = chains.costs
    j_n = len(costs)
    sizes = scenario_windows(TrafficScenario(
        "geotenants", windows, requests, n_tenants=n_tenants))
    window_s = DAY_S / windows
    traces = two_region_traces(mean=ci_mean, offset_h=region_offset_h,
                               rel_amplitude=ci_amplitude)
    region_names = list(traces)
    kpf = grams_per_flop(1.0)  # g per FLOP per unit CI

    # one shared day of traffic: T contiguous equal tenant blocks per
    # window (the pipeline's block layout), same arrivals for every arm
    pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)  # (U, J)
    rng = np.random.default_rng(0)
    rows = np.concatenate([rng.integers(0, pred.shape[0], n)
                           for n in sizes])
    w_of = np.repeat(np.arange(windows), sizes)
    t_of = np.concatenate([np.repeat(np.arange(n_tenants), n // n_tenants)
                           for n in sizes])
    n_req = len(rows)
    R = pred[rows]
    r_geo = np.tile(R, (1, 2))  # option m = r*J + j, region-major
    true_rev = exp.revenue_eval[rows]

    def clicks_of(sel, dec_m):
        return float(true_rev[sel][np.arange(sel.sum()),
                                   dec_m % j_n].sum())

    rows_out = []
    pipe_check = None
    for phase_h in phases:
        ci_w = {r: traces[r].resample(windows, window_s,
                                      phase_s=phase_h * 3600.0)
                for r in region_names}
        s_req = {r: (kpf * ci_w[r])[w_of] for r in region_names}
        eff = {r: s_req[r][:, None] * costs[None, :]
               for r in region_names}  # (N, J) per region
        eff_geo = np.concatenate([eff[r] for r in region_names], axis=1)
        ra = region_names[0]

        arms = {"tenants_only": 0.0, "regions_only": 0.0,
                "combined": 0.0}
        tenant_rows = []
        lam_star = np.zeros(n_tenants)
        for t in range(n_tenants):
            sel = t_of == t
            # daily gram band for tenant t, anchored at region a
            # exactly like bench_geo ([floor_a, natural_a]); the pinned
            # tenants-only arm binds against this budget, and its
            # REALIZED grams then anchor the equal-grams comparison -
            # every arm below spends (at most) the grams the best
            # pinned arm actually spent, so both baselines are
            # restrictions of the combined feasible set at EQUAL grams
            # and the exact dual can only gain clicks.
            floor_g = float(costs.min() * s_req[ra][sel].sum())
            natural_g = float(
                eff[ra][sel][np.arange(sel.sum()),
                             np.argmax(R[sel], axis=1)].sum())
            g_t = floor_g + band_fracs[t] * (natural_g - floor_g)

            # tenants-only: pinned single region, best of the two (a
            # pinned arm whose floor exceeds g_t serves all-cheapest
            # and overspends; the JSON records feasibility)
            pinned = {}
            for r in region_names:
                dec, _ = _exact_alloc(R[sel], eff[r][sel], g_t)
                spend_r = float(eff[r][sel][np.arange(sel.sum()),
                                            dec].sum())
                pinned[r] = (clicks_of(sel, dec), spend_r,
                             spend_r <= g_t * (1 + 1e-6))
            best_r = max(region_names, key=lambda r: pinned[r][0])
            c_ten, grams_eq, _ = pinned[best_r]

            # regions-only: the same grams under rigid halves - each
            # region owns grams_eq/2 of tenant t's spend, a
            # 2-constraint nested-bisection dual over the (chain,
            # region) options (region choice without cross-region gram
            # flexibility)
            dec2, _ = _exact_alloc_2(
                r_geo[sel], eff[ra][sel], eff[region_names[1]][sel],
                grams_eq / 2, grams_eq / 2)
            c_reg = clicks_of(sel, dec2)
            grams_reg = float(eff_geo[sel][np.arange(sel.sum()),
                                           dec2].sum())

            # combined: grams_eq spends freely across both regions.
            # The bisection finds the dual; the primal is rounded with
            # the green tie-break (same chains and price, greener
            # region on ties, so the spend can only drop)
            _, lam_c = _exact_alloc(r_geo[sel], eff_geo[sel], grams_eq)
            lam_star[t] = lam_c
            s_sel = np.stack([s_req[r][sel] for r in region_names],
                             axis=1)
            dec_c = _green_alloc(R[sel], s_sel, costs, lam_c)
            c_com = clicks_of(sel, dec_c)
            grams_c = float(eff_geo[sel][np.arange(sel.sum()),
                                         dec_c].sum())
            assert grams_c <= grams_eq * (1 + 1e-9) or lam_c == 0.0

            arms["tenants_only"] += c_ten
            arms["regions_only"] += c_reg
            arms["combined"] += c_com
            tenant_rows.append({
                "tenant": t, "grams_budget": g_t,
                "grams_equal": grams_eq,
                "lam_star": lam_c,
                "tenants_only_clicks": c_ten,
                "tenants_only_region": best_r,
                "tenants_only_feasible": bool(pinned[best_r][2]),
                "regions_only_clicks": c_reg,
                "regions_only_gco2e": grams_reg,
                "combined_clicks": c_com,
                "combined_gco2e": grams_c,
                "combined_gco2e_saved_pct": round(
                    100 * (1 - grams_c / max(grams_eq, 1e-30)), 2),
                "combined_split": [
                    float(np.mean(dec_c // j_n == k))
                    for k in range(len(region_names))],
            })

        best_base = max(arms["tenants_only"], arms["regions_only"])
        grams_eq_total = sum(tr["grams_equal"] for tr in tenant_rows)
        grams_c_total = sum(tr["combined_gco2e"] for tr in tenant_rows)
        row = {
            "ci_phase_h": phase_h,
            "clicks": arms,
            "tenants": tenant_rows,
            "combined_vs_best_pct": round(
                100 * (arms["combined"] / best_base - 1), 2),
            "combined_vs_tenants_pct": round(
                100 * (arms["combined"] / arms["tenants_only"] - 1), 2),
            "gco2e_saved_pct": round(
                100 * (1 - grams_c_total / grams_eq_total), 2),
            "dominates": bool(arms["combined"] >= arms["tenants_only"]
                              and arms["combined"]
                              >= arms["regions_only"]
                              and grams_c_total
                              <= grams_eq_total * (1 + 1e-9)),
        }
        rows_out.append(row)
        print(f"[bench_geotenants] phase {phase_h:>4.1f}h: tenants-only "
              f"{arms['tenants_only']:.0f} | regions-only "
              f"{arms['regions_only']:.0f} | combined "
              f"{arms['combined']:.0f} clicks "
              f"({row['combined_vs_tenants_pct']:+.2f}% vs "
              f"tenants-only, {row['combined_vs_best_pct']:+.2f}% vs "
              f"best baseline, {row['gco2e_saved_pct']:+.2f}% g saved "
              f"at equal-or-better clicks)")

        # pipeline-vs-oracle gate, once (phase 0 geometry): the fused
        # combined pass at the oracle's per-tenant entry prices must
        # reproduce the oracle's decisions
        if check_pipeline and pipe_check is None:
            s_all = np.stack([s_req[r] for r in region_names], axis=1)
            pipe_check = _pipeline_matches_oracle(
                server, params, rcfg, exp, sizes, rows, n_tenants,
                lam_star, ci_w, kpf, region_names, R, s_all, costs,
                clicks_of, j_n, t_of)
            print(f"[bench_geotenants] pipeline vs oracle: "
                  f"{pipe_check['decision_match_rate']:.4f} decisions, "
                  f"clicks rel err "
                  f"{pipe_check['clicks_rel_err']:.2e}")

    result = {
        "config": {"windows": windows, "requests": requests,
                   "n_tenants": n_tenants,
                   "band_fracs": list(band_fracs), "ci_mean": ci_mean,
                   "ci_amplitude": ci_amplitude,
                   "region_offset_h": region_offset_h, "small": small,
                   "chains": chains.n_chains, "window_s": window_s,
                   "n_requests_day": int(n_req),
                   "regions": region_names,
                   "traffic": "diurnal day curve, T equal tenant "
                              "blocks per window",
                   "arms": {
                       "tenants_only": "per-tenant budgets, pinned "
                                       "best single region (realized "
                                       "grams anchor the equal-grams "
                                       "comparison)",
                       "regions_only": "geo routing under rigid "
                                       "half-per-region splits of the "
                                       "equal grams (2-constraint "
                                       "nested-bisection dual)",
                       "combined": "the same grams freely across both "
                                   "regions under one per-tenant "
                                   "budget over the J*R option space "
                                   "(the ConstraintSpec pipeline's "
                                   "problem)"},
                   "allocator": "exact dual oracles (bisection), "
                                "decisions on reward-model "
                                "predictions"},
        "phases": rows_out,
        "pipeline_check": pipe_check,
        "dominates_all_phases": bool(all(r["dominates"]
                                         for r in rows_out)),
    }
    if json_path is not None:
        from repro.obs.env import env_info
        result["env"] = env_info()
        path = os.path.abspath(json_path)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(json.dumps(result, indent=2))
        print(f"[bench_geotenants] wrote {path}")
    if check_dominance:
        assert result["dominates_all_phases"], result
        if pipe_check is not None:
            assert pipe_check["decision_match_rate"] >= 0.995, pipe_check
            assert abs(pipe_check["clicks_rel_err"]) <= 1e-3, pipe_check
    return result


def _pipeline_matches_oracle(server, params, rcfg, exp, sizes, rows,
                             n_tenants, lam_star, ci_w, kpf,
                             region_names, R, s_all, costs, clicks_of,
                             j_n, t_of):
    """Serve the oracle's day through the ConstraintSpec pipeline with
    entry prices pinned to the oracle's per-tenant duals (region prices
    0 - the oracle has no region caps - and guard off): decisions must
    match the oracle's."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    r_n = len(region_names)
    # budgets in the spec are per-window references; the check pins
    # prices, so only the shapes matter
    spec = ConstraintSpec([
        TenantAxis(tuple(1.0 for _ in range(n_tenants)), priced=True),
        RegionAxis(r_n, split="argmax"),
        GlobalAxis(pricing="carbon"),
    ])
    pipe = ServingPipeline.from_spec(server, params, rcfg, spec,
                                     guard=False)
    lam_pin = np.concatenate([lam_star,
                              np.zeros(r_n)]).astype(np.float32)
    big = np.full(n_tenants + r_n, 1e30, np.float32)
    scale_w = np.stack([kpf * ci_w[r] for r in region_names], axis=1)

    # oracle decisions for the same pinned prices (per-tenant scalar
    # price, f64, green tie-break - the pipeline's semantics).  The
    # match is gated on DECIDED requests: those whose top-2 option gap
    # is resolvable in float32 (duplicate sampled users carry exactly
    # tied rewards that only the f64 oracle can split by their ~1e-14
    # price differences - the f32 pipeline legitimately tie-breaks by
    # index there).
    dec_oracle = np.empty(len(rows), np.int64)
    decided = np.empty(len(rows), bool)
    for t in range(n_tenants):
        sel = t_of == t
        lam_t = float(lam_star[t])
        dec_oracle[sel] = _green_alloc(R[sel], s_all[sel], costs,
                                       lam_t)
        # decidedness follows the factored structure: the REGION
        # preference gap and the CHAIN top-2 gap at the chosen
        # region's price must each clear f32 resolution (the same
        # chain in the other region is always a near-tie option, and
        # chains sharing a model prefix can carry exactly equal
        # rewards - both tie-break by construction, not by pricing)
        s_sel = s_all[sel]
        n_t = int(sel.sum())
        eps = 1e-6 * float(np.abs(R[sel]).max()) \
            / max(float(np.mean(s_sel) * np.mean(costs)), 1e-30)
        u = (lam_t + eps) * s_sel  # (N_t, R)
        gap_r = np.abs(u[:, 0] - u[:, 1]) \
            / np.maximum(u.max(axis=1), 1e-30)
        r0 = np.argmin(u, axis=1)
        score = R[sel].astype(np.float64) \
            - (lam_t * s_sel[np.arange(n_t), r0])[:, None] \
            * costs[None, :]
        srt = np.sort(score, axis=1)
        gap_c = srt[:, -1] - srt[:, -2]
        decided[sel] = (gap_r > 1e-6) \
            & (gap_c > 1e-6 * float(np.abs(R[sel]).max()))

    match = np.zeros(len(rows), bool)
    clicks_pipe = 0.0
    off = 0
    for t, n in enumerate(sizes):
        r_w = rows[off:off + n]
        res = pipe.serve_window(exp.ctx_eval[r_w], r_w, lam=lam_pin,
                                update_lam=False, budget=big,
                                cost_scale=scale_w[t])
        dec_m = (res.regions_np * j_n + res.decisions_np)
        match[off:off + n] = dec_m == dec_oracle[off:off + n]
        clicks_pipe += float(res.revenue_np.sum())
        off += n
    clicks_oracle = clicks_of(np.ones(len(rows), bool), dec_oracle)
    return {
        "decision_match_rate": float(match[decided].mean()),
        "decision_match_rate_all": float(match.mean()),
        "decided_fraction": float(decided.mean()),
        "clicks_pipeline": clicks_pipe,
        "clicks_oracle": clicks_oracle,
        "clicks_rel_err": (clicks_pipe - clicks_oracle)
        / max(abs(clicks_oracle), 1e-30),
    }


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json",
                    default=os.path.join(REPO, "BENCH_geotenants.json"))
    ap.add_argument("--windows", type=int, default=24)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--tenants", type=int, default=3)
    ap.add_argument("--band-fracs", default="0.35,0.55,0.75",
                    help="per-tenant daily gram budget positions in "
                         "[floor, natural]")
    ap.add_argument("--region-offset-h", type=float, default=8.0,
                    help="hours region b's CI peak trails region a's")
    ap.add_argument("--phases", default="0,6,12,18",
                    help="traffic-vs-grid phase offsets (hours, csv)")
    ap.add_argument("--full", action="store_true",
                    help="the non---small serve world")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the dominance assertion")
    args = ap.parse_args()
    return run(windows=args.windows, requests=args.requests,
               n_tenants=args.tenants,
               band_fracs=tuple(float(x)
                                for x in args.band_fracs.split(",")),
               region_offset_h=args.region_offset_h,
               phases=tuple(float(x) for x in args.phases.split(",")),
               small=not args.full, json_path=args.json,
               check_dominance=not args.no_check)


if __name__ == "__main__":
    main()
