"""Chain-simulation benchmark: seed per-chain loop vs rank-based engine.

Measures ``simulate_revenue_matrix`` at the system-test scale (the ISSUE
acceptance config: U=160 users, I=200 items, J=128 chains) and records
the speedup over the SEED implementation (per-chain ``np.argpartition``
over the full score matrices, reproduced verbatim below for timing).

    PYTHONPATH=src python benchmarks/bench_chain_sim.py [--json PATH]

Writes BENCH_chain_sim.json at the repo root by default.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

from repro.cascade.engine import (simulate_revenue_matrix,
                                  simulate_revenue_matrix_reference)
from repro.core.action_chain import (ActionChainSet, ModelInstance,
                                     StageSpec, generate_action_chains)


# ---------------------------------------------------------------------------
# Seed implementation (pre rank-based rewrite), kept verbatim for timing
# ---------------------------------------------------------------------------


def _seed_run_chain(stage_scores, chain_desc, clicks, *, expose=20):
    n1, n2, n3, rank_name = chain_desc
    s1 = stage_scores["DSSM"]
    keep2 = np.argpartition(-s1, kth=min(n2, s1.shape[1] - 1),
                            axis=1)[:, :n2]
    s2 = np.take_along_axis(stage_scores["YDNN"], keep2, axis=1)
    k3 = min(n3, n2)
    idx3 = np.argpartition(-s2, kth=min(k3, s2.shape[1] - 1) - 1,
                           axis=1)[:, :k3]
    keep3 = np.take_along_axis(keep2, idx3, axis=1)
    s3 = np.take_along_axis(stage_scores[rank_name], keep3, axis=1)
    e = min(expose, k3)
    idx_e = np.argsort(-s3, axis=1)[:, :e]
    exposed = np.take_along_axis(keep3, idx_e, axis=1)
    return np.take_along_axis(clicks, exposed, axis=1).sum(axis=1)


def _seed_simulate(stage_scores, chains: ActionChainSet, clicks, *,
                   expose=20):
    u = clicks.shape[0]
    out = np.zeros((u, chains.n_chains), np.float32)
    k_rank = chains.n_stages - 1
    for j in range(chains.n_chains):
        n1 = int(chains.scale_value[j, 0])
        n2 = int(chains.scale_value[j, 1])
        n3 = int(chains.scale_value[j, 2])
        mi = int(chains.chain_idx[j, k_rank, 0])
        rank_name = chains.stages[k_rank].models[mi].name
        out[:, j] = _seed_run_chain(stage_scores, (n1, n2, n3, rank_name),
                                    clicks, expose=expose)
    return out


def _time(fn, *, repeats: int) -> float:
    fn()  # warmup (jit compile for the vectorized path)
    times = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        fn()
        times.append(time.perf_counter() - t0)
    return float(min(times))


def _time_interleaved(fns: list, *, repeats: int) -> list[float]:
    """min-of-N with the candidates ALTERNATED, so a load swing on a
    shared machine hits all of them instead of skewing the ratio."""
    for fn in fns:
        fn()  # warmup
    mins = [float("inf")] * len(fns)
    for _ in range(repeats):
        for k, fn in enumerate(fns):
            t0 = time.perf_counter()
            fn()
            mins[k] = min(mins[k], time.perf_counter() - t0)
    return mins


def run(*, users: int = 160, items: int = 200, expose: int = 8,
        repeats: int = 25, json_path: str | None = None,
        check_speedup: bool = False) -> dict:
    """Measure seed loop vs rank-based engine; optionally write JSON."""
    u, i, e = users, items, expose
    rng = np.random.default_rng(0)
    # float32: the dtype the real pipeline produces (jax model scores)
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.1).astype(np.float32)
    # 8 x 8 x 2 = 128 chains (J in the acceptance config)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 8))
    n3 = tuple(int(x) for x in np.linspace(e, 0.2 * i, 8))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))

    t_seed, t_vec = _time_interleaved(
        [lambda: _seed_simulate(scores, chains, clicks, expose=e),
         lambda: simulate_revenue_matrix(scores, chains, clicks, expose=e)],
        repeats=repeats)
    t_ref = _time(lambda: simulate_revenue_matrix_reference(
        scores, chains, clicks, expose=e), repeats=max(2, repeats // 8))

    vec = simulate_revenue_matrix(scores, chains, clicks, expose=e)
    ref = simulate_revenue_matrix_reference(scores, chains, clicks, expose=e)
    seed = _seed_simulate(scores, chains, clicks, expose=e)
    exact_vs_ref = bool(np.array_equal(vec, ref))
    # seed used different (argpartition) tie handling; on the tie-free
    # random scores here the exposed sets coincide, so values match too
    exact_vs_seed = bool(np.array_equal(vec, seed.astype(np.float32)))

    result = {
        "config": {"users": u, "items": i, "chains": chains.n_chains,
                   "expose": e, "repeats": repeats},
        "seed_loop_s": round(t_seed, 5),
        "numpy_reference_s": round(t_ref, 5),
        "vectorized_s": round(t_vec, 5),
        "speedup_vs_seed": round(t_seed / t_vec, 2),
        "speedup_vs_reference": round(t_ref / t_vec, 2),
        "exact_match_vs_reference": exact_vs_ref,
        "exact_match_vs_seed": exact_vs_seed,
    }
    if json_path is not None:
        from repro.obs.env import env_info
        result["env"] = env_info()
        path = os.path.abspath(json_path)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(json.dumps(result, indent=2))
        print(f"[bench_chain_sim] wrote {path}")
    # exactness is deterministic: always enforced.  The speedup gate is
    # wall-clock and flaky on shared runners, so it is opt-in.
    assert exact_vs_ref, "vectorized != reference"
    if check_speedup:
        assert result["speedup_vs_seed"] >= 5.0, result
    return result


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_chain_sim.json"))
    ap.add_argument("--users", type=int, default=160)
    ap.add_argument("--items", type=int, default=200)
    ap.add_argument("--expose", type=int, default=8)
    # min-of-N timing: N high enough to catch a quiet slice of a noisy
    # shared machine (each vectorized repeat is tens of ms)
    ap.add_argument("--repeats", type=int, default=25)
    ap.add_argument("--check-speedup", action="store_true",
                    help="assert the >=5x speedup (wall-clock: only "
                         "meaningful on an otherwise idle machine)")
    args = ap.parse_args()
    return run(users=args.users, items=args.items, expose=args.expose,
               repeats=args.repeats, json_path=args.json,
               check_speedup=args.check_speedup)


if __name__ == "__main__":
    main()
