"""Scale benchmark: the streaming request path at U >= 100k.

    PYTHONPATH=src python benchmarks/bench_scale.py [--json PATH]
    PYTHONPATH=src python benchmarks/bench_scale.py --small   # CI smoke

The materialized serving path precomputes (U, I) stage scores, (U, I)
clicks and (G, U, cap) compact tables before the first request; host
memory scales with the universe.  This benchmark drives the SAME fused
geotenants pipeline (per-tenant dual prices x per-region caps, one
jitted pass) from a ``GeneratedSource`` - every window generated,
scored and compacted on the fly - and measures what the streaming
refactor claims:

  * requests/sec end-to-end (prefetched ``run_stream``: a background
    worker builds windows ahead of the serving thread; tables compact
    ON DEVICE and the dual chain runs donated) and the serve-only
    window latency (p50/p99, host-blocked), with a per-run
    prep/stall/h2d breakdown;
  * the same big universe through the exact PR 6 path (host table
    compaction, sequential prep, undonated dual) - bitwise-identical
    decisions, a host->device transfer comparison, and a >= 2x
    throughput gate on full-size runs with >= 4 cores (the overlap
    claim needs parallel hardware; below that the speedup is
    report-only, like ci.yml skipping wall-clock speedup asserts);
  * peak host RSS at a small universe vs U >= 100k under an IDENTICAL
    window schedule - the gate asserts the delta stays under
    --rss-gate-mb, i.e. nothing anywhere allocates O(U) (for scale,
    the JSON also reports what materializing U would cost);
  * jit recompiles per window under decade-ladder traffic swings
    (1x..--spike x): with pow2 bucketed padding every shape compiles
    once, and the gate asserts ZERO steady-state recompiles;
  * the small-U parity gate: replaying the materialized server's own
    universe through the chunked path (``TableReplaySource``) is
    BITWISE identical - decisions, revenues, prices, spends - in both
    the plain and the geotenants pipeline;
  * the big universe again with the FULL repro.obs telemetry stack
    live (metrics registry + span tracer + JSONL window exporter):
    bitwise-identical to the telemetry-off run, and on full-size
    multi-core runs a <2% throughput-overhead gate.

Everything model-sized stays at the cached --small serving stack; only
the user universe scales, which is exactly the point.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _vm_mb(key: str = "VmRSS:") -> float:
    """Current (VmRSS:) or peak (VmHWM:) resident set, MB."""
    try:
        with open("/proc/self/status") as f:
            for line in f:
                if line.startswith(key):
                    return int(line.split()[1]) / 1024.0
    except OSError:
        pass
    return float("nan")


class _MeteredSource:
    """Wraps a RequestSource; samples VmRSS after each window build."""

    def __init__(self, src):
        self._src = src
        self.rss_mb: list[float] = []

    def window(self, t, n):
        chunk = self._src.window(t, n)
        self.rss_mb.append(_vm_mb())
        return chunk


def _geotenants_spec(chains, n_base, budget_frac, t_n=2, r_n=2):
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    per_req = budget_frac * float(chains.costs.max())
    total = per_req * n_base
    spec = ConstraintSpec([
        TenantAxis(tuple(np.full(t_n, total / t_n)), priced=True),
        RegionAxis(r_n, names=("region_a", "region_b")),
        GlobalAxis(pricing="carbon"),
    ])
    scale = np.array([1.0, 1.3], np.float32)  # region cost ratios

    def traces(sizes):
        """Budgets scale with the window (tenant grams first, then the
        per-region caps at 60% of the total); cost scales are fixed."""
        bt, st_ = [], []
        for n in sizes:
            tot = per_req * n
            bt.append(np.concatenate([np.full(t_n, tot / t_n),
                                      np.full(r_n, 0.6 * tot)])
                      .astype(np.float32))
            st_.append(scale)
        return bt, st_

    return spec, traces


def _parity_gate(exp, server, params, rcfg, *, windows=6, base=48,
                 budget_frac=0.5) -> dict:
    """Small-U bitwise gate: the chunked TableReplaySource path against
    indexing the materialized server - same arrivals, free-running
    prices - in the plain AND the geotenants pipeline."""
    from repro.data.request_source import TableReplaySource
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import (TrafficScenario, run_stream,
                                      scenario_windows)

    chains = exp.chains
    budget = budget_frac * float(chains.costs.max()) * base
    src = TableReplaySource.from_server(server, exp.ctx_eval, seed=7)

    def sample(t, n):
        rows = src.arrivals(t, n)
        return exp.ctx_eval[rows], rows

    checked = 0
    for mode in ("plain", "geotenants"):
        sc = TrafficScenario("spike", windows, base, spike_mult=3.0,
                             n_tenants=2 if mode == "geotenants" else 1)
        sizes = scenario_windows(sc)
        if mode == "plain":
            pipe_m = ServingPipeline(server, params, rcfg, budget)
            pipe_s = ServingPipeline(src.universe, params, rcfg, budget)
            kw = {}
        else:
            spec, traces = _geotenants_spec(chains, base, budget_frac)
            bt, st_ = traces(sizes)
            pipe_m = ServingPipeline.from_spec(server, params, rcfg,
                                               spec)
            pipe_s = ServingPipeline.from_spec(src.universe, params,
                                               rcfg, spec)
            kw = {"budget_trace": bt, "scale_trace": st_}
        res_m = run_stream(pipe_m, sizes, sample, **kw)
        res_s = run_stream(pipe_s, sizes, src, **kw)
        for t, (a, b) in enumerate(zip(res_m.windows, res_s.windows)):
            tag = f"{mode} w{t}"
            assert np.array_equal(a.decisions_np, b.decisions_np), tag
            assert np.array_equal(a.revenue_np, b.revenue_np), tag
            assert np.array_equal(np.asarray(a.spend),
                                  np.asarray(b.spend)), tag
            assert np.array_equal(np.asarray(a.lam_after),
                                  np.asarray(b.lam_after)), tag
            checked += 1
    return {"bitwise": True, "windows_checked": checked,
            "modes": ["plain", "geotenants"]}


def _swing_run(exp, params, rcfg, *, n_users, sizes, lat_sizes,
               budget_frac=0.5, chunk=512, device_tables=True,
               prefetch=2, donate=True, telemetry=False):
    """One streamed geotenants run at ``n_users``: a prefetched
    throughput pass over ``sizes``, then a host-blocked latency pass
    over ``lat_sizes`` on the same warm pipeline.

    ``device_tables=False, prefetch=0, donate=False`` reproduces the
    PR 6 serving path exactly (host table compaction, sequential
    double-buffered prep, undonated dual chain) - the baseline the
    zero-stall claim is measured against.  ``telemetry=True`` runs with
    the FULL repro.obs stack live (enabled registry, span tracer,
    JSONL window exporter) - the arm the <2% overhead gate compares
    against the telemetry-off twin.  Returns ``(metrics,
    stream_stats)`` so callers can bitwise-compare the modes."""
    import jax

    from dataclasses import replace

    from repro.data.request_source import GeneratedSource
    from repro.data.synthetic import StreamingWorld
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    obs = None
    if telemetry:
        import tempfile

        from repro.obs import Obs, WindowEventLog
        obs = Obs(events=WindowEventLog(os.path.join(
            tempfile.mkdtemp(prefix="bench_scale_obs_"),
            "windows.jsonl")))
    chains = exp.chains
    wcfg = replace(exp.cfg.world, n_users=n_users)
    gen = GeneratedSource(StreamingWorld.build(wcfg), exp.models,
                          chains, expose=exp.cfg.expose, seed=5,
                          chunk=chunk, device_tables=device_tables,
                          obs=obs)
    spec, traces = _geotenants_spec(chains, sizes[0], budget_frac)
    pipe = ServingPipeline.from_spec(gen.universe, params, rcfg, spec,
                                     bucketing="pow2",
                                     donate_dual=donate, obs=obs)
    src = _MeteredSource(gen)
    bt, st_ = traces(sizes)
    rss0 = _vm_mb()
    st = run_stream(pipe, sizes, src, budget_trace=bt, scale_trace=st_,
                    prefetch=prefetch, obs=obs)
    total_req = int(sum(sizes))

    # serve-only latency: chunk built first, then submit -> results
    # host-ready (the nearline price chains on-device, off this path).
    # Device-built tables are ASYNC futures - force them before the
    # timer so table production stays attributed to prep, not serve.
    lat_s = []
    bt2, st2 = traces(lat_sizes)
    for i, n in enumerate(lat_sizes):
        c = gen.window(1000 + i, n)
        jax.block_until_ready(c.tables)
        t0 = time.perf_counter()
        r = pipe.serve_window(c.ctx, c.rows, tables=c.tables,
                              budget=bt2[i], cost_scale=st2[i])
        jax.block_until_ready((r.decisions, r.revenue, r.spend))
        lat_s.append(time.perf_counter() - t0)

    metrics = {
        "n_users": int(n_users),
        "mode": {"device_tables": bool(device_tables),
                 "prefetch": int(prefetch), "donate_dual": bool(donate),
                 "telemetry": bool(telemetry)},
        "sizes": [int(n) for n in sizes],
        "requests": total_req,
        "wall_s": round(st.wall_s, 3),
        "requests_per_sec": round(total_req / st.wall_s, 1),
        "compiles_per_window": st.compiles,
        "steady_state_recompiles": int(st.steady_compiles),
        "compiled_buckets": len({r.bucket for r in st.windows}),
        "prep_ms_total": round(float(sum(st.prep_ms)), 1),
        "stall_ms_total": round(float(sum(st.stall_ms)), 1),
        "submit_ms_total": round(float(sum(st.submit_ms)), 1),
        "h2d_mb": round(st.h2d_bytes / 1e6, 2),
        "table_cache": {"hits": int(gen.cache_hits),
                        "misses": int(gen.cache_misses)},
        "p50_window_ms": round(float(np.percentile(lat_s, 50)) * 1e3, 2),
        "p99_window_ms": round(float(np.percentile(lat_s, 99)) * 1e3, 2),
        "latency_sizes": [int(n) for n in lat_sizes],
        "rss_before_mb": round(rss0, 1),
        "peak_rss_mb": round(max(src.rss_mb), 1),
        "vm_hwm_mb": round(_vm_mb("VmHWM:"), 1),
        "total_revenue": round(st.total_revenue, 2),
    }
    return metrics, st


def run(*, users_small: int = 20_000, users_big: int = 150_000,
        base: int = 16, spike: float = 1000.0, cycles: int = 2,
        budget_frac: float = 0.5, rss_gate_mb: float = 200.0,
        small: bool = False, json_path: str | None = None) -> dict:
    from repro.experiments import build_serving_stack, serve_config
    from repro.serving.stream import TrafficScenario, scenario_windows

    if small:  # CI smoke: 3 decades, one ladder cycle, shorter latency
        spike, cycles = min(spike, 100.0), 1
    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=True), verbose=True)

    print("[bench_scale] parity gate (small U, bitwise) ...")
    parity = _parity_gate(exp, server, params, rcfg)
    print(f"[bench_scale] parity OK over {parity['windows_checked']} "
          f"windows ({'+'.join(parity['modes'])})")

    decades = max(1, int(np.log10(max(10.0, spike))) + 1)
    sc = TrafficScenario("swing", decades * cycles, base,
                         spike_mult=spike, n_tenants=2)
    sizes = scenario_windows(sc)
    lat_sizes = scenario_windows(
        TrafficScenario("swing", decades, base, spike_mult=spike,
                        n_tenants=2))
    runs = {}
    streams = {}
    plans = (
        ("small_universe", users_small, {}),
        ("big_universe", users_big, {}),
        ("big_universe_pr6", users_big,
         {"device_tables": False, "prefetch": 0, "donate": False}),
        ("big_universe_obs", users_big, {"telemetry": True}),
    )
    for label, n_users, mode_kw in plans:
        print(f"[bench_scale] {label}: U={n_users:,}, "
              f"windows {sizes} ...")
        runs[label], streams[label] = _swing_run(
            exp, params, rcfg, n_users=n_users, sizes=sizes,
            lat_sizes=lat_sizes, budget_frac=budget_frac, **mode_kw)
        r = runs[label]
        print(f"[bench_scale]   {r['requests_per_sec']} req/s, "
              f"p99 {r['p99_window_ms']} ms, prep "
              f"{r['prep_ms_total']} ms, stall {r['stall_ms_total']} "
              f"ms, h2d {r['h2d_mb']} MB, peak RSS "
              f"{r['peak_rss_mb']} MB, steady recompiles "
              f"{r['steady_state_recompiles']}")

    # cross-mode parity: the device-table + prefetched + donated path
    # must reproduce the PR 6 host path bitwise at the big universe
    for t, (a, b) in enumerate(zip(streams["big_universe"].windows,
                                   streams["big_universe_pr6"].windows)):
        tag = f"mode parity w{t}"
        assert np.array_equal(a.decisions_np, b.decisions_np), tag
        assert np.array_equal(a.revenue_np, b.revenue_np), tag
        assert np.array_equal(np.asarray(a.spend),
                              np.asarray(b.spend)), tag
        assert np.array_equal(np.asarray(a.lam_after),
                              np.asarray(b.lam_after)), tag
    print(f"[bench_scale] mode parity OK over "
          f"{len(streams['big_universe'].windows)} windows "
          f"(device+prefetch+donate vs PR 6 path, bitwise)")
    # telemetry parity: the full obs stack (registry + tracer + JSONL
    # exporter) must not perturb a single decision, spend or price
    for t, (a, b) in enumerate(zip(streams["big_universe"].windows,
                                   streams["big_universe_obs"].windows)):
        tag = f"obs parity w{t}"
        assert np.array_equal(a.decisions_np, b.decisions_np), tag
        assert np.array_equal(a.revenue_np, b.revenue_np), tag
        assert np.array_equal(np.asarray(a.spend),
                              np.asarray(b.spend)), tag
        assert np.array_equal(np.asarray(a.lam_after),
                              np.asarray(b.lam_after)), tag
    print(f"[bench_scale] telemetry parity OK over "
          f"{len(streams['big_universe'].windows)} windows "
          f"(obs on vs off, bitwise)")
    speedup = (runs["big_universe"]["requests_per_sec"]
               / runs["big_universe_pr6"]["requests_per_sec"])
    print(f"[bench_scale] big-universe speedup vs PR 6 path: "
          f"{speedup:.2f}x")
    obs_overhead_pct = (runs["big_universe"]["requests_per_sec"]
                        / runs["big_universe_obs"]["requests_per_sec"]
                        - 1.0) * 100.0
    print(f"[bench_scale] telemetry overhead: "
          f"{obs_overhead_pct:+.2f}% throughput")

    # what the retired path would have allocated at U_big: four (U, I)
    # float32 stage-score matrices, a (U, I) click matrix and the
    # (G, U, cap) int+float compact tables
    i_n = exp.cfg.world.n_items
    g_n = int(server.compact.p_sorted.shape[0])
    cap = int(server.compact.cap)
    mat_mb = (users_big * i_n * 4 * 5 +
              users_big * g_n * cap * 8) / 1e6
    delta = (runs["big_universe"]["peak_rss_mb"]
             - runs["small_universe"]["peak_rss_mb"])
    result = {
        "config": {"base": base, "spike": spike, "cycles": cycles,
                   "budget_frac": budget_frac, "small": small,
                   "users_small": users_small, "users_big": users_big,
                   "n_items": int(i_n), "chains": exp.chains.n_chains,
                   "pipeline": "geotenants (2 tenants x 2 regions, "
                               "pow2 buckets)"},
        "parity_gate": parity,
        "runs": runs,
        "speedup_vs_pr6": round(speedup, 2),
        "obs_overhead_pct": round(obs_overhead_pct, 2),
        "peak_rss_delta_mb": round(delta, 1),
        "rss_gate_mb": rss_gate_mb,
        "materialized_tables_mb_at_big": round(mat_mb, 1),
        "steady_state_recompiles": int(
            sum(r["steady_state_recompiles"] for r in runs.values())),
    }
    assert result["steady_state_recompiles"] == 0, \
        "bucketed padding must keep the jit cache warm in steady state"
    assert delta < rss_gate_mb, (
        f"peak RSS grew {delta:.1f} MB from U={users_small:,} to "
        f"U={users_big:,} (gate {rss_gate_mb} MB): something allocates "
        f"O(U)")
    # the 2x claim is an OVERLAP claim: prefetch + device tables only
    # buy wall-clock when host prep and device execution can run on
    # different hardware.  Arm it on full-size multi-core runs; on a
    # single/dual-core host the two modes do the same serial work and
    # a wall-clock gate would only measure scheduler noise (same
    # policy as ci.yml skipping bench_chain_sim's --check-speedup).
    cores = os.cpu_count() or 1
    gated_speedup = (not small) and cores >= 4
    result["speedup_gate"] = (
        "armed" if gated_speedup else
        f"report-only ({'--small run' if small else f'{cores} cores'}: "
        f"prefetch overlap needs parallel hardware)")
    if gated_speedup:
        assert speedup >= 2.0, (
            f"big-universe throughput {speedup:.2f}x the PR 6 path "
            f"(gate: >= 2x): the zero-stall claim regressed")
    # the <2% telemetry budget is likewise a wall-clock measurement:
    # arm it on full-size multi-core runs, report-only elsewhere
    result["obs_overhead_gate"] = (
        "armed" if gated_speedup else
        f"report-only ({'--small run' if small else f'{cores} cores'}: "
        f"sub-2% deltas need a full-size run to rise above noise)")
    if gated_speedup:
        assert obs_overhead_pct < 2.0, (
            f"telemetry costs {obs_overhead_pct:.2f}% throughput "
            f"(gate: < 2%): observability must stay free-ish")
    result["gates"] = {"zero_steady_recompiles": True,
                       "rss_flat_wrt_users": True,
                       "bitwise_parity": True,
                       "mode_parity_bitwise": True,
                       "obs_parity_bitwise": True,
                       "speedup_2x": bool(gated_speedup),
                       "obs_overhead_lt_2pct": bool(gated_speedup)}
    if json_path is not None:
        from repro.obs.env import env_info
        result["env"] = env_info()
        path = os.path.abspath(json_path)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(json.dumps(result, indent=2))
        print(f"[bench_scale] wrote {path}")
    return result


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json",
                    default=os.path.join(REPO, "BENCH_scale.json"))
    ap.add_argument("--small", action="store_true",
                    help="CI smoke: 100x swings, one ladder cycle")
    ap.add_argument("--users-small", type=int, default=20_000)
    ap.add_argument("--users", type=int, default=150_000,
                    help="the big universe (the U >= 100k claim)")
    ap.add_argument("--base", type=int, default=16,
                    help="requests in a 1x window (decades multiply it)")
    ap.add_argument("--spike", type=float, default=1000.0,
                    help="top of the decade ladder (1000 = 4 decades)")
    ap.add_argument("--cycles", type=int, default=2,
                    help="ladder repetitions in the throughput pass")
    ap.add_argument("--budget-frac", type=float, default=0.5)
    ap.add_argument("--rss-gate-mb", type=float, default=200.0)
    args = ap.parse_args()
    return run(users_small=args.users_small, users_big=args.users,
               base=args.base, spike=args.spike, cycles=args.cycles,
               budget_frac=args.budget_frac,
               rss_gate_mb=args.rss_gate_mb, small=args.small,
               json_path=args.json)


if __name__ == "__main__":
    main()
