"""Multi-host request-mesh benchmark: throughput scaling + parity.

    PYTHONPATH=src python benchmarks/bench_multihost.py [--fast]

Sweeps the SAME stream over jax.distributed process groups of 1 / 2 /
4 / 8 local processes (each with ``8 / P`` fake host devices, so the
global shard count - and therefore every padded shape and every
stitched collective - is identical at every P).  Per process group it
reports:

  * per-process and aggregate request throughput (req/s) with the
    stall / prep / submit / h2d breakdown from ``StreamStats``;
  * a BITWISE decision-parity gate: every P's stitched decisions, lam
    trace and per-window spends must equal the single-process
    reference exactly (the fixed-shard-count invariant that makes
    elastic re-sharding safe);
  * zero steady-state recompiles on every host;
  * one Perfetto trace per host, merged into a single
    ``multihost_trace.json`` whose track groups are the hosts
    (``Tracer(process_label=...)`` -> ``merge_chrome_traces``), plus
    per-host JSONL flight logs carrying the ``host`` label.

The near-linear scaling assertion is HARDWARE-GATED: P processes on
fewer than P cores time-slice one CPU, so speedup is meaningless
there.  On < 4 cores the sweep is report-only; at >= 4 cores the gate
arms and requires aggregate throughput at P=4 to reach at least half
of linear (efficiency >= 0.5) over P=1.

Writes BENCH_multihost.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

CHILD = textwrap.dedent("""
    import json, os, sys
    import numpy as np

    from repro.distributed import multihost as mh

    dist = mh.initialize()
    import jax
    import jax.numpy as jnp

    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import RewardModelConfig, reward_model_init
    from repro.data.request_source import TableReplaySource
    from repro.launch.mesh import make_request_mesh, process_shard_rows
    from repro.obs import Obs, WindowEventLog
    from repro.serving.pipeline import ServingPipeline, window_layout
    from repro.serving.stream import run_stream

    sizes = json.loads(os.environ["MH_SIZES"])
    art = os.environ["MH_ART_DIR"]
    host = mh.host_label()

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    ctx = np.random.default_rng(5).normal(size=(u, 12)).astype(np.float32)
    src = TableReplaySource.from_server(server, ctx, seed=7,
                                        device_tables=False)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    budget = 0.5 * float(chains.costs.max()) * 64
    mesh = make_request_mesh()
    pipe = ServingPipeline(src.universe, params, rcfg, budget, mesh=mesh)

    obs = Obs(host=host, events=WindowEventLog(
        os.path.join(art, f"windows.{host}.jsonl")))
    source = mh.MultihostSource(src, pipe) if dist else src
    stats = run_stream(pipe, sizes, source, prefetch=1, obs=obs)
    trace = obs.tracer.write(os.path.join(art, f"trace.{host}.json"))

    windows = []
    for t, (r, n) in enumerate(zip(stats.windows, sizes)):
        if dist:
            b = pipe.window_bucket(n)
            perm, valid, _ = window_layout(n, b, None)
            rows_g = np.concatenate(
                [np.arange(lo, hi) for lo, hi in
                 process_shard_rows(pipe.mesh, b)])
            req = perm[rows_g[valid[rows_g] > 0]]
        else:
            req = np.arange(n)
        windows.append({
            "req": req.tolist(),
            "dec": np.asarray(r.decisions_np).tolist(),
            "lam": np.asarray(mh._host_value(r.lam_after),
                              np.float64).reshape(-1).tolist(),
            "spend": np.asarray(mh._host_value(r.spend),
                                np.float64).reshape(-1).tolist(),
        })
    local_req = sum(len(w["req"]) for w in windows)
    out = {
        "host": mh.host_report(), "label": host, "trace": trace,
        "wall_s": float(stats.wall_s),
        "local_requests": local_req,
        "local_req_per_s": local_req / stats.wall_s,
        "submit_ms": float(sum(stats.submit_ms)),
        "prep_ms": float(sum(stats.prep_ms)),
        "stall_ms": float(sum(stats.stall_ms)),
        "h2d_bytes": int(stats.h2d_bytes),
        "steady_compiles": int(stats.steady_compiles),
        "windows": windows,
    }
    with open(os.environ["MH_OUT"], "w") as f:
        json.dump(out, f)
    print("BENCH CHILD OK", host, flush=True)
""")


def _free_port() -> int:
    s = socket.socket()
    s.bind(("localhost", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _launch_group(n_procs: int, sizes: list[int], art_dir: str,
                  cache_dir: str | None, timeout: int) -> list[dict]:
    assert 8 % n_procs == 0
    os.makedirs(art_dir, exist_ok=True)
    port = _free_port()
    procs = []
    for pid in range(n_procs):
        out = os.path.join(art_dir, f"digest_{pid}.json")
        env = dict(os.environ)
        env.update({
            "PYTHONPATH": os.path.join(REPO, "src"),
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": ("--xla_force_host_platform_device_count="
                          f"{8 // n_procs}"),
            "MH_SIZES": json.dumps(sizes),
            "MH_ART_DIR": art_dir, "MH_OUT": out,
        })
        if cache_dir:
            env["JAX_COMPILATION_CACHE_DIR"] = cache_dir
        if n_procs > 1:
            env.update({
                "GREENFLOW_COORDINATOR": f"localhost:{port}",
                "GREENFLOW_NUM_PROCESSES": str(n_procs),
                "GREENFLOW_PROCESS_ID": str(pid),
            })
        procs.append((out, subprocess.Popen(
            [sys.executable, "-c", CHILD], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True)))
    digests = []
    for out, p in procs:
        o, _ = p.communicate(timeout=timeout)
        if p.returncode != 0:
            raise RuntimeError(f"bench child failed ({out}):\n{o[-4000:]}")
        with open(out) as f:
            digests.append(json.load(f))
    return digests


def _stitch(children: list[dict], t: int, key: str) -> np.ndarray:
    req = np.concatenate([np.asarray(c["windows"][t]["req"], np.int64)
                          for c in children])
    val = np.concatenate([np.asarray(c["windows"][t][key])
                          for c in children])
    order = np.argsort(req)
    if not (req[order] == np.arange(len(req))).all():
        raise AssertionError("stitched request ids are not a permutation")
    return val[order]


def _check_parity(ref: dict, children: list[dict]) -> None:
    """Bitwise: stitched decisions + replicated lam/spend vs P=1."""
    for t in range(len(ref["windows"])):
        rw = ref["windows"][t]
        for c in children:
            if c["windows"][t]["lam"] != rw["lam"]:
                raise AssertionError(f"lam diverged at window {t} on "
                                     f"{c['label']}")
            if c["windows"][t]["spend"] != rw["spend"]:
                raise AssertionError(f"spend diverged at window {t} on "
                                     f"{c['label']}")
        dec = (_stitch(children, t, "dec") if len(children) > 1
               else np.asarray(rw["dec"]))
        if not np.array_equal(dec, np.asarray(rw["dec"])):
            raise AssertionError(f"decisions diverged at window {t}")


def run(*, procs: tuple[int, ...] = (1, 2, 4, 8),
        sizes: list[int] | None = None, json_path: str | None = None,
        cache_dir: str | None = None, trace_out: str | None = None,
        timeout: int = 900) -> dict:
    from repro.obs.env import env_info
    from repro.obs.trace import merge_chrome_traces

    if sizes is None:
        sizes = [256, 512, 256, 384, 256, 256]
    total_req = sum(sizes)
    base = os.path.join(REPO, "results", "obs", "multihost")
    sweep: list[dict] = []
    ref_children: list[dict] | None = None
    for p in procs:
        art = os.path.join(base, f"p{p}")
        children = _launch_group(p, sizes, art, cache_dir, timeout)
        if p == 1:
            ref_children = children
        if ref_children is not None:
            _check_parity(ref_children[0], children)
        for c in children:
            # P=1 may pay a one-time donated-lam relayout retrace per
            # bucket; the multihost path replicates lam globally before
            # window 0, so its steady state must be exactly zero.
            if p > 1 and c["steady_compiles"]:
                raise AssertionError(
                    f"{c['label']} (P={p}): {c['steady_compiles']} "
                    "steady-state recompiles")
        wall = max(c["wall_s"] for c in children)
        row = {
            "processes": p,
            "devices_per_process": 8 // p,
            "global_shards": 8,
            "wall_s": wall,
            "aggregate_req_per_s": total_req / wall,
            "per_process": [{
                "label": c["label"],
                "wall_s": c["wall_s"],
                "req_per_s": c["local_req_per_s"],
                "local_requests": c["local_requests"],
                "submit_ms": c["submit_ms"],
                "prep_ms": c["prep_ms"],
                "stall_ms": c["stall_ms"],
                "h2d_bytes": c["h2d_bytes"],
            } for c in children],
            "bitwise_parity_vs_p1": True,
            "steady_compiles": max(c["steady_compiles"]
                                   for c in children),
        }
        sweep.append(row)
        print(f"[bench_multihost] P={p}: {row['aggregate_req_per_s']:.1f}"
              f" req/s aggregate over {wall:.1f}s, parity OK",
              flush=True)

    # merge every host's Perfetto trace into one multi-track file
    paths = [c["trace"] for p_row, p in zip(sweep, procs)
             for c in _read_group(base, p)]
    if trace_out is None:
        trace_out = os.path.join(base, "multihost_trace.json")
    merged = merge_chrome_traces(paths, out_path=trace_out)

    cores = os.cpu_count() or 1
    gate_p = max((p for p in procs if p <= cores), default=1)
    scaling = {
        "cpu_cores": cores,
        "gate_armed": cores >= 4 and len(procs) > 1,
        "gate_processes": gate_p,
        "min_efficiency": 0.5,
    }
    by_p = {r["processes"]: r["aggregate_req_per_s"] for r in sweep}
    if scaling["gate_armed"] and 1 in by_p and gate_p in by_p:
        eff = by_p[gate_p] / (gate_p * by_p[1])
        scaling["efficiency"] = eff
        if eff < scaling["min_efficiency"]:
            raise AssertionError(
                f"scaling gate: P={gate_p} efficiency {eff:.2f} < 0.5")
    elif 1 in by_p and len(by_p) > 1:
        hi = max(p for p in by_p if p > 1)
        scaling["efficiency_report_only"] = by_p[hi] / (hi * by_p[1])

    out = {
        "benchmark": "multihost",
        "sizes": sizes,
        "total_requests": total_req,
        "sweep": sweep,
        "scaling": scaling,
        "merged_trace": trace_out,
        "merged_trace_events": len(merged["traceEvents"]),
        "env": env_info(),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[bench_multihost] wrote {json_path}")
    return out


def _read_group(base: str, p: int) -> list[dict]:
    art = os.path.join(base, f"p{p}")
    out = []
    for pid in range(p):
        with open(os.path.join(art, f"digest_{pid}.json")) as f:
            out.append(json.load(f))
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json",
                    default=os.path.join(REPO, "BENCH_multihost.json"))
    ap.add_argument("--fast", action="store_true",
                    help="P in {1, 2} with short windows (smoke)")
    ap.add_argument("--procs", type=int, nargs="+", default=None,
                    help="process counts to sweep (must divide 8)")
    ap.add_argument("--cache-dir", default=None,
                    help="JAX_COMPILATION_CACHE_DIR for the children")
    ap.add_argument("--trace-out", default=None,
                    help="merged Perfetto trace path")
    args = ap.parse_args(argv)
    procs = tuple(args.procs) if args.procs else (
        (1, 2) if args.fast else (1, 2, 4, 8))
    sizes = [64, 96, 64] if args.fast else None
    run(procs=procs, sizes=sizes, json_path=args.json,
        cache_dir=args.cache_dir, trace_out=args.trace_out)


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(REPO, "src"))
    main()
