"""Roofline analysis from the dry-run artifacts (brief: ROOFLINE ANALYSIS).

Reads results/dryrun/*.json (written by repro.launch.dryrun), computes the
three roofline terms per (arch x shape x mesh) from PER-DEVICE quantities
(XLA cost_analysis reports the partitioned per-device program - calibrated
in EXPERIMENTS.md §Dry-run), and emits a CSV + markdown table.

    T_compute    = flops_dev / 197e12          (bf16 peak per chip)
    T_memory     = bytes_dev / 819e9           (HBM bw per chip)
    T_collective = coll_bytes_dev / 50e9       (ICI per-link bw)

Loop-corrected values (scan bodies counted once by XLA) are used when the
cell provides them.  MODEL_FLOPS / HLO_FLOPS uses GLOBAL model flops vs
flops_dev * n_chips.
"""
from __future__ import annotations

import glob
import json
import os

PEAK = 197e12
HBM = 819e9
ICI = 50e9

RESULTS = os.path.join(os.path.dirname(__file__), "..", "results", "dryrun")


def load_records(results_dir: str = RESULTS) -> list[dict]:
    recs = []
    for path in sorted(glob.glob(os.path.join(results_dir, "*.json"))):
        with open(path) as f:
            recs.append(json.load(f))
    return recs


def roofline_row(rec: dict) -> dict | None:
    if rec.get("skipped"):
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "skipped": rec["reason"]}
    if not rec.get("ok"):
        return {"arch": rec["arch"], "shape": rec["shape"],
                "mesh": rec["mesh"], "error": rec.get("error", "?")}
    corr = rec.get("corrected", {})
    ca = rec.get("cost_analysis", {})
    co = rec.get("collectives", {})
    flops = corr.get("flops", ca.get("flops", 0.0))
    byts = corr.get("bytes_accessed", ca.get("bytes_accessed", 0.0))
    coll = corr.get("collective_total", co.get("total", 0))
    n = rec["n_chips"]
    t_c = flops / PEAK
    t_m = byts / HBM
    t_x = coll / ICI
    dominant = max((("compute", t_c), ("memory", t_m),
                    ("collective", t_x)), key=lambda kv: kv[1])[0]
    model_flops = rec.get("meta", {}).get("model_flops", 0.0)
    hlo_global = flops * n
    t_bound = max(t_c, t_m, t_x)
    # roofline fraction: useful model compute / (chips * peak * bound time)
    frac = (model_flops / (n * PEAK * t_bound)) if t_bound > 0 else 0.0
    mem = rec.get("memory_analysis", {})
    return {
        "arch": rec["arch"], "shape": rec["shape"], "mesh": rec["mesh"],
        "kind": rec.get("kind", "?"),
        "t_compute_s": t_c, "t_memory_s": t_m, "t_collective_s": t_x,
        "dominant": dominant,
        "model_flops": model_flops,
        "hlo_flops_global": hlo_global,
        "useful_ratio": model_flops / hlo_global if hlo_global else 0.0,
        "roofline_frac": frac,
        "temp_gb": mem.get("temp_size_in_bytes", 0) / 1e9,
        "arg_gb": mem.get("argument_size_in_bytes", 0) / 1e9,
    }


def full_table(results_dir: str = RESULTS) -> list[dict]:
    rows = [roofline_row(r) for r in load_records(results_dir)]
    return [r for r in rows if r is not None]


def markdown_table(rows: list[dict], mesh: str = "16x16") -> str:
    """EXPERIMENTS.md §Roofline table (single-pod per the brief)."""
    hdr = ("| arch | shape | T_comp (ms) | T_mem (ms) | T_coll (ms) | "
           "dominant | useful | roofline frac | temp GB |")
    sep = "|" + "---|" * 9
    lines = [hdr, sep]
    for r in rows:
        if r.get("mesh") != mesh:
            continue
        if "skipped" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"SKIP | - | - | - |")
            continue
        if "error" in r:
            lines.append(f"| {r['arch']} | {r['shape']} | - | - | - | "
                         f"ERROR | - | - | - |")
            continue
        lines.append(
            f"| {r['arch']} | {r['shape']} | {r['t_compute_s']*1e3:.2f} | "
            f"{r['t_memory_s']*1e3:.2f} | {r['t_collective_s']*1e3:.2f} | "
            f"{r['dominant']} | {r['useful_ratio']:.2f} | "
            f"{r['roofline_frac']:.2%} | {r['temp_gb']:.1f} |")
    return "\n".join(lines)


def main():
    rows = full_table()
    for r in rows:
        if "skipped" in r or "error" in r:
            continue
        print(f"roofline,{r['arch']},{r['shape']},{r['mesh']},"
              f"{r['t_compute_s']:.6f},{r['t_memory_s']:.6f},"
              f"{r['t_collective_s']:.6f},{r['dominant']},"
              f"{r['roofline_frac']:.4f}")


if __name__ == "__main__":
    main()
