"""Serving benchmark: the legacy host loop vs the fused ServingPipeline.

    PYTHONPATH=src python benchmarks/bench_serve.py [--json PATH]

Measures, at the ``launch/serve.py --small`` config on this host:

  * legacy window latency - the seed's serving path, four host/device
    crossings per window: jitted reward scoring -> NumPy controller
    (Eq. 10 decide + multi-pass guard + synchronous dual descent) ->
    jitted cascade execution, host-blocking after each;
  * fused response latency - the ServingPipeline's online pass (grouped
    scoring -> Eq. 10 -> vectorized guard -> CompactPlan execution) in
    one dispatch, measured submit -> decisions/revenue/spend ready; the
    nearline dual update is dispatched separately and chains on-device,
    exactly as the paper's online/nearline split prescribes - it never
    sits on the response path;
  * sustained throughput for both - windows/sec over a streamed run
    INCLUDING each path's dual update, so the nearline work is fully
    accounted for where it belongs.

Legacy/fused windows are interleaved so load swings on a shared machine
hit both paths instead of skewing the ratio.  Decision parity (pinned
lambda) is asserted always; the >= 2x latency gate is wall-clock and
therefore opt-in (--check-speedup), mirroring bench_chain_sim.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import numpy as np


def run(*, windows: int = 40, requests: int = 96, budget_frac: float = 0.6,
        small: bool = True, json_path: str | None = None,
        check_speedup: bool = False) -> dict:
    import jax

    from repro.experiments import build_serving_stack, serve_config
    from repro.launch.serve import make_legacy_window
    from repro.serving.pipeline import ServingPipeline

    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=small), verbose=True)
    chains = exp.chains
    budget = budget_frac * float(chains.costs.max()) * requests
    rng = np.random.default_rng(0)
    n_eval = exp.ctx_eval.shape[0]

    def sample():
        rows = rng.integers(0, n_eval, requests)
        return exp.ctx_eval[rows].astype(np.float32), rows

    ctl, legacy_window = make_legacy_window(exp, server, params, rcfg,
                                            budget)
    pipe = ServingPipeline(server, params, rcfg, budget)

    def fused_window(ctx, rows):
        res = pipe.serve_window(ctx, rows)
        jax.block_until_ready((res.decisions, res.revenue, res.spend))
        return res

    # parity: pinned lambda, decisions + revenue must match exactly
    for _ in range(3):
        ctx, rows = sample()
        lam = float(ctl.pd.lam)
        dec_l, rev_l = legacy_window(ctx, rows)
        res = pipe.serve_window(ctx, rows, lam=lam)
        assert np.array_equal(dec_l, res.decisions_np), "decision parity"
        assert np.array_equal(rev_l, res.revenue_np), "revenue parity"

    # latency: interleaved, device queue drained before each measurement
    lat_legacy, lat_fused = [], []
    for _ in range(windows):
        ctx, rows = sample()
        t0 = time.perf_counter()
        legacy_window(ctx, rows)
        lat_legacy.append(time.perf_counter() - t0)
        jax.block_until_ready(pipe.lam)  # drain the nearline chain
        t0 = time.perf_counter()
        fused_window(ctx, rows)
        lat_fused.append(time.perf_counter() - t0)

    # sustained throughput incl. each path's dual update
    ctx, rows = sample()
    t0 = time.perf_counter()
    for _ in range(windows):
        legacy_window(ctx, rows)
    thr_legacy = windows / (time.perf_counter() - t0)
    t0 = time.perf_counter()
    for _ in range(windows):
        pipe.serve_window(ctx, rows)
    jax.block_until_ready(pipe.lam)
    thr_fused = windows / (time.perf_counter() - t0)

    med_l = float(np.median(lat_legacy) * 1e3)
    med_f = float(np.median(lat_fused) * 1e3)
    result = {
        "config": {"windows": windows, "requests": requests,
                   "budget_frac": budget_frac, "small": small,
                   "chains": chains.n_chains,
                   "eval_users": int(n_eval),
                   "dual_iters": pipe.dual_cfg.max_iters},
        "legacy": {
            "median_window_ms": round(med_l, 3),
            "p95_window_ms": round(
                float(np.percentile(lat_legacy, 95) * 1e3), 3),
            "windows_per_sec": round(thr_legacy, 2),
        },
        "fused": {
            "median_window_ms": round(med_f, 3),
            "p95_window_ms": round(
                float(np.percentile(lat_fused, 95) * 1e3), 3),
            "windows_per_sec": round(thr_fused, 2),
        },
        "speedup_median_latency": round(med_l / med_f, 2),
        "speedup_throughput": round(thr_fused / thr_legacy, 2),
        "decision_parity": True,  # asserted above
    }
    if json_path is not None:
        from repro.obs.env import env_info
        result["env"] = env_info()
        path = os.path.abspath(json_path)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(json.dumps(result, indent=2))
        print(f"[bench_serve] wrote {path}")
    if check_speedup:
        assert result["speedup_median_latency"] >= 2.0, result
    return result


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(
        os.path.dirname(__file__), "..", "BENCH_serve.json"))
    ap.add_argument("--windows", type=int, default=40)
    ap.add_argument("--requests", type=int, default=96)
    ap.add_argument("--budget-frac", type=float, default=0.6)
    ap.add_argument("--full", action="store_true",
                    help="the non---small serve world")
    ap.add_argument("--check-speedup", action="store_true",
                    help="assert the >=2x median latency gate "
                         "(wall-clock: meaningful on an idle machine)")
    args = ap.parse_args()
    return run(windows=args.windows, requests=args.requests,
               budget_frac=args.budget_frac, small=not args.full,
               json_path=args.json, check_speedup=args.check_speedup)


if __name__ == "__main__":
    main()
