"""Cascade-truncation kernel benchmark at production batch sizes.

    PYTHONPATH=src python benchmarks/bench_truncate.py [--fast]

Times the survivor-compaction truncation round - the per-request
``mask -> cumsum -> expose-cut -> revenue`` of CompactPlan execution -
on two implementations over the same (G, U, cap) tables:

  * the XLA baseline ``cascade.engine._revenue_compact`` (vectorized
    gather + ``jnp.cumsum``; what the fused pipeline runs today and
    the fallback wherever Pallas is unavailable);
  * the Pallas kernel ``kernels.cascade_truncate.compact_truncate_revenue``
    (scalar-prefetched row gather + triangular-matmul cumsum, one grid
    step per request).

Parity between the two is asserted before any timing - to float32
reduction tolerance, since the kernel sums revenue over the padded
lane width in a different association order than the baseline's
masked row sum (the survivor COUNTS are exact; only the final click
sum reassociates).  The kernel timing is HARDWARE-GATED exactly like the kernel
itself: on TPU/GPU the compiled kernel runs at every production batch
size; on CPU only the interpreter exists, which executes grid steps in
Python and would take minutes at B=16384 - so CPU runs time the
interpreter at a small smoke batch (recorded as ``interpret_smoke``)
and the XLA baseline at the full production sweep.

Writes BENCH_truncate.json at the repo root.
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import time

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _time(fn, *args, reps: int = 10, **kw) -> float:
    import jax

    out = fn(*args, **kw)
    jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(reps):
        out = fn(*args, **kw)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / reps


def run(*, batches: tuple[int, ...] = (1024, 4096, 16384),
        g_count: int = 16, u_count: int = 512, cap: int = 150,
        expose: int = 8, parity_batch: int = 256,
        smoke_batch: int = 64, reps: int = 10,
        json_path: str | None = None) -> dict:
    import jax
    import jax.numpy as jnp

    from repro.cascade.engine import _revenue_compact
    from repro.kernels.cascade_truncate import compact_truncate_revenue
    from repro.obs.env import env_info

    backend = jax.default_backend()
    kernel_armed = backend in ("tpu", "gpu")

    rng = np.random.default_rng(0)
    p = np.stack([np.stack([rng.permutation(cap) for _ in range(u_count)])
                  for _ in range(g_count)]).astype(np.int32)
    ck = rng.random((g_count, u_count, cap)).astype(np.float32)
    p_d, ck_d = jnp.asarray(p), jnp.asarray(ck)

    def sample(b):
        return (jnp.asarray(rng.integers(0, g_count, b), jnp.int32),
                jnp.asarray(rng.integers(0, u_count, b), jnp.int32),
                jnp.asarray(rng.integers(1, cap + 1, b), jnp.int32))

    # parity first: the kernel must match the XLA baseline (to f32
    # reduction tolerance - the padded-lane sum reassociates) before
    # any timing
    g_b, r_b, n3_b = sample(parity_batch)
    base = np.asarray(_revenue_compact(p_d, ck_d, g_b, r_b, n3_b,
                                       expose=expose))
    kern = np.asarray(compact_truncate_revenue(
        p_d, ck_d, g_b, r_b, n3_b, expose=expose,
        interpret=not kernel_armed))
    np.testing.assert_allclose(kern, base, rtol=1e-6, atol=1e-6)
    parity_max_rel = float(np.max(np.abs(kern - base)
                                  / np.maximum(np.abs(base), 1e-9)))

    sweep = []
    for b in batches:
        g_b, r_b, n3_b = sample(b)
        t_base = _time(_revenue_compact, p_d, ck_d, g_b, r_b, n3_b,
                       expose=expose, reps=reps)
        row = {
            "batch": b,
            "baseline_us": 1e6 * t_base,
            "baseline_req_per_s": b / t_base,
        }
        if kernel_armed:
            t_k = _time(compact_truncate_revenue, p_d, ck_d, g_b, r_b,
                        n3_b, expose=expose, interpret=False, reps=reps)
            row["kernel_us"] = 1e6 * t_k
            row["kernel_req_per_s"] = b / t_k
            row["speedup"] = t_base / t_k
        sweep.append(row)
        extra = (f", kernel {row['kernel_us']:.0f}us "
                 f"({row['speedup']:.2f}x)" if kernel_armed else "")
        print(f"[bench_truncate] B={b}: baseline "
              f"{row['baseline_us']:.0f}us{extra}", flush=True)

    interp = None
    if not kernel_armed:
        g_b, r_b, n3_b = sample(smoke_batch)
        t_i = _time(compact_truncate_revenue, p_d, ck_d, g_b, r_b, n3_b,
                    expose=expose, interpret=True, reps=max(1, reps // 5))
        interp = {"batch": smoke_batch, "interpret_us": 1e6 * t_i}
        print(f"[bench_truncate] interpret smoke B={smoke_batch}: "
              f"{interp['interpret_us']:.0f}us", flush=True)

    out = {
        "benchmark": "cascade_truncate",
        "tables": {"groups": g_count, "users": u_count, "cap": cap,
                   "expose": expose},
        "backend": backend,
        "kernel_armed": kernel_armed,
        "parity": {"batch": parity_batch, "rtol": 1e-6,
                   "max_rel_err": parity_max_rel,
                   "mode": "compiled" if kernel_armed else "interpret"},
        "sweep": sweep,
        "interpret_smoke": interp,
        "env": env_info(),
    }
    if json_path:
        with open(json_path, "w") as f:
            json.dump(out, f, indent=2)
        print(f"[bench_truncate] wrote {json_path}")
    return out


def main(argv=None):
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--json",
                    default=os.path.join(REPO, "BENCH_truncate.json"))
    ap.add_argument("--fast", action="store_true",
                    help="small batches / few reps (smoke)")
    args = ap.parse_args(argv)
    if args.fast:
        run(batches=(256, 1024), u_count=128, parity_batch=64,
            smoke_batch=32, reps=3, json_path=args.json)
    else:
        run(json_path=args.json)


if __name__ == "__main__":
    sys.path.insert(0, os.path.join(REPO, "src"))
    main()
