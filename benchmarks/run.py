"""Benchmark harness: one function per paper table/figure + serving
micro-latency + roofline summary.  Prints ``name,us_per_call,derived``
CSV rows (plus per-table columns), per the repo skeleton contract.

    PYTHONPATH=src python -m benchmarks.run [--fast]
    PYTHONPATH=src python -m benchmarks.run --all   # every BENCH_*.json

``--all`` is the one-stop regeneration entrypoint: it reruns every
standalone benchmark (chain simulation, fused serving, carbon
allocation, geo-shifting) and rewrites the corresponding
``BENCH_*.json`` at the repo root, then exits.
"""
from __future__ import annotations

import argparse
import sys
import time

import numpy as np


def _emit(rows: list[dict], wall_s: float):
    per = 1e6 * wall_s / max(1, len(rows))
    for r in rows:
        name = r.pop("name")
        derived = ";".join(f"{k}={v}" for k, v in r.items())
        print(f"{name},{per:.1f},{derived}")
    sys.stdout.flush()


def bench_serving_latency(exp, reward_params, reward_cfg) -> list[dict]:
    """us_per_call of the online/nearline hot paths on THIS host (CPU;
    TPU latency derives from the roofline table instead)."""
    import jax
    import jax.numpy as jnp
    from repro.core.primal_dual import allocate, dual_descent
    from repro.core.reward_model import reward_matrix

    chains = exp.chains
    ctx = jnp.asarray(exp.ctx_eval[:256])
    mo = jnp.asarray(chains.model_onehot)
    sh = jnp.asarray(chains.scale_multihot)
    costs = jnp.asarray(chains.costs, jnp.float32)

    score = jax.jit(lambda p, c: reward_matrix(p, reward_cfg, c, mo, sh))
    r = score(reward_params, ctx).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        r = score(reward_params, ctx).block_until_ready()
    score_us = (time.perf_counter() - t0) / 20 * 1e6

    dd = jax.jit(lambda rw: dual_descent(rw, costs, float(np.median(
        chains.costs)) * rw.shape[0], 0.0, max_iters=100))
    lam, _ = dd(r)
    lam.block_until_ready()
    t0 = time.perf_counter()
    for _ in range(20):
        lam, _ = dd(r)
        lam.block_until_ready()
    dual_us = (time.perf_counter() - t0) / 20 * 1e6

    al = jax.jit(lambda rw, l: allocate(rw, costs, l))
    al(r, lam).block_until_ready()
    t0 = time.perf_counter()
    for _ in range(50):
        d = al(r, lam).block_until_ready()
    alloc_us = (time.perf_counter() - t0) / 50 * 1e6

    return [
        {"name": "serve_reward_score_256req", "us": round(score_us, 1),
         "us_per_req": round(score_us / 256, 2)},
        {"name": "nearline_dual_100iter", "us": round(dual_us, 1)},
        {"name": "serve_allocate_256req", "us": round(alloc_us, 1),
         "us_per_req": round(alloc_us / 256, 3)},
    ]


def bench_chain_sim_row() -> list[dict]:
    """Rank-based chain simulator vs the seed per-chain loop (the same
    measurement as benchmarks/bench_chain_sim.py, summarized as one row;
    the standalone script also writes BENCH_chain_sim.json)."""
    from benchmarks import bench_chain_sim

    r = bench_chain_sim.run(repeats=3)
    return [{"name": "chain_sim_U160_I200_J128",
             "us": round(r["vectorized_s"] * 1e6, 1),
             "speedup_vs_seed": r["speedup_vs_seed"],
             "exact": r["exact_match_vs_reference"]}]


def bench_kernels() -> list[dict]:
    """Interpret-mode wall time is NOT TPU perf; reported for harness
    completeness with the jnp-reference ratio as `derived`."""
    import jax
    import jax.numpy as jnp
    from repro.kernels import ops, ref

    rows = []
    key = jax.random.PRNGKey(0)
    feats = jax.random.normal(key, (256, 27, 64))
    for name, fn, rfn in (
        ("dot_interact_256x27x64",
         lambda: ops.dot_interact(feats, block_b=64),
         lambda: ref.dot_interact_ref(feats)),
    ):
        fn().block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            fn().block_until_ready()
        k_us = (time.perf_counter() - t0) / 5 * 1e6
        rref = jax.jit(rfn)
        rref().block_until_ready()  # compile
        t0 = time.perf_counter()
        for _ in range(5):
            rref().block_until_ready()
        r_us = (time.perf_counter() - t0) / 5 * 1e6
        rows.append({"name": f"kernel_{name}", "us": round(k_us, 1),
                     "interpret_vs_jnp": round(k_us / max(r_us, 1e-9), 2)})
    return rows


def run_all_json(fast: bool = False) -> dict:
    """Regenerate every BENCH_*.json from one entrypoint; returns
    {bench name: json path}.  ``fast`` shrinks each bench to a
    CI-smoke size (minutes -> tens of seconds; numbers are NOT
    comparable to the full-size records)."""
    import os

    from benchmarks import (bench_carbon, bench_chain_sim, bench_geo,
                            bench_geotenants, bench_multihost,
                            bench_scale, bench_serve, bench_truncate)

    repo = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))
    out = {}
    print("[run --all] chain simulation ...")
    bench_chain_sim.run(json_path=os.path.join(repo,
                                               "BENCH_chain_sim.json"),
                        **({"repeats": 3} if fast else {}))
    out["chain_sim"] = "BENCH_chain_sim.json"
    print("[run --all] fused serving vs legacy loop ...")
    bench_serve.run(json_path=os.path.join(repo, "BENCH_serve.json"),
                    **({"windows": 10, "requests": 48} if fast else {}))
    out["serve"] = "BENCH_serve.json"
    print("[run --all] carbon-aware vs constant-CI allocation ...")
    bench_carbon.run(json_path=os.path.join(repo, "BENCH_carbon.json"),
                     report_path=os.path.join(repo, "results",
                                              "carbon_report.csv"),
                     **({"windows": 12, "requests": 24,
                         "phases": (0.0, 12.0)} if fast else {}))
    out["carbon"] = "BENCH_carbon.json"
    print("[run --all] geo-shifted vs pinned-region serving ...")
    bench_geo.run(json_path=os.path.join(repo, "BENCH_geo.json"),
                  **({"windows": 12, "requests": 24,
                      "phases": (0.0, 12.0)} if fast else {}))
    out["geo"] = "BENCH_geo.json"
    print("[run --all] combined tenant x region vs single-axis arms ...")
    bench_geotenants.run(
        json_path=os.path.join(repo, "BENCH_geotenants.json"),
        **({"windows": 12, "requests": 24, "n_tenants": 2,
            "band_fracs": (0.35, 0.65),
            "phases": (0.0, 12.0)} if fast else {}))
    out["geotenants"] = "BENCH_geotenants.json"
    print("[run --all] streamed request world at scale ...")
    bench_scale.run(json_path=os.path.join(repo, "BENCH_scale.json"),
                    small=fast)
    out["scale"] = "BENCH_scale.json"
    print("[run --all] multi-host request mesh sweep ...")
    bench_multihost.run(
        json_path=os.path.join(repo, "BENCH_multihost.json"),
        **({"procs": (1, 2), "sizes": [64, 96, 64]} if fast else {}))
    out["multihost"] = "BENCH_multihost.json"
    print("[run --all] cascade-truncation kernel vs XLA baseline ...")
    bench_truncate.run(
        json_path=os.path.join(repo, "BENCH_truncate.json"),
        **({"batches": (256, 1024), "u_count": 128, "parity_batch": 64,
            "smoke_batch": 32, "reps": 3} if fast else {}))
    out["truncate"] = "BENCH_truncate.json"
    for name, path in out.items():
        print(f"[run --all] {name:10s} -> {path}")
    return out


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--fast", action="store_true",
                    help="smaller world (CI-sized)")
    ap.add_argument("--skip-tables", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="regenerate every BENCH_*.json and exit "
                         "(--fast shrinks each bench to smoke size; "
                         "--skip-tables is implied)")
    args = ap.parse_args()

    if args.all:
        run_all_json(fast=args.fast)
        return

    from benchmarks import roofline, tables
    from repro.data.synthetic import WorldConfig
    from repro.experiments import ExperimentConfig, train_reward_model

    print("name,us_per_call,derived")

    cfg = tables.BENCH_CFG
    if args.fast:
        cfg = ExperimentConfig(
            world=WorldConfig(n_users=800, n_items=200, hist_len=10, seed=7),
            expose=8, n_scales=4, cascade_steps=100, reward_steps=200,
            batch=48)

    t0 = time.time()
    exp = tables.get_experiment(cfg)
    print(f"setup_experiment,{(time.time()-t0)*1e6:.0f},"
          f"users={cfg.world.n_users};items={cfg.world.n_items};"
          f"chains={exp.chains.n_chains}")

    t0 = time.time()
    rp, rc = train_reward_model(exp)
    print(f"train_reward_model,{(time.time()-t0)*1e6:.0f},"
          f"steps={cfg.reward_steps}")

    if not args.skip_tables:
        for fn, needs_reward in (
            (tables.fig4_budget_curves, True),
            (tables.table2_stage_ablation, True),
            (tables.table3_model_ablation, True),
            (tables.table4_reward_ablation, False),
            (tables.fig5_traffic_spikes, True),
            (tables.pfec_summary, True),
        ):
            t0 = time.time()
            rows = fn(exp, rp, rc) if needs_reward else fn(exp)
            _emit(rows, time.time() - t0)

    _emit(bench_serving_latency(exp, rp, rc), 0.0)
    _emit(bench_chain_sim_row(), 0.0)
    _emit(bench_kernels(), 0.0)

    # roofline summary (requires a completed dry-run; silent if absent)
    try:
        rows = roofline.full_table()
        ok = [r for r in rows if "error" not in r and "skipped" not in r]
        if ok:
            for r in ok:
                print(f"roofline_{r['arch']}_{r['shape']}_{r['mesh']},0,"
                      f"dominant={r['dominant']};"
                      f"t_comp_ms={r['t_compute_s']*1e3:.3f};"
                      f"t_mem_ms={r['t_memory_s']*1e3:.3f};"
                      f"t_coll_ms={r['t_collective_s']*1e3:.3f};"
                      f"frac={r['roofline_frac']:.4f}")
    except Exception as e:  # noqa: BLE001
        print(f"roofline_summary,0,unavailable={type(e).__name__}")


if __name__ == "__main__":
    main()
