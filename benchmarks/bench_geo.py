"""Geo-shifting benchmark: two-region serving vs pinned-region arms.

    PYTHONPATH=src python benchmarks/bench_geo.py [--json PATH]

Protocol mirrors ``bench_carbon.py`` (deterministic, decision-level):
one diurnal traffic day is sampled once; every arm sees the SAME
requests, the same reward-model predictions, and the same pair of
grid-intensity traces, at several traffic-vs-grid phase offsets.
Regions a/b share the diurnal CI shape ``--region-offset-h`` hours
apart (``two_region_traces`` - e.g. EU vs US-west).  Allocation uses
the exact dual oracle (bisection on one gram price), so the comparison
measures the *routing policy*, not nearline lag:

  * ``pinned_a`` / ``pinned_b`` - single-region serving: request i's
    effective chain costs are kappa * CI_r(t_i) * flops_j for its
    (fixed) region r.  Both arms face the same daily gCO2e budget.
  * ``geo``      - the geo-shifted router: each request chooses
    (chain, serving region) JOINTLY through the same priced argmax over
    the J*R option space with region-dependent effective costs
    c_{j,r}(t) = flops_j * kappa * CI_r(t) - computation flows to
    whichever region is greener at that hour.

Two frontier points are reported per phase:

  * ``equal_grams``    - geo given exactly the BEST pinned arm's
    realized daily gCO2e: clicks retained/gained.  Any pinned
    allocation is feasible for the geo option space at the same gram
    budget, so the exact dual can only gain clicks - the ISSUE
    acceptance gate asserts >= for every tested phase offset.
  * ``matched_clicks`` - the smallest gram budget whose clicks still
    match the best pinned arm: gCO2e saved at equal-or-better clicks.

The per-region-budget NEARLINE router (per-region dual prices + guard +
ledgers inside the fused pipeline) is the serving-system counterpart -
exercised by ``launch/serve.py --scenario georegions`` and the CI
smoke; this benchmark isolates the policy value of the region choice
itself.
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _exact_alloc(r_opt: np.ndarray, eff: np.ndarray, budget: float,
                 *, iters: int = 80) -> np.ndarray:
    """Option decisions at the smallest gram price fitting ``budget``.

    r_opt (N, M) per-option rewards; eff (N, M) per-request per-option
    effective gCO2e cost.  Spend is non-increasing in the price =>
    bisection is exact up to float resolution (cf. dual_bisect).
    """
    ridx = np.arange(r_opt.shape[0])

    def alloc(lam):
        return np.argmax(r_opt - lam * eff, axis=1)

    def spend(dec):
        return float(eff[ridx, dec].sum())

    if spend(alloc(0.0)) <= budget:
        return alloc(0.0)
    lo, hi = 0.0, 1.0
    while spend(alloc(hi)) > budget and hi < 1e30:
        hi *= 2.0
    for _ in range(iters):
        mid = 0.5 * (lo + hi)
        if spend(alloc(mid)) <= budget:
            hi = mid
        else:
            lo = mid
    return alloc(hi)


def run(*, windows: int = 24, requests: int = 48, band_frac: float = 0.5,
        ci_mean: float = 450.0, ci_amplitude: float = 0.45,
        region_offset_h: float = 8.0, phases=(0.0, 6.0, 12.0, 18.0),
        small: bool = True, json_path: str | None = None,
        check_dominance: bool = True) -> dict:
    from repro.carbon.controller import grams_per_flop
    from repro.carbon.intensity import two_region_traces
    from repro.carbon.ledger import DAY_S
    from repro.experiments import (build_serving_stack, predicted_rewards,
                                   serve_config)
    from repro.serving.stream import TrafficScenario, scenario_windows

    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=small), verbose=True)
    chains = exp.chains
    costs = chains.costs
    j_n = len(costs)
    sizes = scenario_windows(
        TrafficScenario("georegions", windows, requests))
    window_s = DAY_S / windows
    traces = two_region_traces(mean=ci_mean, offset_h=region_offset_h,
                               rel_amplitude=ci_amplitude)
    kpf = grams_per_flop(1.0)  # g per FLOP per unit CI

    # one shared day of traffic: same arrivals for every arm/phase
    pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)  # (U, J)
    rng = np.random.default_rng(0)
    rows = np.concatenate([rng.integers(0, pred.shape[0], n)
                           for n in sizes])
    w_of = np.repeat(np.arange(windows), sizes)
    n_req = len(rows)
    ridx = np.arange(n_req)
    R = pred[rows]
    r_geo = np.tile(R, (1, 2))  # option m = r*J + j, region-major
    true_rev = exp.revenue_eval[rows]

    def clicks_of(dec_m):
        return float(true_rev[ridx, dec_m % j_n].sum())

    region_names = list(traces)
    rows_out = []
    for phase_h in phases:
        ci_w = {r: traces[r].resample(windows, window_s,
                                      phase_s=phase_h * 3600.0)
                for r in region_names}
        s_req = {r: (kpf * ci_w[r])[w_of] for r in region_names}
        eff = {r: s_req[r][:, None] * costs[None, :]
               for r in region_names}  # (N, J) per pinned arm
        eff_geo = np.concatenate([eff[r] for r in region_names], axis=1)

        # the allocation band, in grams of region a: below the gram
        # floor Eq. 3b is infeasible, above the natural spend the
        # constraint is slack and all arms coincide
        ra = region_names[0]
        floor_g = float(costs.min() * s_req[ra].sum())
        natural_g = float(
            eff[ra][ridx, np.argmax(R, axis=1)].sum())
        g_budget = floor_g + band_frac * (natural_g - floor_g)

        pinned = {}
        for r in region_names:
            dec = _exact_alloc(R, eff[r], g_budget)
            pinned[r] = {
                "clicks": clicks_of(dec),
                "gco2e": float(eff[r][ridx, dec].sum()),
                "flops": float(costs[dec].sum()),
            }
        best = max(region_names, key=lambda r: pinned[r]["clicks"])
        clicks_b, grams_b = pinned[best]["clicks"], pinned[best]["gco2e"]

        # frontier point 1: geo at exactly the best pinned arm's grams
        dec_eq = _exact_alloc(r_geo, eff_geo, grams_b)
        clicks_eq = clicks_of(dec_eq)
        split = [float(np.mean(dec_eq // j_n == k))
                 for k in range(len(region_names))]

        # frontier point 2: cheapest gram budget matching best pinned's
        # clicks.  Bracket: walk lo down until clicks drop below (or the
        # serve floor is reached) so the saving is never silently capped.
        g_floor_geo = float(
            (costs.min() * np.minimum.reduce(
                [s_req[r] for r in region_names])).sum())
        lo = 0.8 * grams_b
        while lo > g_floor_geo and clicks_of(
                _exact_alloc(r_geo, eff_geo, lo, iters=60)) >= clicks_b:
            lo = max(g_floor_geo, lo * 0.8)
        hi = grams_b
        for _ in range(20):
            mid = 0.5 * (lo + hi)
            if clicks_of(_exact_alloc(r_geo, eff_geo, mid,
                                      iters=60)) >= clicks_b:
                hi = mid
            else:
                lo = mid
        dec_m = _exact_alloc(r_geo, eff_geo, hi, iters=60)
        clicks_m = clicks_of(dec_m)
        grams_m = float(eff_geo[ridx, dec_m].sum())

        row = {
            "ci_phase_h": phase_h,
            "pinned": pinned,
            "best_pinned": best,
            "equal_grams": {
                "clicks": clicks_eq,
                "gco2e": float(eff_geo[ridx, dec_eq].sum()),
                "flops": float(costs[dec_eq % j_n].sum()),
                "region_split": dict(zip(region_names, split)),
                "clicks_delta_pct": round(
                    100 * (clicks_eq / clicks_b - 1), 2)},
            "matched_clicks": {
                "clicks": clicks_m, "gco2e": grams_m,
                "gco2e_saved_pct": round(100 * (1 - grams_m / grams_b),
                                         2)},
            "dominates": bool(clicks_eq >= clicks_b
                              and clicks_m >= clicks_b
                              and grams_m <= grams_b),
        }
        rows_out.append(row)
        print(f"[bench_geo] phase {phase_h:>4.1f}h: best pinned "
              f"({best}) {clicks_b:.0f} clicks @ {grams_b:.3e} g | geo "
              f"equal-grams {row['equal_grams']['clicks_delta_pct']:+.2f}%"
              f" clicks (split {split}) | matched-clicks "
              f"{row['matched_clicks']['gco2e_saved_pct']:+.2f}% g saved")

    result = {
        "config": {"windows": windows, "requests": requests,
                   "band_frac": band_frac, "ci_mean": ci_mean,
                   "ci_amplitude": ci_amplitude,
                   "region_offset_h": region_offset_h, "small": small,
                   "chains": chains.n_chains, "window_s": window_s,
                   "n_requests_day": int(n_req),
                   "regions": region_names,
                   "traffic": "diurnal day curve (georegions scenario)",
                   "intensity": "two-region diurnal, offset peaks",
                   "allocator": "exact dual oracle (bisection) over the "
                                "J*R (chain, region) option space, "
                                "decisions on reward-model predictions"},
        "phases": rows_out,
        "dominates_all_phases": bool(all(r["dominates"]
                                         for r in rows_out)),
    }
    if json_path is not None:
        from repro.obs.env import env_info
        result["env"] = env_info()
        path = os.path.abspath(json_path)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(json.dumps(result, indent=2))
        print(f"[bench_geo] wrote {path}")
    if check_dominance:
        assert result["dominates_all_phases"], result
    return result


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(REPO, "BENCH_geo.json"))
    ap.add_argument("--windows", type=int, default=24)
    ap.add_argument("--requests", type=int, default=48)
    ap.add_argument("--band-frac", type=float, default=0.5,
                    help="daily gram budget position in [floor, natural]")
    ap.add_argument("--region-offset-h", type=float, default=8.0,
                    help="hours region b's CI peak trails region a's")
    ap.add_argument("--phases", default="0,6,12,18",
                    help="traffic-vs-grid phase offsets (hours, csv)")
    ap.add_argument("--full", action="store_true",
                    help="the non---small serve world")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the dominance assertion")
    args = ap.parse_args()
    return run(windows=args.windows, requests=args.requests,
               band_frac=args.band_frac,
               region_offset_h=args.region_offset_h,
               phases=tuple(float(x) for x in args.phases.split(",")),
               small=not args.full, json_path=args.json,
               check_dominance=not args.no_check)


if __name__ == "__main__":
    main()
