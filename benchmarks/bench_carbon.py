"""Carbon benchmark: carbon-aware vs constant-CI allocation over a day.

    PYTHONPATH=src python benchmarks/bench_carbon.py [--json PATH]

Protocol (deterministic, decision-level - no wall-clock): one diurnal
traffic day (the ``carbon`` scenario curve) is sampled once; both
allocators see the SAME requests, the same reward-model predictions,
and the same diurnal grid-intensity trace, at several traffic-vs-grid
phase offsets.  Allocation uses the exact dual oracle (bisection on the
scalar price, the same machinery as ``evaluate_methods``/``dual_bisect``)
so the comparison measures the *allocation policy*, not nearline lag:

  * constant-CI  - today's allocator: one FLOPs price for the whole day
    (CI treated as the constant mean, exactly the seed's Eq. 2 view),
    daily budget halfway between the serve floor (everyone on the
    cheapest chain) and the unconstrained spend - the band where
    allocation actually happens.  Realized FLOPs are then metered
    against the TRUE time-varying CI(t).
  * carbon-aware - the repro.carbon policy: effective per-request costs
    c_j(t) = flops_j * kappa * CI(t) and one reward-per-GRAM price,
    i.e. water-filling computation into green-grid hours.

Two frontier points are reported per phase:

  * ``equal_grams``    - carbon-aware given exactly the constant
    allocator's realized daily gCO2e: clicks retained/gained;
  * ``matched_clicks`` - the smallest gram budget whose clicks still
    match the constant allocator: gCO2e saved at equal-or-better
    clicks (the ISSUE acceptance gate, asserted for every phase).

The constant allocator's day is FEASIBLE for the carbon-aware policy at
the same gram budget, so at the exact dual the equal-grams point can
only gain clicks; the gain is strict because the optimum shifts spend
toward low-CI windows.  ``results/carbon_report.csv`` is the phase-0
carbon-aware day metered window-by-window by the CarbonLedger
(per-stage/per-model attribution + all-max-chain daily savings).
"""
from __future__ import annotations

import argparse
import json
import os

import numpy as np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _exact_alloc(R: np.ndarray, costs: np.ndarray, s_req: np.ndarray,
                 budget: float, *, iters: int = 80) -> np.ndarray:
    """Eq. 10 decisions at the smallest price fitting ``budget``.

    R (N, J) predicted rewards; costs (J,) FLOPs; s_req (N,) per-request
    cost scale (1 = FLOPs pricing, kappa*CI(t_i) = carbon pricing), so
    request i's effective cost vector is s_req[i] * costs.  Delegates to
    the ONE bisection oracle (``bench_geo._exact_alloc``, the general
    per-request-per-option form) so the two benchmarks' "exact dual"
    arms can never drift apart.
    """
    try:
        from benchmarks.bench_geo import _exact_alloc as general
    except ModuleNotFoundError:  # script mode: repo root not on sys.path
        import sys

        sys.path.insert(0, REPO)
        from benchmarks.bench_geo import _exact_alloc as general

    return general(R, s_req[:, None] * costs[None, :], budget,
                   iters=iters)


def run(*, windows: int = 24, requests: int = 64, band_frac: float = 0.5,
        ci_mean: float = 450.0, ci_amplitude: float = 0.45,
        phases=(0.0, 6.0, 12.0, 18.0), small: bool = True,
        json_path: str | None = None, report_path: str | None = None,
        check_dominance: bool = True) -> dict:
    from repro.carbon.controller import grams_per_flop
    from repro.carbon.intensity import diurnal_trace
    from repro.carbon.ledger import DAY_S, CarbonLedger
    from repro.experiments import (build_serving_stack, predicted_rewards,
                                   serve_config)
    from repro.serving.stream import TrafficScenario, scenario_windows

    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=small), verbose=True)
    chains = exp.chains
    costs = chains.costs
    sizes = scenario_windows(TrafficScenario("carbon", windows, requests))
    window_s = DAY_S / windows
    trace = diurnal_trace(mean=ci_mean, rel_amplitude=ci_amplitude)
    kpf = grams_per_flop(1.0)  # g per FLOP per unit CI

    # one shared day of traffic: same arrivals for every allocator/phase
    pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)  # (U, J)
    rng = np.random.default_rng(0)
    rows = np.concatenate([rng.integers(0, pred.shape[0], n)
                           for n in sizes])
    w_of = np.repeat(np.arange(windows), sizes)
    R = pred[rows]
    true_rev = exp.revenue_eval[rows]
    ridx = np.arange(len(rows))

    def clicks_of(dec):
        return float(true_rev[ridx, dec].sum())

    # the allocation band: below `floor` Eq. 3b is infeasible, above
    # `natural` the constraint is slack and all policies coincide
    floor = float(costs.min()) * len(rows)
    natural = float(np.sum(costs[np.argmax(R, axis=1)]))
    f_budget = floor + band_frac * (natural - floor)

    rows_out = []
    ledger0 = None
    ones = np.ones(len(rows))
    for phase_h in phases:
        ci_w = trace.resample(windows, window_s, phase_s=phase_h * 3600.0)
        s_req = (kpf * ci_w)[w_of]  # g/FLOP seen by each request

        dec_c = _exact_alloc(R, costs, ones, f_budget)
        clicks_c = clicks_of(dec_c)
        grams_c = float(np.sum(s_req * costs[dec_c]))

        # frontier point 1: equal realized grams
        dec_eq = _exact_alloc(R, costs, s_req, grams_c)
        clicks_eq = clicks_of(dec_eq)
        grams_eq = float(np.sum(s_req * costs[dec_eq]))

        # frontier point 2: cheapest gram budget matching const's clicks.
        # Bracket: walk lo down until its clicks drop below const's (or
        # the serve floor is reached), so the bisection never silently
        # caps the reported saving at an arbitrary fraction.
        g_floor = float(costs.min() * np.sum(s_req))
        lo = 0.8 * grams_c
        while lo > g_floor and clicks_of(
                _exact_alloc(R, costs, s_req, lo, iters=60)) >= clicks_c:
            lo = max(g_floor, lo * 0.8)
        hi = grams_c
        for _ in range(20):
            mid = 0.5 * (lo + hi)
            if clicks_of(_exact_alloc(R, costs, s_req, mid,
                                      iters=60)) >= clicks_c:
                hi = mid
            else:
                lo = mid
        dec_m = _exact_alloc(R, costs, s_req, hi, iters=60)
        clicks_m = clicks_of(dec_m)
        grams_m = float(np.sum(s_req * costs[dec_m]))

        row = {
            "ci_phase_h": phase_h,
            "constant_ci": {"clicks": clicks_c, "gco2e": grams_c,
                            "flops": float(np.sum(costs[dec_c]))},
            "equal_grams": {"clicks": clicks_eq, "gco2e": grams_eq,
                            "flops": float(np.sum(costs[dec_eq])),
                            "clicks_delta_pct": round(
                                100 * (clicks_eq / clicks_c - 1), 2)},
            "matched_clicks": {"clicks": clicks_m, "gco2e": grams_m,
                               "flops": float(np.sum(costs[dec_m])),
                               "gco2e_saved_pct": round(
                                   100 * (1 - grams_m / grams_c), 2)},
            "dominates": bool(clicks_eq >= clicks_c
                              and clicks_m >= clicks_c
                              and grams_m < grams_c),
        }
        rows_out.append(row)
        print(f"[bench_carbon] phase {phase_h:>4.1f}h: const "
              f"{clicks_c:.0f} clicks @ {grams_c:.3e} g | equal-grams "
              f"{row['equal_grams']['clicks_delta_pct']:+.2f}% clicks | "
              f"matched-clicks "
              f"{row['matched_clicks']['gco2e_saved_pct']:+.2f}% g saved")

        if phase_h == phases[0]:
            ledger0 = CarbonLedger(chains, trace, window_s=window_s,
                                   phase_s=phase_h * 3600.0)
            for t, dec_w in enumerate(
                    np.split(dec_eq, np.cumsum(sizes)[:-1])):
                ledger0.record(dec_w, t=t)

    result = {
        "config": {"windows": windows, "requests": requests,
                   "band_frac": band_frac, "ci_mean": ci_mean,
                   "ci_amplitude": ci_amplitude, "small": small,
                   "chains": chains.n_chains, "window_s": window_s,
                   "n_requests_day": int(len(rows)),
                   "floor_flops": floor, "natural_flops": natural,
                   "daily_flops_budget": f_budget,
                   "traffic": "diurnal day curve (carbon scenario)",
                   "intensity": "diurnal, evening peak",
                   "allocator": "exact dual oracle (bisection), "
                                "decisions on reward-model predictions"},
        "phases": rows_out,
        "dominates_all_phases": bool(all(r["dominates"]
                                         for r in rows_out)),
    }
    if report_path is not None and ledger0 is not None:
        ledger0.to_csv(report_path)
        rep = ledger0.report()
        result["carbon_report"] = {
            "path": os.path.relpath(report_path, REPO),
            "daily_kwh": rep["daily_kwh"],
            "daily_gco2e": rep["daily_gco2e"],
            "daily_saved_kwh_vs_allmax": rep["daily_saved_kwh"],
            "daily_saved_tco2e_vs_allmax": rep["daily_saved_tco2e"],
        }
        print(f"[bench_carbon] wrote {os.path.abspath(report_path)}")
    if json_path is not None:
        from repro.obs.env import env_info
        result["env"] = env_info()
        path = os.path.abspath(json_path)
        with open(path, "w") as f:
            json.dump(result, f, indent=2)
            f.write("\n")
        print(json.dumps(result, indent=2))
        print(f"[bench_carbon] wrote {path}")
    if check_dominance:
        assert result["dominates_all_phases"], result
    return result


def main() -> dict:
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default=os.path.join(REPO,
                                                   "BENCH_carbon.json"))
    ap.add_argument("--report", default=os.path.join(
        REPO, "results", "carbon_report.csv"))
    ap.add_argument("--windows", type=int, default=24)
    ap.add_argument("--requests", type=int, default=64)
    ap.add_argument("--band-frac", type=float, default=0.5,
                    help="daily budget position in [floor, natural]")
    ap.add_argument("--full", action="store_true",
                    help="the non---small serve world")
    ap.add_argument("--no-check", action="store_true",
                    help="skip the dominance assertion")
    args = ap.parse_args()
    return run(windows=args.windows, requests=args.requests,
               band_frac=args.band_frac, small=not args.full,
               json_path=args.json, report_path=args.report,
               check_dominance=not args.no_check)


if __name__ == "__main__":
    main()
