"""LM model invariants: decode/prefill parity, windowing, MoE, chunking."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import lm

TINY = lm.LMConfig(name="t", n_layers=3, d_model=48, n_heads=4, n_kv_heads=2,
                   d_head=12, d_ff=96, vocab=64, padded_vocab=64,
                   dtype="float32", remat=False, fsdp=False)


@pytest.fixture(scope="module")
def setup():
    p = lm.init(jax.random.PRNGKey(0), TINY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 24), 0, 64)
    return p, toks


def test_forward_shapes_and_finite(setup):
    p, toks = setup
    logits, aux = lm.forward(p, TINY, toks)
    assert logits.shape == (2, 24, 64)
    assert bool(jnp.isfinite(logits).all())


def test_prefill_matches_forward(setup):
    p, toks = setup
    pre, cache = lm.prefill(p, TINY, toks, max_len=32)
    full, _ = lm.forward(p, TINY, toks)
    np.testing.assert_allclose(np.asarray(pre), np.asarray(full[:, -1]),
                               rtol=1e-4, atol=1e-4)
    assert cache["k"].shape == (3, 2, 32, 2, 12)
    assert int(cache["length"]) == 24


def test_multistep_decode_matches_forward(setup):
    p, toks = setup
    logits, cache = lm.prefill(p, TINY, toks, max_len=32)
    cur = toks
    for _ in range(6):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = lm.decode_step(p, TINY, nxt, cache)
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
        full, _ = lm.forward(p, TINY, cur)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, -1]),
                                   rtol=1e-3, atol=1e-3)


def test_windowed_decode_matches_forward():
    cfg = dataclasses.replace(TINY, window_pattern=(6, -1))
    p = lm.init(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (1, 16), 0, 64)
    logits, cache = lm.prefill(p, cfg, toks, max_len=24)
    cur = toks
    for _ in range(4):
        nxt = jnp.argmax(logits, -1).astype(jnp.int32)
        logits, cache = lm.decode_step(p, cfg, nxt, cache)
        cur = jnp.concatenate([cur, nxt[:, None]], axis=1)
        full, _ = lm.forward(p, cfg, cur)
        np.testing.assert_allclose(np.asarray(logits),
                                   np.asarray(full[:, -1]),
                                   rtol=1e-3, atol=1e-3)


def test_chunked_attention_matches_full(setup):
    p, toks = setup
    cfg_c = dataclasses.replace(TINY, attn_chunk_q=8)
    full, _ = lm.forward(p, TINY, toks)
    chunked, _ = lm.forward(p, cfg_c, toks)
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(full),
                               rtol=1e-4, atol=1e-4)


def test_scan_unroll_equivalent(setup):
    p, toks = setup
    cfg_u = dataclasses.replace(TINY, scan_unroll=3)
    a, _ = lm.forward(p, TINY, toks)
    b, _ = lm.forward(p, cfg_u, toks)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                               rtol=1e-5, atol=1e-5)


def test_window_pattern_affects_output():
    cfg_w = dataclasses.replace(TINY, window_pattern=(4, -1))
    p = lm.init(jax.random.PRNGKey(0), TINY)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 20), 0, 64)
    a, _ = lm.forward(p, TINY, toks)
    b, _ = lm.forward(p, cfg_w, toks)
    # early positions identical (window covers them), late ones differ
    assert np.allclose(np.asarray(a[:, :4]), np.asarray(b[:, :4]), atol=1e-5)
    assert not np.allclose(np.asarray(a[:, -1]), np.asarray(b[:, -1]),
                           atol=1e-4)


def test_softcap_bounds_logits():
    cfg = dataclasses.replace(TINY, final_softcap=5.0)
    p = lm.init(jax.random.PRNGKey(0), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(1), (1, 8), 0, 64)
    logits, _ = lm.forward(p, cfg, toks)
    assert float(jnp.abs(logits).max()) <= 5.0 + 1e-4


def test_moe_dense_ref_top_k_mass():
    cfg = dataclasses.replace(
        TINY, moe=lm.MoEConfig(n_experts=8, top_k=2, d_expert=32))
    p = lm.init(jax.random.PRNGKey(4), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(5), (2, 8), 0, 64)
    logits, aux = lm.forward(p, cfg, toks)
    assert bool(jnp.isfinite(logits).all())
    assert float(aux) > 0.0  # load-balance loss present


def test_param_count_consistency():
    p = lm.init(jax.random.PRNGKey(0), TINY)
    n = sum(int(x.size) for x in jax.tree_util.tree_leaves(p))
    assert n == pytest.approx(TINY.n_params(), rel=0.02)


def test_rope_rotation_preserves_norm():
    cfg = dataclasses.replace(TINY, rope_fraction=1.0)
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 8, 4, 12))
    pos = jnp.arange(8)[None]
    y = lm.apply_rope(x, pos, cfg)
    np.testing.assert_allclose(np.linalg.norm(np.asarray(x), axis=-1),
                               np.linalg.norm(np.asarray(y), axis=-1),
                               rtol=1e-4)


def test_partial_rope_leaves_pass_through():
    cfg = dataclasses.replace(TINY, rope_fraction=0.5)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 4, 2, 12))
    y = lm.apply_rope(x, jnp.arange(4)[None], cfg)
    np.testing.assert_allclose(np.asarray(x[..., 6:]), np.asarray(y[..., 6:]))
