import numpy as np
import pytest

from repro.core.action_chain import generate_action_chains, paper_stage_specs
from repro.core.budget import BudgetController
from repro.core.pfec import (EnergyConfig, carbon_from_energy,
                             energy_from_flops, pfec_report, revenue_at_e)


def test_energy_and_carbon_follow_paper_constants():
    cfg = EnergyConfig()
    kwh = energy_from_flops(1e15, cfg)
    assert kwh > 0
    # linear in FLOPs
    assert energy_from_flops(2e15, cfg) == pytest.approx(2 * kwh)
    # CE = EC * CI with CI = 615 g/kWh (paper Eq. 2)
    assert carbon_from_energy(kwh, cfg) == pytest.approx(kwh * 615.0)
    # PUE scales EC linearly (paper Eq. 1)
    cfg2 = EnergyConfig(pue=2 * cfg.pue)
    assert energy_from_flops(1e15, cfg2) == pytest.approx(2 * kwh)


def test_energy_config_validation():
    with pytest.raises(ValueError, match="pue"):
        EnergyConfig(pue=0.8)
    with pytest.raises(ValueError, match="p_gpu_w"):
        EnergyConfig(p_gpu_w=0.0)
    with pytest.raises(ValueError, match="sustained_flops_per_s"):
        EnergyConfig(sustained_flops_per_s=-1e12)
    with pytest.raises(ValueError, match="carbon_intensity_g_per_kwh"):
        EnergyConfig(carbon_intensity_g_per_kwh=0.0)
    with pytest.raises(ValueError, match="ram_cpu_fraction"):
        EnergyConfig(ram_cpu_fraction=-0.1)


def test_default_cfg_is_fresh_not_import_time():
    # cfg=None routes through one fresh default; the old `=EnergyConfig()`
    # default arg was evaluated once at import
    assert energy_from_flops(1e15) == energy_from_flops(1e15, EnergyConfig())
    assert carbon_from_energy(2.0) == 2.0 * 615.0


def test_pfec_report_fields():
    r = pfec_report(clicks=123.0, flops=1e12, extra="x")
    row = r.as_row()
    assert row["performance"] == 123.0
    assert row["flops"] == 1e12
    assert row["carbon_g"] == pytest.approx(row["energy_kwh"] * 615.0)
    assert row["extra"] == "x"


def test_pfec_report_meta_passthrough():
    r = pfec_report(clicks=1.0, flops=1e9, method="greenflow",
                    budget_frac=0.5, window=3)
    row = r.as_row()
    assert (row["method"], row["budget_frac"], row["window"]) == \
        ("greenflow", 0.5, 3)
    assert r.meta == {"method": "greenflow", "budget_frac": 0.5, "window": 3}
    # meta never clobbers the four PFEC columns
    assert set(row) == {"performance", "flops", "energy_kwh", "carbon_g",
                        "method", "budget_frac", "window"}


def test_revenue_at_e():
    clicks = np.zeros(50)
    clicks[[3, 7, 40]] = 1.0
    ranked = np.argsort(-clicks, kind="stable")  # clicked first
    assert revenue_at_e(clicks, ranked, e=20) == 3.0
    ranked_bad = np.arange(50)[::-1]
    assert revenue_at_e(clicks, ranked_bad, e=5) == 0.0


def test_revenue_at_e_edge_cases():
    clicks = np.zeros(10)
    clicks[[1, 4]] = 1.0
    ranked = np.argsort(-clicks, kind="stable")
    # e beyond the candidate set exposes everything ranked
    assert revenue_at_e(clicks, ranked, e=500) == 2.0
    # empty ranking exposes nothing (and must not crash on fancy-indexing)
    assert revenue_at_e(clicks, np.array([], dtype=np.int64), e=5) == 0.0
    assert revenue_at_e(clicks, [], e=5) == 0.0
    # non-contiguous / non-float labels: a strided int view and a bool view
    clicks_int = np.zeros(20, np.int32)
    clicks_int[[2, 6]] = 1
    strided = clicks_int[::2]  # items 0,2,4,...: clicks at positions 1, 3
    assert revenue_at_e(strided, np.array([1, 3, 0]), e=2) == 2.0
    assert revenue_at_e(clicks.astype(bool), ranked, e=3) == 2.0


def test_budget_controller_guard_caps_spend():
    chains = generate_action_chains(paper_stage_specs())
    rng = np.random.default_rng(0)
    n = 200
    budget = float(np.median(chains.costs)) * n * 0.7
    ctl = BudgetController(chains, budget)
    # adversarial: rewards favour the most expensive chain for everyone
    rewards = np.tile(chains.costs / chains.costs.max(), (n, 1)).astype(np.float32)
    floor_per_req = chains.costs[chains.cheapest()]
    for _ in range(4):
        decisions = ctl.step_window(rewards + rng.normal(0, 0.01, rewards.shape))
        assert ctl.stats[-1].spend <= budget * (1 + 1e-6)
    # traffic spike: 5x requests.  The guard caps spend at the budget OR
    # the physical floor (every request on the cheapest chain - Eq. 3b
    # serves everyone; the paper calls this "computation downgrade").
    spike = np.tile(rewards, (5, 1))
    ctl.step_window(spike.astype(np.float32))
    cap = max(budget, floor_per_req * len(spike))
    assert ctl.stats[-1].spend <= cap * (1 + 1e-6)
    assert ctl.stats[-1].downgraded > 0
