"""Streaming request path: RequestSource parity, determinism and the
zero-recompile guarantee.

The tentpole claim is that retiring the materialized (U, J) universe
changes NOTHING observable: replaying the server's own tables through
the chunked path is bitwise identical (decisions, revenues, prices,
spends), window production is a pure function of (seed, t) however the
host chunks the work, and bucketed padding keeps the jit cache warm
across traffic spikes.
"""
import numpy as np
import pytest

import jax.numpy as jnp


@pytest.fixture(scope="module")
def serving_stack(system_exp, system_reward):
    from repro.cascade.engine import CascadeServer, precompute_stage_scores

    exp = system_exp
    params, rcfg = system_reward
    scores = precompute_stage_scores(exp.models, exp.world,
                                     exp.split.final_eval)
    server = CascadeServer(stage_scores=scores, chains=exp.chains,
                           clicks=exp.clicks_eval, expose=exp.cfg.expose)
    return exp, server, params, rcfg


@pytest.fixture(scope="module")
def replay_source(serving_stack):
    from repro.data.request_source import TableReplaySource

    exp, server, _, _ = serving_stack
    return TableReplaySource.from_server(server, exp.ctx_eval, seed=7)


def _assert_window_parity(a, b, tag=""):
    np.testing.assert_array_equal(a.decisions_np, b.decisions_np,
                                  err_msg=f"{tag} decisions")
    np.testing.assert_array_equal(a.revenue_np, b.revenue_np,
                                  err_msg=f"{tag} revenue")
    assert np.array_equal(np.asarray(a.spend), np.asarray(b.spend)), tag
    assert np.array_equal(np.asarray(a.lam_after),
                          np.asarray(b.lam_after)), tag


# ---------------------------------------------------------------------------
# Bitwise parity: chunked replay vs the materialized universe
# ---------------------------------------------------------------------------


def test_replay_parity_bitwise_plain(serving_stack, replay_source):
    """Free-running prices over a 3x spike: the streamed chunk path and
    the materialized row path must agree BITWISE every window."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import (TrafficScenario, run_stream,
                                      scenario_windows)

    exp, server, params, rcfg = serving_stack
    src = replay_source
    b = 48
    budget = 0.5 * exp.chains.costs.max() * b
    sizes = scenario_windows(TrafficScenario("spike", 6, b,
                                             spike_mult=3.0))

    def sample(t, n):
        rows = src.arrivals(t, n)
        return exp.ctx_eval[rows], rows

    st_m = run_stream(ServingPipeline(server, params, rcfg, budget),
                      sizes, sample)
    st_s = run_stream(ServingPipeline(src.universe, params, rcfg,
                                      budget), sizes, src)
    for t, (a, b_) in enumerate(zip(st_m.windows, st_s.windows)):
        _assert_window_parity(a, b_, f"w{t}")


def test_replay_parity_bitwise_geotenants(serving_stack, replay_source):
    """The combined tenant x region pass pads in PER-TENANT blocks -
    chunk tables must land in exactly the same slots as global rows."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)
    from repro.serving.stream import run_stream

    exp, server, params, rcfg = serving_stack
    src = replay_source
    sizes = [48, 96, 48]
    per_req = 0.5 * float(exp.chains.costs.max())
    spec = ConstraintSpec([
        TenantAxis((per_req * 24, per_req * 24), priced=True),
        RegionAxis(2), GlobalAxis(pricing="carbon"),
    ])
    bt = [np.concatenate([np.full(2, per_req * n / 2),
                          np.full(2, 0.6 * per_req * n)]).astype(
        np.float32) for n in sizes]
    st_ = [np.array([1.0, 1.3], np.float32)] * len(sizes)

    def sample(t, n):
        rows = src.arrivals(t, n)
        return exp.ctx_eval[rows], rows

    st_m = run_stream(
        ServingPipeline.from_spec(server, params, rcfg, spec),
        sizes, sample, budget_trace=bt, scale_trace=st_)
    st_s = run_stream(
        ServingPipeline.from_spec(src.universe, params, rcfg, spec),
        sizes, src, budget_trace=bt, scale_trace=st_)
    for t, (a, b_) in enumerate(zip(st_m.windows, st_s.windows)):
        _assert_window_parity(a, b_, f"geot w{t}")
        np.testing.assert_array_equal(a.regions_np, b_.regions_np)
        np.testing.assert_array_equal(np.asarray(a.tr_spend),
                                      np.asarray(b_.tr_spend))


def test_memmap_roundtrip_parity(serving_stack, replay_source, tmp_path):
    """save -> load(mmap=True) replays identical windows from disk."""
    from repro.data.request_source import TableReplaySource

    exp, _, _, _ = serving_stack
    src = replay_source
    src.save(str(tmp_path / "universe"))
    disk = TableReplaySource.load(str(tmp_path / "universe"),
                                  exp.chains, seed=7)
    assert disk.n_users == src.n_users
    a, b = src.window(3, 40), disk.window(3, 40)
    np.testing.assert_array_equal(a.users, b.users)
    np.testing.assert_array_equal(a.ctx, b.ctx)
    np.testing.assert_array_equal(a.tables["p"], b.tables["p"])
    np.testing.assert_array_equal(a.tables["ck"], b.tables["ck"])


# ---------------------------------------------------------------------------
# GeneratedSource: determinism, chunk boundaries, streaming world
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def generated_source(serving_stack):
    from dataclasses import replace

    from repro.data.request_source import GeneratedSource
    from repro.data.synthetic import StreamingWorld

    exp, _, _, _ = serving_stack
    wcfg = replace(exp.cfg.world, n_users=50_000)
    return GeneratedSource(StreamingWorld.build(wcfg), exp.models,
                           exp.chains, expose=exp.cfg.expose, seed=3,
                           chunk=64, item_block=128)


def test_generated_deterministic_under_seed(serving_stack,
                                            generated_source):
    """Window t is a pure function of (seed, t): a second source with a
    DIFFERENT host chunking replays it exactly; a different seed does
    not."""
    from dataclasses import replace

    from repro.data.request_source import GeneratedSource
    from repro.data.synthetic import StreamingWorld

    exp, _, _, _ = serving_stack
    wcfg = replace(exp.cfg.world, n_users=50_000)
    other = GeneratedSource(StreamingWorld.build(wcfg), exp.models,
                            exp.chains, expose=exp.cfg.expose, seed=3,
                            chunk=17, item_block=64)
    # 100 requests: chunk 64 splits 64+36, chunk 17 splits 17*5+15 -
    # both off the chunk boundary, plus one exact-boundary window below
    a, b = generated_source.window(4, 100), other.window(4, 100)
    np.testing.assert_array_equal(a.users, b.users)
    np.testing.assert_array_equal(a.ctx, b.ctx)
    np.testing.assert_array_equal(a.tables["p"], b.tables["p"])
    np.testing.assert_array_equal(a.tables["ck"], b.tables["ck"])
    a, b = generated_source.window(5, 64), other.window(5, 64)
    np.testing.assert_array_equal(a.ctx, b.ctx)
    np.testing.assert_array_equal(a.tables["p"], b.tables["p"])

    reseeded = GeneratedSource(StreamingWorld.build(wcfg), exp.models,
                               exp.chains, expose=exp.cfg.expose,
                               seed=4, chunk=64, item_block=128)
    c = reseeded.window(4, 100)
    assert not np.array_equal(a.users[:64], c.users[:64]) or \
        not np.array_equal(generated_source.window(4, 100).ctx, c.ctx)


def test_generated_zero_and_single_request_windows(generated_source):
    z = generated_source.window(9, 0)
    assert z.n == 0 and z.ctx.shape[0] == 0
    assert z.tables["p"].shape[1] == 0
    one = generated_source.window(9, 1)
    assert one.n == 1 and one.tables["p"].shape[1] == 1


def test_streaming_world_repeat_visitors_consistent(serving_stack):
    """Hash-keyed users: the same global id materializes the SAME row
    (history, fields, clicks) in any slab it appears in."""
    from dataclasses import replace

    from repro.data.synthetic import StreamingWorld

    exp, _, _, _ = serving_stack
    w = StreamingWorld.build(replace(exp.cfg.world, n_users=1_000_000))
    ids_a = np.array([5, 999_999, 123_456, 5])
    ids_b = np.array([123_456, 5])
    sa, sb = w.user_slab(ids_a), w.user_slab(ids_b)
    np.testing.assert_array_equal(sa.hist_ids[2], sb.hist_ids[0])
    np.testing.assert_array_equal(sa.user_fields[0], sb.user_fields[1])
    np.testing.assert_array_equal(sa.hist_ids[0], sa.hist_ids[3])
    ca, cb = w.clicks_slab(ids_a, sa), w.clicks_slab(ids_b, sb)
    np.testing.assert_array_equal(ca[0], cb[1])
    np.testing.assert_array_equal(ca[2], cb[0])


def test_generated_stream_end_to_end(serving_stack, generated_source):
    """A generated swing stream serves through the fused pipeline with
    zero steady-state recompiles and positive revenue."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    budget = 0.5 * exp.chains.costs.max() * 32
    pipe = ServingPipeline(generated_source.universe, params, rcfg,
                           budget, bucketing="pow2")
    sizes = [32, 320, 32, 320, 32]
    st = run_stream(pipe, sizes, generated_source)
    assert st.steady_compiles == 0
    assert st.compiles[2] == st.compiles[3] == st.compiles[4] == 0
    assert st.total_revenue > 0


# ---------------------------------------------------------------------------
# Recompile instrumentation + bucketing
# ---------------------------------------------------------------------------


def test_zero_steady_state_recompiles_10x_spike(serving_stack,
                                                replay_source):
    """10x spike, pow2 buckets: every (shape, padded) pair compiles on
    first sight only - repeated buckets report compiles == 0."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    src = replay_source
    b = 32
    budget = 0.5 * exp.chains.costs.max() * b
    pipe = ServingPipeline(src.universe, params, rcfg, budget,
                           bucketing="pow2")
    sizes = [b, b, 10 * b, 10 * b, b, 10 * b, b]
    st = run_stream(pipe, sizes, src)
    assert st.steady_compiles == 0
    seen = set()
    for r in st.windows:
        if r.bucket in seen:
            assert r.compiles == 0, r.bucket
        else:
            assert r.compiles > 0, "first sight of a bucket compiles"
        seen.add(r.bucket)
    assert len(seen) == 2  # 32 -> one bucket, 320 -> one pow2 bucket


def test_pow2_bucketing_bounds_shape_count(serving_stack):
    from repro.serving.pipeline import ServingPipeline

    exp, server, params, rcfg = serving_stack
    pipe_lin = ServingPipeline(server, params, rcfg, 100.0)
    pipe_p2 = ServingPipeline(server, params, rcfg, 100.0,
                              bucketing="pow2")
    lin = {pipe_lin._bucket(n) for n in range(1, 3201)}
    p2 = {pipe_p2._bucket(n) for n in range(1, 3201)}
    assert len(p2) <= 8 and len(lin) == 100  # log vs linear in traffic
    for n in (1, 31, 32, 33, 64, 65, 1000, 3200):
        assert pipe_p2._bucket(n) >= n
    with pytest.raises(ValueError):
        ServingPipeline(server, params, rcfg, 100.0, bucketing="huh")


def test_stream_only_pipeline_requires_chunk_tables(serving_stack,
                                                    replay_source):
    from repro.serving.pipeline import ServingPipeline

    exp, _, params, rcfg = serving_stack
    pipe = ServingPipeline(replay_source.universe, params, rcfg, 100.0)
    c = replay_source.window(0, 8)
    with pytest.raises(ValueError, match="streaming universe"):
        pipe.serve_window(c.ctx, c.rows)
    res = pipe.serve_window(c.ctx, c.rows, tables=c.tables)
    assert res.n_valid == 8


# ---------------------------------------------------------------------------
# Named per-axis budget dicts (PR 5 leftover)
# ---------------------------------------------------------------------------


def test_budget_and_scale_names(serving_stack):
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    plain = ConstraintSpec([GlobalAxis(budget=9.0)]).compile()
    assert plain.budget_names == ("global",)
    assert plain.scale_names == ("global",)
    ten = ConstraintSpec([TenantAxis((4.0, 5.0))]).compile()
    assert ten.budget_names == ("tenant[0]", "tenant[1]")
    assert ten.k_names == ()  # shared price: budgets outnumber prices
    geot = ConstraintSpec([
        TenantAxis((4.0, 5.0), priced=True),
        RegionAxis(2, names=("eu", "us")),
        GlobalAxis(pricing="carbon"),
    ]).compile()
    assert geot.budget_names == ("tenant[0]", "tenant[1]", "eu", "us")
    assert geot.scale_names == ("eu", "us")
    assert geot.budget_names == geot.k_names  # fully priced: equal


def test_named_budget_dict_bitwise_vs_vector(serving_stack,
                                             replay_source):
    """The named-dict budget/cost_scale form is a naming shim: same
    vectors, same bits."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    exp, server, params, rcfg = serving_stack
    src = replay_source
    per_req = 0.5 * float(exp.chains.costs.max())
    n = 48
    rows = src.arrivals(0, n)
    ctx = exp.ctx_eval[rows]
    spec = ConstraintSpec([
        TenantAxis((per_req * 24, per_req * 24), priced=True),
        RegionAxis(2, names=("eu", "us")),
        GlobalAxis(pricing="carbon"),
    ])
    vec_b = np.array([per_req * 24, per_req * 30, per_req * 29,
                      per_req * 28], np.float32)
    vec_s = np.array([1.0, 1.3], np.float32)
    p1 = ServingPipeline.from_spec(server, params, rcfg, spec)
    r1 = p1.serve_window(ctx, rows, budget=vec_b, cost_scale=vec_s)
    p2 = ServingPipeline.from_spec(server, params, rcfg, spec)
    r2 = p2.serve_window(ctx, rows, budget={
        "tenant[0]": vec_b[0], "tenant[1]": vec_b[1],
        "eu": vec_b[2], "us": vec_b[3]},
        cost_scale={"eu": 1.0, "us": 1.3})
    _assert_window_parity(r1, r2, "named-vs-vector")
    with pytest.raises(ValueError, match="missing"):
        p2.serve_window(ctx, rows, budget={"eu": 1.0},
                        cost_scale={"eu": 1.0, "us": 1.3})
    with pytest.raises(ValueError, match="unknown"):
        p2.serve_window(ctx, rows, budget={
            "tenant[0]": 1, "tenant[1]": 1, "eu": 1, "us": 1,
            "mars": 1}, cost_scale=vec_s)


def test_named_scalar_budget_plain_mode(serving_stack, replay_source):
    from repro.serving.pipeline import ServingPipeline

    exp, server, params, rcfg = serving_stack
    src = replay_source
    n = 32
    rows = src.arrivals(1, n)
    ctx = exp.ctx_eval[rows]
    budget = 0.5 * float(exp.chains.costs.max()) * n
    r1 = ServingPipeline(server, params, rcfg, budget).serve_window(
        ctx, rows, budget=budget * 0.7)
    r2 = ServingPipeline(server, params, rcfg, budget).serve_window(
        ctx, rows, budget={"global": budget * 0.7})
    _assert_window_parity(r1, r2, "plain-named")


# ---------------------------------------------------------------------------
# Chunked offline scoring
# ---------------------------------------------------------------------------


def test_reward_matrix_chunked_matches_full(serving_stack):
    """One-chunk inputs are bitwise the direct call; multi-chunk splits
    agree per row up to float ulps (XLA re-blocks matmuls per batch
    shape - the decision-relevant scale here is ~1.0)."""
    from repro.core.reward_model import (reward_matrix,
                                         reward_matrix_chunked)

    exp, _, params, rcfg = serving_stack
    mo = jnp.asarray(exp.chains.model_onehot)
    sh = jnp.asarray(exp.chains.scale_multihot)
    ctx = exp.ctx_eval[:150]
    full = np.asarray(reward_matrix(params, rcfg, jnp.asarray(
        ctx, jnp.float32), mo, sh))
    np.testing.assert_array_equal(
        full, reward_matrix_chunked(params, rcfg, ctx, mo, sh,
                                    chunk=4096))
    for chunk in (64, 75):  # ragged and exact splits
        part = reward_matrix_chunked(params, rcfg, ctx, mo, sh,
                                     chunk=chunk)
        np.testing.assert_allclose(full, part, rtol=3e-6, atol=1e-6,
                                   err_msg=str(chunk))
        # chunk-boundary rows are not special: the LAST padded chunk
        # agrees with the first-chunk rows of an offset call
        assert part.shape == full.shape
