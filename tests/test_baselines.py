import jax.numpy as jnp
import numpy as np

from repro.core.action_chain import generate_action_chains, paper_stage_specs
from repro.core.baselines import (StageActionSpace, cras_allocation,
                                  equal_allocation)

CHAINS = generate_action_chains(paper_stage_specs())


def test_equal_picks_costliest_affordable_chain():
    n = 100
    budget = float(np.median(CHAINS.costs)) * n
    j = equal_allocation(CHAINS, budget, n)
    per_req = budget / n
    assert CHAINS.costs[j] <= per_req
    # no affordable chain is more expensive
    affordable = CHAINS.costs[CHAINS.costs <= per_req]
    assert CHAINS.costs[j] == affordable.max()


def test_equal_rank_model_variants():
    n = 100
    budget = float(CHAINS.costs.max()) * n  # everything affordable
    j_din = equal_allocation(CHAINS, budget, n, rank_model="DIN")
    j_dien = equal_allocation(CHAINS, budget, n, rank_model="DIEN")
    names = [m.name for m in CHAINS.stages[2].models]
    assert names[CHAINS.chain_idx[j_din, 2, 0]] == "DIN"
    assert names[CHAINS.chain_idx[j_dien, 2, 0]] == "DIEN"


def test_equal_downgrades_when_nothing_fits():
    j = equal_allocation(CHAINS, 1.0, 1000)  # absurdly small budget
    assert j == CHAINS.cheapest()


def test_cras_produces_feasible_chains_within_budget():
    rng = np.random.default_rng(0)
    n = 60
    spaces = [StageActionSpace.from_chains(CHAINS, k) for k in range(3)]
    stage_rewards = [jnp.asarray(rng.uniform(0, 1, (n, len(sp.costs))),
                                 jnp.float32) for sp in spaces]
    budget = float(np.median(CHAINS.costs)) * n
    decisions = cras_allocation(stage_rewards, spaces, CHAINS, budget)
    assert decisions.shape == (n,)
    assert (decisions >= 0).all() and (decisions < CHAINS.n_chains).all()
    spend = CHAINS.costs[decisions].sum()
    # per-stage budgets are respected jointly up to stitch-clamping slack
    assert spend <= budget * 1.15


def test_cras_rank_model_restriction():
    rng = np.random.default_rng(1)
    n = 40
    spaces = [StageActionSpace.from_chains(CHAINS, k) for k in range(3)]
    stage_rewards = [jnp.asarray(rng.uniform(0, 1, (n, len(sp.costs))),
                                 jnp.float32) for sp in spaces]
    budget = float(CHAINS.costs.max()) * n
    decisions = cras_allocation(stage_rewards, spaces, CHAINS, budget,
                                rank_model="DIN")
    names = [m.name for m in CHAINS.stages[2].models]
    got = {names[CHAINS.chain_idx[j, 2, 0]] for j in decisions}
    assert got == {"DIN"}
