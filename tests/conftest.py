import os
import sys

# tests and benches see ONE CPU device (the 512-device flag belongs to
# launch/dryrun.py exclusively, per the brief)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest


@pytest.fixture()
def rng():
    return np.random.default_rng(0)
