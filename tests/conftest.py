import os
import sys
import types

# tests and benches see ONE CPU device (the 512-device flag belongs to
# launch/dryrun.py exclusively, per the brief)
os.environ.setdefault("JAX_PLATFORMS", "cpu")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np
import pytest

# ---------------------------------------------------------------------------
# Graceful degradation when the dev-only `hypothesis` dependency is absent:
# install a stub module so test modules still import and their plain pytest
# tests run; @given property tests turn into explicit skips.
# ---------------------------------------------------------------------------

try:
    import hypothesis  # noqa: F401
except ImportError:

    class _DummyStrategy:
        """Accepts any strategy-building call chain at collection time."""

        def __call__(self, *a, **k):
            return self

        def __getattr__(self, name):
            return self

        def __repr__(self):
            return "<hypothesis-stub strategy>"

    def _given(*a, **k):
        def deco(fn):
            def _skipper():
                pytest.skip("hypothesis not installed (property test)")
            _skipper.__name__ = getattr(fn, "__name__", "test_property")
            _skipper.__doc__ = fn.__doc__
            return _skipper
        return deco

    def _settings(*a, **k):
        if a and callable(a[0]):  # bare @settings
            return a[0]
        return lambda fn: fn

    _stub = types.ModuleType("hypothesis")
    _stub.given = _given
    _stub.settings = _settings
    _strategies = types.ModuleType("hypothesis.strategies")
    _strategies.__getattr__ = lambda name: _DummyStrategy()  # PEP 562
    _stub.strategies = _strategies
    sys.modules["hypothesis"] = _stub
    sys.modules["hypothesis.strategies"] = _strategies


@pytest.fixture()
def rng():
    return np.random.default_rng(0)


# ---------------------------------------------------------------------------
# The end-to-end experiment (cascade training + simulation + reward model)
# is the most expensive fixture in the suite; build it once per SESSION and
# share it across test modules.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="session")
def system_exp():
    from repro.data.synthetic import WorldConfig
    from repro.experiments import ExperimentConfig, build_experiment

    cfg = ExperimentConfig(
        world=WorldConfig(n_users=800, n_items=200, hist_len=10, seed=3),
        expose=8, n_scales=4, cascade_steps=120, reward_steps=300, batch=48)
    return build_experiment(cfg)


@pytest.fixture(scope="session")
def system_reward(system_exp):
    from repro.experiments import train_reward_model

    params, rcfg = train_reward_model(system_exp)
    return params, rcfg
