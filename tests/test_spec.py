"""Declarative ConstraintSpec API: the ISSUE acceptance gates.

  * axis/spec validation and the legacy-kwargs -> spec mapping
    (``spec_from_legacy``), including the removal of the old
    ``region_jitter`` knob;
  * property-style parity: any SINGLE-AXIS ConstraintSpec reproduces
    the corresponding legacy flag path bit-identically (decisions,
    lambda traces, spends) across shared / priced / geo / carbon;
  * the exact flow-splitting primal rounding of the degenerate region
    tie (proportional split by remaining capacity; untied windows
    reduce to the argmax);
  * the combined tenant x region pipeline: per-tenant AND per-region
    caps enforced by the chained guard, (T, R) spends consistent,
    (T + R,) prices, and a pinned-price brute-force decision check;
  * spec-built host-loop controllers == directly built ones;
  * 8-device subprocess shard parity for the geotenants pipeline.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.spec import (ConstraintSpec, GlobalAxis, RegionAxis,
                                TenantAxis, spec_from_legacy)

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# Validation + legacy mapping
# ---------------------------------------------------------------------------


def test_axis_validation():
    with pytest.raises(ValueError, match="at least one budget"):
        TenantAxis(())
    with pytest.raises(ValueError, match="positive"):
        TenantAxis((1.0, -2.0))
    with pytest.raises(ValueError, match=">= 2"):
        RegionAxis(1)
    with pytest.raises(ValueError, match="split"):
        RegionAxis(2, split="dither")
    with pytest.raises(ValueError, match="names"):
        RegionAxis(2, names=("only_one",))
    with pytest.raises(ValueError, match="pricing"):
        GlobalAxis(budget=1.0, pricing="joules")
    with pytest.raises(ValueError, match="positive"):
        GlobalAxis(budget=0.0)
    with pytest.raises(ValueError, match="duplicate TenantAxis"):
        ConstraintSpec([TenantAxis((1.0,)), TenantAxis((2.0,))]).compile()
    with pytest.raises(ValueError, match="budget source"):
        ConstraintSpec([RegionAxis(2)]).compile()
    with pytest.raises(TypeError, match="unknown constraint axis"):
        ConstraintSpec(["tenants"]).compile()


def test_region_jitter_is_gone():
    """The PR 5 deprecation window closed: RegionAxis has no jitter
    field and spec_from_legacy no region_jitter kwarg; the explicit
    split= knob is the only tie-rounding control."""
    with pytest.raises(TypeError):
        RegionAxis(2, jitter=0.2)
    with pytest.raises(TypeError):
        spec_from_legacy(10.0, n_regions=2, region_jitter=0.3)
    assert spec_from_legacy(10.0, n_regions=2).compile().split == "argmax"
    spec = ConstraintSpec([RegionAxis(2, split="flow"),
                           GlobalAxis(budget=10.0)])
    assert spec.compile().split == "flow"


def test_spec_from_legacy_mapping():
    cs = spec_from_legacy(100.0).compile()
    assert cs.mode == "plain" and cs.n_prices == 0
    assert cs.total_budget == 100.0 and cs.budget_len() == 1

    cs = spec_from_legacy(100.0, tenant_budgets=[30.0, 70.0]).compile()
    assert cs.mode == "tenants" and cs.n_prices == 0  # shared: 1 price
    assert not cs.tenant_priced and cs.t_n == 2
    assert cs.budget_len() == 2

    cs = spec_from_legacy(100.0, tenant_budgets=[30.0, 70.0],
                          tenant_mode="priced").compile()
    assert cs.tenant_priced and cs.n_prices == 2
    assert cs.k_names == ("tenant[0]", "tenant[1]")

    cs = spec_from_legacy(100.0, n_regions=2).compile()
    assert cs.mode == "geo" and cs.split == "argmax"
    assert cs.n_prices == 2 and cs.budget_len() == 2

    with pytest.raises(ValueError, match="tenant_mode"):
        spec_from_legacy(1.0, tenant_budgets=[1.0], tenant_mode="vip")

    # the combined mode the legacy flags never reached
    cs = ConstraintSpec([
        TenantAxis((30.0, 70.0), priced=True), RegionAxis(2),
        GlobalAxis(pricing="carbon")]).compile()
    assert cs.mode == "geotenants" and cs.n_prices == 4
    assert cs.k_names == ("tenant[0]", "tenant[1]", "region[0]",
                          "region[1]")
    assert cs.budget_len() == 4 and cs.pricing == "carbon"
    assert cs.total_budget == 100.0  # sum of tenant budgets


# ---------------------------------------------------------------------------
# A tiny serving universe (no training - random scores/params)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_stack():
    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import RewardModelConfig, reward_model_init

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    return chains, server, params, rcfg


def _windows(u, n_windows=5, n=64, seed=1):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=(n, 12)).astype(np.float32),
             rng.integers(0, u, n)) for _ in range(n_windows)]


def _assert_same_window(r_a, r_b, *, vector_lam=False):
    np.testing.assert_array_equal(r_a.decisions_np, r_b.decisions_np)
    np.testing.assert_array_equal(r_a.revenue_np, r_b.revenue_np)
    assert int(r_a.downgraded) == int(r_b.downgraded)
    np.testing.assert_array_equal(np.asarray(r_a.spend),
                                  np.asarray(r_b.spend))
    np.testing.assert_array_equal(np.asarray(r_a.lam_after),
                                  np.asarray(r_b.lam_after))


# ---------------------------------------------------------------------------
# THE property gate: single-axis specs == legacy flag paths, bitwise
# ---------------------------------------------------------------------------


def test_single_axis_specs_bit_identical_to_legacy(tiny_stack):
    """For every legacy flag combination (plain / tenants shared /
    tenants priced / geo / carbon-priced plain), ``from_spec`` with the
    hand-built single-axis spec free-runs BIT-identically to the legacy
    keyword constructor: decisions, revenue, downgrades, spends and the
    full lambda trace."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    b = 64
    budget = 0.5 * float(chains.costs.max()) * b
    tb = np.array([0.3, 0.7]) * budget
    kappa_ci = 3.2e-7 * 480.0  # carbon scale (gCO2e per FLOP)

    cases = {
        "plain": (
            dict(budget_per_window=budget),
            ConstraintSpec([GlobalAxis(budget=budget)]), {}),
        "tenants_shared": (
            dict(budget_per_window=budget, tenant_budgets=tb),
            ConstraintSpec([TenantAxis(tuple(tb)),
                            GlobalAxis(budget=budget)]), {}),
        "tenants_priced": (
            dict(budget_per_window=budget, tenant_budgets=tb,
                 tenant_mode="priced"),
            ConstraintSpec([TenantAxis(tuple(tb), priced=True),
                            GlobalAxis(budget=budget)]), {}),
        "geo_argmax": (
            dict(budget_per_window=budget, n_regions=2),
            ConstraintSpec([RegionAxis(2, split="argmax"),
                            GlobalAxis(budget=budget)]),
            dict(budget=np.array([budget, budget]) * kappa_ci,
                 cost_scale=np.array([kappa_ci, kappa_ci]))),
        "carbon_plain": (
            dict(budget_per_window=budget),
            ConstraintSpec([GlobalAxis(budget=budget,
                                       pricing="carbon")]),
            dict(budget=budget * kappa_ci, cost_scale=kappa_ci)),
    }
    for name, (legacy_kw, spec, serve_kw) in cases.items():
        legacy = ServingPipeline(server, params, rcfg, **legacy_kw)
        built = ServingPipeline.from_spec(server, params, rcfg, spec)
        assert built.budget == legacy.budget, name
        assert np.shape(built.lam) == np.shape(legacy.lam), name
        for ctx, rows in _windows(40, seed=11):
            r_l = legacy.serve_window(ctx, rows, **serve_kw)
            r_s = built.serve_window(ctx, rows, **serve_kw)
            _assert_same_window(r_l, r_s)
        # the free-running published prices stayed bitwise in lockstep
        np.testing.assert_array_equal(np.asarray(legacy.lam),
                                      np.asarray(built.lam)), name


# ---------------------------------------------------------------------------
# Exact flow-splitting primal rounding (the region_jitter replacement)
# ---------------------------------------------------------------------------


def test_flow_split_divides_degenerate_window_proportionally(tiny_stack):
    """Identical region scales (exact tie): the flow split hands each
    region a FLOPs share proportional to its remaining budget capacity,
    deterministically, while chain decisions match the plain pipeline."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    b = 64
    budget = 0.45 * float(chains.costs.max()) * b
    spec = ConstraintSpec([RegionAxis(2, split="flow"),
                           GlobalAxis(budget=budget)])
    geo = ServingPipeline.from_spec(server, params, rcfg, spec,
                                    guard=False)
    plain = ServingPipeline(server, params, rcfg, budget, guard=False)
    budgets = np.array([3.0, 1.0]) * budget  # 75 / 25 capacity split
    ctx, rows = _windows(40, n_windows=1, seed=12)[0]
    r_g = geo.serve_window(ctx, rows, lam=0.0, budget=budgets,
                           cost_scale=np.array([1.0, 1.0]))
    r_p = plain.serve_window(ctx, rows, lam=0.0)
    np.testing.assert_array_equal(r_g.decisions_np, r_p.decisions_np)
    flops = chains.costs[r_g.decisions_np]
    frac0 = flops[r_g.regions_np == 0].sum() / flops.sum()
    # proportional up to one request's granularity at the interval edge
    assert abs(frac0 - 0.75) <= float(flops.max() / flops.sum())
    # deterministic: the same window splits the same way again
    r_g2 = geo.serve_window(ctx, rows, lam=0.0, budget=budgets,
                            cost_scale=np.array([1.0, 1.0]))
    np.testing.assert_array_equal(r_g.regions_np, r_g2.regions_np)


def test_flow_split_untied_window_reduces_to_argmax(tiny_stack):
    """Distinct per-flop priced costs (no tie): flow and argmax route
    identically - everything to the cheapest-priced (greener) region."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    b = 64
    budget = 0.45 * float(chains.costs.max()) * b
    kappa = 3.2e-7
    scales = kappa * np.array([600.0, 200.0])  # 3x apart: clear winner
    budgets = np.full(2, budget * kappa * 400.0)
    pipes = {}
    for split in ("flow", "argmax"):
        spec = ConstraintSpec([RegionAxis(2, split=split),
                               GlobalAxis(budget=budget)])
        pipes[split] = ServingPipeline.from_spec(server, params, rcfg,
                                                 spec)
    for ctx, rows in _windows(40, n_windows=3, seed=13):
        r_f = pipes["flow"].serve_window(ctx, rows, lam=0.0,
                                         budget=budgets,
                                         cost_scale=scales)
        r_a = pipes["argmax"].serve_window(ctx, rows, lam=0.0,
                                           budget=budgets,
                                           cost_scale=scales)
        np.testing.assert_array_equal(r_f.decisions_np, r_a.decisions_np)
        np.testing.assert_array_equal(r_f.regions_np, r_a.regions_np)
        assert np.all(r_f.regions_np == 1)  # the greener region


def test_flow_split_respects_caps_and_beats_bang_bang(tiny_stack):
    """Free-running flow-split day on a dirty/green pair: majority lands
    green, per-region caps hold, and the split is non-degenerate once
    the prices bind (not a whole-window bang-bang)."""
    from repro.core.primal_dual import DualDescentConfig
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    b = 64
    kappa = 3.2e-7
    flops_budget = 0.45 * float(chains.costs.max()) * b
    spec = ConstraintSpec([RegionAxis(2, split="flow"),
                           GlobalAxis(budget=flops_budget,
                                      pricing="carbon")])
    geo = ServingPipeline.from_spec(
        server, params, rcfg, spec,
        dual_cfg=DualDescentConfig(max_iters=300, step_decay=0.98))
    ci = np.array([600.0, 200.0])
    scales = kappa * ci
    budgets = np.full(2, 0.5 * flops_budget * kappa * float(ci.mean()))
    splits = []
    for ctx, rows in _windows(40, n_windows=6, seed=7):
        res = geo.serve_window(ctx, rows, budget=budgets,
                               cost_scale=scales)
        splits.append(float((res.regions_np == 1).mean()))
    assert (np.asarray(res.regions_np) == 1).mean() > 0.5
    for r in range(2):
        floor_g = len(res.regions_np) * float(chains.costs.min()) \
            * scales[r]
        assert float(res.region_spend[r]) <= max(budgets[r], floor_g) \
            * (1 + 1e-5)
    # once the green cap binds, the window is SPLIT, not bang-banged
    assert any(0.05 < s < 0.95 for s in splits[2:])


# ---------------------------------------------------------------------------
# The combined tenant x region pipeline
# ---------------------------------------------------------------------------


def _combined_pipe(tiny_stack_t, *, priced=True, split="flow",
                   guard=True, t_n=2, budget=None):
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack_t
    per = 32
    budget = budget or 0.5 * float(chains.costs.max()) * per
    tb = tuple(float(budget) * (0.5 + 0.5 * t) for t in range(t_n))
    spec = ConstraintSpec([
        TenantAxis(tb, priced=priced),
        RegionAxis(2, split=split),
        GlobalAxis(pricing="carbon"),
    ])
    return ServingPipeline.from_spec(server, params, rcfg, spec,
                                     guard=guard), tb, per


def test_geotenants_window_caps_and_spend_consistency(tiny_stack):
    """Both constraint families hold at once: every tenant's gram spend
    respects its budget, every region's its cap, and the (T, R) spend
    matrix is consistent with its marginals and the total."""
    chains, server, params, rcfg = tiny_stack
    pipe, tb_f, per = _combined_pipe(tiny_stack)
    t_n, r_n = 2, 2
    kappa = 3.2e-7
    ci = np.array([500.0, 300.0])
    scales = kappa * ci
    # gram budgets: tenant budgets from FLOPs at mean CI; region caps
    # at 70% of the total (both families can bind)
    tg = np.asarray(tb_f) * kappa * float(ci.mean())
    rg = np.full(r_n, 0.7 * tg.sum())
    bud = np.concatenate([tg, rg])
    res = None
    for ctx, rows in _windows(40, n_windows=6, n=t_n * per, seed=14):
        res = pipe.serve_window(ctx, rows, budget=bud,
                                cost_scale=scales)
    tr = np.asarray(res.tr_spend)
    assert tr.shape == (t_n, r_n)
    np.testing.assert_allclose(tr.sum(axis=1),
                               np.asarray(res.tenant_spend), rtol=1e-6)
    np.testing.assert_allclose(tr.sum(axis=0),
                               np.asarray(res.region_spend), rtol=1e-6)
    np.testing.assert_allclose(tr.sum(), float(res.spend), rtol=1e-6)
    assert np.asarray(res.lam_after).shape == (t_n + r_n,)
    assert res.k_budget.shape == (t_n + r_n,)
    c_min_g = float(chains.costs.min()) * scales.min()
    for t in range(t_n):
        floor = per * c_min_g
        assert tr[t].sum() <= max(tg[t], floor) * (1 + 1e-5), t
    regions = res.regions_np
    for r in range(r_n):
        n_r = int((regions == r).sum())
        floor = n_r * float(chains.costs.min()) * scales[r]
        assert tr[:, r].sum() <= max(rg[r], floor) * (1 + 1e-5), r


def test_geotenants_tight_tenant_carries_higher_price(tiny_stack):
    """The (T + R,) price vector separates the axes: the starved tenant
    's price rises above the slack tenant's, while region prices react
    to the region caps."""
    chains, server, params, rcfg = tiny_stack
    from repro.core.primal_dual import DualDescentConfig
    from repro.serving.pipeline import ServingPipeline

    per, t_n = 32, 2
    c_max = float(chains.costs.max())
    kappa_ci = 3.2e-7 * 450.0
    # tenant 0 starved, tenant 1 slack (in grams)
    tg = np.array([0.25, 3.0]) * c_max * per * kappa_ci
    rg = np.full(2, 0.8 * tg.sum())
    spec = ConstraintSpec([
        TenantAxis(tuple(tg / kappa_ci), priced=True),
        RegionAxis(2, split="flow"),
        GlobalAxis(pricing="carbon"),
    ])
    pipe = ServingPipeline.from_spec(
        server, params, rcfg, spec,
        dual_cfg=DualDescentConfig(max_iters=300, step_decay=0.98))
    bud = np.concatenate([tg, rg])
    scales = np.full(2, kappa_ci)
    for ctx, rows in _windows(40, n_windows=8, n=t_n * per, seed=15):
        res = pipe.serve_window(ctx, rows, budget=bud,
                                cost_scale=scales)
    lam = np.asarray(pipe.lam)
    assert lam.shape == (4,)
    assert lam[0] > lam[1]  # starved tenant prices itself
    floor = per * float(chains.costs.min()) * kappa_ci
    tr = np.asarray(res.tr_spend)
    assert tr[0].sum() <= max(tg[0], floor) * (1 + 1e-5)


def test_geotenants_shared_mode_prices_regions_only(tiny_stack):
    """TenantAxis(priced=False) + RegionAxis: the price vector is (R,)
    (region prices only) while tenant budgets are still guard-enforced."""
    chains, server, params, rcfg = tiny_stack
    pipe, tb_f, per = _combined_pipe(tiny_stack, priced=False)
    kappa_ci = 3.2e-7 * 450.0
    tg = np.asarray(tb_f) * kappa_ci
    bud = np.concatenate([tg, np.full(2, 0.7 * tg.sum())])
    scales = np.full(2, kappa_ci)
    ctx, rows = _windows(40, n_windows=1, n=2 * per, seed=16)[0]
    res = pipe.serve_window(ctx, rows, budget=bud, cost_scale=scales)
    assert np.asarray(res.lam_after).shape == (2,)
    tr = np.asarray(res.tr_spend)
    floor = per * float(chains.costs.min()) * kappa_ci
    for t in range(2):
        assert tr[t].sum() <= max(tg[t], floor) * (1 + 1e-5)


def test_geotenants_pinned_prices_match_brute_force(tiny_stack):
    """At pinned (T + R,) prices with the guard off, the fused combined
    pass reproduces the float64 brute-force argmax over the (chain,
    region) option space wherever the decision is f32-resolvable."""
    chains, server, params, rcfg = tiny_stack
    from repro.core.reward_model import (denormalize_rewards,
                                         reward_matrix)

    pipe, tb_f, per = _combined_pipe(tiny_stack, split="argmax",
                                     guard=False)
    t_n = r_n = 2
    j_n = chains.n_chains
    rng = np.random.default_rng(17)
    lam = rng.uniform(0.0, 1.0, t_n + r_n).astype(np.float32) \
        / float(chains.costs.max())
    scales = np.array([1.1, 0.8], np.float32)
    bud = np.full(t_n + r_n, 1e30, np.float32)
    mo = jnp.asarray(chains.model_onehot)
    sh = jnp.asarray(chains.scale_multihot)
    ctx, rows = _windows(40, n_windows=1, n=t_n * per, seed=18)[0]
    res = pipe.serve_window(ctx, rows, lam=lam, budget=bud,
                            cost_scale=scales)
    dec_m = res.regions_np * j_n + res.decisions_np

    rewards = np.asarray(denormalize_rewards(
        pipe.reward_params, reward_matrix(
            pipe.reward_params, rcfg, jnp.asarray(ctx, jnp.float32),
            mo, sh))).astype(np.float64)
    t_of = np.repeat(np.arange(t_n), per)
    costs = chains.costs.astype(np.float64)
    score = np.concatenate([
        rewards - ((lam[t_of] + lam[t_n + r])[:, None]
                   * float(scales[r]) * costs[None, :])
        for r in range(r_n)], axis=1)
    ref = np.argmax(score, axis=1)
    srt = np.sort(score, axis=1)
    decided = (srt[:, -1] - srt[:, -2]) > 1e-4
    assert decided.mean() > 0.85
    np.testing.assert_array_equal(dec_m[decided], ref[decided])


def test_spec_built_host_controllers_match_direct(tiny_stack):
    """BudgetController/CarbonBudgetController.from_spec == the directly
    built controllers, decision-for-decision."""
    from repro.carbon.controller import (CarbonBudget,
                                         CarbonBudgetController)
    from repro.carbon.intensity import constant_trace
    from repro.core.budget import BudgetController

    chains, _, _, _ = tiny_stack
    b_f = 0.5 * float(chains.costs.max()) * 48
    spec = ConstraintSpec([GlobalAxis(budget=b_f)])
    spec_c = ConstraintSpec([GlobalAxis(budget=b_f, pricing="carbon")])
    tr = constant_trace(600.0, n=24)
    rng = np.random.default_rng(19)
    rewards = [rng.random((48, chains.n_chains)).astype(np.float32)
               for _ in range(3)]

    direct = BudgetController(chains, b_f)
    built = BudgetController.from_spec(chains, spec)
    cb = CarbonBudget.from_flops(b_f, tr)
    direct_c = CarbonBudgetController(chains, cb, pricing="carbon")
    built_c = CarbonBudgetController.from_spec(chains, spec_c, tr)
    assert built_c.pricing == "carbon"
    for r in rewards:
        np.testing.assert_array_equal(direct.step_window(r),
                                      built.step_window(r))
        np.testing.assert_array_equal(direct_c.step_window(r),
                                      built_c.step_window(r))
    with pytest.raises(ValueError, match="plain"):
        BudgetController.from_spec(chains, ConstraintSpec(
            [TenantAxis((1.0, 2.0))]))
    with pytest.raises(ValueError, match="carbon"):
        BudgetController.from_spec(chains, spec_c)


def test_scenario_registry_is_single_source():
    """The stream registry drives both the valid-names error and the
    serve CLI's --scenario choices (no second hand-maintained list)."""
    from repro.serving.stream import (SCENARIOS, TrafficScenario,
                                      scenario_windows)

    assert "geotenants" in SCENARIOS
    sizes = scenario_windows(TrafficScenario("geotenants", 12, 96,
                                             n_tenants=3))
    assert len(sizes) == 12 and all(n % 3 == 0 for n in sizes)
    with pytest.raises(ValueError, match="geotenants"):
        scenario_windows(TrafficScenario("nope", 4, 8))

    import repro.launch.serve as serve_mod
    src = open(serve_mod.__file__).read()
    assert "choices=tuple(SCENARIOS)" in src


# ---------------------------------------------------------------------------
# Request-axis sharding: subprocess with 8 fake host devices
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_geotenants_sharded_matches_unsharded():
    """The combined tenant x region pass under an 8-way request mesh:
    decisions equal and the (T + R,) lambda traces match the
    single-process run at pinned entry prices (the ISSUE acceptance
    gate for the new pipeline)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import RewardModelConfig, reward_model_init
    from repro.launch.mesh import make_request_mesh
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    t_n, r_n, per = 2, 2, 64
    c_max = float(chains.costs.max())
    kappa_ci = 3.2e-7 * 450.0
    tb = (np.array([0.35, 0.6]) * c_max * per).astype(np.float64)
    spec = ConstraintSpec([
        TenantAxis(tuple(tb), priced=True),
        RegionAxis(r_n, split="flow"),
        GlobalAxis(pricing="carbon"),
    ])
    mesh = make_request_mesh(8)
    pipe_s = ServingPipeline.from_spec(server, params, rcfg, spec,
                                       mesh=mesh)
    pipe_u = ServingPipeline.from_spec(server, params, rcfg, spec)
    tg = tb * kappa_ci
    bud = np.concatenate([tg, np.full(r_n, 0.7 * tg.sum())])
    scales = kappa_ci * np.array([1.2, 0.8])
    rng2 = np.random.default_rng(1)
    # free-run the single-process reference, keeping each window's
    # ENTRY price; the sharded run serves at the same pinned entry
    # price, so decisions must match exactly while published
    # (psum-stitched) prices match to float tolerance.
    wins = []
    for t in range(4):
        n = t_n * per
        rows = rng2.integers(0, u, n)
        ctx = rng2.normal(size=(n, 12)).astype(np.float32)
        lam_in = np.asarray(pipe_u.lam)
        wins.append((ctx, rows, lam_in,
                     pipe_u.serve_window(ctx, rows, budget=bud,
                                         cost_scale=scales)))
    for t, (ctx, rows, lam_in, ru) in enumerate(wins):
        rs = pipe_s.serve_window(ctx, rows, lam=jnp.asarray(lam_in),
                                 budget=bud, cost_scale=scales)
        assert np.array_equal(rs.decisions_np, ru.decisions_np), t
        assert np.array_equal(rs.regions_np, ru.regions_np), t
        assert np.array_equal(rs.revenue_np, ru.revenue_np), t
        assert int(rs.downgraded) == int(ru.downgraded), t
        np.testing.assert_allclose(np.asarray(rs.tr_spend),
                                   np.asarray(ru.tr_spend), rtol=1e-5)
        lam_u = np.asarray(ru.lam_after)
        np.testing.assert_allclose(np.asarray(rs.lam_after), lam_u,
                                   rtol=1e-4,
                                   atol=5e-3 * float(np.max(lam_u)))
    assert np.asarray(pipe_u.lam).shape == (t_n + r_n,)
    print("GEOTENANTS SHARDED PARITY OK")
    """)], capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    assert "GEOTENANTS SHARDED PARITY OK" in out.stdout
