"""Multi-host request mesh: bring-up, cross-host window routing,
stitched guard/dual collectives, and elastic re-sharding.

The acceptance gates (ISSUE PR 9):
  * an 8-process subprocess mesh streams windows whose decisions, lam
    traces and per-axis spends are BITWISE identical to the
    single-process reference sharded over the same 8 devices - for the
    plain pipeline AND the combined tenant x region (geotenants) spec -
    with zero steady-state recompiles on every host;
  * every host agrees bitwise with every other host on the replicated
    lam/spend chain (the ordered_psum stitching);
  * a stream checkpointed by a 2-host group resumes on a 4-host group
    (elastic join) and continues bitwise-identically to the
    uninterrupted reference (reshard-on-restore + (seed, t) replay).

True multi-process collectives are exercised by spawning N child
processes that join one ``jax.distributed`` group over the loopback
coordinator, each with ``8/N`` fake host devices so the GLOBAL shard
count is always 8 - bitwise parity across different world sizes only
holds at a fixed shard count, because the all_gather-based reductions
sum in shard order.
"""
import json
import os
import socket
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))

# Self-contained child: builds the cheap random-score serving stack
# (the test_serving.py sharded-parity stack) over a replay source and
# streams it - single-process reference or multi-host member, plain or
# geotenants, with an optional elastic checkpoint/resume phase.
CHILD = textwrap.dedent("""
    import json, os, sys
    import numpy as np

    from repro.distributed import multihost as mh

    dist = mh.initialize()
    import jax
    import jax.numpy as jnp

    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import RewardModelConfig, reward_model_init
    from repro.data.request_source import TableReplaySource
    from repro.launch.mesh import make_request_mesh, process_shard_rows
    from repro.serving.pipeline import ServingPipeline, window_layout
    from repro.serving.stream import run_stream

    mode = os.environ["MH_MODE"]          # plain | geotenants
    phase = os.environ.get("MH_PHASE", "")  # "" | a | b (elastic)

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    ctx = np.random.default_rng(5).normal(size=(u, 12)).astype(np.float32)
    src = TableReplaySource.from_server(server, ctx, seed=7,
                                        device_tables=False)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    budget = 0.5 * float(chains.costs.max()) * 64
    mesh = make_request_mesh()

    bt = st_tr = None
    if mode == "geotenants":
        from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                        RegionAxis, TenantAxis)
        sizes = [48, 96, 48, 64]
        per = 0.5 * float(chains.costs.max())
        spec = ConstraintSpec([
            TenantAxis((per * 24, per * 24), priced=True),
            RegionAxis(2), GlobalAxis(pricing="carbon"),
        ])
        bt = [np.concatenate([np.full(2, per * n / 2),
                              np.full(2, 0.6 * per * n)]).astype(np.float32)
              for n in sizes]
        st_tr = [np.array([1.0, 1.3], np.float32)] * len(sizes)
        pipe = ServingPipeline.from_spec(src.universe, params, rcfg,
                                         spec, mesh=mesh)
    else:
        sizes = [64, 192, 50, 64, 96, 64]
        pipe = ServingPipeline(src.universe, params, rcfg, budget,
                               mesh=mesh)

    t0 = 0
    if phase == "a":      # elastic leg 1: serve a prefix, checkpoint
        sizes = sizes[:3]
    elif phase == "b":    # elastic leg 2: restore, resume the suffix
        ck = mh.restore_stream(os.environ["MH_CKPT"], pipe)
        t0 = ck.t_next
        src = mh.ShiftedSource(src, t0)
        bt, st_tr = (None if bt is None else bt[t0:],
                     None if st_tr is None else st_tr[t0:])
        sizes = sizes[t0:]

    source = mh.MultihostSource(src, pipe) if dist else src
    stats = run_stream(pipe, sizes, source, prefetch=0,
                       budget_trace=bt, scale_trace=st_tr)
    if phase == "a" and jax.process_index() == 0:
        mh.checkpoint_stream(os.environ["MH_CKPT"], pipe,
                             t_next=len(sizes), seed=src.seed)

    t_n = (None if pipe.tenant_budgets is None
           else len(pipe.tenant_budgets))
    windows = []
    for t, (r, n) in enumerate(zip(stats.windows, sizes)):
        if dist:
            b = pipe.window_bucket(n)
            perm, valid, _ = window_layout(n, b, t_n)
            rows_g = np.concatenate(
                [np.arange(lo, hi) for lo, hi in
                 process_shard_rows(pipe.mesh, b)])
            req = perm[rows_g[valid[rows_g] > 0]]
        else:
            req = np.arange(n)
        row = {
            "req": req.tolist(),
            "dec": np.asarray(r.decisions_np).tolist(),
            "lam": np.asarray(mh._host_value(r.lam_after),
                              np.float64).reshape(-1).tolist(),
            "spend": np.asarray(mh._host_value(r.spend),
                                np.float64).reshape(-1).tolist(),
        }
        if mode == "geotenants":
            row["regions"] = np.asarray(r.regions_np).tolist()
            row["tr"] = np.asarray(mh._host_value(r.tr_spend),
                                   np.float64).reshape(-1).tolist()
        windows.append(row)
    out = {"host": mh.host_report(), "t0": t0,
           "steady_compiles": int(stats.steady_compiles),
           "windows": windows}
    with open(os.environ["MH_OUT"], "w") as f:
        json.dump(out, f)
    print("CHILD OK", mh.host_report())
""")


def _free_port() -> int:
    s = socket.socket()
    s.bind(("localhost", 0))
    port = s.getsockname()[1]
    s.close()
    return port


def _launch(n_procs: int, tmp_path, mode: str, phase: str = "",
            timeout: int = 600) -> list[dict]:
    """Spawn a jax.distributed group of ``n_procs`` children (8/N fake
    devices each -> always 8 global shards) and gather their digests;
    ``n_procs=1`` runs the identically-sharded single-process
    reference."""
    assert 8 % n_procs == 0
    port = _free_port()
    procs = []
    for pid in range(n_procs):
        out = str(tmp_path / f"mh_{mode}{phase}_{pid}.json")
        env = dict(os.environ)
        env.update({
            "PYTHONPATH": os.path.join(REPO, "src"),
            "JAX_PLATFORMS": "cpu",
            "XLA_FLAGS": ("--xla_force_host_platform_device_count="
                          f"{8 // n_procs}"),
            "MH_MODE": mode, "MH_PHASE": phase, "MH_OUT": out,
            "MH_CKPT": str(tmp_path / "stream_ckpt.json"),
        })
        if n_procs > 1:
            env.update({
                "GREENFLOW_COORDINATOR": f"localhost:{port}",
                "GREENFLOW_NUM_PROCESSES": str(n_procs),
                "GREENFLOW_PROCESS_ID": str(pid),
            })
        procs.append((out, subprocess.Popen(
            [sys.executable, "-c", CHILD], env=env,
            stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
            text=True)))
    digests = []
    for out, p in procs:
        o, _ = p.communicate(timeout=timeout)
        assert p.returncode == 0, f"child {out} failed:\n{o[-4000:]}"
        with open(out) as f:
            digests.append(json.load(f))
    return digests


def _stitch(children: list[dict], t: int, key: str) -> np.ndarray:
    """Per-host local rows -> the global request-order vector."""
    req = np.concatenate([np.asarray(c["windows"][t]["req"], np.int64)
                          for c in children])
    val = np.concatenate([np.asarray(c["windows"][t][key])
                          for c in children])
    order = np.argsort(req)
    assert (req[order] == np.arange(len(req))).all()
    return val[order]


def _assert_group_matches_reference(ref: dict, children: list[dict],
                                    geotenants: bool = False,
                                    ref_offset: int = 0) -> None:
    for t in range(len(children[0]["windows"])):
        rw = ref["windows"][t + ref_offset]
        for c in children:  # every host agrees bitwise on global state
            cw = c["windows"][t]
            assert cw["lam"] == rw["lam"], \
                (t, c["host"]["process_index"], cw["lam"], rw["lam"])
            assert cw["spend"] == rw["spend"], \
                (t, c["host"]["process_index"])
            if geotenants:
                assert cw["tr"] == rw["tr"], (t, c["host"])
        np.testing.assert_array_equal(
            _stitch(children, t, "dec"), np.asarray(rw["dec"]),
            err_msg=f"decisions w{t}")
        if geotenants:
            np.testing.assert_array_equal(
                _stitch(children, t, "regions"),
                np.asarray(rw["regions"]), err_msg=f"regions w{t}")


# ---------------------------------------------------------------------------
# Subprocess-mesh acceptance gates
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_multihost_8process_bitwise_plain(tmp_path):
    """8 coordinator-joined processes (1 device each) serve the plain
    stream bitwise-identically to the single-process 8-shard reference:
    stitched guard prefix sums, global dual chain, decisions - and zero
    steady-state recompiles on EVERY host."""
    ref = _launch(1, tmp_path, "plain")[0]
    children = _launch(8, tmp_path, "plain")
    # (the reference may pay a one-time donated-lam relayout retrace per
    # bucket; the multihost path replicates lam globally BEFORE window 0,
    # so its steady state must be exactly zero)
    for c in children:
        assert c["steady_compiles"] == 0, c["host"]
        assert c["host"]["process_count"] == 8
        assert c["host"]["global_devices"] == 8
    _assert_group_matches_reference(ref, children)


@pytest.mark.slow
def test_multihost_bitwise_geotenants(tmp_path):
    """The combined tenant x region spec over 2 hosts: per-tenant AND
    per-region prices/spends ((T + R,) budget vectors) stitch globally
    to the reference bitwise - including the (T, R) spend matrix and
    every request's serving region."""
    ref = _launch(1, tmp_path, "geotenants")[0]
    children = _launch(2, tmp_path, "geotenants")
    for c in children:
        assert c["steady_compiles"] == 0, c["host"]
    _assert_group_matches_reference(ref, children, geotenants=True)


@pytest.mark.slow
def test_multihost_elastic_join_leave_resume(tmp_path):
    """Elastic re-sharding mid-stream: a 2-host group serves windows
    0..2 and checkpoints {cursor, dual chain, seed}; a 4-host group
    (hosts JOINED) restores, replays the in-flight window and serves
    3..5 bitwise-identically to the uninterrupted reference - windows
    are pure (seed, t) functions, so nothing but the tiny checkpoint
    crosses the restart.  The SAME checkpoint then resumes on a lone
    process (hosts LEFT), again bitwise: restore is group-size
    agnostic in both directions."""
    ref = _launch(1, tmp_path, "plain")[0]
    a = _launch(2, tmp_path, "plain", phase="a")
    assert all(len(c["windows"]) == 3 for c in a)
    _assert_group_matches_reference(ref, a)  # prefix already bitwise
    b = _launch(4, tmp_path, "plain", phase="b")
    assert all(c["t0"] == 3 for c in b)
    _assert_group_matches_reference(ref, b, ref_offset=3)
    down = _launch(1, tmp_path, "plain", phase="b")
    assert all(c["t0"] == 3 for c in down)
    _assert_group_matches_reference(ref, down, ref_offset=3)


# ---------------------------------------------------------------------------
# Host-side routing geometry (single-process, cheap)
# ---------------------------------------------------------------------------


def _cheap_stack(mesh=None, tenants=None):
    import jax
    import jax.numpy as jnp

    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import (RewardModelConfig,
                                         reward_model_init)
    from repro.data.request_source import TableReplaySource
    from repro.serving.pipeline import ServingPipeline

    rng = np.random.default_rng(0)
    u, i = 30, 80
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 2),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), (24, 40), 2),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),), (8, 16), 2),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    ctx = np.random.default_rng(5).normal(size=(u, 12)).astype(np.float32)
    src = TableReplaySource.from_server(server, ctx, seed=7,
                                        device_tables=False)
    rcfg = RewardModelConfig(n_stages=3, max_models=1, n_scale_groups=2,
                             d_context=12, d_feature=8, d_hidden=8,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    budget = 0.5 * float(chains.costs.max()) * 64
    pipe = ServingPipeline(src.universe, params, rcfg, budget,
                           mesh=mesh, tenant_budgets=tenants,
                           tenant_mode=("priced" if tenants is not None
                                        else "shared"))
    return src, pipe


def test_multihost_source_scatters_exact_table_slices():
    """Single-process MultihostSource geometry: local rows tile per
    shard, pad rows carry the sentinel fill, and every valid row's
    context/table columns are exactly the inner source's rows for the
    globally laid-out users."""
    from repro.distributed.multihost import MultihostSource
    from repro.launch.mesh import make_request_mesh
    from repro.serving.pipeline import window_layout

    mesh = make_request_mesh(1)
    src, pipe = _cheap_stack(mesh=mesh)
    msrc = MultihostSource(src, pipe)
    t, n = 3, 50
    chunk = msrc.window(t, n)
    b = pipe.window_bucket(n)
    perm, valid, _ = window_layout(n, b, None)
    assert chunk.shard.n == n and chunk.shard.b == b
    np.testing.assert_array_equal(chunk.shard.valid, valid)
    np.testing.assert_array_equal(chunk.rows, np.arange(b))
    users = src.arrivals(t, n)
    inner = src.window_for_users(users[perm[valid > 0]])
    m = valid > 0
    np.testing.assert_array_equal(chunk.ctx[m], inner.ctx)
    np.testing.assert_array_equal(chunk.tables["p"][:, m, :],
                                  inner.tables["p"])
    np.testing.assert_array_equal(chunk.tables["ck"][:, m, :],
                                  inner.tables["ck"])
    # pad rows: the _pad_chunk_tables sentinel fill, masked by valid
    assert (chunk.tables["p"][:, ~m, :] == pipe._cap).all()
    assert (chunk.tables["ck"][:, ~m, :] == 0).all()
    assert (chunk.ctx[~m] == 0).all()


def test_multihost_source_tenant_blocks():
    """Tenant windows lay out per-tenant padded blocks; the routed
    slice carries the matching k_of labels."""
    from repro.distributed.multihost import MultihostSource
    from repro.launch.mesh import make_request_mesh
    from repro.serving.pipeline import window_layout

    mesh = make_request_mesh(1)
    src, pipe = _cheap_stack(
        mesh=mesh, tenants=np.asarray([100.0, 100.0], np.float32))
    msrc = MultihostSource(src, pipe)
    n = 36
    chunk = msrc.window(0, n)
    b = pipe.window_bucket(n)
    _, valid, k_of = window_layout(n, b, 2)
    np.testing.assert_array_equal(chunk.shard.k_of, k_of)
    np.testing.assert_array_equal(chunk.shard.valid, valid)
    assert chunk.n == n  # shard-aware WindowChunk.n is the GLOBAL count
    assert len(chunk.rows) == b


def test_window_layout_invariants():
    """Every host derives the same layout from (n, b) alone: plain
    windows pad at the end, tenant windows pad per block, and the valid
    entries of perm enumerate requests in order."""
    from repro.serving.pipeline import window_layout

    perm, valid, k_of = window_layout(50, 64, None)
    assert k_of is None
    np.testing.assert_array_equal(perm[valid > 0], np.arange(50))
    assert valid.sum() == 50 and (valid[:50] == 1).all()

    perm, valid, k_of = window_layout(36, 48, 2)
    np.testing.assert_array_equal(perm[valid > 0], np.arange(36))
    np.testing.assert_array_equal(np.bincount(k_of[valid > 0]), [18, 18])
    with pytest.raises(ValueError):
        window_layout(35, 48, 2)  # n not divisible by tenants
    with pytest.raises(ValueError):
        window_layout(36, 49, 2)  # b not divisible by tenants


def test_process_shard_rows_single_process():
    from repro.launch.mesh import (make_request_mesh, mesh_local_shards,
                                   mesh_num_shards, process_shard_rows)

    mesh = make_request_mesh(1)
    assert mesh_num_shards(mesh) == mesh_local_shards(mesh) == 1
    assert process_shard_rows(mesh, 64) == [(0, 64)]
    assert mesh_num_shards(None) == 1 and mesh_local_shards(None) == 1


# ---------------------------------------------------------------------------
# Elastic checkpoint + bring-up plumbing (single-process, cheap)
# ---------------------------------------------------------------------------


def test_stream_checkpoint_roundtrip(tmp_path):
    """checkpoint_stream -> restore_stream carries the dual chain
    bitwise (float32 -> float64 json -> float32 is exact) and the
    cursor/seed; ShiftedSource replays the global window clock."""
    import jax.numpy as jnp

    from repro.distributed.multihost import (ShiftedSource,
                                             checkpoint_stream,
                                             restore_stream)

    src, pipe = _cheap_stack()
    _serve_one(pipe, src, 0, 40)
    lam_saved = np.asarray(pipe.lam)
    path = checkpoint_stream(str(tmp_path / "ck.json"), pipe,
                             t_next=4, seed=src.seed)
    pipe.lam = jnp.zeros_like(pipe.lam)  # clobber, then restore
    ck = restore_stream(path, pipe)
    assert ck.t_next == 4 and ck.seed == src.seed
    np.testing.assert_array_equal(np.asarray(pipe.lam), lam_saved)

    shifted = ShiftedSource(src, 4)
    np.testing.assert_array_equal(shifted.arrivals(0, 32),
                                  src.arrivals(4, 32))
    a, b = shifted.window(1, 24), src.window(5, 24)
    np.testing.assert_array_equal(a.ctx, b.ctx)
    np.testing.assert_array_equal(a.tables["p"], b.tables["p"])


def _serve_one(pipe, src, t, n):
    chunk = src.window(t, n)
    return pipe.serve_window(chunk.ctx, chunk.rows, tables=chunk.tables)


def test_initialize_noop_without_coordinator(monkeypatch):
    from repro.distributed import multihost as mh

    for k in ("GREENFLOW_COORDINATOR", "GREENFLOW_NUM_PROCESSES",
              "GREENFLOW_PROCESS_ID"):
        monkeypatch.delenv(k, raising=False)
    assert mh.initialize() is False
    assert mh.initialize(num_processes=1) is False
    # num_processes alone (no coordinator anywhere) stays a no-op too
    assert mh.initialize(num_processes=4) is False


def test_host_report_and_label():
    from repro.distributed import multihost as mh

    rep = mh.host_report()
    assert rep["process_count"] == 1 and rep["process_index"] == 0
    assert rep["local_devices"] == rep["global_devices"] >= 1
    assert mh.host_label() == "host0"
    assert mh.host_label(3) == "host3"


# ---------------------------------------------------------------------------
# Per-host flight-recorder labels
# ---------------------------------------------------------------------------


def test_tracer_process_label_and_merge(tmp_path):
    from repro.obs import Tracer, merge_chrome_traces

    paths = []
    for h in range(2):
        tr = Tracer(process_label=f"host{h}")
        with tr.span("serve", t=0):
            pass
        paths.append(tr.write(str(tmp_path / f"trace{h}.json")))
    merged = merge_chrome_traces(
        paths, out_path=str(tmp_path / "merged.json"))
    names = [e["args"]["name"] for e in merged["traceEvents"]
             if e.get("name") == "process_name"]
    assert sorted(names) == ["host0", "host1"]
    with open(tmp_path / "merged.json") as f:
        again = json.load(f)
    assert len(again["traceEvents"]) == len(merged["traceEvents"])
    spans = [e for e in again["traceEvents"] if e.get("ph") == "X"]
    assert len(spans) == 2 and len({e["pid"] for e in
                                    merged["traceEvents"]}) == 1


def test_window_event_host_label():
    from repro.obs import Obs, window_event

    src, pipe = _cheap_stack()
    r = _serve_one(pipe, src, 0, 32)
    row = window_event(0, r, 1.0, host="host5")
    assert row["host"] == "host5"
    assert window_event(0, r, 1.0).get("host") is None
    obs = Obs(host="host2")
    assert obs.tracer.process_label == "host2"
