"""Vectorized multi-price allocator core: the ISSUE acceptance gates.

  * K=1 BIT-parity: the vector core (K-price allocate / dual_descent /
    downgrade_guard with a (J, 1) cost map) reproduces the scalar path
    bit-for-bit - decisions, prices, gap traces, and spends;
  * brute-force reference for K>1 tenant x region decisions and
    consumption at the core level;
  * the per-tenant (k_of) guard equals a vmap of per-block scalar
    guards bit-for-bit;
  * priced-tenant pipeline: T=1 degenerates to the plain pipeline
    bit-identically; distinct per-tenant budgets produce distinct
    per-tenant prices that respect each budget;
  * geo pipeline with two IDENTICAL regions reduces to the pinned
    (plain) pipeline decision-for-decision, flops and carbon pricing;
  * CI-forecast warm-start: bit-exact no-op on constant traces, and
    tracks a stepped CI trace strictly better than the lagging update;
  * 8-device subprocess parity: --tenant-mode priced under an 8-way
    request mesh matches the single-process per-tenant lambda traces.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.primal_dual import allocate, consumption, dual_descent
from repro.serving.guard import downgrade_guard

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# K=1 bit-parity (property-style sweep, fixed shapes -> one compile)
# ---------------------------------------------------------------------------


def test_k1_vector_core_bit_identical_to_scalar():
    rng = np.random.default_rng(0)
    i, j = 96, 12
    for trial in range(25):
        R = jnp.asarray(rng.uniform(0, 5, (i, j)), jnp.float32)
        c = jnp.asarray(rng.uniform(1, 10, j), jnp.float32)
        lam = jnp.float32(rng.uniform(0, 1))
        mask = jnp.asarray((rng.random(i) < 0.8).astype(np.float32))
        cv, lv = c[:, None], jnp.asarray([lam])

        np.testing.assert_array_equal(np.asarray(allocate(R, c, lam)),
                                      np.asarray(allocate(R, cv, lv)))
        u_s = consumption(R, c, lam, mask)
        u_v = consumption(R, cv, lv, mask)
        assert float(u_s) == float(u_v[0]), trial  # bitwise

        budget = 0.5 * float(u_s)
        l_s, g_s = dual_descent(R, c, budget, lam, mask=mask,
                                max_iters=200)
        l_v, g_v = dual_descent(R, cv, jnp.asarray([budget]), lv,
                                mask=mask, max_iters=200)
        assert float(l_s) == float(l_v[0]), trial  # bitwise
        np.testing.assert_array_equal(np.asarray(g_s),
                                      np.asarray(g_v[:, 0]))

        dec = jnp.asarray(rng.integers(0, j, i), jnp.int32)
        cheap = int(np.argmin(np.asarray(c)))
        bud = float(rng.uniform(0.3, 1.1)
                    * float(jnp.sum(jnp.take(c, dec) * mask)))
        d_s, k_s, s_s = downgrade_guard(dec, c, bud, cheap, mask)
        d_v, k_v, s_v = downgrade_guard(dec, c, jnp.asarray([bud]), cheap,
                                        mask, k_of=jnp.zeros(i, jnp.int32))
        np.testing.assert_array_equal(np.asarray(d_s), np.asarray(d_v))
        assert int(k_s) == int(k_v) and float(s_s) == float(s_v[0]), trial


# ---------------------------------------------------------------------------
# K>1 tenant x region: brute-force reference at the core level
# ---------------------------------------------------------------------------


def _tenant_region_instance(seed, i=48, j=5, t_n=2, r_n=2):
    """Random K = T*R instance: option m = r*J + j draws c_{j,r} from
    every (t, r) column; request i is member of its tenant's columns."""
    rng = np.random.default_rng(seed)
    base = rng.uniform(1, 10, j)
    region_scale = rng.uniform(0.5, 2.0, r_n)
    rewards = np.tile(rng.uniform(0, 5, (i, j)), (1, r_n)).astype(
        np.float32)
    k_n = t_n * r_n
    cost_map = np.zeros((j * r_n, k_n), np.float32)
    for r in range(r_n):
        for t in range(t_n):
            cost_map[r * j:(r + 1) * j, t * r_n + r] = base * \
                region_scale[r]
    tenant = rng.integers(0, t_n, i)
    member = np.zeros((i, k_n), np.float32)
    for r in range(r_n):
        member[np.arange(i), tenant * r_n + r] = 1.0
    lam = rng.uniform(0, 0.5, k_n).astype(np.float32)
    return rewards, cost_map, member, lam, tenant


def test_k_gt_1_allocate_matches_brute_force():
    for seed in range(8):
        rewards, cm, member, lam, _ = _tenant_region_instance(seed)
        dec = np.asarray(allocate(jnp.asarray(rewards), jnp.asarray(cm),
                                  jnp.asarray(lam), jnp.asarray(member)))
        # brute force in float64: argmax_m R_im - sum_k lam_k A_imk
        price = np.einsum("ik,mk,k->im", member.astype(np.float64),
                          cm.astype(np.float64), lam.astype(np.float64))
        score = rewards.astype(np.float64) - price
        ref = np.argmax(score, axis=1)
        # f32 core vs f64 reference: compare where the top-2 gap is
        # resolvable in float32
        srt = np.sort(score, axis=1)
        gap = srt[:, -1] - srt[:, -2]
        decided = gap > 1e-4
        assert decided.mean() > 0.9
        np.testing.assert_array_equal(dec[decided], ref[decided])

        used = np.asarray(consumption(
            jnp.asarray(rewards), jnp.asarray(cm), jnp.asarray(lam),
            member=jnp.asarray(member)))
        ref_used = np.einsum("ik,ik->k", member.astype(np.float64),
                             cm[dec].astype(np.float64))
        np.testing.assert_allclose(used, ref_used, rtol=1e-5)


def test_k_gt_1_dual_descent_respects_per_constraint_budgets():
    rewards, cm, member, _, _ = _tenant_region_instance(3, i=96)
    k_n = cm.shape[1]
    lam0 = jnp.zeros(k_n, jnp.float32)
    free = np.asarray(consumption(
        jnp.asarray(rewards), jnp.asarray(cm), lam0,
        member=jnp.asarray(member)))
    budgets = jnp.asarray(0.6 * free, jnp.float32)
    lam, gaps = dual_descent(jnp.asarray(rewards), jnp.asarray(cm),
                             budgets, lam0, member=jnp.asarray(member),
                             max_iters=400, step_size=2.0)
    used = np.asarray(consumption(
        jnp.asarray(rewards), jnp.asarray(cm), lam,
        member=jnp.asarray(member)))
    # every constraint's consumption is driven to (or under) its budget
    assert np.all(used <= np.asarray(budgets) * 1.05)
    # binding constraints carry positive prices
    assert np.all(np.asarray(lam)[used > 0.9 * np.asarray(budgets)] > 0)


def test_k_guard_matches_per_block_vmap_bit_for_bit():
    rng = np.random.default_rng(4)
    j, t_n, per = 8, 3, 64
    costs = jnp.asarray(rng.uniform(1.0, 10.0, j), jnp.float32)
    cheap = int(jnp.argmin(costs))
    for _ in range(10):
        dec = jnp.asarray(rng.integers(0, j, (t_n, per)), jnp.int32)
        budgets = jnp.asarray(rng.uniform(50, 400, t_n), jnp.float32)
        valid = jnp.asarray((rng.random((t_n, per)) < 0.9)
                            .astype(np.float32))
        gfn = jax.vmap(lambda d, v, b: downgrade_guard(d, costs, b,
                                                       cheap, v))
        d_ref, k_ref, s_ref = gfn(dec, valid, budgets)
        k_of = jnp.repeat(jnp.arange(t_n, dtype=jnp.int32), per)
        d_k, k_k, s_k = downgrade_guard(
            dec.reshape(-1), costs, budgets, cheap, valid.reshape(-1),
            k_of=k_of)
        np.testing.assert_array_equal(np.asarray(d_ref).reshape(-1),
                                      np.asarray(d_k))
        assert int(k_ref.sum()) == int(k_k)
        np.testing.assert_array_equal(np.asarray(s_ref), np.asarray(s_k))


# ---------------------------------------------------------------------------
# A tiny serving universe (no training - random scores/params)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_stack():
    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import RewardModelConfig, reward_model_init

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    return chains, server, params, rcfg


def _windows(u, n_windows=5, n=64, seed=1):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=(n, 12)).astype(np.float32),
             rng.integers(0, u, n)) for _ in range(n_windows)]


# ---------------------------------------------------------------------------
# Priced-tenant pipeline
# ---------------------------------------------------------------------------


def test_priced_single_tenant_degenerates_to_plain(tiny_stack):
    """T=1 priced tenants is the K=1 case of the fused pass: decisions,
    spends and the (1,) price trace must equal the plain pipeline's
    bitwise."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    b = 64
    budget = 0.5 * float(chains.costs.max()) * b
    plain = ServingPipeline(server, params, rcfg, budget)
    priced = ServingPipeline(server, params, rcfg, budget,
                             tenant_budgets=[budget],
                             tenant_mode="priced")
    for ctx, rows in _windows(40):
        r_p = plain.serve_window(ctx, rows)
        r_t = priced.serve_window(ctx, rows)
        np.testing.assert_array_equal(r_p.decisions_np, r_t.decisions_np)
        np.testing.assert_array_equal(r_p.revenue_np, r_t.revenue_np)
        assert int(r_p.downgraded) == int(r_t.downgraded)
        assert float(r_p.spend) == float(r_t.spend)
        assert float(r_p.lam_after) == float(np.asarray(r_t.lam_after)[0])


def test_priced_tenants_track_their_own_budgets(tiny_stack):
    """Distinct per-tenant budgets under 'priced' produce distinct
    prices (tight tenant -> higher price) and per-tenant caps hold."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    t_n, per = 4, 32
    b = t_n * per
    c_max = float(chains.costs.max())
    tb = np.array([0.2, 0.4, 0.6, 5.0]) * c_max * per
    pipe = ServingPipeline(server, params, rcfg, float(tb.sum()),
                           tenant_budgets=tb, tenant_mode="priced")
    for ctx, rows in _windows(40, n_windows=8, n=b, seed=3):
        res = pipe.serve_window(ctx, rows)
    floor = per * float(chains.costs.min())
    assert res.tenant_spend is not None
    for t in range(t_n):
        assert float(res.tenant_spend[t]) <= max(tb[t], floor) * (1 + 1e-5)
    lam = np.asarray(pipe.lam)
    assert lam.shape == (t_n,)
    # the slack tenant's constraint never binds -> zero price; tighter
    # budgets command weakly higher prices
    assert lam[3] == 0.0
    assert lam[0] >= lam[2] and lam[0] > 0.0


def test_priced_tenants_with_budget_trace(tiny_stack):
    """Per-window (T,) budget overrides stay traced (no recompile) and
    are enforced per tenant."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    t_n, per = 2, 32
    c_max = float(chains.costs.max())
    tb = np.full(t_n, 0.5 * c_max * per, np.float32)
    pipe = ServingPipeline(server, params, rcfg, float(tb.sum()),
                           tenant_budgets=tb, tenant_mode="priced")
    wins = _windows(40, n_windows=4, n=t_n * per, seed=4)
    floor = per * float(chains.costs.min())
    for t, (ctx, rows) in enumerate(wins):
        scale = 0.5 + 0.25 * t
        res = pipe.serve_window(ctx, rows, budget=tb * scale)
        for k in range(t_n):
            cap = max(tb[k] * scale, floor)
            assert float(res.tenant_spend[k]) <= cap * (1 + 1e-5)
    assert len(pipe._fns) == 1  # one compiled bucket, budgets traced


# ---------------------------------------------------------------------------
# Geo router
# ---------------------------------------------------------------------------


def test_geo_identical_regions_reduce_to_pinned(tiny_stack):
    """Two regions with EQUAL scales and budgets: ties break to region
    0, and decisions/revenue/dual must equal the plain pipeline run at
    that region's budget - flops pricing (scale 1) and carbon pricing
    (scale kappa*CI) alike.  Entry prices are pinned per window so the
    comparison is decision-level."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = tiny_stack
    b = 64
    budget = 0.45 * float(chains.costs.max()) * b
    for scale in (1.0, 3.2e-7):
        plain = ServingPipeline(server, params, rcfg, budget)
        geo = ServingPipeline(server, params, rcfg, budget, n_regions=2)
        lam = 0.0
        for ctx, rows in _windows(40, seed=6):
            r_p = plain.serve_window(ctx, rows, lam=lam,
                                     budget=budget * scale,
                                     cost_scale=scale)
            r_g = geo.serve_window(
                ctx, rows, lam=lam,
                budget=np.array([budget * scale, budget * scale]),
                cost_scale=np.array([scale, scale]))
            np.testing.assert_array_equal(r_p.decisions_np,
                                          r_g.decisions_np)
            np.testing.assert_array_equal(r_p.revenue_np, r_g.revenue_np)
            assert np.all(r_g.regions_np == 0)  # ties -> first region
            assert int(r_p.downgraded) == int(r_g.downgraded)
            assert float(r_p.spend) == float(np.asarray(
                r_g.region_spend)[0])
            assert float(np.asarray(r_g.region_spend)[1]) == 0.0
            lam = float(r_p.lam_after)  # pin both to the scalar trace


def test_geo_router_shifts_toward_greener_region(tiny_stack):
    """With one dirty and one green region, the router sends the load
    majority green and respects per-region gram caps.  The proportional
    cost structure makes the dual equilibrium degenerate (every request
    flips region at once under a pure argmax), so the router runs with
    the exact flow split (``RegionAxis(split="flow")`` - the
    proportional rounding of the degenerate window) and a
    faster-decaying dual step so the published prices settle."""
    from repro.core.primal_dual import DualDescentConfig
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import ConstraintSpec, GlobalAxis, RegionAxis

    chains, server, params, rcfg = tiny_stack
    b = 64
    kappa = 3.2e-7
    flops_budget = 0.45 * float(chains.costs.max()) * b
    geo = ServingPipeline.from_spec(
        server, params, rcfg,
        ConstraintSpec([RegionAxis(2, split="flow"),
                        GlobalAxis(budget=float(flops_budget))]),
        dual_cfg=DualDescentConfig(max_iters=300, step_decay=0.98))
    ci = np.array([600.0, 200.0])  # region 1 is 3x greener
    scales = kappa * ci
    budgets = np.full(2, 0.5 * flops_budget * kappa * float(ci.mean()))
    for ctx, rows in _windows(40, n_windows=6, seed=7):
        res = geo.serve_window(ctx, rows, budget=budgets,
                               cost_scale=scales)
    regions = res.regions_np
    assert (regions == 1).mean() > 0.5  # most load lands green
    floor_g = np.minimum.reduce([len(regions) * float(chains.costs.min())
                                 * s for s in scales])
    for r in range(2):
        assert float(res.region_spend[r]) <= max(budgets[r], floor_g) \
            * (1 + 1e-5)
    # per-region spends add up to the window's total spend
    np.testing.assert_allclose(float(res.spend),
                               float(np.sum(np.asarray(res.region_spend))),
                               rtol=1e-6)


# ---------------------------------------------------------------------------
# CI-forecast warm-start
# ---------------------------------------------------------------------------


def test_forecast_warm_start_noop_on_constant_trace(tiny_stack):
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    chains, server, params, rcfg = tiny_stack
    b = 64
    budget = 0.5 * float(chains.costs.max()) * b
    wins = _windows(40, n_windows=5, n=b, seed=8)

    def sample(t, n):
        return wins[t]

    sizes = [b] * len(wins)
    traces = dict(budget_trace=np.full(len(wins), budget),
                  scale_trace=np.ones(len(wins)))
    p0 = ServingPipeline(server, params, rcfg, budget)
    s0 = run_stream(p0, sizes, sample, **traces)
    p1 = ServingPipeline(server, params, rcfg, budget)
    s1 = run_stream(p1, sizes, sample, forecast=True, **traces)
    for r0, r1 in zip(s0.windows, s1.windows):
        np.testing.assert_array_equal(r0.decisions_np, r1.decisions_np)
        assert float(r0.lam_after) == float(r1.lam_after)  # bit-exact


def test_forecast_warm_start_tracks_ci_step(tiny_stack):
    """Stepped CI (cheap half-day -> 3x dirtier half-day), constant gram
    budget: the forecast-aimed dual prices the step's windows closer to
    their budget than the lagging update."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    from repro.core.primal_dual import DualDescentConfig

    chains, server, params, rcfg = tiny_stack
    b = 64
    kappa = 3.2e-7
    n_w = 8
    flops_budget = 0.45 * float(chains.costs.max()) * b
    ci = np.array([200.0] * (n_w // 2) + [600.0] * (n_w // 2))
    # a gram budget binding on BOTH CI levels, so the price carries real
    # information the step can lag
    grams = np.full(n_w, flops_budget * kappa * 150.0)
    scales = kappa * ci
    wins = _windows(40, n_windows=n_w, n=b, seed=9)

    def sample(t, n):
        return wins[t]

    def gap(stream):
        # guard-off spend-vs-budget tracking error across the day
        return sum(abs(float(r.spend) / r.budget - 1.0)
                   for r in stream.windows[1:])

    cfg = DualDescentConfig(max_iters=400, step_size=6.0,
                            step_decay=0.995)
    runs = {}
    for forecast in (False, True):
        pipe = ServingPipeline(server, params, rcfg, flops_budget,
                               guard=False, dual_cfg=cfg)
        runs[forecast] = run_stream(
            pipe, [b] * n_w, sample, budget_trace=grams,
            scale_trace=scales, forecast=forecast)
    # the forecast run starts the price ramp one window earlier: the
    # published lambda at the step boundary is already nonzero and the
    # step window tracks its budget strictly better
    assert gap(runs[True]) < gap(runs[False])
    lam_t = [float(r.lam_after) for r in runs[True].windows]
    lam_f = [float(r.lam_after) for r in runs[False].windows]
    boundary = n_w // 2 - 1
    assert lam_t[boundary] > lam_f[boundary]


# ---------------------------------------------------------------------------
# Request-axis sharding: subprocess with 8 fake host devices
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_priced_tenants_sharded_matches_unsharded():
    """--tenant-mode priced under an 8-way request mesh: decisions equal
    and the per-tenant lambda traces match the single-process run (the
    ISSUE acceptance gate)."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import RewardModelConfig, reward_model_init
    from repro.launch.mesh import make_request_mesh
    from repro.serving.pipeline import ServingPipeline

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    t_n, per = 4, 32
    c_max = float(chains.costs.max())
    tb = (np.array([0.25, 0.4, 0.55, 0.7]) * c_max * per).astype(
        np.float32)
    mesh = make_request_mesh(8)
    pipe_s = ServingPipeline(server, params, rcfg, float(tb.sum()),
                             tenant_budgets=tb, tenant_mode="priced",
                             mesh=mesh)
    pipe_u = ServingPipeline(server, params, rcfg, float(tb.sum()),
                             tenant_budgets=tb, tenant_mode="priced")
    rng2 = np.random.default_rng(1)
    # free-run the single-process reference first, keeping each
    # window's ENTRY price; the sharded run then serves every window at
    # the same pinned entry price, so decisions must match exactly
    # while the published (psum-stitched) prices match to float
    # tolerance - collective reduction order is the only freedom.
    wins = []
    for t in range(4):
        n = t_n * per
        rows = rng2.integers(0, u, n)
        ctx = rng2.normal(size=(n, 12)).astype(np.float32)
        lam_in = np.asarray(pipe_u.lam)
        wins.append((ctx, rows, lam_in, pipe_u.serve_window(ctx, rows)))
    for t, (ctx, rows, lam_in, ru) in enumerate(wins):
        rs = pipe_s.serve_window(ctx, rows, lam=jnp.asarray(lam_in))
        assert np.array_equal(rs.decisions_np, ru.decisions_np), t
        assert np.array_equal(rs.revenue_np, ru.revenue_np), t
        assert int(rs.downgraded) == int(ru.downgraded), t
        np.testing.assert_allclose(np.asarray(rs.tenant_spend),
                                   np.asarray(ru.tenant_spend),
                                   rtol=1e-5)
        lam_u = np.asarray(ru.lam_after)
        # lambda is reward-per-FLOP (~1e-8 here); tolerate collective
        # reduction order relative to the trace's own scale
        np.testing.assert_allclose(np.asarray(rs.lam_after), lam_u,
                                   rtol=1e-4,
                                   atol=5e-3 * float(np.max(lam_u)))
    assert np.asarray(pipe_u.lam).shape == (t_n,)
    print("PRICED TENANT SHARDED PARITY OK")
    """)], capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    assert "PRICED TENANT SHARDED PARITY OK" in out.stdout
