"""Multi-device semantics, validated in a SUBPROCESS with 8 fake host
devices (the pytest process itself must keep 1 device, per the brief)."""
import os
import subprocess
import sys
import textwrap

import pytest

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


def _run(script: str, timeout=420) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    # force the host platform: the device-count flag shards the CPU
    # backend, and probing for an accelerator backend can hang for
    # minutes on machines with a TPU runtime but no TPU (metadata retry)
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(script)],
                         capture_output=True, text=True, timeout=timeout,
                         env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    return out.stdout


@pytest.mark.slow
def test_distributed_semantics_bundle():
    """One subprocess runs all mesh checks (amortizes jax startup):
    sharded embedding parity, EP-MoE parity vs the dense reference,
    int8 ring all-reduce, elastic checkpoint remesh, LM forward under
    (data, model) mesh."""
    out = _run("""
    import jax, jax.numpy as jnp, numpy as np, tempfile
    from jax.sharding import PartitionSpec as P
    from repro.distributed.compat import make_mesh, mesh_context

    mesh = make_mesh((2, 4), ("data", "model"))

    # 1) sharded embedding lookup == plain take
    from repro.models.embedding import sharded_embedding_apply
    table = jax.random.normal(jax.random.PRNGKey(0), (40, 8))
    ids = jax.random.randint(jax.random.PRNGKey(1), (16,), 0, 40)
    with mesh_context(mesh):
        got = jax.jit(lambda t, i: sharded_embedding_apply(
            t, i, mesh, axis="model", batch_axes=("data",)))(table, ids)
    assert np.allclose(np.asarray(got), np.asarray(table)[np.asarray(ids)],
                       atol=1e-6), "sharded embedding mismatch"
    print("embedding OK")

    # 2) EP MoE == dense reference (capacity high enough for no drops)
    from repro.models import lm
    cfg = lm.LMConfig(name="m", n_layers=2, d_model=32, n_heads=4,
                      n_kv_heads=4, d_head=8, d_ff=64, vocab=64,
                      padded_vocab=64, dtype="float32", remat=False,
                      fsdp=False,
                      moe=lm.MoEConfig(n_experts=8, top_k=2, d_expert=32,
                                       capacity_factor=8.0))
    p = lm.init(jax.random.PRNGKey(2), cfg)
    toks = jax.random.randint(jax.random.PRNGKey(3), (4, 8), 0, 64)
    ref, _ = lm.forward(p, cfg, toks)
    with mesh_context(mesh):
        got, _ = jax.jit(lambda pp, t: lm.forward(pp, cfg, t))(p, toks)
    err = float(jnp.abs(ref - got).max())
    assert err < 1e-4, f"EP MoE err {err}"
    print("moe OK")

    # 3) dense LM under mesh matches single-device
    dcfg = lm.LMConfig(name="d", n_layers=2, d_model=32, n_heads=4,
                       n_kv_heads=2, d_head=8, d_ff=64, vocab=64,
                       padded_vocab=64, dtype="float32", remat=False,
                       fsdp=True, sequence_parallel=True)
    dp = lm.init(jax.random.PRNGKey(4), dcfg)
    ref, _ = lm.forward(dp, dcfg, toks)
    with mesh_context(mesh):
        got, _ = jax.jit(lambda pp, t: lm.forward(pp, dcfg, t))(dp, toks)
    err = float(jnp.abs(ref - got).max())
    assert err < 1e-4, f"dense LM err {err}"
    print("lm OK")

    # 4) int8 ring all-reduce ~= psum
    from repro.distributed.compression import ring_allreduce_int8
    x = jax.random.normal(jax.random.PRNGKey(5), (2, 500))
    xs = jax.device_put(x, jax.sharding.NamedSharding(mesh, P("data", None)))
    out = jax.jit(lambda v: ring_allreduce_int8(
        v.reshape(-1), mesh, axis="data"))(xs)
    ref = jnp.tile(x.sum(0), 2)
    rel = float(jnp.abs(out - ref).max() / jnp.abs(ref).max())
    assert rel < 0.02, f"ring allreduce rel err {rel}"
    print("ring OK")

    # 5) elastic remesh restore
    from repro.training import checkpoint as ck
    from repro.training.elastic import ElasticController
    ec = ElasticController()
    st = {"w": jax.device_put(
        np.arange(64, dtype=np.float32).reshape(8, 8),
        jax.sharding.NamedSharding(mesh, P("data", "model")))}
    specs = {"w": P("data", "model")}
    with tempfile.TemporaryDirectory() as td:
        ck.save(td, 3, st)
        st2, m2, man = ec.remesh_restore(td, st, specs, (2, 4), (4, 2))
        assert np.allclose(np.asarray(jax.device_get(st2["w"])),
                           np.arange(64).reshape(8, 8))
        assert man["step"] == 3
    print("elastic OK")
    print("ALL DISTRIBUTED CHECKS PASSED")
    """)
    assert "ALL DISTRIBUTED CHECKS PASSED" in out


@pytest.mark.slow
def test_mini_dryrun_smoke_arch():
    """A reduced dry-run (small mesh, small cells) proves the launcher
    machinery end-to-end inside CI; the full 512-device run is the
    background deliverable."""
    out = _run("""
    import jax, numpy as np
    from repro.configs import get_arch
    from repro.launch.dryrun import _measure
    from repro.launch.mesh import tree_named_shardings
    from repro.distributed.compat import make_mesh

    mesh = make_mesh((2, 4), ("data", "model"))
    cell = get_arch("greenflow-cascade").make_cell("reward_serve")
    rec = _measure(cell, mesh)
    assert rec["cost_analysis"]["flops"] > 0
    print("mini dryrun OK", rec["cost_analysis"]["flops"])
    """)
    assert "mini dryrun OK" in out
