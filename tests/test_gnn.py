"""SchNet + sampler invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.gnn import schnet
from repro.models.gnn.sampler import (CSRGraph, budget_for, sample_subgraph)

KEY = jax.random.PRNGKey(0)
CFG = schnet.SchNetConfig(n_interactions=2, d_hidden=16, n_rbf=20)


def _batch(rng, n=24, e=48, g=3):
    return dict(
        nodes=jnp.asarray(rng.integers(0, 10, n), jnp.int32),
        src=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dst=jnp.asarray(rng.integers(0, n, e), jnp.int32),
        dist=jnp.asarray(rng.uniform(0.5, 9.0, e), jnp.float32),
        edge_mask=jnp.ones(e, jnp.float32),
        graph_ids=jnp.asarray(np.repeat(np.arange(g), n // g), jnp.int32),
        n_graphs=g,
        target=jnp.asarray(rng.normal(size=g), jnp.float32))


def test_forward_shapes(rng):
    p = schnet.init(KEY, CFG)
    out = schnet.forward(p, CFG, _batch(rng))
    assert out.shape == (3, 1) and bool(jnp.isfinite(out).all())


def test_edge_mask_zeroes_messages(rng):
    """Masked (padding) edges must not affect the output."""
    p = schnet.init(KEY, CFG)
    b = _batch(rng)
    e = b["src"].shape[0]
    mask = jnp.concatenate([jnp.ones(e // 2), jnp.zeros(e - e // 2)])
    b1 = dict(b, edge_mask=mask)
    garbage = jnp.asarray(rng.integers(0, 24, e), jnp.int32)
    b2 = dict(b1, src=jnp.where(mask > 0, b1["src"], garbage))
    np.testing.assert_allclose(np.asarray(schnet.forward(p, CFG, b1)),
                               np.asarray(schnet.forward(p, CFG, b2)),
                               rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 100))
def test_node_permutation_equivariance(seed):
    """Relabeling nodes permutes node outputs / preserves graph readout."""
    rng = np.random.default_rng(seed)
    n, e = 12, 30
    cfg = schnet.SchNetConfig(n_interactions=2, d_hidden=8, n_rbf=10,
                              n_out=3, task="node_class")
    p = schnet.init(KEY, cfg)
    nodes = rng.integers(0, 10, n)
    src, dst = rng.integers(0, n, e), rng.integers(0, n, e)
    dist = rng.uniform(0.5, 9.0, e)
    perm = rng.permutation(n)
    inv = np.argsort(perm)
    base = dict(nodes=jnp.asarray(nodes, jnp.int32),
                src=jnp.asarray(src, jnp.int32),
                dst=jnp.asarray(dst, jnp.int32),
                dist=jnp.asarray(dist, jnp.float32),
                edge_mask=jnp.ones(e))
    out1 = schnet.forward(p, cfg, base)
    permuted = dict(base, nodes=jnp.asarray(nodes[perm], jnp.int32),
                    src=jnp.asarray(inv[src], jnp.int32),
                    dst=jnp.asarray(inv[dst], jnp.int32))
    out2 = schnet.forward(p, cfg, permuted)
    # new position of old node j is inv[j]  =>  out1[j] == out2[inv[j]]
    np.testing.assert_allclose(np.asarray(out1), np.asarray(out2)[inv],
                               rtol=1e-4, atol=1e-4)


def test_cutoff_kills_long_edges(rng):
    p = schnet.init(KEY, CFG)
    b = _batch(rng)
    far = dict(b, dist=jnp.full_like(b["dist"], CFG.cutoff + 1.0))
    none = dict(b, edge_mask=jnp.zeros_like(b["edge_mask"]))
    np.testing.assert_allclose(np.asarray(schnet.forward(p, CFG, far)),
                               np.asarray(schnet.forward(p, CFG, none)),
                               rtol=1e-5, atol=1e-5)


def test_rbf_partition(rng):
    d = jnp.asarray(rng.uniform(0, 10, 50), jnp.float32)
    rbf = schnet.rbf_expand(d, CFG)
    assert rbf.shape == (50, CFG.n_rbf)
    assert float(rbf.max()) <= 1.0 + 1e-6


# -- sampler -----------------------------------------------------------------


def test_sampler_respects_budget_and_locality(rng):
    src = rng.integers(0, 500, 4000)
    dst = rng.integers(0, 500, 4000)
    g = CSRGraph.from_edges(src, dst, 500)
    mn, me = budget_for(16, (5, 3))
    sub = sample_subgraph(g, np.arange(16), (5, 3), rng,
                          max_nodes=mn, max_edges=me)
    n_real = int(sub.node_mask.sum())
    e_real = int(sub.edge_mask.sum())
    assert n_real <= mn and e_real <= me
    # all edge endpoints are valid local indices
    assert (sub.src[:e_real] < n_real).all()
    assert (sub.dst[:e_real] < n_real).all()
    # every sampled edge exists in the original graph
    nodes = sub.nodes
    for s_l, d_l in zip(sub.src[:10], sub.dst[:10]):
        u, v = int(nodes[s_l]), int(nodes[d_l])
        assert u in g.neighbors(v) or v in g.neighbors(u)


def test_csr_roundtrip(rng):
    src = np.asarray([0, 0, 1, 2, 2, 2])
    dst = np.asarray([1, 2, 0, 0, 1, 1])
    g = CSRGraph.from_edges(src, dst, 3)
    assert sorted(g.neighbors(0).tolist()) == [1, 2]
    assert sorted(g.neighbors(2).tolist()) == [0, 1, 1]
    assert g.n_edges == 6
