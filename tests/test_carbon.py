"""Carbon subsystem: traces, ledger math, and the budget-equivalence
(parity) acceptance gate.

Covers the ISSUE acceptance criteria:
  * constant-CI trace => the carbon-denominated controller reproduces
    today's FLOPs-budget decisions BIT-IDENTICALLY (both the fused
    ServingPipeline path and the CarbonBudgetController host loop);
  * diurnal trace => per-window gCO2e spend respects the gram cap;
  * ledger metering equals the Eq. 1-2 arithmetic, with per-stage and
    per-model attribution summing to the total.

Parity tests use INTEGER-VALUED CI and hour-aligned windows so the
trace's window means and the ratio-form effective budget are float-exact
(the designed invariant: x/x == 1.0).
"""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.carbon.controller import (CarbonBudget, CarbonBudgetController,
                                     carbon_costs, grams_per_flop)
from repro.carbon.intensity import (HOUR_S, IntensityTrace, constant_trace,
                                    diurnal_trace, load_ci_csv,
                                    solar_duck_trace, two_region_traces)
from repro.carbon.ledger import DAY_S, CarbonLedger
from repro.core.action_chain import (ModelInstance, StageSpec,
                                     generate_action_chains)
from repro.core.budget import BudgetController
from repro.core.pfec import EnergyConfig, energy_from_flops, kwh_per_flop


# ---------------------------------------------------------------------------
# Intensity traces
# ---------------------------------------------------------------------------


def test_trace_generators_shapes_and_shape_properties():
    d = diurnal_trace(mean=450.0, rel_amplitude=0.4)
    assert len(d) == 24 and d.period_s == HOUR_S
    assert np.all(d.values > 0)
    assert int(np.argmax(d.values)) == 19  # evening peak
    np.testing.assert_allclose(d.mean(), 450.0, rtol=1e-12)

    duck = solar_duck_trace(mean=450.0)
    base = diurnal_trace(mean=450.0, rel_amplitude=0.35)
    assert duck.values[13] < base.values[13]  # midday solar depression
    assert np.all(duck.values >= 0.1 * 450.0 - 1e-9)

    regions = two_region_traces(offset_h=8.0)
    a, b = regions["region_a"], regions["region_b"]
    assert int(np.argmax(a.values)) == 19
    assert int(np.argmax(b.values)) == (19 + 8) % 24

    c = constant_trace(615.0, n=24)
    assert np.all(c.values == 615.0)


def test_trace_validation():
    with pytest.raises(ValueError):
        IntensityTrace(np.array([1.0, -2.0]), 3600.0)
    with pytest.raises(ValueError):
        IntensityTrace(np.array([1.0, 2.0]), 0.0)
    with pytest.raises(ValueError):
        diurnal_trace(rel_amplitude=1.5)
    # day-curve generators must span exactly 24 h (else the cyclic trace
    # would wrap mid-curve with a silent discontinuity)
    with pytest.raises(ValueError, match="span one day"):
        diurnal_trace(n=24, period_s=1800.0)
    with pytest.raises(ValueError, match="span one day"):
        solar_duck_trace(n=12)
    assert len(diurnal_trace(n=48, period_s=1800.0)) == 48


def test_trace_resample_and_wraparound():
    v = np.arange(1.0, 25.0)  # 1..24, hourly
    tr = IntensityTrace(v, HOUR_S)
    # aligned hourly resample reproduces the samples
    np.testing.assert_array_equal(tr.resample(24, HOUR_S), v)
    # cyclic wrap: window 24 sees hour 0 again
    np.testing.assert_array_equal(tr.resample(26, HOUR_S)[24:], v[:2])
    # 2-hour windows take the mean of their two hours
    np.testing.assert_allclose(tr.resample(12, 2 * HOUR_S),
                               v.reshape(12, 2).mean(axis=1))
    # phase shift slides the trace under the windows
    np.testing.assert_array_equal(
        tr.resample(4, HOUR_S, phase_s=3 * HOUR_S), v[3:7])
    # at() is piecewise-constant and cyclic
    assert tr.at(0.0) == 1.0 and tr.at(3600.0 * 25.5) == 2.0


def test_load_ci_csv_uk_layout(tmp_path):
    p = tmp_path / "uk.csv"
    p.write_text(
        "date,start,end,forecast,actual,index\n"
        "2024-03-01,00:00,00:30,210,200,moderate\n"
        "2024-03-01,00:30,01:00,205,190,moderate\n"
        "2024-03-01,01:00,01:30,195,,low\n"  # blank -> forward-fill
        "2024-03-01,01:30,02:00,180,170,low\n")
    tr = load_ci_csv(str(p))
    assert tr.period_s == 1800.0
    np.testing.assert_array_equal(tr.values, [200.0, 190.0, 190.0, 170.0])

    p2 = tmp_path / "simple.csv"
    p2.write_text("date,start,actual\n"
                  "2024-03-01,00:00,300\n"
                  "2024-03-01,01:00,350\n"
                  "2024-03-02,00:00,400\n")  # day boundary, gaps filled
    tr2 = load_ci_csv(str(p2))
    assert tr2.period_s == 3600.0 and len(tr2) == 25
    assert tr2.values[0] == 300.0 and tr2.values[1] == 350.0
    assert np.all(tr2.values[2:24] == 350.0) and tr2.values[24] == 400.0

    bad = tmp_path / "bad.csv"
    bad.write_text("date,start,actual\n2024-03-01,00:00,100\n"
                   "2024-03-01,00:07,110\n2024-03-01,00:10,120\n")
    with pytest.raises(ValueError, match="non-uniform"):
        load_ci_csv(str(bad))


# ---------------------------------------------------------------------------
# Carbon budgets & cost vectors
# ---------------------------------------------------------------------------


def test_carbon_cost_and_budget_arithmetic():
    cfg = EnergyConfig()
    assert grams_per_flop(500.0, cfg) == kwh_per_flop(cfg) * 500.0
    costs = np.array([1e6, 2e6, 4e6])
    np.testing.assert_allclose(carbon_costs(costs, 500.0, cfg),
                               costs * kwh_per_flop(cfg) * 500.0)

    tr = constant_trace(600.0, n=24)
    cb = CarbonBudget.from_flops(1e9, tr, cfg=cfg)
    np.testing.assert_allclose(cb.grams_per_window,
                               1e9 * kwh_per_flop(cfg) * 600.0, rtol=1e-12)
    # the designed ratio-form invariant: constant CI => the effective
    # FLOPs budget is TODAY'S budget, bit-exactly, every window
    for t in range(30):
        assert cb.flops_budget(t) == 1e9
    # grams round-trip
    cb2 = CarbonBudget.from_grams(cb.grams_per_window, tr, cfg=cfg)
    np.testing.assert_allclose(cb2.flops_ref, 1e9, rtol=1e-12)

    sched = cb.schedule(6)
    np.testing.assert_array_equal(sched["flops_budget"], np.full(6, 1e9))
    np.testing.assert_allclose(sched["scale"],
                               np.full(6, grams_per_flop(600.0, cfg)))
    # diurnal: greener window => larger effective FLOPs budget
    cbd = CarbonBudget.from_flops(1e9, diurnal_trace(mean=450.0), cfg=cfg)
    green = int(np.argmin([cbd.ci(t) for t in range(24)]))
    dirty = int(np.argmax([cbd.ci(t) for t in range(24)]))
    assert cbd.flops_budget(green) > 1e9 > cbd.flops_budget(dirty)


# ---------------------------------------------------------------------------
# Ledger metering (Eq. 1-2 per window + attribution)
# ---------------------------------------------------------------------------


def _tiny_chains():
    return generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (150,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), (30, 60), 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), (8, 16), 4),
    ))


def test_ledger_meters_eq1_eq2_with_attribution(tmp_path):
    chains = _tiny_chains()
    cfg = EnergyConfig()
    tr = IntensityTrace(np.array([300.0, 600.0]), HOUR_S)
    led = CarbonLedger(chains, tr, cfg=cfg, window_s=HOUR_S)
    rng = np.random.default_rng(0)
    decs = [rng.integers(0, chains.n_chains, 40) for _ in range(2)]
    for d in decs:
        led.record(d)
    for t, (e, d) in enumerate(zip(led.entries, decs)):
        flops = float(chains.costs[d].sum())
        np.testing.assert_allclose(e.flops, flops, rtol=1e-12)
        np.testing.assert_allclose(e.kwh, energy_from_flops(flops, cfg),
                                   rtol=1e-12)
        assert e.ci_g_per_kwh == tr.values[t]
        np.testing.assert_allclose(e.gco2e, e.kwh * tr.values[t],
                                   rtol=1e-12)
        # attribution closes: stages and models each sum to the total
        np.testing.assert_allclose(sum(e.stage_flops.values()), flops,
                                   rtol=1e-9)
        np.testing.assert_allclose(sum(e.model_flops.values()), flops,
                                   rtol=1e-9)
        np.testing.assert_allclose(
            e.baseline_flops, 40 * chains.costs.max(), rtol=1e-12)
        assert e.baseline_gco2e > e.gco2e
    rep = led.report()
    assert rep["n_windows"] == 2 and rep["n_requests"] == 80
    # 2 recorded 1 h windows extrapolate x12 to the day
    np.testing.assert_allclose(rep["daily_saved_kwh"],
                               (rep["baseline_kwh"] - rep["kwh"]) * 12,
                               rtol=1e-12)
    np.testing.assert_allclose(rep["daily_saved_tco2e"],
                               rep["daily_saved_gco2e"] / 1e6, rtol=1e-12)
    path = str(tmp_path / "carbon_report.csv")
    led.to_csv(path)
    lines = open(path).read().strip().splitlines()
    header = lines[0].split(",")
    assert lines[0].startswith("window,ci_g_per_kwh,n_requests,flops,kwh")
    assert len(lines) == 4 and lines[-1].startswith("TOTAL")
    assert all(len(ln.split(",")) == len(header) for ln in lines[1:])
    assert "stage_rank_flops" in header and "model_DIEN_flops" in header


def test_ledger_mixed_recording_stays_ordered():
    """Parked WindowResults drain before a direct record() infers its
    window index, so mixing the two paths keeps windows ordered and each
    metered at its own CI."""
    chains = _tiny_chains()
    tr = IntensityTrace(np.array([100.0, 200.0, 300.0]), HOUR_S)
    led = CarbonLedger(chains, tr, window_s=HOUR_S)

    class FakeResult:  # duck-typed WindowResult
        def __init__(self, d):
            self.decisions_np = d

    led.record_result(FakeResult(np.zeros(5, np.int64)))
    led.record(np.zeros(3, np.int64))  # must land AFTER the parked window
    assert [e.window for e in led.entries] == [0, 1]
    assert [e.n_requests for e in led.entries] == [5, 3]
    assert [e.ci_g_per_kwh for e in led.entries] == [100.0, 200.0]


def test_budget_controller_ledger_hook():
    chains = _tiny_chains()
    tr = constant_trace(615.0)
    led = CarbonLedger(chains, tr)
    ctl = BudgetController(chains, float(np.median(chains.costs)) * 50,
                           ledger=led)
    rng = np.random.default_rng(1)
    for _ in range(3):
        ctl.step_window(rng.random((50, chains.n_chains)).astype(np.float32))
    assert len(led.entries) == 3
    for e, s in zip(led.entries, ctl.stats):
        np.testing.assert_allclose(e.flops, s.spend, rtol=1e-6)


# ---------------------------------------------------------------------------
# A tiny serving universe (no training - random scores/params)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def carbon_stack():
    from repro.cascade.engine import CascadeServer
    from repro.core.reward_model import RewardModelConfig, reward_model_init

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    return chains, server, params, rcfg


def _windows(u, n_windows=6, n=64, seed=1):
    rng = np.random.default_rng(seed)
    return [(rng.normal(size=(n, 12)).astype(np.float32),
             rng.integers(0, u, n)) for _ in range(n_windows)]


# ---------------------------------------------------------------------------
# THE parity gate: constant CI == today's FLOPs pipeline, bit-identical
# ---------------------------------------------------------------------------


def test_constant_ci_pipeline_parity_bit_identical(carbon_stack):
    """Acceptance: a constant-CI carbon budget reproduces the plain
    FLOPs-budget pipeline decision-for-decision (and, for the ratio-form
    flops pricing, price-for-price bitwise)."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = carbon_stack
    b_f = 0.5 * float(chains.costs.max()) * 64
    tr = constant_trace(600.0, n=24)
    cb = CarbonBudget.from_flops(b_f, tr, window_s=HOUR_S)
    wins = _windows(40)

    pipe_ref = ServingPipeline(server, params, rcfg, b_f)
    pipe_flops = ServingPipeline(server, params, rcfg, b_f)
    pipe_carbon = ServingPipeline(server, params, rcfg, b_f)
    for t, (ctx, rows) in enumerate(wins):
        r_ref = pipe_ref.serve_window(ctx, rows)
        # flops pricing: ratio-form effective budget, bitwise the same
        r_f = pipe_flops.serve_window(ctx, rows,
                                      budget=cb.flops_budget(t))
        np.testing.assert_array_equal(r_ref.decisions_np, r_f.decisions_np)
        assert float(r_ref.lam_after) == float(r_f.lam_after)
        np.testing.assert_array_equal(np.asarray(r_ref.spend),
                                      np.asarray(r_f.spend))
        # native carbon pricing: gram budget + kappa*CI costs; same LP up
        # to a positive scalar => identical decisions
        r_c = pipe_carbon.serve_window(ctx, rows,
                                       budget=cb.grams_per_window,
                                       cost_scale=cb.scale(t))
        np.testing.assert_array_equal(r_ref.decisions_np, r_c.decisions_np)
        np.testing.assert_array_equal(r_ref.revenue_np, r_c.revenue_np)
        assert int(r_ref.downgraded) == int(r_c.downgraded)
        # spend is re-denominated, FLOPs metering is not
        np.testing.assert_allclose(float(r_c.flops), float(r_ref.flops),
                                   rtol=1e-6)


def test_constant_ci_controller_parity_bit_identical(carbon_stack):
    """Same gate for the host-loop controllers: CarbonBudgetController at
    constant CI == BudgetController, decision-for-decision."""
    chains, _, _, _ = carbon_stack
    b_f = 0.5 * float(chains.costs.max()) * 48
    tr = constant_trace(615.0, n=24)
    cb = CarbonBudget.from_flops(b_f, tr, window_s=HOUR_S)
    rng = np.random.default_rng(3)
    rewards = [rng.random((48, chains.n_chains)).astype(np.float32) * 3.0
               for _ in range(5)]

    ref = BudgetController(chains, b_f)
    ctl_f = CarbonBudgetController(chains, cb, pricing="flops")
    ctl_c = CarbonBudgetController(chains, cb, pricing="carbon")
    for r in rewards:
        d_ref = ref.step_window(r)
        d_f = ctl_f.step_window(r)
        d_c = ctl_c.step_window(r)
        np.testing.assert_array_equal(d_ref, d_f)
        np.testing.assert_array_equal(d_ref, d_c)
        s_ref, s_f = ref.stats[-1], ctl_f.stats[-1]
        assert s_ref.downgraded == s_f.downgraded
        assert s_f.lam == s_ref.lam  # bitwise: same descent, same floats
        np.testing.assert_allclose(ctl_c.stats[-1].flops, s_ref.spend,
                                   rtol=1e-12)


def test_diurnal_carbon_run_respects_gram_cap(carbon_stack):
    """Carbon pricing on a diurnal grid: every window's gCO2e spend stays
    under max(gram budget, floor) and dirty hours downgrade chains."""
    from repro.serving.pipeline import ServingPipeline

    chains, server, params, rcfg = carbon_stack
    n = 64
    tr = diurnal_trace(mean=450.0, rel_amplitude=0.45)
    # tight: 30% of the all-max spend at mean CI
    cb = CarbonBudget.from_flops(0.3 * float(chains.costs.max()) * n, tr,
                                 window_s=HOUR_S)
    led = CarbonLedger(chains, tr, cfg=cb.cfg, window_s=HOUR_S)
    pipe = ServingPipeline(server, params, rcfg, cb.flops_ref, ledger=led)
    c_min = float(chains.costs.min())
    wins = _windows(40, n_windows=8, n=n, seed=5)
    for t, (ctx, rows) in enumerate(wins):
        s = cb.scale(t)
        r = pipe.serve_window(ctx, rows, budget=cb.grams_per_window,
                              cost_scale=s)
        cap = max(cb.grams_per_window, n * c_min * s)
        assert float(r.spend) <= cap * (1 + 1e-5)
        # spend is the realized FLOPs re-priced at this window's CI
        np.testing.assert_allclose(float(r.spend), float(r.flops) * s,
                                   rtol=1e-5)
    assert any(int(r.downgraded) > 0 for r in pipe.stats)
    # the ledger metered every window lazily, at the right CI
    assert len(led.entries) == len(wins)
    for t, e in enumerate(led.entries):
        assert e.ci_g_per_kwh == pytest.approx(tr.values[t % 24])


def test_carbon_scenario_windows_and_unknown_error():
    from repro.serving.stream import TrafficScenario, scenario_windows

    carbon = scenario_windows(TrafficScenario("carbon", 12, 96))
    diurnal = scenario_windows(TrafficScenario("diurnal", 12, 96))
    assert carbon == diurnal  # same day curve; carbon adds the CI pairing
    georegions = scenario_windows(TrafficScenario("georegions", 12, 96))
    assert georegions == diurnal  # the router changes WHERE, not HOW MANY
    with pytest.raises(ValueError, match="carbon"):
        scenario_windows(TrafficScenario("nope", 4, 8))


def test_ledger_embodied_amortization(tmp_path):
    """Embodied carbon accrues per device-hour regardless of load and
    rides into report + CSV totals (the under-reporting fix)."""
    from repro.carbon.ledger import (DEFAULT_EMBODIED_G_PER_DEVICE_H,
                                     geo_report_csv)

    chains = _tiny_chains()
    tr = constant_trace(500.0)
    rate, devs = DEFAULT_EMBODIED_G_PER_DEVICE_H, 3
    led = CarbonLedger(chains, tr, window_s=2 * HOUR_S,
                       embodied_g_per_device_h=rate, n_devices=devs)
    rng = np.random.default_rng(2)
    for _ in range(4):
        led.record(rng.integers(0, chains.n_chains, 16))
    per_window = rate * devs * 2.0  # 2 h windows
    for e in led.entries:
        assert e.embodied_gco2e == pytest.approx(per_window)
        assert e.total_gco2e == pytest.approx(e.gco2e + per_window)
    rep = led.report()
    assert rep["embodied_gco2e"] == pytest.approx(4 * per_window)
    assert rep["total_gco2e"] == pytest.approx(
        rep["gco2e"] + rep["embodied_gco2e"])
    # a day has 12 two-hour windows -> daily embodied = 24 h of devices
    assert rep["daily_embodied_gco2e"] == pytest.approx(rate * devs * 24)
    path = str(tmp_path / "report.csv")
    led.to_csv(path)
    lines = open(path).read().strip().splitlines()
    header = lines[0].split(",")
    assert header[-2:] == ["embodied_gco2e", "total_gco2e"]
    assert all(len(ln.split(",")) == len(header) for ln in lines[1:])

    # per-region merge keeps each ledger's windows under a region column
    led_b = CarbonLedger(chains, tr, window_s=2 * HOUR_S)
    led_b.record(np.zeros(4, np.int64))
    gpath = str(tmp_path / "geo.csv")
    geo_report_csv({"region_a": led, "region_b": led_b}, gpath)
    glines = open(gpath).read().strip().splitlines()
    assert glines[0].split(",")[0] == "region"
    assert sum(ln.startswith("region_a,") for ln in glines) == 5  # 4+TOTAL
    assert sum(ln.startswith("region_b,") for ln in glines) == 2
