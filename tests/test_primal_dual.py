import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.primal_dual import (DynamicPrimalDual, allocate, consumption,
                                    dual_bisect, dual_descent,
                                    realized_reward)


def _random_problem(seed, i=64, j=12):
    rng = np.random.default_rng(seed)
    rewards = jnp.asarray(rng.uniform(0, 5, (i, j)), jnp.float32)
    costs = jnp.asarray(rng.uniform(1.0, 10.0, (j,)), jnp.float32)
    return rewards, costs


def test_allocate_is_argmax():
    rewards, costs = _random_problem(0)
    lam = jnp.float32(0.3)
    j_star = allocate(rewards, costs, lam)
    manual = np.argmax(np.asarray(rewards) - 0.3 * np.asarray(costs)[None, :],
                       axis=1)
    np.testing.assert_array_equal(np.asarray(j_star), manual)


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.floats(0.05, 0.95))
def test_bisect_respects_budget_and_is_minimal(seed, frac):
    """Smallest lambda whose consumption fits the budget (paper Eq. 10).

    Eq. 3b assigns exactly ONE chain per request, so consumption can never
    drop below n * min_j(c_j); budgets are drawn above that floor."""
    rewards, costs = _random_problem(seed)
    max_spend = float(consumption(rewards, costs, jnp.float32(0.0)))
    floor = rewards.shape[0] * float(costs.min())
    budget = floor + frac * (max_spend - floor)
    lam = dual_bisect(rewards, costs, budget)
    assert float(consumption(rewards, costs, lam)) <= budget * (1 + 1e-5)
    if float(lam) > 1e-6:
        # a slightly smaller price must overshoot (minimality)
        lam_lo = lam * 0.98
        assert float(consumption(rewards, costs, lam_lo)) >= budget * (1 - 1e-3)


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 500))
def test_consumption_monotone_in_lambda(seed):
    rewards, costs = _random_problem(seed)
    lams = [0.0, 0.05, 0.1, 0.3, 0.8, 2.0]
    spends = [float(consumption(rewards, costs, jnp.float32(l))) for l in lams]
    assert all(a >= b - 1e-4 for a, b in zip(spends, spends[1:]))


def test_dual_descent_converges_near_bisect():
    rewards, costs = _random_problem(42, i=256)
    budget = 0.6 * float(consumption(rewards, costs, jnp.float32(0.0)))
    lam_b = dual_bisect(rewards, costs, budget)
    lam_d, gaps = dual_descent(rewards, costs, budget, 0.0, max_iters=400,
                               step_size=2.0)
    spend_d = float(consumption(rewards, costs, lam_d))
    # descent should get within a few percent of the budget (Algorithm 1)
    assert spend_d <= budget * 1.02
    r_b = float(realized_reward(rewards, allocate(rewards, costs, lam_b)))
    r_d = float(realized_reward(rewards, allocate(rewards, costs, lam_d)))
    assert r_d >= 0.95 * r_b


def test_bisect_lam_hi_bound_near_equal_costs():
    """Pins the smallest-POSITIVE-gap logic in dual_bisect's upper bound.

    Two chains with nearly equal costs need a huge price to separate:
    lambda* ~ r_span / gap.  A bound built from min/max cost (the naive
    choice) would cap bisection far below lambda* and return an
    infeasible price."""
    n = 32
    costs = jnp.asarray([1.0, 1.0 + 1e-6], jnp.float32)
    gap = float(costs[1]) - float(costs[0])  # f32-rounded gap
    rewards = jnp.tile(jnp.asarray([[0.0, 1.0]], jnp.float32), (n, 1))
    c_hi = float(consumption(rewards, costs, jnp.float32(0.0)))
    c_lo = n * float(costs[0])
    budget = 0.5 * (c_hi + c_lo)  # only the cheap chain fits
    lam = dual_bisect(rewards, costs, budget)
    assert float(consumption(rewards, costs, lam)) <= budget * (1 + 1e-6)
    # the returned price must actually be of the ~r_span/gap magnitude
    assert float(lam) > 0.5 / gap


def test_bisect_all_equal_costs_uses_fallback_bound():
    """All costs equal -> no positive gap -> lam_hi falls back to
    max(costs); consumption is constant in lambda so either the budget
    fits at 0 or the cheapest-possible spend is the best bisection can
    certify."""
    costs = jnp.asarray([2.0, 2.0, 2.0], jnp.float32)
    rewards, _ = _random_problem(9, j=3)
    n = rewards.shape[0]
    assert float(dual_bisect(rewards, costs, 2.0 * n + 1.0)) == 0.0
    lam = dual_bisect(rewards, costs, 1.0 * n)  # infeasible budget
    assert float(consumption(rewards, costs, lam)) == 2.0 * n


def test_consumption_and_descent_ignore_padded_requests():
    """mask zeroes padding: the fused pipeline's padded windows must see
    the same dual trajectory as the unpadded host loop."""
    rewards, costs = _random_problem(5, i=128)
    budget = 0.6 * float(consumption(rewards, costs, jnp.float32(0.0)))
    lam_a, _ = dual_descent(rewards, costs, budget, 0.0, max_iters=50)
    padded = jnp.concatenate(
        [rewards, 7.7 * jnp.ones((32, rewards.shape[1]), jnp.float32)], 0)
    mask = jnp.concatenate([jnp.ones(128, jnp.float32),
                            jnp.zeros(32, jnp.float32)])
    used_a = float(consumption(rewards, costs, jnp.float32(0.1)))
    used_b = float(consumption(padded, costs, jnp.float32(0.1), mask))
    np.testing.assert_allclose(used_a, used_b, rtol=1e-6)
    lam_b, _ = dual_descent(padded, costs, budget, 0.0, mask=mask,
                            max_iters=50)
    np.testing.assert_allclose(float(lam_a), float(lam_b), rtol=1e-6)


def test_unconstrained_budget_gives_zero_price():
    rewards, costs = _random_problem(7)
    huge = 1e9
    assert float(dual_bisect(rewards, costs, huge)) == 0.0


def test_streaming_tracker_warm_start():
    rewards, costs = _random_problem(3, i=512)
    budget = 0.5 * float(consumption(rewards, costs, jnp.float32(0.0)))
    pd = DynamicPrimalDual(costs, budget)
    for t in range(5):
        pd.update(rewards)
    decisions = pd.decide(rewards)
    spend = float(np.asarray(costs)[np.asarray(decisions)].sum())
    assert spend <= budget * 1.05
    assert len(pd.history) == 5
