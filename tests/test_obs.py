"""Observability (repro/obs/): free when off, invisible when on.

The flight recorder's two contract halves, each pinned here:

  * OFF is free: a disabled registry/tracer hands out shared stateless
    no-op singletons - no allocations, no locks - so the serving hot
    path pays one method call.
  * ON is invisible: enabling the full stack (registry + spans + JSONL
    window exporter) changes NOTHING numeric - decisions, revenues,
    spends and lambda traces are bitwise identical to a telemetry-off
    run, in the plain and geotenants pipelines, sequential and
    prefetched.

Plus the exporter schemas (Prometheus text, Chrome trace-event JSON,
window JSONL) and deterministic prep/stall/submit attribution through
the injected ``clock``.
"""
import json
import threading

import numpy as np
import pytest


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


def test_registry_counter_gauge_histogram():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    c = reg.counter("greenflow_windows_total", "windows")
    c.inc()
    c.inc(3)
    g = reg.gauge("greenflow_lambda")
    g.labels(axis="tenant[0]").set(1.5e-5)
    g.labels(axis="region_a").set(2.0)
    h = reg.histogram("greenflow_prep_ms", "prep", "ms",
                      edges=(1.0, 2.0, 4.0))
    for v in (0.5, 1.0, 3.0, 100.0):
        h.observe(v)

    snap = reg.snapshot()
    assert snap["greenflow_windows_total"]["series"][0]["value"] == 4
    lam = {tuple(s["labels"].items()): s["value"]
           for s in snap["greenflow_lambda"]["series"]}
    assert lam[(("axis", "tenant[0]"),)] == pytest.approx(1.5e-5)
    hs = snap["greenflow_prep_ms"]["series"][0]
    assert hs["count"] == 4
    assert hs["sum"] == pytest.approx(104.5)
    # le-inclusive cumulative buckets: 1.0 lands IN the le="1" bucket
    assert hs["buckets"] == {"1": 2, "2": 2, "4": 3, "+Inf": 4}


def test_registry_same_instrument_and_child_cached():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    a = reg.counter("x_total")
    assert reg.counter("x_total") is a
    assert a.labels(bucket=128) is a.labels(bucket=128)
    with pytest.raises(ValueError):
        reg.gauge("x_total")  # kind mismatch fails loudly


def test_prometheus_text_format():
    from repro.obs.metrics import MetricsRegistry

    reg = MetricsRegistry()
    reg.counter("greenflow_requests_total", "requests").inc(7)
    reg.gauge("greenflow_spend").labels(axis="region_a").set(0.5)
    h = reg.histogram("greenflow_stall_ms", "stall", "ms",
                      edges=(1.0, 2.0))
    h.observe(1.5)
    text = reg.prometheus_text()
    lines = text.splitlines()
    assert "# TYPE greenflow_requests_total counter" in lines
    assert "greenflow_requests_total 7" in lines
    assert 'greenflow_spend{axis="region_a"} 0.5' in lines
    assert 'greenflow_stall_ms_bucket{le="2"} 1' in lines
    assert 'greenflow_stall_ms_bucket{le="+Inf"} 1' in lines
    assert "greenflow_stall_ms_sum 1.5" in lines
    assert "greenflow_stall_ms_count 1" in lines


def test_disabled_registry_is_allocation_free():
    """The zero-overhead contract: a disabled registry returns shared
    stateless singletons, and driving them over a hot loop allocates
    NOTHING that survives (no children, no lock state, no events)."""
    import gc
    import tracemalloc

    from repro.obs import NULL_OBS, get_obs
    from repro.obs.metrics import (MetricsRegistry, NULL_INSTRUMENT)
    from repro.obs.trace import NULL_SPAN

    reg = MetricsRegistry(enabled=False)
    c = reg.counter("greenflow_windows_total")
    h = reg.histogram("greenflow_prep_ms")
    assert c is NULL_INSTRUMENT and h is NULL_INSTRUMENT
    assert c.labels(bucket=128) is NULL_INSTRUMENT
    obs = get_obs(None)
    assert obs is NULL_OBS
    assert obs.span("prep") is NULL_SPAN

    def hot():
        for _ in range(2000):
            c.inc()
            c.inc(7)
            h.observe(3.5)
            with obs.span("prep"):
                pass

    hot()  # warm every code path first
    # attribute allocations by site and count only what the obs module
    # RETAINS: a full test-process has unrelated background threads
    # allocating, and CPython freelists churn a few transient dicts -
    # neither may flake this.  Any real per-call state would retain
    # >= 100 KB over the 2000 iterations; allow one page of churn.
    import os

    import repro.obs as obs_pkg
    obs_dir = os.path.dirname(obs_pkg.__file__)
    tracemalloc.start(1)
    gc.collect()
    before = tracemalloc.take_snapshot()
    hot()
    gc.collect()
    after = tracemalloc.take_snapshot()
    tracemalloc.stop()
    retained = sum(
        s.size_diff for s in after.compare_to(before, "lineno")
        if s.size_diff > 0
        and s.traceback[0].filename.startswith(obs_dir))
    assert retained < 4096, \
        f"disabled telemetry retained {retained} bytes"
    assert obs.tracer.events == []


# ---------------------------------------------------------------------------
# span tracer + Chrome trace export
# ---------------------------------------------------------------------------


def test_chrome_trace_schema(tmp_path):
    """The exported file is valid Chrome trace-event JSON: complete
    events nest, threads get distinct tids and thread_name metadata."""
    from repro.obs.trace import Tracer

    tracer = Tracer()
    with tracer.span("serve", t=0):
        with tracer.span("dispatch", n=128):
            pass

    def worker():
        with tracer.span("prep", t=1):
            pass

    th = threading.Thread(target=worker, name="chunk-prefetch")
    th.start()
    th.join()

    path = tracer.write(str(tmp_path / "trace.json"))
    with open(path) as f:
        doc = json.load(f)
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    xs = [e for e in evs if e["ph"] == "X"]
    metas = [e for e in evs if e["ph"] == "M"]
    assert {e["name"] for e in xs} == {"serve", "dispatch", "prep"}
    for e in xs:
        assert {"name", "ph", "pid", "tid", "ts", "dur"} <= set(e)
    # two threads -> two distinct tids, both named
    assert len({e["tid"] for e in xs}) == 2
    names = {e["args"]["name"] for e in metas}
    assert {"MainThread", "chunk-prefetch"} <= names
    # nesting: dispatch sits inside serve on the same track
    serve = next(e for e in xs if e["name"] == "serve")
    disp = next(e for e in xs if e["name"] == "dispatch")
    assert disp["tid"] == serve["tid"]
    assert serve["ts"] <= disp["ts"]
    assert disp["ts"] + disp["dur"] <= serve["ts"] + serve["dur"]
    assert serve["args"] == {"t": 0}


# ---------------------------------------------------------------------------
# deterministic timing attribution (injected clock)
# ---------------------------------------------------------------------------


class _FakeResult:
    def __init__(self):
        self.prep_ms = 0.0
        self.stall_ms = 0.0
        self.h2d_bytes = 0
        self.compiles = 0
        self.bucket = None
        self.n_valid = 0
        self.revenue_np = np.zeros(0, np.float32)


class _FakePipeline:
    def serve_window(self, ctx, rows, **kw):
        return _FakeResult()


def test_fake_clock_timing_attribution():
    """With an injected deterministic clock the sequential driver's
    timing attribution is EXACT: each tick is one second, and every
    prep/submit measurement spans exactly one tick."""
    from repro.serving.stream import run_stream

    ticks = iter(range(1000))

    def clock():
        return float(next(ticks))

    def source(t, n):
        return np.zeros((n, 2), np.float32), np.zeros(n, np.int32)

    sizes = [4, 4, 4]
    st = run_stream(_FakePipeline(), sizes, source, prefetch=0,
                    clock=clock)
    # call order: t0 | prep0 | serve0 prep1 | serve1 prep2 | serve2 |
    # wall -> every measured phase is exactly one 1 s tick
    assert st.prep_ms == [1000.0, 1000.0, 1000.0]
    assert st.submit_ms == [1000.0, 1000.0, 1000.0]
    assert st.stall_ms == [0.0, 0.0, 0.0]
    assert st.dispatch_ms == [2000.0, 2000.0, 2000.0]
    # t0 is tick 0; the final wall read is tick 13 (1 + 2*len + 2*len)
    assert st.wall_s == 13.0


# ---------------------------------------------------------------------------
# telemetry on/off bitwise parity (the non-negotiable invariant)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving_stack(system_exp, system_reward):
    from repro.cascade.engine import CascadeServer, precompute_stage_scores

    exp = system_exp
    params, rcfg = system_reward
    scores = precompute_stage_scores(exp.models, exp.world,
                                     exp.split.final_eval)
    server = CascadeServer(stage_scores=scores, chains=exp.chains,
                           clicks=exp.clicks_eval, expose=exp.cfg.expose)
    return exp, server, params, rcfg


def _gen_source(exp, *, seed=3, chunk=64, n_users=50_000, obs=None):
    from dataclasses import replace

    from repro.data.request_source import GeneratedSource
    from repro.data.synthetic import StreamingWorld

    wcfg = replace(exp.cfg.world, n_users=n_users)
    return GeneratedSource(StreamingWorld.build(wcfg), exp.models,
                           exp.chains, expose=exp.cfg.expose, seed=seed,
                           chunk=chunk, item_block=128, obs=obs)


def _full_obs(tmp_path, tag):
    from repro.obs import Obs, WindowEventLog

    return Obs(events=WindowEventLog(str(tmp_path / f"{tag}.jsonl")))


def _assert_stream_parity(a, b):
    for t, (ra, rb) in enumerate(zip(a.windows, b.windows)):
        np.testing.assert_array_equal(ra.decisions_np, rb.decisions_np,
                                      err_msg=f"w{t} decisions")
        np.testing.assert_array_equal(ra.revenue_np, rb.revenue_np,
                                      err_msg=f"w{t} revenue")
        assert np.array_equal(np.asarray(ra.spend),
                              np.asarray(rb.spend)), f"w{t} spend"
        assert np.array_equal(np.asarray(ra.lam_after),
                              np.asarray(rb.lam_after)), f"w{t} lam"


def test_obs_parity_plain(serving_stack, tmp_path):
    """Plain pipeline, sequential reference path: telemetry on vs off
    is bitwise identical, and the on-run's flight log carries one row
    per window with the right shape."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    sizes = [32, 64, 32]
    budget = 0.5 * exp.chains.costs.max() * 32
    off = _gen_source(exp)
    obs = _full_obs(tmp_path, "plain")
    on = _gen_source(exp, obs=obs)
    st_off = run_stream(
        ServingPipeline(off.universe, params, rcfg, budget),
        sizes, off, prefetch=0)
    st_on = run_stream(
        ServingPipeline(on.universe, params, rcfg, budget, obs=obs),
        sizes, on, prefetch=0, obs=obs)
    _assert_stream_parity(st_off, st_on)

    rows = [json.loads(line)
            for line in open(obs.events.path).read().splitlines()]
    assert len(rows) == len(sizes)
    assert [r["n"] for r in rows] == sizes
    assert rows[0]["lam"].keys() == {"global"}
    assert rows[0]["spend"].keys() == {"global"}
    snap = obs.metrics.snapshot()
    assert snap["greenflow_windows_total"]["series"][0]["value"] \
        == len(sizes)
    assert snap["greenflow_requests_total"]["series"][0]["value"] \
        == sum(sizes)


def test_obs_parity_geotenants_prefetched(serving_stack, tmp_path):
    """Geotenants pipeline with prefetch>0: telemetry on vs off stays
    bitwise identical, the JSONL rows name every constraint axis, and
    the trace records the prefetch thread as its own track."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    sizes = [48, 96, 48]
    per_req = 0.5 * float(exp.chains.costs.max())
    spec = ConstraintSpec([
        TenantAxis((per_req * 24, per_req * 24), priced=True),
        RegionAxis(2), GlobalAxis(pricing="carbon"),
    ])
    bt = [np.concatenate([np.full(2, per_req * n / 2),
                          np.full(2, 0.6 * per_req * n)]).astype(
        np.float32) for n in sizes]
    st_ = [np.array([1.0, 1.3], np.float32)] * len(sizes)

    off = _gen_source(exp, seed=11)
    obs = _full_obs(tmp_path, "geotenants")
    on = _gen_source(exp, seed=11, obs=obs)
    st_off = run_stream(
        ServingPipeline.from_spec(off.universe, params, rcfg, spec),
        sizes, off, budget_trace=bt, scale_trace=st_, prefetch=2)
    st_on = run_stream(
        ServingPipeline.from_spec(on.universe, params, rcfg, spec,
                                  obs=obs),
        sizes, on, budget_trace=bt, scale_trace=st_, prefetch=2,
        obs=obs)
    _assert_stream_parity(st_off, st_on)

    cs = spec.compile()
    rows = [json.loads(line)
            for line in open(obs.events.path).read().splitlines()]
    assert len(rows) == len(sizes)
    assert list(rows[-1]["lam"]) == list(cs.k_names)
    assert list(rows[-1]["budget"]) == list(cs.budget_names)
    assert rows[-1]["budget"]["tenant[0]"] == pytest.approx(
        float(bt[-1][0]))
    # the prefetch worker shows up as its own named track
    trace = obs.tracer.chrome_trace()
    tnames = {e["args"]["name"] for e in trace["traceEvents"]
              if e["ph"] == "M"}
    assert "chunk-prefetch" in tnames and "MainThread" in tnames
    span_names = {e["name"] for e in trace["traceEvents"]
                  if e["ph"] == "X"}
    assert {"prep", "serve", "h2d", "dispatch", "dual_update",
            "stall", "block_until_ready"} <= span_names
    # per-axis gauges landed from the final window
    snap = obs.metrics.snapshot()
    lam_axes = {s["labels"]["axis"]
                for s in snap["greenflow_lambda"]["series"]}
    assert lam_axes == set(cs.k_names)


def test_legacy_stats_views_still_derive(serving_stack):
    """The bit-compatible derived views survive the obs refactor:
    StreamStats lists, WindowResult.compiles, source cache counters."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    sizes = [32, 32]
    budget = 0.5 * exp.chains.costs.max() * 32
    src = _gen_source(exp, seed=5)
    st = run_stream(ServingPipeline(src.universe, params, rcfg, budget),
                    sizes, src, prefetch=0)
    assert len(st.prep_ms) == len(st.stall_ms) == len(sizes)
    assert st.dispatch_ms == [p + s for p, s in zip(st.prep_ms,
                                                    st.submit_ms)]
    assert st.compiles == [int(r.compiles) for r in st.windows]
    assert st.h2d_bytes == sum(int(r.h2d_bytes) for r in st.windows)
    assert src.cache_hits + src.cache_misses > 0  # ints still count


def test_env_info_shape():
    from repro.obs.env import env_info

    info = env_info()
    assert isinstance(info["cpu_count"], int)
    assert "timestamp_utc" in info
    assert "jax" in info and "backend" in info
