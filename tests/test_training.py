"""Optimizer, schedules, trainer, checkpoint, compression."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.pipeline import DeterministicPipeline, Prefetcher
from repro.distributed.compression import (dequantize_int8, flatten_tree,
                                           quantize_int8, topk_ef_compress,
                                           topk_ef_init, unflatten_like)
from repro.training import checkpoint as ck
from repro.training.optimizer import (AdamW, SGD, clip_by_global_norm,
                                      cosine_schedule, wsd_schedule)
from repro.training.trainer import (Trainer, TrainerConfig, TrainState,
                                    build_train_step, init_state)


def _quadratic_loss(params, batch):
    return jnp.mean(jnp.square(params["w"] @ batch["x"] - batch["y"]))


def _quad_pipeline():
    w_true = np.asarray([[1.0, -2.0], [0.5, 3.0]])

    def fn(rng, step, lo, hi):
        x = rng.normal(size=(2, hi - lo)).astype(np.float32)
        return {"x": x, "y": (w_true @ x).astype(np.float32)}

    return DeterministicPipeline(fn, 32, seed=1)


def test_adamw_solves_quadratic():
    opt = AdamW()
    params = {"w": jnp.zeros((2, 2))}
    step = build_train_step(_quadratic_loss, opt, lambda s: 0.05,
                            donate=False)
    state = init_state(params, opt)
    pipe = _quad_pipeline()
    for _ in range(300):
        state, m = step(state, jax.tree_util.tree_map(jnp.asarray,
                                                      pipe.next()))
    assert float(m["loss"]) < 1e-2


def test_microbatch_equals_full_batch_grads():
    """Grad accumulation must match the single-batch gradient exactly."""
    opt = SGD(momentum=0.0)
    params = {"w": jnp.asarray(np.random.default_rng(0).normal(size=(2, 2)),
                               jnp.float32)}
    batch = {"x": jnp.asarray(np.random.default_rng(1).normal(size=(4, 2, 8)),
                              jnp.float32),
             "y": jnp.asarray(np.random.default_rng(2).normal(size=(4, 2, 8)),
                              jnp.float32)}

    def loss(p, b):
        return jnp.mean(jnp.square(jnp.einsum("ij,bjk->bik", p["w"], b["x"])
                                   - b["y"]))

    s1 = build_train_step(loss, opt, lambda s: 0.1, n_microbatches=1,
                          donate=False)
    s2 = build_train_step(loss, opt, lambda s: 0.1, n_microbatches=4,
                          donate=False)
    st1, _ = s1(init_state(params, opt), batch)
    st2, _ = s2(init_state(params, opt), batch)
    np.testing.assert_allclose(np.asarray(st1.params["w"]),
                               np.asarray(st2.params["w"]), rtol=1e-5,
                               atol=1e-6)


def test_clip_by_global_norm():
    g = {"a": jnp.full((4,), 10.0)}
    clipped, norm = clip_by_global_norm(g, 1.0)
    assert float(norm) == pytest.approx(20.0)
    assert float(jnp.linalg.norm(clipped["a"])) == pytest.approx(1.0, rel=1e-5)


def test_schedules():
    cos = cosine_schedule(1.0, warmup=10, total=110)
    assert float(cos(0)) == 0.0
    assert float(cos(10)) == pytest.approx(1.0)
    assert float(cos(110)) == pytest.approx(0.0, abs=1e-6)
    wsd = wsd_schedule(1.0, warmup=10, stable=80, decay=20)
    assert float(wsd(50)) == pytest.approx(1.0)
    assert float(wsd(110)) == pytest.approx(0.1, rel=1e-3)


def test_pipeline_determinism_and_seek():
    pipe = _quad_pipeline()
    b3 = None
    for i in range(4):
        b = pipe.next()
        if i == 3:
            b3 = b
    pipe.seek(3)
    again = pipe.next()
    np.testing.assert_array_equal(b3["x"], again["x"])


def test_pipeline_host_sharding():
    from repro.data.pipeline import ShardInfo

    def fn(rng, step, lo, hi):
        return {"rows": np.arange(lo, hi)}

    full = DeterministicPipeline(fn, 8, seed=0).next()["rows"]
    parts = []
    for h in range(2):
        p = DeterministicPipeline(fn, 8, seed=0,
                                  shard=ShardInfo(host_id=h, n_hosts=2))
        parts.append(p.next()["rows"])
    np.testing.assert_array_equal(np.concatenate(parts), full)


def test_prefetcher_yields_in_order():
    it = iter([{"i": np.asarray(i)} for i in range(5)])
    out = [b["i"].item() for b in Prefetcher(it, depth=2)]
    assert out == [0, 1, 2, 3, 4]


def test_checkpoint_atomic_roundtrip_and_gc():
    state = {"w": jnp.arange(6.0).reshape(2, 3), "step": jnp.int32(5)}
    with tempfile.TemporaryDirectory() as td:
        for s in (1, 2, 3, 4):
            ck.save(td, s, state, keep=2)
        assert ck.latest_step(td) == 4
        kept = sorted(os.listdir(td))
        assert len([d for d in kept if d.startswith("step_")]) == 2
        restored, man = ck.restore(td, state)
        np.testing.assert_array_equal(np.asarray(restored["w"]),
                                      np.asarray(state["w"]))
        assert man["step"] == 4


def test_trainer_preemption_checkpoints(tmp_path):
    opt = AdamW()
    params = {"w": jnp.zeros((2, 2))}
    step = build_train_step(_quadratic_loss, opt, lambda s: 0.05,
                            donate=False)
    tr = Trainer(TrainerConfig(total_steps=50, ckpt_dir=str(tmp_path),
                               ckpt_every=1000, log_every=1000),
                 step, init_state(params, opt), _quad_pipeline(),
                 log_fn=lambda *a: None)
    tr._preempted = True  # simulate SIGTERM mid-run
    tr.run()
    assert ck.latest_step(str(tmp_path)) is not None


# -- compression -------------------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(0, 1000), st.integers(10, 5000))
def test_int8_quantization_error_bound(seed, n):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.normal(size=n) * rng.uniform(0.1, 100), jnp.float32)
    q, s = quantize_int8(x, block=256)
    back = dequantize_int8(q, s, n, block=256)
    # per-block max / 127 is the quantization step
    step = np.repeat(np.asarray(s), 256)[:n]
    assert np.all(np.abs(np.asarray(back - x)) <= step * 0.5 + 1e-7)


def test_topk_ef_conserves_mass():
    params = {"w": jnp.ones((100,))}
    state = topk_ef_init(params)
    g = jnp.asarray(np.random.default_rng(0).normal(size=100), jnp.float32)
    sent, state = topk_ef_compress(g, state, k_frac=0.1)
    np.testing.assert_allclose(np.asarray(sent + state.residual),
                               np.asarray(g), rtol=1e-6, atol=1e-6)
    # second step transmits what was withheld
    sent2, state2 = topk_ef_compress(jnp.zeros(100), state, k_frac=1.0)
    np.testing.assert_allclose(np.asarray(sent2), np.asarray(state.residual),
                               rtol=1e-6)


def test_flatten_roundtrip():
    tree = {"a": jnp.ones((3, 2)), "b": {"c": jnp.zeros((5,))}}
    flat = flatten_tree(tree)
    back = unflatten_like(flat, tree)
    for x, y in zip(jax.tree_util.tree_leaves(tree),
                    jax.tree_util.tree_leaves(back)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
