"""RecSys model invariants + embedding substrate."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.embedding import embedding_bag, fixed_bag, hash_bucket
from repro.models.recsys import bst, dien, din, dlrm, dssm, xdeepfm, ydnn

KEY = jax.random.PRNGKey(0)
B, T, N = 4, 10, 5


@pytest.fixture(scope="module")
def din_setup():
    cfg = din.DINConfig(item_vocab=100, cat_vocab=10, user_vocab=50,
                        seq_len=T, embed_dim=8, attn_hidden=(16, 8),
                        mlp_hidden=(32, 16))
    p = din.init(KEY, cfg)
    batch = dict(
        hist_ids=jax.random.randint(KEY, (B, T), 0, 100),
        hist_cats=jax.random.randint(KEY, (B, T), 0, 10),
        hist_mask=jnp.ones((B, T)),
        user_fields=jax.random.randint(KEY, (B, 2), 0, 50),
        item_id=jax.random.randint(KEY, (B,), 0, 100),
        item_cat=jax.random.randint(KEY, (B,), 0, 10),
        label=jnp.ones((B,)))
    return cfg, p, batch


def test_din_masked_history_ignored(din_setup):
    """Padding positions must not change the score (mask invariant)."""
    cfg, p, batch = din_setup
    mask = jnp.concatenate([jnp.ones((B, T // 2)), jnp.zeros((B, T - T // 2))],
                           axis=1)
    b1 = dict(batch, hist_mask=mask)
    garbage = jax.random.randint(jax.random.fold_in(KEY, 9), (B, T), 0, 100)
    b2 = dict(b1, hist_ids=jnp.where(mask > 0, b1["hist_ids"], garbage))
    np.testing.assert_allclose(np.asarray(din.forward(p, cfg, b1)),
                               np.asarray(din.forward(p, cfg, b2)),
                               rtol=1e-5, atol=1e-5)


def test_din_score_consistent_with_forward(din_setup):
    cfg, p, batch = din_setup
    cands = jax.random.randint(jax.random.fold_in(KEY, 1), (B, N), 0, 100)
    ccats = jax.random.randint(jax.random.fold_in(KEY, 2), (B, N), 0, 10)
    s = din.score(p, cfg, batch, cands, ccats)
    b0 = dict(batch, item_id=cands[:, 0], item_cat=ccats[:, 0])
    np.testing.assert_allclose(np.asarray(s[:, 0]),
                               np.asarray(din.forward(p, cfg, b0)),
                               rtol=1e-5, atol=1e-5)


def test_din_chunked_retrieval_matches_score(din_setup):
    cfg, p, batch = din_setup
    one = {k: v[:1] for k, v in batch.items()}
    cands = jax.random.randint(jax.random.fold_in(KEY, 3), (8,), 0, 100)
    ccats = jax.random.randint(jax.random.fold_in(KEY, 4), (8,), 0, 10)
    chunked = din.score_candidates_chunked(p, cfg, one, cands, ccats,
                                           n_chunks=4)
    direct = din.score(p, cfg, one, cands[None], ccats[None])[0]
    np.testing.assert_allclose(np.asarray(chunked), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


def test_dlrm_dot_interact_symmetry():
    feats = jax.random.normal(KEY, (3, 6, 8))
    out = dlrm.dot_interact(feats)
    assert out.shape == (3, 15)
    # permuting the feature slots permutes but preserves the dot set
    perm = feats[:, ::-1, :]
    out_p = dlrm.dot_interact(perm)
    assert np.allclose(sorted(np.asarray(out[0]).tolist()),
                       sorted(np.asarray(out_p[0]).tolist()), atol=1e-5)


def test_dlrm_table_offsets_disjoint():
    cfg = dlrm.DLRMConfig(vocab_sizes=(5, 7, 3), embed_dim=4,
                          bot_mlp=(8, 4), top_mlp=(16, 1), top_pad=32)
    offs = np.asarray(dlrm.table_offsets(cfg))
    assert offs.tolist() == [0, 5, 12]


def test_dlrm_forward_and_retrieval():
    cfg = dlrm.DLRMConfig(vocab_sizes=tuple([16] * 26), embed_dim=8,
                          bot_mlp=(16, 8), top_mlp=(32, 1), top_pad=512)
    p = dlrm.init(KEY, cfg)
    batch = dict(dense=jnp.ones((B, 13)),
                 sparse=jax.random.randint(KEY, (B, 26), 0, 16),
                 label=jnp.ones((B,)))
    out = dlrm.forward(p, cfg, batch)
    assert out.shape == (B,) and bool(jnp.isfinite(out).all())
    user = {"dense": batch["dense"][:1], "sparse": batch["sparse"][:1]}
    cand = jax.random.randint(KEY, (6, 4), 0, 16)
    r = dlrm.retrieval_forward(p, cfg, user, cand)
    assert r.shape == (6,)
    # candidate fields actually matter
    r2 = dlrm.retrieval_forward(p, cfg, user, (cand + 1) % 16)
    assert not np.allclose(np.asarray(r), np.asarray(r2))


def test_xdeepfm_heads_additive():
    cfg = xdeepfm.XDeepFMConfig(vocab_sizes=tuple([8] * 12), embed_dim=4,
                                cin_layers=(6, 6), mlp_hidden=(8, 8))
    p = xdeepfm.init(KEY, cfg)
    batch = dict(sparse=jax.random.randint(KEY, (B, 12), 0, 8),
                 label=jnp.ones((B,)))
    out = xdeepfm.forward(p, cfg, batch)
    assert out.shape == (B,) and bool(jnp.isfinite(out).all())


def test_bst_target_position_matters():
    cfg = bst.BSTConfig(item_vocab=50, cat_vocab=8, user_vocab=20,
                        n_user_fields=2, embed_dim=8, seq_len=6,
                        n_heads=4, mlp_hidden=(16, 8))
    p = bst.init(KEY, cfg)
    t = cfg.seq_len - 1
    batch = dict(hist_ids=jax.random.randint(KEY, (B, t), 0, 50),
                 hist_cats=jax.random.randint(KEY, (B, t), 0, 8),
                 hist_mask=jnp.ones((B, t)),
                 user_fields=jax.random.randint(KEY, (B, 2), 0, 20),
                 item_id=jax.random.randint(KEY, (B,), 0, 50),
                 item_cat=jax.random.randint(KEY, (B,), 0, 8),
                 label=jnp.ones((B,)))
    a = bst.forward(p, cfg, batch)
    b2 = dict(batch, item_id=(batch["item_id"] + 7) % 50)
    b = bst.forward(p, cfg, b2)
    assert not np.allclose(np.asarray(a), np.asarray(b))


def test_dien_gru_state_reacts_to_history():
    cfg = dien.DIENConfig(item_vocab=60, cat_vocab=8, user_vocab=30,
                          seq_len=T, embed_dim=6, attn_hidden=(8, 4),
                          mlp_hidden=(16, 8))
    p = dien.init(KEY, cfg)
    batch = dict(hist_ids=jax.random.randint(KEY, (B, T), 0, 60),
                 hist_cats=jax.random.randint(KEY, (B, T), 0, 8),
                 hist_mask=jnp.ones((B, T)),
                 user_fields=jax.random.randint(KEY, (B, 2), 0, 30),
                 item_id=jax.random.randint(KEY, (B,), 0, 60),
                 item_cat=jax.random.randint(KEY, (B,), 0, 8),
                 label=jnp.ones((B,)))
    a = dien.forward(p, cfg, batch)
    shuffled = dict(batch, hist_ids=batch["hist_ids"][:, ::-1])
    b = dien.forward(p, cfg, shuffled)
    assert not np.allclose(np.asarray(a), np.asarray(b))  # order-sensitive


def test_towers_score_shapes():
    dcfg = dssm.DSSMConfig(user_vocab=50, item_vocab=40, hidden=(16, 8),
                           d_out=4)
    dp = dssm.init(KEY, dcfg)
    s = dssm.score(dp, dcfg, jnp.zeros((B, 4), jnp.int32),
                   jnp.zeros((B, N, 2), jnp.int32))
    assert s.shape == (B, N)
    # cosine scores bounded
    assert float(jnp.abs(s).max()) <= 1.0 + 1e-5
    ycfg = ydnn.YDNNConfig(item_vocab=40, user_vocab=50, hist_len=T,
                           hidden=(16, 8), d_out=4)
    yp = ydnn.init(KEY, ycfg)
    s = ydnn.score(yp, ycfg, jnp.zeros((B, T), jnp.int32), jnp.ones((B, T)),
                   jnp.zeros((B, 4), jnp.int32), jnp.zeros((B, N), jnp.int32))
    assert s.shape == (B, N)


# -- embedding substrate -----------------------------------------------------


@settings(max_examples=20, deadline=None)
@given(st.integers(2, 30), st.integers(1, 8), st.sampled_from(["sum", "mean",
                                                               "max"]))
def test_embedding_bag_modes_vs_numpy(v, l, mode):
    rng = np.random.default_rng(v * 31 + l)
    table = jnp.asarray(rng.normal(size=(v, 4)), jnp.float32)
    ids = jnp.asarray(rng.integers(0, v, 3 * l), jnp.int32)
    seg = jnp.asarray(np.sort(rng.integers(0, 3, 3 * l)), jnp.int32)
    out = embedding_bag(table, ids, seg, 3, mode=mode)
    tnp, inp, snp = map(np.asarray, (table, ids, seg))
    for b in range(3):
        rows = tnp[inp[snp == b]]
        if len(rows) == 0:
            continue
        want = {"sum": rows.sum(0), "mean": rows.mean(0),
                "max": rows.max(0)}[mode]
        np.testing.assert_allclose(np.asarray(out[b]), want, rtol=1e-5,
                                   atol=1e-5)


def test_fixed_bag_mask():
    table = jnp.asarray(np.arange(20, dtype=np.float32).reshape(10, 2))
    ids = jnp.asarray([[1, 2, 3]], jnp.int32)
    mask = jnp.asarray([[1.0, 1.0, 0.0]])
    out = fixed_bag(table, ids, mask, mode="sum")
    want = np.asarray(table)[1] + np.asarray(table)[2]
    np.testing.assert_allclose(np.asarray(out[0]), want)


def test_hash_bucket_in_range():
    ids = jnp.arange(10_000, dtype=jnp.int32)
    h = hash_bucket(ids, 97)
    assert int(h.min()) >= 0 and int(h.max()) < 97
    # roughly uniform occupancy
    counts = np.bincount(np.asarray(h), minlength=97)
    assert counts.min() > 0
