"""Per-arch REDUCED-config smoke tests (brief: one forward/train step on
CPU asserting output shapes + no NaNs).  Full configs are exercised only
via the dry-run (ShapeDtypeStruct, no allocation)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_arch


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_smoke_forward_and_grad(arch_id, rng):
    mod = get_arch(arch_id)
    cfg = mod.smoke_config()
    params = mod.init_smoke(jax.random.PRNGKey(0), cfg)
    batch = mod.smoke_batch(rng, cfg)
    loss = mod.smoke_loss(params, cfg, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch_id} smoke loss not finite"
    grads = jax.grad(lambda p: mod.smoke_loss(p, cfg, batch))(params)
    for leaf in jax.tree_util.tree_leaves(grads):
        assert bool(jnp.isfinite(leaf).all()), f"{arch_id} NaN grads"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_one_sgd_step_reduces_loss(arch_id, rng):
    """A few steps on one repeated batch must reduce the loss."""
    mod = get_arch(arch_id)
    cfg = mod.smoke_config()
    params = mod.init_smoke(jax.random.PRNGKey(0), cfg)
    batch = mod.smoke_batch(rng, cfg)
    loss0 = float(mod.smoke_loss(params, cfg, batch))
    lr = 0.003  # small enough not to overshoot any family's loss surface
    # (schnet's RBF filter net diverges at 0.01 - probed empirically)

    @jax.jit
    def step(p):
        g = jax.grad(lambda pp: mod.smoke_loss(pp, cfg, batch))(p)
        return jax.tree_util.tree_map(lambda x, gg: x - lr * gg, p, g)

    for _ in range(10):
        params = step(params)
    loss1 = float(mod.smoke_loss(params, cfg, batch))
    assert loss1 < loss0, f"{arch_id}: {loss0} -> {loss1}"


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_cells_constructible(arch_id):
    """Every non-skipped (arch x shape) cell builds its specs without
    touching devices (the dry-run proper runs in its own process)."""
    mod = get_arch(arch_id)
    for shape in mod.SHAPES:
        if shape in getattr(mod, "SKIPPED_SHAPES", {}):
            continue
        cell = mod.make_cell(shape)
        assert cell.arch_id == arch_id
        leaves = jax.tree_util.tree_leaves(cell.arg_specs)
        assert leaves, f"{arch_id}/{shape} has no inputs"
        for leaf in leaves:
            assert hasattr(leaf, "shape")
        assert cell.meta.get("model_flops", 0) > 0


def test_skipped_shapes_documented():
    from repro.configs.base import LM_SHAPES
    skipped = {a: get_arch(a).SKIPPED_SHAPES for a in ARCH_IDS
               if getattr(get_arch(a), "SKIPPED_SHAPES", {})}
    # exactly the four pure-full-attention LM archs skip long_500k
    assert set(skipped) == {"granite-moe-1b-a400m", "olmoe-1b-7b",
                            "glm4-9b", "minicpm-2b"}
    for reasons in skipped.values():
        assert set(reasons) == {"long_500k"}
        assert "full-attention" in reasons["long_500k"]
    # gemma2 (hybrid) runs long_500k
    assert not getattr(get_arch("gemma2-2b"), "SKIPPED_SHAPES", {})
