import numpy as np
import pytest

from repro.core.action_chain import (ModelInstance, StageSpec, chain_cost,
                                     generate_action_chains,
                                     paper_stage_specs)


def test_paper_chain_space_size():
    chains = generate_action_chains(paper_stage_specs())
    # 1 recall x (1 model x 8 scales) x (2 models x 8 scales) = 128, and the
    # cascade-feasibility prune removes nothing (all n3 <= all n2)
    assert chains.n_chains == 128
    assert chains.n_stages == 3


def test_costs_match_closed_form():
    chains = generate_action_chains(paper_stage_specs())
    j = 17
    expected = chain_cost(chains.stages, chains.chain_idx[j])
    assert chains.costs[j] == pytest.approx(expected)
    # most expensive chain = max scales + DIEN
    jmax = chains.most_expensive()
    assert chains.scale_value[jmax, 1] == 1500
    assert chains.scale_value[jmax, 2] == 200
    assert chains.stages[2].models[chains.chain_idx[jmax, 2, 0]].name == "DIEN"


def test_cascade_monotonicity_prune():
    s1 = StageSpec("a", (ModelInstance("m", 1.0),), (10, 20), 2)
    s2 = StageSpec("b", (ModelInstance("m", 1.0),), (5, 15, 30), 2)
    chains = generate_action_chains([s1, s2])
    for j in range(chains.n_chains):
        n1, n2 = chains.scale_value[j]
        assert n2 <= n1  # downstream never ranks more than upstream kept


def test_multi_hot_monotone():
    st = paper_stage_specs()[1]
    prev = -1
    for si in range(st.n_scales):
        ones = int(st.multi_hot(si).sum())
        assert ones >= prev  # larger scale -> at least as many ones
        prev = ones
    assert int(st.multi_hot(st.n_scales - 1).sum()) == st.n_scale_groups


def test_scale_groups_cover_all_scales():
    st = paper_stage_specs()[2]
    groups = {st.scale_group(i) for i in range(st.n_scales)}
    assert groups == set(range(st.n_scale_groups))


def test_empty_pool_rejected():
    with pytest.raises(ValueError):
        StageSpec("x", (), (1, 2))
    with pytest.raises(ValueError):
        StageSpec("x", (ModelInstance("m", 1.0),), (2, 1))
