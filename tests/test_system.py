"""End-to-end behaviour tests: the paper's core claims at mini scale.

Claim chain tested here (paper §5.2):
  * the primal-dual allocation on predicted rewards respects the budget;
  * GreenFlow (personalized chains) beats EQUAL (fixed chain) at the same
    budget;
  * the oracle (true-revenue) allocation upper-bounds everything and
    strictly beats EQUAL (i.e. heterogeneous users really do have
    heterogeneous reward curves in our world - the premise of the paper).
"""
import numpy as np
import pytest

from repro.experiments import (cras_stage_rewards, evaluate_methods,
                               predicted_rewards, reward_model_metrics)


# the expensive experiment build is session-scoped (tests/conftest.py) so
# other modules (and reruns within one session) share it
@pytest.fixture(scope="module")
def exp(system_exp):
    return system_exp


@pytest.fixture(scope="module")
def reward(system_reward):
    return system_reward


def test_revenue_matrix_sane(exp):
    assert exp.revenue_eval.shape[1] == exp.chains.n_chains
    assert (exp.revenue_eval >= 0).all()
    assert exp.revenue_eval.max() <= exp.cfg.expose
    assert exp.revenue_eval.mean() > 0.05  # the cascade finds clicks


def test_more_compute_helps_on_average(exp):
    """Paper premise: reward curves increase with computation."""
    order = np.argsort(exp.chains.costs)
    cheap = exp.revenue_eval[:, order[:10]].mean()
    dear = exp.revenue_eval[:, order[-10:]].mean()
    assert dear > cheap


def test_oracle_beats_equal_everywhere(exp):
    rows = evaluate_methods(exp, budgets_frac=(0.4, 0.6, 0.8))
    for row in rows:
        best_equal = max(row["equal_din"], row["equal_dien"])
        assert row["oracle"] >= best_equal, row
        assert row["oracle_spend"] <= row["budget_flops"] * 1.001


def test_greenflow_budget_feasible_and_competitive(exp, reward):
    params, rcfg = reward
    pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)
    rows = evaluate_methods(exp, budgets_frac=(0.4, 0.6, 0.8),
                            rewards_pred=pred)
    for row in rows:
        assert row["greenflow_spend"] <= row["budget_flops"] * 1.001
        best_equal = max(row["equal_din"], row["equal_dien"])
        # the learned reward model should not lose to a fixed chain
        assert row["greenflow"] >= best_equal * 0.95, row


def test_greenflow_beats_equal_at_mid_budget(exp, reward):
    """The headline claim at the paper's operating point."""
    params, rcfg = reward
    pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)
    rows = evaluate_methods(exp, budgets_frac=(0.5,), rewards_pred=pred)
    row = rows[0]
    best_equal = max(row["equal_din"], row["equal_dien"])
    assert row["greenflow"] >= best_equal


def test_reward_model_beats_constant_predictor(exp, reward):
    params, rcfg = reward
    m = reward_model_metrics(exp, params, rcfg)
    const_mse = float(np.mean(
        (exp.revenue_eval - exp.revenue_reward.mean()) ** 2))
    assert m["mse"] < const_mse
    assert m["field_rce"] < 1.0


def test_cras_runs_and_respects_budget(exp):
    sr = cras_stage_rewards(exp)
    rows = evaluate_methods(exp, budgets_frac=(0.6,), stage_rewards=sr)
    assert "cras_both" in rows[0]
    assert rows[0]["cras_both"] >= 0
