import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.action_chain import generate_action_chains, paper_stage_specs
from repro.core.reward_model import (BASIS_FUNCTIONS, RewardModelConfig,
                                     apply_bases, field_rce, reward_apply,
                                     reward_matrix, reward_model_init)

CFG = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                        d_context=8, d_feature=16, d_hidden=16, d_state=8)


@pytest.fixture(scope="module")
def params():
    return reward_model_init(jax.random.PRNGKey(0), CFG)


def _encode(scale_groups):
    """scale_groups (K,) ints -> monotone multi-hot (K, Q)."""
    q = CFG.n_scale_groups
    out = np.zeros((len(scale_groups), q), np.float32)
    for k, g in enumerate(scale_groups):
        out[k, :g + 1] = 1.0
    return out


def test_basis_functions_monotone_increasing():
    x = jnp.linspace(0.0, 20.0, 100)
    ys = apply_bases(jnp.stack([x] * len(BASIS_FUNCTIONS), -1))
    diffs = jnp.diff(ys, axis=0)
    assert bool((diffs >= -1e-6).all())


@settings(max_examples=25, deadline=None)
@given(st.integers(0, 3), st.integers(0, 2), st.data())
def test_reward_monotone_in_item_scale(g_lo, stage_k, data):
    """Paper §4.2 guarantee: larger item scale never predicts less reward."""
    params = reward_model_init(jax.random.PRNGKey(1), CFG)
    g_hi = data.draw(st.integers(g_lo, 3))
    ctx = np.asarray(
        np.random.default_rng(data.draw(st.integers(0, 10))).normal(
            size=(1, CFG.d_context)), np.float32)
    mo = np.zeros((1, 3, 2), np.float32)
    mo[:, :, 0] = 1.0
    groups = [1, 1, 1]
    groups[stage_k] = g_lo
    lo = reward_apply(params, CFG, jnp.asarray(ctx), jnp.asarray(mo),
                      jnp.asarray(_encode(groups)[None]))
    groups[stage_k] = g_hi
    hi = reward_apply(params, CFG, jnp.asarray(ctx), jnp.asarray(mo),
                      jnp.asarray(_encode(groups)[None]))
    assert float(hi[0]) >= float(lo[0]) - 1e-5


def test_reward_matrix_matches_reward_apply(params):
    chains = generate_action_chains(paper_stage_specs())
    ctx = jnp.asarray(np.random.default_rng(3).normal(size=(5, CFG.d_context)),
                      jnp.float32)
    r = reward_matrix(params, CFG, ctx, jnp.asarray(chains.model_onehot),
                      jnp.asarray(chains.scale_multihot))
    assert r.shape == (5, chains.n_chains)
    j = 11
    mo = jnp.broadcast_to(jnp.asarray(chains.model_onehot[j]), (5, 3, 2))
    sh = jnp.broadcast_to(jnp.asarray(chains.scale_multihot[j]), (5, 3, 4))
    direct = reward_apply(params, CFG, ctx, mo, sh)
    np.testing.assert_allclose(np.asarray(r[:, j]), np.asarray(direct),
                               rtol=1e-5, atol=1e-5)


def test_nonrecursive_ablation_changes_output(params):
    import dataclasses
    ctx = jnp.ones((2, CFG.d_context))
    chains = generate_action_chains(paper_stage_specs())
    mo = jnp.asarray(chains.model_onehot[:2])
    sh = jnp.asarray(chains.scale_multihot[:2])
    cfg_nr = dataclasses.replace(CFG, recursive=False)
    r1 = reward_apply(params, CFG, ctx, mo, sh)
    r2 = reward_apply(params, cfg_nr, ctx, mo, sh)
    assert not np.allclose(np.asarray(r1), np.asarray(r2))


def test_flat_head_ablation_runs():
    import dataclasses
    cfg = dataclasses.replace(CFG, multi_basis=False)
    p = reward_model_init(jax.random.PRNGKey(2), cfg)
    ctx = jnp.ones((3, CFG.d_context))
    mo = jnp.zeros((3, 3, 2)).at[:, :, 0].set(1.0)
    sh = jnp.ones((3, 3, 4))
    r = reward_apply(p, cfg, ctx, mo, sh)
    assert r.shape == (3,) and bool(jnp.isfinite(r).all())
    assert bool((r >= 0).all())  # softplus head keeps rewards non-negative


def test_field_rce_zero_for_perfect_predictions():
    y = np.asarray([1.0, 2.0, 3.0, 4.0])
    fields = np.asarray([0, 0, 1, 1])
    assert field_rce(y, y, fields) == pytest.approx(0.0)
    # biased predictions on one field raise the metric
    yp = y + np.asarray([1.0, 1.0, 0.0, 0.0])
    assert field_rce(y, yp, fields) > 0.1
