"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref

KEY = jax.random.PRNGKey(0)


def _tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# -- flash attention ---------------------------------------------------------


@pytest.mark.parametrize("b,t,s,h,hk,d", [
    (1, 128, 128, 2, 2, 64),
    (2, 256, 256, 4, 2, 64),
    (1, 200, 264, 4, 1, 32),  # ragged: pad paths
    (2, 64, 512, 8, 4, 128),  # cross lengths
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_sweep(b, t, s, h, hk, d, dtype):
    q = jax.random.normal(KEY, (b, t, h, d), dtype)
    k = jax.random.normal(jax.random.fold_in(KEY, 1), (b, s, hk, d), dtype)
    v = jax.random.normal(jax.random.fold_in(KEY, 2), (b, s, hk, d), dtype)
    out = ops.flash_attention(q, k, v, causal=True, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


@pytest.mark.parametrize("window,softcap,causal", [
    (64, None, True), (-1, 50.0, True), (32, 30.0, True), (-1, None, False),
])
def test_flash_attention_mask_variants(window, softcap, causal):
    q = jax.random.normal(KEY, (2, 192, 4, 64))
    k = jax.random.normal(jax.random.fold_in(KEY, 3), (2, 192, 2, 64))
    v = jax.random.normal(jax.random.fold_in(KEY, 4), (2, 192, 2, 64))
    out = ops.flash_attention(q, k, v, causal=causal, window=window,
                              softcap=softcap, block_q=64, block_kv=64)
    want = ref.flash_attention_ref(q, k, v, causal=causal, window=window,
                                   softcap=softcap)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


# -- embedding bag -----------------------------------------------------------


@pytest.mark.parametrize("v,d,b,l", [(100, 32, 8, 4), (1000, 128, 32, 16),
                                     (64, 256, 5, 7)])
@pytest.mark.parametrize("weighted", [False, True])
def test_embedding_bag_sweep(v, d, b, l, weighted):
    table = jax.random.normal(KEY, (v, d))
    ids = jax.random.randint(jax.random.fold_in(KEY, 5), (b, l), 0, v)
    w = jax.random.uniform(jax.random.fold_in(KEY, 6), (b, l)) if weighted \
        else None
    out = ops.embedding_bag(table, ids, w)
    want = ref.embedding_bag_ref(table, ids, w)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-5, atol=1e-5)


# -- dot interaction ---------------------------------------------------------


@pytest.mark.parametrize("b,f,d", [(32, 27, 64), (100, 8, 16), (7, 13, 32)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dot_interact_sweep(b, f, d, dtype):
    feats = jax.random.normal(KEY, (b, f, d), dtype)
    out = ops.dot_interact(feats, block_b=16)
    want = ref.dot_interact_ref(feats)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), **_tol(dtype))


# -- target attention (DIN) --------------------------------------------------


@pytest.mark.parametrize("b,t,d,h1,h2", [(16, 12, 36, 80, 40),
                                         (50, 100, 36, 80, 40),
                                         (9, 24, 16, 32, 8)])
def test_target_attention_sweep(b, t, d, h1, h2):
    q = jax.random.normal(KEY, (b, d))
    keys = jax.random.normal(jax.random.fold_in(KEY, 7), (b, t, d))
    mask = (jax.random.uniform(jax.random.fold_in(KEY, 8), (b, t)) > 0.3) \
        .astype(jnp.float32)
    ws = []
    for i, (di, do) in enumerate([(4 * d, h1), (h1, h2), (h2, 1)]):
        ws.append(0.1 * jax.random.normal(jax.random.fold_in(KEY, 9 + i),
                                          (di, do)))
        ws.append(jnp.zeros((do,)))
    out = ops.target_attention(q, keys, mask, *ws, block_b=8)
    want = ref.target_attention_ref(q, keys, mask, *ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=2e-5, atol=2e-5)


def test_target_attention_matches_din_model():
    """The kernel is a drop-in for models/recsys/din.attention_pool."""
    from repro.models.recsys import din
    cfg = din.DINConfig(item_vocab=50, cat_vocab=10, user_vocab=20,
                        seq_len=12, embed_dim=8, attn_hidden=(16, 8))
    p = din.init(jax.random.PRNGKey(1), cfg)
    b, t, d = 6, cfg.seq_len, cfg.d_item
    q = jax.random.normal(KEY, (b, d))
    keys = jax.random.normal(jax.random.fold_in(KEY, 20), (b, t, d))
    mask = jnp.ones((b, t))
    model_out = din.attention_pool(p, q, keys, mask)
    lay = p["attn"]["layers"]
    kern_out = ops.target_attention(
        q, keys, mask, lay[0]["w"], lay[0]["b"], lay[1]["w"], lay[1]["b"],
        lay[2]["w"], lay[2]["b"], block_b=8)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out),
                               rtol=2e-5, atol=2e-5)


# -- CIN ---------------------------------------------------------------------


@pytest.mark.parametrize("b,hp,m,d,ho", [(8, 39, 39, 10, 200),
                                         (20, 200, 39, 10, 200),
                                         (5, 8, 12, 4, 16)])
def test_cin_sweep(b, hp, m, d, ho):
    w = 0.05 * jax.random.normal(KEY, (ho, hp * m))
    xp = jax.random.normal(jax.random.fold_in(KEY, 30), (b, hp, d))
    x0 = jax.random.normal(jax.random.fold_in(KEY, 31), (b, m, d))
    out = ops.cin_layer(w, xp, x0, block_b=4, block_h=64)
    want = ref.cin_layer_ref(w, xp, x0)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want),
                               rtol=1e-4, atol=1e-4)


def test_cin_matches_xdeepfm_model():
    from repro.models.recsys import xdeepfm
    cfg = xdeepfm.XDeepFMConfig(vocab_sizes=tuple([16] * 6), embed_dim=4,
                                cin_layers=(8,), mlp_hidden=(8,))
    p = xdeepfm.init(jax.random.PRNGKey(2), cfg)
    x0 = jax.random.normal(KEY, (5, 6, 4))
    model_out = xdeepfm.cin_layer(p["cin"][0], x0, x0)
    kern_out = ops.cin_layer(p["cin"][0], x0, x0, block_b=8, block_h=8)
    np.testing.assert_allclose(np.asarray(kern_out), np.asarray(model_out),
                               rtol=1e-4, atol=1e-4)


# -- cascade truncation (survivor compaction) --------------------------------


@pytest.mark.parametrize("u,i,b,seed", [(24, 150, 64, 0), (40, 200, 96, 1)])
def test_cascade_truncate_matches_scan_path(u, i, b, seed):
    """Interpret-mode Pallas gather+cumsum truncation vs the lax.scan
    engine path (exercised on CPU runners - the ISSUE CI gate)."""
    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)

    rng = np.random.default_rng(seed)
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rows = rng.integers(0, u, b).astype(np.int32)
    dec = rng.integers(0, chains.n_chains, b).astype(np.int32)
    rev_scan, _ = server.serve(rows, dec)  # CPU default: lax.scan path
    rev_pallas, _ = server.serve(rows, dec, interpret=True)
    np.testing.assert_array_equal(rev_scan, rev_pallas)


def test_cascade_truncate_direct_tables():
    """Kernel-level check on hand-built tables incl. the padded tail."""
    from repro.kernels.cascade_truncate import compact_truncate_revenue

    g_count, u_count, cap = 3, 5, 40  # cap not a multiple of 128: pads
    rng = np.random.default_rng(3)
    p = np.empty((g_count, u_count, cap), np.int32)
    for g in range(g_count):
        for uu in range(u_count):
            p[g, uu] = rng.permutation(cap)
    ck = rng.random((g_count, u_count, cap)).astype(np.float32)
    groups = rng.integers(0, g_count, 32).astype(np.int32)
    rows = rng.integers(0, u_count, 32).astype(np.int32)
    n3 = rng.integers(1, cap + 1, 32).astype(np.int32)
    expose = 6
    got = np.asarray(compact_truncate_revenue(
        jnp.asarray(p), jnp.asarray(ck), jnp.asarray(groups),
        jnp.asarray(rows), jnp.asarray(n3), expose=expose, interpret=True))
    for idx in range(32):
        prow = p[groups[idx], rows[idx]]
        m = prow < n3[idx]
        q = np.cumsum(m)
        keep = m & (q <= expose)
        want = (ck[groups[idx], rows[idx]] * keep).sum()
        np.testing.assert_allclose(got[idx], want, rtol=1e-6)
