"""Zero-stall streaming: device-resident chunk tables, true prefetch,
and the donated dual chain.

The PR 7 claim is that NONE of the fast-path machinery is observable in
the numbers: the jitted device table builder is bitwise the host
builder, the prefetched stream is bitwise the sequential one, the
donated dual chain publishes the same prices as the undonated one, and
the slab-keyed table cache returns the same tables it would recompute.
Every test here pins one of those equivalences, plus the new
observability surface (prep/stall/h2d in StreamStats).
"""
import numpy as np
import pytest

import jax.numpy as jnp


@pytest.fixture(scope="module")
def serving_stack(system_exp, system_reward):
    from repro.cascade.engine import CascadeServer, precompute_stage_scores

    exp = system_exp
    params, rcfg = system_reward
    scores = precompute_stage_scores(exp.models, exp.world,
                                     exp.split.final_eval)
    server = CascadeServer(stage_scores=scores, chains=exp.chains,
                           clicks=exp.clicks_eval, expose=exp.cfg.expose)
    return exp, server, params, rcfg


def _gen_source(exp, *, device_tables, seed=3, chunk=64, workers=None,
                n_users=50_000, table_cache=64):
    from dataclasses import replace

    from repro.data.request_source import GeneratedSource
    from repro.data.synthetic import StreamingWorld

    wcfg = replace(exp.cfg.world, n_users=n_users)
    return GeneratedSource(StreamingWorld.build(wcfg), exp.models,
                           exp.chains, expose=exp.cfg.expose, seed=seed,
                           chunk=chunk, item_block=128,
                           device_tables=device_tables, workers=workers,
                           table_cache=table_cache)


def _assert_window_parity(a, b, tag=""):
    np.testing.assert_array_equal(a.decisions_np, b.decisions_np,
                                  err_msg=f"{tag} decisions")
    np.testing.assert_array_equal(a.revenue_np, b.revenue_np,
                                  err_msg=f"{tag} revenue")
    assert np.array_equal(np.asarray(a.spend), np.asarray(b.spend)), tag
    assert np.array_equal(np.asarray(a.lam_after),
                          np.asarray(b.lam_after)), tag


def _geotenants_spec(chains, sizes):
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    per_req = 0.5 * float(chains.costs.max())
    spec = ConstraintSpec([
        TenantAxis((per_req * 24, per_req * 24), priced=True),
        RegionAxis(2), GlobalAxis(pricing="carbon"),
    ])
    bt = [np.concatenate([np.full(2, per_req * n / 2),
                          np.full(2, 0.6 * per_req * n)]).astype(
        np.float32) for n in sizes]
    st_ = [np.array([1.0, 1.3], np.float32)] * len(sizes)
    return spec, bt, st_


# ---------------------------------------------------------------------------
# Bitwise parity: the full fast path vs the PR 6 reference path
# ---------------------------------------------------------------------------


def test_fast_path_parity_generated_plain(serving_stack):
    """Generated source, plain pipeline: device tables + threaded chunk
    scoring + prefetch + donation vs host tables + sequential prep +
    undonated dual - bitwise identical windows."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    sizes = [32, 96, 32, 64]
    budget = 0.5 * exp.chains.costs.max() * 32
    ref = _gen_source(exp, device_tables=False)
    fast = _gen_source(exp, device_tables=True, workers=2)
    st_ref = run_stream(
        ServingPipeline(ref.universe, params, rcfg, budget,
                        donate_dual=False),
        sizes, ref, prefetch=0)
    st_fast = run_stream(
        ServingPipeline(fast.universe, params, rcfg, budget,
                        donate_dual=True),
        sizes, fast, prefetch=2)
    for t, (a, b) in enumerate(zip(st_ref.windows, st_fast.windows)):
        _assert_window_parity(a, b, f"w{t}")


def test_fast_path_parity_replay_geotenants(serving_stack):
    """Replay source, combined tenant x region pipeline: the one-time
    device table upload + per-window device gather vs host row slices -
    bitwise, including regions and the (T, R) spend."""
    from repro.data.request_source import TableReplaySource
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, server, params, rcfg = serving_stack
    sizes = [48, 96, 48]
    spec, bt, st_ = _geotenants_spec(exp.chains, sizes)
    ref = TableReplaySource.from_server(server, exp.ctx_eval, seed=7,
                                        device_tables=False)
    fast = TableReplaySource.from_server(server, exp.ctx_eval, seed=7,
                                         device_tables=True)
    st_ref = run_stream(
        ServingPipeline.from_spec(ref.universe, params, rcfg, spec,
                                  donate_dual=False),
        sizes, ref, budget_trace=bt, scale_trace=st_, prefetch=0)
    st_fast = run_stream(
        ServingPipeline.from_spec(fast.universe, params, rcfg, spec,
                                  donate_dual=True),
        sizes, fast, budget_trace=bt, scale_trace=st_, prefetch=2)
    for t, (a, b) in enumerate(zip(st_ref.windows, st_fast.windows)):
        _assert_window_parity(a, b, f"geot w{t}")
        np.testing.assert_array_equal(a.regions_np, b.regions_np)
        np.testing.assert_array_equal(np.asarray(a.tr_spend),
                                      np.asarray(b.tr_spend))
    assert st_fast.h2d_bytes > 0  # one-time upload + per-window ids


def test_device_table_builder_bitwise_vs_host(serving_stack):
    """The jitted compaction pass returns exactly the host builder's
    tables - including at a ragged (non-chunk-multiple) window."""
    exp, _, _, _ = serving_stack
    host = _gen_source(exp, device_tables=False)
    dev = _gen_source(exp, device_tables=True)
    for t, n in ((2, 64), (3, 37), (4, 100), (5, 1)):
        a, b = host.window(t, n), dev.window(t, n)
        assert isinstance(b.tables["p"], jnp.ndarray)
        np.testing.assert_array_equal(a.ctx, b.ctx, err_msg=str((t, n)))
        np.testing.assert_array_equal(
            np.asarray(a.tables["p"], np.int32),
            np.asarray(b.tables["p"]), err_msg=str((t, n)))
        np.testing.assert_array_equal(
            np.asarray(a.tables["ck"], np.float32),
            np.asarray(b.tables["ck"]), err_msg=str((t, n)))


# ---------------------------------------------------------------------------
# Prefetch: determinism + stall accounting
# ---------------------------------------------------------------------------


def test_prefetch_deterministic_under_seed(serving_stack):
    """Re-running the prefetched stream replays identical windows (each
    is a pure function of (seed, t); the single ordered worker adds no
    schedule dependence)."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    sizes = [32, 64, 32]
    budget = 0.5 * exp.chains.costs.max() * 32
    runs = []
    for _ in range(2):
        src = _gen_source(exp, device_tables=True, workers=2)
        pipe = ServingPipeline(src.universe, params, rcfg, budget)
        runs.append(run_stream(pipe, sizes, src, prefetch=3))
    for t, (a, b) in enumerate(zip(runs[0].windows, runs[1].windows)):
        _assert_window_parity(a, b, f"rerun w{t}")


def test_prefetch_worker_exception_surfaces(serving_stack):
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    src = _gen_source(exp, device_tables=True)
    pipe = ServingPipeline(src.universe, params, rcfg, 100.0)

    class Boom(RuntimeError):
        pass

    class FailingSource:
        def window(self, t, n):
            if t == 1:
                raise Boom("window 1 failed")
            return src.window(t, n)

    with pytest.raises(Boom, match="window 1"):
        run_stream(pipe, [16, 16, 16], FailingSource(), prefetch=2)


def test_stream_stats_timing_fields(serving_stack):
    """dispatch_ms (legacy) == prep + submit per window; stall and h2d
    are recorded; the prefetch=0 path reports zero stalls."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    sizes = [32, 64]
    budget = 0.5 * exp.chains.costs.max() * 32
    src = _gen_source(exp, device_tables=True)
    st = run_stream(ServingPipeline(src.universe, params, rcfg, budget),
                    sizes, src, prefetch=2)
    assert len(st.prep_ms) == len(st.submit_ms) == len(sizes)
    np.testing.assert_allclose(
        st.dispatch_ms,
        [p + s for p, s in zip(st.prep_ms, st.submit_ms)])
    assert all(s >= 0.0 for s in st.stall_ms)
    assert st.h2d_bytes > 0

    src0 = _gen_source(exp, device_tables=True)
    st0 = run_stream(
        ServingPipeline(src0.universe, params, rcfg, budget),
        sizes, src0, prefetch=0)
    assert st0.stall_ms == [0.0] * len(sizes)


# ---------------------------------------------------------------------------
# Slab-keyed device table cache
# ---------------------------------------------------------------------------


def test_table_cache_hits_are_bitwise(serving_stack):
    """A replayed window hits the cache (no rescoring) and returns the
    same tables bit for bit; a cold source recomputes them equal."""
    exp, _, _, _ = serving_stack
    src = _gen_source(exp, device_tables=True)
    a = src.window(4, 100)
    misses = src.cache_misses
    assert misses > 0 and src.cache_hits == 0
    b = src.window(4, 100)  # same arrivals -> every chunk cached
    assert src.cache_hits > 0 and src.cache_misses == misses
    np.testing.assert_array_equal(np.asarray(a.tables["p"]),
                                  np.asarray(b.tables["p"]))
    np.testing.assert_array_equal(np.asarray(a.tables["ck"]),
                                  np.asarray(b.tables["ck"]))
    cold = _gen_source(exp, device_tables=True)
    c = cold.window(4, 100)
    np.testing.assert_array_equal(np.asarray(a.tables["p"]),
                                  np.asarray(c.tables["p"]))


def test_table_cache_lru_eviction(serving_stack):
    exp, _, _, _ = serving_stack
    src = _gen_source(exp, device_tables=True, table_cache=2)
    src.window(0, 64)
    src.window(1, 64)
    src.window(2, 64)  # evicts window 0's slab
    assert len(src._cache) == 2
    misses = src.cache_misses
    src.window(0, 64)  # cold again
    assert src.cache_misses == misses + 1


# ---------------------------------------------------------------------------
# Donated dual chain
# ---------------------------------------------------------------------------


def test_donated_dual_bitwise_and_records_readable(serving_stack):
    """Donation is invisible: same prices as donate_dual=False, and
    every WindowResult's lam_before/lam_after stays host-readable after
    the chain buffer is consumed by the next window."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, server, params, rcfg = serving_stack
    sizes = [48, 96, 48]
    budget = 0.5 * exp.chains.costs.max() * 48

    def sample(t, n):
        rng = np.random.default_rng((7, t))
        rows = rng.integers(0, exp.ctx_eval.shape[0], n)
        return exp.ctx_eval[rows], rows

    st_d = run_stream(
        ServingPipeline(server, params, rcfg, budget, donate_dual=True),
        sizes, sample)
    st_u = run_stream(
        ServingPipeline(server, params, rcfg, budget,
                        donate_dual=False),
        sizes, sample)
    for t, (a, b) in enumerate(zip(st_d.windows, st_u.windows)):
        _assert_window_parity(a, b, f"donate w{t}")
        # the records are copies, not the donated buffers
        assert np.isfinite(np.asarray(a.lam_before)).all()
        assert np.isfinite(np.asarray(a.lam_after)).all()


def test_donated_pipeline_survives_pinned_lam(serving_stack):
    """An explicit-lam (orphan price) call between chained windows must
    not invalidate the live chain buffer."""
    from repro.serving.pipeline import ServingPipeline

    exp, server, params, rcfg = serving_stack
    n = 48
    budget = 0.5 * exp.chains.costs.max() * n
    rng = np.random.default_rng(11)
    rows = rng.integers(0, exp.ctx_eval.shape[0], n)
    ctx = exp.ctx_eval[rows]
    pipe = ServingPipeline(server, params, rcfg, budget,
                           donate_dual=True)
    r1 = pipe.serve_window(ctx, rows)
    pinned = pipe.serve_window(ctx, rows, lam=0.5, update_lam=False)
    assert float(np.asarray(pinned.lam_before)) == 0.5
    r2 = pipe.serve_window(ctx, rows)  # chain continues from r1's price
    assert np.array_equal(np.asarray(r2.lam_before),
                          np.asarray(r1.lam_after))


# ---------------------------------------------------------------------------
# Zero steady-state recompiles on the fast path
# ---------------------------------------------------------------------------


def test_zero_steady_recompiles_fast_path(serving_stack):
    """Device tables + prefetch + donation under a 10x swing: every
    bucket compiles once, steady state never recompiles."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    exp, _, params, rcfg = serving_stack
    src = _gen_source(exp, device_tables=True, workers=2)
    b = 32
    budget = 0.5 * exp.chains.costs.max() * b
    pipe = ServingPipeline(src.universe, params, rcfg, budget,
                           bucketing="pow2", donate_dual=True)
    sizes = [b, 10 * b, b, 10 * b, b, 10 * b]
    st = run_stream(pipe, sizes, src, prefetch=2)
    assert st.steady_compiles == 0
    assert st.compiles[2] == st.compiles[3] == st.compiles[4] == 0
    assert st.total_revenue > 0
