"""greenflow-check: every rule fires on a known-bad fixture, stays
quiet on its known-good twin, pragmas parse (and demand justification),
the jaxpr-audit gates catch deliberately broken toy jits, and the
self-run over src/ stays clean."""
import os
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import lint_paths, lint_source
from repro.analysis.jaxpr_audit import audit_jitted

SRC_DIR = os.path.join(os.path.dirname(__file__), "..", "src")


def run(code, path, rules=None):
    return lint_source(textwrap.dedent(code), path, rules=rules)


def codes(findings, *, suppressed=False):
    return [f.rule for f in findings if f.suppressed == suppressed]


# ---------------------------------------------------------------------------
# GF001 ordered collectives
# ---------------------------------------------------------------------------


def test_gf001_flags_raw_psum_in_serving():
    bad = """
    from jax import lax
    def stitch(x, ax):
        return lax.psum(x, ax)
    """
    assert "GF001" in codes(run(bad, "src/repro/serving/guard.py"))
    # same code outside the serving/distributed scope is fine
    assert codes(run(bad, "src/repro/training/trainer.py")) == []


def test_gf001_good_twin_ordered_psum():
    good = """
    from repro.distributed.sharding import ordered_psum
    def stitch(x, ax):
        return ordered_psum(x, ax)
    """
    assert codes(run(good, "src/repro/serving/guard.py")) == []


# ---------------------------------------------------------------------------
# GF002 hidden host syncs
# ---------------------------------------------------------------------------


def test_gf002_flags_item_and_device_get():
    bad = """
    import jax
    def drain(arr):
        total = arr.sum().item()
        host = jax.device_get(arr)
        return total, host
    """
    assert codes(run(bad, "src/repro/serving/stream.py")) \
        == ["GF002", "GF002"]


def test_gf002_flags_host_numpy_inside_traced_scope():
    bad = """
    import jax
    import numpy as np
    @jax.jit
    def fn(x):
        return np.asarray(x) + 1
    """
    assert "GF002" in codes(run(bad, "src/repro/serving/pipeline.py"))


def test_gf002_detects_the_builder_idiom():
    # fn is traced via `fn = shard_map(fn, ...)` + `jax.jit(fn)`, the
    # pipeline's _build_main_fn shape -- not via a decorator
    bad = """
    import jax
    import numpy as np
    from jax.experimental.shard_map import shard_map
    def build(mesh):
        def fn(x):
            return float(x[0]) * np.float32(2.0)
        fn = shard_map(fn, mesh=mesh, in_specs=None, out_specs=None)
        return jax.jit(fn)
    """
    got = codes(run(bad, "src/repro/serving/pipeline.py"))
    assert got.count("GF002") == 2  # float(traced) + np call


def test_gf002_good_twin_host_prep_and_static_casts():
    good = """
    import jax
    import jax.numpy as jnp
    import numpy as np
    def prep(chunk):  # host-side window prep: numpy is fine here
        return np.asarray(chunk, np.float32)
    @jax.jit
    def fn(x):
        n = int(x.shape[0])  # static metadata never syncs
        return jnp.sum(x) / n
    """
    assert codes(run(good, "src/repro/serving/pipeline.py")) == []


# ---------------------------------------------------------------------------
# GF003 mean reassociation
# ---------------------------------------------------------------------------


def test_gf003_flags_mean_in_dual_arithmetic():
    bad = """
    import jax.numpy as jnp
    def step(lam, costs, used, budget, eta):
        norm = jnp.mean(costs) ** 2
        return jnp.maximum(lam + eta * (used - budget) / norm, 0.0)
    """
    assert "GF003" in codes(run(bad, "src/repro/core/primal_dual.py"))
    # reward-model losses may average freely
    assert codes(run(bad, "src/repro/core/reward_model.py")) == []


def test_gf003_good_twin_structured_divisor():
    good = """
    import jax.numpy as jnp
    def step(lam, costs, used, budget, eta, n):
        norm = jnp.sum(costs) ** 2 / (n * n)
        return jnp.maximum(lam + eta * (used - budget) / norm, 0.0)
    """
    assert codes(run(good, "src/repro/core/primal_dual.py")) == []


# ---------------------------------------------------------------------------
# GF004 jit hygiene
# ---------------------------------------------------------------------------


def test_gf004_flags_dead_static_argnames():
    bad = """
    import jax
    from functools import partial
    @partial(jax.jit, static_argnames=("max_iter",))
    def descend(lam, max_iters):
        return lam * max_iters
    """
    got = run(bad, "src/repro/core/anything.py")
    assert "GF004" in codes(got)
    assert "max_iter" in [f.message for f in got][0]


def test_gf004_static_argnames_call_form_and_good_twin():
    bad = """
    import jax
    def descend(lam, max_iters):
        return lam * max_iters
    fast = jax.jit(descend, static_argnames=("iters",))
    """
    assert "GF004" in codes(run(bad, "src/repro/core/anything.py"))
    good = bad.replace('"iters"', '"max_iters"')
    assert codes(run(good, "src/repro/core/anything.py")) == []


def test_gf004_kwargs_waives_static_argnames():
    good = """
    import jax
    from functools import partial
    @partial(jax.jit, static_argnames=("whatever",))
    def fn(x, **kw):
        return x
    """
    assert codes(run(good, "src/repro/core/anything.py")) == []


def test_gf004_flags_read_after_donation():
    bad = """
    import jax
    def run(f, lam, x):
        g = jax.jit(f, donate_argnums=(0,))
        out = g(lam, x)
        return out + lam  # lam's buffer is gone
    """
    got = run(bad, "src/repro/serving/anything.py")
    assert "GF004" in codes(got)


def test_gf004_good_twin_rebinding_clears_donation():
    good = """
    import jax
    def run(f, lam, x):
        g = jax.jit(f, donate_argnums=(0,))
        lam = g(lam, x)  # the dual-chain idiom: rebind the buffer
        return lam * 2
    """
    assert codes(run(good, "src/repro/serving/anything.py")) == []


# ---------------------------------------------------------------------------
# GF005 nondeterminism
# ---------------------------------------------------------------------------


def test_gf005_flags_wall_clock_and_global_rng():
    bad = """
    import random
    import time
    import numpy as np
    def make_window(t):
        start = time.time()
        noise = np.random.normal(size=8)
        pick = random.randint(0, 7)
        rng = np.random.default_rng()
        return start, noise, pick, rng
    """
    got = codes(run(bad, "src/repro/data/request_source.py"))
    assert got.count("GF005") == 4


def test_gf005_good_twin_seeded_and_injected():
    good = """
    import time
    import numpy as np
    def make_window(seed, t, clock=None):
        clock = clock or time.perf_counter  # reference, not a call
        rng = np.random.default_rng((seed, t))
        return clock, rng.normal(size=8)
    """
    assert codes(run(good, "src/repro/data/request_source.py")) == []


# ---------------------------------------------------------------------------
# GF006 signed zero
# ---------------------------------------------------------------------------


def test_gf006_flags_plus_zero():
    bad = """
    import jax.numpy as jnp
    def canon(x):
        return x + 0.0
    """
    assert "GF006" in codes(run(bad, "src/repro/cascade/engine.py"))


def test_gf006_good_twin_where():
    good = """
    import jax.numpy as jnp
    def canon(x):
        return jnp.where(x == 0.0, jnp.float32(0.0), x)
    """
    assert codes(run(good, "src/repro/cascade/engine.py")) == []


# ---------------------------------------------------------------------------
# Pragmas
# ---------------------------------------------------------------------------

_PSUM = """
from jax import lax
def stitch(x, ax):
    return lax.psum(x, ax)  # gf: allow[GF001] {why}
"""


def test_pragma_suppresses_with_justification():
    got = run(_PSUM.format(why="loopback test helper, order is fixed"),
              "src/repro/serving/guard.py")
    assert codes(got) == []  # nothing unsuppressed
    assert codes(got, suppressed=True) == ["GF001"]
    assert "loopback" in got[0].justification


def test_pragma_without_justification_is_a_finding():
    got = run(_PSUM.format(why=""), "src/repro/serving/guard.py")
    # the original finding survives AND the empty pragma is flagged
    assert sorted(codes(got)) == ["GF000", "GF001"]


def test_stale_pragma_is_a_finding():
    src = """
    def clean():  # gf: allow[GF001] nothing here actually trips it
        return 1
    """
    assert codes(run(src, "src/repro/serving/guard.py")) == ["GF000"]


def test_standalone_pragma_covers_next_code_line():
    src = """
    from jax import lax
    def stitch(x, ax):
        # gf: allow[GF001] reference reduction for the parity test
        return lax.psum(x, ax)
    """
    got = run(src, "src/repro/serving/guard.py")
    assert codes(got) == [] and codes(got, suppressed=True) == ["GF001"]


# ---------------------------------------------------------------------------
# jaxpr audit gates (toy jits, deliberately broken)
# ---------------------------------------------------------------------------


def test_audit_clean_toy_passes_and_sees_donation():
    fn = jax.jit(lambda x, y: x * 2.0 + y, donate_argnums=(0,))
    x = jnp.ones((8,), jnp.float32)
    res = audit_jitted(fn, (x, x), expect_donation=True)
    assert res.ok and res.donated, res.problems


def test_audit_catches_f64_upcast():
    with jax.experimental.enable_x64():
        fn = jax.jit(lambda x: x.astype(jnp.float64) * 2.0)
        res = audit_jitted(fn, (jnp.ones((4,), jnp.float32),))
    assert not res.ok
    assert any("f64" in p for p in res.problems)


def test_audit_catches_host_callback():
    def fn(x):
        return jax.pure_callback(
            lambda a: np.asarray(a) + 1,
            jax.ShapeDtypeStruct(x.shape, x.dtype), x)
    res = audit_jitted(jax.jit(fn), (jnp.ones((4,), jnp.float32),))
    assert not res.ok
    assert any("callback" in p for p in res.problems)


def test_audit_catches_dropped_donation():
    # a scalar output cannot alias the donated (8,) input: jax warns
    # and the aliasing annotation vanishes -- both must be flagged
    fn = jax.jit(lambda x: jnp.sum(x), donate_argnums=(0,))
    res = audit_jitted(fn, (jnp.ones((8,), jnp.float32),),
                       expect_donation=True)
    assert not res.ok
    assert any("donat" in p for p in res.problems)
    assert not res.donated


def test_audit_bounds_the_transfer_set():
    fn = jax.jit(lambda *xs: sum(xs))
    args = tuple(jnp.ones((2,)) for _ in range(9))
    res = audit_jitted(fn, args, max_invars=8)
    assert not res.ok
    assert any("transfer" in p for p in res.problems)


# ---------------------------------------------------------------------------
# The real pipeline + the self-run regression
# ---------------------------------------------------------------------------


def test_audit_plain_pipeline_is_clean():
    from repro.analysis.jaxpr_audit import (audit_pipeline,
                                            build_audit_stack)
    pipe, window, extra = build_audit_stack("plain")
    results = audit_pipeline(pipe, window, extra, mode="plain")
    assert results and all(r.ok for r in results), \
        [(r.name, r.problems) for r in results]
    assert any(r.donated for r in results)  # the dual chain donates


@pytest.mark.slow
def test_audit_geotenants_pipeline_is_clean():
    from repro.analysis.jaxpr_audit import (audit_pipeline,
                                            build_audit_stack)
    pipe, window, extra = build_audit_stack("geotenants")
    results = audit_pipeline(pipe, window, extra, mode="geotenants")
    assert results and all(r.ok for r in results), \
        [(r.name, r.problems) for r in results]


def test_self_run_on_src_is_clean():
    findings = lint_paths([SRC_DIR])
    bad = [f.format() for f in findings if not f.suppressed]
    assert not bad, "\n".join(bad)
    # and every suppression carries a written justification
    assert all(f.justification for f in findings if f.suppressed)


def test_cli_exit_codes(tmp_path):
    from repro.analysis.__main__ import main
    p = tmp_path / "repro" / "serving" / "guard.py"
    p.parent.mkdir(parents=True)
    p.write_text("from jax import lax\n"
                 "def s(x, ax):\n"
                 "    return lax.psum(x, ax)\n")
    out = tmp_path / "report.json"
    assert main([str(p), "--format", "json", "--out", str(out)]) == 1
    import json
    doc = json.loads(out.read_text())
    assert doc["summary"]["by_rule"] == {"GF001": 1}
    p.write_text("def s(x):\n    return x\n")
    assert main([str(p)]) == 0
