"""Cascade engine: chain execution semantics on hand-built scores."""
import numpy as np
import pytest

from repro.core.action_chain import generate_action_chains, paper_stage_specs
from repro.cascade.engine import run_chain


def _scores(u, i, seed=0):
    rng = np.random.default_rng(seed)
    return {k: rng.normal(size=(u, i)) for k in ("DSSM", "YDNN", "DIN",
                                                 "DIEN")}


def test_run_chain_perfect_scores_find_all_clicks():
    u, i = 4, 100
    rng = np.random.default_rng(1)
    clicks = (rng.random((u, i)) < 0.1).astype(np.float32)
    scores = {k: clicks + 0.01 * rng.random((u, i))
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    rev = run_chain(scores, (i, 50, 30, "DIN"), clicks, expose=20)
    want = np.minimum(clicks.sum(1), 20)
    np.testing.assert_array_equal(rev, want)


def test_bad_early_stage_loses_clicks():
    """If recall buries the clicked items, nothing downstream recovers."""
    u, i = 3, 200
    clicks = np.zeros((u, i), np.float32)
    clicks[:, :10] = 1.0  # clicked items are 0..9
    scores = _scores(u, i, 2)
    scores["YDNN"] = clicks.copy()  # perfect rankers downstream
    scores["DIN"] = clicks.copy()
    # bad recall: higher score for higher item index -> clicks ranked last
    scores["DSSM"] = np.tile(np.arange(i, dtype=float), (u, 1))
    rev_bad = run_chain(scores, (i, 20, 10, "DIN"), clicks, expose=10)
    assert rev_bad.sum() == 0.0  # stage 1 keeps items 180..199
    # good recall: clicks ranked first -> everything survives
    scores["DSSM"] = -np.tile(np.arange(i, dtype=float), (u, 1))
    rev_good = run_chain(scores, (i, 20, 10, "DIN"), clicks, expose=10)
    assert rev_good.sum() == u * 10


def test_rank_model_selects_scores():
    u, i = 2, 50
    clicks = np.zeros((u, i), np.float32)
    clicks[:, 0] = 1.0
    scores = _scores(u, i, 3)
    # early stages pass the clicked item through; the RANK model decides
    scores["DSSM"] = clicks + 0.01 * np.random.default_rng(8).random((u, i))
    scores["YDNN"] = scores["DSSM"].copy()
    scores["DIN"] = clicks.copy()  # DIN finds the click
    scores["DIEN"] = -clicks.copy()  # DIEN buries it
    assert run_chain(scores, (i, 30, 10, "DIN"), clicks, expose=1).sum() == u
    assert run_chain(scores, (i, 30, 10, "DIEN"), clicks, expose=1).sum() == 0


def test_revenue_monotone_in_exposure():
    u, i = 5, 120
    rng = np.random.default_rng(4)
    clicks = (rng.random((u, i)) < 0.2).astype(np.float32)
    scores = _scores(u, i, 5)
    r5 = run_chain(scores, (i, 60, 40, "DIN"), clicks, expose=5)
    r20 = run_chain(scores, (i, 60, 40, "DIN"), clicks, expose=20)
    assert (r20 >= r5).all()


def test_simulate_matrix_shape():
    from repro.cascade.engine import simulate_revenue_matrix
    chains = generate_action_chains(paper_stage_specs())
    u, i = 3, 1600
    rng = np.random.default_rng(6)
    clicks = (rng.random((u, i)) < 0.05).astype(np.float32)
    scores = _scores(u, i, 7)
    mat = simulate_revenue_matrix(scores, chains, clicks)
    assert mat.shape == (u, chains.n_chains)
    assert (mat >= 0).all() and (mat <= 20).all()
