"""Fused serving runtime: guard invariants, pipeline parity, spike runs.

Covers the ISSUE acceptance gates:
  * guard property tests - spend <= budget whenever n*c_min <= budget,
    fused (jax) decisions bit-for-bit equal to the legacy (NumPy) path,
    padding invariance, and the fixed `downgraded` counter semantics;
  * ServingPipeline produces the same decisions and revenue as the
    legacy allocate_window-style loop + CascadeServer.serve on the
    system-test config, exact chain-index equality given the same
    lambda trace;
  * a 12-window spike serve run never overshoots max(budget, n*c_min);
  * request-axis shard_map parity in a subprocess with 8 host devices.
"""
import os
import subprocess
import sys
import textwrap

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.serving.guard import downgrade_guard, downgrade_guard_np

REPO = os.path.abspath(os.path.join(os.path.dirname(__file__), ".."))


# ---------------------------------------------------------------------------
# Guard properties (property-style: seeded sweep over random instances)
# ---------------------------------------------------------------------------


def _random_guard_case(rng):
    j = int(rng.integers(2, 16))
    n = int(rng.integers(1, 256))
    costs = rng.uniform(1.0, 10.0, j).astype(np.float32)
    dec = rng.integers(0, j, n).astype(np.int32)
    budget = float(rng.uniform(0.2, 1.2) * np.sum(costs[dec]))
    return costs, dec, budget, int(np.argmin(costs))


def test_guard_spend_within_budget_property():
    rng = np.random.default_rng(0)
    for _ in range(100):
        costs, dec, budget, cheap = _random_guard_case(rng)
        c_min = float(costs[cheap])
        _, _, spend = downgrade_guard_np(dec, costs, budget, cheap)
        cap = budget if len(dec) * c_min <= budget else len(dec) * c_min
        # float32 cost accumulation rounds ~n*eps relative
        assert spend <= cap * (1 + 1e-6 + 1.2e-7 * len(dec))


def test_guard_fused_matches_legacy_bit_for_bit():
    rng = np.random.default_rng(1)
    for _ in range(100):
        costs, dec, budget, cheap = _random_guard_case(rng)
        d_np, k_np, s_np = downgrade_guard_np(dec, costs, budget, cheap)
        d_j, k_j, s_j = downgrade_guard(jnp.asarray(dec),
                                        jnp.asarray(costs), budget, cheap)
        np.testing.assert_array_equal(d_np, np.asarray(d_j))
        assert k_np == int(k_j)
        np.testing.assert_allclose(s_np, float(s_j), rtol=1e-5)


def test_guard_padding_invariance():
    """Padded (masked) windows decide exactly like unpadded ones."""
    rng = np.random.default_rng(2)
    for _ in range(40):
        costs, dec, budget, cheap = _random_guard_case(rng)
        pad = int(rng.integers(1, 64))
        d0, k0, s0 = downgrade_guard(jnp.asarray(dec), jnp.asarray(costs),
                                     budget, cheap)
        dec_p = np.concatenate(
            [dec, rng.integers(0, len(costs), pad).astype(np.int32)])
        valid = np.concatenate([np.ones(len(dec), np.float32),
                                np.zeros(pad, np.float32)])
        d1, k1, s1 = downgrade_guard(jnp.asarray(dec_p), jnp.asarray(costs),
                                     budget, cheap, jnp.asarray(valid))
        np.testing.assert_array_equal(np.asarray(d0),
                                      np.asarray(d1)[: len(dec)])
        assert int(k0) == int(k1)
        np.testing.assert_allclose(float(s0), float(s1), rtol=1e-5)


def test_guard_downgraded_counts_unique_changed_requests():
    """The seed overwrote the counter each pass and counted already-cheap
    requests; the fixed semantics count requests whose FINAL decision
    differs from the allocator's."""
    costs = np.asarray([1.0, 100.0])
    # below the floor: every request gets flagged every pass, but the two
    # already-cheap requests were never actually downgraded
    dec = np.asarray([1, 0, 1, 0, 1], np.int32)
    d, k, s = downgrade_guard_np(dec, costs, 2.0, 0)
    assert list(d) == [0, 0, 0, 0, 0]
    assert k == 3  # not 5 (the flagged count), not a last-pass overwrite
    d_j, k_j, _ = downgrade_guard(jnp.asarray(dec),
                                  jnp.asarray(costs, jnp.float32), 2.0, 0)
    assert int(k_j) == 3


def test_guard_extra_passes_are_noops():
    """Decisions converge in one pass; the fixed-pass fused guard and a
    single-pass guard agree (the legacy loop's early-break equivalence)."""
    rng = np.random.default_rng(3)
    for _ in range(30):
        costs, dec, budget, cheap = _random_guard_case(rng)
        d1, _, _ = downgrade_guard(jnp.asarray(dec), jnp.asarray(costs),
                                   budget, cheap, passes=1)
        d4, _, _ = downgrade_guard(jnp.asarray(dec), jnp.asarray(costs),
                                   budget, cheap, passes=4)
        np.testing.assert_array_equal(np.asarray(d1), np.asarray(d4))


def test_guard_per_tenant_vmap_respects_each_budget():
    rng = np.random.default_rng(4)
    costs = jnp.asarray(rng.uniform(1.0, 10.0, 8), jnp.float32)
    cheap = int(jnp.argmin(costs))
    dec = jnp.asarray(rng.integers(0, 8, (3, 64)), jnp.int32)
    budgets = jnp.asarray([100.0, 250.0, 400.0], jnp.float32)
    valid = jnp.ones((3, 64), jnp.float32)
    gfn = jax.vmap(lambda d, v, b: downgrade_guard(d, costs, b, cheap, v))
    _, _, spends = gfn(dec, valid, budgets)
    floor = 64 * float(costs[cheap])
    for t in range(3):
        cap = max(float(budgets[t]), floor)
        assert float(spends[t]) <= cap * (1 + 1e-5)


# ---------------------------------------------------------------------------
# Grouped reward scoring == per-chain scoring
# ---------------------------------------------------------------------------


def test_reward_matrix_grouped_matches_full(system_exp):
    from repro.core.reward_model import (RewardModelConfig,
                                         chain_prefix_plan,
                                         reward_matrix,
                                         reward_matrix_grouped,
                                         reward_model_init)

    chains = system_exp.chains
    ctx = jnp.asarray(system_exp.ctx_eval[:32], jnp.float32)
    mo = jnp.asarray(chains.model_onehot)
    sh = jnp.asarray(chains.scale_multihot)
    plan = chain_prefix_plan(chains.chain_idx[:, :, 0])
    for recursive in (True, False):
        for multi_basis in (True, False):
            cfg = RewardModelConfig(
                n_stages=chains.n_stages, max_models=2, n_scale_groups=4,
                d_context=ctx.shape[1], d_feature=32, d_hidden=32,
                d_state=16, recursive=recursive, multi_basis=multi_basis)
            params = reward_model_init(jax.random.PRNGKey(7), cfg)
            full = reward_matrix(params, cfg, ctx, mo, sh)
            grouped = reward_matrix_grouped(params, cfg, ctx, sh, plan)
            np.testing.assert_array_equal(np.asarray(full),
                                          np.asarray(grouped))


# ---------------------------------------------------------------------------
# Fused pipeline vs the legacy loop (system-test config)
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def serving_stack(system_exp, system_reward):
    from repro.cascade.engine import CascadeServer, precompute_stage_scores

    exp = system_exp
    params, rcfg = system_reward
    scores = precompute_stage_scores(exp.models, exp.world,
                                     exp.split.final_eval)
    server = CascadeServer(stage_scores=scores, chains=exp.chains,
                           clicks=exp.clicks_eval, expose=exp.cfg.expose)
    return exp, server, params, rcfg


def test_pipeline_matches_legacy_loop_exactly(serving_stack):
    """Exact chain-index + revenue equality given the same lambda trace,
    across constant and 3x spike windows (the acceptance criterion)."""
    from repro.core.budget import BudgetController
    from repro.core.reward_model import denormalize_rewards, reward_matrix
    from repro.serving.pipeline import ServingPipeline

    exp, server, params, rcfg = serving_stack
    chains = exp.chains
    b = 64
    budget = 0.6 * chains.costs.max() * b
    mo = jnp.asarray(chains.model_onehot)
    sh = jnp.asarray(chains.scale_multihot)
    score = jax.jit(lambda p, c: denormalize_rewards(
        p, reward_matrix(p, rcfg, c, mo, sh)))
    ctl = BudgetController(chains, budget)
    pipe = ServingPipeline(server, params, rcfg, budget)
    rng = np.random.default_rng(0)
    n_eval = exp.ctx_eval.shape[0]
    lam_trace = []
    for t in range(6):
        n_t = b * (3 if t in (2, 3) else 1)
        rows = rng.integers(0, n_eval, n_t)
        ctx = exp.ctx_eval[rows]
        lam_before = float(ctl.pd.lam)
        lam_trace.append(lam_before)
        rewards = np.asarray(score(params, jnp.asarray(ctx, jnp.float32)))
        dec_legacy = ctl.step_window(rewards)
        rev_legacy, flops_legacy = server.serve(rows, dec_legacy)
        res = pipe.serve_window(ctx, rows, lam=lam_before)
        np.testing.assert_array_equal(dec_legacy, res.decisions_np)
        np.testing.assert_array_equal(rev_legacy, res.revenue_np)
        assert int(res.downgraded) == ctl.stats[-1].downgraded
        np.testing.assert_allclose(float(res.spend), ctl.stats[-1].spend,
                                   rtol=1e-6)
        # free-running price agrees too (same rewards, same Algorithm 1)
        np.testing.assert_allclose(float(res.lam_after),
                                   ctl.stats[-1].lam, rtol=1e-5,
                                   atol=1e-12)


def test_pipeline_spike_run_never_overshoots(serving_stack):
    """12-window free-running serve with a 3x spike: every window's spend
    stays under max(budget, n*c_min)."""
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import (TrafficScenario, run_stream,
                                      scenario_windows)

    exp, server, params, rcfg = serving_stack
    chains = exp.chains
    b = 48
    budget = 0.5 * chains.costs.max() * b
    pipe = ServingPipeline(server, params, rcfg, budget)
    sc = TrafficScenario("spike", 12, b, spike_mult=3.0)
    rng = np.random.default_rng(1)
    n_eval = exp.ctx_eval.shape[0]

    def sample(t, n):
        rows = rng.integers(0, n_eval, n)
        return exp.ctx_eval[rows], rows

    st = run_stream(pipe, scenario_windows(sc), sample)
    assert len(st.windows) == 12
    assert st.overshoot(float(chains.costs.min())) <= 1e-5
    spike_windows = [r for r in st.windows if r.n_valid > b]
    assert spike_windows and any(int(r.downgraded) > 0
                                 for r in spike_windows)


def test_pipeline_tenant_budgets_shared_price(serving_stack):
    exp, server, params, rcfg = serving_stack
    from repro.serving.pipeline import ServingPipeline

    chains = exp.chains
    b = 64
    budget = 0.5 * chains.costs.max() * b
    tb = np.full(4, budget / 4, np.float32)
    pipe = ServingPipeline(server, params, rcfg, budget, tenant_budgets=tb)
    rng = np.random.default_rng(2)
    rows = rng.integers(0, exp.ctx_eval.shape[0], b)
    res = pipe.serve_window(exp.ctx_eval[rows], rows)
    # 16-request tenant blocks pad to the 32-wide bucket: the mask-aware
    # trim must still return exactly the real requests
    assert len(res.decisions_np) == b and len(res.revenue_np) == b
    floor = (b // 4) * float(chains.costs.min())
    assert res.tenant_spend is not None
    for t in range(4):
        cap = max(budget / 4, floor)
        assert float(res.tenant_spend[t]) <= cap * (1 + 1e-5)


# ---------------------------------------------------------------------------
# Request-axis sharding: subprocess with 8 fake host devices
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_pipeline_sharded_matches_unsharded():
    env = dict(os.environ)
    env["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    env["JAX_PLATFORMS"] = "cpu"
    out = subprocess.run([sys.executable, "-c", textwrap.dedent("""
    import numpy as np, jax, jax.numpy as jnp
    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import (RewardModelConfig, chain_label_norm,
                                         reward_model_init)
    from repro.launch.mesh import make_request_mesh
    from repro.serving.pipeline import ServingPipeline

    rng = np.random.default_rng(0)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    params["label_norm"] = jnp.asarray(
        np.linspace(1.0, 3.0, chains.n_chains).astype(np.float32))
    budget = 0.5 * float(chains.costs.max()) * 64
    mesh = make_request_mesh(8)
    pipe_s = ServingPipeline(server, params, rcfg, budget, mesh=mesh)
    pipe_u = ServingPipeline(server, params, rcfg, budget)
    rng2 = np.random.default_rng(1)
    for t, n in enumerate([64, 192, 50, 64]):  # incl. padded windows
        rows = rng2.integers(0, u, n)
        ctx = rng2.normal(size=(n, 12)).astype(np.float32)
        rs = pipe_s.serve_window(ctx, rows)
        ru = pipe_u.serve_window(ctx, rows)
        assert np.array_equal(rs.decisions_np, ru.decisions_np), t
        assert np.array_equal(rs.revenue_np, ru.revenue_np), t
        assert int(rs.downgraded) == int(ru.downgraded), t
        np.testing.assert_allclose(float(rs.lam_after),
                                   float(ru.lam_after), rtol=1e-5)
    print("SHARDED SERVING PARITY OK")
    """)], capture_output=True, text=True, timeout=420, env=env)
    assert out.returncode == 0, f"stdout:{out.stdout}\nstderr:{out.stderr}"
    assert "SHARDED SERVING PARITY OK" in out.stdout
