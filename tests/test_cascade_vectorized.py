"""Rank-based vectorized engine vs brute-force references.

Covers the satellite regression for the seed's stage-1/stage-2 top-k
off-by-one (argpartition kth inconsistency): every stage now keeps the
first ``keep`` survivors along the stage model's global descending stable
order (ties by item id), which an independent per-user Python reference
verifies here, including the ``n3 >= n2`` edge and heavy score ties.
"""
import numpy as np
import pytest

from repro.cascade.engine import (CascadeServer, run_chain,
                                  simulate_revenue_matrix,
                                  simulate_revenue_matrix_reference)
from repro.core.action_chain import (ModelInstance, StageSpec,
                                     generate_action_chains)

MODELS = ("DSSM", "YDNN", "DIN", "DIEN")


def _world(u, i, seed, *, ties=False, ctr=0.1, dtype=np.float64):
    rng = np.random.default_rng(seed)
    if ties:  # coarse integer scores -> plenty of exact ties
        scores = {k: rng.integers(0, 5, size=(u, i)).astype(dtype)
                  for k in MODELS}
    else:
        scores = {k: rng.normal(size=(u, i)).astype(dtype) for k in MODELS}
    clicks = (rng.random((u, i)) < ctr).astype(np.float32)
    return scores, clicks


def _brute_chain(scores, desc, clicks, expose):
    """Per-user Python loops; shares NOTHING with the engine internals."""
    n1, n2, n3, name = desc
    u_n, i_n = clicks.shape
    out = np.zeros(u_n, np.float32)
    for u in range(u_n):
        def order(nm):
            return sorted(range(i_n),
                          key=lambda it: (-scores[nm][u, it], it))
        kept1 = order("DSSM")[:min(n1, n2)]
        in1 = set(kept1)
        kept2 = [it for it in order("YDNN") if it in in1][:n3]
        in2 = set(kept2)
        exposed = [it for it in order(name) if it in in2][:expose]
        out[u] = clicks[u, exposed].sum()
    return out


@pytest.mark.parametrize("seed,ties", [(0, False), (1, False), (2, True)])
@pytest.mark.parametrize("desc", [
    (200, 50, 20, "DIN"),
    (200, 30, 30, "DIEN"),   # n3 == n2
    (200, 20, 60, "DIN"),    # n3 > n2: keep degrades to "all survivors"
    (200, 1, 1, "DIEN"),     # the seed's kth=-1 argpartition edge
    (120, 50, 20, "DIN"),    # n1 < I folds into stage-0 keep
])
def test_run_chain_matches_bruteforce(seed, ties, desc):
    scores, clicks = _world(6, 200, seed, ties=ties)
    got = run_chain(scores, desc, clicks, expose=8)
    want = _brute_chain(scores, desc, clicks, expose=8)
    np.testing.assert_array_equal(got, want)


def _chain_set(i, *, n_scales=4, expose=8):
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, n_scales))
    n3 = tuple(int(x) for x in np.linspace(expose, 0.2 * i, n_scales))
    return generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))


# float32 exercises the packed (-score, id) single-key sort; float64 the
# lexsort path; ties exercise the id tie-break in both
@pytest.mark.parametrize("seed,ties,dtype", [
    (3, False, np.float64), (4, False, np.float32),
    (5, True, np.float64), (6, True, np.float32),
])
def test_vectorized_matrix_bit_identical_to_reference(seed, ties, dtype):
    scores, clicks = _world(24, 150, seed, ties=ties, dtype=dtype)
    chains = _chain_set(150)
    fast = simulate_revenue_matrix(scores, chains, clicks, expose=8)
    ref = simulate_revenue_matrix_reference(scores, chains, clicks, expose=8)
    assert fast.shape == (24, chains.n_chains)
    np.testing.assert_array_equal(fast, ref)


def test_vectorized_matrix_many_users_threaded():
    """Enough users to engage the threaded user-shard path."""
    scores, clicks = _world(200, 120, seed=9, dtype=np.float32)
    chains = _chain_set(120)
    fast = simulate_revenue_matrix(scores, chains, clicks, expose=8)
    ref = simulate_revenue_matrix_reference(scores, chains, clicks, expose=8)
    np.testing.assert_array_equal(fast, ref)


def test_float64_precision_ties_match_reference():
    """Scores distinct in float64 but equal at float32 precision: the
    engine must not downcast (it would flip the tie-break)."""
    scores, clicks = _world(4, 100, seed=11, dtype=np.float64)
    scores["DIN"][:, 0] = 1.0 + 1e-12  # beats item 1 only in float64
    scores["DIN"][:, 1] = 1.0
    chains = _chain_set(100)
    fast = simulate_revenue_matrix(scores, chains, clicks, expose=8)
    ref = simulate_revenue_matrix_reference(scores, chains, clicks, expose=8)
    np.testing.assert_array_equal(fast, ref)


def test_signed_zero_scores_match_reference():
    """-0.0 vs +0.0 are equal under float compare; the packed-key sort
    must agree with the reference on that tie."""
    scores, clicks = _world(6, 100, seed=10, dtype=np.float32)
    scores["DIN"][:, :50] = -0.0
    scores["DIN"][:, 50:] = 0.0
    chains = _chain_set(100)
    fast = simulate_revenue_matrix(scores, chains, clicks, expose=8)
    ref = simulate_revenue_matrix_reference(scores, chains, clicks, expose=8)
    np.testing.assert_array_equal(fast, ref)


def test_server_matches_matrix_columns():
    scores, clicks = _world(20, 120, seed=6)
    chains = _chain_set(120)
    mat = simulate_revenue_matrix(scores, chains, clicks, expose=8)
    srv = CascadeServer(stage_scores=scores, chains=chains, clicks=clicks,
                       expose=8)
    rng = np.random.default_rng(7)
    rows = rng.integers(0, 20, 64).astype(np.int32)
    dec = rng.integers(0, chains.n_chains, 64).astype(np.int32)
    rev, flops = srv.serve(rows, dec)
    np.testing.assert_array_equal(rev, mat[rows, dec])
    np.testing.assert_array_equal(flops, chains.costs[dec])


def test_matrix_monotone_in_exposure():
    scores, clicks = _world(10, 100, seed=8, ctr=0.2)
    chains = _chain_set(100)
    r4 = simulate_revenue_matrix(scores, chains, clicks, expose=4)
    r12 = simulate_revenue_matrix(scores, chains, clicks, expose=12)
    assert (r12 >= r4).all()
