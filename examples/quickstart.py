"""GreenFlow quickstart: the paper's machinery in ~60 seconds on CPU.

    PYTHONPATH=src python examples/quickstart.py

Walks the three framework steps of Figure 2 on synthetic rewards:
  1. action-chain generation (Cartesian product over stage pools),
  2. reward + cost estimation per chain,
  3. dynamic primal-dual allocation under a FLOPs budget,
and shows the budget being respected while revenue beats EQUAL.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (DynamicPrimalDual, RewardModelConfig, allocate,
                        consumption, dual_bisect, equal_allocation,
                        generate_action_chains, paper_stage_specs,
                        pfec_report, reward_matrix, reward_model_init)

# -- step 1: the paper's chain space (DSSM -> YDNN@n2 -> DIN|DIEN@n3) -------
chains = generate_action_chains(paper_stage_specs())
print(f"chain space: J={chains.n_chains}  "
      f"cost range {chains.costs.min():.2e}..{chains.costs.max():.2e} FLOPs")
print("cheapest :", chains.describe(chains.cheapest()))
print("dearest  :", chains.describe(chains.most_expensive()))

# -- step 2: personalized rewards from the (untrained here) reward model ----
cfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                        d_context=16)
params = reward_model_init(jax.random.PRNGKey(0), cfg)
ctx = jax.random.normal(jax.random.PRNGKey(1), (512, 16))  # 512 requests
rewards = reward_matrix(params, cfg, ctx, jnp.asarray(chains.model_onehot),
                        jnp.asarray(chains.scale_multihot))
print(f"\nreward matrix: {rewards.shape}, mean={float(rewards.mean()):.3f}")

# -- step 3: primal-dual allocation under 55% of the max budget -------------
costs = jnp.asarray(chains.costs, jnp.float32)
budget = 0.55 * float(chains.costs.max()) * 512
lam = dual_bisect(rewards, costs, budget)
decisions = np.asarray(allocate(rewards, costs, lam))
spend = chains.costs[decisions].sum()
rev = float(np.asarray(rewards)[np.arange(512), decisions].sum())
print(f"\nGreenFlow: lambda*={float(lam):.3e}  spend/budget="
      f"{spend/budget:.3f}  predicted revenue={rev:.1f}")
print(f"chains in use: {len(np.unique(decisions))} distinct "
      f"(personalized allocation)")

# EQUAL baseline at the same budget
j_eq = equal_allocation(chains, budget, 512)
rev_eq = float(np.asarray(rewards)[:, j_eq].sum())
print(f"EQUAL     : fixed chain '{chains.describe(j_eq)}' "
      f"predicted revenue={rev_eq:.1f}")
print(f"uplift    : {100 * (rev / max(rev_eq, 1e-9) - 1):+.1f}%")

# nearline tracker over streaming windows (Algorithm 1 outer loop)
pd = DynamicPrimalDual(chains.costs, budget)
for t in range(5):
    pd.update(np.asarray(rewards))
print(f"\nnearline dual price over 5 windows: "
      f"{[f'{x:.2e}' for x in pd.history]}")

# PFEC accounting (paper §3.2)
rep = pfec_report(clicks=rev, flops=float(spend))
print(f"\nPFEC: {rep.as_row()}")
print("\nquickstart OK")
