"""End-to-end offline reproduction (paper §5.2 protocol, scaled world).

    PYTHONPATH=src python examples/train_cascade.py [--small]

Trains the four cascade models + the personalized reward model on a
synthetic Ali-CCP-style log, then sweeps budgets and prints the Figure-4
comparison (GreenFlow vs CRAS-* vs EQUAL-* vs the true-revenue oracle).
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.data.synthetic import WorldConfig
from repro.experiments import (ExperimentConfig, build_experiment,
                               cras_stage_rewards, evaluate_methods,
                               predicted_rewards, reward_model_metrics,
                               train_reward_model)

ap = argparse.ArgumentParser()
ap.add_argument("--small", action="store_true")
args = ap.parse_args()

cfg = ExperimentConfig(
    world=WorldConfig(n_users=800 if args.small else 2500,
                      n_items=200 if args.small else 400,
                      hist_len=10 if args.small else 12, seed=7),
    expose=8 if args.small else 10, n_scales=4 if args.small else 6,
    cascade_steps=100 if args.small else 220,
    reward_steps=200 if args.small else 500, batch=48)

exp = build_experiment(cfg, verbose=True)
params, rcfg = train_reward_model(exp)
metrics = reward_model_metrics(exp, params, rcfg)
print(f"\nreward model: Field-RCE={metrics['field_rce']:.4f} "
      f"MSE={metrics['mse']:.4f}")

pred = predicted_rewards(exp, params, rcfg, exp.ctx_eval)
sr = cras_stage_rewards(exp)
rows = evaluate_methods(exp, budgets_frac=(0.3, 0.45, 0.6, 0.75, 0.9),
                        rewards_pred=pred, stage_rewards=sr)

cols = ("budget_frac", "greenflow", "cras_din", "cras_dien", "equal_din",
        "equal_dien", "oracle")
print("\n" + "  ".join(f"{c:>11}" for c in cols))
for r in rows:
    print("  ".join(f"{r[c]:>11.1f}" if isinstance(r[c], float) else
                    f"{r[c]:>11}" for c in cols))

mid = rows[len(rows) // 2]
best_base = max(mid["cras_din"], mid["cras_dien"], mid["equal_din"],
                mid["equal_dien"])
print(f"\nGreenFlow uplift vs best baseline at "
      f"{mid['budget_frac']:.0%} budget: "
      f"{100 * (mid['greenflow'] / best_base - 1):+.1f}%")
print("train_cascade OK")
