"""Train a small LM with the full substrate (data pipeline, AdamW + WSD,
microbatching, checkpoint/resume).

    PYTHONPATH=src python examples/train_lm.py            # ~20M params, CPU
    PYTHONPATH=src python examples/train_lm.py --preset 100m --steps 300
        # the deliverable-(b) scale; sized for real hardware

The 100m preset is the gemma2-style architecture at d_model=768/12L - on
TPU it trains a few hundred steps in minutes; on this CPU container use
the default small preset.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import numpy as np

from repro.data.pipeline import DeterministicPipeline, lm_token_batch_fn
from repro.models import lm
from repro.training.optimizer import AdamW, wsd_schedule
from repro.training.trainer import (Trainer, TrainerConfig, build_train_step,
                                    init_state)

PRESETS = {
    "small": dict(n_layers=4, d_model=256, n_heads=4, n_kv_heads=2,
                  d_head=64, d_ff=1024, vocab=4096, padded_vocab=4096,
                  seq=256, batch=8),
    "100m": dict(n_layers=12, d_model=768, n_heads=12, n_kv_heads=4,
                 d_head=64, d_ff=3072, vocab=32768, padded_vocab=32768,
                 seq=1024, batch=32),
}

ap = argparse.ArgumentParser()
ap.add_argument("--preset", choices=PRESETS, default="small")
ap.add_argument("--steps", type=int, default=60)
ap.add_argument("--ckpt-dir", default=None)
args = ap.parse_args()

p = PRESETS[args.preset]
cfg = lm.LMConfig(name=f"lm-{args.preset}", n_layers=p["n_layers"],
                  d_model=p["d_model"], n_heads=p["n_heads"],
                  n_kv_heads=p["n_kv_heads"], d_head=p["d_head"],
                  d_ff=p["d_ff"], vocab=p["vocab"],
                  padded_vocab=p["padded_vocab"], dtype="float32",
                  remat=False, fsdp=False)
params = lm.init(jax.random.PRNGKey(0), cfg)
n = sum(int(x.size) for x in jax.tree_util.tree_leaves(params))
print(f"[train_lm] {cfg.name}: {n/1e6:.1f}M params, seq={p['seq']}, "
      f"batch={p['batch']}, steps={args.steps}")

opt = AdamW(weight_decay=0.01)
sched = wsd_schedule(3e-4, warmup=max(2, args.steps // 10),
                     stable=int(args.steps * 0.7), decay=args.steps // 5)
step = build_train_step(lambda pp, b: lm.loss_fn(pp, cfg, b), opt, sched,
                        donate=False)
pipe = DeterministicPipeline(lm_token_batch_fn(cfg.vocab, p["seq"]),
                             p["batch"], seed=0)
trainer = Trainer(
    TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                  ckpt_every=max(20, args.steps // 3),
                  log_every=max(1, args.steps // 10)),
    step, init_state(params, opt), pipe)
out = trainer.run()
h = out["history"]
print(f"[train_lm] loss {h[0]['loss']:.3f} -> {h[-1]['loss']:.3f} "
      f"in {out['wall_s']:.0f}s")
assert h[-1]["loss"] < h[0]["loss"], "loss must decrease"
print("train_lm OK")
