"""Flight-recorder quickstart: trace a serving run, open it in Perfetto.

The one-liner is the CLI - any ``launch/serve.py`` scenario takes the
observability flags:

    PYTHONPATH=src python -m repro.launch.serve --scenario geotenants \
        --tenants 3 --tenant-mode priced --small --windows 20 \
        --metrics-out results/obs/metrics.prom \
        --trace-out results/obs/trace.json --obs-interval 5

which leaves three artifacts:

  results/obs/trace.json            Chrome trace-event JSON.  Open
      https://ui.perfetto.dev and drag the file in (or
      chrome://tracing).  The serving thread and the chunk-prefetch
      worker render as separate tracks; the per-window ``serve`` spans
      nest ``h2d`` -> ``dispatch`` -> ``dual_update``, the worker track
      shows ``prep``/``chunk_tables``, and any serving-thread gap shows
      up as a ``stall`` span - prefetch working means stalls ~ 0.
  results/obs/metrics.prom(.json)   Prometheus text + JSON snapshot of
      the ``greenflow_*`` registry (windows/requests served, prep /
      stall / submit histograms, h2d bytes, recompiles, per-axis
      lambda / spend / budget gauges).
  results/obs/metrics.prom.windows.jsonl   one JSON row per window:
      size, bucket, every dual price and per-axis spend-vs-budget by
      ConstraintSpec axis name, FLOPs, gCO2e, timing - the flight log.

Add ``--profile-dir /tmp/jaxprof`` to capture a jax.profiler trace of
the same run (device-side timeline, with the obs span names threaded
through as TraceAnnotations).

This script shows the same thing PROGRAMMATICALLY on a toy stream -
build an ``Obs``, hand it to the source / pipeline / driver, export:

    PYTHONPATH=src python examples/trace_serving.py
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def main():
    from dataclasses import replace

    from repro.data.request_source import GeneratedSource
    from repro.data.synthetic import StreamingWorld
    from repro.experiments import build_serving_stack, serve_config
    from repro.obs import Obs, WindowEventLog
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import run_stream

    print("[example] building the small serving stack ...")
    exp, _, params, rcfg = build_serving_stack(
        serve_config(small=True), verbose=True)

    # ONE Obs is shared by the source (cache + chunk_tables spans), the
    # pipeline (h2d/dispatch/dual_update spans) and the stream driver
    # (prep/stall/serve spans, per-window metrics, the flight log)
    obs = Obs(events=WindowEventLog("results/obs/example.windows.jsonl"),
              interval=4)  # live line every 4 windows
    world = StreamingWorld.build(
        replace(exp.cfg.world, n_users=100_000))
    source = GeneratedSource(world, exp.models, exp.chains,
                             expose=exp.cfg.expose, seed=0, obs=obs)
    budget = 0.5 * float(exp.chains.costs.max()) * 64
    pipeline = ServingPipeline(source.universe, params, rcfg, budget,
                               obs=obs)
    sizes = [64, 128, 64, 128] * 4
    stats = run_stream(pipeline, sizes, source, prefetch=2, obs=obs)

    prom, snap = obs.export("results/obs/example.prom")
    trace = obs.tracer.write("results/obs/example_trace.json")
    print(f"served {len(stats.windows)} windows "
          f"({sum(stats.sizes)} requests) in {stats.wall_s:.2f}s")
    print(f"metrics:    {prom}  (+ {snap})")
    print(f"flight log: {obs.events.path} ({obs.events.rows_written} rows)")
    print(f"trace:      {trace}  -> open in https://ui.perfetto.dev")


if __name__ == "__main__":
    main()
