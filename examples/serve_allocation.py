"""ConstraintSpec serving quickstart: declare axes, get one fused pass.

    PYTHONPATH=src python examples/serve_allocation.py [--geo|--combined]

Builds the small serving stack (cascade + reward model, cached under
results/cache), then streams a day of traffic through the fused
score->decide->guard->execute pass.  Requests come from a
``RequestSource`` (``repro.data.request_source``): arrivals are
sampled from an UNBOUNDED hash-generated user universe
(``StreamingWorld``, --users large at no extra memory), each window's
user rows, stage scores and compact execution tables are produced on
the fly as a ``WindowChunk``, and the pipeline gathers within the
chunk - no (U, J) matrix, no per-user precomputation, host memory
O(window).  ``--materialized`` switches back to indexing the small
precomputed eval universe (the legacy front door; bitwise-equivalent
serving is covered by tests/test_request_source.py).

The pipeline itself is built from a declarative ``ConstraintSpec`` -
the operator declares WHAT is budgeted and the spec compiles onto the
multi-price allocator core:

  default     [TenantAxis(budgets, priced=True)]
              four tenants with very different budgets share one jitted
              window pass, each tenant's dual price descending on its
              own consumption-vs-budget subgradient while the
              per-constraint tail-reserve guard hard-caps each block;

  --geo       [RegionAxis(2, split="flow"), GlobalAxis(pricing="carbon")]
              the two-region geo-shifting router (region CI days 8 h
              apart, per-region gram budgets, requests choosing their
              serving region through the priced argmax; degenerate ties
              rounded by the exact flow split);

  --combined  [TenantAxis(priced=True), RegionAxis(2),
               GlobalAxis(pricing="carbon")]
              BOTH axes in one pipeline: per-tenant gram budgets AND
              per-region gram caps priced together - a tenant-t request
              pays (lam_tenant[t] + lam_region[r]) * c_{j,r}, and the
              per-(tenant, region) spend comes back in
              ``WindowResult.tr_spend``.

Migrating from the legacy keyword constructor (every combination maps
to a spec, bit-identically - see ``serving/spec.py`` for the table):

    ServingPipeline(..., budget)                 -> [GlobalAxis(budget)]
    ServingPipeline(..., tenant_budgets=tb)      -> [TenantAxis(tb)]
    ServingPipeline(..., tb, tenant_mode="priced")
                                        -> [TenantAxis(tb, priced=True)]
    ServingPipeline(..., n_regions=2)   -> [RegionAxis(2, "argmax"), ...]

(The old ``region_jitter`` knob is gone - removed in PR 7 after the
PR 5 deprecation; ``RegionAxis(split="flow")`` is its exact
replacement.)

The classic spike scenario of earlier revisions lives on as the
production driver: ``python -m repro.launch.serve --small``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--geo", action="store_true",
                    help="two-region geo router instead of tenants")
    ap.add_argument("--combined", action="store_true",
                    help="tenants x regions in ONE pipeline (the "
                         "ConstraintSpec headline)")
    ap.add_argument("--users", type=int, default=100_000,
                    help="streamed user-universe size (costs nothing: "
                         "users materialize per window, on demand)")
    ap.add_argument("--materialized", action="store_true",
                    help="index the precomputed eval universe instead "
                         "of streaming a generated one")
    args = ap.parse_args()

    from repro.experiments import build_serving_stack, serve_config
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)
    from repro.serving.stream import (TrafficScenario, run_stream,
                                      scenario_windows)

    print("[example] building the small serving stack ...")
    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=True), verbose=True)
    chains = exp.chains

    if args.materialized:  # legacy front door: sample the eval tables
        rng = np.random.default_rng(0)
        n_eval = exp.ctx_eval.shape[0]

        def sample_window(t, n):
            rows = rng.integers(0, n_eval, n)
            return exp.ctx_eval[rows], rows
    else:
        from dataclasses import replace

        from repro.data.request_source import GeneratedSource
        from repro.data.synthetic import StreamingWorld

        world = StreamingWorld.build(
            replace(exp.cfg.world, n_users=args.users))
        source = GeneratedSource(world, exp.models, chains,
                                 expose=exp.cfg.expose)
        print(f"[example] streaming source over U={args.users:,} "
              f"hash-generated users (windows scored on the fly)")
        # the pipeline builds over the layout-only universe; run_stream
        # pulls WindowChunks straight from the source
        server = source.universe
        sample_window = source

    if args.geo or args.combined:
        from repro.carbon.controller import grams_per_flop
        from repro.carbon.intensity import two_region_traces
        from repro.carbon.ledger import DAY_S
        from repro.core.primal_dual import DualDescentConfig

        n_req = 96
        flops_budget = 0.5 * chains.costs.max() * n_req
        scenario = "geotenants" if args.combined else "georegions"
        t_n = 3 if args.combined else 1
        sizes = scenario_windows(TrafficScenario(
            scenario, args.windows, n_req, n_tenants=t_n))
        traces = two_region_traces(mean=450.0, offset_h=8.0)
        kpf = grams_per_flop(1.0)
        window_s = DAY_S / len(sizes)
        ci = np.stack([traces[r].resample(len(sizes), window_s)
                       for r in traces], axis=1)
        g_total = 0.5 * flops_budget * kpf * 450.0 * 2  # day reference
        dual_cfg = DualDescentConfig(max_iters=300, step_decay=0.98)

        if args.combined:
            # tenant 0 tight, tenant 2 loose; regions capped at 60%
            w = np.array([0.6, 1.0, 1.4])
            tenant_g = g_total * w / w.sum()
            region_g = np.full(2, 0.6 * g_total)
            spec = ConstraintSpec([
                TenantAxis(tuple(tenant_g / (kpf * 450.0)),
                           priced=True),
                RegionAxis(2, names=tuple(traces), split="flow"),
                GlobalAxis(pricing="carbon"),
            ])
            pipe = ServingPipeline.from_spec(server, params, rcfg, spec,
                                             dual_cfg=dual_cfg)
            budget_trace = np.tile(
                np.concatenate([tenant_g, region_g]), (len(sizes), 1))
            st = run_stream(pipe, sizes, sample_window,
                            budget_trace=budget_trace,
                            scale_trace=kpf * ci, forecast=True)
            print(f"\n{'win':>4} {'ci_a':>6} {'ci_b':>6} "
                  f"{'split a/b':>10} "
                  + " ".join(f"{'t' + str(k) + ' s/b':>8}"
                             for k in range(3)) + f" {'revenue':>9}")
            for t, r in enumerate(st.windows):
                split = np.bincount(r.regions_np, minlength=2)
                tr = np.asarray(r.tr_spend)
                cols = " ".join(f"{tr[k].sum() / tenant_g[k]:>8.3f}"
                                for k in range(3))
                print(f"{t:>4} {ci[t, 0]:>6.0f} {ci[t, 1]:>6.0f} "
                      f"{split[0]:>4d}/{split[1]:<4d} {cols} "
                      f"{r.revenue_np.sum():>9.1f}")
            lam = np.asarray(pipe.lam)
            print(f"[example] final prices: tenants "
                  + "/".join(f"{v:.2e}" for v in lam[:3])
                  + "  regions " + "/".join(f"{v:.2e}"
                                            for v in lam[3:]))
            print(f"[example] combined day done: "
                  f"{st.total_revenue:.1f} clicks, "
                  f"{len(sizes) / st.wall_s:.1f} win/s - one fused "
                  f"pass, K=5 dual prices over tenants x regions.")
            return 0

        spec = ConstraintSpec([
            RegionAxis(2, names=tuple(traces), split="flow"),
            GlobalAxis(budget=float(flops_budget), pricing="carbon"),
        ])
        pipe = ServingPipeline.from_spec(server, params, rcfg, spec,
                                         dual_cfg=dual_cfg)
        grams = np.full((len(sizes), 2),
                        0.5 * flops_budget * kpf * 450.0)
        st = run_stream(pipe, sizes, sample_window,
                        budget_trace=grams, scale_trace=kpf * ci,
                        forecast=True)
        print(f"\n{'win':>4} {'ci_a':>6} {'ci_b':>6} {'split a/b':>10} "
              f"{'revenue':>9}")
        for t, r in enumerate(st.windows):
            split = np.bincount(r.regions_np, minlength=2)
            print(f"{t:>4} {ci[t, 0]:>6.0f} {ci[t, 1]:>6.0f} "
                  f"{split[0]:>4d}/{split[1]:<4d} "
                  f"{r.revenue_np.sum():>9.1f}")
        print(f"[example] geo day done: {st.total_revenue:.1f} clicks, "
              f"{len(sizes) / st.wall_s:.1f} win/s")
        return 0

    # ---- per-tenant dual prices in one fused pass ----------------------
    t_n = 4
    per_tenant = 32
    n_req = t_n * per_tenant
    c_max = float(chains.costs.max())
    # tenant 0 is starved - its budget sits between the n*c_min serve
    # floor and its natural (price-zero) spend, so its OWN price must
    # rise while the slack tenants' prices stay at zero
    tenant_budgets = np.array([0.22, 0.4, 0.6, 1.0]) * c_max * per_tenant
    spec = ConstraintSpec([TenantAxis(tuple(tenant_budgets),
                                      priced=True)])
    pipe = ServingPipeline.from_spec(server, params, rcfg, spec)
    sizes = [n_req] * args.windows
    st = run_stream(pipe, sizes, sample_window)

    print(f"\n{'win':>4} " + " ".join(f"{'t' + str(k) + ' lam':>9}"
                                      for k in range(t_n))
          + "  " + " ".join(f"{'t' + str(k) + ' s/b':>8}"
                            for k in range(t_n)))
    for t, r in enumerate(st.windows):
        lam = np.asarray(r.lam_after)
        spends = np.asarray(r.tenant_spend)
        print(f"{t:>4} " + " ".join(f"{v:>9.2e}" for v in lam) + "  "
              + " ".join(f"{s / b:>8.3f}"
                         for s, b in zip(spends, tenant_budgets)))
    print(f"\n[example] {len(sizes)} windows, {st.total_revenue:.1f} "
          f"clicks, {len(sizes) / st.wall_s:.1f} win/s")
    print("[example] tighter tenants carry higher prices; every "
          "tenant's spend respects its own budget - one fused pass, "
          "K=4 dual prices, declared in one ConstraintSpec.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
