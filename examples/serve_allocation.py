"""Multi-price serving quickstart: per-tenant dual prices end to end.

    PYTHONPATH=src python examples/serve_allocation.py [--geo]

Builds the small serving world (cascade + reward model, cached under
results/cache), then streams a day of traffic through the fused
score->decide->guard->execute pass with PER-TENANT DUAL PRICES
(``ServingPipeline(tenant_budgets=..., tenant_mode="priced")``): four
tenants with very different budgets share one jitted window pass, each
tenant's price descending on its own consumption-vs-budget subgradient
while the per-constraint tail-reserve guard hard-caps each block.

``--geo`` runs the other face of the same multi-price core instead: the
two-region geo-shifting router (region CI days 8 h apart, per-region
gram budgets, requests choosing their serving region through the priced
argmax).

The classic spike scenario of earlier revisions lives on as the
production driver: ``python -m repro.launch.serve --small``.
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=8)
    ap.add_argument("--geo", action="store_true",
                    help="two-region geo router instead of tenants")
    args = ap.parse_args()

    from repro.experiments import build_serving_stack, serve_config
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.stream import (TrafficScenario, run_stream,
                                      scenario_windows)

    print("[example] building the small serving world ...")
    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=True), verbose=True)
    chains = exp.chains
    rng = np.random.default_rng(0)
    n_eval = exp.ctx_eval.shape[0]

    def sample_window(t, n):
        rows = rng.integers(0, n_eval, n)
        return exp.ctx_eval[rows], rows

    if args.geo:
        from repro.carbon.controller import grams_per_flop
        from repro.carbon.intensity import two_region_traces
        from repro.carbon.ledger import DAY_S
        from repro.core.primal_dual import DualDescentConfig

        n_req = 96
        flops_budget = 0.5 * chains.costs.max() * n_req
        sizes = scenario_windows(TrafficScenario(
            "georegions", args.windows, n_req))
        traces = two_region_traces(mean=450.0, offset_h=8.0)
        kpf = grams_per_flop(1.0)
        window_s = DAY_S / len(sizes)
        ci = np.stack([traces[r].resample(len(sizes), window_s)
                       for r in traces], axis=1)
        pipe = ServingPipeline(
            server, params, rcfg, float(flops_budget), n_regions=2,
            region_jitter=0.2,
            dual_cfg=DualDescentConfig(max_iters=300, step_decay=0.98))
        grams = np.full((len(sizes), 2),
                        0.5 * flops_budget * kpf * 450.0)
        st = run_stream(pipe, sizes, sample_window,
                        budget_trace=grams, scale_trace=kpf * ci,
                        forecast=True)
        print(f"\n{'win':>4} {'ci_a':>6} {'ci_b':>6} {'split a/b':>10} "
              f"{'revenue':>9}")
        for t, r in enumerate(st.windows):
            split = np.bincount(r.regions_np, minlength=2)
            print(f"{t:>4} {ci[t, 0]:>6.0f} {ci[t, 1]:>6.0f} "
                  f"{split[0]:>4d}/{split[1]:<4d} "
                  f"{r.revenue_np.sum():>9.1f}")
        print(f"[example] geo day done: {st.total_revenue:.1f} clicks, "
              f"{len(sizes) / st.wall_s:.1f} win/s")
        return 0

    # ---- per-tenant dual prices in one fused pass ----------------------
    t_n = 4
    per_tenant = 32
    n_req = t_n * per_tenant
    c_max = float(chains.costs.max())
    # tenant 0 is starved - its budget sits between the n*c_min serve
    # floor and its natural (price-zero) spend, so its OWN price must
    # rise while the slack tenants' prices stay at zero
    tenant_budgets = np.array([0.22, 0.4, 0.6, 1.0]) * c_max * per_tenant
    pipe = ServingPipeline(server, params, rcfg,
                           float(tenant_budgets.sum()),
                           tenant_budgets=tenant_budgets,
                           tenant_mode="priced")
    sizes = [n_req] * args.windows
    st = run_stream(pipe, sizes, sample_window)

    print(f"\n{'win':>4} " + " ".join(f"{'t' + str(k) + ' lam':>9}"
                                      for k in range(t_n))
          + "  " + " ".join(f"{'t' + str(k) + ' s/b':>8}"
                            for k in range(t_n)))
    for t, r in enumerate(st.windows):
        lam = np.asarray(r.lam_after)
        spends = np.asarray(r.tenant_spend)
        print(f"{t:>4} " + " ".join(f"{v:>9.2e}" for v in lam) + "  "
              + " ".join(f"{s / b:>8.3f}"
                         for s, b in zip(spends, tenant_budgets)))
    print(f"\n[example] {len(sizes)} windows, {st.total_revenue:.1f} "
          f"clicks, {len(sizes) / st.wall_s:.1f} win/s")
    print("[example] tighter tenants carry higher prices; every "
          "tenant's spend respects its own budget - one fused pass, "
          "K=4 dual prices.")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
