"""Online serving simulation with traffic spikes (paper Fig. 5).

    PYTHONPATH=src python examples/serve_allocation.py [--small]

Thin wrapper over the production driver ``repro.launch.serve`` - the
hybrid online/nearline allocator + cascade server + downgrade guard.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.launch.serve import main

if __name__ == "__main__":
    if "--small" not in sys.argv:
        sys.argv.append("--small")
    raise SystemExit(main())
