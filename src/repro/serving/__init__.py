"""Unified streaming serving runtime (paper §4.3-§5.3 online system).

A map of the unified allocator core and the layers over it:

  core.primal_dual        THE multi-price core: Eq. 10 ``allocate``,
      per-constraint ``consumption``, Algorithm 1 ``dual_descent``.
      One implementation spans every pricing shape - a scalar price
      (the paper, K=1, bit-identical), a (K,) price vector against an
      (M, K) option->constraint cost map (K over tenant x region), and
      per-request constraint membership.  ``window_step`` is the shared
      host-loop body the budget controllers wrap.
  serving.guard           the budget downgrade guard as a vectorized,
      jit-compatible pass: cumsum tail-reserve walk, mask-aware for
      padded windows, shardable over the request axis, and -
      via ``k_of`` - K per-constraint budgets at once (tenant blocks,
      serving regions), each constraint walking only its own requests.
  serving.pipeline        ``ServingPipeline``: reward scoring
      (model-prefix grouped), priced allocation, the fused guard,
      CompactPlan cascade execution and the nearline dual update in ONE
      jitted window pass.  Pricing modes: plain scalar; tenants
      "shared" (one price, per-tenant guard budgets); tenants "priced"
      ((T,) prices in the same pass); geo (``n_regions``: requests pick
      (chain, region) through the priced argmax with region costs
      flops_j * kappa * CI_r(t), per-region budgets + prices).  All
      modes compose with the ("req",) shard_map mesh and the padded
      window buckets, and support the CI-forecast dual warm-start
      (``dual_budget``/``dual_cost_scale``).
  serving.stream          double-buffered streaming driver (host
      prepares window t+1 while the device executes t) + traffic
      scenarios: constant, spike, diurnal, tenants, carbon and
      georegions; per-window budget/scale traces and
      ``forecast=True`` thread time-varying carbon constraints through
      the pipeline without recompiles.
  carbon.*                the gCO2e side: intensity traces, the
      CarbonBudget / CarbonBudgetController wrappers, and the
      CarbonLedger (operational + embodied metering, per-region
      attribution for geo serving).

``launch/serve.py`` is the CLI front end (--scenario ... --tenant-mode
shared|priced --shards N); ``benchmarks/bench_serve.py`` measures the
fused pass against the legacy loop (BENCH_serve.json),
``benchmarks/bench_carbon.py`` the carbon-aware allocator
(BENCH_carbon.json) and ``benchmarks/bench_geo.py`` the two-region
geo-shifting router (BENCH_geo.json).
"""
import importlib

from repro.serving.guard import downgrade_guard, downgrade_guard_np

_LAZY = {
    "ServingPipeline": "repro.serving.pipeline",
    "WindowResult": "repro.serving.pipeline",
    "StreamStats": "repro.serving.stream",
    "TrafficScenario": "repro.serving.stream",
    "SCENARIOS": "repro.serving.stream",
    "run_stream": "repro.serving.stream",
    "scenario_windows": "repro.serving.stream",
}

__all__ = ["downgrade_guard", "downgrade_guard_np", *_LAZY]


def __getattr__(name):  # PEP 562: keep core.budget's import chain light
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
