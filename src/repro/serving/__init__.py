"""Unified streaming serving runtime (paper §4.3-§5.3 online system).

A map of the unified allocator core and the layers over it:

  serving.spec            the DECLARATIVE front door: a
      ``ConstraintSpec`` is a list of constraint axes -
      ``TenantAxis(budgets, priced=...)``, ``RegionAxis(n, split=...)``,
      ``GlobalAxis(budget, pricing="flops"|"carbon")`` - that compiles
      onto the multi-price core's (M, K) cost map, (I, K) membership,
      (K,) budget/price vectors and per-K guard ``k_of``, with K the
      concatenation of the declared axes (priced tenant prices first,
      region prices after).  ``spec_from_legacy`` maps every historical
      flag combination to its spec, bit-identically.
  core.primal_dual        THE multi-price core: Eq. 10 ``allocate``,
      per-constraint ``consumption``, Algorithm 1 ``dual_descent``.
      One implementation spans every pricing shape - a scalar price
      (the paper, K=1, bit-identical), a (K,) price vector against an
      (M, K) option->constraint cost map (K over tenant x region), and
      per-request constraint membership.  ``window_step`` is the shared
      host-loop body the budget controllers wrap.
  serving.guard           the budget downgrade guard as a vectorized,
      jit-compatible pass: cumsum tail-reserve walk, mask-aware for
      padded windows, shardable over the request axis, and -
      via ``k_of`` - K per-constraint budgets at once (tenant blocks,
      serving regions), each constraint walking only its own requests.
      ``downgrade_guard_chain`` sequences several constraint FAMILIES
      (tenant budgets THEN region budgets) over one window.
  data.request_source     where REQUESTS come from.  A ``RequestSource``
      produces each window on demand as a ``WindowChunk`` - sampled
      arrivals, reward contexts, LOCAL rows and a per-window (G, n,
      cap) slice of compact execution tables - so host memory scales
      with the window, never the universe.  ``GeneratedSource`` streams
      an unbounded hash-generated user world
      (``data.synthetic.StreamingWorld``, U >= 100k); a
      ``TableReplaySource`` replays fixed precomputed tables (in
      memory or memmapped ``.npy``), bitwise identical to indexing the
      materialized ``CascadeServer`` it was built from.
      ``source.universe`` is the layout-only server handle a streaming
      pipeline is constructed over.  With ``device_tables`` (default
      for generated and in-memory replay sources) chunk tables live
      ON DEVICE end-to-end: stage scores never cross to host, the
      compaction runs as a jitted pass bitwise equal to the host
      builder, replay windows gather device-resident tables, and a
      slab-keyed LRU cache skips rescoring repeat-visitor chunks.
  serving.pipeline        ``ServingPipeline.from_spec``: reward scoring
      (model-prefix grouped), priced allocation, the fused guard,
      CompactPlan cascade execution and the nearline dual update in ONE
      jitted window pass, for ANY compiled spec: plain scalar; tenants
      shared/priced; geo regions; and the combined tenant x region
      system (a (T + R,) price vector where a tenant-t request pays
      (lam_tenant[t] + lam_region[r]) * c_{j,r}, per-(tenant, region)
      spends in ``WindowResult.tr_spend``).  Tables are a TRACED
      argument: ``serve_window(..., tables=chunk.tables)`` gathers
      within a RequestSource chunk instead of a materialized user
      axis.  Per-window budgets/scales take positional vectors or
      NAMED dicts keyed by ``spec.compile().budget_names`` /
      ``scale_names``.  Degenerate region ties are rounded by the
      exact flow split (``RegionAxis(split="flow")``).  All modes
      compose with the
      ("req",) shard_map mesh, bucketed window padding (``bucketing=
      "linear"|"pow2"``; pow2 keeps the compiled-shape count
      logarithmic under traffic swings) and the CI-forecast dual
      warm-start (``dual_budget``/``dual_cost_scale``).
      ``WindowResult.compiles``/``bucket`` surface per-window jit
      cache misses - zero in steady state, by construction -
      alongside ``h2d_bytes``/``prep_ms``/``stall_ms``.  The nearline
      dual chain runs through donated jits (``donate_dual``, default
      on): steady-state windows update the price allocation-free,
      with readable record copies in ``lam_before``/``lam_after``.
      The legacy keyword constructor survives as a thin shim over
      ``spec_from_legacy``.
  serving.stream          prefetching streaming driver: ``run_stream
      (..., prefetch=N)`` moves chunk production to one background
      worker feeding a bounded queue (windows in strict t order, so
      bitwise identical to ``prefetch=0`` - the sequential
      double-buffered reference), records per-window ``stall_ms``,
      and splits the old dispatch time into ``prep_ms`` +
      ``submit_ms`` (``dispatch_ms`` survives as their sum) + the
      ``SCENARIOS`` registry - ONE dict of per-window-size builders
      (constant, spike, diurnal, tenants, carbon, georegions,
      geotenants, swing) from which the valid-names error and the
      ``launch/serve.py --scenario`` choices both derive; per-window
      budget/scale traces and ``forecast=True`` thread time-varying
      carbon constraints through the pipeline without recompiles;
      ``StreamStats.steady_compiles`` audits the zero-recompile
      guarantee and ``StreamStats.h2d_bytes`` the transfer budget
      over a finished run.
  distributed.multihost   the MULTI-HOST request mesh over all of the
      above: ``initialize()`` brings up ``jax.distributed`` from
      ``GREENFLOW_COORDINATOR`` / ``_NUM_PROCESSES`` / ``_PROCESS_ID``
      (gloo CPU collectives configured first), after which
      ``launch.mesh.make_request_mesh()`` spans every process and the
      SAME fused pipeline runs unchanged - its guard prefix sums,
      per-axis spends and nearline dual updates stitch globally
      through order-fixed all_gather reductions
      (``distributed.sharding.ordered_psum``), so every host agrees
      BITWISE on lambda and every decision.  Windows are never
      shipped: arrivals are pure (seed, t) functions every host
      evaluates, ``pipeline.window_layout`` is the canonical padded
      layout all hosts derive from (n, bucket) alone, and
      ``MultihostSource`` wraps any RequestSource to materialize ONLY
      this host's ``launch.mesh.process_shard_rows`` slice of each
      window (``WindowChunk.shard`` carries the slice geometry into
      ``serve_window``).  Elastic re-sharding is reshard-on-restore:
      ``checkpoint_stream`` persists the tiny {cursor, dual chain,
      seed} state, a DIFFERENT-sized group restores it
      (``restore_stream`` + ``ShiftedSource``) and replays from the
      in-flight window bitwise - the fixed GLOBAL shard count (pad
      quantum lcm's ``mesh_num_shards``) makes the numerics
      process-count-invariant.  Per-host flight-recorder labels
      (``Obs(host=...)``) tag JSONL events and name Perfetto track
      groups; ``merge_chrome_traces`` folds every host's trace into
      one timeline.  ``launch/serve.py --processes/--process-id/
      --coordinator`` is the CLI bring-up (runbook in its module
      docstring); tests/test_multihost.py pins the parity, stitching
      and elastic gates with real subprocess meshes.
  carbon.*                the gCO2e side: intensity traces, the
      CarbonBudget / CarbonBudgetController wrappers (both
      spec-buildable via ``from_spec``), and the CarbonLedger
      (operational + embodied metering, per-region attribution for
      geo serving).
  obs (repro.obs)         the FLIGHT RECORDER over all of the above:
      a pure-Python metrics registry (counters / gauges / fixed-
      bucket log2 histograms under the ``greenflow_*`` namespace -
      see ``repro/obs/__init__.py`` for the full metric table), span
      tracing (``prep``/``stall``/``serve``/``h2d``/``dispatch``/
      ``dual_update``/``chunk_tables``/``ledger``/
      ``block_until_ready``) exported as Chrome trace-event JSON
      (ui.perfetto.dev; the chunk-prefetch worker and the serving
      thread render as separate tracks), and a per-window JSONL
      event log (size, bucket, per-axis lambda / spend vs budget by
      ``CompiledSpec.k_names``, FLOPs, gCO2e, h2d bytes, prep /
      stall / submit ms, recompiles).  Pass an ``Obs`` into
      ``run_stream`` / ``ServingPipeline`` / ``GeneratedSource`` /
      ``CarbonLedger`` (CLI: ``--metrics-out``, ``--trace-out``,
      ``--obs-interval``, ``--profile-dir``).  Two invariants, both
      pinned by tests/test_obs.py and bench_scale gates: telemetry
      on vs off is BITWISE identical (device arrays are only read in
      the post-drain flush), and disabled telemetry is free (shared
      no-op singletons, zero allocations on the window hot path).
      ``run_stream(..., clock=...)`` injects the timing clock so
      tests pin prep/stall/submit attribution deterministically.

Invariants (enforced at lint time by ``repro.analysis`` -- the
greenflow-check suite; ``python -m repro.analysis src`` and the CI
static-analysis job reject violations, ``--jaxpr-audit`` re-checks the
lowered fused pass):

  GF001  ordered collectives.  Serving/distributed code never calls raw
      ``lax.psum``: backend ring/tree reduction order varies with
      topology, and float addition is not associative.  Cross-host
      stitching goes through ``distributed.sharding.ordered_psum``
      (all_gather + local sum over the fixed shard axis) -- the bitwise
      decision/lambda parity the PR 9 mesh guarantees.
  GF002  no hidden host syncs.  The hot-path modules (pipeline, stream,
      guard, engine, request_source) keep ``.item()`` /
      ``jax.device_get`` / host numpy out of the window path: the
      prefetch overlap (PR 7) and the telemetry-off bitwise guarantee
      (PR 8) both assume device arrays are only read post-drain.
  GF003  no ``jnp.mean`` in dual-price arithmetic.  XLA strength-
      reduces mean to sum*(1/n) and reassociates the divisor chain;
      PR 4's scalar-vs-vectorized K=1 bit-parity broke exactly this
      way.  Dual norms structure their divisors explicitly (the two
      sanctioned reference expressions carry justified pragmas).
  GF004  jit hygiene.  ``static_argnames`` must name real parameters
      (a typo is silently ignored and retraces per value -- PR 2), and
      a buffer passed at a ``donate_argnums`` position is never read
      afterwards (the dual chain rebinds, with ``_lam_rec`` as the
      readable bitwise copy -- PR 7/9).
  GF005  pure windows.  Window-producing code is a function of
      (seed, t): no wall clocks (timing is injected via ``run_stream
      (clock=...)``, PR 8) and no global RNG (every host must derive
      identical arrivals, PR 9).
  GF006  signed-zero canonicalization uses ``jnp.where``, never
      ``+ 0.0`` -- XLA folds the add and -0.0 leaks into the monotone
      float-bit sort keys (PR 7's device compactor).

``launch/serve.py`` is the CLI front end (--scenario ... --source
table|generated|memmap --tenant-mode shared|priced --geo-split
flow|argmax --shards N); benchmarks: ``bench_serve.py`` (fused pass vs
legacy loop, BENCH_serve.json), ``bench_carbon.py`` (carbon-aware
allocator, BENCH_carbon.json), ``bench_geo.py`` (two-region router,
BENCH_geo.json), ``bench_geotenants.py`` (the combined tenant x region
spec vs the single-axis arms + the exact-dual pipeline gate,
BENCH_geotenants.json) and ``bench_scale.py`` (the streamed geotenants
pipeline at U >= 100k under 10x-1000x swings: requests/sec, p99 window
latency, flat peak RSS w.r.t. U and zero steady-state recompiles,
BENCH_scale.json); ``bench_multihost.py`` (1/2/4/8-process mesh sweep
at a fixed 8-shard global layout: per-process + aggregate req/s,
bitwise decision parity vs single-process, merged per-host Perfetto
trace, hardware-gated scaling assertion, BENCH_multihost.json) and
``bench_truncate.py`` (the Pallas cascade-truncation kernel vs the XLA
baseline at production batch sizes, BENCH_truncate.json).
"""
import importlib

from repro.serving.guard import (downgrade_guard, downgrade_guard_chain,
                                 downgrade_guard_np)
from repro.serving.spec import (ConstraintSpec, GlobalAxis, RegionAxis,
                                TenantAxis, spec_from_legacy)

_LAZY = {
    "ServingPipeline": "repro.serving.pipeline",
    "WindowResult": "repro.serving.pipeline",
    "StreamStats": "repro.serving.stream",
    "TrafficScenario": "repro.serving.stream",
    "SCENARIOS": "repro.serving.stream",
    "run_stream": "repro.serving.stream",
    "scenario_windows": "repro.serving.stream",
}

__all__ = ["downgrade_guard", "downgrade_guard_chain",
           "downgrade_guard_np", "ConstraintSpec", "TenantAxis",
           "RegionAxis", "GlobalAxis", "spec_from_legacy", *_LAZY]


def __getattr__(name):  # PEP 562: keep core.budget's import chain light
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
