"""Unified streaming serving runtime (paper §4.3-§5.3 online system).

The seed's serving path was a host-side Python loop that crossed the
host/device boundary four times per window: jitted reward scoring, a
jitted Eq. 10 argmax, a multi-pass NumPy downgrade guard, and a jitted
cascade-execution kernel, with jnp<->np conversions between every step.
This package refactors those four layers into ONE pipeline:

  * ``guard``     - the budget downgrade guard as a vectorized,
    jit-compatible pass (cumsum formulation of the tail-reserve rule,
    mask-aware for padded windows, shardable over the request axis);
  * ``pipeline``  - ``ServingPipeline``: reward scoring (model-prefix
    grouped), Eq. 10 allocation, the fused guard, cascade execution on
    compaction tables, and the nearline dual update, all inside a single
    jitted per-window pass; optionally ``shard_map``-ped over a request
    mesh axis with uneven-window padding so traffic spikes never
    recompile;
  * ``stream``    - a double-buffered streaming driver (host prepares
    window t+1 while the device executes window t) plus pluggable
    traffic scenarios: constant, spike, diurnal sinusoid, multi-tenant
    (per-tenant budgets sharing one dual price vs. independent
    controllers), and carbon (diurnal traffic priced against a grid
    intensity trace via per-window budget/cost-scale traces - see
    ``repro.carbon``).

``launch/serve.py`` is the CLI front end; ``benchmarks/bench_serve.py``
measures the fused pass against the legacy loop (BENCH_serve.json).
"""
import importlib

from repro.serving.guard import downgrade_guard, downgrade_guard_np

_LAZY = {
    "ServingPipeline": "repro.serving.pipeline",
    "WindowResult": "repro.serving.pipeline",
    "StreamStats": "repro.serving.stream",
    "TrafficScenario": "repro.serving.stream",
    "SCENARIOS": "repro.serving.stream",
    "run_stream": "repro.serving.stream",
    "scenario_windows": "repro.serving.stream",
}

__all__ = ["downgrade_guard", "downgrade_guard_np", *_LAZY]


def __getattr__(name):  # PEP 562: keep core.budget's import chain light
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
