"""Declarative ConstraintSpec API: tenants x regions x carbon, one pipeline.

GreenFlow's allocation core (``core.primal_dual``) prices K >= 1
constraints at once, but the serving surface historically exposed it as
mutually exclusive flags (``tenant_budgets``/``tenant_mode`` XOR
``n_regions``, carbon-vs-flops pricing picked by which trace the driver
threads).  This module replaces that sprawl with a first-class spec: an
operator DECLARES the constraint axes and the spec COMPILES them onto
the core's structures -

    ConstraintSpec([
        TenantAxis(budgets=(g0, g1, g2), priced=True),
        RegionAxis(n_regions=2),
        GlobalAxis(pricing="carbon"),
    ])

compiles to the ``(M, K)`` option->constraint cost map, the ``(I, K)``
per-request membership, the ``(K,)`` budget/price vectors and the per-K
guard ``k_of`` that ``ServingPipeline.from_spec`` runs in ONE fused,
shardable window pass.  K is the CONCATENATION of the declared axes'
price components:

    axes declared            priced K          guard constraints
    -----------------------  ----------------  ------------------------
    GlobalAxis               scalar (paper)    1 global budget
    TenantAxis(shared)       scalar            T tenant budgets
    TenantAxis(priced)       T                 T tenant budgets
    RegionAxis               R                 R region budgets
    TenantAxis(priced)+      T + R             T tenant + R region
      RegionAxis                                 budgets (two chained
                                                 tail-reserve walks)

With both axes the option space is M = J * R (chain x serving region,
region-major: option m = r*J + j) and a request of tenant t pays
``(lam_tenant[t] + lam_region[r]) * c_{j,r}(t)`` for option (j, r) -
per-tenant fairness prices and per-region carbon prices COMPOSED in one
Eq. 10 argmax.  ``c_{j,r}(t) = flops_j * scale_r(t)`` rides through the
per-window ``cost_scale`` trace exactly as in the single-axis modes
(carbon: scale_r = kappa * CI_r(t)), so carbon is a choice of units,
never a separate wiring.

Migration from the legacy ``ServingPipeline`` kwargs (every combination
maps to a spec, bit-identically - ``spec_from_legacy`` is the shim the
legacy constructor runs):

    legacy kwargs                          ConstraintSpec axes
    -------------------------------------  ---------------------------
    budget_per_window=B                    [GlobalAxis(budget=B)]
    tenant_budgets=tb                      [TenantAxis(tb)]
      (tenant_mode="shared")
    tenant_budgets=tb,                     [TenantAxis(tb, priced=True)]
      tenant_mode="priced"
    n_regions=R                            [RegionAxis(R,
                                              split="argmax"),
                                            GlobalAxis(budget=B)]
    (carbon pricing)                       any of the above +
                                           GlobalAxis(pricing="carbon");
                                           grams/scales still ride the
                                           per-window traces

Region tie handling (``RegionAxis.split``): the two-region cost
structure is proportional (c_{j,r} = s_r * flops_j), so at the dual
equilibrium every request is indifferent between regions at once and a
pure argmax bang-bangs whole windows.  ``split="flow"`` (the default)
resolves the degenerate window EXACTLY: requests whose per-flop priced
costs tie across regions are divided deterministically in arrival
order, each tied region receiving a share of the window's FLOPs mass
proportional to its remaining budget capacity - the flow-splitting
primal rounding of the fractional LP optimum.  ``split="argmax"`` keeps
the historical pure argmax (bit-identical to the pre-spec pipeline;
the legacy shim maps ``n_regions`` here).  The pre-spec
``region_jitter`` eps-distortion is GONE (deprecated in PR 5, removed
in PR 7): ``split="flow"`` is its exact replacement.
"""
from __future__ import annotations

from dataclasses import dataclass, field


VALID_SPLITS = ("flow", "argmax")
VALID_PRICINGS = ("flops", "carbon")


@dataclass(frozen=True)
class TenantAxis:
    """T per-tenant budgets; windows carry T equal-size tenant blocks.

    ``priced=False`` ("shared"): one dual price descends on the TOTAL
    budget while the guard hard-caps each tenant's block.
    ``priced=True``: a (T,) per-tenant price vector inside the fused
    pass, each price descending on its own consumption-vs-budget
    subgradient.
    """

    budgets: tuple[float, ...]
    priced: bool = False

    def __post_init__(self):
        budgets = tuple(float(b) for b in self.budgets)
        object.__setattr__(self, "budgets", budgets)
        if len(budgets) < 1:
            raise ValueError("TenantAxis needs at least one budget")
        if any(b <= 0 for b in budgets):
            raise ValueError(f"tenant budgets must be positive, "
                             f"got {budgets}")

    @property
    def n(self) -> int:
        return len(self.budgets)


@dataclass(frozen=True)
class RegionAxis:
    """R serving regions: each request picks (chain, region) through the
    priced argmax at region costs c_{j,r}(t) = flops_j * scale_r(t).

    Per-region budgets and cost scales ride the per-window
    ``serve_window(budget=..., cost_scale=...)`` traces (they are
    time-varying by nature - grid intensity).  ``split`` selects the
    degenerate-tie rounding (see module docstring); ``tie_tol`` is the
    relative per-flop price band treated as tied.
    """

    n_regions: int = 2
    names: tuple[str, ...] | None = None
    split: str = "flow"
    tie_tol: float = 0.05

    def __post_init__(self):
        if self.n_regions < 2:
            raise ValueError("RegionAxis needs >= 2 serving regions")
        if self.split not in VALID_SPLITS:
            raise ValueError(f"split must be one of {VALID_SPLITS}, "
                             f"got {self.split!r}")
        if not 0.0 <= self.tie_tol < 1.0:
            raise ValueError(f"tie_tol must be in [0, 1), "
                             f"got {self.tie_tol}")
        if self.names is not None and len(self.names) != self.n_regions:
            raise ValueError(f"{len(self.names)} names for "
                             f"{self.n_regions} regions")

    @property
    def n(self) -> int:
        return int(self.n_regions)


@dataclass(frozen=True)
class GlobalAxis:
    """The paper's single budget (Eq. 3) and the pricing denomination.

    ``budget`` is the per-window reference budget (REQUIRED when no
    TenantAxis carries budgets; with tenants it defaults to their sum).
    ``pricing`` declares the cost units the serve driver threads through
    the traces - "flops" (scale 1.0) or "carbon" (scale kappa*CI(t),
    budgets in gCO2e).  The pipeline itself is unit-agnostic; drivers
    (``launch/serve.py``, benchmarks) read this field to build the
    matching budget/scale traces.
    """

    budget: float | None = None
    pricing: str = "flops"

    def __post_init__(self):
        if self.pricing not in VALID_PRICINGS:
            raise ValueError(f"pricing must be one of {VALID_PRICINGS}, "
                             f"got {self.pricing!r}")
        if self.budget is not None and self.budget <= 0:
            raise ValueError(f"budget must be positive, "
                             f"got {self.budget}")


@dataclass(frozen=True)
class ConstraintSpec:
    """An ordered set of constraint axes; ``compile()`` resolves them
    into the normalized description the pipeline executes."""

    axes: tuple

    def __init__(self, axes):
        object.__setattr__(self, "axes", tuple(axes))

    def compile(self) -> "CompiledSpec":
        tenants = regions = global_ = None
        for ax in self.axes:
            if isinstance(ax, TenantAxis):
                if tenants is not None:
                    raise ValueError("duplicate TenantAxis")
                tenants = ax
            elif isinstance(ax, RegionAxis):
                if regions is not None:
                    raise ValueError("duplicate RegionAxis")
                regions = ax
            elif isinstance(ax, GlobalAxis):
                if global_ is not None:
                    raise ValueError("duplicate GlobalAxis")
                global_ = ax
            else:
                raise TypeError(f"unknown constraint axis {ax!r} (want "
                                f"TenantAxis | RegionAxis | GlobalAxis)")
        if tenants is None and (global_ is None or global_.budget is None):
            raise ValueError("a ConstraintSpec needs a budget source: "
                             "GlobalAxis(budget=...) or TenantAxis")
        return CompiledSpec(spec=self, tenants=tenants, regions=regions,
                            global_=global_ or GlobalAxis())


@dataclass(frozen=True)
class CompiledSpec:
    """The resolved constraint structure ``ServingPipeline`` executes.

    ``k_names`` orders the priced constraints exactly as the (K,) price
    vector, the (K,) budget vector and the dual cost-map columns:
    tenant columns first (priced tenants), region columns after.
    ``n_prices == 0`` means the scalar (paper) price.
    """

    spec: ConstraintSpec
    tenants: TenantAxis | None
    regions: RegionAxis | None
    global_: GlobalAxis = field(default_factory=GlobalAxis)

    # -- shape of the compiled constraint system ---------------------------

    @property
    def t_n(self) -> int | None:
        return None if self.tenants is None else self.tenants.n

    @property
    def r_n(self) -> int | None:
        return None if self.regions is None else self.regions.n

    @property
    def tenant_priced(self) -> bool:
        return self.tenants is not None and self.tenants.priced

    @property
    def mode(self) -> str:
        """Which fused-pass branch runs: plain|tenants|geo|geotenants."""
        if self.tenants is not None and self.regions is not None:
            return "geotenants"
        if self.regions is not None:
            return "geo"
        if self.tenants is not None:
            return "tenants"
        return "plain"

    @property
    def n_prices(self) -> int:
        """Length of the (K,) price vector; 0 = scalar price."""
        k = 0
        if self.tenant_priced:
            k += self.tenants.n
        if self.regions is not None:
            k += self.regions.n
        return k

    @property
    def k_names(self) -> tuple[str, ...]:
        names = []
        if self.tenant_priced:
            names += [f"tenant[{t}]" for t in range(self.tenants.n)]
        if self.regions is not None:
            r_names = self.regions.names or tuple(
                f"region[{r}]" for r in range(self.regions.n))
            names += list(r_names)
        return tuple(names)

    @property
    def total_budget(self) -> float:
        if self.global_.budget is not None:
            return float(self.global_.budget)
        return float(sum(self.tenants.budgets))

    @property
    def pricing(self) -> str:
        return self.global_.pricing

    @property
    def split(self) -> str:
        return "argmax" if self.regions is None else self.regions.split

    @property
    def tie_tol(self) -> float:
        return 0.0 if self.regions is None else float(self.regions.tie_tol)

    def budget_len(self) -> int:
        """Entries of a per-window ``budget`` vector: tenant grams first,
        region grams after (1 for the plain/scalar modes)."""
        if self.mode == "geotenants":
            return self.tenants.n + self.regions.n
        if self.mode == "geo":
            return self.regions.n
        if self.mode == "tenants":
            return self.tenants.n
        return 1

    def _region_names(self) -> list[str]:
        r_names = self.regions.names or tuple(
            f"region[{r}]" for r in range((self.regions.n)))
        return list(r_names)

    @property
    def budget_names(self) -> tuple[str, ...]:
        """Axis names of the per-window ``budget`` vector, in positional
        order (the NAMED serve_window form keys a dict by these).  Equal
        to ``k_names`` in the fully priced modes; a superset when
        tenants share one price (every tenant still has a budget entry
        even though none has its own price); ``("global",)`` for the
        plain scalar mode."""
        if self.mode == "geotenants":
            return tuple([f"tenant[{t}]" for t in range(self.tenants.n)]
                         + self._region_names())
        if self.mode == "geo":
            return tuple(self._region_names())
        if self.mode == "tenants":
            return tuple(f"tenant[{t}]" for t in range(self.tenants.n))
        return ("global",)

    @property
    def scale_names(self) -> tuple[str, ...]:
        """Axis names of the per-window ``cost_scale`` vector (regions
        carry per-region carbon intensities; every other mode scales all
        costs by one scalar)."""
        if self.regions is not None:
            return tuple(self._region_names())
        return ("global",)

    # -- core-structure builders (jnp, trace-time) -------------------------
    # These run INSIDE the jitted window pass; they emit exactly the ops
    # the pre-spec pipeline emitted for the single-axis modes, so the
    # compiled spec stays bit-identical to the legacy flag paths.

    def tenant_member(self, k_of):
        """(I,) tenant index -> (I, T) one-hot membership."""
        import jax.numpy as jnp
        return (k_of[:, None] == jnp.arange(self.tenants.n)[None, :]
                ).astype(jnp.float32)

    def region_cost_map(self, opt_costs, j_n: int):
        """(M,) region-major option costs -> (M, R) cost map: option
        m = r*J + j draws c_{j,r} from region column r only."""
        import jax.numpy as jnp
        eye = jnp.eye(self.regions.n, dtype=jnp.float32)
        return opt_costs[:, None] * jnp.repeat(eye, j_n, axis=0)

    def dual_cost_map(self, opt_costs, j_n: int):
        """The full (M, K) dual cost map in ``k_names`` order: priced
        tenant columns draw a request's grams wherever it is served
        (the full option cost), region columns only from their own
        region's options."""
        import jax.numpy as jnp
        cols = []
        if self.tenant_priced:
            cols.append(jnp.broadcast_to(
                opt_costs[:, None],
                (opt_costs.shape[0], self.tenants.n)))
        if self.regions is not None:
            cols.append(self.region_cost_map(opt_costs, j_n))
        if not cols:
            return opt_costs[:, None]
        return cols[0] if len(cols) == 1 else jnp.concatenate(cols, axis=1)

    def dual_member(self, k_of, n_rows: int):
        """The (I, K) dual membership in ``k_names`` order: tenant
        one-hots, all-ones region columns (every request may be served
        in any region; the cost map zeroes the off-region draw).
        ``None`` when the membership is trivial."""
        import jax.numpy as jnp
        if self.mode != "geotenants" or not self.tenant_priced:
            return None
        return jnp.concatenate(
            [self.tenant_member(k_of),
             jnp.ones((n_rows, self.regions.n), jnp.float32)], axis=1)


def spec_from_legacy(budget_per_window: float, *, tenant_budgets=None,
                     tenant_mode: str = "shared",
                     n_regions: int | None = None) -> ConstraintSpec:
    """The legacy ``ServingPipeline`` kwargs -> their ConstraintSpec.

    Every historical flag combination maps to a spec whose compiled
    pipeline is bit-identical to the pre-spec code path (the parity
    gates in tests/test_spec.py).  The pre-spec ``region_jitter`` knob
    was removed in PR 7 (two PRs after deprecation); its exact
    replacement is ``RegionAxis(split="flow")``.
    """
    if tenant_mode not in ("shared", "priced"):
        raise ValueError(f"tenant_mode must be 'shared' or 'priced', "
                         f"got {tenant_mode!r}")
    axes = []
    if tenant_budgets is not None:
        axes.append(TenantAxis(tuple(float(b) for b in tenant_budgets),
                               priced=tenant_mode == "priced"))
    if n_regions is not None:
        axes.append(RegionAxis(int(n_regions), split="argmax"))
    axes.append(GlobalAxis(budget=float(budget_per_window)))
    return ConstraintSpec(axes)
