"""Budget downgrade guard: tail-reserve rule, loop-free and jittable.

The guard (paper: "computation downgrade") keeps realized spend within
the window budget even when the dual price lags a traffic spike.  The
rule: walking the window in arrival order, request i keeps its allocated
option only if

    spend_so_far(i) + c_{m(i)} + c_min * (#requests after i)  <=  B

i.e. its own cost plus a cheapest-option reservation for everyone behind
it still fits; otherwise it is forced onto the cheapest option.  This
guarantees spend <= B whenever n * c_min <= B, and spend <= n * c_min
otherwise (Eq. 3b serves every request exactly one chain).

Downgrading shifts later prefix sums DOWN, which can un-trip requests
that looked over-budget, so the rule is iterated; the first crossing
only ever moves up, and ``GUARD_PASSES`` passes converge (extra passes
are no-ops once no request is over).  Both implementations here run the
same pass structure:

  * ``downgrade_guard_np``  - NumPy float64, the legacy
    ``BudgetController`` semantics (extracted so the controller and the
    fused pipeline share one definition);
  * ``downgrade_guard``     - jnp float32, a fixed-pass cumsum
    formulation that traces under jit, supports a validity mask for
    padded windows, and shards over a request mesh axis (prefix/tail
    sums are stitched across shards with all_gather/psum).

``downgrade_guard`` enforces either ONE budget (scalar ``budget``, the
historical path, bit-identical) or K per-constraint budgets: ``k_of``
maps each request to its constraint (tenant, serving region, or
tenant x region), ``budget`` is (K,), and ``cheap`` is the per-constraint
downgrade option ((K,) - e.g. the cheapest chain *within a request's
serving region*) or a single shared option.  Each constraint runs the
tail-reserve walk over ITS OWN requests (per-k prefix sums), so a block
of tenant windows or a region-split geo window is guarded in one fused
call - including across request shards.

``downgraded`` counts requests whose FINAL decision differs from the
allocator's (the seed overwrote the counter each pass, under-reporting
multi-pass windows; requests already on the cheapest option are never
counted - nothing was downgraded about them).

``downgrade_guard_chain`` composes SEVERAL constraint families over one
window (a compiled ConstraintSpec with both a tenant and a region axis
guards T tenant budgets AND R region budgets): the walks run in
sequence, each family guarding the previous family's output.  The
composition is safe whenever each walk's downgrade option is no more
expensive than the decision it replaces - every later walk then only
LOWERS the spends the earlier walks already capped - which holds for
the tail-reserve rule by construction (requests are only ever moved to
a cheapest option).  ``downgraded`` counts requests whose decision
after the LAST walk differs from the allocator's, once.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import ordered_psum

GUARD_PASSES = 4


def downgrade_guard_np(decisions: np.ndarray, costs: np.ndarray,
                       budget: float, cheap: int,
                       *, passes: int = GUARD_PASSES):
    """Legacy-path guard (NumPy float64).

    decisions: (n,) chain index per request (arrival order);
    costs: (J,) FLOPs per chain; cheap: index of the cheapest chain.
    Returns (decisions, downgraded, spend).
    """
    decisions = np.asarray(decisions).copy()
    costs = np.asarray(costs)
    n = len(decisions)
    if n == 0:
        return decisions, 0, 0.0
    orig = decisions.copy()
    c_min = costs[cheap]
    spend = np.cumsum(costs[decisions])
    if spend[-1] > budget:
        kept_prefix = np.concatenate([[0.0], spend[:-1]])
        reserve = c_min * (n - 1 - np.arange(n))
        for _ in range(passes):
            over = kept_prefix + costs[decisions] + reserve > budget
            if not over.any():
                break
            decisions = np.where(over, cheap, decisions)
            kept_prefix = np.concatenate(
                [[0.0], np.cumsum(costs[decisions])[:-1]])
        spend = np.cumsum(costs[decisions])
    downgraded = int((decisions != orig).sum())
    return decisions, downgraded, float(spend[-1])


def _exclusive_shard_offset(local_total, axis_name):
    """Sum of ``local_total`` over shards strictly before this one.

    Works for scalar totals (the single-budget guard) and (K,) vector
    totals (per-constraint prefixes) alike.
    """
    totals = jax.lax.all_gather(local_total, axis_name)  # (n_shards, ...)
    idx = jax.lax.axis_index(axis_name)
    before = jnp.arange(totals.shape[0]) < idx
    before = before.reshape((-1,) + (1,) * (totals.ndim - 1))
    return jnp.sum(jnp.where(before, totals, 0), axis=0)


def downgrade_guard(decisions: jnp.ndarray, costs: jnp.ndarray,
                    budget, cheap, valid: jnp.ndarray | None = None,
                    *, k_of: jnp.ndarray | None = None,
                    passes: int = GUARD_PASSES,
                    axis_name: str | None = None):
    """Vectorized guard: same passes as the NumPy path, jit/shard ready.

    decisions: (b,) int32 option index; costs: (M,) float32 per-option
    cost (in the budget's units); valid: (b,) 1.0 for real requests, 0.0
    for padding (None = all real).

    Single budget (``k_of`` None): ``budget`` scalar, ``cheap`` a static
    option index - the historical path, bit-identical.

    Per-constraint budgets: ``k_of`` (b,) int32 maps each request to its
    constraint, ``budget`` is (K,) and ``cheap`` the per-constraint
    downgrade option ((K,) or a shared scalar).  Every constraint walks
    its own requests (per-k cumsums; zeros elsewhere keep f32 prefix
    sums bit-equal to a per-block walk), so ``spend`` comes back (K,).

    Under ``shard_map`` the (b,) arrays are the per-shard slice and
    ``axis_name`` names the request axis; prefix spends and tail counts
    are made global.  Returns (decisions, downgraded, spend) -
    ``downgraded`` and ``spend`` are window-global.
    """
    decisions = decisions.astype(jnp.int32)
    costs = costs.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones(decisions.shape, jnp.float32)
    else:
        valid = valid.astype(jnp.float32)

    if k_of is not None:
        return _downgrade_guard_k(decisions, costs, budget, cheap, valid,
                                  k_of, passes, axis_name)

    c_min = costs[cheap]

    # tail reserve: count of VALID requests strictly after i (globally)
    n_prefix = jnp.cumsum(valid)  # inclusive, local
    n_local = n_prefix[-1] if decisions.shape[0] else jnp.float32(0.0)
    if axis_name is not None:
        n_total = ordered_psum(n_local, axis_name)
        n_prefix = n_prefix + _exclusive_shard_offset(n_local, axis_name)
    else:
        n_total = n_local
    tail = n_total - n_prefix  # (b,)
    reserve = c_min * tail

    orig = decisions

    def one_pass(dec, _):
        cd = jnp.take(costs, dec) * valid
        prefix = jnp.cumsum(cd)  # inclusive, local
        total_local = prefix[-1] if dec.shape[0] else jnp.float32(0.0)
        if axis_name is not None:
            prefix = prefix + _exclusive_shard_offset(total_local, axis_name)
        kept_prefix = prefix - cd  # exclusive: spend strictly before i
        over = (valid > 0) & (kept_prefix + jnp.take(costs, dec) + reserve
                              > budget)
        return jnp.where(over, cheap, dec), None

    # the no-op property (over empty once total fits) makes a fixed pass
    # count equivalent to the legacy early-break loop
    decisions, _ = jax.lax.scan(one_pass, decisions, None, length=passes)

    cd = jnp.take(costs, decisions) * valid
    spend_local = jnp.sum(cd)
    changed = jnp.sum(((decisions != orig) & (valid > 0)).astype(jnp.int32))
    if axis_name is not None:
        spend = ordered_psum(spend_local, axis_name)
        downgraded = ordered_psum(changed, axis_name)
    else:
        spend, downgraded = spend_local, changed
    return decisions, downgraded, spend


def downgrade_guard_chain(decisions, costs, plans,
                          valid: jnp.ndarray | None = None,
                          *, passes: int = GUARD_PASSES,
                          axis_name: str | None = None):
    """Chain per-constraint-family tail-reserve walks over one window.

    ``plans`` is a sequence of ``(budget, cheap, k_of)`` triples, one
    per constraint family, walked in order (e.g. tenant gram budgets
    first, per-region gram budgets second); each family sees the
    decisions the previous family produced.  A ``k_of`` callable is
    invoked with the CURRENT decisions (region membership is decided by
    the option, so a later family's mapping must follow earlier
    downgrades); an array ``k_of`` is used as-is.

    Returns ``(decisions, downgraded, spends)`` where ``spends`` lists
    each family's (K,) per-constraint spend of the FINAL decisions and
    ``downgraded`` counts unique changed valid requests across the
    whole chain.
    """
    decisions = decisions.astype(jnp.int32)
    costs = costs.astype(jnp.float32)
    if valid is None:
        valid = jnp.ones(decisions.shape, jnp.float32)
    else:
        valid = valid.astype(jnp.float32)
    orig = decisions
    k_ofs = []
    for budget, cheap, k_of in plans:
        k_ofs.append((budget, k_of))
        k_now = k_of(decisions) if callable(k_of) else k_of
        decisions, _, _ = downgrade_guard(
            decisions, costs, budget, cheap, valid, k_of=k_now,
            passes=passes, axis_name=axis_name)
    # spends of the FINAL decisions, per family (earlier walks' own
    # spend reads are stale once a later walk downgrades further)
    cd = jnp.take(costs, decisions) * valid
    spends = []
    for budget, k_of in k_ofs:
        k_of = k_of(decisions) if callable(k_of) else k_of
        k_n = int(jnp.shape(budget)[0])
        onehot = (k_of[:, None] == jnp.arange(k_n)[None, :]
                  ).astype(jnp.float32)
        spends.append(jnp.stack([jnp.sum(cd * onehot[:, k])
                                 for k in range(k_n)]))
    changed = jnp.sum(((decisions != orig) & (valid > 0))
                      .astype(jnp.int32))
    if axis_name is not None:
        spends = [ordered_psum(s, axis_name) for s in spends]
        changed = ordered_psum(changed, axis_name)
    return decisions, changed, spends


def _downgrade_guard_k(decisions, costs, budget, cheap, valid, k_of,
                       passes, axis_name):
    """Per-constraint tail-reserve walk (the k_of path of
    ``downgrade_guard``): each constraint k guards its own requests
    against budget[k], all K walks in one vectorized pass."""
    budget = jnp.asarray(budget, jnp.float32)
    k_n = int(budget.shape[0])
    k_of = k_of.astype(jnp.int32)
    cheap_k = jnp.broadcast_to(jnp.asarray(cheap, jnp.int32), (k_n,))
    cheap_i = cheap_k[k_of]  # (b,) downgrade option per request
    c_min_i = costs[cheap_k][k_of]  # (b,) reserve unit per request
    budget_i = budget[k_of]
    onehot = (k_of[:, None] == jnp.arange(k_n)[None, :]).astype(jnp.float32)

    # All per-constraint prefixes/totals run one (b,) cumsum/sum PER
    # COLUMN (K is a static shape), not a single (b, K) axis reduction:
    # XLA lowers the two differently, and the K=1 column must execute
    # the single-budget path's exact reductions to stay bit-identical
    # (zeros off a request's own constraint leave f32 prefix sums
    # bit-equal to a per-block walk: x + 0.0 == x for x >= 0).
    def per_k_prefix(x):
        """(b,) per-request values -> (inclusive local per-k prefix
        (b, K), global per-k total (K,)), stitched across shards."""
        prefixes, totals = [], []
        for k in range(k_n):
            pk = jnp.cumsum(x * onehot[:, k])
            prefixes.append(pk)
            totals.append(pk[-1] if x.shape[0] else jnp.float32(0.0))
        prefix = jnp.stack(prefixes, axis=1)  # (b, K)
        local = jnp.stack(totals)  # (K,)
        if axis_name is not None:
            total = ordered_psum(local, axis_name)
            prefix = prefix + _exclusive_shard_offset(local, axis_name)
        else:
            total = local
        return prefix, total

    # tail reserve per constraint: valid requests of k strictly after i
    n_prefix, n_total = per_k_prefix(valid)
    tail = jnp.sum((n_total[None, :] - n_prefix) * onehot, axis=1)  # (b,)
    reserve = c_min_i * tail

    orig = decisions

    def one_pass(dec, _):
        cd = jnp.take(costs, dec) * valid
        prefix, _ = per_k_prefix(cd)
        kept_prefix = jnp.sum(prefix * onehot, axis=1) - cd  # exclusive
        over = (valid > 0) & (kept_prefix + jnp.take(costs, dec) + reserve
                              > budget_i)
        return jnp.where(over, cheap_i, dec), None

    decisions, _ = jax.lax.scan(one_pass, decisions, None, length=passes)

    cd = jnp.take(costs, decisions) * valid
    spend_local = jnp.stack([jnp.sum(cd * onehot[:, k])
                             for k in range(k_n)])  # (K,)
    changed = jnp.sum(((decisions != orig) & (valid > 0)).astype(jnp.int32))
    if axis_name is not None:
        spend = ordered_psum(spend_local, axis_name)
        downgraded = ordered_psum(changed, axis_name)
    else:
        spend, downgraded = spend_local, changed
    return decisions, downgraded, spend
