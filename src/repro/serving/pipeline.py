"""ServingPipeline: the fused score->decide->guard->execute window pass.

The legacy loop (``GreenFlowAllocator.allocate_window`` +
``CascadeServer.serve``) crosses the host/device boundary four times per
window and runs the downgrade guard as a multi-pass NumPy loop.  Here
the whole window is ONE jitted pass:

  1. reward scoring   - ``reward_matrix_grouped`` (model-prefix dedup:
     the recursive state depends on model choices only, so the paper
     layout runs ~2 trunk evaluations per stage instead of J);
  2. Eq. 10 decisions - ``allocate`` with the window's entry price;
  3. downgrade guard  - ``serving.guard.downgrade_guard`` (vectorized
     cumsum tail-reserve, mask-aware, optionally per-tenant);
  4. cascade execute  - CompactPlan threshold arithmetic (gathers over
     cap-wide rows instead of the item axis) with the lax.scan
     ``_revenue_requests`` kernel as the generic-layout fallback;
  5. nearline update  - ``dual_descent`` (Algorithm 1) on the window's
     rewards publishes the next window's price.

Steps 1-4 are the ONLINE response path: one jitted dispatch whose
latency is what a request sees.  Step 5 is NEARLINE exactly as in the
paper (the price "reacts within one window", it never blocks a
response): it is dispatched as a second device computation that reuses
the window's reward matrix on-device, and the next window's decisions
simply depend on its output - the host never blocks on it.  Keeping the
two graphs separate also sidesteps an XLA:CPU scheduling cliff where
fusing the 200-step dual scan into the serving graph doubles its wall
time.

Request-axis sharding: pass a 1-D mesh (``launch.mesh.make_request_mesh``)
and the pass runs under ``shard_map`` over axis "req" - per-request work
stays local while the guard stitches global prefix spends with
all_gather/psum and the dual update psums consumption.

Uneven windows: arrivals are padded up to a small set of bucket sizes
(multiples of ``pad_quantum``) with a validity mask, so a 3x traffic
spike reuses a handful of compiled shapes instead of recompiling per
window size.
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.cascade.engine import CascadeServer, _revenue_compact, \
    _revenue_requests
from repro.core.budget import WindowStats
from repro.core.primal_dual import DualDescentConfig, allocate, dual_descent
from repro.core.reward_model import (RewardModelConfig, chain_prefix_plan,
                                     denormalize_rewards,
                                     reward_matrix_grouped)
from repro.distributed.compat import shard_map
from repro.distributed.sharding import REQUEST_AXIS as AXIS
from repro.serving.guard import downgrade_guard


@dataclass
class WindowResult:
    """One served window; arrays stay on device until read.

    ``budget``/``spend`` are in the window's ACTIVE cost units - FLOPs by
    default, gCO2e when a carbon ``cost_scale`` was applied (see
    ``serve_window``); ``flops`` is always the realized FLOPs, so carbon
    ledgers and PFEC reports meter the same quantity either way.
    """

    n_valid: int
    budget: float
    lam_before: jnp.ndarray
    lam_after: jnp.ndarray
    decisions: jnp.ndarray  # (B,) padded
    revenue: jnp.ndarray  # (B,) padded (0 on padding)
    spend: jnp.ndarray
    downgraded: jnp.ndarray
    valid: np.ndarray = None  # (B,) 1.0 on real requests
    tenant_spend: jnp.ndarray | None = None
    flops: jnp.ndarray | None = None  # realized FLOPs (unit-independent)
    cost_scale: float = 1.0  # active-units per FLOP (1.0 = FLOPs mode)

    @property
    def decisions_np(self) -> np.ndarray:
        return np.asarray(self.decisions)[self.valid > 0]

    @property
    def revenue_np(self) -> np.ndarray:
        return np.asarray(self.revenue)[self.valid > 0]

    def stats(self) -> WindowStats:
        return WindowStats(
            n_requests=self.n_valid, spend=float(self.spend),
            budget=self.budget, lam=float(self.lam_after),
            downgraded=int(self.downgraded))


class ServingPipeline:
    """Fused per-window serving pass over a CascadeServer's universe.

    Parameters
    ----------
    server: executes chains for the serving users (its CompactPlan - or
        scan-kernel fallback - becomes the fused execute step).
    reward_params / reward_cfg: the trained reward model (must carry
        ``label_norm`` if trained on ratio labels).
    budget_per_window: B_t for the guard and the dual update.
    mesh: optional 1-D request mesh -> shard_map over axis "req".
    tenant_budgets: optional (T,) per-tenant budgets; windows then carry
        T equal-size tenant blocks sharing ONE dual price while the
        guard enforces each tenant's budget separately.
    """

    def __init__(self, server: CascadeServer, reward_params: dict,
                 reward_cfg: RewardModelConfig, budget_per_window: float,
                 *, dual_cfg: DualDescentConfig | None = None,
                 guard: bool = True, mesh=None, pad_quantum: int = 32,
                 tenant_budgets=None, lam_init: float = 0.0, ledger=None):
        self.server = server
        self.ledger = ledger  # optional CarbonLedger (lazy metering hook)
        self.chains = server.chains
        self.reward_params = reward_params
        self.reward_cfg = reward_cfg
        self.budget = float(budget_per_window)
        self.dual_cfg = dual_cfg or DualDescentConfig()
        self.guard = guard
        self.mesh = mesh
        self.tenant_budgets = (None if tenant_budgets is None
                               else np.asarray(tenant_budgets, np.float32))
        if mesh is not None and self.tenant_budgets is not None:
            raise NotImplementedError("tenant blocks + request sharding")
        self._n_shards = (1 if mesh is None
                          else int(np.prod(list(mesh.shape.values()))))
        q = math.lcm(int(pad_quantum), self._n_shards)
        if self.tenant_budgets is not None:
            q = math.lcm(q, len(self.tenant_budgets))
        self.pad_quantum = q

        chains = self.chains
        self._prefix_plan = chain_prefix_plan(chains.chain_idx[:, :, 0])
        self._sh = jnp.asarray(chains.scale_multihot)
        self._costs = jnp.asarray(chains.costs, jnp.float32)
        self._cheap = int(chains.cheapest())
        if server.compact is not None:
            c = server.compact
            self._tables = {
                "p": jnp.asarray(c.p_sorted),
                "ck": jnp.asarray(c.clicks_sorted),
                "g_of": jnp.asarray(c.group_of_chain),
                "n3_of": jnp.asarray(c.n3_of_chain),
            }
            self._expose = c.expose
        else:  # generic layout: the lax.scan kernel path
            self._tables = {
                "orders": server._orders, "ranks": server._ranks,
                "clicks": server._clicks,
                "slots": jnp.asarray(server._slots),
                "keeps": jnp.asarray(server._keeps),
            }
            self._expose = server.expose
        self.lam = jnp.float32(lam_init)
        self.stats: list[WindowResult] = []
        self._fns: dict = {}

    # -- fused pass -----------------------------------------------------------

    def _execute(self, tables, dec, rows, valid):
        if "p" in tables:
            rev = _revenue_compact(
                tables["p"], tables["ck"], tables["g_of"][dec], rows,
                tables["n3_of"][dec], expose=self._expose)
        else:
            rev = _revenue_requests(
                tables["orders"], tables["ranks"], tables["clicks"],
                tables["slots"][dec], tables["keeps"][dec], rows,
                n_stages=self.chains.n_stages)
        return rev * valid

    def _build_main_fn(self, b: int, padded: bool):
        """Online response path: score -> decide -> guard -> execute.

        ``budget`` and ``scale`` ride through as TRACED scalars, so
        per-window budgets (traffic reshaping) and per-window cost scales
        (carbon pricing: costs become c_j(t) = flops_j * kappa * CI(t))
        reuse the compiled pass instead of recompiling.  ``scale`` = 1.0
        multiplies bit-exactly, keeping the FLOPs path unchanged.
        """
        axis = AXIS if self.mesh is not None else None
        costs, cheap = self._costs, self._cheap
        tb = self.tenant_budgets

        def fn(params, tables, ctx, rows, valid, lam, budget, scale):
            rewards = denormalize_rewards(params, reward_matrix_grouped(
                params, self.reward_cfg, ctx, self._sh, self._prefix_plan))
            costs_eff = costs * scale  # active units (FLOPs or gCO2e)
            dec = allocate(rewards, costs_eff, lam)
            mask = valid if padded else None
            tenant_spend = None
            if not self.guard:
                dg = jnp.int32(0)
                spend = jnp.sum(jnp.take(costs_eff, dec) * valid)
                if axis is not None:
                    spend = jax.lax.psum(spend, axis)
            elif tb is not None:
                t_n = len(tb)
                gfn = jax.vmap(
                    lambda d, v, bud: downgrade_guard(d, costs_eff, bud,
                                                      cheap, v))
                dec_t, dg_t, spend_t = gfn(
                    dec.reshape(t_n, -1), valid.reshape(t_n, -1),
                    jnp.asarray(tb))
                dec = dec_t.reshape(-1)
                dg, spend, tenant_spend = dg_t.sum(), spend_t.sum(), spend_t
            else:
                dec, dg, spend = downgrade_guard(
                    dec, costs_eff, budget, cheap, mask, axis_name=axis)
            flops = jnp.sum(jnp.take(costs, dec) * valid)
            if axis is not None:
                flops = jax.lax.psum(flops, axis)
            rev = self._execute(tables, dec, rows, valid)
            return rewards, dec, rev, spend, flops, dg, tenant_spend

        if self.mesh is not None:
            fn = shard_map(
                fn, mesh=self.mesh,
                in_specs=(P(), P(), P(AXIS), P(AXIS), P(AXIS), P(), P(),
                          P()),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P(), P()))
        return jax.jit(fn)

    def _build_dual_fn(self, b: int, padded: bool):
        """Nearline price update: Algorithm 1 on the window's rewards,
        against the same traced (budget, scale) pair as the online pass -
        in carbon mode the published price is reward-per-gCO2e."""
        axis = AXIS if self.mesh is not None else None
        cfg = self.dual_cfg
        costs = self._costs

        def fn(rewards, valid, lam, budget, scale):
            mask = valid if padded else None
            lam_new, _ = dual_descent(
                rewards, costs * scale, budget, lam, mask=mask,
                max_iters=cfg.max_iters, step_size=cfg.step_size,
                step_decay=cfg.step_decay, axis_name=axis)
            return lam_new

        if self.mesh is not None:
            fn = shard_map(fn, mesh=self.mesh,
                           in_specs=(P(AXIS), P(AXIS), P(), P(), P()),
                           out_specs=P())
        return jax.jit(fn)

    def _bucket(self, n: int) -> int:
        q = self.pad_quantum
        return max(q, ((n + q - 1) // q) * q)

    # -- public API -----------------------------------------------------------

    def serve_window(self, ctx: np.ndarray, rows: np.ndarray, *,
                     lam=None, update_lam: bool = True, budget=None,
                     cost_scale=None) -> WindowResult:
        """Serve one traffic window.

        ctx (n, d_context) raw contexts, rows (n,) user indices into the
        server's score tables.  Decisions use ``lam`` (default: the
        pipeline's nearline price, i.e. lambda_{t-1}); the pass then
        publishes lambda_t unless ``update_lam=False``.

        ``budget`` overrides this window's budget (default: the
        pipeline's); ``cost_scale`` re-denominates the window's costs as
        ``costs * cost_scale`` - carbon pricing passes kappa*CI(t)
        [gCO2e/FLOP] here together with a gCO2e ``budget``, making the
        dual price reward-per-gram.  Both are traced, so time-varying
        values never recompile.
        """
        n = len(rows)
        ctx = np.asarray(ctx, np.float32)
        rows = np.asarray(rows, np.int32)
        if (budget is not None or cost_scale is not None) \
                and self.tenant_budgets is not None:
            raise NotImplementedError(
                "per-window budget/cost_scale overrides with tenant blocks")
        bud = self.budget if budget is None else float(budget)
        sc = 1.0 if cost_scale is None else float(cost_scale)
        if n == 0:  # zero-arrival window: nothing to serve or learn from
            res = WindowResult(
                n_valid=0, budget=bud, lam_before=self.lam,
                lam_after=self.lam, decisions=jnp.zeros(0, jnp.int32),
                revenue=jnp.zeros(0, jnp.float32),
                spend=jnp.float32(0.0), downgraded=jnp.int32(0),
                valid=np.zeros(0, np.float32), flops=jnp.float32(0.0),
                cost_scale=sc)
            self.stats.append(res)
            if self.ledger is not None:
                self.ledger.record_result(res)
            return res
        if self.tenant_budgets is not None:
            # tenant windows carry T equal blocks; padding must land at
            # the END OF EACH BLOCK so the fused pass's (T, b/T) reshape
            # keeps blocks aligned with their budgets
            t_n = len(self.tenant_budgets)
            if n % t_n:
                raise ValueError(f"window size {n} not divisible by "
                                 f"{t_n} tenants")
            n_t = n // t_n
            bt = self._bucket(n_t)
            b = bt * t_n
            ctx_b = np.zeros((t_n, bt, ctx.shape[1]), np.float32)
            rows_b = np.zeros((t_n, bt), np.int32)
            valid = np.zeros((t_n, bt), np.float32)
            ctx_b[:, :n_t] = ctx.reshape(t_n, n_t, -1)
            rows_b[:, :n_t] = rows.reshape(t_n, n_t)
            valid[:, :n_t] = 1.0
            ctx, rows = ctx_b.reshape(b, -1), rows_b.reshape(b)
            valid = valid.reshape(b)
        else:
            b = self._bucket(n)
            if b != n:
                ctx = np.concatenate(
                    [ctx, np.zeros((b - n, ctx.shape[1]), np.float32)])
                rows = np.concatenate([rows, np.zeros(b - n, np.int32)])
            valid = np.zeros(b, np.float32)
            valid[:n] = 1.0
        key = (b, b != n)
        if key not in self._fns:
            self._fns[key] = (self._build_main_fn(b, b != n),
                              self._build_dual_fn(b, b != n))
        main_fn, dual_fn = self._fns[key]
        lam_in = self.lam if lam is None else jnp.float32(lam)
        valid_j = jnp.asarray(valid)
        bud_j, sc_j = jnp.float32(bud), jnp.float32(sc)
        rewards, dec, rev, spend, flops, dg, t_spend = main_fn(
            self.reward_params, self._tables, jnp.asarray(ctx),
            jnp.asarray(rows, jnp.int32), valid_j, lam_in, bud_j, sc_j)
        # nearline: the price update never blocks the response - it is a
        # second dispatch reusing the on-device reward matrix, and the
        # NEXT window's decisions depend on its (device-side) output
        lam_new = dual_fn(rewards, valid_j, lam_in, bud_j, sc_j)
        if update_lam:
            self.lam = lam_new
        res = WindowResult(
            n_valid=n, budget=bud, lam_before=lam_in,
            lam_after=lam_new, decisions=dec, revenue=rev, spend=spend,
            downgraded=dg, valid=valid, tenant_spend=t_spend, flops=flops,
            cost_scale=sc)
        self.stats.append(res)
        if self.ledger is not None:
            self.ledger.record_result(res)
        return res

    def spend_trace(self) -> np.ndarray:
        return np.array([float(r.spend) for r in self.stats])
