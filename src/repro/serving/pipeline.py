"""ServingPipeline: the fused score->decide->guard->execute window pass.

The legacy loop (``GreenFlowAllocator.allocate_window`` +
``CascadeServer.serve``) crosses the host/device boundary four times per
window and runs the downgrade guard as a multi-pass NumPy loop.  Here
the whole window is ONE jitted pass:

  1. reward scoring   - ``reward_matrix_grouped`` (model-prefix dedup:
     the recursive state depends on model choices only, so the paper
     layout runs ~2 trunk evaluations per stage instead of J);
  2. Eq. 10 decisions - ``allocate`` with the window's entry price(s);
  3. downgrade guard  - ``serving.guard.downgrade_guard`` (vectorized
     cumsum tail-reserve, mask-aware, per-constraint budgets);
  4. cascade execute  - CompactPlan threshold arithmetic (gathers over
     cap-wide rows instead of the item axis) with the lax.scan
     ``_revenue_requests`` kernel as the generic-layout fallback;
  5. nearline update  - ``dual_descent`` (Algorithm 1) on the window's
     rewards publishes the next window's price(s).

Steps 1-4 are the ONLINE response path: one jitted dispatch whose
latency is what a request sees.  Step 5 is NEARLINE exactly as in the
paper (the price "reacts within one window", it never blocks a
response): it is dispatched as a second device computation that reuses
the window's reward matrix on-device, and the next window's decisions
simply depend on its output - the host never blocks on it.  Keeping the
two graphs separate also sidesteps an XLA:CPU scheduling cliff where
fusing the 200-step dual scan into the serving graph doubles its wall
time.

WHAT is budgeted is declared by a ``serving.spec.ConstraintSpec`` -
the pipeline's front door is ``ServingPipeline.from_spec``:

  * [GlobalAxis]                 - one budget, one dual price (the
    paper's system; the K=1 case of the core, bit-identical);
  * [TenantAxis(shared)]         - T equal-size tenant blocks per
    window, ONE dual price, the guard enforcing each tenant's own
    budget (k_of path);
  * [TenantAxis(priced)]         - a (T,) PRICE VECTOR inside the same
    fused pass: each tenant's price descends on its own
    consumption-vs-budget subgradient;
  * [RegionAxis]                 - the geo router: each request chooses
    (chain, serving region) by the same priced argmax over J*R options
    with region-dependent effective costs c_{j,r}(t) = flops_j *
    scale_r(t) (carbon: scale_r = kappa * CI_r(t)), (R,) per-region
    budgets/prices, the guard downgrading within a request's region;
  * [TenantAxis + RegionAxis]    - the COMBINED system: per-tenant
    gram budgets and per-region gram budgets priced together, a
    (T + R,) price vector (priced tenants) where a tenant-t request
    pays (lam_tenant[t] + lam_region[r]) * c_{j,r} for option (j, r),
    and the guard chains a tenant walk with a per-region walk.

The legacy keyword constructor (``tenant_budgets``/``tenant_mode``/
``n_regions``) survives as a thin shim that builds the equivalent spec
(``serving.spec.spec_from_legacy``) - bit-identical to the historical
flag paths.

Region ties: the proportional cost structure (c_{j,r} = s_r * flops_j)
makes every request indifferent between regions at once at the dual
equilibrium, so a pure argmax bang-bangs whole windows.
``RegionAxis(split="flow")`` (the default for new specs) resolves the
degenerate window exactly: tied requests are divided deterministically
in arrival order, each tied region receiving a share of the window's
FLOPs mass proportional to its remaining budget capacity - the
flow-splitting primal rounding of the fractional LP optimum.
``split="argmax"`` keeps the historical knife-edge behavior (and the
bit-exact reduction to a pinned pipeline when regions are identical).

Request-axis sharding: pass a 1-D mesh (``launch.mesh.make_request_mesh``)
and the pass runs under ``shard_map`` over axis "req" - per-request work
stays local while the guard stitches per-constraint prefix spends with
all_gather/psum and the dual update psums per-constraint consumption.
Tenant blocks compose with sharding (blocks may span shard boundaries;
the per-k prefix stitching keeps the walk exact), and so does the flow
split (the arrival-order FLOPs prefix is stitched the same way).

Uneven windows: arrivals are padded up to a small set of bucket sizes
(multiples of ``pad_quantum``) with a validity mask, so a 3x traffic
spike reuses a handful of compiled shapes instead of recompiling.

CI-forecast warm-start: ``serve_window(dual_budget=..,
dual_cost_scale=..)`` runs the nearline update against the NEXT
window's (known or forecast) budget and cost scale while the online
pass uses the current ones - the published price then lands where the
next window needs it instead of lagging a CI swing by one window
(``run_stream(forecast=True)`` threads this automatically).
"""
from __future__ import annotations

import math
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.cascade.engine import CascadeServer, _revenue_compact, \
    _revenue_requests
from repro.core.budget import WindowStats
from repro.core.primal_dual import DualDescentConfig, allocate, dual_descent
from repro.core.reward_model import (RewardModelConfig, chain_prefix_plan,
                                     denormalize_rewards,
                                     reward_matrix_grouped)
from repro.distributed.compat import shard_map
from repro.distributed.sharding import REQUEST_AXIS as AXIS
from repro.distributed.sharding import ordered_psum
from repro.serving.guard import (_exclusive_shard_offset, downgrade_guard,
                                 downgrade_guard_chain)
from repro.serving.spec import ConstraintSpec, spec_from_legacy


def _local_np(arr) -> np.ndarray:
    """Device array -> THIS process's rows, as numpy.

    Single-process (fully addressable) arrays convert wholesale.  A
    request-sharded global array of a multi-process mesh yields the
    concatenation of its ADDRESSABLE shards in request order: each host
    reads exactly the window rows it serves, and the read never moves
    data across hosts.
    """
    if getattr(arr, "is_fully_addressable", True):
        return np.asarray(arr)
    shards = sorted(arr.addressable_shards,
                    key=lambda s: s.index[0].start or 0)
    return np.concatenate([np.asarray(s.data) for s in shards])


def window_layout(n: int, b: int, t_n: int | None = None):
    """The canonical padded layout of an n-request window in a b slot
    bucket: ``(perm, valid, k_of)``.

    ``perm[pos]`` is the ORIGINAL request index at padded position
    ``pos`` (0 on padding slots), ``valid`` masks real requests, and
    ``k_of`` maps positions to tenants (``None`` without tenants).
    Plain windows pad at the end; tenant windows carry ``t_n`` equal
    blocks of ``b // t_n`` slots each, padded at the end of EACH block
    so per-tenant guard walks stay aligned with their budgets.

    Extracted to module level because the multi-host window protocol
    depends on every host deriving the SAME layout from ``(n, b)``
    alone: each host materializes only its own contiguous slice of
    these positions and the stitched collectives see one consistent
    global window.
    """
    if t_n is None:
        valid = np.zeros(b, np.float32)
        valid[:n] = 1.0
        perm = np.concatenate(
            [np.arange(n, dtype=np.intp), np.zeros(b - n, np.intp)])
        return perm, valid, None
    if n % t_n:
        raise ValueError(f"window size {n} not divisible by "
                         f"{t_n} tenants")
    if b % t_n:
        raise ValueError(f"bucket {b} not divisible by {t_n} tenants")
    n_t, bt = n // t_n, b // t_n
    valid = np.zeros((t_n, bt), np.float32)
    valid[:, :n_t] = 1.0
    perm = np.zeros((t_n, bt), np.intp)
    perm[:, :n_t] = (np.arange(t_n)[:, None] * n_t
                     + np.arange(n_t)[None, :])
    k_of = np.repeat(np.arange(t_n, dtype=np.int32), bt)
    return perm.reshape(b), valid.reshape(b), k_of


@dataclass
class WindowResult:
    """One served window; arrays stay on device until read.

    ``budget``/``spend`` are in the window's ACTIVE cost units - FLOPs by
    default, gCO2e when a carbon ``cost_scale`` was applied (see
    ``serve_window``); ``flops`` is always the realized FLOPs, so carbon
    ledgers and PFEC reports meter the same quantity either way.
    ``lam_before``/``lam_after`` are scalars in the single-price modes
    and (K,) vectors otherwise (``spec.k_names`` order: priced tenant
    entries first, region entries after).  In the combined
    tenant x region mode ``tr_spend`` carries the full (T, R)
    per-(tenant, region) spend whose marginals are ``tenant_spend`` and
    ``region_spend``.
    """

    n_valid: int
    budget: float
    lam_before: jnp.ndarray
    lam_after: jnp.ndarray
    decisions: jnp.ndarray  # (B,) padded CHAIN index
    revenue: jnp.ndarray  # (B,) padded (0 on padding)
    spend: jnp.ndarray
    downgraded: jnp.ndarray
    valid: np.ndarray = None  # (B,) 1.0 on real requests
    tenant_spend: jnp.ndarray | None = None  # (T,) per-tenant spend
    flops: jnp.ndarray | None = None  # realized FLOPs (unit-independent)
    cost_scale: float = 1.0  # active-units per FLOP (1.0 = FLOPs mode)
    regions: jnp.ndarray | None = None  # (B,) serving region (geo mode)
    region_spend: jnp.ndarray | None = None  # (R,) per-region spend
    k_budget: np.ndarray | None = None  # per-constraint budgets
    tr_spend: jnp.ndarray | None = None  # (T, R) per-(tenant, region)
    compiles: int = 0  # jit cache misses this window (0 = warm bucket)
    bucket: tuple | None = None  # the (b, padded, chunked) shape key
    h2d_bytes: int = 0  # host->device bytes dispatched for this window
    prep_ms: float = 0.0  # host chunk production (set by run_stream)
    stall_ms: float = 0.0  # host wait for a prefetched chunk (run_stream)

    @property
    def decisions_np(self) -> np.ndarray:
        return _local_np(self.decisions)[self.valid > 0]

    @property
    def revenue_np(self) -> np.ndarray:
        return _local_np(self.revenue)[self.valid > 0]

    @property
    def regions_np(self) -> np.ndarray | None:
        if self.regions is None:
            return None
        return _local_np(self.regions)[self.valid > 0]

    def stats(self) -> WindowStats:
        return WindowStats(
            n_requests=self.n_valid, spend=float(np.sum(np.asarray(
                self.spend))), budget=self.budget,
            lam=float(np.max(np.asarray(self.lam_after))),
            downgraded=int(self.downgraded))


class ServingPipeline:
    """Fused per-window serving pass over a CascadeServer's universe.

    The front door is ``ServingPipeline.from_spec(server, params, cfg,
    spec)`` with a declarative ``serving.spec.ConstraintSpec``; the
    keyword constructor below is the LEGACY shim - every historical
    flag combination builds its equivalent spec via
    ``spec_from_legacy`` and is bit-identical to the pre-spec paths.

    Parameters
    ----------
    server: executes chains for the serving users (its CompactPlan - or
        scan-kernel fallback - becomes the fused execute step).
    reward_params / reward_cfg: the trained reward model (must carry
        ``label_norm`` if trained on ratio labels).
    budget_per_window: B_t for the guard and the dual update (the
        TOTAL budget; per-tenant/per-region caps refine it below).
    mesh: optional 1-D request mesh -> shard_map over axis "req"
        (composes with every pricing mode).
    tenant_budgets / tenant_mode / n_regions: legacy flags, see
        ``spec_from_legacy`` for the mapping.
    donate_dual: thread the nearline lambda update through
        ``jax.jit(..., donate_argnums=...)`` so the steady-state price
        chain updates its device buffer IN PLACE (allocation-free);
        records stay readable via a bitwise device copy, so results
        are bit-identical either way.
    spec: a ConstraintSpec - overrides the legacy flags entirely.
    """

    def __init__(self, server: CascadeServer, reward_params: dict,
                 reward_cfg: RewardModelConfig, budget_per_window: float,
                 *, dual_cfg: DualDescentConfig | None = None,
                 guard: bool = True, mesh=None, pad_quantum: int = 32,
                 bucketing: str = "linear",
                 tenant_budgets=None, tenant_mode: str = "shared",
                 n_regions: int | None = None,
                 lam_init: float = 0.0, ledger=None,
                 donate_dual: bool = True,
                 spec: ConstraintSpec | None = None, obs=None,
                 multihost: bool | None = None):
        if spec is None:
            spec = spec_from_legacy(
                float(budget_per_window), tenant_budgets=tenant_budgets,
                tenant_mode=tenant_mode, n_regions=n_regions)
        cs = spec.compile()
        self.spec = spec
        self._cs = cs
        self.server = server
        self.ledger = ledger  # optional CarbonLedger (lazy metering hook)
        from repro.obs import get_obs
        self.obs = get_obs(obs)  # host spans only; never touches numerics
        self.chains = server.chains
        self.reward_params = reward_params
        self.reward_cfg = reward_cfg
        self.budget = cs.total_budget
        self.dual_cfg = dual_cfg or DualDescentConfig()
        self.guard = guard
        self.mesh = mesh
        # multi-process request mesh (repro.distributed.multihost): the
        # window pass runs over GLOBAL arrays assembled from each host's
        # slice; auto-detected from jax.distributed state, overridable
        # for tests
        self.multihost = (bool(multihost) if multihost is not None
                          else mesh is not None
                          and jax.process_count() > 1)
        if self.multihost and mesh is None:
            raise ValueError("multihost serving needs a request mesh")
        self._params_mh = None  # replicated global params (built lazily)
        self._layout_mh = None  # replicated global g_of/n3_of tables
        self._mh_lam = False  # lam chain converted to a global array?
        # legacy-compatible views of the compiled spec
        self.tenant_mode = "priced" if cs.tenant_priced else "shared"
        self.tenant_budgets = (
            None if cs.tenants is None
            else np.asarray(cs.tenants.budgets, np.float32))
        self.n_regions = cs.r_n
        self.region_split = cs.split
        from repro.launch.mesh import mesh_num_shards
        self._n_shards = mesh_num_shards(mesh)
        q = math.lcm(int(pad_quantum), self._n_shards)
        if self.tenant_budgets is not None:
            q = math.lcm(q, len(self.tenant_budgets))
        self.pad_quantum = q
        if bucketing not in ("linear", "pow2"):
            raise ValueError(f"bucketing must be 'linear' or 'pow2', "
                             f"got {bucketing!r}")
        self.bucketing = bucketing

        chains = self.chains
        self._prefix_plan = chain_prefix_plan(chains.chain_idx[:, :, 0])
        self._sh = jnp.asarray(chains.scale_multihot)
        self._costs = jnp.asarray(chains.costs, jnp.float32)
        self._cheap = int(chains.cheapest())
        # a streaming universe (``data.request_source.StreamUniverse``)
        # carries the compact LAYOUT only - every serve_window call must
        # bring its own chunk tables
        self._stream_only = bool(getattr(server, "stream_only", False))
        self._cap = None
        if server.compact is not None:
            c = server.compact
            self._tables = {
                "p": jnp.asarray(c.p_sorted),
                "ck": jnp.asarray(c.clicks_sorted),
                "g_of": jnp.asarray(c.group_of_chain),
                "n3_of": jnp.asarray(c.n3_of_chain),
            }
            self._expose = c.expose
            self._cap = int(c.cap)
        else:  # generic layout: the lax.scan kernel path
            self._tables = {
                "orders": server._orders, "ranks": server._ranks,
                "clicks": server._clicks,
                "slots": jnp.asarray(server._slots),
                "keeps": jnp.asarray(server._keeps),
            }
            self._expose = server.expose
        # K price components in spec.k_names order (priced tenants
        # first, regions after); scalar for the single-price modes
        if cs.n_prices:
            self.lam = jnp.full(cs.n_prices, lam_init, jnp.float32)
        else:
            self.lam = jnp.float32(lam_init)
        # with donation the chain buffer ``self.lam`` is consumed by the
        # next window's dual dispatch; ``_lam_rec`` is its always-
        # readable twin (a bitwise device copy) that WindowResult
        # records point at
        self.donate_dual = bool(donate_dual)
        self._lam_rec = jnp.copy(self.lam) if donate_dual else self.lam
        self._h2d_window = 0
        self.stats: list[WindowResult] = []
        self._fns: dict = {}
        self._built: list = []  # every jitted fn ever built (compile count)

    @classmethod
    def from_spec(cls, server: CascadeServer, reward_params: dict,
                  reward_cfg: RewardModelConfig, spec: ConstraintSpec,
                  *, dual_cfg: DualDescentConfig | None = None,
                  guard: bool = True, mesh=None, pad_quantum: int = 32,
                  bucketing: str = "linear", lam_init: float = 0.0,
                  ledger=None,
                  donate_dual: bool = True, obs=None,
                  multihost: bool | None = None) -> "ServingPipeline":
        """Build the pipeline from a declarative ConstraintSpec (the
        compiled total budget seeds ``budget_per_window``)."""
        return cls(server, reward_params, reward_cfg,
                   spec.compile().total_budget, dual_cfg=dual_cfg,
                   guard=guard, mesh=mesh, pad_quantum=pad_quantum,
                   bucketing=bucketing, lam_init=lam_init, ledger=ledger,
                   donate_dual=donate_dual, spec=spec, obs=obs,
                   multihost=multihost)

    # -- fused pass -----------------------------------------------------------

    def _execute(self, tables, dec, rows, valid):
        if "p" in tables:
            rev = _revenue_compact(
                tables["p"], tables["ck"], tables["g_of"][dec], rows,
                tables["n3_of"][dec], expose=self._expose)
        else:
            rev = _revenue_requests(
                tables["orders"], tables["ranks"], tables["clicks"],
                tables["slots"][dec], tables["keeps"][dec], rows,
                n_stages=self.chains.n_stages)
        return rev * valid

    def _flow_split(self, flops_mass, share, axis):
        """Deterministic proportional rounding of a degenerate window:
        walk the (masked) FLOPs mass in arrival order and hand region r
        the ``share[r]`` fraction of it (a Bresenham-style interval
        assignment on the cumulative mass - exact up to one request per
        region, shard-stitched like every guard prefix)."""
        edges = jnp.cumsum(share)  # (R,) interval right edges in (0, 1]
        prefix = jnp.cumsum(flops_mass)
        local_total = prefix[-1] if flops_mass.shape[0] \
            else jnp.float32(0.0)
        if axis is not None:
            total = ordered_psum(local_total, axis)
            prefix = prefix + _exclusive_shard_offset(local_total, axis)
        else:
            total = local_total
        pos = (prefix - 0.5 * flops_mass) / jnp.maximum(total, 1e-30)
        return jnp.sum((pos[:, None] > edges[None, :-1])
                       .astype(jnp.int32), axis=1)

    def _build_main_fn(self, b: int, padded: bool):
        """Online response path: score -> decide -> guard -> execute.

        ``budget`` and ``scale`` ride through as TRACED values, so
        per-window budgets (traffic reshaping) and per-window cost
        scales (carbon pricing: costs become c_j(t) = flops_j * kappa *
        CI(t); geo pricing: an (R,) scale vector, one per region's
        CI_r(t)) reuse the compiled pass instead of recompiling.
        ``scale`` = 1.0 multiplies bit-exactly, keeping the FLOPs path
        unchanged.
        """
        axis = AXIS if self.mesh is not None else None
        costs, cheap = self._costs, self._cheap
        j_n = int(costs.shape[0])
        cs = self._cs
        tb = self.tenant_budgets
        r_n = self.n_regions
        mode = cs.mode
        # chunk tables ride REQUEST-SHARDED through a multi-process mesh
        # (each host uploads only its own rows; ``rows`` then index the
        # shard-local slice) - the single-process path keeps replicated
        # tables + the padded-perm gather.  Both gather identical
        # values, so results stay bitwise equal across the two layouts.
        tspec = ({"p": P(None, AXIS, None), "ck": P(None, AXIS, None),
                  "g_of": P(), "n3_of": P()}
                 if self.multihost else P())

        if mode == "geotenants":
            t_n = len(tb)
            priced = cs.tenant_priced
            flow = cs.split == "flow"
            tie_tol = cs.tie_tol

            def fn(params, tables, ctx, rows, valid, k_of, lam, budgets,
                   scales):
                rewards = denormalize_rewards(
                    params, reward_matrix_grouped(
                        params, self.reward_cfg, ctx, self._sh,
                        self._prefix_plan))
                # option axis m = r*J + j: region-major tiling
                opt_costs = (scales[:, None] * costs[None, :]).reshape(-1)
                if priced:
                    lam_t, lam_r = lam[:t_n], lam[t_n:]
                    lam_ti = lam_t[k_of]  # (b,)
                else:  # shared tenants: region prices only, tenant
                    lam_r = lam  # budgets enforced by the guard walk
                    lam_ti = jnp.zeros(rewards.shape[0], jnp.float32)
                # per-flop priced cost of serving request i in region r
                q_ir = (lam_ti[:, None] + lam_r[None, :]) \
                    * scales[None, :]  # (b, R)
                r_max = jnp.max(jnp.abs(rewards))
                if axis is not None:  # shard-invariant scale
                    r_max = jax.lax.pmax(r_max, axis)
                # gf: allow[GF003] tie-break scale only: eps_green
                # orders regions at lam=0 and never enters the dual
                # update, so reassociation cannot drift the price
                eps_green = 1e-6 * r_max / (jnp.mean(opt_costs) + 1e-30)
                u_ir = q_ir + eps_green * scales[None, :]  # green floor
                r0 = jnp.argmin(u_ir, axis=1)  # (b,)
                # the per-flop price factors out of the chain argmax, so
                # chains compete at the chosen region's price (Eq. 10)
                p_i = jnp.take_along_axis(q_ir, r0[:, None],
                                          axis=1)[:, 0]
                dec = jnp.argmax(rewards - p_i[:, None] * costs[None, :],
                                 axis=1).astype(jnp.int32)
                f = jnp.take(costs, dec) * valid
                if flow:
                    u_min = jnp.take_along_axis(u_ir, r0[:, None],
                                                axis=1)[:, 0]
                    tied_ir = u_ir <= u_min[:, None] * (1.0 + tie_tol)
                    is_tied = jnp.sum(tied_ir.astype(jnp.int32),
                                      axis=1) > 1
                    # region capacity left after the untied requests
                    oh_r0 = (r0[:, None] == jnp.arange(r_n)[None, :]
                             ).astype(jnp.float32)
                    fixed = jnp.sum(
                        f[:, None] * oh_r0
                        * (1.0 - is_tied.astype(jnp.float32))[:, None],
                        axis=0)
                    # flow shares only cover regions inside some tied
                    # request's tie band (tie sets are per-tenant, so
                    # with R > 2 a far-overpriced region must not soak
                    # up tied mass just because capacity remains there)
                    any_tied = jnp.any(tied_ir & is_tied[:, None],
                                       axis=0).astype(jnp.float32)
                    if axis is not None:
                        fixed = ordered_psum(fixed, axis)
                        any_tied = jax.lax.pmax(any_tied, axis)
                    cap = jnp.maximum(
                        budgets[t_n:] / jnp.maximum(scales, 1e-30)
                        - fixed, 0.0) * any_tied
                    total_cap = jnp.sum(cap)
                    share = cap / (total_cap + 1e-30)
                    r_flow = self._flow_split(
                        f * is_tied.astype(jnp.float32), share, axis)
                    # a request never leaves its OWN tie band (the
                    # union share may point outside it when R > 2),
                    # and exhausted capacity (share all-zero) falls
                    # back to the priced argmin instead of dumping
                    # the window into the last region
                    ok = jnp.take_along_axis(tied_ir, r_flow[:, None],
                                             axis=1)[:, 0]
                    region = jnp.where(is_tied & ok & (total_cap > 0),
                                       r_flow, r0)
                else:
                    region = r0
                dec_m = (region * j_n + dec).astype(jnp.int32)
                mask = valid if padded else None
                if self.guard:
                    # tenant walk downgrades to the globally cheapest
                    # priced option (greenest region's cheap chain),
                    # then the region walk re-caps within each region -
                    # later walks only lower earlier spends
                    cheap_m = jnp.argmin(opt_costs).astype(jnp.int32)
                    cheap_k = jnp.arange(r_n) * j_n + cheap
                    dec_m, dg, _ = downgrade_guard_chain(
                        dec_m, opt_costs,
                        [(budgets[:t_n], cheap_m, k_of),
                         (budgets[t_n:], cheap_k, lambda d: d // j_n)],
                        mask, axis_name=axis)
                else:
                    dg = jnp.int32(0)
                dec = dec_m % j_n
                region = dec_m // j_n
                # per-(tenant, region) spends of the FINAL decisions
                cd = jnp.take(opt_costs, dec_m) * valid
                oh_t = (k_of[:, None] == jnp.arange(t_n)[None, :]
                        ).astype(jnp.float32)
                oh_r = (region[:, None] == jnp.arange(r_n)[None, :]
                        ).astype(jnp.float32)
                tr_spend = (oh_t * cd[:, None]).T @ oh_r  # (T, R)
                if axis is not None:
                    tr_spend = ordered_psum(tr_spend, axis)
                spend = jnp.sum(tr_spend)
                flops = jnp.sum(jnp.take(costs, dec) * valid)
                if axis is not None:
                    flops = ordered_psum(flops, axis)
                rev = self._execute(tables, dec, rows, valid)
                return (rewards, dec, rev, spend, flops, dg,
                        jnp.sum(tr_spend, axis=1), region,
                        jnp.sum(tr_spend, axis=0), tr_spend)

            if self.mesh is not None:
                fn = shard_map(
                    fn, mesh=self.mesh,
                    in_specs=(P(), tspec, P(AXIS), P(AXIS), P(AXIS),
                              P(AXIS), P(), P(), P()),
                    out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P(),
                               P(), P(AXIS), P(), P()))
            return jax.jit(fn)

        if r_n is not None:
            flow = cs.split == "flow"
            tie_tol = cs.tie_tol

            def fn(params, tables, ctx, rows, valid, lam, budgets,
                   scales):
                rewards = denormalize_rewards(
                    params, reward_matrix_grouped(
                        params, self.reward_cfg, ctx, self._sh,
                        self._prefix_plan))
                # option axis m = r*J + j: region-major tiling
                opt_costs = (scales[:, None] * costs[None, :]).reshape(-1)
                r_max = jnp.max(jnp.abs(rewards))
                if axis is not None:  # shard-invariant scale
                    r_max = jax.lax.pmax(r_max, axis)
                # gf: allow[GF003] tie-break scale only: eps_green
                # orders regions at lam=0 and never enters the dual
                # update, so reassociation cannot drift the price
                eps_green = 1e-6 * r_max / (jnp.mean(opt_costs) + 1e-30)
                if flow:
                    # per-flop priced cost per region; the eps_green
                    # floor routes slack (lam = 0) windows green
                    u = (lam + eps_green) * scales  # (R,)
                    r0 = jnp.argmin(u)
                    price_best = (lam[r0] * scales[r0]) * costs  # (J,)
                    dec = jnp.argmax(rewards - price_best[None, :],
                                     axis=1).astype(jnp.int32)
                    f = jnp.take(costs, dec) * valid
                    tied = u <= jnp.min(u) * (1.0 + tie_tol)
                    cap = jnp.where(
                        tied, budgets / jnp.maximum(scales, 1e-30), 0.0)
                    total_cap = jnp.sum(cap)
                    share = cap / (total_cap + 1e-30)
                    region = self._flow_split(f, share, axis)
                    # zero remaining capacity (share all-zero): fall
                    # back to the priced argmin instead of dumping the
                    # window into the last region
                    region = jnp.where(total_cap > 0, region, r0)
                    dec_m = (region * j_n + dec).astype(jnp.int32)
                else:
                    # The joint argmax over (chain, region) factors: the
                    # reward is region-free, so each (request, chain)
                    # first picks its cheapest-PRICED region, then
                    # chains compete by the usual Eq. 10 argmax
                    # (first-index ties, exactly the scalar semantics).
                    # The region argmin runs at lam + eps_green - an
                    # infinitesimal price floor, ~1e-6 of the natural
                    # reward-per-cost scale - so a slack window
                    # (lam = 0, every price 0) still routes to the
                    # GREENER region instead of tie-breaking
                    # arbitrarily, while any meaningful price dwarfs
                    # it.  Equal regions keep equal floors, so ties
                    # still resolve to region 0 and the pinned-pipeline
                    # reduction stays bit-exact.
                    price_r = lam[:, None] * (scales[:, None]
                                              * costs[None, :])  # (R, J)
                    price_irj = jnp.broadcast_to(
                        price_r[None], (rewards.shape[0], r_n, j_n))
                    tie = price_irj + eps_green * (
                        scales[:, None] * costs[None, :])[None]
                    r_star = jnp.argmin(tie, axis=1)  # (I, J)
                    price_best = jnp.take_along_axis(
                        price_irj, r_star[:, None, :], axis=1)[:, 0, :]
                    dec = jnp.argmax(rewards - price_best,
                                     axis=1).astype(jnp.int32)
                    dec_m = (jnp.take_along_axis(
                        r_star, dec[:, None], axis=1)[:, 0] * j_n + dec)
                mask = valid if padded else None
                if not self.guard:
                    dg = jnp.int32(0)
                    region_spend = None
                    spend = jnp.sum(jnp.take(opt_costs, dec_m) * valid)
                    if axis is not None:
                        spend = ordered_psum(spend, axis)
                else:
                    cheap_k = jnp.arange(r_n) * j_n + cheap
                    dec_m, dg, region_spend = downgrade_guard(
                        dec_m, opt_costs, budgets, cheap_k, mask,
                        k_of=dec_m // j_n, axis_name=axis)
                    spend = jnp.sum(region_spend)
                dec = dec_m % j_n
                regions = dec_m // j_n
                flops = jnp.sum(jnp.take(costs, dec) * valid)
                if axis is not None:
                    flops = ordered_psum(flops, axis)
                rev = self._execute(tables, dec, rows, valid)
                return (rewards, dec, rev, spend, flops, dg, None,
                        regions, region_spend)

            if self.mesh is not None:
                fn = shard_map(
                    fn, mesh=self.mesh,
                    in_specs=(P(), tspec, P(AXIS), P(AXIS), P(AXIS),
                              P(), P(), P()),
                    out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P(),
                               P(), P(AXIS), P()))
            return jax.jit(fn)

        if tb is not None:
            t_n = len(tb)
            priced = cs.tenant_priced

            def fn(params, tables, ctx, rows, valid, k_of, lam, budgets,
                   scale):
                rewards = denormalize_rewards(
                    params, reward_matrix_grouped(
                        params, self.reward_cfg, ctx, self._sh,
                        self._prefix_plan))
                costs_eff = costs * scale  # active units (FLOPs or gCO2e)
                if priced:
                    member = self._cs.tenant_member(k_of)
                    dec = allocate(rewards, costs_eff[:, None], lam,
                                   member)
                else:
                    dec = allocate(rewards, costs_eff, lam)
                mask = valid if padded else None
                tenant_spend = None
                if not self.guard:
                    dg = jnp.int32(0)
                    spend = jnp.sum(jnp.take(costs_eff, dec) * valid)
                    if axis is not None:
                        spend = ordered_psum(spend, axis)
                else:
                    dec, dg, tenant_spend = downgrade_guard(
                        dec, costs_eff, budgets, cheap, mask, k_of=k_of,
                        axis_name=axis)
                    spend = jnp.sum(tenant_spend)
                flops = jnp.sum(jnp.take(costs, dec) * valid)
                if axis is not None:
                    flops = ordered_psum(flops, axis)
                rev = self._execute(tables, dec, rows, valid)
                return (rewards, dec, rev, spend, flops, dg, tenant_spend,
                        None, None)

            if self.mesh is not None:
                fn = shard_map(
                    fn, mesh=self.mesh,
                    in_specs=(P(), tspec, P(AXIS), P(AXIS), P(AXIS),
                              P(AXIS), P(), P(), P()),
                    out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P(),
                               P(), P(), P()))
            return jax.jit(fn)

        def fn(params, tables, ctx, rows, valid, lam, budget, scale):
            rewards = denormalize_rewards(params, reward_matrix_grouped(
                params, self.reward_cfg, ctx, self._sh, self._prefix_plan))
            costs_eff = costs * scale  # active units (FLOPs or gCO2e)
            dec = allocate(rewards, costs_eff, lam)
            mask = valid if padded else None
            if not self.guard:
                dg = jnp.int32(0)
                spend = jnp.sum(jnp.take(costs_eff, dec) * valid)
                if axis is not None:
                    spend = ordered_psum(spend, axis)
            else:
                dec, dg, spend = downgrade_guard(
                    dec, costs_eff, budget, cheap, mask, axis_name=axis)
            flops = jnp.sum(jnp.take(costs, dec) * valid)
            if axis is not None:
                flops = ordered_psum(flops, axis)
            rev = self._execute(tables, dec, rows, valid)
            return rewards, dec, rev, spend, flops, dg, None, None, None

        if self.mesh is not None:
            fn = shard_map(
                fn, mesh=self.mesh,
                in_specs=(P(), tspec, P(AXIS), P(AXIS), P(AXIS), P(), P(),
                          P()),
                out_specs=(P(AXIS), P(AXIS), P(AXIS), P(), P(), P(), P(),
                           P(), P()))
        return jax.jit(fn)

    def _build_dual_fn(self, b: int, padded: bool):
        """Nearline price update: Algorithm 1 on the window's rewards,
        against a traced (budget, scale) pair - by default this window's,
        or the NEXT window's when the driver forecasts (CI warm-start).
        In carbon mode the published price is reward-per-gCO2e.

        The (M, K) dual cost map and (I, K) membership come from the
        compiled ConstraintSpec (``dual_cost_map``/``dual_member``) -
        tenant columns draw a request's spend wherever it is served,
        region columns only from their own region's options.

        With ``donate_dual`` the lambda argument is DONATED: the update
        aliases its output onto the incoming price buffer (same shape/
        dtype, so XLA reuses it in place) and the steady-state chain
        lambda_0 -> lambda_1 -> ... runs allocation-free.  The donated
        buffer is dead afterwards - ``serve_window`` keeps
        ``self._lam_rec`` as the readable twin for records."""
        axis = AXIS if self.mesh is not None else None

        def _jit(fn, lam_argnum):
            if self.donate_dual:
                return jax.jit(fn, donate_argnums=(lam_argnum,))
            return jax.jit(fn)
        cfg = self.dual_cfg
        costs = self._costs
        j_n = int(costs.shape[0])
        cs = self._cs
        r_n = self.n_regions
        priced = cs.tenant_priced
        t_n = None if self.tenant_budgets is None else len(
            self.tenant_budgets)

        if cs.mode == "geotenants":
            def fn(rewards, valid, k_of, lam, budgets, scales):
                mask = valid if padded else None
                opt_costs = (scales[:, None] * costs[None, :]).reshape(-1)
                cost_map = cs.dual_cost_map(opt_costs, j_n)
                member = cs.dual_member(k_of, rewards.shape[0])
                bud = budgets if priced else budgets[t_n:]
                lam_new, _ = dual_descent(
                    jnp.tile(rewards, (1, r_n)), cost_map, bud, lam,
                    mask=mask, member=member, max_iters=cfg.max_iters,
                    step_size=cfg.step_size, step_decay=cfg.step_decay,
                    axis_name=axis)
                return lam_new

            if self.mesh is not None:
                fn = shard_map(fn, mesh=self.mesh,
                               in_specs=(P(AXIS), P(AXIS), P(AXIS), P(),
                                         P(), P()),
                               out_specs=P())
            return _jit(fn, 3)

        if r_n is not None:
            def fn(rewards, valid, lam, budgets, scales):
                mask = valid if padded else None
                opt_costs = (scales[:, None] * costs[None, :]).reshape(-1)
                cost_map = cs.region_cost_map(opt_costs, j_n)
                lam_new, _ = dual_descent(
                    jnp.tile(rewards, (1, r_n)), cost_map, budgets, lam,
                    mask=mask, max_iters=cfg.max_iters,
                    step_size=cfg.step_size, step_decay=cfg.step_decay,
                    axis_name=axis)
                return lam_new

            if self.mesh is not None:
                fn = shard_map(fn, mesh=self.mesh,
                               in_specs=(P(AXIS), P(AXIS), P(), P(),
                                         P()),
                               out_specs=P())
            return _jit(fn, 2)

        if priced:
            def fn(rewards, valid, k_of, lam, budgets, scale):
                mask = valid if padded else None
                member = cs.tenant_member(k_of)
                lam_new, _ = dual_descent(
                    rewards, (costs * scale)[:, None], budgets, lam,
                    mask=mask, member=member, max_iters=cfg.max_iters,
                    step_size=cfg.step_size, step_decay=cfg.step_decay,
                    axis_name=axis)
                return lam_new

            if self.mesh is not None:
                fn = shard_map(fn, mesh=self.mesh,
                               in_specs=(P(AXIS), P(AXIS), P(AXIS), P(),
                                         P(), P()),
                               out_specs=P())
            return _jit(fn, 3)

        def fn(rewards, valid, lam, budget, scale):
            mask = valid if padded else None
            lam_new, _ = dual_descent(
                rewards, costs * scale, budget, lam, mask=mask,
                max_iters=cfg.max_iters, step_size=cfg.step_size,
                step_decay=cfg.step_decay, axis_name=axis)
            return lam_new

        if self.mesh is not None:
            fn = shard_map(fn, mesh=self.mesh,
                           in_specs=(P(AXIS), P(AXIS), P(), P(), P()),
                           out_specs=P())
        return _jit(fn, 2)

    def _bucket(self, n: int) -> int:
        """Pad target for an n-request window.

        ``linear``: the next multiple of ``pad_quantum`` (historical -
        tight padding, but a noisy size distribution visits many
        buckets).  ``pow2``: the next power-of-two MULTIPLE of the
        quantum, so arbitrary 10x-1000x traffic swings land on
        O(log(max/min)) compiled shapes - the zero-steady-state-
        recompile guarantee bench_scale gates on.
        """
        q = self.pad_quantum
        b = max(q, ((n + q - 1) // q) * q)
        if self.bucketing == "pow2":
            b = q * (1 << max(0, (b + q - 1) // q - 1).bit_length())
        return b

    def window_bucket(self, n: int) -> int:
        """Padded size of an n-request window - the GLOBAL bucket every
        host of a multi-process mesh derives identically from n alone
        (tenant windows bucket per block; see ``window_layout``)."""
        if self.tenant_budgets is not None:
            t_n = len(self.tenant_budgets)
            if n % t_n:
                raise ValueError(f"window size {n} not divisible by "
                                 f"{t_n} tenants")
            return self._bucket(n // t_n) * t_n
        return self._bucket(n)

    # -- multi-process array assembly ----------------------------------------

    def _repl(self, x):
        """Host value -> fully-replicated global array on the mesh
        (every process passes the same bytes - pure (seed, t) windows
        and the replicated dual chain guarantee it)."""
        from jax.experimental import multihost_utils
        return multihost_utils.host_local_array_to_global_array(
            np.asarray(x), self.mesh, P())

    def _shard_rows(self, x):
        """This host's rows of a request-sharded (b, ...) array -> the
        global array (rows stay on the host that produced them)."""
        from jax.experimental import multihost_utils
        return multihost_utils.host_local_array_to_global_array(
            np.asarray(x), self.mesh, P(AXIS))

    def _shard_tables(self, x):
        """This host's (G, rows, cap) chunk-table slice -> the global
        (G, b, cap) array sharded along the row axis."""
        from jax.experimental import multihost_utils
        return multihost_utils.host_local_array_to_global_array(
            np.asarray(x), self.mesh, P(None, AXIS, None))

    def compile_count(self) -> int:
        """Total jit cache entries (XLA traces) across every window fn
        this pipeline ever built - the delta per window lands in
        ``WindowResult.compiles``; steady-state traffic on warm buckets
        must hold it at zero."""
        total = 0
        for f in self._built:
            try:
                total += int(f._cache_size())
            except AttributeError:  # older jax: count builds, not traces
                total += 1
        return total

    # -- public API -----------------------------------------------------------

    def _named_vector(self, value, names: tuple, what: str):
        """A named per-axis dict -> the canonical vector (scalar when
        the axis is the single global one); non-dicts pass through."""
        if not isinstance(value, dict):
            return value
        missing = [k for k in names if k not in value]
        extra = [k for k in value if k not in names]
        if missing or extra:
            raise ValueError(
                f"named {what} keys must be exactly {list(names)} "
                f"(missing {missing}, unknown {extra})")
        vec = np.asarray([float(value[k]) for k in names], np.float32)
        return float(vec[0]) if names == ("global",) else vec

    def _pad_chunk_tables(self, tables: dict, n: int, b: int) -> dict:
        """A WindowChunk's (G, n, cap) tables -> the (G, b, cap) traced
        tables of this window.  Padded REQUESTS gather chunk row 0 and
        are valid-masked (exactly like the materialized path's padding
        rows), so the sentinel fill rows here are never read - they only
        keep the traced shape bucket-stable."""
        if "p" not in self._tables:
            raise ValueError("per-window chunk tables need the compact "
                             "(k3) layout; this pipeline runs the "
                             "generic scan kernel")
        p, ck = tables["p"], tables["ck"]
        if p.shape[1] != n:
            raise ValueError(f"chunk tables carry {p.shape[1]} rows for "
                             f"a {n}-request window")
        if isinstance(p, jax.Array):  # device-resident chunk: pad there
            if p.dtype != jnp.int32:
                p = p.astype(jnp.int32)
            if ck.dtype != jnp.float32:
                ck = ck.astype(jnp.float32)
            if b != n:
                p = jnp.pad(p, ((0, 0), (0, b - n), (0, 0)),
                            constant_values=self._cap)
                ck = jnp.pad(ck, ((0, 0), (0, b - n), (0, 0)))
            return {"p": p, "ck": ck, "g_of": self._tables["g_of"],
                    "n3_of": self._tables["n3_of"]}
        p = np.asarray(p, np.int32)
        ck = np.asarray(ck, np.float32)
        if b != n:
            g_n, _, cap = p.shape
            p = np.concatenate(
                [p, np.full((g_n, b - n, cap), self._cap, np.int32)],
                axis=1)
            ck = np.concatenate(
                [ck, np.zeros((g_n, b - n, cap), np.float32)], axis=1)
        self._h2d_window += int(p.nbytes + ck.nbytes)
        return {"p": jnp.asarray(p), "ck": jnp.asarray(ck),
                "g_of": self._tables["g_of"],
                "n3_of": self._tables["n3_of"]}

    def _mh_tables(self, tables: dict) -> dict:
        """A host-local (already padded + sentineled) chunk-table slice
        -> the global row-sharded tables of the multi-process pass.
        The (G,)/(J,) layout vectors are replicated once and cached."""
        if "p" not in tables:
            raise ValueError("multihost serving needs the compact (k3) "
                             "chunk-table layout")
        p = np.asarray(tables["p"], np.int32)
        ck = np.asarray(tables["ck"], np.float32)
        self._h2d_window += int(p.nbytes + ck.nbytes)
        if self._layout_mh is None:
            self._layout_mh = {
                "g_of": self._repl(self._tables["g_of"]),
                "n3_of": self._repl(self._tables["n3_of"]),
            }
        return {"p": self._shard_tables(p),
                "ck": self._shard_tables(ck), **self._layout_mh}

    def serve_window(self, ctx: np.ndarray, rows: np.ndarray, *,
                     lam=None, update_lam: bool = True, budget=None,
                     cost_scale=None, dual_budget=None,
                     dual_cost_scale=None,
                     tables: dict | None = None,
                     shard=None) -> WindowResult:
        """Serve one traffic window.

        ctx (n, d_context) raw contexts, rows (n,) user indices into the
        server's score tables - or, with ``tables`` (a ``WindowChunk``'s
        per-window (G, n, cap) compact tables), LOCAL chunk indices
        0..n-1: the fused pass then gathers within the chunk instead of
        a materialized user axis, which is how a streaming
        ``RequestSource`` serves unbounded universes (REQUIRED when the
        pipeline was built over a ``StreamUniverse``).  Decisions use
        ``lam`` (default: the pipeline's nearline price(s), i.e.
        lambda_{t-1}); the pass then publishes lambda_t unless
        ``update_lam=False``.

        ``budget`` overrides this window's budget (scalar; (T,) with
        tenant blocks; (R,) in geo mode and (T + R,) - tenant grams
        first, region grams after - in the combined mode, REQUIRED
        there together with an (R,) ``cost_scale``).  Both accept the
        NAMED form: a dict keyed by ``spec.compile().budget_names`` /
        ``.scale_names`` (the ``k_names`` constraint order) instead of
        a positional vector - the vector form stays bit-identical.
        ``cost_scale`` re-denominates the window's costs as
        ``costs * cost_scale`` - carbon pricing passes kappa*CI(t)
        [gCO2e/FLOP] here together with a gCO2e ``budget``, making the
        dual price reward-per-gram.  All are traced, so time-varying
        values never recompile; ``WindowResult.compiles`` reports this
        window's jit cache misses (nonzero only on a cold bucket).

        ``dual_budget``/``dual_cost_scale`` aim the NEARLINE update at a
        different (budget, scale) than the online pass - pass the NEXT
        window's values to warm-start the price where the grid is about
        to be (the CI-forecast warm-start; defaults: the online values).

        ``shard`` (a ``repro.distributed.multihost.HostWindowSlice``,
        normally carried by a ``MultihostSource`` chunk) switches the
        call to the MULTI-PROCESS window protocol: ``ctx``/``rows``/
        ``tables`` are this host's ALREADY-PADDED slice of the global
        window (``shard`` names the global n/bucket and the local
        valid/k_of), the pass runs over global arrays assembled from
        every host's slice, and the stitched collectives make lambda,
        spends and counters replicated - bitwise equal on every host.
        """
        if shard is not None and not self.multihost:
            raise ValueError("serve_window(shard=...) needs a pipeline "
                             "built over the multi-process mesh "
                             "(multihost=True)")
        if self.multihost and shard is None:
            raise ValueError("a multihost pipeline serves host slices: "
                             "pass shard= (use a MultihostSource)")
        n = len(rows) if shard is None else int(shard.n)
        ctx = np.asarray(ctx, np.float32)
        rows = np.asarray(rows, np.int32)
        if self._stream_only and tables is None and n:
            raise ValueError(
                "this pipeline serves a streaming universe: every "
                "window must carry its RequestSource chunk tables "
                "(serve_window(..., tables=chunk.tables))")
        bn = self._cs.budget_names
        budget = self._named_vector(budget, bn, "budget")
        dual_budget = self._named_vector(dual_budget, bn, "dual_budget")
        sn = self._cs.scale_names
        cost_scale = self._named_vector(cost_scale, sn, "cost_scale")
        dual_cost_scale = self._named_vector(dual_cost_scale, sn,
                                             "dual_cost_scale")
        cs = self._cs
        mode = cs.mode
        geo = mode == "geo"
        combined = mode == "geotenants"
        tb = self.tenant_budgets

        if combined:
            t_n, r_n = len(tb), self.n_regions
            if budget is None or cost_scale is None:
                raise ValueError(
                    "the combined tenant x region mode serves against "
                    "per-tenant AND per-region budgets: pass a "
                    f"({t_n} + {r_n},) budget (tenant grams first) and "
                    f"an ({r_n},) cost_scale every window")
            bud_vec = np.asarray(budget, np.float32).reshape(-1)
            sc_vec = np.asarray(cost_scale, np.float32).reshape(-1)
            if len(bud_vec) != t_n + r_n or len(sc_vec) != r_n:
                raise ValueError(
                    f"combined budget/cost_scale must have {t_n + r_n} "
                    f"and {r_n} entries, got {len(bud_vec)} and "
                    f"{len(sc_vec)}")
            # the tightest aggregate cap the chained walks enforce
            bud = float(min(bud_vec[:t_n].sum(), bud_vec[t_n:].sum()))
            sc = float(sc_vec.mean())
        elif geo:
            if budget is None or cost_scale is None:
                raise ValueError("geo mode serves against per-region "
                                 "budgets: pass (R,) budget and (R,) "
                                 "cost_scale every window")
            bud_vec = np.asarray(budget, np.float32).reshape(-1)
            sc_vec = np.asarray(cost_scale, np.float32).reshape(-1)
            if len(bud_vec) != self.n_regions \
                    or len(sc_vec) != self.n_regions:
                raise ValueError(f"geo budget/cost_scale must have "
                                 f"{self.n_regions} entries")
            bud, sc = float(bud_vec.sum()), float(sc_vec.mean())
        elif tb is not None:
            if budget is None:
                bud_vec = tb
            else:
                bud_vec = np.asarray(budget, np.float32).reshape(-1)
                if len(bud_vec) != len(tb):
                    raise ValueError(f"tenant budget override must have "
                                     f"{len(tb)} entries")
            sc = 1.0 if cost_scale is None else float(cost_scale)
            bud = float(bud_vec.sum())
        else:
            bud = self.budget if budget is None else float(budget)
            sc = 1.0 if cost_scale is None else float(cost_scale)
            bud_vec = None

        if n == 0:  # zero-arrival window: nothing to serve or learn from
            r_n = self.n_regions
            res = WindowResult(
                n_valid=0, budget=bud, lam_before=self._lam_rec,
                lam_after=self._lam_rec, decisions=jnp.zeros(0, jnp.int32),
                revenue=jnp.zeros(0, jnp.float32),
                spend=jnp.float32(0.0), downgraded=jnp.int32(0),
                valid=np.zeros(0, np.float32), flops=jnp.float32(0.0),
                cost_scale=sc,
                regions=(jnp.zeros(0, jnp.int32) if r_n is not None
                         else None),
                region_spend=(jnp.zeros(r_n, jnp.float32)
                              if r_n is not None else None),
                tr_spend=(jnp.zeros((len(tb), r_n), jnp.float32)
                          if combined else None),
                tenant_spend=(jnp.zeros(len(tb), jnp.float32)
                              if combined else None),
                k_budget=None if bud_vec is None else np.array(bud_vec))
            self.stats.append(res)
            if self.ledger is not None:
                self.ledger.record_result(res)
            return res

        chunked = tables is not None
        if shard is not None:
            # multi-process window: the source already laid out this
            # host's padded slice (window_layout positions lo..hi); the
            # global (n, b) pair keys the SAME bucket on every host
            if not chunked:
                raise ValueError("multihost serving streams chunk "
                                 "tables; materialized (U, J) serving "
                                 "is single-process only")
            b = int(shard.b)
            valid = np.asarray(shard.valid, np.float32)
            k_of = (None if shard.k_of is None
                    else np.asarray(shard.k_of, np.int32))
            perm = None
        else:
            # tenant windows carry T equal blocks, padded at the end of
            # EACH block so per-tenant guard walks and prices see blocks
            # aligned with their budgets; plain windows pad at the end
            b = self.window_bucket(n)
            perm, valid, k_of = window_layout(
                n, b, None if tb is None else len(tb))
            if b != n:
                m = valid > 0
                ctx_p = np.zeros((b, ctx.shape[1]), np.float32)
                rows_p = np.zeros(b, np.int32)
                ctx_p[m] = ctx[perm[m]]
                rows_p[m] = rows[perm[m]]
                ctx, rows = ctx_p, rows_p
        self._h2d_window = int(ctx.nbytes + rows.nbytes + valid.nbytes
                               + (k_of.nbytes if k_of is not None else 0))
        with self.obs.span("h2d", n=n, b=b):
            if shard is not None:
                run_tables = self._mh_tables(tables)
                ctx_j = self._shard_rows(ctx)
                rows_j = self._shard_rows(rows.astype(np.int32))
            elif chunked:
                run_tables = self._pad_chunk_tables(tables, n, b)
                rows = perm.astype(np.int32)  # gather within padded chunk
                ctx_j = jnp.asarray(ctx)
                rows_j = jnp.asarray(rows, jnp.int32)
            else:
                run_tables = self._tables
                ctx_j = jnp.asarray(ctx)
                rows_j = jnp.asarray(rows, jnp.int32)
        key = (b, b != n, chunked)
        if key not in self._fns:
            self._fns[key] = (self._build_main_fn(b, b != n),
                              self._build_dual_fn(b, b != n))
            self._built.extend(self._fns[key])
        main_fn, dual_fn = self._fns[key]
        c0 = self.compile_count()
        params = self.reward_params
        if shard is not None:
            # global twins of host-resident state, built once: params
            # replicate to every host's devices; the lambda chain is
            # converted in place and stays global from then on (dual-fn
            # outputs over the process-spanning mesh are global already)
            if self._params_mh is None:
                self._params_mh = jax.tree_util.tree_map(
                    self._repl, self.reward_params)
            params = self._params_mh
            if not self._mh_lam:
                self.lam = self._repl(self.lam)
                self._lam_rec = self._repl(self._lam_rec)
                self._mh_lam = True
            _c = self._repl  # replicated scalars / (K,) vectors
            _k = self._shard_rows  # request-sharded per-position maps
        else:
            _c = _k = jnp.asarray
        if lam is None:
            lam_in = self.lam
            lam_before_rec = self._lam_rec
        else:
            lam_in = _c(np.broadcast_to(np.asarray(lam, np.float32),
                                        np.shape(self.lam)))
            lam_before_rec = lam_in
        # the dual fn DONATES its lambda argument: hand it the chain
        # buffer only when this call advances the chain; otherwise (a
        # pinned price, or update_lam=False keeping the old chain) a
        # bitwise device copy is consumed so live buffers survive
        if not self.donate_dual:
            lam_dual = lam_in
        elif lam is None and update_lam:
            lam_dual = lam_in
        else:
            lam_dual = jnp.copy(lam_in)
        valid_j = _k(valid) if shard is not None else jnp.asarray(valid)
        k_of_j = None if k_of is None else _k(k_of)

        if combined:
            bud_j = _c(np.asarray(bud_vec, np.float32))
            sc_j = _c(np.asarray(sc_vec, np.float32))
            args = (k_of_j, lam_in, bud_j, sc_j)
        elif geo:
            bud_j = _c(np.asarray(bud_vec, np.float32))
            sc_j = _c(np.asarray(sc_vec, np.float32))
            args = (lam_in, bud_j, sc_j)
        elif tb is not None:
            bud_j = _c(np.asarray(bud_vec, np.float32))
            sc_j = _c(np.float32(sc))
            args = (k_of_j, lam_in, bud_j, sc_j)
        else:
            bud_j, sc_j = _c(np.float32(bud)), _c(np.float32(sc))
            args = (lam_in, bud_j, sc_j)
        with self.obs.span("dispatch", n=n, b=b):
            out = main_fn(params, run_tables, ctx_j, rows_j, valid_j,
                          *args)
        (rewards, dec, rev, spend, flops, dg, t_spend, regions,
         r_spend) = out[:9]
        tr_spend = out[9] if len(out) > 9 else None

        # nearline: the price update never blocks the response - it is a
        # second dispatch reusing the on-device reward matrix, and the
        # NEXT window's decisions depend on its (device-side) output.
        # dual_budget/dual_cost_scale retarget it at the next window's
        # constraint (CI-forecast warm-start); defaults keep this
        # window's, bit-identical to the non-forecast behavior.
        with self.obs.span("dual_update", n=n, b=b):
            if combined:
                d_bud = bud_j if dual_budget is None \
                    else _c(np.asarray(dual_budget,
                                       np.float32).reshape(-1))
                d_sc = sc_j if dual_cost_scale is None \
                    else _c(np.asarray(dual_cost_scale, np.float32))
                lam_new = dual_fn(rewards, valid_j, k_of_j,
                                  lam_dual, d_bud, d_sc)
            elif geo:
                d_bud = bud_j if dual_budget is None \
                    else _c(np.asarray(dual_budget, np.float32))
                d_sc = sc_j if dual_cost_scale is None \
                    else _c(np.asarray(dual_cost_scale, np.float32))
                lam_new = dual_fn(rewards, valid_j, lam_dual, d_bud, d_sc)
            elif tb is not None:
                d_bud = bud_j if dual_budget is None \
                    else _c(np.asarray(dual_budget,
                                       np.float32).reshape(-1))
                d_sc = sc_j if dual_cost_scale is None \
                    else _c(np.float32(dual_cost_scale))
                if cs.tenant_priced:
                    lam_new = dual_fn(rewards, valid_j, k_of_j,
                                      lam_dual, d_bud, d_sc)
                else:  # shared price descends on the TOTAL budget
                    lam_new = dual_fn(rewards, valid_j, lam_dual,
                                      jnp.sum(d_bud), d_sc)
            else:
                d_bud = bud_j if dual_budget is None else _c(
                    np.float32(dual_budget))
                d_sc = sc_j if dual_cost_scale is None else _c(
                    np.float32(dual_cost_scale))
                lam_new = dual_fn(rewards, valid_j, lam_dual, d_bud, d_sc)
        if update_lam:
            self.lam = lam_new
            # the chain buffer will be donated next window; records keep
            # a bitwise device copy that stays readable forever
            self._lam_rec = jnp.copy(lam_new) if self.donate_dual \
                else lam_new
            lam_after_rec = self._lam_rec
        else:  # orphan price: never enters the chain, never donated
            lam_after_rec = lam_new
        res = WindowResult(
            n_valid=n, budget=bud, lam_before=lam_before_rec,
            lam_after=lam_after_rec, decisions=dec, revenue=rev,
            spend=spend,
            downgraded=dg, valid=valid, tenant_spend=t_spend, flops=flops,
            cost_scale=sc, regions=regions, region_spend=r_spend,
            k_budget=None if bud_vec is None else np.array(bud_vec),
            tr_spend=tr_spend, compiles=self.compile_count() - c0,
            bucket=key, h2d_bytes=self._h2d_window)
        self.stats.append(res)
        if self.ledger is not None:
            self.ledger.record_result(res)
        return res

    def spend_trace(self) -> np.ndarray:
        return np.array([float(np.sum(np.asarray(r.spend)))
                         for r in self.stats])
