"""Prefetching streaming driver + pluggable traffic scenarios.

``run_stream`` drives a ServingPipeline through a traffic scenario the
way a production frontend would: a background prefetch thread produces
window chunks (sampling arrivals, hashing user rows, dispatching chunk
scoring) into a bounded queue while the serving thread dispatches each
window's fused pass (jax async dispatch - device arrays come back
immediately) and only blocks when a chunk is not ready yet (the
per-window ``stall_ms``).  The nearline price update chains
device-side - with buffer donation it updates the price buffer in
place - so the host never blocks on it.  ``prefetch=0`` falls back to
the sequential double-buffered loop, bitwise identical (each window is
a pure function of (seed, t)).

Scenarios live in the ``SCENARIOS`` registry: one dict of builder
functions mapping a scenario name to its per-window request counts.
The registry is the SINGLE source of truth for valid scenario names -
``scenario_windows``'s error message and ``launch/serve.py``'s
``--scenario`` choices both derive from it, and each scenario's
canonical ConstraintSpec shape (what the serve driver builds for it)
is documented on its builder.  ``run_stream`` optionally threads
per-window budget and cost-scale traces into the pipeline, which is
how the carbon/geo scenarios price each window at its grid intensity.
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

import numpy as np

from repro.obs import MS_EDGES, get_obs
from repro.obs.metrics import log2_edges
from repro.serving.pipeline import ServingPipeline, WindowResult


@dataclass(frozen=True)
class TrafficScenario:
    """A named per-window traffic shape.

    ``name`` selects a builder from the ``SCENARIOS`` registry (see the
    builders' docstrings for each shape and its canonical
    ConstraintSpec wiring in ``launch/serve.py``).
    """

    name: str
    n_windows: int
    n_base: int
    spike_mult: float = 3.0
    n_tenants: int = 1

    def window_sizes(self) -> list[int]:
        return scenario_windows(self)


def _constant_windows(sc: TrafficScenario) -> list[int]:
    """``n_base`` requests every window (steady state)."""
    return [sc.n_base] * sc.n_windows


def _spike_windows(sc: TrafficScenario) -> list[int]:
    """``n_base`` with a ``spike_mult`` x burst over the 3 windows
    starting at the first third (paper Fig. 5 protocol: the dual price
    lags the burst, the guard absorbs it)."""
    sizes = []
    for t in range(sc.n_windows):
        burst = sc.n_windows // 3 <= t < sc.n_windows // 3 + 3
        sizes.append(int(sc.n_base * (sc.spike_mult if burst else 1.0)))
    return sizes


def _diurnal_windows(sc: TrafficScenario) -> list[int]:
    """One full day-curve sinusoid over ``n_windows``, swinging between
    ~0.4x and ~1.6x of ``n_base``."""
    sizes = []
    for t in range(sc.n_windows):
        phase = 2.0 * math.pi * t / max(1, sc.n_windows)
        sizes.append(int(sc.n_base * (1.0 + 0.6 * math.sin(phase))))
    return sizes


def _tenants_windows(sc: TrafficScenario) -> list[int]:
    """Constant traffic in ``n_tenants`` equal blocks per window
    (spec: ``[TenantAxis(budgets, priced=...)]`` - per-tenant budgets
    under one shared dual price, per-tenant prices, or independent
    pipelines - see launch/serve.py --tenant-mode)."""
    return _constant_windows(sc)


def _carbon_windows(sc: TrafficScenario) -> list[int]:
    """The diurnal day-curve, intended to be paired with a
    grid-intensity trace (intensity x traffic): the driver prices each
    window at kappa*CI(t) and budgets it in gCO2e (spec:
    ``[GlobalAxis(pricing="carbon")]``; see repro.carbon and
    launch/serve.py --scenario carbon).  Window counts are the same day
    shape as ``diurnal``; the carbon part lives in the per-window
    (budget, cost_scale) traces fed to ``run_stream``."""
    return _diurnal_windows(sc)


def _georegions_windows(sc: TrafficScenario) -> list[int]:
    """The day-curve served by the two-region geo-shifting router
    (spec: ``[RegionAxis(2), GlobalAxis(pricing="carbon")]``): the
    pipeline takes per-window (R,) gram budgets and (R,) kappa*CI_r(t)
    cost scales, and each request picks its serving region through the
    priced argmax (see launch/serve.py --scenario georegions)."""
    return _diurnal_windows(sc)


def _geotenants_windows(sc: TrafficScenario) -> list[int]:
    """The day-curve with BOTH axes live (spec:
    ``[TenantAxis(budgets, priced=True), RegionAxis(2),
    GlobalAxis(pricing="carbon")]``): per-tenant gram budgets AND
    per-region gram caps priced together in one fused pass - a
    tenant-t request pays (lam_tenant[t] + lam_region[r]) * c_{j,r}
    (see launch/serve.py --scenario geotenants)."""
    return _diurnal_windows(sc)


def _swing_windows(sc: TrafficScenario) -> list[int]:
    """Decade-ladder traffic swings: window sizes cycle through
    ``n_base`` x {1, 10, 100, ...} up to ``spike_mult`` (so
    spike_mult=1000 exercises 4 decades), the bench_scale protocol for
    proving the bucketed-padding jit cache absorbs 10x-1000x swings
    with ZERO steady-state recompiles (``bucketing='pow2'`` keeps the
    compiled-shape count logarithmic in the swing)."""
    decades = max(1, int(math.log10(max(10.0, sc.spike_mult))) + 1)
    mults = [10.0 ** d for d in range(decades)]
    return [int(sc.n_base * mults[t % decades])
            for t in range(sc.n_windows)]


# The ONE registry of traffic scenarios: name -> per-window size
# builder.  launch/serve.py's --scenario choices and the unknown-name
# error below both derive from these keys; each builder's docstring
# names the canonical ConstraintSpec the serve driver compiles for it.
SCENARIOS: dict = {
    "constant": _constant_windows,
    "spike": _spike_windows,
    "diurnal": _diurnal_windows,
    "tenants": _tenants_windows,
    "carbon": _carbon_windows,
    "georegions": _georegions_windows,
    "geotenants": _geotenants_windows,
    "swing": _swing_windows,
}


def scenario_windows(sc: TrafficScenario) -> list[int]:
    """Per-window request counts for a scenario."""
    try:
        builder = SCENARIOS[sc.name]
    except KeyError:
        raise ValueError(f"unknown scenario {sc.name!r}: valid "
                         f"scenarios are {', '.join(SCENARIOS)}") \
            from None
    sizes = builder(sc)
    out = []
    for n in sizes:
        if sc.n_tenants > 1:  # keep tenant blocks equal-sized
            n = max(sc.n_tenants, n - n % sc.n_tenants)
        out.append(max(1, n))
    return out


@dataclass
class StreamStats:
    """Host-side view of a finished streaming run.

    Timing is attributed per window: ``prep_ms`` is host chunk
    production (arrival sampling, hashing, scoring dispatch - off the
    critical path when prefetching), ``submit_ms`` is the
    ``serve_window`` dispatch, and ``stall_ms`` is how long the serving
    thread actually waited for a chunk that was not ready.  The legacy
    ``dispatch_ms`` survives as the per-window prep + submit sum."""

    windows: list[WindowResult]
    sizes: list[int]
    submit_ms: list[float]  # host time per serve_window dispatch
    wall_s: float

    @property
    def prep_ms(self) -> list[float]:
        return [float(r.prep_ms) for r in self.windows]

    @property
    def stall_ms(self) -> list[float]:
        return [float(r.stall_ms) for r in self.windows]

    @property
    def dispatch_ms(self) -> list[float]:
        """Legacy aggregate: per-window prep + submit (the two used to
        be timed as one number)."""
        return [p + s for p, s in zip(self.prep_ms, self.submit_ms)]

    @property
    def h2d_bytes(self) -> int:
        """Total host->device bytes across the run (chunk production +
        per-window serving uploads)."""
        return int(sum(int(r.h2d_bytes) for r in self.windows))

    @property
    def total_revenue(self) -> float:
        return float(sum(r.revenue_np.sum() for r in self.windows))

    @property
    def total_spend(self) -> float:
        from repro.obs.events import _host_np
        return float(sum(float(np.sum(_host_np(r.spend)))
                         for r in self.windows))

    def overshoot(self, c_min: float) -> float:
        """Max relative spend overshoot vs. max(budget, n*c_min)."""
        from repro.obs.events import _host_np
        worst = 0.0
        for r in self.windows:
            cap = max(r.budget, r.n_valid * c_min)
            worst = max(worst, float(np.sum(_host_np(r.spend))) / cap - 1.0)
        return worst

    @property
    def compiles(self) -> list[int]:
        """Per-window jit cache misses (WindowResult.compiles)."""
        return [int(r.compiles) for r in self.windows]

    @property
    def steady_compiles(self) -> int:
        """Cache misses in STEADY STATE: total compiles in windows
        whose padding bucket was already served earlier in the run.
        Bucketed padding promises this is ZERO however traffic swings -
        every shape compiles once, on its first appearance."""
        seen: set = set()
        steady = 0
        for r in self.windows:
            if r.bucket in seen:
                steady += int(r.compiles)
            seen.add(r.bucket)
        return steady


def run_stream(pipeline: ServingPipeline, sizes: list[int],
               source, *, lam_trace=None, budget_trace=None,
               scale_trace=None, forecast: bool = False,
               prefetch: int = 2, obs=None,
               clock=None) -> StreamStats:
    """Drive the pipeline through ``sizes``, prefetching host prep.

    ``source`` produces each window's arrivals and runs while the
    device executes the previous window.  Two forms:

    - a ``data.request_source.RequestSource`` (anything with a
      ``.window(t, n)`` method): each window's ``WindowChunk`` carries
      freshly generated/replayed contexts, LOCAL rows and per-chunk
      score tables, which ``serve_window(..., tables=...)`` gathers
      in-window - no (U, J) universe ever materializes on the device.
    - a plain callable ``sample_window(t, n) -> (ctx (n, d), rows
      (n,))`` indexing a materialized server (the legacy form).

    lam_trace optionally pins the per-window entry price (parity
    testing); budget_trace / scale_trace set each window's budget and
    cost scale (e.g. a ``CarbonBudget.schedule``'s grams + kappa*CI(t)
    columns; in geo mode each entry is the (R,) per-region vector, in
    the combined tenant x region mode the (T + R,) concatenation -
    tenant grams first; each entry may also be the NAMED dict form
    keyed by ``spec.compile().budget_names``) - all are traced by the
    pipeline, so they never recompile.

    ``forecast=True`` is the CI-forecast warm-start for the nearline
    dual update: window t's price update runs against window t+1's
    (budget, scale) - both known ahead of time, the grid-intensity
    trace is a forecastable signal - so the published price lands where
    the NEXT window's CI needs it instead of lagging the swing by one
    window (the lambda-lag gap benchmarked in bench_carbon.py).  With
    constant traces this is a bit-exact no-op.

    ``prefetch`` > 0 moves chunk production to ONE background thread
    feeding a bounded queue (depth = ``prefetch``): the serving thread
    only blocks when a chunk is not ready yet (recorded per window as
    ``stall_ms``), and host prep genuinely overlaps device execution
    instead of merely overlapping async dispatch.  Windows are produced
    strictly in t order by a single worker and every window is a pure
    function of (seed, t), so the prefetched stream is BITWISE
    identical to ``prefetch=0`` (the sequential double-buffered path,
    kept as the parity/debug reference).

    ``obs`` (an ``repro.obs.Obs`` bundle, default off) records spans
    ("prep" on the producer thread, "serve"/"stall" on the serving
    thread, "block_until_ready" around the final drain) and per-window
    metrics; ``clock`` (default ``time.perf_counter``) is the timing
    source for every host measurement, injectable so tests can pin
    prep/stall/submit attribution with a fake clock.  Neither touches
    the numerics: telemetry-on runs are bitwise identical.
    """
    streaming = hasattr(source, "window")
    obs = get_obs(obs)
    if clock is None:
        clock = time.perf_counter
    m = obs.metrics
    windows_c = m.counter("greenflow_windows_total",
                          "serving windows completed")
    reqs_c = m.counter("greenflow_requests_total",
                       "requests served across windows")
    size_h = m.histogram("greenflow_window_size",
                         "requests per window", "1",
                         log2_edges(1.0, float(1 << 22)))
    prep_h = m.histogram("greenflow_prep_ms",
                         "host chunk production time", "ms", MS_EDGES)
    stall_h = m.histogram("greenflow_stall_ms",
                          "serving-thread wait for an unready chunk",
                          "ms", MS_EDGES)
    submit_h = m.histogram("greenflow_submit_ms",
                           "serve_window dispatch time", "ms", MS_EDGES)
    h2d_c = m.counter("greenflow_h2d_bytes_total",
                      "host->device bytes uploaded", "bytes")
    compiles_c = m.counter("greenflow_compiles_total",
                           "jit cache misses")
    bucket_c = m.counter("greenflow_bucket_windows_total",
                         "windows served per padding bucket")

    def _prep(t: int, n: int):
        with obs.span("prep", t=t, n=n):
            p0 = clock()
            if streaming:
                chunk = source.window(t, n)
                out = (chunk.ctx, chunk.rows, chunk.tables,
                       int(getattr(chunk, "h2d_bytes", 0)),
                       getattr(chunk, "shard", None))
            else:
                ctx, rows = source(t, n)
                out = (ctx, rows, None, 0, None)
            return out + ((clock() - p0) * 1e3,)

    t0 = clock()
    submit_ms: list[float] = []
    results: list[WindowResult] = []
    last = len(sizes) - 1

    def _serve(t: int, item, stall: float):
        ctx, rows, tables, h2d, shard, prep = item
        d0 = clock()
        lam = None if lam_trace is None else lam_trace[t]
        t_next = min(t + 1, last)  # final window: nothing left to aim at
        with obs.span("serve", t=t, n=sizes[t]):
            res = pipeline.serve_window(
                ctx, rows, lam=lam, tables=tables, shard=shard,
                budget=None if budget_trace is None else budget_trace[t],
                cost_scale=None if scale_trace is None
                else scale_trace[t],
                dual_budget=(budget_trace[t_next]
                             if forecast and budget_trace is not None
                             else None),
                dual_cost_scale=(scale_trace[t_next]
                                 if forecast and scale_trace is not None
                                 else None))
        submit = (clock() - d0) * 1e3
        submit_ms.append(submit)
        res.prep_ms += prep
        res.stall_ms += stall
        res.h2d_bytes += h2d
        results.append(res)
        # per-window host-side metrics (never reads a device array)
        windows_c.inc()
        reqs_c.inc(sizes[t])
        size_h.observe(sizes[t])
        prep_h.observe(res.prep_ms)
        stall_h.observe(res.stall_ms)
        submit_h.observe(submit)
        h2d_c.inc(int(res.h2d_bytes))
        compiles_c.inc(int(res.compiles))
        if res.bucket is not None:
            bucket_c.labels(bucket=res.bucket).inc()
        if obs.interval > 0 and t % obs.interval == 0:
            print(obs.live_line(t, res, submit))

    if prefetch > 0:
        import queue
        import threading

        q: queue.Queue = queue.Queue(maxsize=max(1, int(prefetch)))

        def _worker():
            try:
                for t, n in enumerate(sizes):
                    q.put(_prep(t, n))
            except BaseException as e:  # surface in the serving thread
                q.put(e)

        th = threading.Thread(target=_worker, daemon=True,
                              name="chunk-prefetch")
        th.start()
        try:
            for t, n in enumerate(sizes):
                s0 = clock()
                with obs.span("stall", t=t):
                    item = q.get()
                stall = (clock() - s0) * 1e3
                if isinstance(item, BaseException):
                    raise item
                _serve(t, item, stall)
        finally:
            while th.is_alive():  # unblock a worker stuck on q.put
                try:
                    q.get_nowait()
                except queue.Empty:
                    pass
                th.join(timeout=0.05)
    else:  # sequential double-buffered reference path
        nxt = _prep(0, sizes[0])
        for t, n in enumerate(sizes):
            _serve(t, nxt, 0.0)
            if t + 1 < len(sizes):  # prep t+1 while the device runs t
                nxt = _prep(t + 1, sizes[t + 1])
    with obs.span("block_until_ready", windows=len(results)):
        for r in results:  # drain: force every window's device work
            r.revenue_np
    stats = StreamStats(windows=results, sizes=list(sizes),
                        submit_ms=submit_ms,
                        wall_s=clock() - t0)
    # gauges + JSONL flight log: only AFTER the drain, so these device
    # reads can no longer stall the serving path
    obs.flush_stream(stats, cs=getattr(pipeline, "_cs", None),
                     ledger=getattr(pipeline, "ledger", None))
    return stats
