"""Double-buffered streaming driver + pluggable traffic scenarios.

``run_stream`` drives a ServingPipeline through a traffic scenario the
way a production frontend would: window t's pass is DISPATCHED (jax
async dispatch - device arrays come back immediately), then the host
prepares window t+1 (sampling arrivals, building contexts, padding)
while the device is still executing, and only then does the host read
window t's results.  The nearline price update chains device-side, so
the host never blocks on it.

Scenarios yield per-window request counts:

  constant  - n_base forever;
  spike     - n_base, with a ``spike_mult`` x burst in the middle third
              (paper Fig. 5 protocol);
  diurnal   - a day-curve sinusoid between ~0.4x and 1.6x of n_base;
  tenants   - constant traffic split into T equal tenant blocks; the
              pipeline enforces per-tenant budgets under ONE shared dual
              price (vs. running T independent pipelines - see
              launch/serve.py --tenant-mode).
"""
from __future__ import annotations

import math
import time
from dataclasses import dataclass

import numpy as np

from repro.serving.pipeline import ServingPipeline, WindowResult


@dataclass(frozen=True)
class TrafficScenario:
    name: str
    n_windows: int
    n_base: int
    spike_mult: float = 3.0
    n_tenants: int = 1

    def window_sizes(self) -> list[int]:
        return scenario_windows(self)


def scenario_windows(sc: TrafficScenario) -> list[int]:
    """Per-window request counts for a scenario."""
    sizes = []
    for t in range(sc.n_windows):
        if sc.name == "constant" or sc.name == "tenants":
            n = sc.n_base
        elif sc.name == "spike":
            burst = sc.n_windows // 3 <= t < sc.n_windows // 3 + 3
            n = int(sc.n_base * (sc.spike_mult if burst else 1.0))
        elif sc.name == "diurnal":
            phase = 2.0 * math.pi * t / max(1, sc.n_windows)
            n = int(sc.n_base * (1.0 + 0.6 * math.sin(phase)))
        else:
            raise ValueError(f"unknown scenario {sc.name!r}")
        if sc.n_tenants > 1:  # keep tenant blocks equal-sized
            n = max(sc.n_tenants, n - n % sc.n_tenants)
        sizes.append(max(1, n))
    return sizes


@dataclass
class StreamStats:
    """Host-side view of a finished streaming run."""

    windows: list[WindowResult]
    sizes: list[int]
    dispatch_ms: list[float]  # host time per submit (prep + dispatch)
    wall_s: float

    @property
    def total_revenue(self) -> float:
        return float(sum(r.revenue_np.sum() for r in self.windows))

    @property
    def total_spend(self) -> float:
        return float(sum(float(r.spend) for r in self.windows))

    def overshoot(self, c_min: float) -> float:
        """Max relative spend overshoot vs. max(budget, n*c_min)."""
        worst = 0.0
        for r in self.windows:
            cap = max(r.budget, r.n_valid * c_min)
            worst = max(worst, float(r.spend) / cap - 1.0)
        return worst


def run_stream(pipeline: ServingPipeline, sizes: list[int],
               sample_window, *, lam_trace=None) -> StreamStats:
    """Drive the pipeline through ``sizes``, double-buffering host prep.

    sample_window(t, n) -> (ctx (n, d), rows (n,)) produces window t's
    arrivals; it runs while the device executes window t-1.  lam_trace
    optionally pins the per-window entry price (parity testing).
    """
    t0 = time.perf_counter()
    dispatch_ms: list[float] = []
    results: list[WindowResult] = []
    nxt = sample_window(0, sizes[0])
    for t, n in enumerate(sizes):
        ctx, rows = nxt
        d0 = time.perf_counter()
        lam = None if lam_trace is None else lam_trace[t]
        results.append(pipeline.serve_window(ctx, rows, lam=lam))
        dispatch_ms.append((time.perf_counter() - d0) * 1e3)
        if t + 1 < len(sizes):  # prep t+1 while the device runs t
            nxt = sample_window(t + 1, sizes[t + 1])
    for r in results:  # drain: force every window's device work
        r.revenue_np
    return StreamStats(windows=results, sizes=list(sizes),
                       dispatch_ms=dispatch_ms,
                       wall_s=time.perf_counter() - t0)
