"""Synthetic Ali-CCP-style click log (see DESIGN.md §8 for why synthetic).

A latent-utility model generates structurally-faithful traffic:

  * users: latent taste z_u in R^dl, activity a_u ~ heavy-tailed lognormal
    (the paper's "users with varying levels of activity" whose reward
    curves differ - the property GreenFlow exploits);
  * items: latent z_i, popularity pop_i ~ zipf-ish, category from a
    clustering of z_i;
  * click model: p(u clicks i) = sigmoid(s * <z_u, z_i> + pop_i + b_u)
    with activity entering through b_u - active users click more and
    saturate earlier (=> concave reward curves with different slopes);
  * per-user behavior history sampled proportional to affinity;
  * categorical user/item features are quantized projections of the
    latents (so models CAN learn preferences from ids).

Everything is generated lazily from a seed - the 85M-sample scale of
Ali-CCP is samplable without materializing it.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class WorldConfig:
    n_users: int = 20_000
    n_items: int = 4_000
    n_cats: int = 50
    d_latent: int = 16
    hist_len: int = 50
    n_user_fields: int = 4
    user_field_vocab: int = 64  # per-field quantization buckets
    click_scale: float = 4.0
    click_bias: float = -2.0
    seed: int = 0


@dataclass
class World:
    cfg: WorldConfig
    z_user: np.ndarray  # (U, dl)
    z_item: np.ndarray  # (I, dl)
    activity: np.ndarray  # (U,) in (0, inf), heavy tailed
    popularity: np.ndarray  # (I,)
    item_cat: np.ndarray  # (I,) int
    user_fields: np.ndarray  # (U, F) int
    hist_ids: np.ndarray  # (U, T) int
    hist_mask: np.ndarray  # (U, T) float

    # ---- click ground truth -------------------------------------------------
    def click_prob(self, users: np.ndarray, items: np.ndarray) -> np.ndarray:
        """users (B,), items (B,) or (B, N) -> p(click)."""
        cfg = self.cfg
        zu = self.z_user[users]
        if items.ndim == 1:
            zi = self.z_item[items]
            aff = np.einsum("bd,bd->b", zu, zi)
            pop = self.popularity[items]
        else:
            zi = self.z_item[items]
            aff = np.einsum("bd,bnd->bn", zu, zi)
            pop = self.popularity[items]
        act = np.log1p(self.activity[users])
        # heterogeneous preference SHARPNESS (the paper's premise: users
        # differ in how much ranking quality matters): active users click
        # by affinity (good rankers pay off), casual users click diffusely
        # (cheap chains suffice) - this is what GreenFlow exploits.
        sharp = cfg.click_scale * (0.35 + 1.3 * np.tanh(self.activity[users]))
        if items.ndim == 2:
            act = act[:, None]
            sharp = sharp[:, None]
        logits = sharp * aff + pop + act + cfg.click_bias
        return 1.0 / (1.0 + np.exp(-logits))

    def sample_clicks(self, users, items, rng: np.random.Generator):
        return (rng.random(items.shape) < self.click_prob(users, items)) \
            .astype(np.float32)

    def reward_context(self, users: np.ndarray) -> np.ndarray:
        """Per-request context features f_i for the reward model:
        activity (log + saturating tanh, the preference-sharpness driver),
        history length, field one-hot hashes, taste norm."""
        act = np.log1p(self.activity[users])[:, None]
        sharp = np.tanh(self.activity[users])[:, None]
        hl = self.hist_mask[users].sum(-1, keepdims=True) / self.cfg.hist_len
        fields = self.user_fields[users] / self.cfg.user_field_vocab
        taste = np.abs(self.z_user[users])  # coarse taste signature
        return np.concatenate([act, sharp, hl, fields, taste],
                              -1).astype(np.float32)

    @property
    def d_context(self) -> int:
        return 3 + self.cfg.n_user_fields + self.cfg.d_latent


def build_world(cfg: WorldConfig = WorldConfig()) -> World:
    rng = np.random.default_rng(cfg.seed)
    z_user = rng.normal(size=(cfg.n_users, cfg.d_latent)) / np.sqrt(cfg.d_latent)
    z_item = rng.normal(size=(cfg.n_items, cfg.d_latent)) / np.sqrt(cfg.d_latent)
    activity = rng.lognormal(mean=0.0, sigma=1.0, size=cfg.n_users)
    popularity = -np.log(1.0 + np.arange(cfg.n_items) / 50.0)
    popularity = popularity - popularity.mean()
    rng.shuffle(popularity)

    # categories = k-means-ish hash of item latents
    proto = rng.normal(size=(cfg.n_cats, cfg.d_latent))
    item_cat = np.argmax(z_item @ proto.T, axis=1).astype(np.int64)

    # user categorical fields: quantized random projections of taste
    proj = rng.normal(size=(cfg.d_latent, cfg.n_user_fields))
    q = z_user @ proj
    ranks = np.argsort(np.argsort(q, axis=0), axis=0) / cfg.n_users
    user_fields = np.minimum((ranks * cfg.user_field_vocab).astype(np.int64),
                             cfg.user_field_vocab - 1)
    # field id spaces are disjoint per field
    user_fields += np.arange(cfg.n_user_fields) * cfg.user_field_vocab

    # histories: affinity-proportional sampling, length ~ activity
    aff = z_user @ z_item.T + popularity[None, :]
    hist_ids = np.zeros((cfg.n_users, cfg.hist_len), np.int64)
    hist_mask = np.zeros((cfg.n_users, cfg.hist_len), np.float32)
    lengths = np.clip((activity / activity.max() * cfg.hist_len * 2).astype(int),
                      3, cfg.hist_len)
    gumbel = rng.gumbel(size=aff.shape)
    order = np.argsort(-(aff * 3.0 + gumbel), axis=1)
    for u in range(cfg.n_users):
        t = lengths[u]
        hist_ids[u, :t] = order[u, :t]
        hist_mask[u, :t] = 1.0

    return World(cfg, z_user, z_item, activity, popularity, item_cat,
                 user_fields, hist_ids, hist_mask)


# ---------------------------------------------------------------------------
# Streaming world: users as a pure function of (seed, user id)
# ---------------------------------------------------------------------------
#
# ``build_world`` materializes every user up front - including a (U, I)
# affinity matrix for histories and population-rank field quantization -
# which caps it at a few thousand users.  The streaming variant keeps
# the SAME latent-utility click model and O(I) item side but derives
# each user row from a counter-based hash RNG (splitmix64 -> uniforms ->
# Box-Muller), so ANY slice of an unbounded user universe materializes
# on demand in O(n * I), independent of cfg.n_users: rank quantization
# becomes Gaussian-CDF quantization (same distribution, per-user
# computable) and the history Gumbel noise is keyed per (user, item).
# It is a DIFFERENT (larger) world than build_world's for the same
# config - bitwise parity across the two generators is neither needed
# nor claimed; streamed-vs-materialized serving parity is tested on
# replay sources that share one world.


_M1 = np.uint64(0xBF58476D1CE4E5B9)
_M2 = np.uint64(0x94D049BB133111EB)
_GAMMA = np.uint64(0x9E3779B97F4A7C15)


def _mix64(x: np.ndarray) -> np.ndarray:
    """splitmix64 finalizer: a bijective avalanche on uint64 (overflow
    IS the mod-2^64 arithmetic, so the warning is silenced)."""
    x = np.asarray(x).astype(np.uint64, copy=True)
    with np.errstate(over="ignore"):
        x ^= x >> np.uint64(30)
        x *= _M1
        x ^= x >> np.uint64(27)
        x *= _M2
        x ^= x >> np.uint64(31)
    return x


def _hash_u64(seed: int, *streams) -> np.ndarray:
    """Counter-based uint64 hash of (seed, *streams) - broadcasting.

    Each stream is folded in through the splitmix64 finalizer, so any
    coordinate change avalanches the output; streams broadcast against
    each other (e.g. ``(ids[:, None], dims[None, :])`` -> (n, d))."""
    with np.errstate(over="ignore"):
        x = _mix64(np.uint64(seed) + _GAMMA)
        for k, s in enumerate(streams):
            s = np.asarray(s, np.uint64)
            x = _mix64(x ^ (s * _GAMMA + np.uint64(2 * k + 1)))
    return x


def _hash_u01(seed: int, *streams) -> np.ndarray:
    """Uniforms in [2^-53, 1): the top 53 bits of the hash."""
    u = (_hash_u64(seed, *streams) >> np.uint64(11)).astype(np.float64)
    return np.maximum(u * (2.0 ** -53), 2.0 ** -53)


def _hash_normal(seed: int, *streams) -> np.ndarray:
    """Standard normals via Box-Muller on two hashed uniform draws
    (sub-stream ids 0/1 appended to the key)."""
    u1 = _hash_u01(seed, *streams, 0)
    u2 = _hash_u01(seed, *streams, 1)
    return np.sqrt(-2.0 * np.log(u1)) * np.cos(2.0 * np.pi * u2)


# hash key sub-stream ids (the leading stream of every per-user draw)
_H_TASTE, _H_ACT, _H_HIST, _H_CLICK = 11, 12, 13, 14

# activity reference for history length (~97.7th pct of lognormal(0,1));
# build_world uses the realized population max, which a lazy generator
# cannot see - a fixed distributional reference replaces it
_ACT_REF = float(np.exp(2.0))


@dataclass
class StreamingWorld:
    """Unbounded-U lazy world: the item side of ``World`` plus per-user
    generation on demand.

    ``user_slab(ids)`` returns a regular ``World`` whose arrays hold
    exactly those users under LOCAL indices 0..n-1 (``click_prob``,
    ``reward_context`` and the cascade-model feature batches all run on
    the slab unchanged), and ``clicks_slab(ids)`` samples the (n, I)
    ground-truth click realization - keyed per (user, item), so a user
    arriving in two windows sees the same clicks, exactly like the
    materialized world's once-per-(user, item) sampling.
    """

    cfg: WorldConfig
    z_item: np.ndarray  # (I, dl)
    popularity: np.ndarray  # (I,)
    item_cat: np.ndarray  # (I,) int
    field_proj: np.ndarray  # (dl, F) field projections
    field_sigma: np.ndarray  # (F,) per-field projection std

    @classmethod
    def build(cls, cfg: WorldConfig) -> "StreamingWorld":
        """O(I) item side from its own seed stream (independent of U)."""
        rng = np.random.default_rng((cfg.seed, 0xC0FFEE))
        z_item = rng.normal(size=(cfg.n_items, cfg.d_latent)) \
            / np.sqrt(cfg.d_latent)
        popularity = -np.log(1.0 + np.arange(cfg.n_items) / 50.0)
        popularity = popularity - popularity.mean()
        rng.shuffle(popularity)
        proto = rng.normal(size=(cfg.n_cats, cfg.d_latent))
        item_cat = np.argmax(z_item @ proto.T, axis=1).astype(np.int64)
        proj = rng.normal(size=(cfg.d_latent, cfg.n_user_fields))
        # z_user ~ N(0, I/dl), so q_f = z @ proj_f ~ N(0, |proj_f|^2/dl)
        sigma = np.linalg.norm(proj, axis=0) / np.sqrt(cfg.d_latent)
        return cls(cfg, z_item, popularity, item_cat, proj, sigma)

    @property
    def d_context(self) -> int:
        return 3 + self.cfg.n_user_fields + self.cfg.d_latent

    def user_slab(self, ids: np.ndarray) -> World:
        """Materialize exactly these users as a World (local indices)."""
        from scipy.special import ndtr  # Phi, vectorized
        cfg = self.cfg
        ids = np.asarray(ids, np.int64)
        z = _hash_normal(cfg.seed, _H_TASTE, ids[:, None],
                         np.arange(cfg.d_latent)[None, :]) \
            / np.sqrt(cfg.d_latent)
        activity = np.exp(_hash_normal(cfg.seed, _H_ACT, ids))
        # Gaussian-CDF quantization: same marginal as build_world's
        # population ranks, but a pure per-user function
        q = ndtr((z * np.sqrt(cfg.d_latent)) @ self.field_proj
                 / (self.field_sigma[None, :] * np.sqrt(cfg.d_latent)))
        user_fields = np.minimum((q * cfg.user_field_vocab).astype(np.int64),
                                 cfg.user_field_vocab - 1)
        user_fields += np.arange(cfg.n_user_fields) * cfg.user_field_vocab
        # histories: affinity-proportional, Gumbel keyed per (user, item)
        aff = z @ self.z_item.T + self.popularity[None, :]
        gum = -np.log(-np.log(_hash_u01(
            cfg.seed, _H_HIST, ids[:, None],
            np.arange(cfg.n_items)[None, :])))
        order = np.argsort(-(aff * 3.0 + gum), axis=1, kind="stable")
        lengths = np.clip((activity / _ACT_REF * cfg.hist_len * 2)
                          .astype(int), 3, cfg.hist_len)
        hist_ids = order[:, :cfg.hist_len].astype(np.int64)
        hist_mask = (np.arange(cfg.hist_len)[None, :]
                     < lengths[:, None]).astype(np.float32)
        hist_ids[hist_mask == 0.0] = 0
        return World(cfg, z, self.z_item, activity, self.popularity,
                     self.item_cat, user_fields, hist_ids, hist_mask)

    def clicks_slab(self, ids: np.ndarray, slab: World | None = None,
                    pad_rows: int | None = None) -> np.ndarray:
        """(n, I) ground-truth clicks, keyed per (user, item).

        ``pad_rows`` returns a (pad_rows, I) array with zero rows past
        ``len(ids)`` - the chunk-padded layout the device table builder
        consumes, written once instead of computed then copied."""
        cfg = self.cfg
        ids = np.asarray(ids, np.int64)
        n = len(ids)
        slab = slab if slab is not None else self.user_slab(ids)
        items = np.broadcast_to(np.arange(cfg.n_items),
                                (n, cfg.n_items))
        p = slab.click_prob(np.arange(n), items)
        u = _hash_u01(cfg.seed, _H_CLICK, ids[:, None],
                      np.arange(cfg.n_items)[None, :])
        if pad_rows is None:
            return (u < p).astype(np.float32)
        out = np.zeros((pad_rows, cfg.n_items), np.float32)
        np.less(u, p, out=out[:n])
        return out


# ---------------------------------------------------------------------------
# Paper split (§5.1): 50% cascade-model train / 25% validation /
# 22.5% reward-model sample generation / 2.5% final eval.  At mini scale
# a 2.5% eval slice is a handful of users and the realized-revenue
# comparisons drown in click noise, so ``fracs`` is configurable; the
# experiment harness shifts mass from validation (unused offline) to the
# final-eval slice (documented deviation, DESIGN.md §8).
# ---------------------------------------------------------------------------


@dataclass
class UserSplit:
    cascade_train: np.ndarray
    validation: np.ndarray
    reward_train: np.ndarray
    final_eval: np.ndarray


PAPER_SPLIT = (0.5, 0.25, 0.225, 0.025)


def split_users(world: World, seed: int = 1,
                fracs: tuple = PAPER_SPLIT) -> UserSplit:
    if len(fracs) != 4 or abs(sum(fracs) - 1.0) > 1e-6:
        raise ValueError(f"fracs must be 4 fractions summing to 1: {fracs}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(world.cfg.n_users)
    n = world.cfg.n_users
    a = int(fracs[0] * n)
    b = a + int(fracs[1] * n)
    c = b + int(fracs[2] * n)
    return UserSplit(perm[:a], perm[a:b], perm[b:c], perm[c:])


def ctr_batch(world: World, users: np.ndarray, rng: np.random.Generator,
              batch: int) -> dict:
    """Pointwise CTR training batch (for DIN/DIEN/BST-style rankers)."""
    u = rng.choice(users, size=batch)
    items = rng.integers(0, world.cfg.n_items, size=batch)
    y = world.sample_clicks(u, items, rng)
    return {
        "user_fields": world.user_fields[u].astype(np.int32),
        "hist_ids": world.hist_ids[u].astype(np.int32),
        "hist_cats": world.item_cat[world.hist_ids[u]].astype(np.int32),
        "hist_mask": world.hist_mask[u],
        "item_id": items.astype(np.int32),
        "item_cat": world.item_cat[items].astype(np.int32),
        "label": y,
        "users": u,
    }
