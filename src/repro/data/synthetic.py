"""Synthetic Ali-CCP-style click log (see DESIGN.md §8 for why synthetic).

A latent-utility model generates structurally-faithful traffic:

  * users: latent taste z_u in R^dl, activity a_u ~ heavy-tailed lognormal
    (the paper's "users with varying levels of activity" whose reward
    curves differ - the property GreenFlow exploits);
  * items: latent z_i, popularity pop_i ~ zipf-ish, category from a
    clustering of z_i;
  * click model: p(u clicks i) = sigmoid(s * <z_u, z_i> + pop_i + b_u)
    with activity entering through b_u - active users click more and
    saturate earlier (=> concave reward curves with different slopes);
  * per-user behavior history sampled proportional to affinity;
  * categorical user/item features are quantized projections of the
    latents (so models CAN learn preferences from ids).

Everything is generated lazily from a seed - the 85M-sample scale of
Ali-CCP is samplable without materializing it.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class WorldConfig:
    n_users: int = 20_000
    n_items: int = 4_000
    n_cats: int = 50
    d_latent: int = 16
    hist_len: int = 50
    n_user_fields: int = 4
    user_field_vocab: int = 64  # per-field quantization buckets
    click_scale: float = 4.0
    click_bias: float = -2.0
    seed: int = 0


@dataclass
class World:
    cfg: WorldConfig
    z_user: np.ndarray  # (U, dl)
    z_item: np.ndarray  # (I, dl)
    activity: np.ndarray  # (U,) in (0, inf), heavy tailed
    popularity: np.ndarray  # (I,)
    item_cat: np.ndarray  # (I,) int
    user_fields: np.ndarray  # (U, F) int
    hist_ids: np.ndarray  # (U, T) int
    hist_mask: np.ndarray  # (U, T) float

    # ---- click ground truth -------------------------------------------------
    def click_prob(self, users: np.ndarray, items: np.ndarray) -> np.ndarray:
        """users (B,), items (B,) or (B, N) -> p(click)."""
        cfg = self.cfg
        zu = self.z_user[users]
        if items.ndim == 1:
            zi = self.z_item[items]
            aff = np.einsum("bd,bd->b", zu, zi)
            pop = self.popularity[items]
        else:
            zi = self.z_item[items]
            aff = np.einsum("bd,bnd->bn", zu, zi)
            pop = self.popularity[items]
        act = np.log1p(self.activity[users])
        # heterogeneous preference SHARPNESS (the paper's premise: users
        # differ in how much ranking quality matters): active users click
        # by affinity (good rankers pay off), casual users click diffusely
        # (cheap chains suffice) - this is what GreenFlow exploits.
        sharp = cfg.click_scale * (0.35 + 1.3 * np.tanh(self.activity[users]))
        if items.ndim == 2:
            act = act[:, None]
            sharp = sharp[:, None]
        logits = sharp * aff + pop + act + cfg.click_bias
        return 1.0 / (1.0 + np.exp(-logits))

    def sample_clicks(self, users, items, rng: np.random.Generator):
        return (rng.random(items.shape) < self.click_prob(users, items)) \
            .astype(np.float32)

    def reward_context(self, users: np.ndarray) -> np.ndarray:
        """Per-request context features f_i for the reward model:
        activity (log + saturating tanh, the preference-sharpness driver),
        history length, field one-hot hashes, taste norm."""
        act = np.log1p(self.activity[users])[:, None]
        sharp = np.tanh(self.activity[users])[:, None]
        hl = self.hist_mask[users].sum(-1, keepdims=True) / self.cfg.hist_len
        fields = self.user_fields[users] / self.cfg.user_field_vocab
        taste = np.abs(self.z_user[users])  # coarse taste signature
        return np.concatenate([act, sharp, hl, fields, taste],
                              -1).astype(np.float32)

    @property
    def d_context(self) -> int:
        return 3 + self.cfg.n_user_fields + self.cfg.d_latent


def build_world(cfg: WorldConfig = WorldConfig()) -> World:
    rng = np.random.default_rng(cfg.seed)
    z_user = rng.normal(size=(cfg.n_users, cfg.d_latent)) / np.sqrt(cfg.d_latent)
    z_item = rng.normal(size=(cfg.n_items, cfg.d_latent)) / np.sqrt(cfg.d_latent)
    activity = rng.lognormal(mean=0.0, sigma=1.0, size=cfg.n_users)
    popularity = -np.log(1.0 + np.arange(cfg.n_items) / 50.0)
    popularity = popularity - popularity.mean()
    rng.shuffle(popularity)

    # categories = k-means-ish hash of item latents
    proto = rng.normal(size=(cfg.n_cats, cfg.d_latent))
    item_cat = np.argmax(z_item @ proto.T, axis=1).astype(np.int64)

    # user categorical fields: quantized random projections of taste
    proj = rng.normal(size=(cfg.d_latent, cfg.n_user_fields))
    q = z_user @ proj
    ranks = np.argsort(np.argsort(q, axis=0), axis=0) / cfg.n_users
    user_fields = np.minimum((ranks * cfg.user_field_vocab).astype(np.int64),
                             cfg.user_field_vocab - 1)
    # field id spaces are disjoint per field
    user_fields += np.arange(cfg.n_user_fields) * cfg.user_field_vocab

    # histories: affinity-proportional sampling, length ~ activity
    aff = z_user @ z_item.T + popularity[None, :]
    hist_ids = np.zeros((cfg.n_users, cfg.hist_len), np.int64)
    hist_mask = np.zeros((cfg.n_users, cfg.hist_len), np.float32)
    lengths = np.clip((activity / activity.max() * cfg.hist_len * 2).astype(int),
                      3, cfg.hist_len)
    gumbel = rng.gumbel(size=aff.shape)
    order = np.argsort(-(aff * 3.0 + gumbel), axis=1)
    for u in range(cfg.n_users):
        t = lengths[u]
        hist_ids[u, :t] = order[u, :t]
        hist_mask[u, :t] = 1.0

    return World(cfg, z_user, z_item, activity, popularity, item_cat,
                 user_fields, hist_ids, hist_mask)


# ---------------------------------------------------------------------------
# Paper split (§5.1): 50% cascade-model train / 25% validation /
# 22.5% reward-model sample generation / 2.5% final eval.  At mini scale
# a 2.5% eval slice is a handful of users and the realized-revenue
# comparisons drown in click noise, so ``fracs`` is configurable; the
# experiment harness shifts mass from validation (unused offline) to the
# final-eval slice (documented deviation, DESIGN.md §8).
# ---------------------------------------------------------------------------


@dataclass
class UserSplit:
    cascade_train: np.ndarray
    validation: np.ndarray
    reward_train: np.ndarray
    final_eval: np.ndarray


PAPER_SPLIT = (0.5, 0.25, 0.225, 0.025)


def split_users(world: World, seed: int = 1,
                fracs: tuple = PAPER_SPLIT) -> UserSplit:
    if len(fracs) != 4 or abs(sum(fracs) - 1.0) > 1e-6:
        raise ValueError(f"fracs must be 4 fractions summing to 1: {fracs}")
    rng = np.random.default_rng(seed)
    perm = rng.permutation(world.cfg.n_users)
    n = world.cfg.n_users
    a = int(fracs[0] * n)
    b = a + int(fracs[1] * n)
    c = b + int(fracs[2] * n)
    return UserSplit(perm[:a], perm[a:b], perm[b:c], perm[c:])


def ctr_batch(world: World, users: np.ndarray, rng: np.random.Generator,
              batch: int) -> dict:
    """Pointwise CTR training batch (for DIN/DIEN/BST-style rankers)."""
    u = rng.choice(users, size=batch)
    items = rng.integers(0, world.cfg.n_items, size=batch)
    y = world.sample_clicks(u, items, rng)
    return {
        "user_fields": world.user_fields[u].astype(np.int32),
        "hist_ids": world.hist_ids[u].astype(np.int32),
        "hist_cats": world.item_cat[world.hist_ids[u]].astype(np.int32),
        "hist_mask": world.hist_mask[u],
        "item_id": items.astype(np.int32),
        "item_cat": world.item_cat[items].astype(np.int32),
        "label": y,
        "users": u,
    }
