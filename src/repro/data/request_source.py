"""RequestSource: generate, score and serve request windows on the fly.

The materialized serving path precomputes the whole per-user universe
up front - four (U, I) stage-score matrices, (M, U, I) orderings, a
(U, I) click realization and the (G, U, cap) CompactPlan tables - and
every window merely indexes into it.  That tops out at a few thousand
users: at U >= 100k those tables are hundreds of MB to GB of host RSS
before the first request arrives.

A ``RequestSource`` inverts the dataflow.  Each window is produced on
demand as a ``WindowChunk``: sampled arrivals, their reward contexts,
and a PER-WINDOW (G, n, cap) slice of compact execution tables - the
decision-independent cascade arithmetic for exactly the users who
showed up.  The fused ``ServingPipeline`` pass consumes the chunk
unchanged (its tables are a traced argument, so bucketed padding keeps
the jit cache warm), and host memory scales with the WINDOW size, never
with the universe size.

Two sources cover the two serving regimes:

  * ``GeneratedSource`` - the open-world path: arrivals sampled from an
    unbounded user universe (``data.synthetic.StreamingWorld``), user
    rows hash-generated on demand, stage models scored per window in
    fixed-shape chunks (one jit cache entry regardless of traffic), and
    clicks realized per (user, item) so repeat visitors are consistent.
    This is what drives ``benchmarks/bench_scale.py`` at U >= 100k.
  * ``TableReplaySource`` - the fixed-replay path: per-user tables
    precomputed once (in memory, or memmapped ``.npy`` files via
    ``save``/``load`` so only the touched rows page in), windows gather
    row slices.  Built ``from_server`` it is BITWISE identical to
    serving the materialized ``CascadeServer`` - the parity gate in
    tests/test_request_source.py.

``source.universe`` is the server-shaped handle a streaming
``ServingPipeline`` is constructed over: the chain set and compact
LAYOUT (group maps + row width) without any per-user tables; every
``serve_window`` call must then carry a chunk's tables.
"""
from __future__ import annotations

import json
import os
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass

import numpy as np

from repro.cascade.engine import (CascadeModels, CompactPlan, _k3_layout,
                                  _compact_group_tables,
                                  _compact_group_tables_jax, _user_batch,
                                  build_compact_layout)
from repro.data.synthetic import StreamingWorld, World


@dataclass
class WindowChunk:
    """One window's worth of requests, self-contained.

    ``rows`` are LOCAL indices into ``tables`` (0..n-1): a chunk carries
    its own (G, n, cap) compact tables, so the fused pass gathers within
    the chunk instead of a global user axis.  ``users`` keeps the global
    ids for logging/attribution only - nothing downstream indexes them.
    """

    ctx: np.ndarray  # (n, d_context) float32 reward contexts
    rows: np.ndarray  # (n,) int32 local row indices (arange)
    tables: dict  # {"p": (G, n, cap) int32, "ck": (G, n, cap) float32}
    users: np.ndarray | None = None  # (n,) global user ids
    h2d_bytes: int = 0  # host->device bytes this chunk's production cost
    shard: object | None = None  # HostWindowSlice in a multi-host stream

    @property
    def n(self) -> int:
        if self.shard is not None:
            return int(self.shard.n)
        return int(len(self.rows))


@dataclass
class StreamUniverse:
    """The server-shaped handle a streaming pipeline builds against:
    chain set + compact layout (``build_compact_layout``: group maps and
    row width, EMPTY per-user tables).  ``stream_only`` marks that every
    ``serve_window`` call must bring a chunk's tables."""

    chains: object
    compact: CompactPlan
    expose: int
    stream_only: bool = True


class RequestSource:
    """Base: arrival sampling + per-window chunk production.

    Subclasses set ``chains``, ``expose``, ``n_users``, ``seed`` and
    implement ``window(t, n)``.  Window t is a pure function of
    (seed, t) - re-running a stream replays identical traffic.
    """

    chains = None
    expose: int = 0
    n_users: int = 0
    seed: int = 0

    def arrivals(self, t: int, n: int) -> np.ndarray:
        """(n,) sampled user ids for window t (uniform arrivals)."""
        rng = np.random.default_rng((self.seed, t))
        return rng.integers(0, self.n_users, size=n)

    def window(self, t: int, n: int) -> WindowChunk:
        raise NotImplementedError

    def window_for_users(self, users: np.ndarray) -> WindowChunk:
        """Chunk for an EXPLICIT arrival list (rows = arange(len)).

        The multi-host routing layer depends on this split of
        ``window``: every host can compute the full ``arrivals(t, n)``
        cheaply (a pure (seed, t) function), then materialize contexts
        and score tables for ONLY the slice of users it serves.
        """
        raise NotImplementedError

    @property
    def universe(self) -> StreamUniverse:
        lay = build_compact_layout(self.chains, n_items=self._n_items(),
                                   expose=self.expose)
        if lay is None:
            raise ValueError(
                "streaming sources need the k3 cascade layout (single "
                "recall/prerank model pools); this chain set compiles "
                "to the generic scan kernel, which has no chunked form")
        return StreamUniverse(self.chains, lay, self.expose)

    def _n_items(self) -> int:
        raise NotImplementedError


class GeneratedSource(RequestSource):
    """On-the-fly request generation from a ``StreamingWorld``.

    Per window: sample arrivals, hash-materialize exactly those user
    rows, score the four stage models over the corpus in FIXED-SHAPE
    chunks (padded to ``chunk`` users - one compiled shape for any
    traffic level), realize per-(user, item) clicks, and compact the
    (n, I) scores into the (G, n, cap) execution tables.  Peak host
    memory is O(chunk * I) transient + O(n * G * cap) for the chunk
    tables - independent of ``cfg.n_users``.

    With ``device_tables=True`` (the default) the stage scores never
    leave the device: compaction runs as a jitted pass
    (``_compact_group_tables_jax``, bitwise equal to the host builder)
    at the fixed chunk shape, ``WindowChunk.tables`` hold jax arrays
    end-to-end (the pipeline pads them on device), and a slab-keyed
    LRU cache of ``table_cache`` chunk tables lets repeat-visitor
    chunks skip hashing/scoring entirely (``cache_hits``/
    ``cache_misses`` count lookups).  ``workers`` > 1 scores a
    window's chunks on a thread pool - each chunk is a pure function
    of its arrival ids, so the parallel window is bitwise identical
    to the sequential one.  ``device_tables=False`` keeps the PR 6
    host-built numpy tables (the parity reference).
    """

    def __init__(self, world: StreamingWorld, models: CascadeModels,
                 chains, *, expose: int, seed: int = 0, chunk: int = 512,
                 item_block: int = 256, device_tables: bool = True,
                 table_cache: int = 64, workers: int | None = None,
                 obs=None):
        self.world = world
        self.models = models
        self.chains = chains
        self.expose = int(expose)
        self.seed = int(seed)
        self.chunk = int(chunk)
        self.item_block = int(item_block)
        self.n_users = int(world.cfg.n_users)
        self._lay = _k3_layout(chains, n_items=world.cfg.n_items)
        if self._lay is None:
            raise ValueError("GeneratedSource needs the k3 cascade layout")
        self._score_fns = None  # built lazily (jax import cost)
        self.device_tables = bool(device_tables)
        if workers is None:
            workers = max(1, min(4, (os.cpu_count() or 2) - 1))
        self.workers = int(workers)
        self._table_fn = None  # jitted device compaction (lazy)
        self._cache: OrderedDict = OrderedDict()  # slab key -> tables
        self._cache_cap = int(table_cache)
        self._lock = threading.Lock()
        self._pool = None
        # the plain ints stay authoritative (bench/report reads survive
        # a disabled registry); the obs counters mirror them
        self.cache_hits = 0
        self.cache_misses = 0
        from repro.obs import get_obs
        self.obs = get_obs(obs)
        self._hits_c = self.obs.metrics.counter(
            "greenflow_table_cache_hits_total",
            "slab-table cache hits (a hit IS the chunk result)")
        self._misses_c = self.obs.metrics.counter(
            "greenflow_table_cache_misses_total",
            "slab-table cache misses (chunk scored + compacted)")

    def _n_items(self) -> int:
        return int(self.world.cfg.n_items)

    @property
    def d_context(self) -> int:
        return self.world.d_context

    # -- fixed-shape stage scoring ---------------------------------------

    def _build_score_fns(self):
        """One jitted closure per stage model at the FIXED chunk shape -
        the per-window scoring analogue of the pipeline's bucketed
        padding: any window size reuses the same compiled kernels."""
        import jax
        import jax.numpy as jnp

        from repro.models.recsys import dien, din, dssm, ydnn

        models = self.models
        n_items = self._n_items()
        item_ids = jnp.arange(n_items, dtype=jnp.int32)
        item_cats = jnp.asarray(self.world.item_cat, jnp.int32)
        if models.dssm_cfg.n_item_fields == 1:
            dssm_item_fields = jnp.stack([item_cats], axis=-1)
        else:
            dssm_item_fields = jnp.stack([item_ids, item_cats], axis=-1)

        @jax.jit
        def dssm_all(uf):
            v = dssm.item_tower(models.dssm_params, models.dssm_cfg,
                                dssm_item_fields)
            u = dssm.user_tower(models.dssm_params, models.dssm_cfg, uf)
            return u @ v.T

        @jax.jit
        def ydnn_all(hist, mask, uf):
            u = ydnn.user_vector(models.ydnn_params, models.ydnn_cfg,
                                 hist, mask, uf)
            v = models.ydnn_params["out_emb"]["table"][:n_items]
            return u @ v.T

        @jax.jit
        def din_block(batch, cand_ids, cand_cats):
            return din.score(models.din_params, models.din_cfg, batch,
                             cand_ids, cand_cats)

        @jax.jit
        def dien_block(batch, cand_ids, cand_cats):
            return dien.score(models.dien_params, models.dien_cfg, batch,
                              cand_ids, cand_cats)

        self._score_fns = {"DSSM": dssm_all, "YDNN": ydnn_all,
                           "DIN": din_block, "DIEN": dien_block}
        self._item_ids = item_ids
        self._item_cats = item_cats

    def _score_slab(self, slab: World, n_real: int) -> dict:
        """{name: (n_real, I) float np} stage scores for a slab, padded
        to the fixed chunk shape for the jitted kernels."""
        import jax.numpy as jnp

        if self._score_fns is None:
            self._build_score_fns()
        c = self.chunk
        ub = _user_batch(slab, np.arange(n_real))
        pad = c - n_real
        if pad:
            ub = {k: jnp.concatenate(
                [v, jnp.zeros((pad, *v.shape[1:]), v.dtype)])
                for k, v in ub.items()}
        scores = {
            "DSSM": np.asarray(self._score_fns["DSSM"](
                ub["user_fields"]))[:n_real],
            "YDNN": np.asarray(self._score_fns["YDNN"](
                ub["hist_ids"], ub["hist_mask"],
                ub["user_fields"]))[:n_real],
        }
        n_items = self._n_items()
        for name in ("DIN", "DIEN"):
            fn = self._score_fns[name]
            cols = []
            for lo in range(0, n_items, self.item_block):
                hi = min(n_items, lo + self.item_block)
                ids = jnp.broadcast_to(self._item_ids[lo:hi], (c, hi - lo))
                cats = jnp.broadcast_to(self._item_cats[lo:hi],
                                        (c, hi - lo))
                cols.append(np.asarray(fn(ub, ids, cats))[:n_real])
            scores[name] = np.concatenate(cols, axis=1)
        return scores

    def _score_slab_dev(self, slab: World, n_real: int):
        """Device twin of ``_score_slab``: the same jitted kernels at the
        same fixed chunk shape, but the (chunk, I) score slabs STAY jax
        arrays (no ``np.asarray`` sync, no host copy) - rows past
        ``n_real`` carry padding garbage the caller slices off on
        device.  Returns ({name: (chunk, I) jax f32}, h2d_bytes)."""
        import jax.numpy as jnp

        if self._score_fns is None:
            self._build_score_fns()
        c = self.chunk
        ub = _user_batch(slab, np.arange(n_real))
        pad = c - n_real
        if pad:
            ub = {k: jnp.concatenate(
                [v, jnp.zeros((pad, *v.shape[1:]), v.dtype)])
                for k, v in ub.items()}
        h2d = sum(int(v.size) * v.dtype.itemsize for v in ub.values())
        scores = {
            "DSSM": self._score_fns["DSSM"](ub["user_fields"]),
            "YDNN": self._score_fns["YDNN"](ub["hist_ids"],
                                            ub["hist_mask"],
                                            ub["user_fields"]),
        }
        n_items = self._n_items()
        for name in ("DIN", "DIEN"):
            fn = self._score_fns[name]
            cols = []
            for lo in range(0, n_items, self.item_block):
                hi = min(n_items, lo + self.item_block)
                ids = jnp.broadcast_to(self._item_ids[lo:hi], (c, hi - lo))
                cats = jnp.broadcast_to(self._item_cats[lo:hi],
                                        (c, hi - lo))
                cols.append(fn(ub, ids, cats))
            scores[name] = (cols[0] if len(cols) == 1
                            else jnp.concatenate(cols, axis=1))
        return scores, h2d

    # -- device chunk tables (jitted compaction + slab cache) --------------

    def _build_table_fn(self):
        import jax

        lay = self._lay

        @jax.jit
        def build(scores, clicks):
            return _compact_group_tables_jax(scores, lay, clicks)

        self._table_fn = build

    def _chunk_tables(self, ids: np.ndarray):
        """One scoring chunk -> (ctx, p_dev, ck_dev, h2d_bytes), via the
        slab cache when these exact arrivals were produced before (a
        chunk is a pure function of its ids, so a hit IS the result)."""
        key = (len(ids), ids.tobytes())
        with self._lock:
            hit = self._cache.get(key)
            if hit is not None:
                self._cache.move_to_end(key)
                self.cache_hits += 1
                self._hits_c.inc()
                return (*hit, 0)
            self.cache_misses += 1
            self._misses_c.inc()
        m = len(ids)
        slab = self.world.user_slab(ids)
        ctx = slab.reward_context(np.arange(m))
        scores, h2d = self._score_slab_dev(slab, m)
        if self._table_fn is None:
            self._build_table_fn()
        import jax.numpy as jnp

        clicks = self.world.clicks_slab(ids, slab, pad_rows=self.chunk)
        h2d += clicks.nbytes
        p, ck = self._table_fn(scores, jnp.asarray(clicks))
        if m != self.chunk:  # static device slice to the real rows
            p, ck = p[:, :m], ck[:, :m]
        with self._lock:
            self._cache[key] = (ctx, p, ck)
            while len(self._cache) > self._cache_cap:
                self._cache.popitem(last=False)
        return ctx, p, ck, h2d

    # -- window production -----------------------------------------------

    def window(self, t: int, n: int) -> WindowChunk:
        if n == 0:
            lay = build_compact_layout(self.chains,
                                       n_items=self._n_items(),
                                       expose=self.expose)
            g_n, cap = lay.p_sorted.shape[0], lay.cap
            return WindowChunk(
                ctx=np.zeros((0, self.d_context), np.float32),
                rows=np.zeros(0, np.int32),
                tables={"p": np.zeros((g_n, 0, cap), np.int32),
                        "ck": np.zeros((g_n, 0, cap), np.float32)},
                users=np.zeros(0, np.int64))
        return self.window_for_users(self.arrivals(t, n), _t=t)

    def window_for_users(self, users: np.ndarray,
                         _t: int | None = None) -> WindowChunk:
        users = np.asarray(users)
        n = len(users)
        if not self.device_tables:  # host-built numpy tables (PR 6 path)
            ctx_parts, p_parts, ck_parts = [], [], []
            for lo in range(0, n, self.chunk):
                ids = users[lo:lo + self.chunk]
                slab = self.world.user_slab(ids)
                ctx_parts.append(slab.reward_context(np.arange(len(ids))))
                scores = self._score_slab(slab, len(ids))
                clicks = self.world.clicks_slab(ids, slab)
                p, ck, _cap = _compact_group_tables(
                    scores, self._lay, clicks, expose=self.expose)
                p_parts.append(p.astype(np.int32))
                ck_parts.append(ck.astype(np.float32))
            return WindowChunk(
                ctx=np.concatenate(ctx_parts, axis=0),
                rows=np.arange(n, dtype=np.int32),
                tables={"p": np.concatenate(p_parts, axis=1),
                        "ck": np.concatenate(ck_parts, axis=1)},
                users=users)
        import jax.numpy as jnp

        chunk_ids = [users[lo:lo + self.chunk]
                     for lo in range(0, n, self.chunk)]
        with self.obs.span("chunk_tables", t=_t, n=n,
                           chunks=len(chunk_ids)):
            if self.workers > 1 and len(chunk_ids) > 1:
                if self._pool is None:
                    self._pool = ThreadPoolExecutor(
                        max_workers=self.workers,
                        thread_name_prefix="chunk-score")
                parts = list(self._pool.map(self._chunk_tables,
                                            chunk_ids))
            else:
                parts = [self._chunk_tables(ids) for ids in chunk_ids]
        if len(parts) == 1:
            ctx, p, ck, h2d = parts[0]
        else:
            ctx = np.concatenate([pt[0] for pt in parts], axis=0)
            p = jnp.concatenate([pt[1] for pt in parts], axis=1)
            ck = jnp.concatenate([pt[2] for pt in parts], axis=1)
            h2d = sum(pt[3] for pt in parts)
        return WindowChunk(ctx=np.asarray(ctx, np.float32),
                           rows=np.arange(n, dtype=np.int32),
                           tables={"p": p, "ck": ck}, users=users,
                           h2d_bytes=int(h2d))


class TableReplaySource(RequestSource):
    """Fixed replay over precomputed per-user tables.

    The scoring-input tables (contexts + compact execution rows) are
    computed ONCE - by a materialized ``CascadeServer`` or a prior
    ``save`` - and windows gather per-arrival slices.  With
    ``load(..., mmap=True)`` the tables stay on disk as memmapped
    ``.npy`` files and only the rows a window touches page in, so
    replaying a large fixed universe costs O(window), not O(U).

    Built ``from_server`` over the same arrivals, the streamed path is
    bit-identical to indexing the materialized universe: the chunk
    tables are row-gathers of the server's own tables and the contexts
    are the same array rows.

    ``device_tables`` uploads the full tables to the device ONCE and
    turns each window into a device-side row gather - no per-window
    (G, n, cap) host->device copy.  Default: on for in-memory tables,
    off for memmapped ones (whose point is that untouched rows never
    leave the disk).
    """

    def __init__(self, ctx: np.ndarray, p_sorted: np.ndarray,
                 clicks_sorted: np.ndarray, chains, *, n_items: int,
                 expose: int, seed: int = 0,
                 device_tables: bool | None = None):
        if ctx.shape[0] != p_sorted.shape[1]:
            raise ValueError(
                f"ctx rows ({ctx.shape[0]}) must match table users "
                f"({p_sorted.shape[1]})")
        self.ctx = ctx
        self.p_sorted = p_sorted
        self.clicks_sorted = clicks_sorted
        self.chains = chains
        self.n_items = int(n_items)
        self.expose = int(expose)
        self.seed = int(seed)
        self.n_users = int(ctx.shape[0])
        if device_tables is None:
            device_tables = not isinstance(p_sorted, np.memmap)
        self.device_tables = bool(device_tables)
        self._dev = None  # one-time device upload (lazy)
        lay = build_compact_layout(chains, n_items=self.n_items,
                                   expose=self.expose)
        if lay is None or lay.cap != p_sorted.shape[2]:
            raise ValueError(
                f"tables (cap={p_sorted.shape[2]}) do not match the "
                f"chain set's compact layout at n_items={self.n_items}")

    @classmethod
    def from_server(cls, server, ctx: np.ndarray, *, seed: int = 0,
                    device_tables: bool | None = None
                    ) -> "TableReplaySource":
        """Replay source over a materialized CascadeServer's universe
        (``ctx`` row u = the reward context of table row u)."""
        if server.compact is None:
            raise ValueError("from_server needs a CompactPlan server "
                             "(the k3 cascade layout)")
        return cls(np.asarray(ctx, np.float32),
                   np.asarray(server.compact.p_sorted, np.int32),
                   np.asarray(server.compact.clicks_sorted, np.float32),
                   server.chains, n_items=server.clicks.shape[1],
                   expose=server.compact.expose, seed=seed,
                   device_tables=device_tables)

    def _n_items(self) -> int:
        return self.n_items

    @property
    def d_context(self) -> int:
        return int(self.ctx.shape[1])

    def window(self, t: int, n: int) -> WindowChunk:
        return self.window_for_users(self.arrivals(t, n))

    def window_for_users(self, users: np.ndarray) -> WindowChunk:
        users = np.asarray(users)
        n = len(users)
        if self.device_tables:
            import jax.numpy as jnp

            h2d = 0
            if self._dev is None:  # one-time universe upload
                self._dev = (
                    jnp.asarray(np.asarray(self.p_sorted, np.int32)),
                    jnp.asarray(np.asarray(self.clicks_sorted,
                                           np.float32)))
                h2d = int(self._dev[0].nbytes + self._dev[1].nbytes)
            u = jnp.asarray(users.astype(np.int32))
            h2d += int(u.nbytes)
            return WindowChunk(
                ctx=np.asarray(self.ctx[users], np.float32),
                rows=np.arange(n, dtype=np.int32),
                tables={"p": jnp.take(self._dev[0], u, axis=1),
                        "ck": jnp.take(self._dev[1], u, axis=1)},
                users=users, h2d_bytes=h2d)
        return WindowChunk(
            ctx=np.asarray(self.ctx[users], np.float32),
            rows=np.arange(n, dtype=np.int32),
            tables={"p": np.ascontiguousarray(self.p_sorted[:, users]),
                    "ck": np.ascontiguousarray(
                        self.clicks_sorted[:, users])},
            users=users)

    # -- on-disk (memmap) form -------------------------------------------

    def save(self, path: str) -> None:
        """Write the tables as raw ``.npy`` (memmap-loadable) + meta."""
        os.makedirs(path, exist_ok=True)
        np.save(os.path.join(path, "ctx.npy"),
                np.asarray(self.ctx, np.float32))
        np.save(os.path.join(path, "p_sorted.npy"),
                np.asarray(self.p_sorted, np.int32))
        np.save(os.path.join(path, "clicks_sorted.npy"),
                np.asarray(self.clicks_sorted, np.float32))
        with open(os.path.join(path, "meta.json"), "w") as f:
            json.dump({"expose": self.expose, "n_items": self.n_items,
                       "n_users": self.n_users}, f)

    @classmethod
    def load(cls, path: str, chains, *, seed: int = 0,
             mmap: bool = True) -> "TableReplaySource":
        """Open a saved universe; ``mmap=True`` keeps tables on disk."""
        mode = "r" if mmap else None
        with open(os.path.join(path, "meta.json")) as f:
            meta = json.load(f)
        return cls(np.load(os.path.join(path, "ctx.npy"), mmap_mode=mode),
                   np.load(os.path.join(path, "p_sorted.npy"),
                           mmap_mode=mode),
                   np.load(os.path.join(path, "clicks_sorted.npy"),
                           mmap_mode=mode),
                   chains, n_items=int(meta["n_items"]),
                   expose=int(meta["expose"]), seed=seed)
