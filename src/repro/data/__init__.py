"""Data substrate: synthetic Ali-CCP-style log, sharded pipelines, graphs."""
