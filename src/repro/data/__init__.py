"""Data substrate: synthetic Ali-CCP-style log, sharded pipelines,
graphs - and the streaming request layer.

``synthetic`` builds materialized worlds (``build_world``: every user
row up front, a few thousand users) and streaming ones
(``StreamingWorld``: counter-hash user generation, any slice of an
unbounded universe on demand).  ``request_source`` turns either into
per-window ``WindowChunk``s for the fused serving pipeline -
``GeneratedSource`` scores arrivals on the fly, ``TableReplaySource``
replays fixed (optionally memmapped) tables bitwise-identically to the
materialized server they came from.
"""
import importlib

_LAZY = {
    "World": "repro.data.synthetic",
    "WorldConfig": "repro.data.synthetic",
    "StreamingWorld": "repro.data.synthetic",
    "build_world": "repro.data.synthetic",
    "GeneratedSource": "repro.data.request_source",
    "RequestSource": "repro.data.request_source",
    "StreamUniverse": "repro.data.request_source",
    "TableReplaySource": "repro.data.request_source",
    "WindowChunk": "repro.data.request_source",
}

__all__ = list(_LAZY)


def __getattr__(name):  # PEP 562: keep bare `import repro.data` light
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
