"""Data substrate: synthetic Ali-CCP-style log, sharded pipelines,
graphs - and the streaming request layer.

``synthetic`` builds materialized worlds (``build_world``: every user
row up front, a few thousand users) and streaming ones
(``StreamingWorld``: counter-hash user generation, any slice of an
unbounded universe on demand).  ``request_source`` turns either into
per-window ``WindowChunk``s for the fused serving pipeline -
``GeneratedSource`` scores arrivals on the fly, ``TableReplaySource``
replays fixed (optionally memmapped) tables bitwise-identically to the
materialized server they came from.

Chunk tables are DEVICE-RESIDENT by default (``device_tables``):
``GeneratedSource`` compacts stage scores into execution tables in a
jitted pass (bitwise equal to the host builder - scores never cross
back to host), scores a window's chunks on a small thread pool, and
keeps a slab-keyed LRU cache so repeat-visitor chunks skip
hashing/scoring; in-memory ``TableReplaySource`` uploads its tables
once and serves windows as device row gathers.  ``WindowChunk.
h2d_bytes`` meters what each window's production actually shipped to
the device.  ``device_tables=False`` keeps the PR 6 host-numpy path
(the parity reference, and the default for memmapped replay, whose
point is that untouched rows never leave disk).
"""
import importlib

_LAZY = {
    "World": "repro.data.synthetic",
    "WorldConfig": "repro.data.synthetic",
    "StreamingWorld": "repro.data.synthetic",
    "build_world": "repro.data.synthetic",
    "GeneratedSource": "repro.data.request_source",
    "RequestSource": "repro.data.request_source",
    "StreamUniverse": "repro.data.request_source",
    "TableReplaySource": "repro.data.request_source",
    "WindowChunk": "repro.data.request_source",
}

__all__ = list(_LAZY)


def __getattr__(name):  # PEP 562: keep bare `import repro.data` light
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
