"""Sharded, resumable, prefetching batch pipelines.

Design goals for the 1000-node posture:
  * determinism: batch t is a pure function of (seed, t) - any host can
    reproduce any step, which makes restart/elastic-rescale trivial;
  * shard-awareness: each host slices its (host_id / n_hosts) stripe of
    the global batch - no cross-host data shuffles;
  * resume: ``seek(step)`` fast-forwards without replaying data;
  * prefetch: a single background thread keeps ``depth`` batches ready
    (CPU-side; device transfer happens in the training loop).
"""
from __future__ import annotations

import queue
import threading
from dataclasses import dataclass
from typing import Callable, Iterator

import numpy as np


@dataclass
class ShardInfo:
    host_id: int = 0
    n_hosts: int = 1


class DeterministicPipeline:
    """batch_fn(rng, step, lo, hi) -> dict of np arrays for rows [lo, hi)."""

    def __init__(self, batch_fn: Callable, global_batch: int, seed: int = 0,
                 shard: ShardInfo = ShardInfo()):
        if global_batch % shard.n_hosts:
            raise ValueError("global_batch must divide across hosts")
        self.batch_fn = batch_fn
        self.global_batch = global_batch
        self.seed = seed
        self.shard = shard
        self.step = 0

    def seek(self, step: int):
        """Resume support: jump to any step in O(1)."""
        self.step = int(step)

    def next(self) -> dict:
        per_host = self.global_batch // self.shard.n_hosts
        lo = self.shard.host_id * per_host
        rng = np.random.default_rng((self.seed, self.step))
        out = self.batch_fn(rng, self.step, lo, lo + per_host)
        self.step += 1
        return out

    def __iter__(self) -> Iterator[dict]:
        while True:
            yield self.next()


class Prefetcher:
    """Background-thread prefetch with clean shutdown."""

    _SENTINEL = object()

    def __init__(self, it: Iterator, depth: int = 2):
        self.q: queue.Queue = queue.Queue(maxsize=depth)
        self._stop = threading.Event()

        def worker():
            try:
                for item in it:
                    if self._stop.is_set():
                        return
                    self.q.put(item)
            finally:
                self.q.put(self._SENTINEL)

        self.t = threading.Thread(target=worker, daemon=True)
        self.t.start()

    def __iter__(self):
        return self

    def __next__(self):
        item = self.q.get()
        if item is self._SENTINEL:
            raise StopIteration
        return item

    def close(self):
        self._stop.set()
        try:
            while True:
                self.q.get_nowait()
        except queue.Empty:
            pass


# ---------------------------------------------------------------------------
# Concrete batch functions
# ---------------------------------------------------------------------------


def lm_token_batch_fn(vocab: int, seq_len: int):
    """Synthetic zipf-ish token stream for LM substrate tests/examples."""

    def fn(rng: np.random.Generator, step: int, lo: int, hi: int) -> dict:
        n = hi - lo
        # zipf via inverse-CDF on a power law, clipped to vocab
        u = rng.random((n, seq_len + 1))
        toks = np.minimum((u ** -1.3).astype(np.int64), vocab - 1)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "targets": toks[:, 1:].astype(np.int32),
            "mask": np.ones((n, seq_len), np.float32),
        }

    return fn


def recsys_ctr_batch_fn(world, users: np.ndarray):
    """Cascade CTR batches bound to a user split (see data.synthetic)."""
    from repro.data.synthetic import ctr_batch

    def fn(rng: np.random.Generator, step: int, lo: int, hi: int) -> dict:
        return ctr_batch(world, users, rng, hi - lo)

    return fn
