"""Host-side fanout neighbor sampler (GraphSAGE-style) for minibatch_lg.

Numpy/CSR on the host (this is data-pipeline work, not device work):
given seed nodes, sample ``fanout[0]`` neighbors per seed, then
``fanout[1]`` per frontier node, etc.  Emits a PADDED static-shape
subgraph so every training step compiles once.
"""
from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass
class CSRGraph:
    """Compressed neighbor lists: indptr (N+1,), indices (nnz,)."""

    indptr: np.ndarray
    indices: np.ndarray

    @property
    def n_nodes(self) -> int:
        return len(self.indptr) - 1

    @property
    def n_edges(self) -> int:
        return len(self.indices)

    @classmethod
    def from_edges(cls, src: np.ndarray, dst: np.ndarray,
                   n_nodes: int) -> "CSRGraph":
        order = np.argsort(src, kind="stable")
        src_s, dst_s = src[order], dst[order]
        counts = np.bincount(src_s, minlength=n_nodes)
        indptr = np.concatenate([[0], np.cumsum(counts)])
        return cls(indptr.astype(np.int64), dst_s.astype(np.int32))

    def neighbors(self, v: int) -> np.ndarray:
        return self.indices[self.indptr[v]:self.indptr[v + 1]]


@dataclass
class SampledSubgraph:
    """Padded static-shape subgraph (device-ready)."""

    nodes: np.ndarray  # (max_nodes,) global node ids (0-padded)
    node_mask: np.ndarray  # (max_nodes,) 1.0 = real
    src: np.ndarray  # (max_edges,) LOCAL indices into `nodes`
    dst: np.ndarray  # (max_edges,)
    edge_mask: np.ndarray  # (max_edges,)
    seeds_local: np.ndarray  # (n_seeds,) local indices of the seed nodes


def sample_subgraph(graph: CSRGraph, seeds: np.ndarray, fanout: tuple,
                    rng: np.random.Generator, *, max_nodes: int,
                    max_edges: int) -> SampledSubgraph:
    """Fanout sampling with replacement-free caps; pads to static shapes.

    Budget overflow is handled by truncation (counts toward straggler
    mitigation: every step costs the same regardless of local degree).
    """
    local_of = {int(s): i for i, s in enumerate(seeds)}
    nodes = list(map(int, seeds))
    edges_src, edges_dst = [], []
    frontier = list(map(int, seeds))
    for f in fanout:
        nxt = []
        for v in frontier:
            nb = graph.neighbors(v)
            if len(nb) == 0:
                continue
            take = nb if len(nb) <= f else rng.choice(nb, size=f, replace=False)
            for u in map(int, take):
                if u not in local_of:
                    if len(nodes) >= max_nodes:
                        continue
                    local_of[u] = len(nodes)
                    nodes.append(u)
                    nxt.append(u)
                if len(edges_src) < max_edges:
                    # message flows neighbor -> center
                    edges_src.append(local_of[u])
                    edges_dst.append(local_of[v])
        frontier = nxt
        if not frontier:
            break

    n, e = len(nodes), len(edges_src)
    out_nodes = np.zeros(max_nodes, np.int64)
    out_nodes[:n] = nodes
    node_mask = np.zeros(max_nodes, np.float32)
    node_mask[:n] = 1.0
    src = np.zeros(max_edges, np.int32)
    dst = np.zeros(max_edges, np.int32)
    emask = np.zeros(max_edges, np.float32)
    src[:e], dst[:e], emask[:e] = edges_src, edges_dst, 1.0
    return SampledSubgraph(out_nodes, node_mask, src, dst, emask,
                           np.arange(len(seeds), dtype=np.int32))


def budget_for(n_seeds: int, fanout: tuple) -> tuple[int, int]:
    """Static (max_nodes, max_edges) for a fanout spec."""
    nodes, layer, edges = n_seeds, n_seeds, 0
    for f in fanout:
        layer = layer * f
        nodes += layer
        edges += layer
    return nodes, edges
