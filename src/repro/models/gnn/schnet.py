"""SchNet (Schuett et al. [arXiv:1706.08566]) - continuous-filter
convolutional network.

Assigned config: n_interactions=3, d_hidden=64, rbf=300, cutoff=10.

Message passing is edge-parallel: gather source features, modulate with the
RBF-filter network, ``jax.ops.segment_sum`` into destinations (JAX has no
sparse SpMM for this - the segment-op path IS the system, per the brief).
Under a mesh the edge arrays shard over the batch axes and the scatter-add
reduces partially per shard + all-reduce (GSPMD).

Two task heads (the assigned shapes span both):
  * graph_reg   - per-graph energy (molecule batches; segment-sum readout),
  * node_class  - per-node logits (full_graph_sm / ogb_products /
    minibatch_lg citation-style graphs; SchNet's geometry comes from
    synthesized positional distances, see data/graphs.py).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.distributed.sharding import constrain
from repro.models import layers as L


def ssp(x):
    """Shifted softplus - SchNet's activation."""
    return jax.nn.softplus(x) - jnp.log(2.0)


@dataclass(frozen=True)
class SchNetConfig:
    n_interactions: int = 3
    d_hidden: int = 64
    n_rbf: int = 300
    cutoff: float = 10.0
    d_feat: int = 0  # >0: project node features; 0: embed atom types
    n_atom_types: int = 100
    n_out: int = 1  # 1 for graph_reg; n_classes for node_class
    task: str = "graph_reg"  # graph_reg | node_class
    readout_hidden: int = 32


def init(key, cfg: SchNetConfig) -> dict:
    k = jax.random.split(key, 4 + cfg.n_interactions)
    d = cfg.d_hidden
    if cfg.d_feat > 0:
        inp = {"proj": L.dense_init(k[0], cfg.d_feat, d)}
    else:
        inp = {"embed": L.embedding_init(k[0], cfg.n_atom_types, d)}
    blocks = []
    for i in range(cfg.n_interactions):
        kk = jax.random.split(k[2 + i], 4)
        blocks.append({
            "filter": L.mlp_init(kk[0], [cfg.n_rbf, d, d]),
            "in_proj": L.dense_init(kk[1], d, d, use_bias=False),
            "out1": L.dense_init(kk[2], d, d),
            "out2": L.dense_init(kk[3], d, d),
        })
    ko = jax.random.split(k[1], 2)
    return {
        **inp,
        "blocks": blocks,
        "head1": L.dense_init(ko[0], d, cfg.readout_hidden),
        "head2": L.dense_init(ko[1], cfg.readout_hidden, cfg.n_out),
    }


def rbf_expand(dist: jnp.ndarray, cfg: SchNetConfig) -> jnp.ndarray:
    """dist (E,) -> (E, n_rbf) Gaussian radial basis on [0, cutoff]."""
    mu = jnp.linspace(0.0, cfg.cutoff, cfg.n_rbf)
    gamma = 1.0 / (mu[1] - mu[0]) ** 2
    return jnp.exp(-gamma * jnp.square(dist[:, None] - mu[None, :]))


def cosine_cutoff(dist: jnp.ndarray, cfg: SchNetConfig) -> jnp.ndarray:
    c = 0.5 * (jnp.cos(jnp.pi * dist / cfg.cutoff) + 1.0)
    return jnp.where(dist < cfg.cutoff, c, 0.0)


def interaction(block, cfg: SchNetConfig, x, src, dst, rbf, cut, edge_mask,
                n_nodes: int):
    """One cfconv + atom-wise block. x (N, d); src/dst (E,) int32."""
    w = L.mlp_apply(block["filter"], rbf, act="none", final_act="none")
    w = ssp(w) * cut[:, None] * edge_mask[:, None]
    h = L.dense_apply(block["in_proj"], x)
    msg = jnp.take(h, src, axis=0) * w  # (E, d) gather + modulate
    agg = jax.ops.segment_sum(msg, dst, num_segments=n_nodes)
    v = ssp(L.dense_apply(block["out1"], agg))
    return x + L.dense_apply(block["out2"], v)


def forward(params, cfg: SchNetConfig, batch: dict) -> jnp.ndarray:
    """batch:
      nodes      - (N,) int32 atom types OR (N, d_feat) float features
      src, dst   - (E,) int32 edge endpoints
      dist       - (E,) float edge distances
      edge_mask  - (E,) 1.0 = real edge (padding support)
      graph_ids  - (N,) int32 graph membership (graph_reg only)
      n_graphs   - static int (graph_reg only)
    Returns (n_graphs, n_out) for graph_reg, (N, n_out) for node_class.
    """
    if cfg.d_feat > 0:
        x = L.dense_apply(params["proj"], batch["nodes"].astype(jnp.float32))
    else:
        x = L.embedding_apply(params["embed"], batch["nodes"])
    n_nodes = x.shape[0]
    src = constrain(batch["src"], ("pod", "data", "model"))
    dst = constrain(batch["dst"], ("pod", "data", "model"))
    dist = constrain(batch["dist"], ("pod", "data", "model"))
    edge_mask = constrain(batch["edge_mask"], ("pod", "data", "model"))
    rbf = rbf_expand(dist, cfg)
    cut = cosine_cutoff(dist, cfg)
    for block in params["blocks"]:
        x = interaction(block, cfg, x, src, dst, rbf, cut, edge_mask, n_nodes)
    h = ssp(L.dense_apply(params["head1"], x))
    out = L.dense_apply(params["head2"], h)  # (N, n_out)
    if cfg.task == "graph_reg":
        return jax.ops.segment_sum(out, batch["graph_ids"],
                                   num_segments=batch["n_graphs"])
    return out


def loss_fn(params, cfg: SchNetConfig, batch: dict) -> jnp.ndarray:
    out = forward(params, cfg, batch)
    if cfg.task == "graph_reg":
        return jnp.mean(jnp.square(out[..., 0] - batch["target"]))
    logits = out.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, batch["target"][:, None], axis=-1)[:, 0]
    nll = (lse - picked) * batch["node_mask"]
    return jnp.sum(nll) / jnp.maximum(jnp.sum(batch["node_mask"]), 1.0)


def flops_per_edge(cfg: SchNetConfig) -> float:
    d, r = cfg.d_hidden, cfg.n_rbf
    filt = 2.0 * (r * d + d * d)
    return cfg.n_interactions * (filt + 3.0 * d)


def flops_per_node(cfg: SchNetConfig) -> float:
    d = cfg.d_hidden
    inp = 2.0 * (cfg.d_feat or 1) * d
    block = 3 * 2.0 * d * d
    head = 2.0 * (d * cfg.readout_hidden + cfg.readout_hidden * cfg.n_out)
    return inp + cfg.n_interactions * block + head
