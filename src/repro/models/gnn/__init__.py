"""GNN models: SchNet (continuous-filter convolutions) + neighbor sampler."""
