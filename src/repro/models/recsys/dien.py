"""DIEN - Deep Interest Evolution Network (Zhou et al., AAAI'19).

Paper cascade's second ranking model (Table 1: 7098K FLOPs, AUC 0.641 -
deliberately ~DIN FLOPs so the multi-model ablation (Table 3) is about
per-user fit, not scale).

Interest extractor: GRU over the behavior sequence (lax.scan - a true
recurrence; see DESIGN.md §3 on MXU fit).  Interest evolution: AUGRU
(attention-gated update) conditioned on the target item.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import gru_flops, mlp_flops
from repro.models import layers as L
from repro.models.recsys.din import embed_items  # shared embedding layout


@dataclass(frozen=True)
class DIENConfig:
    item_vocab: int = 200_000
    cat_vocab: int = 5_000
    user_vocab: int = 200_000
    n_user_fields: int = 2
    embed_dim: int = 18
    seq_len: int = 100
    attn_hidden: tuple = (80, 40)
    mlp_hidden: tuple = (200, 80)

    @property
    def d_item(self) -> int:
        return 2 * self.embed_dim


def _gru_init(key, d_in, d_h):
    k = jax.random.split(key, 3)
    mk = lambda kk: {"wx": L.glorot_uniform(kk, (d_in, d_h)),
                     "wh": L.glorot_uniform(jax.random.fold_in(kk, 1), (d_h, d_h)),
                     "b": jnp.zeros((d_h,))}
    return {"r": mk(k[0]), "z": mk(k[1]), "h": mk(k[2])}


def _gru_cell(p, h, x, update_gate_scale=None):
    r = jax.nn.sigmoid(x @ p["r"]["wx"] + h @ p["r"]["wh"] + p["r"]["b"])
    z = jax.nn.sigmoid(x @ p["z"]["wx"] + h @ p["z"]["wh"] + p["z"]["b"])
    hh = jnp.tanh(x @ p["h"]["wx"] + (r * h) @ p["h"]["wh"] + p["h"]["b"])
    if update_gate_scale is not None:  # AUGRU: a_t scales the update gate
        z = z * update_gate_scale[..., None]
    return (1.0 - z) * h + z * hh


def init(key, cfg: DIENConfig) -> dict:
    k = jax.random.split(key, 8)
    d = cfg.d_item
    d_mlp_in = cfg.n_user_fields * cfg.embed_dim + 2 * d
    return {
        "item_emb": L.embedding_init(k[0], cfg.item_vocab, cfg.embed_dim),
        "cat_emb": L.embedding_init(k[1], cfg.cat_vocab, cfg.embed_dim),
        "user_emb": L.embedding_init(k[2], cfg.user_vocab, cfg.embed_dim),
        "gru1": _gru_init(k[3], d, d),
        "augru": _gru_init(k[4], d, d),
        "attn": L.mlp_init(k[5], [4 * d, *cfg.attn_hidden, 1]),
        "mlp": L.mlp_init(k[6], [d_mlp_in, *cfg.mlp_hidden, 1]),
    }


def _run_gru(p, xs, mask):
    """xs (B, T, d), mask (B, T) -> states (B, T, d)."""
    def step(h, inp):
        x_t, m_t = inp
        h_new = _gru_cell(p, h, x_t)
        h = jnp.where(m_t[..., None] > 0, h_new, h)
        return h, h
    h0 = jnp.zeros(xs.shape[:1] + xs.shape[2:], xs.dtype)
    _, hs = jax.lax.scan(step, h0, (jnp.moveaxis(xs, 1, 0),
                                    jnp.moveaxis(mask, 1, 0)))
    return jnp.moveaxis(hs, 0, 1)


def _run_augru(p, xs, mask, attn_w):
    """AUGRU: attention scalar a_t gates the update (B, T)."""
    def step(h, inp):
        x_t, m_t, a_t = inp
        h_new = _gru_cell(p, h, x_t, update_gate_scale=a_t)
        h = jnp.where(m_t[..., None] > 0, h_new, h)
        return h, None
    h0 = jnp.zeros(xs.shape[:1] + xs.shape[2:], xs.dtype)
    h, _ = jax.lax.scan(step, h0, (jnp.moveaxis(xs, 1, 0),
                                   jnp.moveaxis(mask, 1, 0),
                                   jnp.moveaxis(attn_w, 1, 0)))
    return h  # final state (B, d)


def _attention_weights(params, query, states, mask):
    q = jnp.broadcast_to(query[..., None, :], states.shape)
    feat = jnp.concatenate([q, states, q - states, q * states], axis=-1)
    logits = L.mlp_apply(params["attn"], feat, act="sigmoid")[..., 0]
    logits = jnp.where(mask > 0, logits, -1e9)
    return jax.nn.softmax(logits, axis=-1) * (mask.sum(-1, keepdims=True) > 0)


def forward(params, cfg: DIENConfig, batch: dict) -> jnp.ndarray:
    """Pointwise CTR logit; same batch schema as DIN."""
    xs = embed_items(params, batch["hist_ids"], batch["hist_cats"])
    mask = batch["hist_mask"]
    states = _run_gru(params["gru1"], xs, mask)  # interest extractor
    q = embed_items(params, batch["item_id"], batch["item_cat"])
    a = _attention_weights(params, q, states, mask)
    final = _run_augru(params["augru"], states, mask, a)  # evolution
    prof = L.embedding_apply(params["user_emb"], batch["user_fields"])
    prof = prof.reshape(*prof.shape[:-2], -1)
    x = jnp.concatenate([prof, final, q], axis=-1)
    return L.mlp_apply(params["mlp"], x, act="relu")[..., 0]


def score(params, cfg: DIENConfig, batch: dict, cand_ids, cand_cats):
    """(B, N) candidates. GRU1 runs once per user; AUGRU per candidate."""
    xs = embed_items(params, batch["hist_ids"], batch["hist_cats"])
    mask = batch["hist_mask"]
    states = _run_gru(params["gru1"], xs, mask)  # (B,T,d)
    prof = L.embedding_apply(params["user_emb"], batch["user_fields"])
    prof = prof.reshape(*prof.shape[:-2], -1)

    def per_cand(cid, ccat):
        q = embed_items(params, cid, ccat)  # (B,d)
        a = _attention_weights(params, q, states, mask)
        final = _run_augru(params["augru"], states, mask, a)
        x = jnp.concatenate([prof, final, q], axis=-1)
        return L.mlp_apply(params["mlp"], x, act="relu")[..., 0]

    return jax.vmap(per_cand, in_axes=(1, 1), out_axes=1)(cand_ids, cand_cats)


def loss_fn(params, cfg: DIENConfig, batch: dict) -> jnp.ndarray:
    logits = forward(params, cfg, batch)
    y = batch["label"].astype(logits.dtype)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def flops_per_item(cfg: DIENConfig) -> float:
    d = cfg.d_item
    gru1 = gru_flops(cfg.seq_len, d, d)  # amortizable but paper bills per item
    attn = cfg.seq_len * (mlp_flops([4 * d, *cfg.attn_hidden, 1]) + 4 * d)
    augru = gru_flops(cfg.seq_len, d, d)
    d_mlp_in = cfg.n_user_fields * cfg.embed_dim + 2 * d
    head = mlp_flops([d_mlp_in, *cfg.mlp_hidden, 1])
    return gru1 + attn + augru + head
