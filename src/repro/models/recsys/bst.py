"""BST - Behavior Sequence Transformer (Chen et al. [arXiv:1905.06874]).

Assigned config: embed_dim=32, seq_len=20, n_blocks=1, n_heads=8,
mlp=1024-512-256, interaction=transformer-seq.

The behavior sequence (19 history items + the target item appended, each
with a learned position embedding) runs through one post-LN transformer
block; the flattened sequence output concats with profile features into
the 1024-512-256 MLP head (LeakyReLU per the paper).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import attention_flops, dense_flops, mlp_flops
from repro.models import layers as L


@dataclass(frozen=True)
class BSTConfig:
    item_vocab: int = 4_000_000
    cat_vocab: int = 100_000
    user_vocab: int = 1_000_000
    n_user_fields: int = 4
    embed_dim: int = 32
    seq_len: int = 20  # includes the target item slot
    n_blocks: int = 1
    n_heads: int = 8
    d_ff_mult: int = 4
    mlp_hidden: tuple = (1024, 512, 256)

    @property
    def d_item(self) -> int:  # id ++ cat
        return 2 * self.embed_dim

    @property
    def d_head(self) -> int:
        return self.d_item // self.n_heads


def _block_init(key, cfg: BSTConfig) -> dict:
    d = cfg.d_item
    k = jax.random.split(key, 6)
    return {
        "wq": L.glorot_uniform(k[0], (d, d)),
        "wk": L.glorot_uniform(k[1], (d, d)),
        "wv": L.glorot_uniform(k[2], (d, d)),
        "wo": L.glorot_uniform(k[3], (d, d)),
        "ln1": L.layernorm_init(d),
        "ln2": L.layernorm_init(d),
        "ffn": L.mlp_init(k[4], [d, cfg.d_ff_mult * d, d]),
    }


def init(key, cfg: BSTConfig) -> dict:
    k = jax.random.split(key, 6 + cfg.n_blocks)
    d_mlp_in = cfg.n_user_fields * cfg.embed_dim + cfg.seq_len * cfg.d_item
    return {
        "item_emb": L.embedding_init(k[0], cfg.item_vocab, cfg.embed_dim),
        "cat_emb": L.embedding_init(k[1], cfg.cat_vocab, cfg.embed_dim),
        "user_emb": L.embedding_init(k[2], cfg.user_vocab, cfg.embed_dim),
        "pos_emb": L.normal_init(k[3], (cfg.seq_len, cfg.d_item)),
        "blocks": [_block_init(k[5 + i], cfg) for i in range(cfg.n_blocks)],
        "mlp": L.mlp_init(k[4], [d_mlp_in, *cfg.mlp_hidden, 1]),
    }


def _mha(p, cfg: BSTConfig, x: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """x (..., T, d), mask (..., T)."""
    t, d, h, dh = x.shape[-2], cfg.d_item, cfg.n_heads, cfg.d_head
    q = (x @ p["wq"]).reshape(*x.shape[:-1], h, dh)
    k = (x @ p["wk"]).reshape(*x.shape[:-1], h, dh)
    v = (x @ p["wv"]).reshape(*x.shape[:-1], h, dh)
    s = jnp.einsum("...qhd,...khd->...hqk", q, k) / jnp.sqrt(float(dh))
    s = jnp.where(mask[..., None, None, :] > 0, s, -1e9)
    a = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("...hqk,...khd->...qhd", a, v).reshape(*x.shape[:-1], d)
    return o @ p["wo"]


def _block(p, cfg: BSTConfig, x, mask):
    # post-LN, per the BST paper
    x = L.layernorm_apply(p["ln1"], x + _mha(p, cfg, x, mask))
    leaky = lambda z: jnp.where(z >= 0, z, 0.01 * z)
    h = L.dense_apply(p["ffn"]["layers"][0], x)
    h = leaky(h)
    h = L.dense_apply(p["ffn"]["layers"][1], h)
    return L.layernorm_apply(p["ln2"], x + h)


def embed_seq(params, ids, cats):
    return jnp.concatenate(
        [L.embedding_apply(params["item_emb"], ids),
         L.embedding_apply(params["cat_emb"], cats)], axis=-1)


def forward(params, cfg: BSTConfig, batch: dict) -> jnp.ndarray:
    """batch: hist_ids/hist_cats/hist_mask (B, T-1), item_id/item_cat (B,),
    user_fields (B, F) -> (B,) logits."""
    hist = embed_seq(params, batch["hist_ids"], batch["hist_cats"])
    target = embed_seq(params, batch["item_id"], batch["item_cat"])
    x = jnp.concatenate([hist, target[..., None, :]], axis=-2)  # (B,T,d)
    mask = jnp.concatenate(
        [batch["hist_mask"],
         jnp.ones((*batch["hist_mask"].shape[:-1], 1),
                  batch["hist_mask"].dtype)], axis=-1)
    x = x + params["pos_emb"]
    for blk in params["blocks"]:
        x = _block(blk, cfg, x, mask)
    x = x * mask[..., None]
    seq_flat = x.reshape(*x.shape[:-2], -1)
    prof = L.embedding_apply(params["user_emb"], batch["user_fields"])
    prof = prof.reshape(*prof.shape[:-2], -1)
    z = jnp.concatenate([prof, seq_flat], axis=-1)
    leaky = lambda v: jnp.where(v >= 0, v, 0.01 * v)
    for i, layer in enumerate(params["mlp"]["layers"]):
        z = L.dense_apply(layer, z)
        if i < len(params["mlp"]["layers"]) - 1:
            z = leaky(z)
    return z[..., 0]


def score(params, cfg: BSTConfig, batch: dict, cand_ids, cand_cats):
    """(B, N) candidates -> (B, N) scores (vmap over candidates)."""
    def per_cand(cid, ccat):
        b = dict(batch, item_id=cid, item_cat=ccat)
        return forward(params, cfg, b)
    return jax.vmap(per_cand, in_axes=(1, 1), out_axes=1)(cand_ids, cand_cats)


def loss_fn(params, cfg: BSTConfig, batch: dict) -> jnp.ndarray:
    logits = forward(params, cfg, batch)
    y = batch["label"].astype(logits.dtype)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def flops_per_example(cfg: BSTConfig) -> float:
    d, t = cfg.d_item, cfg.seq_len
    proj = 4 * dense_flops(d, d, t)
    attn = attention_flops(t, t, cfg.n_heads, cfg.d_head)
    ffn = mlp_flops([d, cfg.d_ff_mult * d, d], t)
    block = (proj + attn + ffn) * cfg.n_blocks
    d_mlp_in = cfg.n_user_fields * cfg.embed_dim + t * d
    head = mlp_flops([d_mlp_in, *cfg.mlp_hidden, 1])
    return block + head


def score_candidates_chunked(params, cfg: BSTConfig, batch: dict,
                             cand_ids: jnp.ndarray, cand_cats: jnp.ndarray,
                             *, n_chunks: int = 16) -> jnp.ndarray:
    """retrieval_cand path: ONE request vs N candidates, python-loop
    chunked (exact HLO flop counts; see dryrun notes)."""
    n = cand_ids.shape[0]
    assert n % n_chunks == 0

    def one_chunk(cid, ccat):
        c = cid.shape[0]
        b = {
            "hist_ids": jnp.broadcast_to(batch["hist_ids"][0][None],
                                         (c, batch["hist_ids"].shape[1])),
            "hist_cats": jnp.broadcast_to(batch["hist_cats"][0][None],
                                          (c, batch["hist_cats"].shape[1])),
            "hist_mask": jnp.broadcast_to(batch["hist_mask"][0][None],
                                          (c, batch["hist_mask"].shape[1])),
            "user_fields": jnp.broadcast_to(batch["user_fields"][0][None],
                                            (c, batch["user_fields"].shape[1])),
            "item_id": cid, "item_cat": ccat,
        }
        return forward(params, cfg, b)

    c = n // n_chunks
    outs = [one_chunk(cand_ids[i * c:(i + 1) * c],
                      cand_cats[i * c:(i + 1) * c]) for i in range(n_chunks)]
    return jnp.concatenate(outs)
