"""YoutubeDNN (Covington et al., RecSys'16) - pre-ranking model.

User tower: mean-pooled watch-history embeddings + profile fields -> MLP.
Scoring: dot(user_vector, item_embedding).  123K FLOPs/item in paper
Table 1 comes from their production feature count; ours is configurable
and measured analytically by ``flops_per_item``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import dense_flops, mlp_flops
from repro.models import layers as L
from repro.models.embedding import fixed_bag


@dataclass(frozen=True)
class YDNNConfig:
    item_vocab: int = 100_000
    n_user_fields: int = 4
    user_vocab: int = 200_000
    hist_len: int = 50
    embed_dim: int = 32
    hidden: tuple = (256, 128)
    d_out: int = 64


def init(key, cfg: YDNNConfig) -> dict:
    k = jax.random.split(key, 4)
    d_in = cfg.embed_dim + cfg.n_user_fields * cfg.embed_dim
    return {
        "item_emb": L.embedding_init(k[0], cfg.item_vocab, cfg.embed_dim),
        "user_emb": L.embedding_init(k[1], cfg.user_vocab, cfg.embed_dim),
        "tower": L.mlp_init(k[2], [d_in, *cfg.hidden, cfg.d_out]),
        "out_emb": L.embedding_init(k[3], cfg.item_vocab, cfg.d_out),
    }


def user_vector(params, cfg: YDNNConfig, hist_ids: jnp.ndarray,
                hist_mask: jnp.ndarray, user_fields: jnp.ndarray):
    """hist (B, T), mask (B, T), user_fields (B, F) -> (B, d_out)."""
    hist = fixed_bag(params["item_emb"]["table"], hist_ids, hist_mask,
                     mode="mean")  # (B, D)
    prof = L.embedding_apply(params["user_emb"], user_fields)
    prof = prof.reshape(*prof.shape[:-2], -1)
    x = jnp.concatenate([hist, prof], axis=-1)
    return L.mlp_apply(params["tower"], x, act="relu")


def score(params, cfg: YDNNConfig, hist_ids, hist_mask, user_fields,
          item_ids: jnp.ndarray) -> jnp.ndarray:
    """item_ids (B, N) -> (B, N) scores."""
    u = user_vector(params, cfg, hist_ids, hist_mask, user_fields)
    v = L.embedding_apply(params["out_emb"], item_ids)  # (B, N, d)
    return jnp.einsum("bd,bnd->bn", u, v)


def flops_per_item(cfg: YDNNConfig) -> float:
    return dense_flops(cfg.d_out, 1, use_bias=False)


def flops_per_request(cfg: YDNNConfig, n_items: int) -> float:
    d_in = cfg.embed_dim + cfg.n_user_fields * cfg.embed_dim
    tower = mlp_flops([d_in, *cfg.hidden, cfg.d_out])
    pool = cfg.hist_len * cfg.embed_dim
    return tower + pool + n_items * flops_per_item(cfg)
