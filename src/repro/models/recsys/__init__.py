"""RecSys model zoo: paper cascade models + assigned architectures."""
from repro.models.recsys import (bst, dien, din, dlrm, dssm, xdeepfm, ydnn)

__all__ = ["bst", "dien", "din", "dlrm", "dssm", "xdeepfm", "ydnn"]
