"""DLRM-RM2 (Naumov et al. [arXiv:1906.00091]; RM2 sizing from the
DeepRecSys/accelerator literature).

Assigned config: n_dense=13, n_sparse=26, embed_dim=64,
bot_mlp=13-512-256-64, top_mlp=512-512-256-1, interaction=dot.

The `512` leading the top MLP is its input width: pairwise dots among the
27 feature vectors (26 sparse + bottom output) give 27*26/2 = 351 terms,
concat the 64-dim bottom output = 415, zero-padded to 512 (documented in
DESIGN.md).  Embedding tables are the memory + collective hot path; the
sharded lookup lives in ``repro.models.embedding``.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import mlp_flops
from repro.models import layers as L
from repro.models.embedding import (sharded_embedding_apply,
                                    sharded_embedding_apply_2d)

# Criteo-like vocabulary sizes for the 26 sparse fields (sum ~88M rows).
CRITEO_VOCABS = (
    10_000_000, 39_060, 17_295, 7_424, 20_265, 3, 7_122, 1_543, 63,
    5_000_000, 3_067_956, 405_282, 10, 2_209, 11_938, 155, 4, 976, 14,
    10_000_000, 9_000_000, 40_000_000, 452_104, 12_606, 104, 35,
)


@dataclass(frozen=True)
class DLRMConfig:
    n_dense: int = 13
    vocab_sizes: tuple = CRITEO_VOCABS
    embed_dim: int = 64
    bot_mlp: tuple = (512, 256, 64)
    top_mlp: tuple = (512, 256, 1)
    top_pad: int = 512  # interaction output padded to this width
    stack_tables: bool = True  # one (sum V, D) table: single sharded lookup
    lookup_dtype: str = "bfloat16"  # wire dtype of the sharded lookup/grads
    table_dtype: str = "bfloat16"  # storage dtype (halves HBM + grad wire)
    shard_2d: bool = True  # unique row ownership over (model x data)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)

    @property
    def d_interact(self) -> int:
        f = self.n_sparse + 1
        return f * (f - 1) // 2 + self.bot_mlp[-1]


def init(key, cfg: DLRMConfig, *, pad_vocab_to: int = 1) -> dict:
    k = jax.random.split(key, 3)
    total_rows = sum(cfg.vocab_sizes)
    pad = (-total_rows) % pad_vocab_to
    table = L.normal_init(k[0], (total_rows + pad, cfg.embed_dim), std=0.01,
                          dtype=jnp.dtype(cfg.table_dtype))
    return {
        "tables": {"stacked": table},
        "bot": L.mlp_init(k[1], [cfg.n_dense, *cfg.bot_mlp]),
        "top": L.mlp_init(k[2], [cfg.top_pad, *cfg.top_mlp]),
    }


def table_offsets(cfg: DLRMConfig) -> jnp.ndarray:
    """Row offset of each field's sub-table inside the stacked table."""
    import numpy as np
    return jnp.asarray(np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]]),
                       jnp.int32)


def lookup(params, cfg: DLRMConfig, sparse_ids: jnp.ndarray,
           mesh=None) -> jnp.ndarray:
    """sparse_ids (B, 26) per-field ids -> (B, 26, D).

    With a mesh: ONE row-sharded lookup + ONE psum for all 26 fields
    (the stacked-table trick - see EXPERIMENTS.md §Perf)."""
    flat = sparse_ids + table_offsets(cfg)[None, :]
    table = params["tables"]["stacked"]
    dt = jnp.dtype(cfg.lookup_dtype)
    if mesh is None:
        return jnp.take(table, flat, axis=0).astype(dt)
    if cfg.shard_2d and "data" in mesh.axis_names:
        # TorchRec-style unique row ownership: grads never cross the wire
        out = sharded_embedding_apply_2d(
            table, flat.reshape(-1), mesh,
            axes=("model", "pod", "data"), out_dtype=dt)
    else:
        out = sharded_embedding_apply(table, flat.reshape(-1), mesh,
                                      axis="model", batch_axes=("data",),
                                      out_dtype=dt)
    return out.reshape(*sparse_ids.shape, cfg.embed_dim)


def dot_interact(feats: jnp.ndarray) -> jnp.ndarray:
    """feats (B, F, D) -> strictly-lower-triangle pairwise dots (B, F(F-1)/2).

    Oracle for ``repro.kernels.dot_interact``."""
    z = jnp.einsum("bfd,bgd->bfg", feats, feats)
    f = feats.shape[-2]
    iu, ju = jnp.tril_indices(f, k=-1)
    return z[..., iu, ju]


def forward(params, cfg: DLRMConfig, batch: dict, mesh=None) -> jnp.ndarray:
    """batch: dense (B, 13) float, sparse (B, 26) int32 -> (B,) logits."""
    x = L.mlp_apply(params["bot"], batch["dense"], act="relu",
                    final_act="relu")  # (B, 64)
    emb = lookup(params, cfg, batch["sparse"], mesh)  # (B, 26, D)
    feats = jnp.concatenate([x[:, None, :].astype(emb.dtype), emb], axis=1)
    inter = dot_interact(feats).astype(x.dtype)  # (B, 351) back to fp32
    z = jnp.concatenate([inter, x], axis=-1)  # (B, 415)
    pad = cfg.top_pad - z.shape[-1]
    if pad < 0:
        raise ValueError("top_pad smaller than interaction width")
    z = jnp.pad(z, ((0, 0), (0, pad)))
    return L.mlp_apply(params["top"], z, act="relu")[..., 0]


def loss_fn(params, cfg: DLRMConfig, batch: dict, mesh=None) -> jnp.ndarray:
    logits = forward(params, cfg, batch, mesh)
    y = batch["label"].astype(logits.dtype)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_forward(params, cfg: DLRMConfig, user_batch: dict,
                      cand_sparse: jnp.ndarray, mesh=None) -> jnp.ndarray:
    """retrieval_cand cell: one request (dense (1,13), sparse (1,26)) scored
    against N candidate item-side fields cand_sparse (N, n_item_fields=4):
    the last 4 sparse fields are item-side and swapped per candidate."""
    n = cand_sparse.shape[0]
    dense = jnp.broadcast_to(user_batch["dense"], (n, cfg.n_dense))
    user_sp = jnp.broadcast_to(user_batch["sparse"], (n, cfg.n_sparse))
    sparse = user_sp.at[:, -cand_sparse.shape[1]:].set(cand_sparse)
    return forward(params, cfg, {"dense": dense, "sparse": sparse}, mesh)


def flops_per_example(cfg: DLRMConfig) -> float:
    bot = mlp_flops([cfg.n_dense, *cfg.bot_mlp])
    f = cfg.n_sparse + 1
    inter = 2.0 * f * f * cfg.embed_dim
    top = mlp_flops([cfg.top_pad, *cfg.top_mlp])
    return bot + inter + top
