"""xDeepFM (Lian et al. [arXiv:1803.05170]).

Assigned config: n_sparse=39, embed_dim=10, cin_layers=200-200-200,
mlp=400-400, interaction=CIN (Compressed Interaction Network).

CIN layer k:  X^k[b,h,d] = sum_{i,j} W^k[h,i,j] * X^{k-1}[b,i,d] * X^0[b,j,d]
(vector-wise outer product compressed by a 1x1 "conv").  Sum-pool over d of
every layer's feature maps -> CIN logit.  Three heads (linear + CIN + DNN)
sum into the final logit, faithful to the paper.

The fused Pallas CIN layer lives in ``repro.kernels.cin``; ``cin_layer``
here is its oracle.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import mlp_flops
from repro.models import layers as L
from repro.models.embedding import (sharded_embedding_apply,
                                    sharded_embedding_apply_2d)

# 39 sparse fields, Criteo-like tails plus extra fields (sum ~93M rows)
XDEEPFM_VOCABS = (
    10_000_000, 39_060, 17_295, 7_424, 20_265, 3, 7_122, 1_543, 63,
    5_000_000, 3_067_956, 405_282, 10, 2_209, 11_938, 155, 4, 976, 14,
    10_000_000, 9_000_000, 40_000_000, 452_104, 12_606, 104, 35,
    1_000_000, 500_000, 250_000, 100_000, 50_000, 20_000, 10_000,
    5_000, 2_000, 1_000, 500, 200, 100,
)


@dataclass(frozen=True)
class XDeepFMConfig:
    vocab_sizes: tuple = XDEEPFM_VOCABS
    embed_dim: int = 10
    cin_layers: tuple = (200, 200, 200)
    mlp_hidden: tuple = (400, 400)
    table_dtype: str = "bfloat16"  # storage dtype (DLRM §Perf iter 3 port)
    lookup_dtype: str = "bfloat16"
    shard_2d: bool = True  # unique row ownership over (model x pod x data)

    @property
    def n_sparse(self) -> int:
        return len(self.vocab_sizes)


def init(key, cfg: XDeepFMConfig, *, pad_vocab_to: int = 1) -> dict:
    k = jax.random.split(key, 4 + len(cfg.cin_layers))
    total = sum(cfg.vocab_sizes)
    pad = (-total) % pad_vocab_to
    m = cfg.n_sparse
    cin_w = []
    h_prev = m
    for i, h in enumerate(cfg.cin_layers):
        cin_w.append(L.glorot_uniform(k[3 + i], (h, h_prev * m)))
        h_prev = h
    dt = jnp.dtype(cfg.table_dtype)
    return {
        "tables": {"stacked": L.normal_init(k[0], (total + pad, cfg.embed_dim),
                                            std=0.01, dtype=dt)},
        "linear": L.normal_init(k[1], (total + pad, 1), std=0.01, dtype=dt),
        "cin": cin_w,
        "cin_out": L.dense_init(k[2], sum(cfg.cin_layers), 1),
        "dnn": L.mlp_init(jax.random.fold_in(k[2], 7),
                          [m * cfg.embed_dim, *cfg.mlp_hidden, 1]),
    }


def table_offsets(cfg: XDeepFMConfig) -> jnp.ndarray:
    import numpy as np
    return jnp.asarray(np.concatenate([[0], np.cumsum(cfg.vocab_sizes)[:-1]]),
                       jnp.int32)


def cin_layer(w: jnp.ndarray, x_prev: jnp.ndarray,
              x0: jnp.ndarray) -> jnp.ndarray:
    """w (H_out, H_prev*m), x_prev (B, H_prev, D), x0 (B, m, D) -> (B, H_out, D).

    Oracle for ``repro.kernels.cin``."""
    b, hp, d = x_prev.shape
    m = x0.shape[1]
    z = jnp.einsum("bhd,bmd->bhmd", x_prev, x0).reshape(b, hp * m, d)
    return jnp.einsum("oc,bcd->bod", w, z)


def forward(params, cfg: XDeepFMConfig, batch: dict, mesh=None) -> jnp.ndarray:
    """batch: sparse (B, 39) int32 -> (B,) logits."""
    flat = batch["sparse"] + table_offsets(cfg)[None, :]
    table = params["tables"]["stacked"]
    dt = jnp.dtype(cfg.lookup_dtype)
    if mesh is None:
        x0 = jnp.take(table, flat, axis=0).astype(dt)  # (B, m, D)
        lin = jnp.take(params["linear"], flat, axis=0)[..., 0].astype(dt)
    elif cfg.shard_2d and "data" in mesh.axis_names:
        axes = ("model", "pod", "data")
        x0 = sharded_embedding_apply_2d(table, flat.reshape(-1), mesh,
                                        axes=axes, out_dtype=dt
                                        ).reshape(*flat.shape, cfg.embed_dim)
        lin = sharded_embedding_apply_2d(params["linear"], flat.reshape(-1),
                                         mesh, axes=axes, out_dtype=dt
                                         ).reshape(*flat.shape)
    else:
        x0 = sharded_embedding_apply(table, flat.reshape(-1), mesh,
                                     axis="model", batch_axes=("data",),
                                     out_dtype=dt
                                     ).reshape(*flat.shape, cfg.embed_dim)
        lin = sharded_embedding_apply(params["linear"], flat.reshape(-1), mesh,
                                      axis="model", batch_axes=("data",),
                                      out_dtype=dt
                                      ).reshape(*flat.shape)
    y_lin = jnp.sum(lin.astype(jnp.float32), axis=-1)

    # CIN head (f32 math on bf16-fetched embeddings)
    x0 = x0.astype(jnp.float32)
    x = x0
    pooled = []
    for w in params["cin"]:
        x = cin_layer(w, x, x0)
        pooled.append(jnp.sum(x, axis=-1))  # (B, H_k)
    y_cin = L.dense_apply(params["cin_out"],
                          jnp.concatenate(pooled, axis=-1))[..., 0]

    # DNN head
    flat_emb = x0.reshape(x0.shape[0], -1)
    y_dnn = L.mlp_apply(params["dnn"], flat_emb, act="relu")[..., 0]
    return y_lin + y_cin + y_dnn


def loss_fn(params, cfg: XDeepFMConfig, batch: dict, mesh=None) -> jnp.ndarray:
    logits = forward(params, cfg, batch, mesh)
    y = batch["label"].astype(logits.dtype)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def retrieval_forward(params, cfg: XDeepFMConfig, user_batch: dict,
                      cand_sparse: jnp.ndarray, mesh=None) -> jnp.ndarray:
    n = cand_sparse.shape[0]
    user_sp = jnp.broadcast_to(user_batch["sparse"], (n, cfg.n_sparse))
    sparse = user_sp.at[:, -cand_sparse.shape[1]:].set(cand_sparse)
    return forward(params, cfg, {"sparse": sparse}, mesh)


def flops_per_example(cfg: XDeepFMConfig) -> float:
    m, d = cfg.n_sparse, cfg.embed_dim
    h_prev, cin = m, 0.0
    for h in cfg.cin_layers:
        cin += 2.0 * h * h_prev * m * d + h_prev * m * d  # contraction + outer
        h_prev = h
    dnn = mlp_flops([m * d, *cfg.mlp_hidden, 1])
    return cin + dnn + 2.0 * sum(cfg.cin_layers) + m
