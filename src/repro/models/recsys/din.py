"""DIN - Deep Interest Network (Zhou et al., KDD'18).

Assigned config [arXiv:1706.06978]: embed_dim=18, seq_len=100,
attn_mlp=80-40, mlp=200-80, interaction=target-attn.

Also the paper cascade's ranking model (Table 1: 7020K FLOPs, AUC 0.639).

Target attention: for target item q and history key k_t the score is
MLP([q, k_t, q-k_t, q*k_t]) -> scalar; weighted sum WITHOUT softmax
normalization (faithful to the DIN paper: attention intensities are kept
unnormalized to preserve interest strength).  Activation: PReLU (Dice's
batch statistics are jit-unfriendly; noted in DESIGN.md).

The fused Pallas version of the attention pool is
``repro.kernels.target_attention``; this module is its jnp oracle.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import dense_flops, mlp_flops
from repro.models import layers as L


@dataclass(frozen=True)
class DINConfig:
    item_vocab: int = 200_000
    cat_vocab: int = 5_000
    user_vocab: int = 200_000
    n_user_fields: int = 2
    embed_dim: int = 18
    seq_len: int = 100
    attn_hidden: tuple = (80, 40)
    mlp_hidden: tuple = (200, 80)

    @property
    def d_item(self) -> int:  # id-emb ++ cat-emb
        return 2 * self.embed_dim


def init(key, cfg: DINConfig) -> dict:
    k = jax.random.split(key, 7)
    d = cfg.d_item
    d_mlp_in = cfg.n_user_fields * cfg.embed_dim + 2 * d  # profile ++ pool ++ target
    return {
        "item_emb": L.embedding_init(k[0], cfg.item_vocab, cfg.embed_dim),
        "cat_emb": L.embedding_init(k[1], cfg.cat_vocab, cfg.embed_dim),
        "user_emb": L.embedding_init(k[2], cfg.user_vocab, cfg.embed_dim),
        "attn": L.mlp_init(k[3], [4 * d, *cfg.attn_hidden, 1]),
        "mlp": L.mlp_init(k[4], [d_mlp_in, *cfg.mlp_hidden, 1]),
        "prelu1": L.prelu_init(cfg.mlp_hidden[0]),
        "prelu2": L.prelu_init(cfg.mlp_hidden[1]),
    }


def embed_items(params, ids: jnp.ndarray, cats: jnp.ndarray) -> jnp.ndarray:
    return jnp.concatenate(
        [L.embedding_apply(params["item_emb"], ids),
         L.embedding_apply(params["cat_emb"], cats)], axis=-1)


def attention_pool(params, query: jnp.ndarray, keys: jnp.ndarray,
                   mask: jnp.ndarray) -> jnp.ndarray:
    """query (..., d), keys (..., T, d), mask (..., T) -> pooled (..., d)."""
    q = jnp.broadcast_to(query[..., None, :], keys.shape)
    feat = jnp.concatenate([q, keys, q - keys, q * keys], axis=-1)
    w = L.mlp_apply(params["attn"], feat, act="sigmoid")[..., 0]  # (...,T)
    w = w * mask  # padded history contributes nothing
    return jnp.einsum("...t,...td->...d", w, keys)


def _head(params, cfg: DINConfig, profile, pooled, target):
    x = jnp.concatenate([profile, pooled, target], axis=-1)
    x = L.dense_apply(params["mlp"]["layers"][0], x)
    x = L.prelu_apply(params["prelu1"], x)
    x = L.dense_apply(params["mlp"]["layers"][1], x)
    x = L.prelu_apply(params["prelu2"], x)
    return L.dense_apply(params["mlp"]["layers"][2], x)[..., 0]


def forward(params, cfg: DINConfig, batch: dict) -> jnp.ndarray:
    """Pointwise CTR logit. batch: hist_ids/hist_cats/hist_mask (B,T),
    user_fields (B,F), item_id/item_cat (B,) -> (B,) logits."""
    keys = embed_items(params, batch["hist_ids"], batch["hist_cats"])
    q = embed_items(params, batch["item_id"], batch["item_cat"])
    pooled = attention_pool(params, q, keys, batch["hist_mask"])
    prof = L.embedding_apply(params["user_emb"], batch["user_fields"])
    prof = prof.reshape(*prof.shape[:-2], -1)
    return _head(params, cfg, prof, pooled, q)


def score(params, cfg: DINConfig, batch: dict, cand_ids: jnp.ndarray,
          cand_cats: jnp.ndarray) -> jnp.ndarray:
    """Rank N candidates per request: cand_ids/cand_cats (B, N) -> (B, N)."""
    keys = embed_items(params, batch["hist_ids"], batch["hist_cats"])  # (B,T,d)
    q = embed_items(params, cand_ids, cand_cats)  # (B,N,d)
    keys_b = jnp.broadcast_to(keys[..., None, :, :],
                              (*q.shape[:-1], keys.shape[-2], keys.shape[-1]))
    mask_b = jnp.broadcast_to(batch["hist_mask"][..., None, :],
                              (*q.shape[:-1], keys.shape[-2]))
    pooled = attention_pool(params, q, keys_b, mask_b)
    prof = L.embedding_apply(params["user_emb"], batch["user_fields"])
    prof = prof.reshape(*prof.shape[:-2], -1)
    prof = jnp.broadcast_to(prof[..., None, :], (*q.shape[:-1], prof.shape[-1]))
    return _head(params, cfg, prof, pooled, q)


def loss_fn(params, cfg: DINConfig, batch: dict) -> jnp.ndarray:
    logits = forward(params, cfg, batch)
    y = batch["label"].astype(logits.dtype)
    return jnp.mean(
        jnp.maximum(logits, 0) - logits * y + jnp.log1p(jnp.exp(-jnp.abs(logits))))


def flops_per_item(cfg: DINConfig) -> float:
    """Score one candidate for one user (paper Table 1 grain)."""
    d = cfg.d_item
    attn = cfg.seq_len * (mlp_flops([4 * d, *cfg.attn_hidden, 1]) + 4 * d)
    pool = dense_flops(cfg.seq_len, 1, use_bias=False) * d
    d_mlp_in = cfg.n_user_fields * cfg.embed_dim + 2 * d
    head = mlp_flops([d_mlp_in, *cfg.mlp_hidden, 1])
    return attn + pool + head


def score_candidates_chunked(params, cfg: DINConfig, batch: dict,
                             cand_ids: jnp.ndarray, cand_cats: jnp.ndarray,
                             *, n_chunks: int = 16) -> jnp.ndarray:
    """retrieval_cand path: ONE request vs huge candidate sets.

    cand_ids/cand_cats (N,) -> (N,) scores.  Chunked with a PYTHON loop so
    the (chunk, T, 4d) attention feature tensor stays bounded AND the HLO
    flop count stays exact (while-loops undercount - see dryrun notes);
    candidates are expected sharded over the batch axes by the caller."""
    n = cand_ids.shape[0]
    assert n % n_chunks == 0, "candidate count must divide n_chunks"
    keys = embed_items(params, batch["hist_ids"], batch["hist_cats"])  # (1,T,d)
    prof = L.embedding_apply(params["user_emb"], batch["user_fields"])
    prof = prof.reshape(*prof.shape[:-2], -1)  # (1, F*D)

    def one_chunk(cid, ccat):
        q = embed_items(params, cid, ccat)  # (C, d)
        keys_b = jnp.broadcast_to(keys[0][None], (q.shape[0], *keys.shape[1:]))
        mask_b = jnp.broadcast_to(batch["hist_mask"][0][None],
                                  (q.shape[0], keys.shape[1]))
        pooled = attention_pool(params, q, keys_b, mask_b)
        prof_b = jnp.broadcast_to(prof[0][None], (q.shape[0], prof.shape[-1]))
        return _head(params, cfg, prof_b, pooled, q)

    c = n // n_chunks
    outs = [one_chunk(cand_ids[i * c:(i + 1) * c],
                      cand_cats[i * c:(i + 1) * c]) for i in range(n_chunks)]
    return jnp.concatenate(outs)
