"""DSSM (Huang et al., CIKM'13) - two-tower recall model.

Recall stage of the paper's cascade: cheap (13K FLOPs/item, Table 1)
because candidate scoring is one dot product once towers are computed; the
item tower is precomputed offline for the whole corpus.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.core.flops import mlp_flops
from repro.models import layers as L


@dataclass(frozen=True)
class DSSMConfig:
    user_vocab: int = 200_000  # hashed user categorical ids
    item_vocab: int = 100_000
    n_user_fields: int = 4
    n_item_fields: int = 2
    embed_dim: int = 16
    hidden: tuple = (128, 64)
    d_out: int = 32


def init(key, cfg: DSSMConfig) -> dict:
    k = jax.random.split(key, 4)
    d_user_in = cfg.n_user_fields * cfg.embed_dim
    d_item_in = cfg.n_item_fields * cfg.embed_dim
    return {
        "user_emb": L.embedding_init(k[0], cfg.user_vocab, cfg.embed_dim),
        "item_emb": L.embedding_init(k[1], cfg.item_vocab, cfg.embed_dim),
        "user_tower": L.mlp_init(k[2], [d_user_in, *cfg.hidden, cfg.d_out]),
        "item_tower": L.mlp_init(k[3], [d_item_in, *cfg.hidden, cfg.d_out]),
    }


def user_tower(params, cfg: DSSMConfig, user_fields: jnp.ndarray):
    """user_fields (B, n_user_fields) int32 -> (B, d_out)."""
    e = L.embedding_apply(params["user_emb"], user_fields)  # (B,F,D)
    e = e.reshape(*e.shape[:-2], -1)
    u = L.mlp_apply(params["user_tower"], e, act="relu")
    return u / (jnp.linalg.norm(u, axis=-1, keepdims=True) + 1e-6)


def item_tower(params, cfg: DSSMConfig, item_fields: jnp.ndarray):
    """item_fields (..., n_item_fields) int32 -> (..., d_out)."""
    e = L.embedding_apply(params["item_emb"], item_fields)
    e = e.reshape(*e.shape[:-2], -1)
    v = L.mlp_apply(params["item_tower"], e, act="relu")
    return v / (jnp.linalg.norm(v, axis=-1, keepdims=True) + 1e-6)


def score(params, cfg: DSSMConfig, user_fields: jnp.ndarray,
          item_fields: jnp.ndarray) -> jnp.ndarray:
    """user (B, Fu), items (B, N, Fi) -> cosine scores (B, N)."""
    u = user_tower(params, cfg, user_fields)  # (B, d)
    v = item_tower(params, cfg, item_fields)  # (B, N, d)
    return jnp.einsum("bd,bnd->bn", u, v)


def retrieval_scores(params, cfg: DSSMConfig, user_fields: jnp.ndarray,
                     corpus_vectors: jnp.ndarray) -> jnp.ndarray:
    """Online recall: user (B, Fu) x precomputed corpus (N, d) -> (B, N)."""
    u = user_tower(params, cfg, user_fields)
    return u @ corpus_vectors.T


def flops_per_item(cfg: DSSMConfig) -> float:
    """Online cost to score ONE candidate = one d_out dot (towers amortized)."""
    return dense_flops(cfg.d_out, 1, use_bias=False)


def flops_per_request(cfg: DSSMConfig, n_items: int) -> float:
    d_user_in = cfg.n_user_fields * cfg.embed_dim
    tower = mlp_flops([d_user_in, *cfg.hidden, cfg.d_out])
    return tower + n_items * flops_per_item(cfg)
