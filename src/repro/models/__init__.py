"""Shared model zoo: pure-pytree init/apply modules."""
