"""Configurable decoder-only LM covering the five assigned architectures.

One implementation, config-selected features:
  * GQA (n_kv_heads <= n_heads), RoPE (partial fraction, theta),
  * dense gated FFN (SwiGLU/GeGLU) or MoE (top-k routing, EP-sharded),
  * gemma2: local/global alternating sliding window, attn + final logit
    softcap, zero-centered RMSNorm, sandwich (pre+post) norms, GeGLU,
  * olmoe: QK-norm,
  * minicpm: embedding scale, depth-scaled residuals (mup-ish),
  * layers stacked on a leading L dim and executed with ``lax.scan``
    (compile time stays flat in depth - critical for the 512-device
    dry-run on one CPU core).

Sharding (GSPMD via ``distributed.sharding.constrain``; see DESIGN.md §6):
batch over (pod, data); attention heads + ffn hidden + vocab over 'model'
(Megatron TP); params optionally FSDP over 'data'; MoE experts over
'model' (EP) via an explicit shard_map (psum-combined, the EP-as-TP
pattern).  Entry points: ``forward`` / ``loss_fn`` (train),
``prefill`` and ``decode_step`` (serve).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import batch_spec, constrain, current_mesh
from repro.models import layers as L

# ---------------------------------------------------------------------------
# Config
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int  # per-expert FFN hidden
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01  # load-balance loss (Switch-style)


@dataclass(frozen=True)
class LMConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_head: int
    d_ff: int
    vocab: int
    padded_vocab: int  # multiple of 256 (shardable over 'model')
    rope_theta: float = 10_000.0
    rope_fraction: float = 1.0  # glm4 rotates half the head dim
    moe: MoEConfig | None = None
    window_pattern: tuple | None = None  # e.g. (4096, -1): local, global, ...
    attn_softcap: float | None = None
    final_softcap: float | None = None
    qk_norm: bool = False
    sandwich_norm: bool = False  # gemma2 pre+post norms
    zero_centered_norm: bool = False  # gemma (1+scale) RMSNorm
    gated_ffn: bool = True
    act: str = "silu"  # silu (llama) | gelu (gemma GeGLU)
    embed_scale: float | None = None  # gemma sqrt(d), minicpm 12.0
    residual_scale: float = 1.0  # minicpm 1.4/sqrt(40)
    logit_divisor: float = 1.0  # minicpm d_model/dim_base
    tie_embeddings: bool = True
    query_scale: float | None = None  # default 1/sqrt(d_head)
    dtype: str = "bfloat16"  # activation/compute dtype
    remat: bool = True  # checkpoint each layer in training
    fsdp: bool = True  # shard params over 'data'
    # q-block-chunked attention (python-unrolled: exact HLO flop counts,
    # bounded score memory - the jnp stand-in for the Pallas flash kernel)
    attn_chunk_q: int | None = None
    # unroll factor for the layer scan (dry-run flop-count variants set
    # this = n_layers so XLA sees every body; production leaves it 1)
    scan_unroll: int = 1
    # attention sharding axis: "heads" (Megatron TP; needs n_heads %
    # n_model_shards == 0) or "seq" (context-parallel: q stays seq-sharded,
    # kv gathers - the fix for gemma2's 8 heads / minicpm's 36 heads vs a
    # 16-way model axis, which otherwise triggers GSPMD involuntary full
    # rematerialization; see EXPERIMENTS.md SPerf iteration 1)
    attn_shard: str = "heads"
    # Megatron-style sequence parallelism: the inter-layer residual stream
    # is sharded over 'model' on the SEQ dim (norms/elementwise run
    # seq-sharded; GSPMD inserts all-gather at attention/FFN entry and
    # reduce-scatter at exit).  Cuts the per-layer activation stash (the
    # dominant train-memory term - see EXPERIMENTS.md SPerf) by the TP
    # degree.
    sequence_parallel: bool = False

    @property
    def compute_dtype(self):
        return jnp.dtype(self.dtype)

    @property
    def d_q(self) -> int:
        return self.n_heads * self.d_head

    @property
    def d_kv(self) -> int:
        return self.n_kv_heads * self.d_head

    def window_for_layer(self, i: int) -> int:
        if not self.window_pattern:
            return -1
        return self.window_pattern[i % len(self.window_pattern)]

    def n_params(self) -> float:
        """Total parameter count (embedding included once if tied)."""
        d, l = self.d_model, self.n_layers
        attn = d * (self.d_q + 2 * self.d_kv) + self.d_q * d
        if self.moe:
            n_mats = 3 if self.gated_ffn else 2
            ffn = self.moe.n_experts * n_mats * d * self.moe.d_expert
            ffn += d * self.moe.n_experts  # router
        else:
            n_mats = 3 if self.gated_ffn else 2
            ffn = n_mats * d * self.d_ff
        norms = 4 * d if self.sandwich_norm else 2 * d
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn + norms) + emb + d

    def n_active_params(self) -> float:
        """Params touched per token (MoE: top_k of n_experts)."""
        if not self.moe:
            return self.n_params()
        d, l = self.d_model, self.n_layers
        attn = d * (self.d_q + 2 * self.d_kv) + self.d_q * d
        n_mats = 3 if self.gated_ffn else 2
        ffn = self.moe.top_k * n_mats * d * self.moe.d_expert
        ffn += d * self.moe.n_experts
        emb = self.padded_vocab * d * (1 if self.tie_embeddings else 2)
        return l * (attn + ffn) + emb + d


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------


def rope_freqs(cfg: LMConfig) -> jnp.ndarray:
    rot = int(cfg.d_head * cfg.rope_fraction)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    return inv  # (rot/2,)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               cfg: LMConfig) -> jnp.ndarray:
    """x (..., T, H, dh), positions (..., T) -> rotated x."""
    inv = rope_freqs(cfg)
    rot = inv.shape[0] * 2
    ang = positions[..., None].astype(jnp.float32) * inv  # (..., T, rot/2)
    cos, sin = jnp.cos(ang)[..., None, :], jnp.sin(ang)[..., None, :]
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin
    yr = jnp.stack([out1, out2], axis=-1).reshape(xr.shape)
    return jnp.concatenate([yr, xp], axis=-1).astype(x.dtype)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _layer_init(key, cfg: LMConfig) -> dict:
    d = cfg.d_model
    k = jax.random.split(key, 10)
    p = {
        "wq": L.normal_init(k[0], (d, cfg.d_q), std=0.02),
        "wk": L.normal_init(k[1], (d, cfg.d_kv), std=0.02),
        "wv": L.normal_init(k[2], (d, cfg.d_kv), std=0.02),
        "wo": L.normal_init(k[3], (cfg.d_q, d), std=0.02 / math.sqrt(2 * cfg.n_layers)),
        "ln_attn": L.rmsnorm_init(d),
        "ln_ffn": L.rmsnorm_init(d),
    }
    if cfg.sandwich_norm:
        p["ln_attn_post"] = L.rmsnorm_init(d)
        p["ln_ffn_post"] = L.rmsnorm_init(d)
    if cfg.qk_norm:
        p["q_norm"] = L.rmsnorm_init(cfg.d_head)
        p["k_norm"] = L.rmsnorm_init(cfg.d_head)
    if cfg.moe:
        e, f = cfg.moe.n_experts, cfg.moe.d_expert
        p["router"] = L.normal_init(k[4], (d, e), std=0.02)
        p["w1"] = L.normal_init(k[5], (e, d, f), std=0.02)
        p["w2"] = L.normal_init(k[6], (e, f, d), std=0.02 / math.sqrt(2 * cfg.n_layers))
        if cfg.gated_ffn:
            p["w3"] = L.normal_init(k[7], (e, d, f), std=0.02)
    else:
        f = cfg.d_ff
        p["w1"] = L.normal_init(k[5], (d, f), std=0.02)
        p["w2"] = L.normal_init(k[6], (f, d), std=0.02 / math.sqrt(2 * cfg.n_layers))
        if cfg.gated_ffn:
            p["w3"] = L.normal_init(k[7], (d, f), std=0.02)
    return p


def init(key, cfg: LMConfig) -> dict:
    """Stacked-layer params: every layer tensor gets a leading (L,) dim."""
    k_emb, k_layers, k_out = jax.random.split(key, 3)
    layer_keys = jax.random.split(k_layers, cfg.n_layers)
    layers = [_layer_init(kk, cfg) for kk in layer_keys]
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *layers)
    params = {
        "embed": L.normal_init(k_emb, (cfg.padded_vocab, cfg.d_model), std=0.02),
        "layers": stacked,
        "ln_final": L.rmsnorm_init(cfg.d_model),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = L.normal_init(k_out, (cfg.padded_vocab, cfg.d_model),
                                          std=0.02)
    return params


def window_array(cfg: LMConfig) -> jnp.ndarray:
    """Per-layer sliding-window sizes (-1 = global) as a scan xs constant."""
    return jnp.asarray([cfg.window_for_layer(i) for i in range(cfg.n_layers)],
                       jnp.int32)


def param_shardings(cfg: LMConfig) -> dict:
    """PartitionSpec tree matching ``init`` (leading L dim unsharded)."""
    dp = "data" if cfg.fsdp else None
    lay = {
        "wq": P(None, dp, "model"),
        "wk": P(None, dp, "model"),
        "wv": P(None, dp, "model"),
        "wo": P(None, "model", dp),
        "ln_attn": {"scale": P(None, None)},
        "ln_ffn": {"scale": P(None, None)},
    }
    if cfg.sandwich_norm:
        lay["ln_attn_post"] = {"scale": P(None, None)}
        lay["ln_ffn_post"] = {"scale": P(None, None)}
    if cfg.qk_norm:
        lay["q_norm"] = {"scale": P(None, None)}
        lay["k_norm"] = {"scale": P(None, None)}
    if cfg.moe:
        lay["router"] = P(None, dp, None)
        lay["w1"] = P(None, "model", dp, None)
        lay["w2"] = P(None, "model", None, dp)
        if cfg.gated_ffn:
            lay["w3"] = P(None, "model", dp, None)
    else:
        lay["w1"] = P(None, dp, "model")
        lay["w2"] = P(None, "model", dp)
        if cfg.gated_ffn:
            lay["w3"] = P(None, dp, "model")
    out = {
        "embed": P("model", dp),
        "layers": lay,
        "ln_final": {"scale": P(None)},
    }
    if not cfg.tie_embeddings:
        out["lm_head"] = P("model", dp)
    return out


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------


def _split_heads(x, n, dh):
    return x.reshape(*x.shape[:-1], n, dh)


def _constrain_stream(cfg, x):
    """Residual-stream layout between blocks: batch over (pod,data) and,
    under sequence parallelism, seq over 'model'."""
    bspec = batch_spec()
    if cfg.sequence_parallel and x.shape[1] > 1:
        return constrain(x, bspec, "model", None)
    return constrain(x, bspec, None, None)


def _attn_mask(q_pos, k_pos, window):
    """Causal + optional sliding window.  window < 0 => global."""
    causal = k_pos[None, :] <= q_pos[:, None]
    in_window = (q_pos[:, None] - k_pos[None, :]) < jnp.where(window < 0,
                                                              jnp.iinfo(jnp.int32).max,
                                                              window)
    return causal & in_window


def _attention(cfg: LMConfig, q, k, v, mask):
    """q (B,T,H,dh), k/v (B,S,Hkv,dh), mask (T,S) or (B,T,S)."""
    scale = cfg.query_scale or 1.0 / math.sqrt(cfg.d_head)
    groups = cfg.n_heads // cfg.n_kv_heads
    b, t = q.shape[0], q.shape[1]
    qg = q.reshape(b, t, cfg.n_kv_heads, groups, cfg.d_head)
    logits = jnp.einsum("btkgd,bskd->bkgts", qg, k).astype(jnp.float32) * scale
    if cfg.attn_softcap:
        logits = cfg.attn_softcap * jnp.tanh(logits / cfg.attn_softcap)
    if mask.ndim == 2:
        mask = mask[None]
    logits = jnp.where(mask[:, None, None, :, :], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkgts,bskd->btkgd", probs, v)
    return out.reshape(b, t, cfg.d_q)


def _attention_chunked(cfg: LMConfig, q, k, v, positions, window,
                       chunk: int):
    """Exact attention, python-unrolled over q blocks: score memory is
    bounded to (B, Hkv, G, chunk, S) and the HLO contains every block
    (no while-loop flop undercount).  TPU production uses the Pallas
    flash kernel (repro.kernels.flash_attention); this is its XLA twin."""
    t = q.shape[1]
    n_blocks = -(-t // chunk)
    k_pos = positions[0]
    outs = []
    for i in range(n_blocks):
        lo = i * chunk
        hi = min(t, lo + chunk)
        qb = q[:, lo:hi]
        mask = _attn_mask(positions[0, lo:hi], k_pos, window)
        outs.append(_attention(cfg, qb, k, v, mask))
    return jnp.concatenate(outs, axis=1)


def _attn_block(p, cfg: LMConfig, x, positions, window, kv=None, kv_pos=None):
    """x (B,T,d).  kv: optional (k_cache, v_cache) for decode."""
    bspec = batch_spec()
    q = _split_heads(x @ p["wq"].astype(x.dtype), cfg.n_heads, cfg.d_head)
    k = _split_heads(x @ p["wk"].astype(x.dtype), cfg.n_kv_heads, cfg.d_head)
    v = _split_heads(x @ p["wv"].astype(x.dtype), cfg.n_kv_heads, cfg.d_head)
    if cfg.qk_norm:
        q = L.rmsnorm_apply(p["q_norm"], q, zero_centered=cfg.zero_centered_norm)
        k = L.rmsnorm_apply(p["k_norm"], k, zero_centered=cfg.zero_centered_norm)
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    if cfg.attn_shard == "seq" and q.shape[1] > 1:
        # context-parallel: shard queries on SEQ over 'model'; kv replicate
        q = constrain(q, bspec, "model", None, None)
        k = constrain(k, bspec, None, None, None)
        v = constrain(v, bspec, None, None, None)
    else:
        q = constrain(q, bspec, None, "model", None)
        k = constrain(k, bspec, None, None, None)  # kv heads < shards
    if kv is None:
        if cfg.attn_chunk_q and q.shape[1] > cfg.attn_chunk_q:
            out = _attention_chunked(cfg, q, k, v, positions, window,
                                     cfg.attn_chunk_q)
        else:
            mask = _attn_mask(positions[0], positions[0], window)
            out = _attention(cfg, q, k, v, mask)
        new_kv = (k, v)
    else:
        k_cache, v_cache = kv
        mask = _attn_mask(positions[0], kv_pos, window)
        out = _attention(cfg, q, k_cache, v_cache, mask)
        new_kv = (k, v)
    out = out @ p["wo"].astype(x.dtype)
    return constrain(out, bspec, None, None), new_kv


# ---------------------------------------------------------------------------
# FFN / MoE
# ---------------------------------------------------------------------------


def _dense_ffn(p, cfg: LMConfig, x):
    bspec = batch_spec()
    act = jax.nn.silu if cfg.act == "silu" else partial(jax.nn.gelu, approximate=True)
    h = act(x @ p["w1"].astype(x.dtype))
    if cfg.gated_ffn:
        h = h * (x @ p["w3"].astype(x.dtype))
    h = constrain(h, bspec, None, "model")
    return constrain(h @ p["w2"].astype(x.dtype), bspec, None, None)


def _moe_ref(p, cfg: LMConfig, x):
    """Dense reference MoE: computes every expert, exact top-k combine.
    Used on CPU (no mesh) and as the EP oracle in tests."""
    m = cfg.moe
    act = jax.nn.silu if cfg.act == "silu" else partial(jax.nn.gelu, approximate=True)
    n = x.shape[0] * x.shape[1]
    xt = x.reshape(n, cfg.d_model)
    logits = (xt @ p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = top_w / jnp.sum(top_w, axis=-1, keepdims=True)
    gates = jnp.zeros_like(probs).at[jnp.arange(n)[:, None], top_e].set(top_w)
    h = jnp.einsum("nd,edf->nef", xt, p["w1"].astype(x.dtype))
    h = act(h)
    if cfg.gated_ffn:
        h = h * jnp.einsum("nd,edf->nef", xt, p["w3"].astype(x.dtype))
    y = jnp.einsum("nef,efd->ned", h, p["w2"].astype(x.dtype))
    out = jnp.einsum("ned,ne->nd", y, gates.astype(x.dtype))
    aux = _router_aux(probs, top_e, m)
    return out.reshape(x.shape), aux


def _router_aux(probs, top_e, m: MoEConfig):
    """Switch-style load-balance loss: E * sum_e f_e * P_e."""
    e = probs.shape[-1]
    hot = jax.nn.one_hot(top_e[..., 0], e, dtype=probs.dtype)
    f = jnp.mean(hot, axis=0)
    p_bar = jnp.mean(probs, axis=0)
    return e * jnp.sum(f * p_bar)


def _moe_ep_body(xt, router, w1, w3, w2, *, cfg: LMConfig, axis: str,
                 batch_axes: tuple = ()):
    """shard_map body: xt (n_loc, d) data-sharded / model-replicated;
    w* (E_loc, ...) expert-sharded over `axis`.  See DESIGN.md §6."""
    m = cfg.moe
    act = jax.nn.silu if cfg.act == "silu" else partial(jax.nn.gelu, approximate=True)
    s_idx = jax.lax.axis_index(axis)
    e_loc = w1.shape[0]
    n = xt.shape[0]
    logits = (xt @ router.astype(xt.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    top_w, top_e = jax.lax.top_k(probs, m.top_k)
    top_w = (top_w / jnp.sum(top_w, axis=-1, keepdims=True)).astype(xt.dtype)

    flat_e = top_e.reshape(-1)  # (n*k,)
    flat_w = top_w.reshape(-1)
    tok_id = jnp.arange(n * m.top_k, dtype=jnp.int32) // m.top_k
    cap = max(1, int(m.capacity_factor * n * m.top_k / m.n_experts))

    out = jnp.zeros((n, cfg.d_model), xt.dtype)
    for e_local in range(e_loc):
        e_global = s_idx * e_loc + e_local
        sel = flat_e == e_global
        pos = jnp.cumsum(sel) - 1
        slot = jnp.where(sel & (pos < cap), pos, cap).astype(jnp.int32)
        buf = jnp.zeros((cap + 1, cfg.d_model), xt.dtype).at[slot].set(
            xt[tok_id], mode="drop")
        h = act(buf[:cap] @ w1[e_local].astype(xt.dtype))
        if cfg.gated_ffn:
            h = h * (buf[:cap] @ w3[e_local].astype(xt.dtype))
        y = h @ w2[e_local].astype(xt.dtype)  # (cap, d)
        tok_of = jnp.zeros((cap + 1,), jnp.int32).at[slot].set(tok_id, mode="drop")
        w_of = jnp.zeros((cap + 1,), xt.dtype).at[slot].set(
            flat_w * sel.astype(flat_w.dtype), mode="drop")
        out = out.at[tok_of[:cap]].add(y * w_of[:cap, None], mode="drop")

    out = jax.lax.psum(out, axis)
    aux = _router_aux(probs, top_e, m)[None]
    if batch_axes:
        aux = jax.lax.pmean(aux, batch_axes)
    return out, aux


def _moe_ep(p, cfg: LMConfig, x):
    """EP-as-TP MoE (see DESIGN.md §6): experts sharded over 'model',
    activations batch-sharded over (pod, data); combine via psum."""
    mesh = current_mesh()
    bspec = batch_spec(mesh)
    b, t, d = x.shape
    xt = x.reshape(b * t, d)
    w3 = p.get("w3", p["w1"])  # dummy when ungated
    baxes = bspec if isinstance(bspec, tuple) else ((bspec,) if bspec else ())
    body = partial(_moe_ep_body, cfg=cfg, axis="model", batch_axes=baxes)
    from repro.distributed.compat import shard_map

    out, aux = shard_map(
        body, mesh=mesh,
        in_specs=(P(bspec, None), P(None, None), P("model", None, None),
                  P("model", None, None), P("model", None, None)),
        out_specs=(P(bspec, None), P(None)),
        check_vma=False,
    )(xt, p["router"], p["w1"], w3, p["w2"])
    return out.reshape(b, t, d), jnp.mean(aux)


def _ffn_block(p, cfg: LMConfig, x):
    if cfg.moe is None:
        return _dense_ffn(p, cfg, x), jnp.float32(0.0)
    if current_mesh() is None:
        return _moe_ref(p, cfg, x)
    return _moe_ep(p, cfg, x)


# ---------------------------------------------------------------------------
# Block + full forward (scan over layers)
# ---------------------------------------------------------------------------


def _block(p, cfg: LMConfig, x, positions, window, kv=None, kv_pos=None):
    zc = cfg.zero_centered_norm
    h = L.rmsnorm_apply(p["ln_attn"], x, zero_centered=zc)
    attn_out, new_kv = _attn_block(p, cfg, h, positions, window, kv, kv_pos)
    if cfg.sandwich_norm:
        attn_out = L.rmsnorm_apply(p["ln_attn_post"], attn_out, zero_centered=zc)
    x = _constrain_stream(cfg, x + cfg.residual_scale * attn_out)
    h = L.rmsnorm_apply(p["ln_ffn"], x, zero_centered=zc)
    ffn_out, aux = _ffn_block(p, cfg, h)
    if cfg.sandwich_norm:
        ffn_out = L.rmsnorm_apply(p["ln_ffn_post"], ffn_out, zero_centered=zc)
    x = _constrain_stream(cfg, x + cfg.residual_scale * ffn_out)
    return x, new_kv, aux


def _embed(params, cfg: LMConfig, tokens):
    x = jnp.take(params["embed"], tokens, axis=0).astype(cfg.compute_dtype)
    if cfg.embed_scale:
        x = x * jnp.asarray(cfg.embed_scale, x.dtype)
    return _constrain_stream(cfg, x)


def _unembed(params, cfg: LMConfig, x):
    table = params["embed"] if cfg.tie_embeddings else params["lm_head"]
    logits = x @ table.T.astype(x.dtype)
    logits = logits / jnp.asarray(cfg.logit_divisor, x.dtype)
    if cfg.final_softcap:
        logits = cfg.final_softcap * jnp.tanh(logits / cfg.final_softcap)
    return constrain(logits, batch_spec(), None, "model")


def forward(params, cfg: LMConfig, tokens: jnp.ndarray) -> jnp.ndarray:
    """tokens (B, T) -> logits (B, T, padded_vocab)."""
    b, t = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))

    def body(x, xs):
        layer, window = xs
        y, _, aux = _block(layer, cfg, x, positions, window)
        return y, aux

    body_fn = jax.checkpoint(body) if cfg.remat else body
    x, auxes = jax.lax.scan(body_fn, x, (params["layers"], window_array(cfg)),
                            unroll=cfg.scan_unroll)
    x = L.rmsnorm_apply(params["ln_final"], x,
                        zero_centered=cfg.zero_centered_norm)
    return _unembed(params, cfg, x), jnp.sum(auxes)


def loss_fn(params, cfg: LMConfig, batch: dict) -> jnp.ndarray:
    """batch: tokens (B,T) int32, targets (B,T) int32, mask (B,T)."""
    logits, aux = forward(params, cfg, batch["tokens"])
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, batch["targets"][..., None],
                                 axis=-1)[..., 0]
    nll = (lse - picked) * batch["mask"]
    loss = jnp.sum(nll) / jnp.maximum(jnp.sum(batch["mask"]), 1.0)
    if cfg.moe:
        loss = loss + cfg.moe.router_aux_weight * aux / cfg.n_layers
    return loss


# ---------------------------------------------------------------------------
# Serving: prefill + single-token decode with a KV cache
# ---------------------------------------------------------------------------


def init_cache(cfg: LMConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Unified stacked (L, B, S, Hkv, dh) cache; sliding windows are applied
    via the attention mask against absolute positions.  (Baseline layout -
    bounding local-layer caches to their window is a recorded §Perf
    optimization, see EXPERIMENTS.md.)"""
    dtype = dtype or cfg.compute_dtype
    return {
        "k": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.d_head), dtype),
        "v": jnp.zeros((cfg.n_layers, batch, max_len, cfg.n_kv_heads,
                        cfg.d_head), dtype),
        "length": jnp.zeros((), jnp.int32),
    }


def cache_shardings(cfg: LMConfig, *, seq_sharded: bool = False):
    """Cache specs: batch over (pod,data); optionally KV-seq over 'model'
    (long-context decode; see DESIGN.md §5)."""
    bspec = ("pod", "data")
    seq = "model" if seq_sharded else None
    kvh = None if seq_sharded else None  # kv heads < shards for these archs
    return {
        "k": P(None, bspec, seq, kvh, None),
        "v": P(None, bspec, seq, kvh, None),
        "length": P(),
    }


def prefill(params, cfg: LMConfig, tokens: jnp.ndarray, max_len: int):
    """tokens (B, T) -> (last-token logits (B, V), cache)."""
    b, t = tokens.shape
    x = _embed(params, cfg, tokens)
    positions = jnp.broadcast_to(jnp.arange(t, dtype=jnp.int32), (b, t))
    cache = init_cache(cfg, b, max_len)
    s_max = cache["k"].shape[2]

    def body(x, xs):
        layer, window = xs
        y, (k, v), _ = _block(layer, cfg, x, positions, window)
        k_pad = jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.d_head), k.dtype)
        k_pad = jax.lax.dynamic_update_slice(k_pad, k, (0, 0, 0, 0))
        v_pad = jnp.zeros((b, s_max, cfg.n_kv_heads, cfg.d_head), v.dtype)
        v_pad = jax.lax.dynamic_update_slice(v_pad, v, (0, 0, 0, 0))
        return y, (k_pad, v_pad)

    x, (ks, vs) = jax.lax.scan(body, x, (params["layers"], window_array(cfg)),
                               unroll=cfg.scan_unroll)
    x = L.rmsnorm_apply(params["ln_final"], x,
                        zero_centered=cfg.zero_centered_norm)
    logits = _unembed(params, cfg, x[:, -1:, :])[:, 0]
    cache = {"k": ks, "v": vs, "length": jnp.asarray(t, jnp.int32)}
    return logits, cache


def decode_step(params, cfg: LMConfig, token: jnp.ndarray, cache: dict):
    """One serve step: token (B,) int32 + cache -> (logits (B, V), cache).

    The KV of the new token is written at position cache.length; attention
    runs against the full cache with positions masked beyond length.
    """
    b = token.shape[0]
    pos = cache["length"]
    x = _embed(params, cfg, token[:, None])
    positions = jnp.broadcast_to(pos[None, None], (b, 1)).astype(jnp.int32)
    s_max = cache["k"].shape[2]
    kv_pos = jnp.arange(s_max, dtype=jnp.int32)

    def body(x, xs):
        layer, window, k_cache, v_cache = xs
        zc = cfg.zero_centered_norm
        h = L.rmsnorm_apply(layer["ln_attn"], x, zero_centered=zc)
        # project the single new token
        q = _split_heads(h @ layer["wq"].astype(h.dtype), cfg.n_heads, cfg.d_head)
        k = _split_heads(h @ layer["wk"].astype(h.dtype), cfg.n_kv_heads, cfg.d_head)
        v = _split_heads(h @ layer["wv"].astype(h.dtype), cfg.n_kv_heads, cfg.d_head)
        if cfg.qk_norm:
            q = L.rmsnorm_apply(layer["q_norm"], q, zero_centered=zc)
            k = L.rmsnorm_apply(layer["k_norm"], k, zero_centered=zc)
        q = apply_rope(q, positions, cfg)
        k = apply_rope(k, positions, cfg)
        k_cache = jax.lax.dynamic_update_slice(k_cache, k, (0, pos, 0, 0))
        v_cache = jax.lax.dynamic_update_slice(v_cache, v, (0, pos, 0, 0))
        # causal against absolute positions + optional sliding window
        valid = (kv_pos <= pos) & (
            (pos - kv_pos) < jnp.where(window > 0, window,
                                       jnp.iinfo(jnp.int32).max))
        mask = jnp.broadcast_to(valid[None, :], (1, s_max))
        attn = _attention(cfg, q, k_cache, v_cache, mask)
        attn = attn @ layer["wo"].astype(h.dtype)
        if cfg.sandwich_norm:
            attn = L.rmsnorm_apply(layer["ln_attn_post"], attn, zero_centered=zc)
        x = x + cfg.residual_scale * attn
        h = L.rmsnorm_apply(layer["ln_ffn"], x, zero_centered=zc)
        f, _ = _ffn_block(layer, cfg, h)
        if cfg.sandwich_norm:
            f = L.rmsnorm_apply(layer["ln_ffn_post"], f, zero_centered=zc)
        return x + cfg.residual_scale * f, (k_cache, v_cache)

    x, (ks, vs) = jax.lax.scan(
        body, x, (params["layers"], window_array(cfg), cache["k"], cache["v"]),
        unroll=cfg.scan_unroll)
    x = L.rmsnorm_apply(params["ln_final"], x,
                        zero_centered=cfg.zero_centered_norm)
    logits = _unembed(params, cfg, x)[:, 0]
    new_cache = {"k": ks, "v": vs, "length": pos + 1}
    return logits, new_cache


# ---------------------------------------------------------------------------
# FLOPs
# ---------------------------------------------------------------------------


def flops_per_token(cfg: LMConfig, seq_len: int, *, decode: bool = False) -> float:
    """Forward FLOPs per token (attention quadratic term included)."""
    d = cfg.d_model
    proj = 2.0 * d * (cfg.d_q + 2 * cfg.d_kv) + 2.0 * cfg.d_q * d
    kv_len = seq_len
    attn = 4.0 * cfg.n_heads * cfg.d_head * (kv_len if decode else kv_len / 2)
    if cfg.moe:
        n_mats = 3 if cfg.gated_ffn else 2
        ffn = n_mats * 2.0 * d * cfg.moe.d_expert * cfg.moe.top_k
        ffn += 2.0 * d * cfg.moe.n_experts
    else:
        n_mats = 3 if cfg.gated_ffn else 2
        ffn = n_mats * 2.0 * d * cfg.d_ff
    unembed = 2.0 * d * cfg.padded_vocab
    return cfg.n_layers * (proj + attn + ffn) + unembed
