"""Minimal pure-pytree neural-net substrate (no flax/haiku dependency).

Every layer is a pair of functions:
  ``<name>_init(key, ...) -> params``   (params = nested dict of jnp arrays)
  ``<name>_apply(params, x, ...) -> y`` (pure, jit/vmap/pjit friendly)

Parameters are plain dicts so they shard transparently under pjit and
serialize trivially in the checkpoint layer.
"""
from __future__ import annotations

import math
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

Params = dict

# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def lecun_normal(key, shape, dtype=jnp.float32, fan_in=None):
    fan_in = fan_in if fan_in is not None else shape[0]
    std = 1.0 / math.sqrt(max(1, fan_in))
    return std * jax.random.normal(key, shape, dtype)


def glorot_uniform(key, shape, dtype=jnp.float32):
    fan_in, fan_out = shape[0], shape[-1]
    limit = math.sqrt(6.0 / (fan_in + fan_out))
    return jax.random.uniform(key, shape, dtype, -limit, limit)


def normal_init(key, shape, std=0.02, dtype=jnp.float32):
    return std * jax.random.normal(key, shape, dtype)


# ---------------------------------------------------------------------------
# Dense / MLP
# ---------------------------------------------------------------------------


def dense_init(key, d_in: int, d_out: int, *, use_bias: bool = True,
               init: Callable = lecun_normal, dtype=jnp.float32) -> Params:
    p = {"w": init(key, (d_in, d_out), dtype)}
    if use_bias:
        p["b"] = jnp.zeros((d_out,), dtype)
    return p


def dense_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    y = x @ params["w"]
    if "b" in params:
        y = y + params["b"]
    return y


_ACTIVATIONS = {
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "sigmoid": jax.nn.sigmoid,
    "softplus": jax.nn.softplus,
    "dice": None,  # resolved in din.py (needs running stats); placeholder
    "prelu": None,  # handled explicitly with a slope param
    "none": lambda x: x,
    None: lambda x: x,
}


def activation(name):
    fn = _ACTIVATIONS.get(name, None)
    if fn is None and name not in (None, "none"):
        raise ValueError(f"unknown activation {name!r}")
    return fn


def mlp_init(key, dims: Sequence[int], *, use_bias: bool = True,
             dtype=jnp.float32) -> Params:
    """dims = [d_in, h1, h2, ..., d_out]."""
    keys = jax.random.split(key, len(dims) - 1)
    return {
        "layers": [
            dense_init(k, dims[i], dims[i + 1], use_bias=use_bias, dtype=dtype)
            for i, k in enumerate(keys)
        ]
    }


def mlp_apply(params: Params, x: jnp.ndarray, *, act: str = "relu",
              final_act: str = "none") -> jnp.ndarray:
    n = len(params["layers"])
    act_fn, final_fn = activation(act), activation(final_act)
    for i, layer in enumerate(params["layers"]):
        x = dense_apply(layer, x)
        x = final_fn(x) if i == n - 1 else act_fn(x)
    return x


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def layernorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype), "bias": jnp.zeros((d,), dtype)}


def layernorm_apply(params: Params, x: jnp.ndarray, *, eps: float = 1e-6):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    y = (x - mu) * jax.lax.rsqrt(var + eps)
    return y * params["scale"] + params["bias"]


def rmsnorm_init(d: int, dtype=jnp.float32) -> Params:
    return {"scale": jnp.ones((d,), dtype)}


def rmsnorm_apply(params: Params, x: jnp.ndarray, *, eps: float = 1e-6,
                  zero_centered: bool = False):
    dtype = x.dtype
    x32 = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x32), axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    scale = params["scale"].astype(jnp.float32)
    if zero_centered:  # gemma-style (1 + scale)
        scale = 1.0 + scale
    return (y * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Embedding
# ---------------------------------------------------------------------------


def embedding_init(key, vocab: int, dim: int, *, std: float = 0.02,
                   dtype=jnp.float32) -> Params:
    return {"table": std * jax.random.normal(key, (vocab, dim), dtype)}


def embedding_apply(params: Params, ids: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["table"], ids, axis=0)


# ---------------------------------------------------------------------------
# PReLU (used by DIN)
# ---------------------------------------------------------------------------


def prelu_init(d: int, dtype=jnp.float32) -> Params:
    return {"alpha": 0.25 * jnp.ones((d,), dtype)}


def prelu_apply(params: Params, x: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(x >= 0, x, params["alpha"] * x)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))


def global_norm(tree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(p.astype(jnp.float32)))
              for p in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(sum(leaves))


def param_bytes(params) -> int:
    return sum(int(p.size * p.dtype.itemsize)
               for p in jax.tree_util.tree_leaves(params))
