"""Embedding substrate for recsys: EmbeddingBag + sharded tables.

JAX has no native ``nn.EmbeddingBag`` and no CSR sparse — per the brief we
BUILD the lookup path:

  * ``embedding_bag``          - ragged bags via (ids, segment_ids) ->
                                  ``jnp.take`` + ``jax.ops.segment_sum/max``.
  * ``fixed_bag``              - static (B, L) bags with a pad mask (the
                                  TPU-friendly layout used by the models).
  * ``sharded_embedding_apply``- row-sharded table lookup under shard_map:
                                  shard-local take + mask + psum('model').
                                  One all-reduce of (batch, dim) per stacked
                                  table group - THE collective hot path for
                                  DLRM-class models (see EXPERIMENTS.md).

A Pallas kernel version of the fused gather+reduce lives in
``repro/kernels/embedding_bag.py``; these jnp forms are its oracle and the
default path on CPU.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Ragged EmbeddingBag (torch.nn.EmbeddingBag parity)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("num_bags", "mode"))
def embedding_bag(table: jnp.ndarray, ids: jnp.ndarray,
                  segment_ids: jnp.ndarray, num_bags: int,
                  *, mode: str = "sum",
                  per_sample_weights: jnp.ndarray | None = None):
    """table (V, D); ids (N,); segment_ids (N,) in [0, num_bags)."""
    rows = jnp.take(table, ids, axis=0)  # (N, D)
    if per_sample_weights is not None:
        rows = rows * per_sample_weights[:, None]
    if mode == "sum":
        return jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
    if mode == "mean":
        s = jax.ops.segment_sum(rows, segment_ids, num_segments=num_bags)
        cnt = jax.ops.segment_sum(jnp.ones_like(ids, jnp.float32),
                                  segment_ids, num_segments=num_bags)
        return s / jnp.maximum(cnt, 1.0)[:, None]
    if mode == "max":
        return jax.ops.segment_max(rows, segment_ids, num_segments=num_bags)
    raise ValueError(f"unknown mode {mode!r}")


# ---------------------------------------------------------------------------
# Fixed-size bags (static shapes: the TPU layout)
# ---------------------------------------------------------------------------


def fixed_bag(table: jnp.ndarray, ids: jnp.ndarray,
              mask: jnp.ndarray | None = None, *, mode: str = "sum"):
    """table (V, D); ids (..., L) -> (..., D). mask (..., L) 1=valid."""
    rows = jnp.take(table, ids, axis=0)  # (..., L, D)
    if mask is not None:
        rows = rows * mask[..., None]
    if mode == "sum":
        return jnp.sum(rows, axis=-2)
    if mode == "mean":
        denom = (jnp.sum(mask, axis=-1, keepdims=True)
                 if mask is not None else ids.shape[-1])
        return jnp.sum(rows, axis=-2) / jnp.maximum(denom, 1.0)
    if mode == "max":
        if mask is not None:
            rows = jnp.where(mask[..., None] > 0, rows, -jnp.inf)
        return jnp.max(rows, axis=-2)
    raise ValueError(f"unknown mode {mode!r}")


def hash_bucket(ids: jnp.ndarray, vocab: int, *, salt: int = 0x9E3779B9):
    """Quotient-free hashing trick for unbounded id spaces."""
    h = (ids.astype(jnp.uint32) * jnp.uint32(salt)) ^ (ids.astype(jnp.uint32) >> 16)
    return (h % jnp.uint32(vocab)).astype(jnp.int32)


# ---------------------------------------------------------------------------
# Row-sharded lookup (model-parallel embedding tables)
# ---------------------------------------------------------------------------


def shard_local_lookup(table_shard: jnp.ndarray, ids: jnp.ndarray,
                       shard_idx: jnp.ndarray, rows_per_shard: int,
                       axis_name: str, out_dtype=None):
    """Body to run under shard_map: every shard owns rows
    [shard_idx*rows_per_shard, ...); misses contribute zeros; psum merges.

    table_shard (V/S, D); ids (...,) GLOBAL row ids (replicated).
    Returns (..., D) replicated across the axis.
    """
    lo = shard_idx * rows_per_shard
    local = ids - lo
    hit = (local >= 0) & (local < rows_per_shard)
    local = jnp.clip(local, 0, rows_per_shard - 1)
    rows = jnp.take(table_shard, local, axis=0)
    if out_dtype is not None:
        # bf16 on the wire: halves the psum here AND the table-grad
        # all-reduce in backward (cotangents inherit this dtype)
        rows = rows.astype(out_dtype)
    rows = jnp.where(hit[..., None], rows, jnp.zeros((), rows.dtype))
    return jax.lax.psum(rows, axis_name)


def sharded_embedding_apply(table: jnp.ndarray, ids: jnp.ndarray, mesh,
                            *, axis: str = "model",
                            batch_axes: tuple[str, ...] = (),
                            out_dtype=None):
    """Row-shard ``table`` over ``axis`` and look up GLOBAL ``ids``.

    Usable inside jit (shard_map nests under pjit).  ids may themselves be
    sharded over ``batch_axes``; the psum only runs over the table axis so
    each batch shard reduces its own rows.
    """
    from jax.sharding import PartitionSpec as P

    n_shards = mesh.shape[axis]
    vocab = table.shape[0]
    if vocab % n_shards != 0:
        raise ValueError(f"vocab {vocab} must divide by {n_shards} shards "
                         f"(pad the table)")
    rows_per_shard = vocab // n_shards

    batch_spec = P(batch_axes if batch_axes else None)

    def body(tbl, local_ids):
        shard_idx = jax.lax.axis_index(axis)
        return shard_local_lookup(tbl, local_ids, shard_idx, rows_per_shard,
                                  axis, out_dtype)

    from repro.distributed.compat import shard_map

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axis, None), batch_spec),
        out_specs=batch_spec,
        check_vma=False,
    )(table, ids)


def sharded_embedding_apply_2d(table: jnp.ndarray, ids: jnp.ndarray, mesh,
                               *, axes: tuple = ("model", "data"),
                               out_dtype=None):
    """TorchRec-style row-wise sharding over TWO mesh axes: every row is
    owned by exactly ONE device, so the table GRADIENT never crosses the
    wire (scatter-add stays shard-local).  The forward routes activations
    instead: ids replicate (ints, cheap) and the bag values psum over both
    axes.  For DLRM train this trades a ~1.3 GB fp32 grad all-reduce for a
    ~0.2-0.4 GB activation psum - see EXPERIMENTS.md §Perf iteration 3.

    table (V, D) with spec P(axes, None); ids (N,) GLOBAL row ids
    (replicated in-body).  Returns (N, D) replicated.
    """
    from jax.sharding import PartitionSpec as P

    axes = tuple(a for a in axes if a in mesh.axis_names)
    n_shards = 1
    for a in axes:
        n_shards *= mesh.shape[a]
    vocab = table.shape[0]
    if vocab % n_shards != 0:
        raise ValueError(f"vocab {vocab} must divide by {n_shards} shards")
    rows_per_shard = vocab // n_shards

    batch_axes = axes[1:]  # ids are batch-ordered: scatter back over these

    def body(tbl, all_ids):
        idx = jnp.int32(0)
        for a in axes:
            idx = idx * mesh.shape[a] + jax.lax.axis_index(a)
        lo = idx * rows_per_shard
        local = all_ids - lo
        hit = (local >= 0) & (local < rows_per_shard)
        local = jnp.clip(local, 0, rows_per_shard - 1)
        rows = jnp.take(tbl, local, axis=0)
        if out_dtype is not None:
            rows = rows.astype(out_dtype)
        rows = jnp.where(hit[..., None], rows, jnp.zeros((), rows.dtype))
        # reduction order matters for the wire (EXPERIMENTS.md §Perf iter 3):
        # psum_scatter over the batch axes FIRST (slices the result back to
        # each data shard's own bags - 1/|data| the bytes), THEN the small
        # psum over 'model'.
        for a in batch_axes:
            rows = jax.lax.psum_scatter(rows, a, scatter_dimension=0,
                                        tiled=True)
        return jax.lax.psum(rows, axes[0])

    from repro.distributed.compat import shard_map

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), P(None)),
        out_specs=P(batch_axes if batch_axes else None, None),
        check_vma=False,
    )(table, ids)


def pad_vocab(vocab: int, n_shards: int) -> int:
    """Round a table's row count up so it row-shards evenly."""
    return ((vocab + n_shards - 1) // n_shards) * n_shards
