"""Budget controller: keeps realized consumption under the global budget
even through traffic spikes (paper Fig. 5).

Two mechanisms compose:

  * the nearline dual price reacts within one window (more requests at the
    same price -> overshoot -> price rises next window);
  * a hard *downgrade guard* inside the window: if the running spend would
    exceed the window budget, remaining requests are forced onto the
    cheapest chain ("computation downgrade" in the paper's words).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.core.primal_dual import (DualDescentConfig, DynamicPrimalDual,
                                    window_step)


@dataclass
class WindowStats:
    n_requests: int
    spend: float
    budget: float
    lam: float
    downgraded: int


@dataclass
class BudgetController:
    chains: ActionChainSet
    budget_per_window: float
    dual_cfg: DualDescentConfig = field(default_factory=DualDescentConfig)
    guard: bool = True
    # optional repro.carbon.ledger.CarbonLedger (duck-typed: anything with
    # .record(decisions, t=...)): every served window is metered into
    # kWh/gCO2e at that window's grid intensity
    ledger: object = None

    def __post_init__(self):
        self.pd = DynamicPrimalDual(self.chains.costs, self.budget_per_window,
                                    self.dual_cfg)
        self.stats: list[WindowStats] = []

    @classmethod
    def from_spec(cls, chains: ActionChainSet, spec, **kw
                  ) -> "BudgetController":
        """Build the host-loop controller from a ConstraintSpec.

        The host loop serves exactly the paper's single-budget system,
        so only a plain FLOPs ``[GlobalAxis(budget=...)]`` spec maps
        here; tenant/region axes need the fused
        ``ServingPipeline.from_spec`` and carbon pricing the
        ``carbon.controller.CarbonBudgetController.from_spec`` twin.
        """
        cs = spec.compile()
        if cs.mode != "plain":
            raise ValueError(
                f"the host-loop BudgetController serves the plain "
                f"single-budget spec only (got mode {cs.mode!r}); "
                f"use ServingPipeline.from_spec for tenant/region axes")
        if cs.pricing != "flops":
            raise ValueError(
                "carbon pricing on the host loop lives in "
                "carbon.controller.CarbonBudgetController.from_spec")
        return cls(chains, cs.total_budget, **kw)

    def step_window(self, rewards: np.ndarray) -> np.ndarray:
        """Serve one traffic window: decide with lambda_{t-1}, meter spend,
        apply the downgrade guard, then update the price for t+1.

        The whole decide -> tail-reserve guard -> Algorithm 1 body is
        ``core.primal_dual.window_step`` (shared with the carbon-priced
        controller); this wrapper only meters the ledger and keeps the
        DynamicPrimalDual tracker's price/history in sync.

        rewards: (I_t, J) estimated rewards for this window's requests.
        Returns the (possibly downgraded) chain index per request.
        """
        decisions, downgraded, spend, lam_new = window_step(
            rewards, self.chains.costs, self.budget_per_window, self.pd.lam,
            cheap=self.chains.cheapest(), guard=self.guard,
            cfg=self.dual_cfg)
        if self.ledger is not None:
            self.ledger.record(decisions, t=len(self.stats))
        self.pd.lam = lam_new
        self.pd.history.append(float(lam_new))
        self.stats.append(WindowStats(
            n_requests=len(decisions), spend=spend,
            budget=self.budget_per_window, lam=float(lam_new),
            downgraded=downgraded))
        return decisions

    def spend_trace(self) -> np.ndarray:
        return np.array([s.spend for s in self.stats])
