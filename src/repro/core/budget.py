"""Budget controller: keeps realized consumption under the global budget
even through traffic spikes (paper Fig. 5).

Two mechanisms compose:

  * the nearline dual price reacts within one window (more requests at the
    same price -> overshoot -> price rises next window);
  * a hard *downgrade guard* inside the window: if the running spend would
    exceed the window budget, remaining requests are forced onto the
    cheapest chain ("computation downgrade" in the paper's words).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.core.primal_dual import DynamicPrimalDual, DualDescentConfig


@dataclass
class WindowStats:
    n_requests: int
    spend: float
    budget: float
    lam: float
    downgraded: int


@dataclass
class BudgetController:
    chains: ActionChainSet
    budget_per_window: float
    dual_cfg: DualDescentConfig = field(default_factory=DualDescentConfig)
    guard: bool = True

    def __post_init__(self):
        self.pd = DynamicPrimalDual(self.chains.costs, self.budget_per_window,
                                    self.dual_cfg)
        self.stats: list[WindowStats] = []

    def step_window(self, rewards: np.ndarray) -> np.ndarray:
        """Serve one traffic window: decide with lambda_{t-1}, meter spend,
        apply the downgrade guard, then update the price for t+1.

        rewards: (I_t, J) estimated rewards for this window's requests.
        Returns the (possibly downgraded) chain index per request.
        """
        decisions = np.asarray(self.pd.decide(rewards))
        costs = self.chains.costs
        spend = np.cumsum(costs[decisions])
        downgraded = 0
        if self.guard and spend[-1] > self.budget_per_window:
            cheap = self.chains.cheapest()
            c_min = costs[cheap]
            n = len(decisions)
            # greedy with tail reserve: request i keeps its chain only if
            # the spend so far + its cost + a cheapest-chain reservation
            # for everyone behind it still fits; else it is downgraded.
            # Guarantees spend <= budget whenever n * c_min <= budget.
            kept_prefix = np.concatenate(
                [[0.0], np.cumsum(costs[decisions])[:-1]])
            # iterate: downgrading shifts prefixes; 2 passes converge for
            # the monotone tail-reserve rule (first crossing only moves up)
            for _ in range(4):
                reserve = c_min * (n - 1 - np.arange(n))
                over = kept_prefix + costs[decisions] + reserve \
                    > self.budget_per_window
                if not over.any():
                    break
                decisions = np.where(over, cheap, decisions)
                kept_prefix = np.concatenate(
                    [[0.0], np.cumsum(costs[decisions])[:-1]])
                downgraded = int(over.sum())
            spend = np.cumsum(costs[decisions])

        lam = self.pd.update(rewards)
        self.stats.append(WindowStats(
            n_requests=len(decisions), spend=float(spend[-1]),
            budget=self.budget_per_window, lam=lam, downgraded=downgraded))
        return decisions

    def spend_trace(self) -> np.ndarray:
        return np.array([s.spend for s in self.stats])
