"""Budget controller: keeps realized consumption under the global budget
even through traffic spikes (paper Fig. 5).

Two mechanisms compose:

  * the nearline dual price reacts within one window (more requests at the
    same price -> overshoot -> price rises next window);
  * a hard *downgrade guard* inside the window: if the running spend would
    exceed the window budget, remaining requests are forced onto the
    cheapest chain ("computation downgrade" in the paper's words).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.core.primal_dual import DynamicPrimalDual, DualDescentConfig
from repro.serving.guard import downgrade_guard_np


@dataclass
class WindowStats:
    n_requests: int
    spend: float
    budget: float
    lam: float
    downgraded: int


@dataclass
class BudgetController:
    chains: ActionChainSet
    budget_per_window: float
    dual_cfg: DualDescentConfig = field(default_factory=DualDescentConfig)
    guard: bool = True
    # optional repro.carbon.ledger.CarbonLedger (duck-typed: anything with
    # .record(decisions, t=...)): every served window is metered into
    # kWh/gCO2e at that window's grid intensity
    ledger: object = None

    def __post_init__(self):
        self.pd = DynamicPrimalDual(self.chains.costs, self.budget_per_window,
                                    self.dual_cfg)
        self.stats: list[WindowStats] = []

    def step_window(self, rewards: np.ndarray) -> np.ndarray:
        """Serve one traffic window: decide with lambda_{t-1}, meter spend,
        apply the downgrade guard, then update the price for t+1.

        rewards: (I_t, J) estimated rewards for this window's requests.
        Returns the (possibly downgraded) chain index per request.
        """
        decisions = np.asarray(self.pd.decide(rewards))
        costs = self.chains.costs
        downgraded = 0
        spend = float(np.sum(costs[decisions]))
        if self.guard:
            # greedy with tail reserve (repro.serving.guard): request i
            # keeps its chain only if the spend so far + its cost + a
            # cheapest-chain reservation for everyone behind it still
            # fits.  Guarantees spend <= budget whenever n*c_min <= budget.
            decisions, downgraded, spend = downgrade_guard_np(
                decisions, costs, self.budget_per_window,
                self.chains.cheapest())
        if self.ledger is not None:
            self.ledger.record(decisions, t=len(self.stats))

        lam = self.pd.update(rewards)
        self.stats.append(WindowStats(
            n_requests=len(decisions), spend=spend,
            budget=self.budget_per_window, lam=lam, downgraded=downgraded))
        return decisions

    def spend_trace(self) -> np.ndarray:
        return np.array([s.spend for s in self.stats])
