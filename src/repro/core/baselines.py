"""Comparison methods (paper §5.1): EQUAL and CRAS.

* EQUAL - every request gets the same fixed action chain; the chain is the
  most expensive one that fits the per-request budget share C/I.  Variants
  EQUAL-DIN / EQUAL-DIEN restrict the ranking-stage model pool.

* CRAS (Yang et al. 2021) - decomposes allocation into INDEPENDENT
  per-stage subproblems: stage k has its own reward model r_k(f_i, a_k)
  (no cross-stage state) and its own budget share C_k, solved with the same
  primal-dual machinery.  The combined decision is the per-stage argmaxes
  stitched into a chain.  This reproduces the paper's observation that
  ignoring cross-stage effects costs revenue (Table 2).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax.numpy as jnp
import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.core.primal_dual import dual_bisect, allocate


# ---------------------------------------------------------------------------
# EQUAL
# ---------------------------------------------------------------------------


def equal_allocation(chains: ActionChainSet, budget: float, n_requests: int,
                     *, rank_model: str | None = None) -> int:
    """Fixed chain index for everyone: costliest chain with I*c_j <= C."""
    per_request = budget / max(1, n_requests)
    mask = np.ones(chains.n_chains, bool)
    if rank_model is not None:
        k_rank = chains.n_stages - 1
        model_names = [m.name for m in chains.stages[k_rank].models]
        want = model_names.index(rank_model)
        mask = chains.chain_idx[:, k_rank, 0] == want
    costs = np.where(mask, chains.costs, np.inf)
    affordable = costs <= per_request
    if not affordable.any():
        # nothing fits: fall back to the cheapest allowed chain (downgrade)
        return int(np.argmin(costs))
    return int(np.argmax(np.where(affordable, chains.costs, -np.inf)))


# ---------------------------------------------------------------------------
# CRAS
# ---------------------------------------------------------------------------


@dataclass
class StageActionSpace:
    """Flattened (model, scale) actions of one stage with per-action cost."""

    stage_k: int
    actions: np.ndarray  # (A_k, 2) int32 (model_idx, scale_idx)
    costs: np.ndarray  # (A_k,) float

    @classmethod
    def from_chains(cls, chains: ActionChainSet, k: int) -> "StageActionSpace":
        st = chains.stages[k]
        acts, costs = [], []
        for mi, m in enumerate(st.models):
            for si, n in enumerate(st.item_scales):
                acts.append((mi, si))
                costs.append(m.fixed_flops + m.flops_per_item * n)
        return cls(k, np.asarray(acts, np.int32), np.asarray(costs))


def cras_allocation(stage_rewards: list[jnp.ndarray],
                    stage_spaces: list[StageActionSpace],
                    chains: ActionChainSet, budget: float,
                    *, rank_model: str | None = None) -> np.ndarray:
    """Per-stage independent primal-dual (Yang et al. 2021 style).

    stage_rewards[k]: (I, A_k) independently-estimated stage revenues.
    Budget is split across stages proportionally to each stage's maximum
    spend, then each stage solves its own scalar dual price.  Returns (I,)
    chain indices into ``chains``.
    """
    n_req = stage_rewards[0].shape[0]
    max_spend = np.array([sp.costs.max() for sp in stage_spaces])
    shares = max_spend / max_spend.sum()

    per_stage_choice = []
    for k, (rw, sp) in enumerate(zip(stage_rewards, stage_spaces)):
        costs = sp.costs.copy()
        if rank_model is not None and k == chains.n_stages - 1:
            names = [m.name for m in chains.stages[k].models]
            want = names.index(rank_model)
            banned = sp.actions[:, 0] != want
            costs = np.where(banned, 1e30, costs)  # price them out
        c = jnp.asarray(costs, jnp.float32)
        lam = dual_bisect(jnp.asarray(rw), c, budget * shares[k])
        per_stage_choice.append(np.asarray(allocate(jnp.asarray(rw), c, lam)))

    # stitch per-stage actions into chain indices
    lookup = {}
    for j in range(chains.n_chains):
        key = tuple(map(tuple, chains.chain_idx[j]))
        lookup[key] = j

    out = np.zeros((n_req,), np.int32)
    for i in range(n_req):
        choice = []
        for k, sp in enumerate(stage_spaces):
            a = sp.actions[per_stage_choice[k][i]]
            choice.append((int(a[0]), int(a[1])))
        key = tuple(choice)
        if key not in lookup:
            # per-stage independence can pick n_{k+1} > n_k which the cascade
            # prunes; clamp the downstream scale to the feasible maximum.
            choice = _clamp_feasible(chains, choice)
            key = tuple(choice)
        out[i] = lookup[key]
    return out


def _clamp_feasible(chains: ActionChainSet, choice):
    fixed = [list(choice[0])]
    for k in range(1, len(choice)):
        mi, si = choice[k]
        up_scale = chains.stages[k - 1].item_scales[fixed[k - 1][1]]
        scales = chains.stages[k].item_scales
        while si > 0 and scales[si] > up_scale:
            si -= 1
        fixed.append([mi, si])
    return [tuple(c) for c in fixed]
