"""GreenFlow core: the paper's contribution as a composable JAX library."""
from repro.core.action_chain import (ActionChainSet, ModelInstance, StageSpec,
                                     generate_action_chains,
                                     paper_stage_specs)
from repro.core.allocator import GreenFlowAllocator
from repro.core.baselines import (StageActionSpace, cras_allocation,
                                  equal_allocation)
from repro.core.budget import BudgetController
from repro.core.pfec import (EnergyConfig, PFECReport, carbon_from_energy,
                             energy_from_flops, pfec_report, revenue_at_e)
from repro.core.primal_dual import (DualDescentConfig, DynamicPrimalDual,
                                    allocate, consumption, dual_bisect,
                                    dual_descent)
from repro.core.reward_model import (BASIS_FUNCTIONS, RewardModelConfig,
                                     field_rce, reward_apply, reward_loss,
                                     reward_matrix, reward_model_init)

__all__ = [
    "ActionChainSet", "ModelInstance", "StageSpec", "generate_action_chains",
    "paper_stage_specs", "GreenFlowAllocator", "StageActionSpace",
    "cras_allocation", "equal_allocation", "BudgetController", "EnergyConfig",
    "PFECReport", "carbon_from_energy", "energy_from_flops", "pfec_report",
    "revenue_at_e", "DualDescentConfig", "DynamicPrimalDual", "allocate",
    "consumption", "dual_bisect", "dual_descent", "BASIS_FUNCTIONS",
    "RewardModelConfig", "field_rce", "reward_apply", "reward_loss",
    "reward_matrix", "reward_model_init",
]
