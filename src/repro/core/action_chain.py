"""Action chains — the allocation unit of GreenFlow (paper §3.1, §4.1).

A cascade RS has K stages. Stage k picks a model instance ``m_k`` from its
*Model Pool* and an item scale ``n_k`` from its *Item Scale* set.  An action
chain ``a = ((m_1, n_1), ..., (m_K, n_K))`` fixes the computation of one
request end to end.  The generator enumerates the Cartesian product over
stages and pre-computes, for every chain j:

  * integer encodings   (J, K, 2)  -> (model_idx, scale_idx) per stage
  * FLOPs cost vector   (J,)       -> c_j = sum_k n_k * flops_per_item(m_k)
  * reward-model features: per-stage model one-hot + multi-hot scale code

Everything is static/arrays so the whole chain set rides through jit.
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass, field
from typing import Sequence

import numpy as np


@dataclass(frozen=True)
class ModelInstance:
    """A trained instance available in a stage's model pool (paper Table 1)."""

    name: str
    flops_per_item: float  # FLOPs to score ONE candidate item
    fixed_flops: float = 0.0  # per-request overhead independent of n_k
    auc: float | None = None  # bookkeeping only


@dataclass(frozen=True)
class StageSpec:
    """One cascade stage: its model pool and item-scale set."""

    name: str
    models: tuple[ModelInstance, ...]
    item_scales: tuple[int, ...]  # paper's N_k, ascending
    n_scale_groups: int = 4  # Q: multi-hot groups for the scale embedding

    def __post_init__(self):
        if tuple(sorted(self.item_scales)) != tuple(self.item_scales):
            raise ValueError(f"item_scales for stage {self.name} must ascend")
        if not self.models:
            raise ValueError(f"stage {self.name} has an empty model pool")

    @property
    def n_models(self) -> int:
        return len(self.models)

    @property
    def n_scales(self) -> int:
        return len(self.item_scales)

    def scale_group(self, scale_idx: int) -> int:
        """Which of the Q groups a scale index falls in (paper §4.2)."""
        q = self.n_scale_groups
        # ceil-partition the ascending scale list into Q contiguous groups
        return min(q - 1, scale_idx * q // max(1, self.n_scales))

    def multi_hot(self, scale_idx: int) -> np.ndarray:
        """Monotone multi-hot code: larger scale -> more ones (paper §4.2)."""
        g = self.scale_group(scale_idx)
        v = np.zeros((self.n_scale_groups,), np.float32)
        v[: g + 1] = 1.0
        return v


@dataclass
class ActionChainSet:
    """The enumerated chain set A with |A| = J and all derived arrays."""

    stages: tuple[StageSpec, ...]
    chain_idx: np.ndarray  # (J, K, 2) int32: (model_idx, scale_idx)
    costs: np.ndarray  # (J,) float64 FLOPs per request
    model_onehot: np.ndarray  # (J, K, max_models) float32
    scale_multihot: np.ndarray  # (J, K, Q) float32
    scale_value: np.ndarray  # (J, K) float32 raw n_k (for logging/cost)
    names: list[str] = field(default_factory=list)

    @property
    def n_chains(self) -> int:
        return int(self.chain_idx.shape[0])

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def chain_name(self, j: int) -> str:
        return self.names[j]

    def cheapest(self) -> int:
        return int(np.argmin(self.costs))

    def most_expensive(self) -> int:
        return int(np.argmax(self.costs))

    def describe(self, j: int) -> str:
        parts = []
        for k, st in enumerate(self.stages):
            mi, si = self.chain_idx[j, k]
            parts.append(f"{st.name}:{st.models[mi].name}@{st.item_scales[si]}")
        return " -> ".join(parts)


def chain_cost(stages: Sequence[StageSpec], choice) -> float:
    """FLOPs of one chain. choice = [(model_idx, scale_idx), ...]."""
    total = 0.0
    for st, (mi, si) in zip(stages, choice):
        m = st.models[mi]
        total += m.fixed_flops + m.flops_per_item * st.item_scales[si]
    return total


def generate_action_chains(stages: Sequence[StageSpec]) -> ActionChainSet:
    """Cartesian-product generator (paper step 1, Figure 2).

    Downstream stages never score more items than the upstream stage kept,
    so combinations with n_{k+1} > n_k are pruned (the cascade hands at most
    n_k items to stage k+1).
    """
    stages = tuple(stages)
    per_stage = [
        list(itertools.product(range(st.n_models), range(st.n_scales)))
        for st in stages
    ]
    max_models = max(st.n_models for st in stages)
    q = stages[0].n_scale_groups
    if any(st.n_scale_groups != q for st in stages):
        raise ValueError("all stages must share Q (n_scale_groups)")

    idx_rows, names = [], []
    for combo in itertools.product(*per_stage):
        scales = [stages[k].item_scales[si] for k, (_, si) in enumerate(combo)]
        if any(scales[k + 1] > scales[k] for k in range(len(scales) - 1)):
            continue  # cascade monotonicity: can't rank more than received
        idx_rows.append([list(c) for c in combo])
        names.append("/".join(
            f"{stages[k].models[mi].name}@{stages[k].item_scales[si]}"
            for k, (mi, si) in enumerate(combo)))

    chain_idx = np.asarray(idx_rows, np.int32)  # (J, K, 2)
    j_total, k_total = chain_idx.shape[0], chain_idx.shape[1]

    costs = np.zeros((j_total,), np.float64)
    model_onehot = np.zeros((j_total, k_total, max_models), np.float32)
    scale_multihot = np.zeros((j_total, k_total, q), np.float32)
    scale_value = np.zeros((j_total, k_total), np.float32)
    for j in range(j_total):
        costs[j] = chain_cost(stages, chain_idx[j])
        for k, st in enumerate(stages):
            mi, si = chain_idx[j, k]
            model_onehot[j, k, mi] = 1.0
            scale_multihot[j, k] = st.multi_hot(int(si))
            scale_value[j, k] = st.item_scales[si]

    return ActionChainSet(
        stages=stages,
        chain_idx=chain_idx,
        costs=costs,
        model_onehot=model_onehot,
        scale_multihot=scale_multihot,
        scale_value=scale_value,
        names=names,
    )


# ---------------------------------------------------------------------------
# The paper's experimental chain space (§5.1 "Implementation of Action Chain")
# ---------------------------------------------------------------------------


def paper_stage_specs(
    *,
    dssm_flops: float = 13e3,
    ydnn_flops: float = 123e3,
    din_flops: float = 7020e3,
    dien_flops: float = 7098e3,
    n2: Sequence[int] = (800, 900, 1000, 1100, 1200, 1300, 1400, 1500),
    n3: Sequence[int] = (60, 80, 100, 120, 140, 160, 180, 200),
    q: int = 4,
) -> tuple[StageSpec, ...]:
    """DSSM (fixed) -> YDNN@n2 -> {DIN|DIEN}@n3, FLOPs from paper Table 1.

    The recall stage {DSSM, n_1} has fixed computation and is omitted from
    the decision space exactly as in the paper; we keep it as a stage with a
    single (model, scale) choice so the cascade engine still runs it.
    """
    recall = StageSpec(
        name="recall",
        models=(ModelInstance("DSSM", dssm_flops, auc=0.525),),
        item_scales=(4000,),
        n_scale_groups=q,
    )
    prerank = StageSpec(
        name="prerank",
        models=(ModelInstance("YDNN", ydnn_flops, auc=0.581),),
        item_scales=tuple(n2),
        n_scale_groups=q,
    )
    rank = StageSpec(
        name="rank",
        models=(
            ModelInstance("DIN", din_flops, auc=0.639),
            ModelInstance("DIEN", dien_flops, auc=0.641),
        ),
        item_scales=tuple(n3),
        n_scale_groups=q,
    )
    return (recall, prerank, rank)
