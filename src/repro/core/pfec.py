"""PFEC evaluation methodology (paper §3.2): Performance / FLOPs / Energy /
Carbon.  Energy follows Lacoste et al. 2019 (Eq. 1-2):

    EC = PUE * (p_ram*e_ram + p_cpu*e_cpu + p_gpu*e_gpu)      [kWh]
    CE = EC * CI                                              [gCO2e]

Offline we cannot meter wall power, so device usage e_(.) is derived from
the FLOPs the allocator actually spends, through a joules-per-FLOP
efficiency constant per device class (calibrated or spec-sheet).  This is
the deviation recorded in DESIGN.md §8.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np


@dataclass(frozen=True)
class EnergyConfig:
    """Paper constants: PUE 1.67 (worldwide avg), CI 615 gCO2e/kWh."""

    pue: float = 1.67
    carbon_intensity_g_per_kwh: float = 615.0
    # device rated powers (W) - paper Eq. 1 terms
    p_ram_w: float = 20.0
    p_cpu_w: float = 105.0
    p_gpu_w: float = 250.0
    # sustained efficiency used to convert FLOPs -> device-hours.
    # (TPU v5e ~197 TF/s bf16 peak; serving fleets in the paper are CPU/GPU -
    # we expose the knob and default to a GPU-class 2e13 FLOP/s sustained.)
    sustained_flops_per_s: float = 2.0e13
    ram_cpu_fraction: float = 0.15  # fraction of device-hours billed to ram+cpu

    def __post_init__(self):
        if self.pue < 1.0:
            raise ValueError(
                f"pue must be >= 1.0 (total/IT power ratio), got {self.pue}")
        for name in ("carbon_intensity_g_per_kwh", "p_ram_w", "p_cpu_w",
                     "p_gpu_w", "sustained_flops_per_s"):
            v = getattr(self, name)
            if not v > 0:
                raise ValueError(f"{name} must be positive, got {v}")
        if self.ram_cpu_fraction < 0:
            raise ValueError(f"ram_cpu_fraction must be >= 0, "
                             f"got {self.ram_cpu_fraction}")


@dataclass
class PFECReport:
    performance: float  # revenue@e (clicks)
    flops: float  # total FLOPs consumed
    energy_kwh: float
    carbon_g: float
    meta: dict = field(default_factory=dict)

    def as_row(self) -> dict:
        return {
            "performance": self.performance,
            "flops": self.flops,
            "energy_kwh": self.energy_kwh,
            "carbon_g": self.carbon_g,
            **self.meta,
        }


def _resolve(cfg: EnergyConfig | None) -> EnergyConfig:
    """One place builds the default config (a ``cfg=EnergyConfig()`` default
    arg would be evaluated once at import and silently pin its constants)."""
    return EnergyConfig() if cfg is None else cfg


def energy_from_flops(flops: float, cfg: EnergyConfig | None = None) -> float:
    """FLOPs -> kWh via Eq. 1 with usage-hours derived from throughput."""
    cfg = _resolve(cfg)
    hours = flops / cfg.sustained_flops_per_s / 3600.0
    e_gpu = hours
    e_cpu = hours * cfg.ram_cpu_fraction
    e_ram = hours * cfg.ram_cpu_fraction
    watts = (cfg.p_ram_w * e_ram + cfg.p_cpu_w * e_cpu + cfg.p_gpu_w * e_gpu)
    return cfg.pue * watts / 1000.0  # W*h -> kWh


def kwh_per_flop(cfg: EnergyConfig | None = None) -> float:
    """kappa: the (linear) Eq. 1 slope, kWh consumed per FLOP served."""
    return energy_from_flops(1.0, cfg)


def carbon_from_energy(kwh: float, cfg: EnergyConfig | None = None) -> float:
    """Eq. 2: CE = EC * CI  [gCO2e]."""
    return kwh * _resolve(cfg).carbon_intensity_g_per_kwh


def pfec_report(*, clicks: float, flops: float,
                cfg: EnergyConfig | None = None, **meta) -> PFECReport:
    cfg = _resolve(cfg)
    kwh = energy_from_flops(flops, cfg)
    return PFECReport(
        performance=float(clicks),
        flops=float(flops),
        energy_kwh=float(kwh),
        carbon_g=float(carbon_from_energy(kwh, cfg)),
        meta=meta,
    )


def revenue_at_e(click_labels: np.ndarray, ranked_items: np.ndarray,
                 e: int = 20) -> float:
    """Paper Eq. 11 for one request: clicks among the top-e exposed items.

    click_labels: (n_items,) 0/1 ground-truth clicks for the request's
    candidate set; ranked_items: indices ordered by the final stage.
    ``e`` past the ranking length exposes everything ranked; an empty
    ranking exposes nothing (0 clicks).  Labels of any numeric dtype or
    layout (views/slices) are accepted.
    """
    top = np.asarray(ranked_items, dtype=np.intp).reshape(-1)[:e]
    if top.size == 0:
        return 0.0
    return float(np.asarray(click_labels, dtype=np.float64)[top].sum())
