"""Personalized reward model (paper §4.2).

Recursive multi-stage design:  R_ij = sum_k dr_k with
    (dr_k, h_k) = g_k(h_{k-1}, f_i, m_k, n_k)

Each stage cell g_k enforces the paper's three mechanisms:

  * Recursive multi-stage: h_k threads stage context downstream (Fig. 3).
  * Multi-basis functions (Eq. 5-7): dr_k = sum_p w_p * phi_p(v_p) with
    B = {tanh, ln, x/sqrt(1+x^2), sigmoid, x},  w = softmax(FNN_0(z)),
    v_p = 1_Q^T (softplus(FNN_p(z)) * n_multihot).
  * Monotonic constraint: the multi-hot scale code has more ones for larger
    n_k, softplus keeps the per-group contributions positive, every basis is
    increasing and w >= 0  =>  dr_k is non-decreasing in n_k.

Ablation switches (`recursive`, `multi_basis`) reproduce paper Table 4.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import layers as L

# ---------------------------------------------------------------------------
# Basis functions (paper Eq. 7).  ln -> ln(1+x) for x>=0 numerical safety;
# still increasing, concave, phi(0)=0 (see DESIGN.md §8).
# ---------------------------------------------------------------------------

BASIS_FUNCTIONS = (
    ("tanh", jnp.tanh),
    ("ln", jnp.log1p),
    ("rsqrt1p", lambda x: x * jax.lax.rsqrt(1.0 + x * x)),
    ("sigmoid", jax.nn.sigmoid),
    ("identity", lambda x: x),
)
N_BASIS = len(BASIS_FUNCTIONS)


def apply_bases(v: jnp.ndarray) -> jnp.ndarray:
    """v: (..., P) -> phi_p(v_p) stacked on the last axis, P == N_BASIS."""
    outs = [fn(v[..., p]) for p, (_, fn) in enumerate(BASIS_FUNCTIONS)]
    return jnp.stack(outs, axis=-1)


@dataclass(frozen=True)
class RewardModelConfig:
    n_stages: int  # K: decision stages
    max_models: int  # width of the per-stage model one-hot
    n_scale_groups: int  # Q
    d_context: int  # raw context feature dim fed to the encoder
    d_feature: int = 64  # encoded f_i dim
    d_hidden: int = 64  # trunk width inside each cell
    d_state: int = 32  # h_k carried between stages
    d_model_emb: int = 8  # model-instance embedding dim
    recursive: bool = True  # ablation: thread h_k between stages
    multi_basis: bool = True  # ablation: use Eq. 5-7 vs plain MLP head
    encoder_hidden: tuple = (128,)


# ---------------------------------------------------------------------------
# Init
# ---------------------------------------------------------------------------


def _cell_init(key, cfg: RewardModelConfig) -> dict:
    d_in = cfg.d_state + cfg.d_feature + cfg.d_model_emb
    k = jax.random.split(key, 5)
    p = {
        "trunk": L.mlp_init(k[0], [d_in, cfg.d_hidden, cfg.d_hidden]),
        "state": L.dense_init(k[1], cfg.d_hidden, cfg.d_state),
        "model_emb": L.normal_init(k[2], (cfg.max_models, cfg.d_model_emb)),
    }
    if cfg.multi_basis:
        # FNN_0 -> basis mixture logits; FNN_p (p=1..P) -> Q-dim group scores
        p["w_head"] = L.dense_init(k[3], cfg.d_hidden, N_BASIS)
        p["v_heads"] = L.dense_init(k[4], cfg.d_hidden,
                                    N_BASIS * cfg.n_scale_groups)
    else:
        # plain MLP head on (trunk, multi-hot code) - no monotone guarantee
        p["flat_head"] = L.mlp_init(
            k[3], [cfg.d_hidden + cfg.n_scale_groups, cfg.d_hidden, 1])
    return p


def reward_model_init(key, cfg: RewardModelConfig) -> dict:
    keys = jax.random.split(key, cfg.n_stages + 1)
    enc_dims = [cfg.d_context, *cfg.encoder_hidden, cfg.d_feature]
    return {
        "encoder": L.mlp_init(keys[0], enc_dims),
        "cells": [_cell_init(keys[1 + k], cfg) for k in range(cfg.n_stages)],
    }


# ---------------------------------------------------------------------------
# Apply
# ---------------------------------------------------------------------------


def encode_context(params: dict, raw_context: jnp.ndarray) -> jnp.ndarray:
    """raw_context: (..., d_context) -> f_i: (..., d_feature)."""
    return L.mlp_apply(params["encoder"], raw_context, act="relu")


def _cell_apply(cell: dict, cfg: RewardModelConfig, h: jnp.ndarray,
                f: jnp.ndarray, model_onehot: jnp.ndarray,
                scale_multihot: jnp.ndarray):
    """One g_k. Shapes: h (..., d_state), f (..., d_feature),
    model_onehot (..., max_models), scale_multihot (..., Q)."""
    m_emb = model_onehot @ cell["model_emb"]
    z = jnp.concatenate([h, f, m_emb], axis=-1)
    t = L.mlp_apply(cell["trunk"], z, act="relu", final_act="relu")
    h_new = jnp.tanh(L.dense_apply(cell["state"], t))

    if cfg.multi_basis:
        w = jax.nn.softmax(L.dense_apply(cell["w_head"], t), axis=-1)  # (...,P)
        u = jax.nn.softplus(L.dense_apply(cell["v_heads"], t))  # (...,P*Q)
        u = u.reshape(*u.shape[:-1], N_BASIS, cfg.n_scale_groups)
        v = jnp.einsum("...pq,...q->...p", u, scale_multihot)  # Eq. 6
        dr = jnp.sum(w * apply_bases(v), axis=-1)  # Eq. 5
    else:
        zz = jnp.concatenate([t, scale_multihot], axis=-1)
        dr = L.mlp_apply(cell["flat_head"], zz, act="relu")[..., 0]
        dr = jax.nn.softplus(dr)  # keep rewards non-negative for parity
    return dr, h_new


def reward_apply(params: dict, cfg: RewardModelConfig,
                 raw_context: jnp.ndarray, model_onehot: jnp.ndarray,
                 scale_multihot: jnp.ndarray) -> jnp.ndarray:
    """Reward of ONE chain per request.

    raw_context:    (B, d_context)
    model_onehot:   (B, K, max_models)
    scale_multihot: (B, K, Q)
    returns:        (B,) predicted reward R_ij (Eq. 4)
    """
    f = encode_context(params, raw_context)
    h = jnp.zeros((*f.shape[:-1], cfg.d_state), f.dtype)
    total = jnp.zeros(f.shape[:-1], f.dtype)
    for k in range(cfg.n_stages):
        dr, h_new = _cell_apply(params["cells"][k], cfg, h, f,
                                model_onehot[..., k, :],
                                scale_multihot[..., k, :])
        total = total + dr
        if cfg.recursive:
            h = h_new  # else: every stage sees the zero state (Table 4 abl.)
    return total


def reward_matrix(params: dict, cfg: RewardModelConfig,
                  raw_context: jnp.ndarray, chain_model_onehot: jnp.ndarray,
                  chain_scale_multihot: jnp.ndarray) -> jnp.ndarray:
    """Full R in R^{I x J}: every request scored against every chain.

    raw_context:          (I, d_context)
    chain_model_onehot:   (J, K, max_models)   [from ActionChainSet]
    chain_scale_multihot: (J, K, Q)
    returns:              (I, J)
    """
    f = encode_context(params, raw_context)  # encode once: (I, d_f)

    def per_chain(m1, s1):  # m1: (K, M), s1: (K, Q)
        h = jnp.zeros((f.shape[0], cfg.d_state), f.dtype)
        total = jnp.zeros((f.shape[0],), f.dtype)
        for k in range(cfg.n_stages):
            mo = jnp.broadcast_to(m1[k], (f.shape[0], m1.shape[1]))
            sh = jnp.broadcast_to(s1[k], (f.shape[0], s1.shape[1]))
            dr, h_new = _cell_apply(params["cells"][k], cfg, h, f, mo, sh)
            total = total + dr
            if cfg.recursive:
                h = h_new
        return total  # (I,)

    return jax.vmap(per_chain, in_axes=(0, 0), out_axes=1)(
        chain_model_onehot, chain_scale_multihot)


def reward_matrix_chunked(params: dict, cfg: RewardModelConfig,
                          raw_context, chain_model_onehot,
                          chain_scale_multihot, *,
                          chunk: int = 2048) -> np.ndarray:
    """``reward_matrix`` evaluated in fixed-size request chunks.

    Peak memory is O(chunk * J) instead of O(I * J) - the offline
    analogue of the streaming serve path.  Inputs that fit one chunk
    take the direct call (bitwise identical to ``reward_matrix``);
    larger inputs run a jitted per-chunk kernel, identical per row up
    to float ulps (XLA blocks matmuls differently per batch shape).
    The last chunk is padded to ``chunk`` rows and sliced back, so any
    request count reuses ONE compiled shape.  Returns numpy (the
    chunks are host-concatenated).
    """
    ctx = np.asarray(raw_context, np.float32)
    i_n = ctx.shape[0]
    if i_n <= chunk:
        return np.asarray(reward_matrix(params, cfg, jnp.asarray(ctx),
                                        chain_model_onehot,
                                        chain_scale_multihot))
    fn = jax.jit(lambda c: reward_matrix(params, cfg, c,
                                         chain_model_onehot,
                                         chain_scale_multihot))
    parts = []
    for lo in range(0, i_n, chunk):
        sl = ctx[lo:lo + chunk]
        pad = chunk - sl.shape[0]
        if pad:
            sl = np.concatenate(
                [sl, np.zeros((pad, sl.shape[1]), np.float32)])
        parts.append(np.asarray(fn(jnp.asarray(sl)))[:chunk - pad or None])
    return np.concatenate(parts, axis=0)


# ---------------------------------------------------------------------------
# Model-prefix grouped scoring (the fused serving pipeline's hot path)
# ---------------------------------------------------------------------------
#
# The recursive state h_k is a function of the MODEL choices of stages
# <= k only: _cell_apply derives h_new from trunk(h, f, m_emb), never
# from the scale multi-hot (scales enter through the basis head alone).
# A chain set enumerates the Cartesian product of per-stage choices, so
# most chains share model prefixes - the paper layout has ONE distinct
# (recall, prerank) path and two rank models, i.e. J=O(100) chains but
# only ~2 distinct trunk evaluations per stage.  ``reward_matrix_grouped``
# runs each cell once per distinct prefix and broadcasts dr across the
# chains sharing it, cutting the per-window scoring FLOPs by ~J/2 while
# producing the same matrix as ``reward_matrix``.


def chain_prefix_plan(chain_model_idx: np.ndarray) -> tuple:
    """Static dedup plan from the (J, K) per-stage model indices.

    Returns one (model_of_prefix, parent_prefix, chain_to_prefix) triple
    per stage: stage k evaluates its cell once per distinct model prefix
    (m_1..m_k); ``parent_prefix`` maps each prefix to the stage-(k-1)
    prefix it extends, ``chain_to_prefix`` maps chains to prefixes.
    """
    chain_model_idx = np.asarray(chain_model_idx)
    j_n, k_n = chain_model_idx.shape
    plan = []
    prev_rows: list[tuple] = [()]
    for k in range(k_n):
        pref, inv = np.unique(chain_model_idx[:, :k + 1], axis=0,
                              return_inverse=True)
        prev_map = {r: i for i, r in enumerate(prev_rows)}
        parent = np.asarray([prev_map[tuple(r[:-1])] for r in pref],
                            np.int32)
        plan.append((pref[:, -1].astype(np.int32), parent,
                     inv.astype(np.int32).reshape(j_n)))
        prev_rows = [tuple(r) for r in pref]
    return tuple(plan)


def reward_matrix_grouped(params: dict, cfg: RewardModelConfig,
                          raw_context: jnp.ndarray,
                          chain_scale_multihot: jnp.ndarray,
                          plan: tuple) -> jnp.ndarray:
    """R in R^{I x J} with per-stage model-prefix deduplication.

    Same output as ``reward_matrix`` (cells see identical inputs, so
    chains sharing a prefix get the shared result rather than J
    recomputations); ``plan`` comes from ``chain_prefix_plan`` on the
    chain set's ``chain_idx[:, :, 0]``.
    """
    f = encode_context(params, raw_context)  # (I, d_f)
    i_n = f.shape[0]
    j_n = chain_scale_multihot.shape[0]
    h = jnp.zeros((i_n, 1, cfg.d_state), f.dtype)
    total = jnp.zeros((i_n, j_n), f.dtype)
    for k, (model_of_prefix, parent, chain_to_prefix) in enumerate(plan):
        cell = params["cells"][k]
        # non-recursive ablation: every stage reads the zero state, which
        # is what h holds when it is never updated below
        gather = parent if h.shape[1] > 1 else np.zeros_like(parent)
        n_p = len(model_of_prefix)
        z = jnp.concatenate([
            h[:, gather, :],
            jnp.broadcast_to(f[:, None, :], (i_n, n_p, f.shape[-1])),
            jnp.broadcast_to(cell["model_emb"][model_of_prefix],
                             (i_n, n_p, cfg.d_model_emb)),
        ], axis=-1)
        t = L.mlp_apply(cell["trunk"], z, act="relu", final_act="relu")
        sh_k = chain_scale_multihot[:, k, :]  # (J, Q)
        if cfg.multi_basis:
            w = jax.nn.softmax(L.dense_apply(cell["w_head"], t), axis=-1)
            u = jax.nn.softplus(L.dense_apply(cell["v_heads"], t))
            u = u.reshape(i_n, n_p, N_BASIS, cfg.n_scale_groups)
            v = jnp.einsum("ijpq,jq->ijp", u[:, chain_to_prefix],
                           sh_k)  # Eq. 6 per chain
            dr = jnp.sum(w[:, chain_to_prefix] * apply_bases(v), axis=-1)
        else:
            zz = jnp.concatenate([
                t[:, chain_to_prefix],
                jnp.broadcast_to(sh_k[None], (i_n, j_n, sh_k.shape[-1])),
            ], axis=-1)
            dr = L.mlp_apply(cell["flat_head"], zz, act="relu")[..., 0]
            dr = jax.nn.softplus(dr)
        total = total + dr
        if cfg.recursive:
            h = jnp.tanh(L.dense_apply(cell["state"], t))
    return total


# ---------------------------------------------------------------------------
# Per-chain label normalization (ratio targets)
# ---------------------------------------------------------------------------
#
# The multi-basis head is non-negative and monotone by construction, so the
# model cannot regress SIGNED residuals.  Instead the trainer fits the ratio
# y_uj = rev_uj / mean_u(rev_uj): the per-chain mean reward curve is
# measured exactly from simulation and stored in params["label_norm"]; the
# network only learns per-user deviations (the heterogeneity GreenFlow
# allocates on), and predictions de-normalize back to revenue units.


def chain_label_norm(revenue: np.ndarray, floor: float = 1e-3) -> np.ndarray:
    """Per-chain mean revenue over training users -> (J,) norm vector."""
    return np.maximum(np.asarray(revenue).mean(axis=0), floor) \
        .astype(np.float32)


def denormalize_rewards(params: dict, r):
    """Scale ratio predictions (.., J) back to revenue units, if the
    params carry a ``label_norm`` (no-op otherwise).  Backend-agnostic:
    works on numpy arrays and inside jit on tracers alike."""
    norm = params.get("label_norm")
    if norm is None:
        return r
    return r * norm[None, :]


# ---------------------------------------------------------------------------
# Training loss + calibration metric
# ---------------------------------------------------------------------------


def reward_loss(params: dict, cfg: RewardModelConfig, batch: dict) -> jnp.ndarray:
    """MSE on realized chain rewards (clicks among top-e).

    batch = {context (B,dc), model_onehot (B,K,M), scale_multihot (B,K,Q),
             label (B,), [weight (B,)]}
    """
    pred = reward_apply(params, cfg, batch["context"], batch["model_onehot"],
                        batch["scale_multihot"])
    err = jnp.square(pred - batch["label"])
    w = batch.get("weight")
    return jnp.mean(err * w) / jnp.maximum(jnp.mean(w), 1e-8) if w is not None \
        else jnp.mean(err)


def field_rce(y_true: np.ndarray, y_pred: np.ndarray,
              field_values: np.ndarray) -> float:
    """Field-level relative calibration error (paper Eq. 12, Pan et al.).

    Field-RCE = (1/|D|) * sum_f |sum_{i in D_f} (y_i - yhat_i)|
                          / ((1/|D_f|) * sum_{i in D_f} y_i)
    """
    y_true = np.asarray(y_true, np.float64)
    y_pred = np.asarray(y_pred, np.float64)
    field_values = np.asarray(field_values)
    total = 0.0
    for f in np.unique(field_values):
        m = field_values == f
        mean_y = y_true[m].mean()
        if mean_y <= 0:
            continue
        total += abs((y_true[m] - y_pred[m]).sum()) / mean_y
    return float(total / max(1, len(y_true)))
