"""Computation measure module (paper Fig. 2, step 2).

Two sources of FLOPs numbers, cross-checkable against each other:

  * analytic   - closed-form per-layer counts (used online: the allocator
    needs c_j without compiling anything);
  * compiled   - ``jax.stages.Compiled.cost_analysis()`` of the real jitted
    program (used by benchmarks + the roofline harness; catches drift
    between the analytic model and what XLA actually emits).
"""
from __future__ import annotations

from typing import Sequence

# ---------------------------------------------------------------------------
# Analytic counts (multiply-add = 2 FLOPs)
# ---------------------------------------------------------------------------


def dense_flops(d_in: int, d_out: int, batch: int = 1,
                use_bias: bool = True) -> float:
    f = 2.0 * d_in * d_out
    if use_bias:
        f += d_out
    return f * batch


def mlp_flops(dims: Sequence[int], batch: int = 1) -> float:
    return sum(dense_flops(dims[i], dims[i + 1], batch)
               for i in range(len(dims) - 1))


def attention_flops(seq_q: int, seq_kv: int, n_heads: int, d_head: int,
                    batch: int = 1) -> float:
    """QK^T + softmax*V (projections counted separately via dense_flops)."""
    qk = 2.0 * seq_q * seq_kv * n_heads * d_head
    av = 2.0 * seq_q * seq_kv * n_heads * d_head
    softmax = 5.0 * seq_q * seq_kv * n_heads
    return (qk + av + softmax) * batch


def gru_flops(seq: int, d_in: int, d_hidden: int, batch: int = 1) -> float:
    """3 gates, each (d_in + d_hidden) -> d_hidden matmuls per step."""
    per_step = 3 * (dense_flops(d_in, d_hidden) + dense_flops(d_hidden, d_hidden))
    return (per_step + 9.0 * d_hidden) * seq * batch


def embedding_flops(n_lookups: int, dim: int) -> float:
    """Lookups are gathers: ~0 MACs; count the bag-sum adds."""
    return float(n_lookups * dim)


def transformer_layer_flops(seq: int, d_model: int, n_heads: int,
                            n_kv_heads: int, d_head: int, d_ff: int,
                            *, gated_ffn: bool = True, causal: bool = True,
                            batch: int = 1) -> float:
    q = dense_flops(d_model, n_heads * d_head, seq)
    kv = 2 * dense_flops(d_model, n_kv_heads * d_head, seq)
    o = dense_flops(n_heads * d_head, d_model, seq)
    attn = attention_flops(seq, seq, n_heads, d_head) * (0.5 if causal else 1.0)
    n_mats = 3 if gated_ffn else 2
    ffn = n_mats * dense_flops(d_model, d_ff, seq)
    return (q + kv + o + attn + ffn) * batch


def lm_train_step_flops(n_params: float, n_tokens: float) -> float:
    """The 6*N*D rule (fwd 2ND + bwd 4ND)."""
    return 6.0 * n_params * n_tokens


# ---------------------------------------------------------------------------
# Compiled counts
# ---------------------------------------------------------------------------


def flops_from_compiled(compiled) -> float:
    """Total FLOPs from an XLA cost analysis (0.0 if unavailable)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return float(ca.get("flops", 0.0)) if ca else 0.0


def bytes_from_compiled(compiled) -> float:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not ca:
        return 0.0
    return float(ca.get("bytes accessed", ca.get("bytes_accessed", 0.0)))
