"""GreenFlow facade: the hybrid online-nearline allocator (paper Fig. 2).

Ties together:
  chain set (step 1)  +  reward model & cost measure (step 2)
  +  dynamic primal-dual (step 3, nearline)  +  Eq. 10 decisions (online).

The allocator itself consumes compute (the paper quantifies +3~8% FLOPs);
``self_cost_flops`` meters the reward-model forward so PFEC reports include
the overhead honestly (Table 5 "Additional Cost").
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.core.budget import BudgetController
from repro.core.flops import mlp_flops
from repro.core.pfec import PFECReport, pfec_report
from repro.core.primal_dual import DualDescentConfig
from repro.core.reward_model import (RewardModelConfig, denormalize_rewards,
                                     reward_matrix, N_BASIS)


@dataclass
class GreenFlowAllocator:
    chains: ActionChainSet
    reward_params: dict
    reward_cfg: RewardModelConfig
    budget_per_window: float
    dual_cfg: DualDescentConfig = field(default_factory=DualDescentConfig)
    guard: bool = True

    def __post_init__(self):
        self.controller = BudgetController(
            self.chains, self.budget_per_window, self.dual_cfg, self.guard)
        self._chain_mo = jnp.asarray(self.chains.model_onehot)
        self._chain_sh = jnp.asarray(self.chains.scale_multihot)

        def _fn(params, ctx):
            r = reward_matrix(params, self.reward_cfg, ctx, self._chain_mo,
                              self._chain_sh)
            # ratio-normalized training (core.reward_model): predictions
            # must scale back to revenue units before meeting chain costs
            return denormalize_rewards(params, r)

        self._reward_fn = jax.jit(_fn)
        self._total_self_flops = 0.0
        self._total_spend = 0.0
        self._n_requests = 0

    # -- step 2: reward scores for a window of requests ---------------------
    def score(self, raw_context: np.ndarray) -> jnp.ndarray:
        ctx = jnp.asarray(raw_context, jnp.float32)
        self._total_self_flops += self.self_cost_flops(ctx.shape[0])
        return self._reward_fn(self.reward_params, ctx)

    # -- steps 3+4: allocate one window --------------------------------------
    def allocate_window(self, raw_context: np.ndarray) -> np.ndarray:
        rewards = self.score(raw_context)
        decisions = self.controller.step_window(np.asarray(rewards))
        self._total_spend += float(self.chains.costs[decisions].sum())
        self._n_requests += len(decisions)
        return decisions

    # -- PFEC accounting ------------------------------------------------------
    def self_cost_flops(self, n_requests: int) -> float:
        """FLOPs of GreenFlow itself: encoder + K cells x J chains/request."""
        cfg = self.reward_cfg
        enc = mlp_flops([cfg.d_context, *cfg.encoder_hidden, cfg.d_feature])
        d_in = cfg.d_state + cfg.d_feature + cfg.d_model_emb
        cell = (mlp_flops([d_in, cfg.d_hidden, cfg.d_hidden])
                + mlp_flops([cfg.d_hidden, cfg.d_state])
                + mlp_flops([cfg.d_hidden, N_BASIS])
                + mlp_flops([cfg.d_hidden, N_BASIS * cfg.n_scale_groups]))
        per_request = enc + cfg.n_stages * cell * self.chains.n_chains
        return per_request * n_requests

    def report(self, clicks: float) -> PFECReport:
        return pfec_report(
            clicks=clicks,
            flops=self._total_spend,
            n_requests=self._n_requests,
            overhead_flops=self._total_self_flops,
            overhead_frac=self._total_self_flops / max(self._total_spend, 1.0),
            lam=float(self.controller.pd.lam),
        )

    @property
    def lam(self) -> float:
        return float(self.controller.pd.lam)
