"""Dynamic primal-dual optimization (paper §4.3, Algorithm 1).

The assignment LP (Eq. 3) has ONE coupling constraint (the global FLOPs
budget), so its Lagrangian dual is a scalar problem in the dual price
lambda.  Given lambda, the inner max decomposes per request:

    x_ij = 1  iff  j = argmax_j (R_ij - lambda * c_j)          (Eq. 10)

and the dual subgradient is  dL/dlambda = C - sum_i c_{j*(i)}.

We provide:
  * ``dual_descent``  - Algorithm 1 verbatim as a lax.scan (jit-able, runs
    the whole nearline window on-device).
  * ``dual_bisect``   - an exact oracle: consumption(lambda) is a step
    function, non-increasing in lambda, so the optimal price is found by
    bisection.  Used for tests and as a warm-start.
  * ``allocate``      - Eq. 10 decisions for a batch of requests.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp


@jax.jit
def allocate(rewards: jnp.ndarray, costs: jnp.ndarray,
             lam: jnp.ndarray) -> jnp.ndarray:
    """Eq. 10: per-request argmax of the lagrangian score.

    rewards: (I, J), costs: (J,), lam: scalar -> (I,) int32 chain index.
    """
    score = rewards - lam * costs[None, :]
    return jnp.argmax(score, axis=1).astype(jnp.int32)


def consumption(rewards: jnp.ndarray, costs: jnp.ndarray,
                lam: jnp.ndarray, mask: jnp.ndarray | None = None,
                *, axis_name: str | None = None) -> jnp.ndarray:
    """Total FLOPs consumed if lambda is the dual price.

    mask (I,) zeroes padded requests; axis_name sums across a request
    mesh axis (shard_map), so the padded + sharded fused pipeline sees
    the same window-global consumption as the host loop.
    """
    j_star = allocate(rewards, costs, lam)
    taken = jnp.take(costs, j_star)
    used = jnp.sum(taken if mask is None else taken * mask)
    return used if axis_name is None else jax.lax.psum(used, axis_name)


def realized_reward(rewards: jnp.ndarray, j_star: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.take_along_axis(rewards, j_star[:, None], axis=1))


# ---------------------------------------------------------------------------
# Algorithm 1 (dual descent)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DualDescentConfig:
    max_iters: int = 200  # L in Algorithm 1
    step_size: float = 1.0  # eta (normalized internally, see below)
    step_decay: float = 0.999
    lam_init: float = 0.0


@partial(jax.jit, static_argnames=("max_iters", "axis_name"))
def dual_descent(rewards: jnp.ndarray, costs: jnp.ndarray, budget: float,
                 lam0: jnp.ndarray, *, mask: jnp.ndarray | None = None,
                 max_iters: int = 200, step_size: float = 1.0,
                 step_decay: float = 0.999, axis_name: str | None = None):
    """Algorithm 1 inner loop (steps 5-9), vectorized over all requests.

    The raw subgradient C - sum c_j x_ij has the scale of the budget, while
    useful lambda values have the scale of reward-per-FLOP; we therefore
    normalize the step by (I * mean(c)^2) so `step_size` is dimensionless
    and stable across budgets.  Returns (lam, trace_of_gaps).

    mask/axis_name (see ``consumption``) let the fused serving pipeline
    run the update on padded, request-sharded windows: I in the step
    normalization becomes the VALID request count, and every shard sees
    the same (replicated) lambda trajectory.
    """
    costs = costs.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    if mask is None:
        n_eff = jnp.float32(rewards.shape[0])
        if axis_name is not None:
            n_eff = jax.lax.psum(n_eff, axis_name)
    else:
        n_eff = jnp.sum(mask.astype(jnp.float32))
        if axis_name is not None:
            n_eff = jax.lax.psum(n_eff, axis_name)
    # an all-masked (empty) window carries no information: floor n_eff so
    # the step normalization cannot explode and slam lambda to 0
    norm = jnp.maximum(n_eff, 1.0) * jnp.mean(costs) ** 2 + 1e-30

    def body(carry, _):
        lam, eta = carry
        used = consumption(rewards, costs, lam, mask, axis_name=axis_name)
        grad = budget - used  # dL/dlambda
        lam_new = jnp.maximum(0.0, lam - eta * grad / norm)
        return (lam_new, eta * step_decay), (budget - used)

    (lam, _), gaps = jax.lax.scan(
        body, (jnp.asarray(lam0, jnp.float32), jnp.asarray(step_size)),
        None, length=max_iters)
    return lam, gaps


# ---------------------------------------------------------------------------
# Exact oracle by bisection (single constraint => monotone consumption)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("iters",))
def dual_bisect(rewards: jnp.ndarray, costs: jnp.ndarray, budget: float,
                *, iters: int = 64, lam_hi_init: float = None):
    """Smallest lambda >= 0 with consumption(lambda) <= budget.

    consumption is non-increasing in lambda (higher price -> cheaper chains)
    so bisection is exact up to float resolution. If even lambda=0 fits the
    budget, returns 0 (budget slack; constraint inactive).
    """
    rewards = rewards.astype(jnp.float32)
    costs = costs.astype(jnp.float32)
    # Upper bound: the price at which every request picks its cheapest
    # chain.  Chain j beats a cheaper j' once lam > (R_j - R_j')/(c_j -
    # c_j'), so the bound must use the smallest POSITIVE cost gap (two
    # nearly-equal costs need a huge price to separate), not min cost.
    r_span = jnp.max(rewards) - jnp.min(rewards)
    sorted_c = jnp.sort(costs)
    gaps = jnp.diff(sorted_c)
    min_gap = jnp.min(jnp.where(gaps > 0, gaps, jnp.inf), initial=jnp.inf)
    min_gap = jnp.where(jnp.isfinite(min_gap), min_gap, jnp.max(costs))
    lam_hi = (r_span / jnp.maximum(min_gap, 1e-30) + 1.0) \
        if lam_hi_init is None else jnp.asarray(lam_hi_init, jnp.float32)

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        fits = consumption(rewards, costs, mid) <= budget
        return jnp.where(fits, lo, mid), jnp.where(fits, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body,
                               (jnp.float32(0.0), lam_hi))
    # prefer 0 if the unconstrained allocation already fits
    fits0 = consumption(rewards, costs, jnp.float32(0.0)) <= budget
    return jnp.where(fits0, 0.0, hi)


# ---------------------------------------------------------------------------
# Streaming wrapper (the nearline job, Algorithm 1 outer loop)
# ---------------------------------------------------------------------------


class DynamicPrimalDual:
    """Nearline dual-price tracker.

    Every window t: observe the (R, c) samples collected from traffic,
    run L descent steps warm-started at lambda_{t-1}, publish lambda_t.
    Online decisions for window t+1 use lambda_t (paper: near-optimal
    under i.i.d. arrivals, Agrawal et al. 2014).
    """

    def __init__(self, costs, budget_per_window: float,
                 cfg: DualDescentConfig = DualDescentConfig()):
        self.costs = jnp.asarray(costs, jnp.float32)
        self.budget = float(budget_per_window)
        self.cfg = cfg
        self.lam = jnp.float32(cfg.lam_init)
        self.history: list[float] = []

    def update(self, rewards) -> float:
        """One nearline window: returns the new published dual price."""
        lam, _ = dual_descent(
            jnp.asarray(rewards), self.costs, self.budget, self.lam,
            max_iters=self.cfg.max_iters, step_size=self.cfg.step_size,
            step_decay=self.cfg.step_decay)
        self.lam = lam
        self.history.append(float(lam))
        return float(lam)

    def decide(self, rewards) -> jnp.ndarray:
        """Online module: Eq. 10 with the latest published price."""
        return allocate(jnp.asarray(rewards), self.costs, self.lam)

    def set_budget(self, budget: float):
        self.budget = float(budget)
