"""Vectorized multi-price allocator core (paper §4.3, Algorithm 1).

This module is the ONE implementation of GreenFlow's Eq. 10 / Algorithm 1
machinery; everything that prices computation builds on it:

  * the fused serving pass  - ``serving.pipeline.ServingPipeline`` calls
    ``allocate``/``dual_descent`` inside its jitted window pass (scalar
    price, per-tenant prices, or per-region geo prices);
  * the host loops          - ``core.budget.BudgetController`` and
    ``carbon.controller.CarbonBudgetController`` are thin wrappers over
    ``window_step`` (decide -> guard -> dual) with FLOPs- or
    carbon-denominated costs;
  * the downgrade guard     - ``serving.guard.downgrade_guard`` shares
    the same scalar/vector duality (per-constraint budgets via ``k_of``).

The assignment LP (Eq. 3) couples requests through budget constraints.
With ONE global budget the Lagrangian dual is a scalar price lambda and
the inner max decomposes per request:

    x_ij = 1  iff  j = argmax_j (R_ij - lambda * c_j)          (Eq. 10)

The general form prices K >= 1 constraints at once - K ranges over
tenant x region in the serving system, but the core is agnostic:

    x_im = 1  iff  m = argmax_m (R_im - sum_k lam_k * A_imk)

where m indexes OPTIONS (chains, or chains x serving regions) and the
consumption tensor factors as  A_imk = member_ik * C_mk  with

    C      (M, K)  cost map: what option m draws from constraint k
                   (e.g. c_{j,r}(t) = flops_j * kappa * CI_r(t) in
                   column r for geo options, zero elsewhere);
    member (I, K)  which constraints request i is subject to (tenant
                   one-hot; None = every request subject to all K).

Every function below accepts BOTH forms and the scalar form is the K=1
special case executing the identical floating-point operations - the
bit-parity gate in tests/test_multi_price.py:

  * scalar: ``lam`` a scalar, ``costs`` (M,);
  * vector: ``lam`` (K,), ``costs`` (M, K) (an (M, 1) column broadcasts
    across K when ``member`` carries the constraint structure).

We provide:
  * ``allocate``      - Eq. 10 decisions for a batch of requests.
  * ``consumption``   - per-constraint spend at a given price (psum-able
    across a request mesh axis for the sharded fused pipeline).
  * ``dual_descent``  - Algorithm 1 as a lax.scan (jit-able, runs the
    whole nearline window on-device); K prices descend jointly on the
    per-constraint subgradients.
  * ``dual_bisect``   - an exact scalar oracle (single constraint =>
    consumption is a step function, non-increasing in lambda); used for
    tests, benchmarks and warm-starts.
  * ``window_step``   - the host-loop window body (decide -> NumPy guard
    -> dual update) shared by the budget controllers.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp

from repro.distributed.sharding import ordered_psum


def _as_cost_map(costs: jnp.ndarray) -> jnp.ndarray:
    """(M,) or (M, K) costs -> (M, K) cost map."""
    return costs if costs.ndim == 2 else costs[:, None]


def _option_prices(costs, lam, member):
    """The lagrangian price term, broadcast to (I, M) or (M,).

    Scalar lam: lam * costs (the original Eq. 10 term).  Vector lam:
    sum_k lam_k * member_ik * C_mk - an (I, K) @ (K, M) matmul when
    member is given, else the (M,) column combination C @ lam.
    """
    if jnp.ndim(lam) == 0:
        return lam * costs
    cm = _as_cost_map(costs)
    if member is None:
        if cm.shape[1] != lam.shape[0]:  # an (M, 1) column only spans K
            raise ValueError(             # constraints through member
                f"cost map with {cm.shape[1]} columns cannot be priced "
                f"by {lam.shape[0]} duals without a member matrix")
        return cm @ lam
    return member @ (cm * lam[None, :]).T


@jax.jit
def allocate(rewards: jnp.ndarray, costs: jnp.ndarray,
             lam: jnp.ndarray, member: jnp.ndarray | None = None
             ) -> jnp.ndarray:
    """Eq. 10: per-request argmax of the lagrangian score.

    rewards: (I, M); costs: (M,) with scalar ``lam`` (the K=1 path,
    bit-identical to the historical scalar implementation), or (M, K)
    with ``lam`` (K,) and optional ``member`` (I, K).  Returns (I,)
    int32 option index.
    """
    if jnp.ndim(lam) == 0:
        score = rewards - lam * costs[None, :]
    else:
        price = _option_prices(costs, lam, member)
        score = rewards - (price if price.ndim == 2 else price[None, :])
    return jnp.argmax(score, axis=1).astype(jnp.int32)


def consumption(rewards: jnp.ndarray, costs: jnp.ndarray,
                lam: jnp.ndarray, mask: jnp.ndarray | None = None,
                *, member: jnp.ndarray | None = None,
                axis_name: str | None = None) -> jnp.ndarray:
    """Spend per constraint if ``lam`` is the dual price.

    Scalar ``lam``: the total (scalar) spend - unchanged semantics.
    Vector ``lam``: (K,) per-constraint spend sum_i member_ik *
    C[m*_i, k].  mask (I,) zeroes padded requests; axis_name sums across
    a request mesh axis (shard_map), so the padded + sharded fused
    pipeline sees the same window-global consumption as the host loop.
    """
    j_star = allocate(rewards, costs, lam, member)
    if jnp.ndim(lam) == 0:
        taken = jnp.take(costs, j_star)
        used = jnp.sum(taken if mask is None else taken * mask)
    else:
        taken = _as_cost_map(costs)[j_star]  # (I, K) or (I, 1)
        # one (I,) reduction per constraint, not a (I, K) axis-0 sum:
        # XLA lowers the two differently, and the K=1 column must run
        # the scalar path's exact reduction to stay bit-identical
        cols = []
        for k in range(int(lam.shape[0])):
            tk = taken[:, min(k, taken.shape[1] - 1)]
            if member is not None:
                tk = tk * member[:, k]
            cols.append(jnp.sum(tk if mask is None else tk * mask))
        used = jnp.stack(cols)
    return used if axis_name is None else ordered_psum(used, axis_name)


def realized_reward(rewards: jnp.ndarray, j_star: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.take_along_axis(rewards, j_star[:, None], axis=1))


# ---------------------------------------------------------------------------
# Algorithm 1 (dual descent)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DualDescentConfig:
    max_iters: int = 200  # L in Algorithm 1
    step_size: float = 1.0  # eta (normalized internally, see below)
    step_decay: float = 0.999
    lam_init: float = 0.0


@partial(jax.jit, static_argnames=("max_iters", "axis_name"))
def dual_descent(rewards: jnp.ndarray, costs: jnp.ndarray, budget,
                 lam0: jnp.ndarray, *, mask: jnp.ndarray | None = None,
                 member: jnp.ndarray | None = None,
                 max_iters: int = 200, step_size: float = 1.0,
                 step_decay: float = 0.999, axis_name: str | None = None):
    """Algorithm 1 inner loop (steps 5-9), vectorized over all requests.

    Scalar ``lam0``/``budget``: the single-price update (bit-identical
    to the historical scalar implementation).  Vector ``lam0`` (K,) with
    ``budget`` (K,): all K prices descend jointly, each on its own
    subgradient B_k - used_k.

    The raw subgradient has the scale of the budget, while useful lambda
    values have the scale of reward-per-unit-cost; the step is therefore
    normalized by (n_k * mean_cost_k^2) so `step_size` is dimensionless
    and stable across budgets (n_k = requests subject to constraint k,
    mean_cost_k = mean over the options that draw from k).

    mask/member/axis_name (see ``consumption``) let the fused serving
    pipeline run the update on padded, request-sharded windows: n_k
    counts VALID requests only, and every shard sees the same
    (replicated) price trajectory.  Returns (lam, trace_of_gaps).
    """
    costs = costs.astype(jnp.float32)
    rewards = rewards.astype(jnp.float32)
    vector = jnp.ndim(lam0) > 0
    if mask is None:
        n_eff = jnp.float32(rewards.shape[0])
        if axis_name is not None:
            n_eff = ordered_psum(n_eff, axis_name)
    else:
        n_eff = jnp.sum(mask.astype(jnp.float32))
        if axis_name is not None:
            n_eff = ordered_psum(n_eff, axis_name)
    if not vector:
        # an all-masked (empty) window carries no information: floor
        # n_eff so the step normalization cannot explode and slam the
        # price to 0
        # gf: allow[GF003] THE scalar reference expression: the vector
        # path below reproduces this exact float program (PR 4)
        norm = jnp.maximum(n_eff, 1.0) * jnp.mean(costs) ** 2 + 1e-30
    else:
        cm = _as_cost_map(costs)
        if member is not None:
            m = member if mask is None else member * mask[:, None]
            n_k = jnp.sum(m, axis=0)
            if axis_name is not None:
                n_k = ordered_psum(n_k, axis_name)
        else:
            n_k = n_eff
        # per-constraint norm n_k * mean_k^2 where mean_k averages the
        # options that DRAW from the constraint (a geo cost map is zero
        # off its region's column).  Structured as the scalar path's
        # exact expression times a sparsity correction (M/cnt_k)^2 -
        # exactly 1.0 for fully active columns - so the K=1 case stays
        # BIT-identical to the scalar norm: folding the correction into
        # the mean instead lets XLA reassociate the constant divisor
        # chain and drift the last mantissa bits.
        active = (cm > 0).astype(jnp.float32)
        cnt = jnp.maximum(jnp.sum(active, axis=0), 1.0)
        corr = (jnp.float32(cm.shape[0]) / cnt) ** 2
        # gf: allow[GF003] deliberately the scalar path's mean times a
        # separate (M/cnt)^2 correction so K=1 stays bitwise (PR 4;
        # folding the correction INTO the mean is the hazard)
        base = jnp.maximum(n_k, 1.0) * jnp.mean(cm, axis=0) ** 2 + 1e-30
        norm = jnp.broadcast_to(base * corr, lam0.shape)

    def body(carry, _):
        lam, eta = carry
        used = consumption(rewards, costs, lam, mask, member=member,
                           axis_name=axis_name)
        grad = budget - used  # dL/dlambda (per constraint)
        lam_new = jnp.maximum(0.0, lam - eta * grad / norm)
        return (lam_new, eta * step_decay), (budget - used)

    (lam, _), gaps = jax.lax.scan(
        body, (jnp.asarray(lam0, jnp.float32), jnp.asarray(step_size)),
        None, length=max_iters)
    return lam, gaps


# ---------------------------------------------------------------------------
# Exact oracle by bisection (single constraint => monotone consumption)
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("iters",))
def dual_bisect(rewards: jnp.ndarray, costs: jnp.ndarray, budget: float,
                *, iters: int = 64, lam_hi_init: float = None):
    """Smallest lambda >= 0 with consumption(lambda) <= budget.

    Single-constraint only: consumption is non-increasing in lambda
    (higher price -> cheaper chains) so bisection is exact up to float
    resolution.  If even lambda=0 fits the budget, returns 0 (budget
    slack; constraint inactive).
    """
    rewards = rewards.astype(jnp.float32)
    costs = costs.astype(jnp.float32)
    # Upper bound: the price at which every request picks its cheapest
    # chain.  Chain j beats a cheaper j' once lam > (R_j - R_j')/(c_j -
    # c_j'), so the bound must use the smallest POSITIVE cost gap (two
    # nearly-equal costs need a huge price to separate), not min cost.
    r_span = jnp.max(rewards) - jnp.min(rewards)
    sorted_c = jnp.sort(costs)
    gaps = jnp.diff(sorted_c)
    min_gap = jnp.min(jnp.where(gaps > 0, gaps, jnp.inf), initial=jnp.inf)
    min_gap = jnp.where(jnp.isfinite(min_gap), min_gap, jnp.max(costs))
    lam_hi = (r_span / jnp.maximum(min_gap, 1e-30) + 1.0) \
        if lam_hi_init is None else jnp.asarray(lam_hi_init, jnp.float32)

    def body(_, bounds):
        lo, hi = bounds
        mid = 0.5 * (lo + hi)
        fits = consumption(rewards, costs, mid) <= budget
        return jnp.where(fits, lo, mid), jnp.where(fits, mid, hi)

    lo, hi = jax.lax.fori_loop(0, iters, body,
                               (jnp.float32(0.0), lam_hi))
    # prefer 0 if the unconstrained allocation already fits
    fits0 = consumption(rewards, costs, jnp.float32(0.0)) <= budget
    return jnp.where(fits0, 0.0, hi)


# ---------------------------------------------------------------------------
# The shared host-loop window body (controllers are wrappers over this)
# ---------------------------------------------------------------------------


def window_step(rewards, costs, budget: float, lam, *, cheap: int,
                guard: bool = True,
                cfg: DualDescentConfig | None = None):
    """One host-loop serving window: Eq. 10 decide -> tail-reserve guard
    -> Algorithm 1 price update, in the single-price (scalar) form.

    ``core.budget.BudgetController`` (FLOPs costs) and
    ``carbon.controller.CarbonBudgetController`` (carbon-effective
    costs) both delegate here so the three historical copies of this
    loop stay ONE implementation.  Returns
    ``(decisions, downgraded, spend, lam_new)`` with ``decisions`` a
    host ndarray and ``lam_new`` the published (device) price.
    """
    import numpy as np

    from repro.serving.guard import downgrade_guard_np

    cfg = cfg or DualDescentConfig()  # fresh default, never import-time
    costs = np.asarray(costs)
    costs_j = jnp.asarray(costs, jnp.float32)
    rewards_j = jnp.asarray(rewards)
    decisions = np.asarray(allocate(rewards_j, costs_j, lam))
    downgraded = 0
    spend = float(np.sum(costs[decisions]))
    if guard:
        decisions, downgraded, spend = downgrade_guard_np(
            decisions, costs, budget, cheap)
    lam_new, _ = dual_descent(
        rewards_j, costs_j, budget, lam, max_iters=cfg.max_iters,
        step_size=cfg.step_size, step_decay=cfg.step_decay)
    return decisions, downgraded, spend, lam_new


# ---------------------------------------------------------------------------
# Streaming wrapper (the nearline job, Algorithm 1 outer loop)
# ---------------------------------------------------------------------------


class DynamicPrimalDual:
    """Nearline dual-price tracker.

    Every window t: observe the (R, c) samples collected from traffic,
    run L descent steps warm-started at lambda_{t-1}, publish lambda_t.
    Online decisions for window t+1 use lambda_t (paper: near-optimal
    under i.i.d. arrivals, Agrawal et al. 2014).
    """

    def __init__(self, costs, budget_per_window: float,
                 cfg: DualDescentConfig | None = None):
        self.costs = jnp.asarray(costs, jnp.float32)
        self.budget = float(budget_per_window)
        self.cfg = cfg or DualDescentConfig()
        self.lam = jnp.float32(self.cfg.lam_init)
        self.history: list[float] = []

    def update(self, rewards) -> float:
        """One nearline window: returns the new published dual price."""
        lam, _ = dual_descent(
            jnp.asarray(rewards), self.costs, self.budget, self.lam,
            max_iters=self.cfg.max_iters, step_size=self.cfg.step_size,
            step_decay=self.cfg.step_decay)
        self.lam = lam
        self.history.append(float(lam))
        return float(lam)

    def decide(self, rewards) -> jnp.ndarray:
        """Online module: Eq. 10 with the latest published price."""
        return allocate(jnp.asarray(rewards), self.costs, self.lam)

    def set_budget(self, budget: float):
        self.budget = float(budget)
