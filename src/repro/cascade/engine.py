"""Cascade execution engine (paper §5.1 protocol), rank-based + vectorized.

Offline protocol: per evaluation user, every stage model scores the whole
corpus ONCE (jitted, batched); evaluating an action chain is then pure
ranking arithmetic over precomputed score vectors - exactly the paper's
"simulate different action chains for each user" procedure, and it makes
the J-chain sweep cheap.

THE RANK-BASED SIMULATION TRICK
-------------------------------
Every chain truncates the candidate set along the SAME per-model orderings;
only the truncation thresholds (n_2, n_3, e) and the rank-stage model
differ.  So instead of re-running top-k selection per chain (the seed ran
``np.argpartition`` over the full (U, I) score matrices J times), chain
evaluation becomes *rank-threshold arithmetic* over shared orderings:
walking a stage's order, a candidate survives iff fewer than ``keep_k``
survivors precede it - an exclusive cumulative sum of the survivor mask.

The fast path for the paper's 3-stage layout (``_simulate_k3_numpy``)
pushes this further with three structural facts:

  1. only the RECALL stage needs a full-corpus argsort: later stages rank
     candidates relative to each other, and sorting a candidate list by
     (-score, item_id) reproduces the global stable descending order
     restricted to that list, exactly;
  2. chains sharing (rank model, n2) differ only in n3, and the stage-1
     survivor list for n3 is a PREFIX of the list for any larger n3 - so
     one compact candidate list of length cap = max(n3) per distinct n2
     serves every chain, and all per-chain work runs on (U, cap) arrays,
     nearly independent of corpus size and amortized over all J chains
     (O(U*I*log I) once + O(U*J*cap) thresholds, vs the seed's
     O(J*U*I) partial sorts with Python-loop overhead);
  3. every step is independent per user, so the user axis shards across
     cores.

For float32 scores the (-score, id) sort packs both keys into one int64
via an order-preserving bit map (one stable argsort instead of a
two-pass lexsort).  Generic chain layouts and accelerator execution use
the jitted kernels (``_revenue_all_chains``: a ``lax.scan`` over chains
of gather/cumsum/scatter rounds on precomputed orders; CascadeServer's
``_revenue_requests``: the same per (user, chain) pair).  A brute-force
NumPy implementation of the SAME semantics (``run_chain`` /
``simulate_revenue_matrix_reference``) is the oracle; the vectorized
matrix is bit-identical to it (tested, including tie and signed-zero
cases).

Truncation semantics (unified; fixes the seed's stage-1/stage-2
``argpartition`` kth inconsistency): every stage keeps the first
``keep_k`` *surviving* items along the stage model's global descending
stable order, ties broken by item id.  ``keep > #survivors`` degrades to
"keep all" (the n3 >= n2 edge), and the exposure stage is just one more
truncation with ``keep = e``.

Online serving (`CascadeServer`): requests carry per-request chain ids;
one batched jitted kernel evaluates every (user, chain) pair in a single
pass - no per-chain-group NumPy recomputation (DESIGN.md §3).

Scoring truncated candidate sets uses TOP-K SELECTION ON SCORES from the
upstream stage; clicks are ground-truth sampled once per (user, item) so
revenue@e is deterministic given the seed.
"""
from __future__ import annotations

import os
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.models.recsys import dien, din, dssm, ydnn

_POOL: ThreadPoolExecutor | None = None
_POOL_WORKERS = 0


def _shared_pool(n_workers: int) -> ThreadPoolExecutor:
    """Lazy module-level pool: thread spawn costs milliseconds on small
    hosts, comparable to one whole simulation call."""
    global _POOL, _POOL_WORKERS
    if _POOL is None or _POOL_WORKERS < n_workers:
        if _POOL is not None:
            _POOL.shutdown(wait=False)
        _POOL = ThreadPoolExecutor(max_workers=n_workers)
        _POOL_WORKERS = n_workers
    return _POOL


@dataclass
class CascadeModels:
    """Trained stage models + their configs."""

    dssm_params: dict
    dssm_cfg: dssm.DSSMConfig
    ydnn_params: dict
    ydnn_cfg: ydnn.YDNNConfig
    din_params: dict
    din_cfg: din.DINConfig
    dien_params: dict
    dien_cfg: dien.DIENConfig


def _user_batch(world, users: np.ndarray) -> dict:
    return {
        "user_fields": jnp.asarray(world.user_fields[users], jnp.int32),
        "hist_ids": jnp.asarray(world.hist_ids[users], jnp.int32),
        "hist_cats": jnp.asarray(world.item_cat[world.hist_ids[users]],
                                 jnp.int32),
        "hist_mask": jnp.asarray(world.hist_mask[users], jnp.float32),
    }


def precompute_stage_scores(models: CascadeModels, world, users: np.ndarray,
                            *, item_block: int = 256) -> dict:
    """Score the full corpus with every stage model -> {name: (U, I)}."""
    n_items = world.cfg.n_items
    item_ids = jnp.arange(n_items, dtype=jnp.int32)
    item_cats = jnp.asarray(world.item_cat, jnp.int32)
    ub = _user_batch(world, users)

    # user fields for the recall/prerank towers use the raw field ids;
    # the recall item tower sees (category,) or (id, category) per its cfg
    if models.dssm_cfg.n_item_fields == 1:
        dssm_item_fields = jnp.stack([item_cats], axis=-1)  # (I, 1)
    else:
        dssm_item_fields = jnp.stack([item_ids, item_cats], axis=-1)  # (I, 2)

    @jax.jit
    def dssm_all(uf):
        v = dssm.item_tower(models.dssm_params, models.dssm_cfg,
                            dssm_item_fields)
        u = dssm.user_tower(models.dssm_params, models.dssm_cfg, uf)
        return u @ v.T

    @jax.jit
    def ydnn_all(hist, mask, uf):
        u = ydnn.user_vector(models.ydnn_params, models.ydnn_cfg, hist, mask,
                             uf)
        v = models.ydnn_params["out_emb"]["table"][:n_items]
        return u @ v.T

    scores = {
        "DSSM": np.asarray(dssm_all(ub["user_fields"])),
        "YDNN": np.asarray(ydnn_all(ub["hist_ids"], ub["hist_mask"],
                                    ub["user_fields"])),
    }

    @jax.jit
    def din_block(batch, cand_ids, cand_cats):
        return din.score(models.din_params, models.din_cfg, batch,
                         cand_ids, cand_cats)

    @jax.jit
    def dien_block(batch, cand_ids, cand_cats):
        return dien.score(models.dien_params, models.dien_cfg, batch,
                          cand_ids, cand_cats)

    for name, fn in (("DIN", din_block), ("DIEN", dien_block)):
        rows = []
        for lo in range(0, n_items, item_block):
            hi = min(n_items, lo + item_block)
            ids = jnp.broadcast_to(item_ids[lo:hi], (len(users), hi - lo))
            cats = jnp.broadcast_to(item_cats[lo:hi], (len(users), hi - lo))
            rows.append(np.asarray(fn(ub, ids, cats)))
        scores[name] = np.concatenate(rows, axis=1)
    return scores


# ---------------------------------------------------------------------------
# Shared sorted orderings (computed once, reused by every chain)
# ---------------------------------------------------------------------------


@dataclass
class RankedScores:
    """Per-model global item orderings shared by all chains.

    ``orders[m, u]`` lists item ids in descending score order of model
    ``names[m]`` for user ``u`` (stable: ties break by item id);
    ``ranks[m, u]`` is the inverse permutation (item id -> position).
    """

    names: tuple  # (M,) model names, axis-0 of orders/ranks
    orders: np.ndarray  # (M, U, I) int32
    ranks: np.ndarray  # (M, U, I) int32

    @property
    def slot(self) -> dict:
        return {n: m for m, n in enumerate(self.names)}


def rank_stage_scores(stage_scores: dict) -> RankedScores:
    """Stable-argsort every stage model's scores once -> RankedScores."""
    names = tuple(stage_scores)
    mats = [np.asarray(stage_scores[n]) for n in names]
    u, i = mats[0].shape
    orders = np.empty((len(names), u, i), np.int32)
    ranks = np.empty_like(orders)
    pos = np.broadcast_to(np.arange(i, dtype=np.int32), (u, i))
    for m, s in enumerate(mats):
        o = np.argsort(-s, axis=1, kind="stable").astype(np.int32)
        orders[m] = o
        np.put_along_axis(ranks[m], o, pos, axis=1)
    return RankedScores(names, orders, ranks)


def chain_plan(chains: ActionChainSet, slot: dict, *, expose: int,
               n_items: int) -> tuple[np.ndarray, np.ndarray]:
    """Compile the chain set against a RankedScores slot map.

    Returns (model_slots (J, K) int32, keeps (J, K) int32): stage k of
    chain j scores with model ``model_slots[j, k]`` and keeps the first
    ``keeps[j, k]`` survivors of its ordering.  keeps[:, 0] folds the
    stage-0 scale n_1 in (top-n1 then top-n2 by the same score is
    top-min(n1, n2)); the last stage keeps ``expose``.
    """
    j_n, k_n = chains.chain_idx.shape[:2]
    slots = np.zeros((j_n, k_n), np.int32)
    keeps = np.zeros((j_n, k_n), np.int32)
    for j in range(j_n):
        for k in range(k_n):
            mi = int(chains.chain_idx[j, k, 0])
            slots[j, k] = slot[chains.stages[k].models[mi].name]
            if k < k_n - 1:
                keeps[j, k] = int(chains.scale_value[j, k + 1])
            else:
                keeps[j, k] = expose
        keeps[j, 0] = min(keeps[j, 0], int(chains.scale_value[j, 0]),
                          n_items)
    return slots, keeps


def _k3_layout(chains: ActionChainSet, *, n_items: int):
    """Compile the chain set for the specialized 3-stage kernel, or None.

    Applicable when recall and prerank have single-model pools (the paper
    layout); the rank stage may pool any number of models.  Chains are
    grouped by their (rank model, effective n2) pair: members of a group
    share the whole stage-0/1 rank arithmetic and differ only in the n3
    threshold, so the group structure is STATIC in the jitted kernel (no
    per-chain dynamic slicing - the XLA:CPU killer).
    """
    if chains.n_stages != 3:
        return None
    if chains.stages[0].n_models != 1 or chains.stages[1].n_models != 1:
        return None
    keep0 = np.minimum(chains.scale_value[:, 1],
                       np.minimum(chains.scale_value[:, 0],
                                  n_items)).astype(np.int64)
    n2_vals, n2_idx = np.unique(keep0, return_inverse=True)
    m_idx = chains.chain_idx[:, 2, 0].astype(np.int64)
    n3 = chains.scale_value[:, 2].astype(np.int64)
    groups = {}
    for j in range(chains.n_chains):
        groups.setdefault((int(m_idx[j]), int(n2_idx[j])), []).append(j)
    group_key = tuple(  # one (rank_model, n2, (n3, ...)) tuple per group
        (mi, int(n2_vals[n2i]), tuple(int(n3[j]) for j in js))
        for (mi, n2i), js in sorted(groups.items()))
    chain_order = np.asarray(
        [j for _, js in sorted(groups.items()) for j in js], np.int64)
    return {
        "group_key": group_key,
        "chain_order": chain_order,  # kernel row -> chain id
        "stage_names": (chains.stages[0].models[0].name,
                        chains.stages[1].models[0].name,
                        tuple(m.name for m in chains.stages[2].models)),
    }


# ---------------------------------------------------------------------------
# NumPy reference (the oracle the vectorized kernel is tested against)
# ---------------------------------------------------------------------------


def _truncate_np(surv: np.ndarray, order: np.ndarray, rank: np.ndarray,
                 keep: int) -> np.ndarray:
    """Keep the first ``keep`` survivors along ``order`` (one stage)."""
    so = np.take_along_axis(surv, order, axis=1)
    q = np.cumsum(so, axis=1) - so  # exclusive: survivors strictly before
    so &= q < keep
    return np.take_along_axis(so, rank, axis=1)


def run_chain(stage_scores: dict, chain_desc: tuple, clicks: np.ndarray,
              *, expose: int = 20) -> np.ndarray:
    """One chain for all users - NumPy reference implementation.

    chain_desc = (n1, n2, n3, rank_model_name); clicks (U, I) ground truth.
    Returns per-user revenue@expose (clicks among exposed items).

    Semantics (shared with the vectorized engine): each stage keeps the
    first ``keep`` surviving items along the stage model's descending
    stable order (ties by item id); keeps are (min(n1, n2), n3, expose).
    """
    n1, n2, n3, rank_name = chain_desc
    i = clicks.shape[1]
    surv = np.ones(clicks.shape, bool)
    pos = np.broadcast_to(np.arange(i, dtype=np.int32), clicks.shape)
    for name, keep in (("DSSM", min(int(n1), int(n2))), ("YDNN", int(n3)),
                       (rank_name, int(expose))):
        order = np.argsort(-np.asarray(stage_scores[name]), axis=1,
                           kind="stable").astype(np.int32)
        rank = np.empty_like(order)
        np.put_along_axis(rank, order, pos, axis=1)
        surv = _truncate_np(surv, order, rank, keep)
    return (surv * clicks).sum(axis=1).astype(np.float32)


def simulate_revenue_matrix_reference(stage_scores: dict,
                                      chains: ActionChainSet,
                                      clicks: np.ndarray, *,
                                      expose: int = 20) -> np.ndarray:
    """Per-chain loop over ``run_chain`` - the brute-force oracle."""
    u = clicks.shape[0]
    out = np.zeros((u, chains.n_chains), np.float32)
    k_rank = chains.n_stages - 1
    for j in range(chains.n_chains):
        n1 = int(chains.scale_value[j, 0])
        n2 = int(chains.scale_value[j, 1])
        n3 = int(chains.scale_value[j, 2])
        mi = int(chains.chain_idx[j, k_rank, 0])
        rank_name = chains.stages[k_rank].models[mi].name
        out[:, j] = run_chain(stage_scores, (n1, n2, n3, rank_name), clicks,
                              expose=expose)
    return out


# ---------------------------------------------------------------------------
# Vectorized jitted kernels
# ---------------------------------------------------------------------------
#
# Two paths:
#   * `_revenue_matrix_k3` - the paper cascade layout (3 stages, single
#     recall/prerank models, a rank-stage model pool).  All (U, I) gathers
#     are hoisted OUT of the per-chain loop: survivor counts are
#     precomputed per DISTINCT n2 threshold (a handful) and per rank
#     model, so one chain costs compares + one cumsum + one masked sum -
#     XLA:CPU gathers are what made the naive per-chain loop slow.
#   * `_revenue_all_chains` - generic K-stage fallback (any pool layout).


def _desc_perm(scores: np.ndarray, ids: np.ndarray) -> np.ndarray:
    """Indirect sort of the last axis by (-score, id) - the restriction of
    the global stable descending order to an arbitrary candidate list.

    float32 scores take a single-key path: an order-preserving bit trick
    packs (score, id) into one int64 so one stable argsort replaces the
    two indirect sorts of np.lexsort (ids must be < 2^31, scores finite
    or -inf).  Other dtypes fall back to np.lexsort.
    """
    if scores.dtype == np.float32:
        # gf: allow[GF006] host-NumPy path: the add executes eagerly
        # so -0.0 really becomes +0.0; only the jitted twin needs the
        # where form (_desc_perm_jax uses it)
        s = scores + 0.0  # canonicalize -0.0 to +0.0
        b = s.view(np.int32)
        mono = b ^ ((b >> 31) & np.int32(0x7FFFFFFF))  # float order -> int
        key = ((~mono).astype(np.int64) << 32) + ids
        return np.argsort(key, axis=-1, kind="stable")
    return np.lexsort((ids, -scores), axis=-1)


def _compact_group_tables(stage_scores: dict, lay: dict, clicks: np.ndarray,
                          *, order1: np.ndarray | None = None,
                          expose: int):
    """Decision-independent compaction tables for the k3 layout.

    For each group g = (rank model, effective n2) and user u,
    ``p_sorted[g, u]`` lists, in the rank model's descending stable
    order over the group's compact candidate list, each entry's
    survivor-prefix position (sentinel ``cap`` for invalid tail slots)
    and ``clicks_sorted[g, u]`` the matching ground-truth clicks.  Every
    chain in the group is then pure threshold arithmetic on (U, cap)
    arrays - the shared precompute behind ``_simulate_k3_numpy``, the
    fused serving pipeline and the Pallas truncation kernel.
    Returns (p_sorted (G, U, cap), clicks_sorted (G, U, cap), cap).
    """
    m0, m1, mr = lay["stage_names"]
    u_n, i_n = clicks.shape
    gk = lay["group_key"]
    n2_list = sorted({g[1] for g in gk})
    n2_pos = {n2: k for k, n2 in enumerate(n2_list)}
    n2_max = n2_list[-1]
    cap = min(n2_max, max(max(g[2]) for g in gk))
    cdt = np.int16 if i_n < 2 ** 15 else np.int32  # count dtype
    qdt = np.int8 if max(cap, expose) < 127 else cdt  # survivor counts
    # flat-gather offsets in intp: M*U*I can exceed int32 at large worlds
    rows_off = (np.arange(u_n, dtype=np.intp) * i_n)[:, None]

    if order1 is None:
        order1 = np.argsort(-np.asarray(stage_scores[m0]), axis=1,
                            kind="stable")

    # candidate universe: the top-n2_max recall items, ordered by the
    # prerank model ((-score, id) == the global stable order restricted)
    cands = order1[:, :n2_max].astype(np.int32)  # (U, C); stage-0 rank = c
    sy = np.take(np.asarray(stage_scores[m1]).ravel(), cands + rows_off)
    yperm = _desc_perm(sy, cands)  # (U, C)
    l_items = np.take_along_axis(cands, yperm, axis=1)
    r1_l = yperm.astype(cdt)  # stage-0 rank of entry == pre-perm column

    # per distinct n2 (batched): compact the first-cap stage-1 survivors
    s1 = r1_l[None, :, :] < np.asarray(n2_list, cdt)[:, None, None]
    q2 = np.cumsum(s1, axis=2, dtype=cdt) - s1  # exclusive survivor count
    # q2 of the k-th survivor is exactly k -> it is the compact slot
    slot = np.where(s1 & (q2 < cap), q2, cdt(cap))
    scat = np.full((len(n2_list), u_n, cap + 1), n2_max, cdt)
    np.put_along_axis(
        scat, slot,
        np.broadcast_to(np.arange(n2_max, dtype=cdt), slot.shape), axis=2)
    lpos = scat[:, :, :cap]  # positions into the prerank-ordered list
    lvalid = lpos < n2_max
    lpos_c = np.minimum(lpos, cdt(n2_max - 1))

    # per group = (rank model, n2): order each compact list by the rank
    # model ((-score, id) again); invalid tail slots sink via -inf
    n2_of_g = np.asarray([n2_pos[n2] for _, n2, _ in gk], np.intp)
    m_of_g = np.asarray([mi for mi, _, _ in gk], np.intp)
    g_items = np.take_along_axis(l_items[None], lpos_c, axis=2)[n2_of_g]
    g_valid = lvalid[n2_of_g]
    # keep the native score dtype: a float64->float32 downcast could merge
    # scores that are distinct in float64 and change tie-breaking vs the
    # reference (exactness guarantee)
    scores_r = np.stack([np.asarray(stage_scores[n]) for n in mr])
    g_scores = np.take(scores_r.ravel(),
                       g_items + ((m_of_g * (u_n * i_n))[:, None, None]
                                  + rows_off[None]))
    g_scores[~g_valid] = -np.inf  # invalid tail slots sort last
    mperm = _desc_perm(g_scores, g_items)  # (G, U, cap)
    # survivor prefix-position of each entry (sentinel cap for invalid)
    p_sorted = np.where(np.take_along_axis(g_valid, mperm, axis=2),
                        mperm.astype(qdt), qdt(cap))
    g_clicks = np.take(clicks.ravel(), g_items + rows_off[None]) * g_valid
    clicks_sorted = np.take_along_axis(g_clicks, mperm, axis=2)
    return p_sorted, clicks_sorted, cap


def _desc_perm_jax(scores: jnp.ndarray, ids: jnp.ndarray) -> jnp.ndarray:
    """Device twin of ``_desc_perm`` for float32 scores: indirect sort of
    the last axis by (-score, id), BITWISE identical to the host order.

    The int64 bit-pack of the host path needs x64; instead the
    order-preserving int32 map of the float bits feeds a two-key
    ``lax.sort`` with the (unique) candidate ids as tiebreak - unique
    composite keys make the permutation a total order, so stability is
    irrelevant and the result matches the host stable argsort exactly.
    """
    # canonicalize -0.0 to +0.0 without an add (XLA may fold x + 0.0)
    s = jnp.where(scores == 0.0, jnp.float32(0.0), scores)
    b = jax.lax.bitcast_convert_type(s, jnp.int32)
    mono = b ^ ((b >> 31) & jnp.int32(0x7FFFFFFF))  # float order -> int
    iota = jnp.broadcast_to(
        jnp.arange(scores.shape[-1], dtype=jnp.int32), scores.shape)
    _, _, perm = jax.lax.sort(
        (~mono, ids.astype(jnp.int32), iota), dimension=-1, num_keys=2)
    return perm


def _compact_group_tables_jax(stage_scores: dict, lay: dict,
                              clicks: jnp.ndarray):
    """Jitted-traceable device twin of ``_compact_group_tables``.

    Same algorithm on jnp float32 score slabs: every step is row
    (user-axis) independent - per-row sorts, gathers and cumsums - so a
    padded scoring chunk can be compacted at the fixed chunk shape and
    sliced to the real rows afterwards.  Returns (p_sorted (G, U, cap)
    int32, clicks_sorted (G, U, cap) float32); values are BITWISE equal
    to the host builder (the parity gates in tests/test_request_source
    ride on it).  Scores must be float32 (the streaming stage models');
    other dtypes belong on the host path.
    """
    m0, m1, mr = lay["stage_names"]
    u_n, i_n = clicks.shape
    gk = lay["group_key"]
    n2_list = sorted({g[1] for g in gk})
    n2_pos = {n2: k for k, n2 in enumerate(n2_list)}
    n2_max = n2_list[-1]
    cap = min(n2_max, max(max(g[2]) for g in gk))

    s0 = stage_scores[m0]
    if s0.dtype != jnp.float32:
        raise ValueError("device table builder needs float32 scores")
    ids_full = jnp.broadcast_to(
        jnp.arange(i_n, dtype=jnp.int32), (u_n, i_n))
    cands = _desc_perm_jax(s0, ids_full)[:, :n2_max]  # (U, C)
    sy = jnp.take_along_axis(stage_scores[m1], cands, axis=1)
    yperm = _desc_perm_jax(sy, cands)  # (U, C)
    l_items = jnp.take_along_axis(cands, yperm, axis=1)

    # per distinct n2 (batched): compact the first-cap stage-1 survivors
    n2_arr = jnp.asarray(n2_list, jnp.int32)
    s1 = yperm[None, :, :] < n2_arr[:, None, None]
    s1_i = s1.astype(jnp.int32)
    q2 = jnp.cumsum(s1_i, axis=2) - s1_i  # exclusive survivor count
    slot = jnp.where(s1 & (q2 < cap), q2, jnp.int32(cap))
    k2 = len(n2_list)
    scat = jnp.full((k2, u_n, cap + 1), n2_max, jnp.int32)
    kk = jnp.arange(k2, dtype=jnp.int32)[:, None, None]
    uu = jnp.arange(u_n, dtype=jnp.int32)[None, :, None]
    vals = jnp.broadcast_to(jnp.arange(n2_max, dtype=jnp.int32),
                            slot.shape)
    # collisions only ever land on the dropped sentinel column ``cap``
    scat = scat.at[kk, uu, slot].set(vals, mode="drop")
    lpos = scat[:, :, :cap]
    lvalid = lpos < n2_max
    lpos_c = jnp.minimum(lpos, jnp.int32(n2_max - 1))

    # per group = (rank model, n2): rank-model (-score, id) order
    n2_of_g = np.asarray([n2_pos[n2] for _, n2, _ in gk], np.intp)
    m_of_g = np.asarray([mi for mi, _, _ in gk], np.intp)
    l_items_b = jnp.broadcast_to(l_items[None], (k2, u_n, n2_max))
    g_items = jnp.take_along_axis(l_items_b, lpos_c, axis=2)[n2_of_g]
    g_valid = lvalid[n2_of_g]
    scores_r = jnp.stack([stage_scores[nm] for nm in mr])[m_of_g]
    g_scores = jnp.take_along_axis(scores_r, g_items, axis=2)
    g_scores = jnp.where(g_valid, g_scores, -jnp.inf)
    mperm = _desc_perm_jax(g_scores, g_items)  # (G, U, cap)
    p_sorted = jnp.where(jnp.take_along_axis(g_valid, mperm, axis=2),
                         mperm, jnp.int32(cap))
    g_n = len(gk)
    clicks_b = jnp.broadcast_to(clicks[None], (g_n, u_n, i_n))
    g_clicks = jnp.take_along_axis(clicks_b, g_items, axis=2) * g_valid
    clicks_sorted = jnp.take_along_axis(g_clicks, mperm, axis=2)
    return p_sorted, clicks_sorted.astype(jnp.float32)


def _simulate_k3_numpy(stage_scores: dict, lay: dict, clicks: np.ndarray,
                       *, expose: int,
                       order1: np.ndarray | None = None) -> np.ndarray:
    """Compaction-based CPU path for the paper cascade layout -> (U, J).

    Two structural facts make the sweep nearly independent of both the
    corpus size and the chain count after ONE full argsort:

    * only the recall stage needs a global ordering - every later stage
      only ranks candidates RELATIVE to each other, so ordering the
      compact candidate lists by (-score, item_id) lexsort reproduces
      the global stable order restricted to the list, exactly;
    * the stage-1 survivor list for threshold n3 is a PREFIX of the list
      for any larger n3 (both walk the same prerank order), so one
      compact list of length cap = max(n3) per distinct n2 serves every
      chain, and all chain arithmetic runs on (U, cap) arrays.
    """
    gk = lay["group_key"]
    g_n = len(gk)
    p_sorted, clicks_sorted, cap = _compact_group_tables(
        stage_scores, lay, clicks, order1=order1, expose=expose)
    qdt = p_sorted.dtype

    # all chains batched: chain n3 keeps prefix positions < n3; exposure
    # is the first `expose` of those in rank-model order
    k_max = max(len(g[2]) for g in gk)
    n3_pad = np.zeros((g_n, k_max), qdt)
    for g, (_, _, n3list) in enumerate(gk):
        n3_pad[g, :len(n3list)] = [min(n, cap) for n in n3list]
    mask = p_sorted[:, None, :, :] < n3_pad[:, :, None, None]
    q3 = np.cumsum(mask, axis=3, dtype=qdt)  # inclusive survivor count
    mask &= q3 <= expose  # exposed: among the first `expose` survivors
    rev = np.einsum("gkuc,guc->gku", mask, clicks_sorted)
    rows = [rev[g, :len(n3list)]
            for g, (_, _, n3list) in enumerate(gk)]
    return np.concatenate(rows, axis=0)  # (J, U) in group order


@partial(jax.jit, static_argnames=("n_stages",))
def _revenue_all_chains(orders, ranks, clicks, slots, keeps, *, n_stages):
    """(U, J) revenue matrix in one lax.scan over chains.

    orders/ranks (M, U, I) int32; clicks (U, I) f32; slots/keeps (J, K).
    Each scan step is fully vectorized over users; memory stays O(U*I).
    """

    def one_chain(_, jparams):
        slot, keep = jparams  # (K,), (K,)
        surv = jnp.ones(clicks.shape, jnp.bool_)
        for k in range(n_stages):
            o = orders[slot[k]]
            r = ranks[slot[k]]
            so = jnp.take_along_axis(surv, o, axis=1)
            q = jnp.cumsum(so.astype(jnp.int32), axis=1) - so
            so = so & (q < keep[k])
            surv = jnp.take_along_axis(so, r, axis=1)
        return _, jnp.sum(jnp.where(surv, clicks, 0.0), axis=1)

    _, rev = jax.lax.scan(one_chain, 0, (slots, keeps))
    return rev.T  # (U, J)


@partial(jax.jit, static_argnames=("n_stages",))
def _revenue_requests(orders, ranks, clicks, slots, keeps, rows, *,
                      n_stages):
    """Per-request revenue: request b = (user rows[b], chain slots/keeps[b]).

    One batched pass over all requests - chains need not be grouped.
    """

    def one(row, slot, keep):
        surv = jnp.ones((clicks.shape[1],), jnp.bool_)
        for k in range(n_stages):
            o = orders[slot[k], row]
            r = ranks[slot[k], row]
            so = jnp.take(surv, o)
            q = jnp.cumsum(so.astype(jnp.int32)) - so
            so = so & (q < keep[k])
            surv = jnp.take(so, r)
        return jnp.sum(jnp.where(surv, clicks[row], 0.0))

    return jax.vmap(one)(rows, slots, keeps)


@dataclass
class CompactPlan:
    """Decision-independent serving tables for the k3 cascade layout.

    Per request the whole cascade collapses to threshold arithmetic on a
    (cap,)-wide row: gather ``p_sorted[group, user]`` (survivor-prefix
    positions in rank-model order) and ``clicks_sorted[group, user]``,
    keep positions < n3, expose the first ``expose`` survivors.  Built
    once at server start; the jitted ``_revenue_compact`` (XLA) and the
    Pallas truncation kernel (TPU) both execute it.
    """

    p_sorted: np.ndarray  # (G, U, cap) int32, sentinel cap = invalid
    clicks_sorted: np.ndarray  # (G, U, cap) float32
    group_of_chain: np.ndarray  # (J,) int32
    n3_of_chain: np.ndarray  # (J,) int32, min(n3, cap)
    cap: int
    expose: int


def _layout_cap(gk: tuple) -> int:
    """Compact-row width for a k3 group key: min(max n2, max n3) - the
    same bound ``_compact_group_tables`` derives, exposed so per-window
    chunk tables and the full-universe tables agree on shape."""
    n2_max = max(g[1] for g in gk)
    return min(n2_max, max(max(g[2]) for g in gk))


def _layout_chain_maps(lay: dict, n_chains: int,
                       cap: int) -> tuple[np.ndarray, np.ndarray]:
    """(group_of_chain, n3_of_chain) int32 vectors from a k3 layout."""
    g_of = np.empty(n_chains, np.int32)
    n3_of = np.empty(n_chains, np.int32)
    pos = 0
    for g, (_, _, n3list) in enumerate(lay["group_key"]):
        for n3 in n3list:
            j = int(lay["chain_order"][pos])
            g_of[j] = g
            n3_of[j] = min(int(n3), cap)
            pos += 1
    return g_of, n3_of


def build_compact_layout(chains: ActionChainSet, *, n_items: int,
                         expose: int) -> CompactPlan | None:
    """The USER-INDEPENDENT part of a CompactPlan (or None off the k3
    layout): group/threshold maps and the row width ``cap``, with EMPTY
    per-user tables.  This is what a streaming ``RequestSource`` serves
    against - each window brings its own (G, n, cap) chunk tables while
    the chain->group arithmetic stays fixed."""
    lay = _k3_layout(chains, n_items=n_items)
    if lay is None:
        return None
    cap = _layout_cap(lay["group_key"])
    g_of, n3_of = _layout_chain_maps(lay, chains.n_chains, cap)
    g_n = len(lay["group_key"])
    return CompactPlan(np.full((g_n, 1, cap), cap, np.int32),
                       np.zeros((g_n, 1, cap), np.float32), g_of, n3_of,
                       int(cap), int(expose))


def build_compact_plan(stage_scores: dict, chains: ActionChainSet,
                       clicks: np.ndarray, *,
                       expose: int) -> CompactPlan | None:
    """CompactPlan for the serving universe, or None off the k3 layout."""
    lay = _k3_layout(chains, n_items=clicks.shape[1])
    if lay is None:
        return None
    p_sorted, clicks_sorted, cap = _compact_group_tables(
        stage_scores, lay, np.asarray(clicks, np.float32), expose=expose)
    g_of, n3_of = _layout_chain_maps(lay, chains.n_chains, cap)
    return CompactPlan(p_sorted.astype(np.int32),
                       clicks_sorted.astype(np.float32), g_of, n3_of,
                       int(cap), int(expose))


@partial(jax.jit, static_argnames=("expose",))
def _revenue_compact(p_sorted, clicks_sorted, groups, rows, n3, *, expose):
    """Per-request revenue on CompactPlan tables (XLA path).

    groups/rows/n3: (B,) int32 - request b reads row (groups[b], rows[b])
    and keeps survivor positions < n3[b], exposing the first `expose`.
    """
    p = p_sorted[groups, rows]  # (B, cap)
    ck = clicks_sorted[groups, rows]
    m = p < n3[:, None]
    q3 = jnp.cumsum(m.astype(jnp.int32), axis=1)  # inclusive
    m = m & (q3 <= expose)
    return jnp.sum(jnp.where(m, ck, 0.0), axis=1)


def simulate_revenue_matrix(stage_scores: dict, chains: ActionChainSet,
                            clicks: np.ndarray, *, expose: int = 20,
                            ranked: RankedScores | None = None) -> np.ndarray:
    """Ground-truth revenue of EVERY chain for every user -> (U, J).

    This is the paper's training-sample generation for the reward model
    (and the oracle for evaluating allocations).  Rank-based vectorized
    path; matches ``simulate_revenue_matrix_reference`` exactly.
    """
    lay = _k3_layout(chains, n_items=clicks.shape[1])
    if lay is not None:  # paper cascade layout: compaction fast path
        order1 = (ranked.orders[ranked.slot[lay["stage_names"][0]]]
                  if ranked is not None else None)
        clicks32 = np.asarray(clicks, np.float32)
        u_n = clicks.shape[0]
        # every step is independent per user: shard the user axis across
        # cores (numpy releases the GIL in sorts/ufuncs/gathers)
        n_w = max(1, min(os.cpu_count() or 1, u_n // 64))
        if n_w > 1:
            # a whole multiple of the worker count keeps rounds balanced;
            # 2x oversharding (when shards stay >=64 users) lets a free
            # worker pick up slack if a core is stolen mid-call
            n_shards = n_w * (2 if u_n // (2 * n_w) >= 64 else 1)
            bounds = np.linspace(0, u_n, n_shards + 1).astype(int)
            parts = list(_shared_pool(n_w).map(
                lambda b: _simulate_k3_numpy(
                    {k: v[b[0]:b[1]] for k, v in stage_scores.items()},
                    lay, clicks32[b[0]:b[1]], expose=expose,
                    order1=(order1[b[0]:b[1]]
                            if order1 is not None else None)),
                zip(bounds[:-1], bounds[1:])))
            grouped = np.concatenate(parts, axis=1)
        else:
            grouped = _simulate_k3_numpy(stage_scores, lay, clicks32,
                                         expose=expose, order1=order1)
        out = np.empty((u_n, chains.n_chains), np.float32)
        out[:, lay["chain_order"]] = grouped.T
        return out
    ranked = ranked or rank_stage_scores(stage_scores)
    slots, keeps = chain_plan(chains, ranked.slot, expose=expose,
                              n_items=clicks.shape[1])
    rev = _revenue_all_chains(
        jnp.asarray(ranked.orders), jnp.asarray(ranked.ranks),
        jnp.asarray(clicks, jnp.float32), jnp.asarray(slots),
        jnp.asarray(keeps), n_stages=chains.n_stages)
    return np.asarray(rev)


@dataclass
class CascadeServer:
    """Online path: execute allocated chains for a request batch.

    The same rank-based kernel as offline simulation, vmapped over
    requests: per-request chain ids go straight into one jitted pass
    (the seed grouped requests by chain and re-ran NumPy top-k per
    group).  On accelerator backends the k3 layout additionally runs
    through the Pallas gather+cumsum truncation kernel on CompactPlan
    tables (``use_pallas``); the lax.scan ``_revenue_requests`` path is
    the CPU / interpret-mode fallback and the parity oracle."""

    stage_scores: dict  # precomputed for the serving user universe
    chains: ActionChainSet
    clicks: np.ndarray
    expose: int = 20
    use_pallas: bool | None = None  # None: auto (accelerator backends)

    def __post_init__(self):
        self._ranked = rank_stage_scores(self.stage_scores)
        self._slots, self._keeps = chain_plan(
            self.chains, self._ranked.slot, expose=self.expose,
            n_items=self.clicks.shape[1])
        self._orders = jnp.asarray(self._ranked.orders)
        self._ranks = jnp.asarray(self._ranked.ranks)
        self._clicks = jnp.asarray(self.clicks, jnp.float32)
        self.compact = build_compact_plan(
            self.stage_scores, self.chains, self.clicks, expose=self.expose)
        if self.use_pallas is None:
            self.use_pallas = jax.default_backend() != "cpu"
        self._pallas_tables = None

    def serve(self, user_rows: np.ndarray, decisions: np.ndarray,
              *, interpret: bool | None = None):
        """user_rows: indices into the score matrices; decisions: (B,)
        chain ids.  Returns (revenue (B,), flops (B,)).

        interpret: None (default) lets ``use_pallas`` pick the path;
        True forces the Pallas kernel under the interpreter (CPU
        parity tests); False forces the lax.scan fallback.
        """
        decisions = np.asarray(decisions, np.int32)
        rows = np.asarray(user_rows, np.int32)
        pallas = (self.use_pallas if interpret is None
                  else interpret) and self.compact is not None
        if pallas:
            from repro.kernels import ops
            if self._pallas_tables is None:
                self._pallas_tables = (
                    jnp.asarray(self.compact.p_sorted),
                    jnp.asarray(self.compact.clicks_sorted))
            p_tab, c_tab = self._pallas_tables
            rev = ops.cascade_truncate(
                p_tab, c_tab,
                jnp.asarray(self.compact.group_of_chain[decisions]),
                jnp.asarray(rows),
                jnp.asarray(self.compact.n3_of_chain[decisions]),
                expose=self.compact.expose,
                **({} if interpret is None else {"interpret": True}))
        else:
            rev = _revenue_requests(
                self._orders, self._ranks, self._clicks,
                jnp.asarray(self._slots[decisions]),
                jnp.asarray(self._keeps[decisions]),
                jnp.asarray(rows),
                n_stages=self.chains.n_stages)
        flops = self.chains.costs[decisions]
        return np.asarray(rev), flops
