"""Cascade execution engine (paper §5.1 protocol).

Offline protocol: per evaluation user, every stage model scores the whole
corpus ONCE (jitted, batched); evaluating an action chain is then pure
ranking arithmetic over precomputed score vectors - exactly the paper's
"simulate different action chains for each user" procedure, and it makes
the J=128-chain sweep cheap.

Online serving (`CascadeServer`): requests are grouped by allocated chain
and each group executes the (statically-shaped) bucketed pipeline - the
TPU-idiomatic form of per-request item scales (DESIGN.md §3).

Scoring truncated candidate sets uses TOP-K SELECTION ON SCORES from the
upstream stage; clicks are ground-truth sampled once per (user, item) so
revenue@e is deterministic given the seed.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.action_chain import ActionChainSet
from repro.models.recsys import dien, din, dssm, ydnn


@dataclass
class CascadeModels:
    """Trained stage models + their configs."""

    dssm_params: dict
    dssm_cfg: dssm.DSSMConfig
    ydnn_params: dict
    ydnn_cfg: ydnn.YDNNConfig
    din_params: dict
    din_cfg: din.DINConfig
    dien_params: dict
    dien_cfg: dien.DIENConfig


def _user_batch(world, users: np.ndarray) -> dict:
    return {
        "user_fields": jnp.asarray(world.user_fields[users], jnp.int32),
        "hist_ids": jnp.asarray(world.hist_ids[users], jnp.int32),
        "hist_cats": jnp.asarray(world.item_cat[world.hist_ids[users]],
                                 jnp.int32),
        "hist_mask": jnp.asarray(world.hist_mask[users], jnp.float32),
    }


def precompute_stage_scores(models: CascadeModels, world, users: np.ndarray,
                            *, item_block: int = 256) -> dict:
    """Score the full corpus with every stage model -> {name: (U, I)}."""
    n_items = world.cfg.n_items
    item_ids = jnp.arange(n_items, dtype=jnp.int32)
    item_cats = jnp.asarray(world.item_cat, jnp.int32)
    ub = _user_batch(world, users)

    # user fields for the recall/prerank towers use the raw field ids
    dssm_item_fields = jnp.stack([item_ids, item_cats], axis=-1)  # (I, 2)

    @jax.jit
    def dssm_all(uf):
        v = dssm.item_tower(models.dssm_params, models.dssm_cfg,
                            dssm_item_fields)
        u = dssm.user_tower(models.dssm_params, models.dssm_cfg, uf)
        return u @ v.T

    @jax.jit
    def ydnn_all(hist, mask, uf):
        u = ydnn.user_vector(models.ydnn_params, models.ydnn_cfg, hist, mask,
                             uf)
        v = models.ydnn_params["out_emb"]["table"][:n_items]
        return u @ v.T

    scores = {
        "DSSM": np.asarray(dssm_all(ub["user_fields"])),
        "YDNN": np.asarray(ydnn_all(ub["hist_ids"], ub["hist_mask"],
                                    ub["user_fields"])),
    }

    @jax.jit
    def din_block(batch, cand_ids, cand_cats):
        return din.score(models.din_params, models.din_cfg, batch,
                         cand_ids, cand_cats)

    @jax.jit
    def dien_block(batch, cand_ids, cand_cats):
        return dien.score(models.dien_params, models.dien_cfg, batch,
                          cand_ids, cand_cats)

    for name, fn in (("DIN", din_block), ("DIEN", dien_block)):
        rows = []
        for lo in range(0, n_items, item_block):
            hi = min(n_items, lo + item_block)
            ids = jnp.broadcast_to(item_ids[lo:hi], (len(users), hi - lo))
            cats = jnp.broadcast_to(item_cats[lo:hi], (len(users), hi - lo))
            rows.append(np.asarray(fn(ub, ids, cats)))
        scores[name] = np.concatenate(rows, axis=1)
    return scores


def run_chain(stage_scores: dict, chain_desc: tuple, clicks: np.ndarray,
              *, expose: int = 20) -> np.ndarray:
    """One chain for all users.

    chain_desc = (n1, n2, n3, rank_model_name); clicks (U, I) ground truth.
    Returns per-user revenue@expose (clicks among exposed items).
    """
    n1, n2, n3, rank_name = chain_desc
    u = clicks.shape[0]
    s1 = stage_scores["DSSM"]
    # stage 1 keeps top-n2 (it scored n1 = corpus)
    keep2 = np.argpartition(-s1, kth=min(n2, s1.shape[1] - 1), axis=1)[:, :n2]
    s2 = np.take_along_axis(stage_scores["YDNN"], keep2, axis=1)
    # stage 2 keeps top-n3 of its n2
    k3 = min(n3, n2)
    idx3 = np.argpartition(-s2, kth=min(k3, s2.shape[1] - 1) - 1,
                           axis=1)[:, :k3]
    keep3 = np.take_along_axis(keep2, idx3, axis=1)
    s3 = np.take_along_axis(stage_scores[rank_name], keep3, axis=1)
    # final exposure: top-`expose` of the n3
    e = min(expose, k3)
    idx_e = np.argsort(-s3, axis=1)[:, :e]
    exposed = np.take_along_axis(keep3, idx_e, axis=1)
    return np.take_along_axis(clicks, exposed, axis=1).sum(axis=1)


def simulate_revenue_matrix(stage_scores: dict, chains: ActionChainSet,
                            clicks: np.ndarray, *, expose: int = 20):
    """Ground-truth revenue of EVERY chain for every user -> (U, J).

    This is the paper's training-sample generation for the reward model
    (and the oracle for evaluating allocations)."""
    u = clicks.shape[0]
    out = np.zeros((u, chains.n_chains), np.float32)
    k_rank = chains.n_stages - 1
    for j in range(chains.n_chains):
        n1 = int(chains.scale_value[j, 0])
        n2 = int(chains.scale_value[j, 1])
        n3 = int(chains.scale_value[j, 2])
        mi = int(chains.chain_idx[j, k_rank, 0])
        rank_name = chains.stages[k_rank].models[mi].name
        out[:, j] = run_chain(stage_scores, (n1, n2, n3, rank_name), clicks,
                              expose=expose)
    return out


@dataclass
class CascadeServer:
    """Online path: execute allocated chains, grouped by chain id."""

    stage_scores: dict  # precomputed for the serving user universe
    chains: ActionChainSet
    clicks: np.ndarray
    expose: int = 20

    def serve(self, user_rows: np.ndarray, decisions: np.ndarray):
        """user_rows: indices into the score matrices; decisions: (B,)
        chain ids.  Returns (revenue (B,), flops (B,))."""
        revenue = np.zeros(len(user_rows), np.float32)
        k_rank = self.chains.n_stages - 1
        for j in np.unique(decisions):
            sel = decisions == j
            rows = user_rows[sel]
            n1 = int(self.chains.scale_value[j, 0])
            n2 = int(self.chains.scale_value[j, 1])
            n3 = int(self.chains.scale_value[j, 2])
            mi = int(self.chains.chain_idx[j, k_rank, 0])
            rank_name = self.chains.stages[k_rank].models[mi].name
            sub_scores = {k: v[rows] for k, v in self.stage_scores.items()}
            revenue[sel] = run_chain(sub_scores, (n1, n2, n3, rank_name),
                                     self.clicks[rows], expose=self.expose)
        flops = self.chains.costs[decisions]
        return revenue, flops
