"""Cascade serving engine: recall -> prerank -> rank with action chains."""
from repro.cascade.engine import (CascadeModels, CascadeServer,
                                  precompute_stage_scores, run_chain,
                                  simulate_revenue_matrix)

__all__ = ["CascadeModels", "CascadeServer", "precompute_stage_scores",
           "run_chain", "simulate_revenue_matrix"]
