"""Per-window operational-carbon ledger for serving runs.

The seed converted total FLOPs to carbon once, after the run, at a
single grid intensity (``core.pfec``, CI=615).  The ledger instead
meters every serving window as it lands:

    kwh_t   = energy_from_flops(flops_t)          # Eq. 1
    gco2e_t = kwh_t * CI(t)                       # Eq. 2, CI time-varying

with the realized FLOPs attributed per cascade stage (recall / prerank
/ rank) and per model variant (DSSM / YDNN / DIN / DIEN), and a running
all-max-chain baseline (every request on the most expensive chain -
what a cascade without GreenFlow allocation would burn) so the daily
report states the repro's version of the paper's "saves ~5000 kWh and
3 tCO2e per day" headline.

Windows recorded through :meth:`CarbonLedger.record_result` (the
``ServingPipeline`` hook) are metered LAZILY: the ledger parks the
``WindowResult`` and only reads its device arrays when a report is
requested, so metering never blocks the double-buffered stream.

EMBODIED carbon: the hardware's manufacturing footprint amortized over
its service life (the ichnos ``EmbodiedCarbon.py`` model - a constant
gCO2e per device-hour) accrues per window as
``embodied_g_per_device_h * n_devices * window_s / 3600`` regardless of
load, so reports and the CSV carry operational AND total footprints -
a serving day is never under-reported as operational-only.  The default
constant amortizes a ~1.3 tCO2e server manufacture over a 4-year life.

Geo serving keeps ONE ledger PER REGION (each metered at its region's
CI trace); ``geo_report_csv`` merges them into a single CSV with a
leading ``region`` column - the per-region attribution artifact.
"""
from __future__ import annotations

import os
from dataclasses import dataclass, field

import numpy as np

from repro.carbon.intensity import IntensityTrace
from repro.core.action_chain import ActionChainSet
from repro.core.pfec import EnergyConfig, energy_from_flops

DAY_S = 86400.0

# ichnos EmbodiedCarbon-style amortization constant: ~1.3 tCO2e server
# manufacture / (4 y * 365 d * 24 h) ~= 37 g per device-hour
DEFAULT_EMBODIED_G_PER_DEVICE_H = 37.0


@dataclass(frozen=True)
class WindowCarbonEntry:
    """One metered serving window (all energies kWh, all carbon gCO2e)."""

    window: int
    ci_g_per_kwh: float
    n_requests: int
    flops: float
    kwh: float
    gco2e: float
    baseline_flops: float  # all-max-chain counterfactual
    baseline_kwh: float
    baseline_gco2e: float
    embodied_gco2e: float = 0.0  # amortized manufacture, load-independent
    stage_flops: dict[str, float] = field(default_factory=dict)
    model_flops: dict[str, float] = field(default_factory=dict)

    @property
    def total_gco2e(self) -> float:
        """Operational + embodied footprint of the window."""
        return self.gco2e + self.embodied_gco2e


class CarbonLedger:
    """Meters realized per-window FLOPs into kWh / gCO2e at CI(t).

    Parameters
    ----------
    chains: the serving chain set; its per-stage (model, scale) structure
        drives the FLOPs attribution tables.
    trace: grid intensity; window t reads the trace mean over
        ``[phase_s + t*window_s, phase_s + (t+1)*window_s)``.
    cfg: Eq. 1 energy constants (default: fresh ``EnergyConfig``).
    window_s: serving-window length in seconds (sets the windows-per-day
        extrapolation of the daily report).
    embodied_g_per_device_h / n_devices: amortized embodied carbon
        accrued per window (0.0 disables the line; pass
        ``DEFAULT_EMBODIED_G_PER_DEVICE_H`` for the ichnos-style server
        constant).
    name: label used by multi-ledger (per-region) reports.
    """

    def __init__(self, chains: ActionChainSet, trace: IntensityTrace, *,
                 cfg: EnergyConfig | None = None, window_s: float = 3600.0,
                 phase_s: float = 0.0,
                 embodied_g_per_device_h: float = 0.0, n_devices: int = 1,
                 name: str = "serving", obs=None):
        from repro.obs import get_obs
        self.obs = get_obs(obs)
        self.chains = chains
        self.trace = trace
        self.cfg = cfg or EnergyConfig()
        self.window_s = float(window_s)
        self.phase_s = float(phase_s)
        self.embodied_g_per_device_h = float(embodied_g_per_device_h)
        self.n_devices = int(n_devices)
        self.name = name
        self._entries: list[WindowCarbonEntry] = []
        self._pending: list = []  # WindowResults awaiting metering

        # attribution tables: stage_table (J, K) FLOPs of chain j's stage
        # k; model_table (J, M) the same FLOPs bucketed by model variant
        j_n, k_n = chains.chain_idx.shape[:2]
        self.stage_names = [st.name for st in chains.stages]
        names: list[str] = []
        for st in chains.stages:
            for m in st.models:
                if m.name not in names:
                    names.append(m.name)
        self.model_names = names
        self._stage_table = np.zeros((j_n, k_n), np.float64)
        self._model_table = np.zeros((j_n, len(names)), np.float64)
        for j in range(j_n):
            for k, st in enumerate(chains.stages):
                mi, si = chains.chain_idx[j, k]
                m = st.models[mi]
                f = m.fixed_flops + m.flops_per_item * st.item_scales[si]
                self._stage_table[j, k] = f
                self._model_table[j, names.index(m.name)] += f
        self._max_cost = float(chains.costs.max())

        # metered-total mirrors (labeled per ledger, e.g. per region)
        m = self.obs.metrics
        self._windows_c = m.counter(
            "greenflow_ledger_windows_total",
            "windows metered by the carbon ledger").labels(name=name)
        self._flops_c = m.counter(
            "greenflow_flops_total",
            "realized FLOPs metered", "FLOPs").labels(name=name)
        self._kwh_c = m.counter(
            "greenflow_energy_kwh_total",
            "operational energy metered (Eq. 1)", "kWh").labels(name=name)
        self._gco2e_c = m.counter(
            "greenflow_gco2e_total",
            "operational carbon metered (Eq. 2)", "g").labels(name=name)

    # -- recording ----------------------------------------------------------

    def window_ci(self, t: int) -> float:
        """CI (g/kWh) seen by window ``t``."""
        return self.trace.window_mean(self.phase_s + t * self.window_s,
                                      self.window_s)

    def record(self, decisions: np.ndarray, *, t: int | None = None,
               ci: float | None = None) -> WindowCarbonEntry:
        """Meter one window's realized decisions (valid requests only)."""
        # drain parked WindowResults first so this window's inferred index
        # lands after them (mixing record_result and record stays ordered)
        self._drain()
        dec = np.asarray(decisions).astype(np.intp).reshape(-1)
        t = len(self._entries) if t is None else t
        ci = self.window_ci(t) if ci is None else float(ci)
        n = int(dec.size)
        counts = np.bincount(dec, minlength=self.chains.n_chains) \
            .astype(np.float64)
        flops = float(counts @ self.chains.costs)
        kwh = energy_from_flops(flops, self.cfg)
        base_flops = n * self._max_cost
        base_kwh = energy_from_flops(base_flops, self.cfg)
        per_stage = counts @ self._stage_table  # (K,)
        per_model = counts @ self._model_table  # (M,)
        embodied = (self.embodied_g_per_device_h * self.n_devices
                    * self.window_s / 3600.0)
        entry = WindowCarbonEntry(
            window=t, ci_g_per_kwh=ci, n_requests=n, flops=flops, kwh=kwh,
            gco2e=kwh * ci, baseline_flops=base_flops, baseline_kwh=base_kwh,
            baseline_gco2e=base_kwh * ci, embodied_gco2e=embodied,
            stage_flops={s: float(v)
                         for s, v in zip(self.stage_names, per_stage)},
            model_flops={m: float(v)
                         for m, v in zip(self.model_names, per_model)})
        self._entries.append(entry)
        self._windows_c.inc()
        self._flops_c.inc(flops)
        self._kwh_c.inc(kwh)
        self._gco2e_c.inc(kwh * ci)
        return entry

    def record_result(self, result) -> None:
        """ServingPipeline hook: park a ``WindowResult`` for lazy metering
        (reading its decision array would force a device sync mid-stream)."""
        self._pending.append(result)

    def _drain(self) -> None:
        pending, self._pending = self._pending, []
        if not pending:
            return
        # lazy metering: this is the only place ledger work reads device
        # arrays, and it runs at report time, never inside the stream
        with self.obs.span("ledger", windows=len(pending)):
            for res in pending:
                self.record(res.decisions_np)

    @property
    def entries(self) -> list[WindowCarbonEntry]:
        self._drain()
        return self._entries

    # -- reporting ----------------------------------------------------------

    def report(self) -> dict:
        """Cumulative + per-day-extrapolated totals and baseline savings.

        ``daily_*`` figures scale the recorded windows to a 24 h day
        (``86400 / window_s`` windows) - the repro-scale analogue of the
        paper's ~5000 kWh / ~3 tCO2e per day claim.
        """
        entries = self.entries
        if not entries:
            raise ValueError("carbon ledger is empty: no windows recorded")
        tot = {k: float(sum(getattr(e, k) for e in entries))
               for k in ("flops", "kwh", "gco2e", "baseline_flops",
                         "baseline_kwh", "baseline_gco2e",
                         "embodied_gco2e")}
        n_w = len(entries)
        day_factor = (DAY_S / self.window_s) / n_w
        saved_kwh = tot["baseline_kwh"] - tot["kwh"]
        saved_g = tot["baseline_gco2e"] - tot["gco2e"]
        stage = {s: float(sum(e.stage_flops.get(s, 0.0) for e in entries))
                 for s in self.stage_names}
        model = {m: float(sum(e.model_flops.get(m, 0.0) for e in entries))
                 for m in self.model_names}
        total_g = tot["gco2e"] + tot["embodied_gco2e"]
        return {
            "n_windows": n_w,
            "window_s": self.window_s,
            "n_requests": int(sum(e.n_requests for e in entries)),
            "mean_ci_g_per_kwh": float(np.mean(
                [e.ci_g_per_kwh for e in entries])),
            **tot,
            "total_gco2e": total_g,
            "saved_kwh": saved_kwh,
            "saved_gco2e": saved_g,
            "daily_kwh": tot["kwh"] * day_factor,
            "daily_gco2e": tot["gco2e"] * day_factor,
            "daily_embodied_gco2e": tot["embodied_gco2e"] * day_factor,
            "daily_total_gco2e": total_g * day_factor,
            "daily_saved_kwh": saved_kwh * day_factor,
            "daily_saved_gco2e": saved_g * day_factor,
            "daily_saved_tco2e": saved_g * day_factor / 1e6,
            "stage_flops": stage,
            "model_flops": model,
        }

    def _csv_columns(self) -> list[str]:
        cols = ["window", "ci_g_per_kwh", "n_requests", "flops", "kwh",
                "gco2e", "baseline_flops", "baseline_kwh", "baseline_gco2e",
                "saved_kwh", "saved_gco2e"]
        cols += [f"stage_{s}_flops" for s in self.stage_names]
        cols += [f"model_{m}_flops" for m in self.model_names]
        cols += ["embodied_gco2e", "total_gco2e"]
        return cols

    def _csv_rows(self) -> list[list]:
        rows = []
        for e in self.entries:
            row = [e.window, e.ci_g_per_kwh, e.n_requests, e.flops,
                   e.kwh, e.gco2e, e.baseline_flops, e.baseline_kwh,
                   e.baseline_gco2e, e.baseline_kwh - e.kwh,
                   e.baseline_gco2e - e.gco2e]
            row += [e.stage_flops[s] for s in self.stage_names]
            row += [e.model_flops[m] for m in self.model_names]
            row += [e.embodied_gco2e, e.total_gco2e]
            rows.append(row)
        r = self.report()
        row = ["TOTAL", r["mean_ci_g_per_kwh"], r["n_requests"],
               r["flops"], r["kwh"], r["gco2e"], r["baseline_flops"],
               r["baseline_kwh"], r["baseline_gco2e"], r["saved_kwh"],
               r["saved_gco2e"]]
        row += [r["stage_flops"][s] for s in self.stage_names]
        row += [r["model_flops"][m] for m in self.model_names]
        row += [r["embodied_gco2e"], r["total_gco2e"]]
        rows.append(row)
        return rows

    def to_csv(self, path: str) -> str:
        """Write per-window rows + a TOTAL row; returns the path."""
        os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
        with open(path, "w") as f:
            f.write(",".join(self._csv_columns()) + "\n")
            for row in self._csv_rows():
                f.write(",".join(_fmt(v) for v in row) + "\n")
        return path


def geo_report_csv(ledgers: dict[str, "CarbonLedger"], path: str) -> str:
    """Merge per-region ledgers into one CSV with a ``region`` column.

    ``ledgers`` maps region name -> that region's ledger (each metered
    at its own CI trace) - the per-region attribution artifact of a
    geo-shifted serving day.  Rows keep each ledger's windows + TOTAL.
    """
    if not ledgers:
        raise ValueError("geo_report_csv needs at least one ledger")
    first = next(iter(ledgers.values()))
    cols = ["region"] + first._csv_columns()
    os.makedirs(os.path.dirname(os.path.abspath(path)), exist_ok=True)
    with open(path, "w") as f:
        f.write(",".join(cols) + "\n")
        for name, led in ledgers.items():
            if led._csv_columns() != cols[1:]:
                raise ValueError(f"ledger {name!r} has a different "
                                 f"column layout")
            for row in led._csv_rows():
                f.write(",".join(_fmt(v) for v in [name] + row) + "\n")
    return path


def _fmt(v) -> str:
    if isinstance(v, str):
        return v
    if isinstance(v, (int, np.integer)):
        return str(int(v))
    return f"{float(v):.6g}"
