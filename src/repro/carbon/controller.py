"""Carbon-denominated budgets for the primal-dual allocation loop.

The paper's constraint (Eq. 3) is a FLOPs budget per window.  Here the
budget becomes **gCO2e per window** with time-varying effective chain
costs

    c_j(t) = flops_j * kappa * CI(t)        [gCO2e]

where ``kappa`` is the Eq. 1 kWh-per-FLOP slope and CI(t) the grid
intensity seen by window t.  The existing machinery
(``allocate`` / ``dual_descent`` / ``downgrade_guard``) already takes an
arbitrary cost vector, so pricing computation in carbon is a change of
units, not of algorithm: the dual price lambda becomes reward-per-gram
and *persists across windows*, which is exactly what shifts computation
into green-grid hours - when CI drops, every chain gets cheaper in
carbon, the Eq. 10 argmax climbs the chain ladder, and the per-window
gram cap is still hard-enforced by the tail-reserve guard.

Two equivalent formulations are provided (both per-window LPs are the
same program up to a positive scalar):

  * ``pricing="carbon"`` - native: carbon cost vector + gram budget +
    carbon-space lambda.  The principled form: lambda does not need to
    re-converge when CI moves between windows.
  * ``pricing="flops"``  - reduction: FLOPs cost vector with the
    per-window *effective FLOPs budget* B_f(t) = B_g / (kappa * CI(t)),
    computed in ratio form ``flops_ref * (ci_ref / CI(t))`` so that a
    constant-CI trace yields B_f(t) == flops_ref BIT-EXACTLY (x/x == 1.0
    in IEEE) and the whole loop reproduces today's FLOPs-budget
    decisions bit-identically - the parity gate in tests/test_carbon.py.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

from repro.carbon.intensity import IntensityTrace
from repro.core.action_chain import ActionChainSet
from repro.core.pfec import EnergyConfig, kwh_per_flop
from repro.core.primal_dual import DualDescentConfig, window_step


def grams_per_flop(ci_g_per_kwh: float,
                   cfg: EnergyConfig | None = None) -> float:
    """kappa * CI: operational gCO2e emitted per FLOP served."""
    return kwh_per_flop(cfg) * float(ci_g_per_kwh)


def carbon_costs(flops_costs: np.ndarray, ci_g_per_kwh: float,
                 cfg: EnergyConfig | None = None) -> np.ndarray:
    """The time-varying effective cost vector c_j(t) [gCO2e]."""
    return np.asarray(flops_costs, np.float64) \
        * grams_per_flop(ci_g_per_kwh, cfg)


@dataclass(frozen=True)
class CarbonBudget:
    """A per-window gCO2e budget against a grid-intensity trace.

    Canonical fields are ``flops_ref`` (the FLOPs the budget admits at
    the reference intensity ``ci_ref``) rather than raw grams: the
    effective FLOPs budget is then the exact ratio
    ``flops_ref * (ci_ref / CI(t))``, algebraically equal to
    ``grams_per_window / (kappa * CI(t))`` but bit-stable when
    CI(t) == ci_ref (the constant-CI parity case).
    """

    flops_ref: float
    ci_ref: float
    trace: IntensityTrace
    cfg: EnergyConfig = field(default_factory=EnergyConfig)
    window_s: float = 3600.0
    phase_s: float = 0.0

    @classmethod
    def from_flops(cls, flops_budget: float, trace: IntensityTrace, *,
                   ci_ref: float | None = None,
                   cfg: EnergyConfig | None = None,
                   window_s: float = 3600.0,
                   phase_s: float = 0.0) -> "CarbonBudget":
        """The gram budget that admits ``flops_budget`` FLOPs per window
        at ``ci_ref`` (default: the trace mean) - how a FLOPs-budgeted
        deployment is migrated to a carbon-budgeted one."""
        return cls(flops_ref=float(flops_budget),
                   ci_ref=float(trace.mean() if ci_ref is None else ci_ref),
                   trace=trace, cfg=cfg or EnergyConfig(),
                   window_s=window_s, phase_s=phase_s)

    @classmethod
    def from_grams(cls, grams_per_window: float, trace: IntensityTrace, *,
                   ci_ref: float | None = None,
                   cfg: EnergyConfig | None = None,
                   window_s: float = 3600.0,
                   phase_s: float = 0.0) -> "CarbonBudget":
        cfg = cfg or EnergyConfig()
        ci_ref = float(trace.mean() if ci_ref is None else ci_ref)
        return cls(flops_ref=float(grams_per_window)
                   / grams_per_flop(ci_ref, cfg),
                   ci_ref=ci_ref, trace=trace, cfg=cfg,
                   window_s=window_s, phase_s=phase_s)

    @property
    def grams_per_window(self) -> float:
        return self.flops_ref * grams_per_flop(self.ci_ref, self.cfg)

    def ci(self, t: int) -> float:
        """Grid intensity seen by window t (trace mean over its span)."""
        return self.trace.window_mean(self.phase_s + t * self.window_s,
                                      self.window_s)

    def scale(self, t: int) -> float:
        """kappa * CI(t): the FLOPs->gCO2e cost scale for window t."""
        return grams_per_flop(self.ci(t), self.cfg)

    def flops_budget(self, t: int) -> float:
        """Effective FLOPs budget B_g / (kappa*CI(t)), in ratio form."""
        return self.flops_ref * (self.ci_ref / self.ci(t))

    def schedule(self, n_windows: int) -> dict[str, np.ndarray]:
        """Vectorized per-window (ci, cost scale, flops budget) arrays -
        what a streaming driver feeds ``run_stream``."""
        ci = np.array([self.ci(t) for t in range(n_windows)], np.float64)
        kpf = kwh_per_flop(self.cfg)
        return {"ci": ci, "scale": ci * kpf,
                "flops_budget": self.flops_ref * (self.ci_ref / ci),
                "grams": np.full(n_windows, self.grams_per_window)}


@dataclass
class CarbonWindowStats:
    """Per-window record of the carbon-budgeted controller."""

    n_requests: int
    ci_g_per_kwh: float
    flops: float
    spend_g: float
    budget_g: float
    lam: float  # reward per gCO2e (carbon pricing) or per FLOP (flops)
    downgraded: int


@dataclass
class CarbonBudgetController:
    """Carbon-denominated sibling of ``core.budget.BudgetController``.

    Each window t: decide with the persisted dual price, hard-cap spend
    with the tail-reserve guard, meter into the optional ledger, then
    run the nearline dual update - all against the window's effective
    costs.  ``pricing`` selects the formulation (see module docstring);
    both enforce spend_g <= grams_per_window whenever the floor fits.
    """

    chains: ActionChainSet
    budget: CarbonBudget
    dual_cfg: DualDescentConfig = field(default_factory=DualDescentConfig)
    guard: bool = True
    pricing: str = "carbon"
    ledger: object = None  # CarbonLedger, duck-typed to avoid the import

    def __post_init__(self):
        import jax.numpy as jnp
        if self.pricing not in ("carbon", "flops"):
            raise ValueError(f"pricing must be 'carbon' or 'flops', "
                             f"got {self.pricing!r}")
        self._jnp = jnp
        self.lam = jnp.float32(self.dual_cfg.lam_init)
        self.stats: list[CarbonWindowStats] = []

    @classmethod
    def from_spec(cls, chains: ActionChainSet, spec,
                  trace: IntensityTrace, *, window_s: float = 3600.0,
                  phase_s: float = 0.0, ci_ref: float | None = None,
                  **kw) -> "CarbonBudgetController":
        """Build the carbon host loop from a ConstraintSpec.

        The spec's ``GlobalAxis`` supplies the per-window reference
        budget (in FLOPs at ``ci_ref``, default the trace mean) and the
        pricing formulation; tenant/region axes need the fused
        ``ServingPipeline.from_spec``.
        """
        cs = spec.compile()
        if cs.mode != "plain":
            raise ValueError(
                f"the host-loop CarbonBudgetController serves the plain "
                f"single-budget spec only (got mode {cs.mode!r}); use "
                f"ServingPipeline.from_spec for tenant/region axes")
        cb = CarbonBudget.from_flops(cs.total_budget, trace,
                                     ci_ref=ci_ref, window_s=window_s,
                                     phase_s=phase_s)
        return cls(chains, cb, pricing=cs.pricing, **kw)

    def step_window(self, rewards: np.ndarray) -> np.ndarray:
        """Serve one window: Eq. 10 decide -> guard -> ledger -> dual.

        The loop body is ``core.primal_dual.window_step`` - the SAME
        implementation the FLOPs-budget ``BudgetController`` wraps;
        pricing carbon is only a change of cost vector and cap."""
        t = len(self.stats)
        ci = self.budget.ci(t)
        scale = self.budget.scale(t)
        if self.pricing == "carbon":
            costs = self.chains.costs * scale  # gCO2e
            cap = self.budget.grams_per_window
        else:  # flops reduction: same LP, costs stay in FLOPs
            costs = self.chains.costs
            cap = self.budget.flops_budget(t)
        decisions, downgraded, spend, self.lam = window_step(
            rewards, costs, cap, self.lam, cheap=self.chains.cheapest(),
            guard=self.guard, cfg=self.dual_cfg)
        flops = float(np.sum(self.chains.costs[decisions]))
        if self.ledger is not None:
            self.ledger.record(decisions, t=t, ci=ci)
        spend_g = spend if self.pricing == "carbon" else spend * scale
        self.stats.append(CarbonWindowStats(
            n_requests=len(decisions), ci_g_per_kwh=ci, flops=flops,
            spend_g=spend_g, budget_g=self.budget.grams_per_window,
            lam=float(self.lam), downgraded=downgraded))
        return decisions

    def spend_trace_g(self) -> np.ndarray:
        return np.array([s.spend_g for s in self.stats])
