"""Grid carbon-intensity traces: generators, CSV loading, resampling.

An :class:`IntensityTrace` is a uniformly sampled, piecewise-constant
CI(t) signal in gCO2e/kWh.  Traces are treated as CYCLIC (a canonical
"day" repeated), so a serving run longer than one trace period simply
wraps - the same convention real intensity feeds use when a forecast is
extended with the seasonal profile.

Synthetic generators cover the shapes the carbon-aware allocator is
benchmarked against:

  * ``constant_trace``   - today's single-number assumption (paper Eq. 2
    with CI = 615 g/kWh), the parity baseline;
  * ``diurnal_trace``    - a day sinusoid: dirty evening peak, clean
    night/midday trough (thermal-dominated grids);
  * ``solar_duck_trace`` - diurnal shape plus a midday solar "duck"
    depression and a steep evening ramp (solar-heavy grids, CAISO-like);
  * ``two_region_traces``- the same diurnal shape phase-shifted between
    two regions, for geo-shift scenarios (serve where it is night).

``load_ci_csv`` reads real exported intensity files in the two layouts
the ichnos trace->intensity pipeline parses (``parse_ci_intervals``):
``date,start,actual`` and the UK national-grid style
``date,start,end,forecast,actual,index``; the sampling period is
inferred from the first two chronological rows.
"""
from __future__ import annotations

import csv
import math
from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class IntensityTrace:
    """Uniform, cyclic grid carbon-intensity samples [gCO2e/kWh]."""

    values: np.ndarray  # (T,) float64, > 0
    period_s: float  # seconds between consecutive samples
    name: str = "ci"

    def __post_init__(self):
        v = np.asarray(self.values, np.float64)
        object.__setattr__(self, "values", v)
        if v.ndim != 1 or v.size == 0:
            raise ValueError("intensity trace needs a 1-D non-empty series")
        if not np.all(np.isfinite(v)) or not np.all(v > 0):
            raise ValueError("carbon intensity must be finite and positive")
        if not self.period_s > 0:
            raise ValueError(f"period_s must be positive, got {self.period_s}")

    def __len__(self) -> int:
        return int(self.values.size)

    @property
    def span_s(self) -> float:
        """Length of one cycle in seconds."""
        return self.period_s * len(self)

    def at(self, t_s: float) -> float:
        """Piecewise-constant CI at time ``t_s`` seconds (cyclic)."""
        idx = int(math.floor(t_s / self.period_s)) % len(self)
        return float(self.values[idx])

    def resample(self, n_windows: int, window_s: float,
                 *, phase_s: float = 0.0) -> np.ndarray:
        """CI per serving window: window t covers [t*window_s, (t+1)*...).

        Each window takes the MEAN of the trace over its span (exact for
        the piecewise-constant signal), so a 6 h window over an hourly
        trace sees the 6-hour average, not one sampled hour.  ``phase_s``
        shifts the trace relative to window 0 (traffic-vs-grid offset
        experiments).
        """
        if n_windows <= 0:
            raise ValueError(f"n_windows must be positive, got {n_windows}")
        return np.array([self.window_mean(phase_s + t * window_s, window_s)
                         for t in range(n_windows)], np.float64)

    def window_mean(self, lo_s: float, window_s: float) -> float:
        """Mean CI over [lo_s, lo_s + window_s) - exact for the
        piecewise-constant signal (integrate the step function)."""
        hi_s = lo_s + window_s
        i0 = math.floor(lo_s / self.period_s)
        i1 = math.ceil(hi_s / self.period_s)
        acc = 0.0
        for i in range(i0, i1):
            seg_lo = max(lo_s, i * self.period_s)
            seg_hi = min(hi_s, (i + 1) * self.period_s)
            if seg_hi > seg_lo:
                acc += self.values[i % len(self)] * (seg_hi - seg_lo)
        return acc / window_s

    def mean(self) -> float:
        return float(self.values.mean())


# ---------------------------------------------------------------------------
# Synthetic generators
# ---------------------------------------------------------------------------

HOUR_S = 3600.0


def constant_trace(ci: float = 615.0, *, n: int = 24,
                   period_s: float = HOUR_S) -> IntensityTrace:
    """The paper's constant-CI world (Eq. 2 default 615 g/kWh)."""
    return IntensityTrace(np.full(n, float(ci)), period_s, name="constant")


def _check_day_span(n: int, period_s: float) -> None:
    """The day-shaped generators are cyclic over exactly 24 h; any other
    span would wrap mid-curve (discontinuity, skewed mean) silently."""
    if abs(n * period_s - 24.0 * HOUR_S) > 1e-6:
        raise ValueError(
            f"n*period_s must span one day (86400 s) for a day-curve "
            f"generator, got {n} x {period_s} s = {n * period_s} s; "
            f"pick n = {int(round(24.0 * HOUR_S / period_s))}")


def diurnal_trace(mean: float = 450.0, *, rel_amplitude: float = 0.45,
                  peak_hour: float = 19.0, n: int = 24,
                  period_s: float = HOUR_S) -> IntensityTrace:
    """Day sinusoid: CI peaks at ``peak_hour`` (evening demand ramp) and
    troughs 12 h away; ``rel_amplitude`` is the peak deviation / mean."""
    if not 0 <= rel_amplitude < 1:
        raise ValueError("rel_amplitude must be in [0, 1)")
    _check_day_span(n, period_s)
    hours = np.arange(n) * (period_s / HOUR_S)
    v = mean * (1.0 + rel_amplitude
                * np.cos(2.0 * np.pi * (hours - peak_hour) / 24.0))
    return IntensityTrace(v, period_s, name="diurnal")


def solar_duck_trace(mean: float = 450.0, *, rel_amplitude: float = 0.35,
                     solar_dip: float = 0.35, dip_hour: float = 13.0,
                     dip_width_h: float = 3.0, peak_hour: float = 19.0,
                     n: int = 24, period_s: float = HOUR_S) -> IntensityTrace:
    """The solar "duck": diurnal base minus a Gaussian midday depression
    (solar flooding the grid) which steepens the evening ramp.  The curve
    is floored at 10% of ``mean`` so intensity stays physical."""
    _check_day_span(n, period_s)
    base = diurnal_trace(mean, rel_amplitude=rel_amplitude,
                         peak_hour=peak_hour, n=n, period_s=period_s).values
    hours = np.arange(n) * (period_s / HOUR_S)
    # cyclic hour distance to the dip center
    d = np.minimum(np.abs(hours % 24.0 - dip_hour),
                   24.0 - np.abs(hours % 24.0 - dip_hour))
    dip = mean * solar_dip * np.exp(-0.5 * (d / dip_width_h) ** 2)
    v = np.maximum(base - dip, 0.1 * mean)
    return IntensityTrace(v, period_s, name="solar_duck")


def two_region_traces(mean: float = 450.0, *, offset_h: float = 8.0,
                      rel_amplitude: float = 0.45, n: int = 24,
                      period_s: float = HOUR_S
                      ) -> dict[str, IntensityTrace]:
    """Two grids with the same day shape ``offset_h`` hours apart (e.g.
    EU vs US-west): the geo-shift scenario serves each window from
    whichever region is currently greener."""
    a = diurnal_trace(mean, rel_amplitude=rel_amplitude, n=n,
                      period_s=period_s)
    b = diurnal_trace(mean, rel_amplitude=rel_amplitude,
                      peak_hour=19.0 + offset_h, n=n, period_s=period_s)
    return {"region_a": IntensityTrace(a.values, period_s, name="region_a"),
            "region_b": IntensityTrace(b.values, period_s, name="region_b")}


# ---------------------------------------------------------------------------
# CSV loading (ichnos parse_ci_intervals layouts)
# ---------------------------------------------------------------------------


def _parse_minutes(date: str, start: str) -> int:
    """'YYYY-MM-DD' + 'HH:MM' -> minutes since epoch-less day origin.
    Only DELTAS matter (period inference), so days are taken as 1440 min
    apart without touching timezone-dependent epoch conversion."""
    y, m, d = (int(x) for x in date.strip().split("-"))
    hh, mm = (int(x) for x in start.strip().split(":")[:2])
    # proleptic day number is overkill; a month-agnostic ordinal is fine
    # for period inference within one exported file
    from datetime import date as _date
    return _date(y, m, d).toordinal() * 1440 + hh * 60 + mm


def load_ci_csv(path: str, *, value_col: str | None = None,
                name: str | None = None) -> IntensityTrace:
    """Load an exported grid-intensity CSV as an :class:`IntensityTrace`.

    Accepts the two layouts ichnos' ``parse_ci_intervals`` reads:
    ``date,start,actual`` and ``date,start,end,forecast,actual,index``
    (UK carbon-intensity exports).  The value column is ``actual`` (or
    ``value``) unless ``value_col`` overrides it; the sampling period is
    inferred from the smallest positive timestamp delta and every row
    must land on that grid.  Missing/blank samples are filled by the
    previous value (the feed convention for short gaps).
    """
    with open(path, newline="") as f:
        reader = csv.DictReader(f)
        fields = [c.strip().lower() for c in (reader.fieldnames or [])]
        rows = list(reader)
    if not rows:
        raise ValueError(f"no data rows in {path}")
    if "date" not in fields or "start" not in fields:
        raise ValueError(f"{path}: expected 'date' and 'start' columns, "
                         f"got {fields}")
    col = value_col
    if col is None:
        for cand in ("actual", "value"):
            if cand in fields:
                col = cand
                break
    if col is None or col.lower() not in fields:
        raise ValueError(f"{path}: no intensity value column "
                         f"('actual'/'value') in {fields}")

    def get(row, key):
        for k, v in row.items():
            if k is not None and k.strip().lower() == key:
                return v
        return None

    stamps: list[tuple[int, float]] = []
    for r in rows:
        t = _parse_minutes(get(r, "date"), get(r, "start"))
        raw = get(r, col.lower())
        v = float(raw) if raw not in (None, "") else math.nan
        stamps.append((t, v))
    stamps.sort(key=lambda x: x[0])
    deltas = sorted({b - a for (a, _), (b, _) in zip(stamps, stamps[1:])
                     if b > a})
    if not deltas:
        raise ValueError(f"{path}: cannot infer a sampling period")
    period_min = deltas[0]
    if any(d % period_min for d in deltas):
        raise ValueError(f"{path}: non-uniform sampling, deltas={deltas} min")
    t0 = stamps[0][0]
    steps = (stamps[-1][0] - t0) // period_min + 1
    by_t = {t: v for t, v in stamps}
    values = np.empty(steps, np.float64)
    prev = math.nan
    for i in range(steps):
        v = by_t.get(t0 + i * period_min, math.nan)
        if math.isnan(v):
            v = prev  # forward-fill gaps
        if math.isnan(v):
            raise ValueError(f"{path}: leading sample is missing/blank")
        values[i] = prev = v
    import os
    return IntensityTrace(values, period_min * 60.0,
                          name=name or os.path.splitext(
                              os.path.basename(path))[0])
