"""Carbon-aware allocation: traces, per-window ledger, gCO2e budgets.

The paper accounts energy/carbon with Lacoste et al. 2019 (its Eq. 1-2);
this package makes those equations *per-window, time-varying, and
decision-relevant* instead of a post-hoc constant-CI conversion.  The
mapping from the paper's quantities to ledger fields:

    paper Eq. 1   EC = PUE * (p_ram e_ram + p_cpu e_cpu + p_gpu e_gpu)
        -> WindowCarbonEntry.kwh            (realized window energy;
           device-hours e_(.) derived from metered FLOPs through the
           EnergyConfig throughput model, as in core.pfec)
        -> WindowCarbonEntry.baseline_kwh   (the all-max-chain
           counterfactual: every request on the costliest chain)

    paper Eq. 2   CE = EC * CI
        -> WindowCarbonEntry.gco2e          with CI = CI(t) from an
           IntensityTrace, not the constant 615 g/kWh
        -> WindowCarbonEntry.ci_g_per_kwh   (the CI(t) actually applied)

    paper Eq. 3 budget C (FLOPs per window)
        -> CarbonBudget.grams_per_window    (gCO2e per window) with
           effective chain costs c_j(t) = flops_j * kappa * CI(t), so
           the Eq. 10 argmax and Algorithm 1 dual price operate in
           carbon units (see carbon.controller)

    "saves ~5000 kWh and ~3 tCO2e per day" (paper §1/§5)
        -> CarbonLedger.report()["daily_saved_kwh" / "daily_saved_tco2e"]
           (recorded windows extrapolated to a 24 h day vs the
           all-max-chain baseline, emitted to results/carbon_report.csv)

Submodules: ``intensity`` (trace generators + ichnos-style CSV loader),
``ledger`` (per-window operational-carbon metering with per-stage and
per-model attribution), ``controller`` (carbon-denominated dual
budgets).  Real ElectricityMaps/NESO feed adapters and embodied carbon
are future work (ROADMAP).
"""
import importlib

_LAZY = {
    "IntensityTrace": "repro.carbon.intensity",
    "constant_trace": "repro.carbon.intensity",
    "diurnal_trace": "repro.carbon.intensity",
    "solar_duck_trace": "repro.carbon.intensity",
    "two_region_traces": "repro.carbon.intensity",
    "load_ci_csv": "repro.carbon.intensity",
    "CarbonLedger": "repro.carbon.ledger",
    "WindowCarbonEntry": "repro.carbon.ledger",
    "CarbonBudget": "repro.carbon.controller",
    "CarbonBudgetController": "repro.carbon.controller",
    "carbon_costs": "repro.carbon.controller",
    "grams_per_flop": "repro.carbon.controller",
}

__all__ = list(_LAZY)


def __getattr__(name):  # PEP 562: keep `import repro.carbon` jax-free
    if name in _LAZY:
        return getattr(importlib.import_module(_LAZY[name]), name)
    raise AttributeError(name)
