import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input shape)
on the production meshes and capture memory/cost/collective analysis.

MUST be run as its own process (the device-count flag above is read at
first jax init, BEFORE any other import - hence the file's first two
lines).  Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch all --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --arch glm4-9b \
        --shape train_4k --mesh single

Results land as JSON under results/dryrun/ (one file per cell x mesh);
EXPERIMENTS.md §Dry-run and the roofline benchmark read them.
"""
import argparse
import json
import time
import traceback

import jax

from repro.configs import ARCH_IDS, get_arch
from repro.launch import hlo_analysis as H
from repro.launch.mesh import (device_bytes_estimate, make_production_mesh,
                               tree_named_shardings)

RESULTS_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                           "results", "dryrun")


def _measure(cell, mesh) -> dict:
    """Lower + compile one cell on one mesh; return all analyses."""
    t0 = time.time()
    from repro.distributed.compat import mesh_context
    with mesh_context(mesh):
        in_sh = tree_named_shardings(cell.in_shardings, mesh)
        out_sh = (tree_named_shardings(cell.out_shardings, mesh)
                  if cell.out_shardings is not None else None)
        jitted = jax.jit(cell.fn, in_shardings=in_sh, out_shardings=out_sh,
                         donate_argnums=cell.donate or ())
        lowered = jitted.lower(*cell.arg_specs)
        lower_s = time.time() - t0
        t1 = time.time()
        compiled = lowered.compile()
        compile_s = time.time() - t1
    txt = compiled.as_text()
    return {
        "lower_s": lower_s, "compile_s": compile_s,
        "memory_analysis": H.memory_dict(compiled),
        "cost_analysis": H.cost_dict(compiled),
        "collectives": H.collective_bytes(txt),
        "hlo_chars": len(txt),
    }


def _corrected(main: dict, m_p: dict, m_2p: dict, trips: int,
               period: int) -> dict:
    """XLA counts while-loop bodies once; extrapolate from the p vs 2p
    layer-count variants: corrected = m(p) + (trips/p - 1) * (m(2p)-m(p))."""
    n_periods = trips // period
    out = {}
    for key in ("flops", "bytes_accessed", "transcendentals"):
        a = m_p["cost_analysis"].get(key, 0.0)
        b = m_2p["cost_analysis"].get(key, 0.0)
        out[key] = a + (n_periods - 1) * max(0.0, b - a)
    a = m_p["collectives"].get("total", 0)
    b = m_2p["collectives"].get("total", 0)
    out["collective_total"] = a + (n_periods - 1) * max(0, b - a)
    out["per_period_flops"] = max(
        0.0, m_2p["cost_analysis"].get("flops", 0.0)
        - m_p["cost_analysis"].get("flops", 0.0))
    return out


def run_cell(cell, *, multi_pod: bool, verbose: bool = True) -> dict:
    mesh = make_production_mesh(multi_pod=multi_pod)
    rec = {
        "arch": cell.arch_id, "shape": cell.shape_name, "kind": cell.kind,
        "mesh": "x".join(map(str, mesh.devices.shape)),
        "n_chips": mesh.size, "ok": False, "meta": cell.meta,
    }
    try:
        rec.update(_measure(cell, mesh))
        rec["arg_bytes_per_device"] = device_bytes_estimate(
            cell.arg_specs, cell.in_shardings, mesh)
        if cell.variant_fn is not None and cell.loop_trips:
            p = cell.loop_period
            m_p = _measure(cell.variant_fn(p), mesh)
            m_2p = _measure(cell.variant_fn(2 * p), mesh)
            rec["corrected"] = _corrected(rec, m_p, m_2p,
                                          cell.loop_trips, p)
        rec["ok"] = True
        if verbose:
            ca, co = rec["cost_analysis"], rec["collectives"]
            flops = rec.get("corrected", {}).get("flops",
                                                 ca.get("flops", 0))
            coll = rec.get("corrected", {}).get("collective_total",
                                                co.get("total", 0))
            print(f"[dryrun] {cell.arch_id}/{cell.shape_name} "
                  f"mesh={rec['mesh']} OK "
                  f"lower={rec['lower_s']:.1f}s compile={rec['compile_s']:.1f}s "
                  f"flops/dev={flops:.3e} coll/dev={coll:.3e}B")
            if rec["memory_analysis"]:
                print(f"         memory_analysis: {rec['memory_analysis']}")
    except Exception as e:  # noqa: BLE001 - a failed cell is a data point
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {cell.arch_id}/{cell.shape_name} "
                  f"mesh={rec['mesh']} FAILED: {rec['error']}")
    return rec


def save_record(rec: dict, out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    name = f"{rec['arch']}__{rec['shape']}__{rec['mesh']}.json"
    with open(os.path.join(out_dir, name), "w") as f:
        json.dump(rec, f, indent=1)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all",
                    help="arch id or 'all' (see configs.ARCH_IDS)")
    ap.add_argument("--shape", default=None, help="one shape name only")
    ap.add_argument("--mesh", choices=("single", "multi", "both"),
                    default="both")
    ap.add_argument("--out", default=os.path.abspath(RESULTS_DIR))
    ap.add_argument("--skip-existing", action="store_true")
    args = ap.parse_args()

    archs = list(ARCH_IDS) if args.arch == "all" else [args.arch]
    meshes = {"single": [False], "multi": [True],
              "both": [False, True]}[args.mesh]

    n_ok = n_fail = n_skip = 0
    for arch_id in archs:
        mod = get_arch(arch_id)
        shapes = [args.shape] if args.shape else list(mod.SHAPES)
        for shape in shapes:
            if shape in getattr(mod, "SKIPPED_SHAPES", {}):
                print(f"[dryrun] {arch_id}/{shape} SKIPPED: "
                      f"{mod.SKIPPED_SHAPES[shape]}")
                for multi in meshes:
                    rec = {"arch": arch_id, "shape": shape,
                           "mesh": "2x16x16" if multi else "16x16",
                           "ok": True, "skipped": True,
                           "reason": mod.SKIPPED_SHAPES[shape]}
                    save_record(rec, args.out)
                n_skip += 1
                continue
            for multi in meshes:
                mesh_name = "2x16x16" if multi else "16x16"
                path = os.path.join(
                    args.out, f"{arch_id}__{shape}__{mesh_name}.json")
                if args.skip_existing and os.path.exists(path):
                    with open(path) as f:
                        if json.load(f).get("ok"):
                            n_skip += 1
                            continue
                cell = mod.make_cell(shape)
                rec = run_cell(cell, multi_pod=multi)
                save_record(rec, args.out)
                n_ok += rec["ok"]
                n_fail += (not rec["ok"])
    print(f"[dryrun] done: ok={n_ok} fail={n_fail} skipped={n_skip}")
    return 0 if n_fail == 0 else 1


if __name__ == "__main__":
    raise SystemExit(main())
