"""Production mesh definitions.

Axis semantics (DESIGN.md §6):
  pod   - crosses pod boundaries (DCN-connected); pure DP traffic only
  data  - in-pod data parallel + FSDP
  model - tensor / expert / vocab / embedding-row parallel (ICI-local)

``make_production_mesh`` is a FUNCTION (never called at import time) so
importing this module touches no jax device state.
"""
from __future__ import annotations

import jax
from jax.sharding import PartitionSpec as P

from repro.distributed.compat import make_mesh, mesh_context  # noqa: F401


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def mesh_num_shards(mesh) -> int:
    """GLOBAL device count of a mesh (1 for ``None``).

    ``mesh.shape`` spans every process of a multi-process mesh, so this
    is the count the serving pipeline's pad quantum and window bucketing
    MUST key off: padded shapes derive from (n, quantum) only, so every
    host computes the same bucket for the same window and per-shard
    slices divide evenly.  Host-local array building (how many rows
    THIS process materializes) keys off ``mesh_local_shards`` instead -
    conflating the two breaks pow2 bucketing the moment a second
    process joins (local count 1, global count P).
    """
    if mesh is None:
        return 1
    import numpy as np

    return int(np.prod(list(mesh.shape.values())))


def mesh_local_shards(mesh) -> int:
    """Shards of ``mesh`` owned by THIS process (1 for ``None``).

    Equal to ``mesh_num_shards`` in a single-process mesh; in a
    ``jax.distributed`` mesh it is the addressable-device count -
    what sizes the host-local slice of a request-sharded array.
    """
    if mesh is None:
        return 1
    pid = jax.process_index()
    return sum(1 for d in mesh.devices.flat if d.process_index == pid)


def process_shard_rows(mesh, b: int) -> list[tuple[int, int]]:
    """Row slices of a (b,)-request-sharded array held by THIS process.

    One ``[lo, hi)`` pair per addressable device, in mesh order: shard
    ``s`` of the 1-D request mesh holds rows ``[s*b/S, (s+1)*b/S)`` of
    the globally padded window (``S = mesh_num_shards``).  This is the
    routing table of the multi-host window protocol: each host builds
    exactly these rows of every window and never ships a request.
    """
    n_shards = mesh_num_shards(mesh)
    if b % n_shards:
        raise ValueError(f"b={b} not divisible by {n_shards} shards")
    per = b // n_shards
    pid = jax.process_index()
    return [(pos * per, (pos + 1) * per)
            for pos, d in enumerate(mesh.devices.flat)
            if d.process_index == pid]


def make_request_mesh(n_shards: int | None = None):
    """1-D mesh over the serving request axis (sharding.REQUEST_AXIS).

    The fused ServingPipeline shard_maps its window pass over this axis:
    per-request work (scoring, Eq. 10, cascade execution) stays local
    while the guard and the dual update stitch global sums.  Defaults to
    ALL devices - in a ``jax.distributed`` run ``jax.devices()`` spans
    every process, so the default mesh is the process-spanning request
    mesh (each host contributes its local devices; pass
    ``repro.distributed.multihost.initialize`` first).
    """
    from repro.distributed.sharding import REQUEST_AXIS

    n = n_shards if n_shards is not None else len(jax.devices())
    return make_mesh((n,), (REQUEST_AXIS,))


def resolve_spec(spec, mesh):
    """Drop axis names not present in ``mesh`` from a PartitionSpec.

    Lets one spec tree (written against the multi-pod axis set) serve both
    the (data, model) and (pod, data, model) meshes.
    """
    if spec is None:
        return None
    names = set(mesh.axis_names)

    def keep(entry):
        if entry is None:
            return None
        if isinstance(entry, (tuple, list)):
            kept = tuple(a for a in entry if a in names)
            return kept if kept else None
        return entry if entry in names else None

    return P(*(keep(e) for e in spec))


def tree_named_shardings(spec_tree, mesh):
    """PartitionSpec pytree -> NamedSharding pytree (specs resolved)."""
    is_spec = lambda x: isinstance(x, P) or x is None

    def conv(s):
        if s is None:
            return jax.sharding.NamedSharding(mesh, P())
        return jax.sharding.NamedSharding(mesh, resolve_spec(s, mesh))

    return jax.tree_util.tree_map(conv, spec_tree, is_leaf=is_spec)


def device_bytes_estimate(arg_specs, spec_tree, mesh) -> int:
    """Per-device input bytes from shapes + shardings (backup for
    backends whose compiled.memory_analysis() is unavailable)."""
    import numpy as np

    is_spec = lambda x: isinstance(x, P) or x is None
    specs_flat = jax.tree_util.tree_leaves(spec_tree, is_leaf=is_spec)
    args_flat = jax.tree_util.tree_leaves(arg_specs)
    total = 0
    for arr, spec in zip(args_flat, specs_flat):
        if not hasattr(arr, "shape"):
            continue
        shards = 1
        spec = resolve_spec(spec, mesh) if spec is not None else P()
        for entry in (spec or P()):
            if entry is None:
                continue
            axes = entry if isinstance(entry, tuple) else (entry,)
            for a in axes:
                shards *= mesh.shape[a]
        size = int(np.prod(arr.shape)) if arr.shape else 1
        total += size * arr.dtype.itemsize // max(1, shards)
    return total
