"""Compiled-HLO analysis: cost terms + collective-traffic extraction.

``collective_bytes`` parses the optimized HLO text and sums the RESULT
sizes of every collective op (all-reduce / all-gather / reduce-scatter /
all-to-all / collective-permute, sync or async-start).  cost_analysis()
does not expose this - parsing the module text is the documented approach
(brief: ROOFLINE ANALYSIS).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "s32": 4, "s16": 2, "s8": 1,
    "u64": 8, "u32": 4, "u16": 2, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"\b([a-z]+\d*(?:e\d+m\d+(?:fn)?)?)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    if dtype not in _DTYPE_BYTES:
        return 0
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def collective_bytes(hlo_text: str) -> dict:
    """Sum result bytes per collective kind.  Returns
    {kind: bytes, ..., 'total': bytes, 'counts': {kind: n}}."""
    out: dict = defaultdict(int)
    counts: dict = defaultdict(int)
    for line in hlo_text.splitlines():
        if "=" not in line:
            continue
        for kind in COLLECTIVES:
            # match sync and async-start forms; skip -done (double count)
            token_s = f" {kind}-start("
            token = f" {kind}("
            if token not in line and token_s not in line:
                continue
            lhs = line.split(f"{kind}-start(" if token_s in line
                             else f"{kind}(")[0]
            # result shapes sit between '=' and the op name
            lhs = lhs.split("=", 1)[-1]
            for dtype, dims in _SHAPE_RE.findall(lhs):
                out[kind] += _shape_bytes(dtype, dims)
            counts[kind] += 1
            break
    out = dict(out)
    out["total"] = sum(v for k, v in out.items() if k in COLLECTIVES)
    out["counts"] = dict(counts)
    return out


def cost_dict(compiled) -> dict:
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    if not ca:
        return {}
    return {"flops": float(ca.get("flops", 0.0)),
            "bytes_accessed": float(ca.get("bytes accessed",
                                           ca.get("bytes_accessed", 0.0))),
            "transcendentals": float(ca.get("transcendentals", 0.0))}


def memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    if ma is None:
        return {}
    out = {}
    for field in ("argument_size_in_bytes", "output_size_in_bytes",
                  "temp_size_in_bytes", "alias_size_in_bytes",
                  "generated_code_size_in_bytes"):
        v = getattr(ma, field, None)
        if v is not None:
            out[field] = int(v)
    return out


# TPU v5e hardware constants (brief: ROOFLINE ANALYSIS)
PEAK_FLOPS_BF16 = 197e12  # per chip
HBM_BW = 819e9  # bytes/s per chip
ICI_BW = 50e9  # bytes/s per link


def roofline_terms(*, flops: float, hbm_bytes: float,
                   coll_bytes: float, n_chips: int,
                   flops_is_global: bool = True) -> dict:
    """The three roofline terms in seconds (see EXPERIMENTS.md §Roofline)."""
    div = n_chips if flops_is_global else 1
    t_compute = flops / (div * PEAK_FLOPS_BF16)
    t_memory = hbm_bytes / (div * HBM_BW)
    t_coll = coll_bytes / (div * ICI_BW)
    dominant = max((("compute", t_compute), ("memory", t_memory),
                    ("collective", t_coll)), key=lambda kv: kv[1])[0]
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_coll, "dominant": dominant}
