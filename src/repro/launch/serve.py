"""GreenFlow serving driver: the paper's system end to end.

    PYTHONPATH=src python -m repro.launch.serve --windows 12 --spike 3.0

Builds (or loads from the benchmark cache) the trained cascade + reward
model, then runs an online serving simulation: batched request windows
flow through the GreenFlow allocator (nearline dual updates + online
Eq. 10 decisions + downgrade guard) and the cascade executes the
allocated chains.  Reports per-window spend/λ/revenue and the final PFEC
comparison against EQUAL at the same realized computation.
"""
from __future__ import annotations

import argparse
import time

import numpy as np

from repro.cascade.engine import CascadeServer, precompute_stage_scores
from repro.core.budget import BudgetController
from repro.core.pfec import pfec_report
from repro.experiments import (ExperimentConfig, build_experiment,
                               predicted_rewards, train_reward_model)
from repro.data.synthetic import WorldConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--windows", type=int, default=10)
    ap.add_argument("--requests", type=int, default=96,
                    help="requests per normal window")
    ap.add_argument("--spike", type=float, default=3.0,
                    help="traffic multiplier on the spike windows")
    ap.add_argument("--budget-frac", type=float, default=0.6)
    ap.add_argument("--small", action="store_true", help="CI-sized world")
    args = ap.parse_args()

    cfg = ExperimentConfig(
        world=WorldConfig(n_users=800 if args.small else 2000,
                          n_items=200 if args.small else 400,
                          hist_len=10, seed=11),
        expose=8, n_scales=4,
        cascade_steps=100 if args.small else 200,
        reward_steps=200 if args.small else 400, batch=48)
    print("[serve] building world + training cascade & reward models ...")
    exp = build_experiment(cfg, verbose=True)
    rp, rc = train_reward_model(exp)

    # serving universe = the eval users; ground-truth clicks already sampled
    scores = precompute_stage_scores(exp.models, exp.world,
                                     exp.split.final_eval)
    server = CascadeServer(stage_scores=scores, chains=exp.chains,
                           clicks=exp.clicks_eval, expose=cfg.expose)
    pred = predicted_rewards(exp, rp, rc, exp.ctx_eval)

    budget = args.budget_frac * exp.chains.costs.max() * args.requests
    ctl = BudgetController(exp.chains, budget)
    rng = np.random.default_rng(0)
    n_eval = pred.shape[0]

    total_rev = total_flops = 0.0
    serve_ms = []
    print(f"{'win':>4} {'traffic':>8} {'spend/budget':>13} {'lam':>12} "
          f"{'downgraded':>10} {'revenue':>8} {'serve_ms':>9}")
    for t in range(args.windows):
        mult = args.spike if args.windows // 3 <= t < args.windows // 3 + 3 \
            else 1.0
        n_t = int(args.requests * mult)
        rows = rng.integers(0, n_eval, n_t)
        decisions = ctl.step_window(pred[rows])
        t0 = time.perf_counter()
        # one batched kernel pass over the whole window - chain ids go in
        # per request, no per-chain-group recomputation
        rev, flops = server.serve(rows, decisions)
        dt_ms = (time.perf_counter() - t0) * 1e3
        serve_ms.append(dt_ms)
        total_rev += rev.sum()
        total_flops += flops.sum()
        s = ctl.stats[-1]
        print(f"{t:>4} {mult:>8.1f} {s.spend/s.budget:>13.3f} "
              f"{s.lam:>12.3e} {s.downgraded:>10d} {rev.sum():>8.1f} "
              f"{dt_ms:>9.2f}")
    print(f"[serve] cascade execution: median {np.median(serve_ms):.2f} ms"
          f"/window, p95 {np.percentile(serve_ms, 95):.2f} ms")

    print("\n[serve] PFEC (GreenFlow serving run):")
    rep = pfec_report(clicks=total_rev, flops=total_flops)
    for k, v in rep.as_row().items():
        print(f"    {k:14s} {v}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
