"""GreenFlow streaming serving driver: the paper's online system end to
end on the fused ServingPipeline (repro/serving/).

    PYTHONPATH=src python -m repro.launch.serve --small --windows 12
    PYTHONPATH=src python -m repro.launch.serve --scenario diurnal
    PYTHONPATH=src python -m repro.launch.serve --scenario tenants \
        --tenants 4 --tenant-mode shared
    PYTHONPATH=src python -m repro.launch.serve --shards 2   # request mesh
    PYTHONPATH=src python -m repro.launch.serve --legacy     # old loop

Builds (or loads from the results/cache) the trained cascade + reward
model, then streams request windows through the fused
score->decide->guard->execute pass with double-buffered host prep; the
nearline dual update chains on-device and never blocks a response.

Scenario flags
--------------
--scenario constant   steady traffic at --requests per window
--scenario spike      a --spike x burst in the middle third (Fig. 5)
--scenario diurnal    day-curve sinusoid between 0.4x and 1.6x
--scenario tenants    --tenants equal blocks per window; --tenant-mode
                      `shared` = per-tenant budgets under ONE dual price
                      (the fused per-tenant guard); `independent` = one
                      pipeline (own price + budget) per tenant
--shards N            shard_map the pass over an N-way request mesh
--legacy              run the seed's host loop (scoring + NumPy guard +
                      separate serve kernel) instead, for comparison

Reports per-window spend/lambda/downgrades/revenue, host dispatch time,
and the final PFEC summary.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.pfec import pfec_report
from repro.experiments import build_serving_stack, serve_config
from repro.serving.pipeline import ServingPipeline
from repro.serving.stream import TrafficScenario, run_stream


def make_legacy_window(exp, server, params, rcfg, budget):
    """The seed's serving path, packaged for reuse (CLI --legacy and
    benchmarks/bench_serve.py share ONE definition of "legacy"): four
    host/device crossings per window - jitted scoring, NumPy controller
    (decide + guard + synchronous dual), jitted cascade execution.

    Returns (controller, window_fn) with window_fn(ctx, rows) ->
    (decisions, revenue).
    """
    import jax
    import jax.numpy as jnp

    from repro.core.budget import BudgetController
    from repro.core.reward_model import denormalize_rewards, reward_matrix

    mo = jnp.asarray(exp.chains.model_onehot)
    sh = jnp.asarray(exp.chains.scale_multihot)
    score = jax.jit(lambda p, c: denormalize_rewards(
        p, reward_matrix(p, rcfg, c, mo, sh)))
    ctl = BudgetController(exp.chains, budget)

    def window(ctx, rows):
        rewards = np.asarray(score(params, jnp.asarray(ctx, jnp.float32)))
        dec = ctl.step_window(rewards)
        rev, _ = server.serve(rows, dec)
        return dec, rev

    return ctl, window


def _legacy_loop(exp, server, params, rcfg, sizes, budget):
    import time

    ctl, window = make_legacy_window(exp, server, params, rcfg, budget)
    rng = np.random.default_rng(0)
    n_eval = exp.ctx_eval.shape[0]
    total_rev = total_flops = 0.0
    print(f"{'win':>4} {'n':>5} {'spend/budget':>13} {'lam':>12} "
          f"{'downgraded':>10} {'revenue':>9} {'window_ms':>9}")
    for t, n in enumerate(sizes):
        t0 = time.perf_counter()
        rows = rng.integers(0, n_eval, n)
        dec, rev = window(exp.ctx_eval[rows], rows)
        dt = (time.perf_counter() - t0) * 1e3
        s = ctl.stats[-1]
        total_rev += rev.sum()
        total_flops += s.spend
        print(f"{t:>4} {n:>5} {s.spend / s.budget:>13.3f} {s.lam:>12.3e} "
              f"{s.downgraded:>10d} {rev.sum():>9.1f} {dt:>9.2f}")
    return total_rev, total_flops


def main():
    ap = argparse.ArgumentParser(
        description="GreenFlow streaming serving (fused pipeline)")
    ap.add_argument("--windows", type=int, default=12)
    ap.add_argument("--requests", type=int, default=96,
                    help="requests per normal window")
    ap.add_argument("--scenario", default="spike",
                    choices=("constant", "spike", "diurnal", "tenants"))
    ap.add_argument("--spike", type=float, default=3.0,
                    help="traffic multiplier on the spike windows")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--tenant-mode", default="shared",
                    choices=("shared", "independent"))
    ap.add_argument("--budget-frac", type=float, default=0.6)
    ap.add_argument("--shards", type=int, default=0,
                    help=">0: shard_map over an N-way request mesh")
    ap.add_argument("--small", action="store_true", help="CI-sized world")
    ap.add_argument("--legacy", action="store_true",
                    help="run the seed's host loop instead")
    args = ap.parse_args()

    print("[serve] building world + training cascade & reward models ...")
    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=args.small), verbose=True)
    chains = exp.chains
    budget = args.budget_frac * chains.costs.max() * args.requests
    n_tenants = args.tenants if args.scenario == "tenants" else 1
    sc = TrafficScenario(args.scenario, args.windows, args.requests,
                         spike_mult=args.spike, n_tenants=n_tenants)
    sizes = sc.window_sizes()

    if args.legacy:
        total_rev, total_flops = _legacy_loop(exp, server, params, rcfg,
                                              sizes, budget)
    else:
        mesh = None
        if args.shards > 0:
            from repro.launch.mesh import make_request_mesh
            mesh = make_request_mesh(args.shards)
        rng = np.random.default_rng(0)
        n_eval = exp.ctx_eval.shape[0]

        def sample_window(t, n):
            rows = rng.integers(0, n_eval, n)
            return exp.ctx_eval[rows], rows

        if args.scenario == "tenants" and args.tenant_mode == "independent":
            pipes = [ServingPipeline(server, params, rcfg,
                                     budget / n_tenants)
                     for _ in range(n_tenants)]
            stats = []
            for p in pipes:
                stats.append(run_stream(
                    p, [n // n_tenants for n in sizes], sample_window))
            total_rev = sum(s.total_revenue for s in stats)
            total_flops = sum(s.total_spend for s in stats)
            for t in range(len(sizes)):
                spends = [float(s.windows[t].spend) for s in stats]
                print(f"win {t:>3}: per-tenant spend/budget "
                      + " ".join(f"{sp / (budget / n_tenants):.3f}"
                                 for sp in spends))
        else:
            tb = None
            if args.scenario == "tenants":  # shared dual price
                tb = np.full(n_tenants, budget / n_tenants, np.float32)
            pipe = ServingPipeline(server, params, rcfg, budget,
                                   mesh=mesh, tenant_budgets=tb)
            st = run_stream(pipe, sizes, sample_window)
            total_rev, total_flops = st.total_revenue, st.total_spend
            print(f"{'win':>4} {'n':>5} {'spend/budget':>13} {'lam':>12} "
                  f"{'downgraded':>10} {'revenue':>9} {'dispatch_ms':>11}")
            for t, r in enumerate(st.windows):
                print(f"{t:>4} {r.n_valid:>5} "
                      f"{float(r.spend) / r.budget:>13.3f} "
                      f"{float(r.lam_after):>12.3e} "
                      f"{int(r.downgraded):>10d} "
                      f"{r.revenue_np.sum():>9.1f} "
                      f"{st.dispatch_ms[t]:>11.2f}")
            c_min = float(chains.costs.min())
            print(f"[serve] {len(sizes)} windows in {st.wall_s:.2f}s "
                  f"({len(sizes) / st.wall_s:.1f} win/s), worst overshoot "
                  f"vs cap: {st.overshoot(c_min) * 100:.3f}%")

    print("\n[serve] PFEC (GreenFlow serving run):")
    rep = pfec_report(clicks=float(total_rev), flops=float(total_flops))
    for k, v in rep.as_row().items():
        print(f"    {k:14s} {v}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
