"""GreenFlow streaming serving driver: the paper's online system end to
end on the fused ServingPipeline (repro/serving/).

    PYTHONPATH=src python -m repro.launch.serve --small --windows 12
    PYTHONPATH=src python -m repro.launch.serve --scenario diurnal
    PYTHONPATH=src python -m repro.launch.serve --scenario tenants \
        --tenants 4 --tenant-mode shared
    PYTHONPATH=src python -m repro.launch.serve --scenario carbon \
        --ci-trace duck --ci-phase-h 6      # carbon-budgeted day
    PYTHONPATH=src python -m repro.launch.serve --shards 2   # request mesh
    PYTHONPATH=src python -m repro.launch.serve --legacy     # old loop

Builds (or loads from the results/cache) the trained cascade + reward
model, then streams request windows through the fused
score->decide->guard->execute pass with double-buffered host prep; the
nearline dual update chains on-device and never blocks a response.

Scenario flags
--------------
--scenario constant   steady traffic at --requests per window
--scenario spike      a --spike x burst in the middle third (Fig. 5)
--scenario diurnal    day-curve sinusoid between 0.4x and 1.6x
--scenario tenants    --tenants equal blocks per window; --tenant-mode
                      `shared` = per-tenant budgets under ONE dual price
                      (the fused per-tenant guard); `priced` = per-tenant
                      DUAL PRICES inside the one fused pass (a (T,)
                      price vector, each tenant descending on its own
                      budget; composes with --shards); `independent` =
                      one pipeline (own price + budget) per tenant
--scenario carbon     diurnal traffic priced against a grid-intensity
                      trace: per-window budgets in gCO2e, chain costs
                      c_j(t) = flops_j*kappa*CI(t), dual price in
                      reward-per-gram; the run is one 24 h day
                      (window_s = 86400/windows), metered by a
                      CarbonLedger into results/carbon_report.csv.
                      Knobs: --ci-trace diurnal|duck|constant (or
                      --ci-csv FILE), --ci-mean, --ci-phase-h (grid vs
                      traffic phase offset), --carbon-pricing
                      carbon|flops (native gram costs vs the
                      effective-FLOPs-budget reduction), --ci-forecast
                      (nearline dual warm-started on the NEXT window's
                      CI - closes the lambda-lag gap)
--scenario georegions the two-region geo-shifting router (spec:
                      RegionAxis(2) + GlobalAxis(pricing="carbon")):
                      each request picks (chain, serving region)
                      through one priced argmax with region costs
                      flops_j*kappa*CI_r(t) (region CI days
                      --geo-offset-h apart), (R,) dual prices +
                      per-region gram budgets + per-region guard;
                      per-region CarbonLedgers merge into
                      results/carbon_report_geo.csv.  --geo-split
                      flow|argmax picks the degenerate-tie rounding
                      (flow = the exact proportional flow split;
                      argmax = the historical knife edge)
--scenario geotenants the COMBINED tenant x region pipeline (spec:
                      TenantAxis(budgets, priced=True) + RegionAxis(2)
                      + GlobalAxis(pricing="carbon")): per-tenant gram
                      budgets AND per-region gram caps priced together
                      in ONE fused pass - a tenant-t request pays
                      (lam_tenant[t] + lam_region[r]) * c_{j,r} for
                      option (j, r), the guard chains a tenant walk
                      with a per-region walk, and WindowResult carries
                      the full (T, R) per-(tenant, region) spend.
                      Knobs: --tenants, --tenant-spread (budget
                      tightness ratio across tenants),
                      --region-cap-frac (each region's gram cap as a
                      fraction of the window's total tenant grams),
                      plus every georegions knob
--shards N            shard_map the pass over an N-way request mesh
                      (composes with tenants, georegions, geotenants)
--source table        index the materialized eval universe (default)
--source generated    stream windows from an unbounded hash-generated
                      user universe (--users sets its size; no (U, J)
                      table ever materializes - each window is scored
                      on the fly by a data.request_source
                      GeneratedSource; composes with every scenario)
--source memmap       replay fixed precomputed tables from memmapped
                      .npy files (saved to --replay-dir on first use):
                      only the rows a window touches page in
--legacy              run the seed's host loop (scoring + NumPy guard +
                      separate serve kernel) instead, for comparison
                      (with --scenario carbon: the CarbonBudgetController
                      host loop; table source only)

Reports per-window spend/lambda/downgrades/revenue, host dispatch time,
and the final PFEC summary.

Observability (repro/obs/): --metrics-out PATH writes a Prometheus-text
snapshot (+ PATH.json + PATH.windows.jsonl per-window flight log),
--trace-out PATH writes the host span trace as Chrome trace-event JSON
(open in ui.perfetto.dev), --obs-interval N prints a live line every N
windows, --profile-dir DIR wraps the run in jax.profiler.trace with
host spans as TraceAnnotations.  Telemetry never changes decisions or
prices - enabled runs are bitwise identical to disabled runs.

Multi-host runbook
------------------
One serve process per host, every process running the SAME command
plus its own identity flags (or the GREENFLOW_COORDINATOR /
GREENFLOW_NUM_PROCESSES / GREENFLOW_PROCESS_ID environment
variables)::

    # host 0 (also runs the coordinator service)
    PYTHONPATH=src python -m repro.launch.serve --source generated \
        --processes 2 --process-id 0 --coordinator host0:9987
    # host 1
    PYTHONPATH=src python -m repro.launch.serve --source generated \
        --processes 2 --process-id 1 --coordinator host0:9987

What happens (repro/distributed/multihost.py): the processes join one
``jax.distributed`` group, the request mesh spans every host's
devices, and each host GENERATES its deterministic slice of every
window - arrivals are pure (seed, t) functions, so no request ever
crosses the network; only the guard/dual collectives do.  All hosts
agree bitwise on every dual price and every decision (the parity gate
in tests/test_multihost.py).  Requirements: a streaming --source
(generated or memmap - every host needs the same universe; --source
table and --legacy are single-process), and --shards unset (the mesh
is the full process-spanning device set).  Per-host telemetry:
--metrics-out/--trace-out write per-host files suffixed with the host
label; merge the traces with ``repro.obs.merge_chrome_traces`` to see
every host's tracks in one Perfetto timeline.  Elastic resharding
(host join/leave) is checkpoint/replay - see
``repro.distributed.multihost.checkpoint_stream``.
"""
from __future__ import annotations

import argparse

import numpy as np

from repro.core.pfec import pfec_report
from repro.experiments import build_serving_stack, serve_config
from repro.obs.events import _host_np
from repro.serving.pipeline import ServingPipeline
from repro.serving.stream import SCENARIOS, TrafficScenario, run_stream


def _f(x) -> float:
    """Scalar host value of a (possibly multi-process) device array."""
    return float(np.sum(_host_np(x)))


def make_legacy_scorer(exp, rcfg):
    """The seed's jitted reward scorer - the ONE definition every legacy
    host loop (FLOPs or carbon) shares: score(params, ctx) -> (n, J)."""
    import jax
    import jax.numpy as jnp

    from repro.core.reward_model import denormalize_rewards, reward_matrix

    mo = jnp.asarray(exp.chains.model_onehot)
    sh = jnp.asarray(exp.chains.scale_multihot)
    return jax.jit(lambda p, c: denormalize_rewards(
        p, reward_matrix(p, rcfg, c, mo, sh)))


def make_legacy_window(exp, server, params, rcfg, budget):
    """The seed's serving path, packaged for reuse (CLI --legacy and
    benchmarks/bench_serve.py share ONE definition of "legacy"): four
    host/device crossings per window - jitted scoring, NumPy controller
    (decide + guard + synchronous dual), jitted cascade execution.

    Returns (controller, window_fn) with window_fn(ctx, rows) ->
    (decisions, revenue).
    """
    import jax.numpy as jnp

    from repro.core.budget import BudgetController

    score = make_legacy_scorer(exp, rcfg)
    ctl = BudgetController(exp.chains, budget)

    def window(ctx, rows):
        rewards = np.asarray(score(params, jnp.asarray(ctx, jnp.float32)))
        dec = ctl.step_window(rewards)
        rev, _ = server.serve(rows, dec)
        return dec, rev

    return ctl, window


def _legacy_loop(exp, server, params, rcfg, sizes, budget):
    import time

    ctl, window = make_legacy_window(exp, server, params, rcfg, budget)
    rng = np.random.default_rng(0)
    n_eval = exp.ctx_eval.shape[0]
    total_rev = total_flops = 0.0
    print(f"{'win':>4} {'n':>5} {'spend/budget':>13} {'lam':>12} "
          f"{'downgraded':>10} {'revenue':>9} {'window_ms':>9}")
    for t, n in enumerate(sizes):
        t0 = time.perf_counter()
        rows = rng.integers(0, n_eval, n)
        dec, rev = window(exp.ctx_eval[rows], rows)
        dt = (time.perf_counter() - t0) * 1e3
        s = ctl.stats[-1]
        total_rev += rev.sum()
        total_flops += s.spend
        print(f"{t:>4} {n:>5} {s.spend / s.budget:>13.3f} {s.lam:>12.3e} "
              f"{s.downgraded:>10d} {rev.sum():>9.1f} {dt:>9.2f}")
    return total_rev, total_flops


def _build_ci_trace(args):
    from repro.carbon.intensity import (constant_trace, diurnal_trace,
                                        load_ci_csv, solar_duck_trace)

    if args.ci_csv:
        return load_ci_csv(args.ci_csv)
    if args.ci_trace == "diurnal":
        return diurnal_trace(mean=args.ci_mean)
    if args.ci_trace == "duck":
        return solar_duck_trace(mean=args.ci_mean)
    return constant_trace(args.ci_mean)


def _carbon_stream(server, params, rcfg, sizes, cb, ledger,
                   sample_window, pricing, mesh=None, forecast=False,
                   prefetch=2, obs=None, wrap_source=None):
    """Fused-pipeline carbon day: per-window gram budgets + CI-scaled
    costs threaded through run_stream (carbon pricing) or the
    effective-FLOPs-budget reduction (flops pricing); ``forecast`` aims
    each nearline dual update at the NEXT window's CI."""
    sched = cb.schedule(len(sizes))
    pipe = ServingPipeline(server, params, rcfg, cb.flops_ref,
                           ledger=ledger, mesh=mesh, obs=obs)
    if wrap_source is not None:  # multi-host: route windows over hosts
        sample_window = wrap_source(pipe, sample_window)
    if pricing == "carbon":
        st = run_stream(pipe, sizes, sample_window,
                        budget_trace=sched["grams"],
                        scale_trace=sched["scale"], forecast=forecast,
                        prefetch=prefetch, obs=obs)
    else:
        st = run_stream(pipe, sizes, sample_window,
                        budget_trace=sched["flops_budget"],
                        forecast=forecast, prefetch=prefetch, obs=obs)
    print(f"{'win':>4} {'n':>5} {'ci_g/kwh':>9} {'spend/budget':>13} "
          f"{'lam':>12} {'downgraded':>10} {'revenue':>9} "
          f"{'dispatch_ms':>11}")
    for t, r in enumerate(st.windows):
        print(f"{t:>4} {r.n_valid:>5} {sched['ci'][t]:>9.1f} "
              f"{_f(r.spend) / r.budget:>13.3f} "
              f"{_f(r.lam_after):>12.3e} {int(r.downgraded):>10d} "
              f"{r.revenue_np.sum():>9.1f} {st.dispatch_ms[t]:>11.2f}")
    total_flops = float(sum(_f(r.flops) for r in st.windows))
    print(f"[serve] {len(sizes)} windows in {st.wall_s:.2f}s "
          f"({len(sizes) / st.wall_s:.1f} win/s)")
    return st.total_revenue, total_flops


def _geo_stream(chains, server, params, rcfg, sizes, flops_budget, args,
                sample_window, mesh=None, obs=None, wrap_source=None):
    """Two-region geo-shifted serving day: (R,) per-region gram budgets
    and kappa*CI_r(t) cost scales through the fused router, per-region
    CarbonLedgers merged into one region-attributed CSV."""
    import os

    from repro.carbon.controller import grams_per_flop
    from repro.carbon.intensity import two_region_traces
    from repro.carbon.ledger import DAY_S, CarbonLedger, geo_report_csv
    from repro.core.primal_dual import DualDescentConfig
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis)

    traces = two_region_traces(mean=args.ci_mean,
                               offset_h=args.geo_offset_h)
    names = list(traces)
    n_w = len(sizes)
    window_s = DAY_S / n_w
    phase_s = args.ci_phase_h * 3600.0
    kpf = grams_per_flop(1.0)
    ci_w = {r: traces[r].resample(n_w, window_s, phase_s=phase_s)
            for r in names}
    scale_trace = np.stack([kpf * ci_w[r] for r in names], axis=1)
    g_total = flops_budget * kpf * args.ci_mean
    budget_trace = np.full((n_w, len(names)), g_total / len(names))
    split = args.geo_split
    print(f"[serve] geo day: {n_w} windows x {window_s / 3600.0:.2f} h, "
          f"regions {names} offset {args.geo_offset_h:.0f} h, "
          f"{g_total / len(names):.3e} g/window/region, split "
          f"{split}")
    spec = ConstraintSpec([
        RegionAxis(len(names), names=tuple(names), split=split),
        GlobalAxis(budget=float(flops_budget), pricing="carbon"),
    ])
    pipe = ServingPipeline.from_spec(
        server, params, rcfg, spec, mesh=mesh, obs=obs,
        dual_cfg=DualDescentConfig(max_iters=300, step_decay=0.98))
    if wrap_source is not None:  # multi-host: route windows over hosts
        sample_window = wrap_source(pipe, sample_window)
    st = run_stream(pipe, sizes, sample_window,
                    budget_trace=budget_trace, scale_trace=scale_trace,
                    forecast=args.ci_forecast, prefetch=args.prefetch,
                    obs=obs)
    header = " ".join(f"{'ci_' + r[-1]:>6} {'spd/bud_' + r[-1]:>9}"
                      for r in names)
    print(f"{'win':>4} {'n':>5} {'split':>12} {header} {'revenue':>9} "
          f"{'dispatch_ms':>11}")
    ledgers = {
        r: CarbonLedger(chains, traces[r], window_s=window_s,
                        phase_s=phase_s, name=r, obs=obs,
                        embodied_g_per_device_h=args.embodied_g_per_device_h,
                        n_devices=args.devices)
        for r in names}
    total_rev = total_flops = 0.0
    for t, r in enumerate(st.windows):
        regions = r.regions_np
        dec = r.decisions_np
        split = [int(x) for x in np.bincount(regions,
                                             minlength=len(names))]
        spends = _host_np(r.region_spend)
        cols = " ".join(
            f"{ci_w[n_][t]:>6.0f} "
            f"{spends[k] / r.k_budget[k]:>9.3f}"
            for k, n_ in enumerate(names))
        print(f"{t:>4} {r.n_valid:>5} {str(split):>12} {cols} "
              f"{r.revenue_np.sum():>9.1f} {st.dispatch_ms[t]:>11.2f}")
        for k, n_ in enumerate(names):
            ledgers[n_].record(dec[regions == k], t=t, ci=ci_w[n_][t])
        total_rev += float(r.revenue_np.sum())
        total_flops += _f(r.flops)
    print(f"[serve] {n_w} windows in {st.wall_s:.2f}s "
          f"({n_w / st.wall_s:.1f} win/s)")
    report_path = args.carbon_report or os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results",
        "carbon_report_geo.csv")
    geo_report_csv(ledgers, report_path)
    print(f"\n[serve] per-region carbon ledger -> "
          f"{os.path.abspath(report_path)}")
    for n_, led in ledgers.items():
        rep = led.report()
        print(f"    {n_}: {rep['gco2e']:.4e} g operational + "
              f"{rep['embodied_gco2e']:.4e} g embodied = "
              f"{rep['total_gco2e']:.4e} gCO2e "
              f"({rep['n_requests']} requests)")
    return total_rev, total_flops


def _geotenants_stream(chains, server, params, rcfg, sizes,
                       flops_budget, args, sample_window, mesh=None,
                       obs=None, wrap_source=None):
    """The combined tenant x region day: per-tenant gram budgets AND
    per-region gram caps priced in one fused pass (the ConstraintSpec
    headline).  Budget trace entries are the (T + R,) concatenation -
    tenant grams first - and the per-(tenant, region) spends come back
    in WindowResult.tr_spend."""
    import os

    from repro.carbon.controller import grams_per_flop
    from repro.carbon.intensity import two_region_traces
    from repro.carbon.ledger import DAY_S, CarbonLedger, geo_report_csv
    from repro.core.primal_dual import DualDescentConfig
    from repro.serving.spec import (ConstraintSpec, GlobalAxis,
                                    RegionAxis, TenantAxis)

    if args.tenant_mode == "independent":
        raise SystemExit("--scenario geotenants composes tenants and "
                         "regions in ONE pipeline; --tenant-mode "
                         "independent contradicts that (use shared or "
                         "priced)")
    t_n = args.tenants
    traces = two_region_traces(mean=args.ci_mean,
                               offset_h=args.geo_offset_h)
    names = list(traces)
    r_n = len(names)
    n_w = len(sizes)
    window_s = DAY_S / n_w
    phase_s = args.ci_phase_h * 3600.0
    kpf = grams_per_flop(1.0)
    ci_w = {r: traces[r].resample(n_w, window_s, phase_s=phase_s)
            for r in names}
    scale_trace = np.stack([kpf * ci_w[r] for r in names], axis=1)
    g_total = flops_budget * kpf * args.ci_mean  # grams per window
    # distinct per-tenant tightness: budgets spread by --tenant-spread
    # (ratio of the loosest to the tightest tenant), summing to g_total
    w = np.linspace(1.0, args.tenant_spread, t_n)
    tenant_g = (g_total * w / w.sum()).astype(np.float64)
    region_g = np.full(r_n, args.region_cap_frac * g_total)
    budget_trace = np.tile(np.concatenate([tenant_g, region_g]),
                           (n_w, 1))
    split = args.geo_split
    print(f"[serve] geotenants day: {n_w} windows x "
          f"{window_s / 3600.0:.2f} h, {t_n} tenants x {r_n} regions "
          f"(offset {args.geo_offset_h:.0f} h), tenant grams "
          + "/".join(f"{g:.2e}" for g in tenant_g)
          + f", region cap {region_g[0]:.2e} g "
          f"({args.region_cap_frac:.0%} of total), split {split}, "
          f"tenant-mode {args.tenant_mode}")
    spec = ConstraintSpec([
        TenantAxis(tuple(tenant_g),
                   priced=args.tenant_mode == "priced"),
        RegionAxis(r_n, names=tuple(names), split=split),
        GlobalAxis(pricing="carbon"),
    ])
    pipe = ServingPipeline.from_spec(
        server, params, rcfg, spec, mesh=mesh, obs=obs,
        dual_cfg=DualDescentConfig(max_iters=300, step_decay=0.98))
    if wrap_source is not None:  # multi-host: route windows over hosts
        sample_window = wrap_source(pipe, sample_window)
    st = run_stream(pipe, sizes, sample_window,
                    budget_trace=budget_trace, scale_trace=scale_trace,
                    forecast=args.ci_forecast, prefetch=args.prefetch,
                    obs=obs)
    t_hdr = " ".join(f"{'t' + str(k) + ' s/b':>8}" for k in range(t_n))
    r_hdr = " ".join(f"{'r_' + r[-1] + ' s/b':>8}" for r in names)
    print(f"{'win':>4} {'n':>5} {'split':>12} {t_hdr} {r_hdr} "
          f"{'revenue':>9} {'dispatch_ms':>11}")
    ledgers = {
        r: CarbonLedger(chains, traces[r], window_s=window_s,
                        phase_s=phase_s, name=r, obs=obs,
                        embodied_g_per_device_h=args.embodied_g_per_device_h,
                        n_devices=args.devices)
        for r in names}
    total_rev = total_flops = 0.0
    tenant_spend = np.zeros(t_n)
    for t, r in enumerate(st.windows):
        regions = r.regions_np
        dec = r.decisions_np
        split_c = [int(x) for x in np.bincount(regions, minlength=r_n)]
        tr = _host_np(r.tr_spend)
        tenant_spend += tr.sum(axis=1)
        t_cols = " ".join(f"{tr[k].sum() / tenant_g[k]:>8.3f}"
                          for k in range(t_n))
        r_cols = " ".join(f"{tr[:, k].sum() / region_g[k]:>8.3f}"
                          for k in range(r_n))
        print(f"{t:>4} {r.n_valid:>5} {str(split_c):>12} {t_cols} "
              f"{r_cols} {r.revenue_np.sum():>9.1f} "
              f"{st.dispatch_ms[t]:>11.2f}")
        for k, n_ in enumerate(names):
            ledgers[n_].record(dec[regions == k], t=t, ci=ci_w[n_][t])
        total_rev += float(r.revenue_np.sum())
        total_flops += _f(r.flops)
    print(f"[serve] {n_w} windows in {st.wall_s:.2f}s "
          f"({n_w / st.wall_s:.1f} win/s)")
    print("[serve] day totals, per tenant (spend_g / budget_g): "
          + " ".join(f"t{k}={tenant_spend[k] / (n_w * tenant_g[k]):.3f}"
                     for k in range(t_n)))
    report_path = args.carbon_report or os.path.join(
        os.path.dirname(__file__), "..", "..", "..", "results",
        "carbon_report_geotenants.csv")
    geo_report_csv(ledgers, report_path)
    print(f"[serve] per-region carbon ledger -> "
          f"{os.path.abspath(report_path)}")
    return total_rev, total_flops


def _legacy_carbon_loop(exp, server, params, rcfg, sizes, cb, ledger,
                        sample_window, pricing):
    """Host-loop carbon day on CarbonBudgetController (the --legacy twin
    of _carbon_stream)."""
    import jax.numpy as jnp

    from repro.carbon.controller import CarbonBudgetController

    score = make_legacy_scorer(exp, rcfg)
    ctl = CarbonBudgetController(exp.chains, cb, ledger=ledger,
                                 pricing=pricing)
    total_rev = total_flops = 0.0
    print(f"{'win':>4} {'n':>5} {'ci_g/kwh':>9} {'spend_g/budget_g':>17} "
          f"{'lam':>12} {'downgraded':>10} {'revenue':>9}")
    for t, n in enumerate(sizes):
        ctx, rows = sample_window(t, n)
        rewards = np.asarray(score(params, jnp.asarray(ctx, jnp.float32)))
        dec = ctl.step_window(rewards)
        rev, _ = server.serve(rows, dec)
        s = ctl.stats[-1]
        total_rev += rev.sum()
        total_flops += s.flops
        print(f"{t:>4} {n:>5} {s.ci_g_per_kwh:>9.1f} "
              f"{s.spend_g / s.budget_g:>17.3f} {s.lam:>12.3e} "
              f"{s.downgraded:>10d} {rev.sum():>9.1f}")
    return total_rev, total_flops


def main():
    ap = argparse.ArgumentParser(
        description="GreenFlow streaming serving (fused pipeline)")
    ap.add_argument("--windows", type=int, default=12)
    ap.add_argument("--requests", type=int, default=96,
                    help="requests per normal window")
    ap.add_argument("--scenario", default="spike",
                    choices=tuple(SCENARIOS))
    ap.add_argument("--spike", type=float, default=3.0,
                    help="traffic multiplier on the spike windows")
    ap.add_argument("--tenants", type=int, default=4)
    ap.add_argument("--tenant-mode", default="shared",
                    choices=("shared", "priced", "independent"))
    ap.add_argument("--budget-frac", type=float, default=0.6)
    ap.add_argument("--shards", type=int, default=0,
                    help=">0: shard_map over an N-way request mesh")
    ap.add_argument("--processes", type=int, default=0,
                    help=">1: join a jax.distributed group of N serve "
                         "processes (one per host); the request mesh "
                         "then spans every host's devices and each "
                         "host generates its slice of every window "
                         "(see the multi-host runbook above)")
    ap.add_argument("--process-id", type=int, default=None,
                    help="this process's rank in the --processes group "
                         "(default: $GREENFLOW_PROCESS_ID)")
    ap.add_argument("--coordinator", default=None,
                    help="host:port of the jax.distributed coordinator "
                         "(process 0's address; default: "
                         "$GREENFLOW_COORDINATOR)")
    ap.add_argument("--small", action="store_true", help="CI-sized world")
    ap.add_argument("--source", default="table",
                    choices=("table", "generated", "memmap"),
                    help="request source: index the materialized eval "
                         "universe, stream a hash-generated one, or "
                         "replay memmapped tables")
    ap.add_argument("--users", type=int, default=100_000,
                    help="--source generated: size of the streamed "
                         "user universe")
    ap.add_argument("--replay-dir", default=None,
                    help="--source memmap: directory for the saved "
                         ".npy universe (default: "
                         "results/replay_universe)")
    ap.add_argument("--legacy", action="store_true",
                    help="run the seed's host loop instead")
    ap.add_argument("--ci-trace", default="diurnal",
                    choices=("diurnal", "duck", "constant"),
                    help="grid-intensity shape for --scenario carbon")
    ap.add_argument("--ci-csv", default=None,
                    help="load the intensity trace from an exported CSV "
                         "(ichnos parse_ci_intervals layouts)")
    ap.add_argument("--ci-mean", type=float, default=450.0,
                    help="mean grid intensity, gCO2e/kWh")
    ap.add_argument("--ci-phase-h", type=float, default=0.0,
                    help="hours the intensity day leads the traffic day")
    ap.add_argument("--carbon-pricing", default="carbon",
                    choices=("carbon", "flops"))
    ap.add_argument("--carbon-report", default=None,
                    help="CSV path for the carbon ledger (default: "
                         "results/carbon_report.csv, georegions: "
                         "results/carbon_report_geo.csv)")
    ap.add_argument("--ci-forecast", action="store_true",
                    help="warm-start the nearline dual on the NEXT "
                         "window's known CI (carbon/georegions)")
    ap.add_argument("--geo-offset-h", type=float, default=8.0,
                    help="hours region b's CI peak trails region a's")
    ap.add_argument("--geo-split", default="flow",
                    choices=("flow", "argmax"),
                    help="region-tie rounding: 'flow' = exact "
                         "proportional flow split of the degenerate "
                         "window, 'argmax' = the historical knife edge")
    ap.add_argument("--tenant-spread", type=float, default=4.0,
                    help="geotenants: gram-budget ratio of the loosest "
                         "to the tightest tenant")
    ap.add_argument("--region-cap-frac", type=float, default=0.6,
                    help="geotenants: each region's per-window gram cap "
                         "as a fraction of the total tenant grams")
    ap.add_argument("--embodied-g-per-device-h", type=float, default=None,
                    help="embodied-carbon amortization per device-hour "
                         "(default: the ichnos-style server constant; "
                         "0 disables the ledger line)")
    ap.add_argument("--devices", type=int, default=1,
                    help="devices metered for embodied carbon (per "
                         "region in georegions)")
    ap.add_argument("--prefetch", type=int, default=2,
                    help="window-prep prefetch queue depth (0 = the "
                         "sequential double-buffered reference path)")
    ap.add_argument("--cache-dir", default=None,
                    help="persistent JAX compilation-cache directory: "
                         "repeat runs skip XLA compiles entirely")
    ap.add_argument("--metrics-out", default=None,
                    help="write a Prometheus-text metrics snapshot here "
                         "at exit (plus a JSON snapshot at PATH.json and "
                         "the per-window JSONL flight log at "
                         "PATH.windows.jsonl)")
    ap.add_argument("--trace-out", default=None,
                    help="write the host span trace as Chrome "
                         "trace-event JSON (open in ui.perfetto.dev or "
                         "chrome://tracing; prefetch and serving "
                         "threads land on separate tracks)")
    ap.add_argument("--obs-interval", type=int, default=0,
                    help=">0: print a compact live telemetry line every "
                         "N windows")
    ap.add_argument("--profile-dir", default=None,
                    help="run under jax.profiler.trace writing here; "
                         "host spans become TraceAnnotations lined up "
                         "against XLA device events")
    args = ap.parse_args()
    multihost = False
    host = None
    if args.processes > 1 or args.coordinator:
        from repro.distributed import multihost as mh
        if args.legacy:
            raise SystemExit("--processes runs the fused SPMD pipeline; "
                             "--legacy is single-process")
        if args.source == "table":
            raise SystemExit("--processes needs a streaming --source "
                             "(generated or memmap): every host "
                             "generates its own slice of each window")
        if args.shards > 0:
            raise SystemExit("--shards picks a device subset; with "
                             "--processes the mesh is always the full "
                             "process-spanning device set (drop "
                             "--shards)")
        multihost = mh.initialize(
            coordinator=args.coordinator,
            num_processes=args.processes or None,
            process_id=args.process_id)
        if not multihost:
            raise SystemExit("--processes > 1 needs a --coordinator "
                             "(or $GREENFLOW_COORDINATOR)")
        host = mh.host_label()
        # per-host artifact files: suffix every output with the label
        for attr in ("metrics_out", "trace_out"):
            if getattr(args, attr):
                setattr(args, attr, getattr(args, attr) + "." + host)
        print(f"[serve] multihost: {mh.host_report()}")
    if args.cache_dir:
        import jax
        jax.config.update("jax_compilation_cache_dir", args.cache_dir)
        jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
        jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
        print(f"[serve] jax compilation cache -> {args.cache_dir}")
    if args.embodied_g_per_device_h is None:  # resolved ONCE for every
        from repro.carbon.ledger import \
            DEFAULT_EMBODIED_G_PER_DEVICE_H  # scenario that meters it
        args.embodied_g_per_device_h = DEFAULT_EMBODIED_G_PER_DEVICE_H

    obs = None
    if (args.metrics_out or args.trace_out or args.obs_interval
            or args.profile_dir):
        from repro.obs import Obs, WindowEventLog
        obs = Obs(events=(WindowEventLog(args.metrics_out
                                         + ".windows.jsonl")
                          if args.metrics_out else None),
                  interval=args.obs_interval,
                  annotate=bool(args.profile_dir), host=host)
    if args.profile_dir:
        import jax
        jax.profiler.start_trace(args.profile_dir)
        print(f"[obs] jax profiler trace -> {args.profile_dir}")

    print("[serve] building world + training cascade & reward models ...")
    exp, server, params, rcfg = build_serving_stack(
        serve_config(small=args.small), verbose=True)
    chains = exp.chains
    budget = args.budget_frac * chains.costs.max() * args.requests
    n_tenants = (args.tenants
                 if args.scenario in ("tenants", "geotenants") else 1)
    sc = TrafficScenario(args.scenario, args.windows, args.requests,
                         spike_mult=args.spike, n_tenants=n_tenants)
    sizes = sc.window_sizes()

    if args.source != "table":
        if args.legacy:
            raise SystemExit("--legacy indexes the materialized server; "
                             "the streaming --source forms have no "
                             "legacy loop")
        from repro.data.request_source import (GeneratedSource,
                                               TableReplaySource)
        if args.source == "generated":
            from dataclasses import replace

            from repro.data.synthetic import StreamingWorld
            wcfg = replace(exp.cfg.world, n_users=args.users)
            source = GeneratedSource(StreamingWorld.build(wcfg),
                                     exp.models, chains,
                                     expose=exp.cfg.expose, obs=obs)
            print(f"[serve] source: generated stream over "
                  f"U={args.users:,} hash-materialized users (no per-"
                  f"user tables held)")
        else:
            import os
            path = args.replay_dir or os.path.join(
                os.path.dirname(__file__), "..", "..", "..", "results",
                "replay_universe")
            if not os.path.exists(os.path.join(path, "meta.json")):
                print(f"[serve] saving replay universe -> {path}")
                TableReplaySource.from_server(
                    server, exp.ctx_eval).save(path)
            source = TableReplaySource.load(path, chains)
            print(f"[serve] source: memmapped replay of "
                  f"U={source.n_users:,} users from {path}")
        # streaming pipelines build over the layout-only universe; the
        # source plugs straight into run_stream (duck-typed .window)
        server = source.universe
        sample_window = source
    else:
        rng = np.random.default_rng(0)
        n_eval = exp.ctx_eval.shape[0]

        def sample_window(t, n):
            rows = rng.integers(0, n_eval, n)
            return exp.ctx_eval[rows], rows

    mesh = None
    wrap_source = None
    if multihost:
        from repro.launch.mesh import make_request_mesh
        mesh = make_request_mesh()  # spans every process's devices

        def wrap_source(pipe, src_):
            from repro.distributed.multihost import MultihostSource
            return MultihostSource(src_, pipe)
    elif args.shards > 0 and not args.legacy:
        from repro.launch.mesh import make_request_mesh
        mesh = make_request_mesh(args.shards)

    if args.scenario == "carbon":
        # the run is one 24 h day: the diurnal traffic curve spans the
        # n_windows horizon, so the intensity day must span it too
        import os

        from repro.carbon.controller import CarbonBudget
        from repro.carbon.ledger import DAY_S, CarbonLedger

        trace = _build_ci_trace(args)
        window_s = DAY_S / len(sizes)
        cb = CarbonBudget.from_flops(
            float(budget), trace, window_s=window_s,
            phase_s=args.ci_phase_h * 3600.0)
        ledger = CarbonLedger(
            chains, trace, window_s=window_s, phase_s=cb.phase_s,
            embodied_g_per_device_h=args.embodied_g_per_device_h,
            n_devices=args.devices, obs=obs)
        print(f"[serve] carbon day: {len(sizes)} windows x "
              f"{window_s / 3600.0:.2f} h, CI '{trace.name}' mean "
              f"{trace.mean():.0f} g/kWh, budget "
              f"{cb.grams_per_window:.3e} g/window "
              f"({args.carbon_pricing} pricing)")
        if args.legacy:
            total_rev, total_flops = _legacy_carbon_loop(
                exp, server, params, rcfg, sizes, cb, ledger,
                sample_window, args.carbon_pricing)
        else:
            total_rev, total_flops = _carbon_stream(
                server, params, rcfg, sizes, cb, ledger,
                sample_window, args.carbon_pricing, mesh=mesh,
                forecast=args.ci_forecast, prefetch=args.prefetch,
                obs=obs, wrap_source=wrap_source)
        report_path = args.carbon_report or os.path.join(
            os.path.dirname(__file__), "..", "..", "..", "results",
            "carbon_report.csv")
        ledger.to_csv(report_path)
        rep = ledger.report()
        print(f"\n[serve] carbon ledger -> {os.path.abspath(report_path)}")
        print(f"    realized      {rep['kwh']:.4e} kWh  "
              f"{rep['gco2e']:.4e} gCO2e")
        print(f"    all-max base  {rep['baseline_kwh']:.4e} kWh  "
              f"{rep['baseline_gco2e']:.4e} gCO2e")
        print(f"    embodied      {rep['embodied_gco2e']:.4e} gCO2e "
              f"({args.devices} device(s) amortized)  total "
              f"{rep['total_gco2e']:.4e} gCO2e")
        print(f"    daily savings {rep['daily_saved_kwh']:.4e} kWh/day  "
              f"{rep['daily_saved_tco2e']:.4e} tCO2e/day "
              f"(vs all-max-chain)")
        for s, v in rep["stage_flops"].items():
            print(f"    stage {s:10s} {v:.4e} FLOPs")
        for m, v in rep["model_flops"].items():
            print(f"    model {m:10s} {v:.4e} FLOPs")
    elif args.scenario == "georegions":
        if args.legacy:
            raise SystemExit("--scenario georegions has no legacy loop "
                             "(the router exists only in the fused pass)")
        total_rev, total_flops = _geo_stream(
            chains, server, params, rcfg, sizes, float(budget), args,
            sample_window, mesh=mesh, obs=obs, wrap_source=wrap_source)
    elif args.scenario == "geotenants":
        if args.legacy:
            raise SystemExit("--scenario geotenants has no legacy loop "
                             "(the combined tenant x region pass exists "
                             "only in the fused pipeline)")
        total_rev, total_flops = _geotenants_stream(
            chains, server, params, rcfg, sizes, float(budget), args,
            sample_window, mesh=mesh, obs=obs, wrap_source=wrap_source)
    elif args.legacy:
        total_rev, total_flops = _legacy_loop(exp, server, params, rcfg,
                                              sizes, budget)
    else:
        if args.scenario == "tenants" and args.tenant_mode == "independent":
            if multihost:
                raise SystemExit("--tenant-mode independent runs one "
                                 "pipeline per tenant; compose with "
                                 "--processes via shared or priced")
            pipes = [ServingPipeline(server, params, rcfg,
                                     budget / n_tenants, obs=obs)
                     for _ in range(n_tenants)]
            stats = []
            for p in pipes:
                stats.append(run_stream(
                    p, [n // n_tenants for n in sizes], sample_window,
                    prefetch=args.prefetch, obs=obs))
            total_rev = sum(s.total_revenue for s in stats)
            total_flops = sum(s.total_spend for s in stats)
            for t in range(len(sizes)):
                spends = [float(s.windows[t].spend) for s in stats]
                print(f"win {t:>3}: per-tenant spend/budget "
                      + " ".join(f"{sp / (budget / n_tenants):.3f}"
                                 for sp in spends))
        else:
            tb = None
            if args.scenario == "tenants":  # shared or per-tenant prices
                tb = np.full(n_tenants, budget / n_tenants, np.float32)
            pipe = ServingPipeline(server, params, rcfg, budget,
                                   mesh=mesh, tenant_budgets=tb,
                                   tenant_mode=(args.tenant_mode
                                                if tb is not None
                                                else "shared"), obs=obs)
            if wrap_source is not None:  # multi-host window routing
                sample_window = wrap_source(pipe, sample_window)
            st = run_stream(pipe, sizes, sample_window,
                            prefetch=args.prefetch, obs=obs)
            total_rev, total_flops = st.total_revenue, st.total_spend
            priced = tb is not None and args.tenant_mode == "priced"
            lam_hdr = "lam(per-tenant)" if priced else "lam"
            print(f"{'win':>4} {'n':>5} {'spend/budget':>13} "
                  f"{lam_hdr:>12} {'downgraded':>10} {'revenue':>9} "
                  f"{'dispatch_ms':>11}")
            for t, r in enumerate(st.windows):
                if priced:
                    lam_disp = "/".join(
                        f"{v:.2e}" for v in _host_np(r.lam_after))
                else:
                    lam_disp = f"{_f(r.lam_after):.3e}"
                print(f"{t:>4} {r.n_valid:>5} "
                      f"{_f(r.spend) / r.budget:>13.3f} "
                      f"{lam_disp:>12} "
                      f"{int(r.downgraded):>10d} "
                      f"{r.revenue_np.sum():>9.1f} "
                      f"{st.dispatch_ms[t]:>11.2f}")
            c_min = float(chains.costs.min())
            print(f"[serve] {len(sizes)} windows in {st.wall_s:.2f}s "
                  f"({len(sizes) / st.wall_s:.1f} win/s), worst overshoot "
                  f"vs cap: {st.overshoot(c_min) * 100:.3f}%")

    print("\n[serve] PFEC (GreenFlow serving run):")
    rep = pfec_report(clicks=float(total_rev), flops=float(total_flops))
    for k, v in rep.as_row().items():
        print(f"    {k:14s} {v}")

    if args.profile_dir:
        import jax
        jax.profiler.stop_trace()
    if obs is not None:
        import os
        if args.metrics_out:
            prom, js = obs.export(args.metrics_out)
            print(f"[obs] metrics -> {prom} (+ {os.path.basename(js)})")
            if obs.events is not None:
                print(f"[obs] window log -> {obs.events.path} "
                      f"({obs.events.rows_written} rows)")
        if args.trace_out:
            path = obs.tracer.write(args.trace_out)
            print(f"[obs] trace -> {path} "
                  f"({len(obs.tracer.events)} spans; open in "
                  f"ui.perfetto.dev)")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
