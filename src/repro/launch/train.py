"""Generic training driver.

    PYTHONPATH=src python -m repro.launch.train --arch din --steps 300
    PYTHONPATH=src python -m repro.launch.train --arch gemma2-2b \
        --preset smoke --steps 50 --ckpt-dir /tmp/ck --resume

Selects the arch from the registry, builds the matching synthetic data
pipeline, and drives training/trainer.Trainer (checkpoint/resume/
preemption handling included).  ``--preset smoke`` (default) trains the
reduced config (CPU-sized); ``--preset full`` uses the assigned config
(real-hardware scale).
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro.configs import ARCH_IDS, get_arch
from repro.data.pipeline import DeterministicPipeline
from repro.training.optimizer import AdamW, cosine_schedule, wsd_schedule
from repro.training.trainer import (Trainer, TrainerConfig, build_train_step,
                                    init_state)


def make_pipeline(mod, cfg, global_batch: int, seed: int):
    def fn(rng, step, lo, hi):
        b = mod.smoke_batch(rng, cfg)
        return {k: np.asarray(v) for k, v in b.items()}

    return DeterministicPipeline(fn, global_batch, seed=seed)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=ARCH_IDS)
    ap.add_argument("--preset", choices=("smoke", "full"), default="smoke")
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--schedule", choices=("cosine", "wsd"), default="cosine")
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    mod = get_arch(args.arch)
    cfg = mod.smoke_config() if args.preset == "smoke" else mod.full_config()
    params = mod.init_smoke(jax.random.PRNGKey(args.seed), cfg)
    n_params = sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
    print(f"[train] arch={args.arch} preset={args.preset} "
          f"params={n_params/1e6:.2f}M steps={args.steps}")

    opt = AdamW(weight_decay=0.01)
    if args.schedule == "wsd":
        sched = wsd_schedule(args.lr, warmup=args.steps // 10,
                             stable=int(args.steps * 0.7),
                             decay=args.steps // 5)
    else:
        sched = cosine_schedule(args.lr, warmup=args.steps // 10,
                                total=args.steps)
    step = build_train_step(lambda p, b: mod.smoke_loss(p, cfg, b), opt,
                            sched, n_microbatches=args.microbatches,
                            donate=False)
    state = init_state(params, opt)
    pipe = make_pipeline(mod, cfg, args.batch, args.seed)

    trainer = Trainer(
        TrainerConfig(total_steps=args.steps, ckpt_dir=args.ckpt_dir,
                      ckpt_every=args.ckpt_every,
                      log_every=max(1, args.steps // 10)),
        step, state, pipe)
    trainer.install_preemption_handler()
    if args.resume:
        trainer.maybe_resume()
    out = trainer.run()
    final = out["final"]
    print(f"[train] done in {out['wall_s']:.1f}s "
          f"final_loss={final.get('loss', float('nan')):.4f}")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
