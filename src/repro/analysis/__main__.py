"""CLI: ``python -m repro.analysis`` (see package docstring)."""
from __future__ import annotations

import argparse
import os
import sys

from repro.analysis import lint
from repro.analysis.rules import BY_CODE, RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="greenflow-check: invariant lint + jaxpr audit")
    ap.add_argument("paths", nargs="*", default=None,
                    help="files/dirs to lint (default: src)")
    ap.add_argument("--format", choices=("text", "json"),
                    default="text")
    ap.add_argument("--out", default=None,
                    help="also write the report to this file")
    ap.add_argument("--rules", default=None,
                    help="comma-separated rule codes (default: all)")
    ap.add_argument("--show-suppressed", action="store_true",
                    help="list suppressed findings in text output")
    ap.add_argument("--list-rules", action="store_true")
    ap.add_argument("--jaxpr-audit", default=None, metavar="SPECS",
                    help="trace the fused serve_window pass for these "
                         "comma-separated specs (plain,geotenants) and "
                         "audit the lowerings; skips the AST lint "
                         "unless paths are also given")
    args = ap.parse_args(argv)

    if args.list_rules:
        for r in RULES:
            print(f"{r.CODE}  {r.TITLE}\n       {r.RATIONALE}")
        return 0

    rules = None
    if args.rules:
        codes = [c.strip().upper() for c in args.rules.split(",")]
        unknown = [c for c in codes if c not in BY_CODE]
        if unknown:
            ap.error(f"unknown rules {unknown}; known: "
                     f"{sorted(BY_CODE)}")
        rules = [BY_CODE[c] for c in codes]

    findings: list = []
    ran_lint = False
    if args.paths or not args.jaxpr_audit:
        paths = args.paths or ["src"]
        findings = lint.lint_paths(paths, rules=rules)
        ran_lint = True

    audit = None
    if args.jaxpr_audit:
        from repro.analysis.jaxpr_audit import SPECS, run_audit
        specs = tuple(s.strip() for s in args.jaxpr_audit.split(",")
                      if s.strip()) or SPECS
        audit = run_audit(specs)

    if args.format == "json":
        report = lint.render_json(findings, audit=audit)
    else:
        parts = []
        if ran_lint:
            parts.append(lint.render_text(
                findings, show_suppressed=args.show_suppressed))
        if audit is not None:
            for c in audit["checks"]:
                status = "ok" if c["ok"] else "FAIL"
                parts.append(f"jaxpr-audit {c['name']}: {status} "
                             f"({c['invars']} invars, "
                             f"donated={c['donated']})")
                parts.extend(f"  - {p}" for p in c["problems"])
            parts.append("jaxpr-audit: %s" % (
                "clean" if audit["ok"] else "FAILED"))
        report = "\n".join(parts)
    print(report)
    if args.out:
        parent = os.path.dirname(args.out)
        if parent:
            os.makedirs(parent, exist_ok=True)
        with open(args.out, "w", encoding="utf-8") as f:
            f.write(lint.render_json(findings, audit=audit)
                    if args.out.endswith(".json") else report)

    bad = any(not f.suppressed for f in findings)
    if audit is not None and not audit["ok"]:
        bad = True
    return 1 if bad else 0


if __name__ == "__main__":
    sys.exit(main())
