"""Trace-time auditor for the fused ``serve_window`` pass.

The AST rules catch what source text shows; this layer checks what XLA
actually sees.  It builds a tiny UNTRAINED serving stack (fabricated
seeded stage scores -- tracing needs shapes and dtypes, not trained
weights), runs one window through ``ServingPipeline.serve_window`` to
populate the jit cache, captures the exact arguments of a second
window by wrapping the cached callables, and then statically audits
every (main, dual) jitted fn via ``jax.make_jaxpr`` + ``.lower()``:

* **no f64** -- no ``convert_element_type`` to float64 and no f64/c128
  intermediate anywhere in the jaxpr (an accidental x64 upcast doubles
  transfer bytes and breaks cross-backend bit parity);
* **no host callbacks** -- no ``pure_callback`` / ``io_callback`` /
  debug-print primitives (each is a hidden host round-trip per window);
* **donations honored** -- every ``donate_argnums`` declaration must
  survive lowering as a ``tf.aliasing_output`` input alias, and the
  "Some donated buffers were not usable" warning is promoted to a
  failure (PR 9's silent un-donation relayout);
* **bounded transfers** -- the flattened argument count of each jitted
  fn stays under a fixed cap (closure-capture leaks show up here as an
  exploding invar list).

``audit_jitted`` is the reusable core (the analyzer's own tests point
it at deliberately broken toy jits); ``run_audit`` drives the plain
and geotenants specs end to end for CI.
"""
from __future__ import annotations

import dataclasses
import warnings

# the per-fn invar cap: reward params contribute ~40 leaves, window
# arrays ~10; anything past this is a closure-capture leak
MAX_INVARS = 128

_CALLBACK_PRIMS = ("pure_callback", "io_callback", "callback",
                   "outside_call", "debug_callback", "debug_print")
_BAD_DTYPES = ("float64", "complex128")


@dataclasses.dataclass
class AuditResult:
    name: str
    problems: list
    invars: int = 0
    donated: bool = False

    @property
    def ok(self) -> bool:
        return not self.problems

    def to_dict(self) -> dict:
        return dataclasses.asdict(self) | {"ok": self.ok}


def _iter_eqns(jaxpr):
    """Walk every eqn, descending into pjit/scan/cond/... sub-jaxprs."""
    stack = [jaxpr]
    while stack:
        j = stack.pop()
        j = getattr(j, "jaxpr", j)  # ClosedJaxpr -> Jaxpr
        for eqn in j.eqns:
            yield eqn
            for v in eqn.params.values():
                if hasattr(v, "eqns") or hasattr(v, "jaxpr"):
                    stack.append(v)
                elif isinstance(v, (list, tuple)):
                    stack.extend(x for x in v
                                 if hasattr(x, "eqns")
                                 or hasattr(x, "jaxpr"))


def audit_jitted(fn, args, *, name="fn", expect_donation=False,
                 max_invars=MAX_INVARS) -> AuditResult:
    """Statically audit one jitted callable against concrete args."""
    import jax

    problems = []
    closed = jax.make_jaxpr(fn)(*args)
    for eqn in _iter_eqns(closed):
        prim = eqn.primitive.name
        if prim in _CALLBACK_PRIMS:
            problems.append(
                f"host callback `{prim}` at {eqn.source_info.traceback}"
                if eqn.source_info else f"host callback `{prim}`")
        if prim == "convert_element_type" \
                and str(eqn.params.get("new_dtype")) in _BAD_DTYPES:
            problems.append("f64 convert_element_type")
        for v in eqn.outvars:
            dt = getattr(getattr(v, "aval", None), "dtype", None)
            if dt is not None and str(dt) in _BAD_DTYPES:
                problems.append(
                    f"f64 intermediate: {prim} -> {v.aval.str_short()}")
                break
    invars = len(closed.jaxpr.invars)
    if invars > max_invars:
        problems.append(
            f"unbounded transfer set: {invars} flattened args "
            f"(cap {max_invars}) -- closure-capture leak?")

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        lowered = fn.lower(*args) if hasattr(fn, "lower") else None
        hlo = lowered.as_text() if lowered is not None else ""
    for w in caught:
        if "donated buffers were not usable" in str(w.message):
            problems.append(f"donation dropped at lowering: {w.message}")
    donated = "tf.aliasing_output" in hlo
    if expect_donation and not donated:
        problems.append(
            "declared donation left no input/output alias in the "
            "lowered module (silent un-donation, PR 9)")
    # dedupe, keep order
    problems = list(dict.fromkeys(problems))
    return AuditResult(name=name, problems=problems, invars=invars,
                       donated=donated)


# ---------------------------------------------------------------------------
# The serve_window audit: tiny untrained stack + capture
# ---------------------------------------------------------------------------

SPECS = ("plain", "geotenants")


def build_audit_stack(mode: str = "plain", *, seed: int = 0):
    """A minimal UNTRAINED serving stack: fabricated seeded stage
    scores + random clicks + init-only reward params.  Shapes mirror
    the tiny test stacks; tracing never looks at the values."""
    import jax
    import numpy as np

    from repro.cascade.engine import CascadeServer
    from repro.core.action_chain import (ModelInstance, StageSpec,
                                         generate_action_chains)
    from repro.core.reward_model import (RewardModelConfig,
                                         reward_model_init)
    from repro.serving.pipeline import ServingPipeline
    from repro.serving.spec import (ConstraintSpec, RegionAxis,
                                    TenantAxis)

    rng = np.random.default_rng(seed)
    u, i = 40, 150
    scores = {k: rng.normal(size=(u, i)).astype(np.float32)
              for k in ("DSSM", "YDNN", "DIN", "DIEN")}
    clicks = (rng.random((u, i)) < 0.15).astype(np.float32)
    n2 = tuple(int(x) for x in np.linspace(0.2 * i, 0.5 * i, 4))
    n3 = tuple(int(x) for x in np.linspace(8, 0.2 * i, 4))
    chains = generate_action_chains((
        StageSpec("recall", (ModelInstance("DSSM", 13e3),), (i,), 4),
        StageSpec("prerank", (ModelInstance("YDNN", 123e3),), n2, 4),
        StageSpec("rank", (ModelInstance("DIN", 7020e3),
                           ModelInstance("DIEN", 7098e3)), n3, 4),
    ))
    server = CascadeServer(stage_scores=scores, chains=chains,
                           clicks=clicks, expose=8)
    rcfg = RewardModelConfig(n_stages=3, max_models=2, n_scale_groups=4,
                             d_context=12, d_feature=16, d_hidden=16,
                             d_state=8)
    params = dict(reward_model_init(jax.random.PRNGKey(0), rcfg))
    budget = 0.5 * float(chains.costs.max()) * 64
    if mode == "plain":
        pipe = ServingPipeline(server, params, rcfg, budget)
        extra = {}
    elif mode == "geotenants":
        t_n, r_n = 2, 2
        spec = ConstraintSpec([
            TenantAxis(tuple(budget / t_n for _ in range(t_n)),
                       priced=True),
            RegionAxis(r_n),
        ])
        pipe = ServingPipeline.from_spec(server, params, rcfg, spec)
        extra = {
            "budget": np.full(t_n + r_n, budget / 2, np.float32),
            "cost_scale": np.ones(r_n, np.float32),
        }
    else:
        raise ValueError(f"unknown audit spec {mode!r} "
                         f"(choose from {SPECS})")

    def window(t):
        w = np.random.default_rng((seed, t))
        n = 64
        return (w.normal(size=(n, 12)).astype(np.float32),
                w.integers(0, u, n).astype(np.int32))

    return pipe, window, extra


class _Capture:
    """Wraps a cached jitted fn; records a pre-donation copy of the
    args of every call, then forwards."""

    def __init__(self, fn):
        self.fn = fn
        self.calls = []

    def __call__(self, *args):
        import jax
        import jax.numpy as jnp

        self.calls.append(jax.tree_util.tree_map(
            lambda x: jnp.copy(x) if isinstance(x, jax.Array) else x,
            args))
        return self.fn(*args)

    def lower(self, *args):
        return self.fn.lower(*args)


def audit_pipeline(pipe, window, extra, *, mode="plain") -> list:
    """Run two windows (populate the jit cache, then capture args) and
    audit every cached (main, dual) callable."""
    pipe.serve_window(*window(0), **extra)
    captures = {}
    for key, fns in list(pipe._fns.items()):
        wrapped = tuple(_Capture(f) if callable(f) else f for f in fns)
        pipe._fns[key] = wrapped
        captures[key] = wrapped
    pipe.serve_window(*window(1), **extra)
    results = []
    for key, fns in captures.items():
        for role, cap in zip(("main", "dual"), fns):
            if not isinstance(cap, _Capture) or not cap.calls:
                continue
            expect_don = role == "dual" and pipe.donate_dual
            results.append(audit_jitted(
                cap.fn, cap.calls[0],
                name=f"{mode}/{role}{tuple(key) if key else ''}",
                expect_donation=expect_don))
    if not results:
        results.append(AuditResult(
            name=f"{mode}/(none)",
            problems=["no jitted fns captured -- pipeline cache layout "
                      "changed under the auditor"]))
    return results


def run_audit(specs=SPECS) -> dict:
    """Audit the fused pass for each named spec; returns a JSON-ready
    report with ``ok`` per fn and overall."""
    results = []
    for mode in specs:
        pipe, window, extra = build_audit_stack(mode)
        results.extend(audit_pipeline(pipe, window, extra, mode=mode))
    return {
        "ok": all(r.ok for r in results),
        "checks": [r.to_dict() for r in results],
    }
