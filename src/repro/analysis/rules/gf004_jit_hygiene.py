"""GF004: jit hygiene -- dead static_argnames and use-after-donation.

Two historical bug classes share this rule:

* ``static_argnames`` naming a parameter the wrapped function does not
  have (PR 2): jax silently ignores the name, the argument stays
  traced, and every distinct value recompiles -- the exact retrace
  storm the bucketing work exists to prevent.
* Reading a buffer after passing it to a ``donate_argnums`` position
  (PR 7/9): donation invalidates the array; steady-state code that
  still reads it either crashes or silently un-donates (XLA inserts a
  copy and the "allocation-free dual chain" claim quietly dies).

Both checks are literal-only: dynamically-computed argnames/argnums are
skipped rather than guessed at.
"""
import ast

from repro.analysis.lint import _is_jit_name, dotted

CODE = "GF004"
TITLE = "jit hygiene: dead static_argnames / read-after-donation"
RATIONALE = ("PR 2: a misspelled static_argnames is silently ignored "
             "and retraces per value; PR 7/9: reading a donated buffer "
             "un-donates it (or crashes), breaking the allocation-free "
             "dual chain.")


def applies(mod: str) -> bool:
    return mod.endswith(".py")


def _literal_strs(node):
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, str)):
                return None
            out.append(e.value)
        return out
    return None


def _literal_ints(node):
    if isinstance(node, ast.Constant) and isinstance(node.value, int):
        return [node.value]
    if isinstance(node, (ast.Tuple, ast.List)):
        out = []
        for e in node.elts:
            if not (isinstance(e, ast.Constant)
                    and isinstance(e.value, int)):
                return None
            out.append(e.value)
        return out
    return None


def _params(fdef):
    a = fdef.args
    pos = [p.arg for p in a.posonlyargs + a.args]
    return pos, [p.arg for p in a.kwonlyargs], a.vararg, a.kwarg


def _defs_by_name(tree):
    defs = {}
    for n in ast.walk(tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            defs.setdefault(n.name, []).append(n)
    return defs


def _jit_call_targets(ctx):
    """(call, fdef) pairs: jit-ish Call nodes plus the def they wrap --
    from ``@partial(jax.jit, ...)`` decorators or ``jit(f, ...)`` with
    ``f`` resolvable by name."""
    defs = _defs_by_name(ctx.tree)
    for n in ast.walk(ctx.tree):
        if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in n.decorator_list:
                if isinstance(dec, ast.Call) and (
                        _is_jit_name(dotted(dec.func))
                        or (dotted(dec.func) or "").rsplit(".", 1)[-1]
                        == "partial" and dec.args
                        and _is_jit_name(dotted(dec.args[0]))):
                    yield dec, n
        elif isinstance(n, ast.Call) and _is_jit_name(dotted(n.func)):
            for a in n.args[:1]:
                if isinstance(a, ast.Name):
                    for fdef in defs.get(a.id, []):
                        yield n, fdef


def _check_static_args(ctx):
    for call, fdef in _jit_call_targets(ctx):
        kw = {k.arg: k.value for k in call.keywords if k.arg}
        pos, kwonly, vararg, kwarg = _params(fdef)
        if "static_argnames" in kw and kwarg is None:
            names = _literal_strs(kw["static_argnames"])
            for name in names or []:
                if name not in pos and name not in kwonly:
                    yield (call.lineno, call.col_offset,
                           f"static_argnames names `{name}` but "
                           f"`{fdef.name}` has no such parameter -- "
                           "jax ignores it silently and the argument "
                           "retraces per value (PR 2)")
        if "static_argnums" in kw and vararg is None:
            for i in _literal_ints(kw["static_argnums"]) or []:
                if i >= len(pos) or i < -len(pos):
                    yield (call.lineno, call.col_offset,
                           f"static_argnums {i} is out of range for "
                           f"`{fdef.name}` ({len(pos)} positional "
                           "parameters)")


def _donating_jits(ctx):
    """name -> donated positions, for literal donate_argnums only."""
    donators: dict = {}
    for n in ast.walk(ctx.tree):
        # g = jax.jit(f, donate_argnums=(0,))
        if isinstance(n, ast.Assign) and isinstance(n.value, ast.Call) \
                and _is_jit_name(dotted(n.value.func)):
            kw = {k.arg: k.value for k in n.value.keywords if k.arg}
            if "donate_argnums" not in kw:
                continue
            nums = _literal_ints(kw["donate_argnums"])
            if not nums:
                continue
            for t in n.targets:
                if isinstance(t, ast.Name):
                    donators[t.id] = tuple(nums)
        # @partial(jax.jit, donate_argnums=(0,)) on a def
        elif isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef)):
            for dec in n.decorator_list:
                if not isinstance(dec, ast.Call):
                    continue
                if not (_is_jit_name(dotted(dec.func))
                        or ((dotted(dec.func) or "")
                            .rsplit(".", 1)[-1] == "partial" and dec.args
                            and _is_jit_name(dotted(dec.args[0])))):
                    continue
                kw = {k.arg: k.value for k in dec.keywords if k.arg}
                nums = _literal_ints(kw.get("donate_argnums")) \
                    if "donate_argnums" in kw else None
                if nums:
                    donators[n.name] = tuple(nums)
    return donators


def _check_donated_reads(ctx):
    donators = _donating_jits(ctx)
    if not donators:
        return
    for call in ctx.calls():
        fname = dotted(call.func)
        if fname not in donators:
            continue
        scope = ctx.enclosing_scope(call)
        names = [n for n in ast.walk(scope) if isinstance(n, ast.Name)]
        for pos in donators[fname]:
            if pos >= len(call.args):
                continue
            arg = call.args[pos]
            if not isinstance(arg, ast.Name):
                continue
            # first re-binding after the donating call clears the hazard
            stores = [n.lineno for n in names
                      if n.id == arg.id
                      and isinstance(n.ctx, (ast.Store, ast.Del))
                      and n.lineno >= call.lineno]
            horizon = min(stores) if stores else None
            for n in names:
                if n.id != arg.id or not isinstance(n.ctx, ast.Load):
                    continue
                if n.lineno <= call.lineno:
                    continue
                if horizon is not None and n.lineno > horizon:
                    continue
                yield (n.lineno, n.col_offset,
                       f"`{arg.id}` is read after being donated to "
                       f"`{fname}` (argnum {pos}) -- donation "
                       "invalidates the buffer; keep a jnp.copy record "
                       "like the dual chain's _lam_rec (PR 7/9)")
                break  # one report per donated arg is enough


def check(ctx):
    yield from _check_static_args(ctx)
    yield from _check_donated_reads(ctx)
