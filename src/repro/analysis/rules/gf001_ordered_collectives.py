"""GF001: raw ``psum`` in serving/distributed code.

``lax.psum`` reduces in whatever order the backend's ring/tree picks,
which varies with topology and process count -- float addition is not
associative, so a raw psum breaks the bitwise cross-host decision
parity PR 9's multi-host mesh guarantees.  The sanctioned collective is
``repro.distributed.sharding.ordered_psum`` (all_gather + a local
``jnp.sum`` over the fixed shard axis), which every host evaluates in
the same order.
"""
from repro.analysis.lint import dotted

CODE = "GF001"
TITLE = "raw psum in serving/distributed code (use ordered_psum)"
RATIONALE = ("PR 9: cross-host bitwise decision parity relies on "
             "order-fixed all_gather reductions; backend psum order "
             "varies with topology.")

_SCOPE = ("serving/", "distributed/", "cascade/", "data/")
_RAW = ("psum", "psum_scatter")


def applies(mod: str) -> bool:
    return any(mod.startswith(p) for p in _SCOPE)


def check(ctx):
    for call in ctx.calls():
        name = dotted(call.func)
        if not name:
            continue
        if name.rsplit(".", 1)[-1] in _RAW:
            yield (call.lineno, call.col_offset,
                   f"raw `{name}` reduces in backend ring order and "
                   "breaks cross-host bitwise parity -- use "
                   "distributed.sharding.ordered_psum")
