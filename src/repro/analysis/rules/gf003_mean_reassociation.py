"""GF003: ``jnp.mean`` in dual-price arithmetic.

XLA strength-reduces ``mean`` into ``sum * (1/n)`` and is free to
reassociate the product through neighbouring expressions; PR 4 hit
exactly this when unifying the scalar (K=1) and vectorized dual cores
-- the two mathematically-identical norms compiled to different float
programs and broke the K=1 bit-parity gate.  Dual-price / lambda
arithmetic must build its divisors explicitly (``jnp.sum`` plus a
structured scalar factor), or carry a pragma explaining why this
``mean`` is the reference expression both paths share.
"""
from repro.analysis.lint import dotted

CODE = "GF003"
TITLE = "jnp.mean in dual-price/lambda arithmetic (reassociation hazard)"
RATIONALE = ("PR 4: mean -> sum*(1/n) strength reduction reassociates "
             "under XLA and broke scalar-vs-vectorized K=1 bitwise "
             "parity; dual arithmetic structures its divisors "
             "explicitly.")

_SCOPE = ("core/primal_dual.py", "serving/pipeline.py",
          "serving/guard.py", "serving/spec.py", "carbon/controller.py")
_MEAN = ("jnp.mean", "jax.numpy.mean")


def applies(mod: str) -> bool:
    return mod in _SCOPE


def check(ctx):
    for call in ctx.calls():
        if dotted(call.func) in _MEAN:
            yield (call.lineno, call.col_offset,
                   "`jnp.mean` in dual-price arithmetic reassociates "
                   "under XLA strength reduction (PR 4's K=1 parity "
                   "bug) -- use jnp.sum with an explicit structured "
                   "divisor, or justify with a pragma")
