"""GF005: unseeded nondeterminism in pure-window code.

The multi-host mesh (PR 9) never ships a request: every host evaluates
the same pure (seed, t) arrival functions and must agree bitwise; the
streaming driver (PR 8) takes an injectable ``clock`` so timing tests
are deterministic.  Wall-clock reads and unseeded RNG inside the
window-production modules break both -- timing goes through the
injected ``clock``, randomness through ``np.random.default_rng((seed,
t))`` / ``jax.random.PRNGKey``.
"""
from repro.analysis.lint import dotted

CODE = "GF005"
TITLE = "unseeded nondeterminism in pure-window code"
RATIONALE = ("PR 8/9: hosts recompute identical windows from (seed, t) "
             "and timing is injected via run_stream(clock=...); "
             "wall-clock or global-RNG reads desynchronize hosts and "
             "flake the deterministic timing tests.")

_SCOPE = ("serving/pipeline.py", "serving/stream.py", "serving/guard.py",
          "cascade/engine.py", "data/request_source.py",
          "distributed/multihost.py")

_CLOCKS = {"time.time", "time.monotonic", "time.perf_counter",
           "time.process_time", "time.time_ns", "time.monotonic_ns",
           "time.perf_counter_ns"}
_DATETIME = {"datetime.now", "datetime.datetime.now", "datetime.today",
             "datetime.utcnow", "datetime.datetime.utcnow",
             "datetime.datetime.today", "date.today",
             "datetime.date.today"}
_SEEDED_NP = {"default_rng", "Generator", "SeedSequence", "PCG64",
              "Philox", "MT19937", "bit_generator"}


def applies(mod: str) -> bool:
    return mod in _SCOPE


def check(ctx):
    for call in ctx.calls():
        name = dotted(call.func)
        if not name:
            continue
        if name in _CLOCKS:
            yield (call.lineno, call.col_offset,
                   f"wall-clock `{name}()` in pure-window code -- "
                   "timing must flow through the injected `clock` "
                   "(run_stream(clock=...))")
        elif name in _DATETIME:
            yield (call.lineno, call.col_offset,
                   f"`{name}()` reads the wall clock -- pure-window "
                   "code must be a function of (seed, t)")
        elif name.startswith("random."):
            yield (call.lineno, call.col_offset,
                   f"stdlib `{name}` draws from the unseeded global "
                   "RNG -- windows are pure (seed, t) functions; use "
                   "np.random.default_rng((seed, t))")
        elif name.startswith(("np.random.", "numpy.random.")):
            attr = name.rsplit(".", 1)[-1]
            if attr in _SEEDED_NP:
                if attr == "default_rng" and not call.args:
                    yield (call.lineno, call.col_offset,
                           "`default_rng()` without a seed is "
                           "entropy-seeded -- derive the seed from "
                           "(seed, t)")
                continue
            yield (call.lineno, call.col_offset,
                   f"`{name}` uses numpy's GLOBAL RNG -- windows are "
                   "pure (seed, t) functions; use "
                   "np.random.default_rng((seed, t))")
