"""GF006: ``-0.0`` canonicalization via ``+ 0.0``.

``x + 0.0`` looks like it normalizes ``-0.0`` to ``+0.0`` (IEEE-754:
``-0.0 + 0.0 == +0.0``), and it does -- until XLA's algebraic
simplifier folds the add away entirely, at which point ``-0.0``
survives into sort keys and monotone float-bit encodings and flips
orderings.  PR 7 hit this in the device twin of the chunk-table
compactor (two-key ``lax.sort`` over monotone float bits): the fix is
an explicit select, ``jnp.where(x == 0, 0.0, x)``, which XLA does not
fold.
"""
import ast

from repro.analysis.lint import dotted

CODE = "GF006"
TITLE = "-0.0 canonicalization via `+ 0.0` (XLA folds it)"
RATIONALE = ("PR 7: the jitted chunk-table compactor needed -0.0 "
             "canonicalized before monotone-bit sorting; `+ 0.0` is "
             "folded by the algebraic simplifier, `where` is not.")


def applies(mod: str) -> bool:
    return mod.endswith(".py")


def _is_float_zero(node) -> bool:
    return (isinstance(node, ast.Constant)
            and isinstance(node.value, float) and node.value == 0.0)


def check(ctx):
    for n in ast.walk(ctx.tree):
        if isinstance(n, ast.BinOp) and isinstance(n.op, (ast.Add,
                                                          ast.Sub)):
            if _is_float_zero(n.right) or (isinstance(n.op, ast.Add)
                                           and _is_float_zero(n.left)):
                yield (n.lineno, n.col_offset,
                       "`+ 0.0` / `- 0.0` is folded away by XLA and "
                       "does NOT canonicalize -0.0 -- use "
                       "jnp.where(x == 0, 0.0, x) (PR 7)")
        elif isinstance(n, ast.Call) and dotted(n.func) in ("jnp.add",
                                                            "lax.add"):
            if any(_is_float_zero(a) for a in n.args):
                yield (n.lineno, n.col_offset,
                       "`add(x, 0.0)` is folded away by XLA and does "
                       "NOT canonicalize -0.0 -- use jnp.where "
                       "(PR 7)")
