"""Rule registry: one module per invariant, ordered by code."""
from repro.analysis.rules import (gf001_ordered_collectives,
                                  gf002_host_syncs,
                                  gf003_mean_reassociation,
                                  gf004_jit_hygiene,
                                  gf005_nondeterminism,
                                  gf006_signed_zero)

RULES = (gf001_ordered_collectives, gf002_host_syncs,
         gf003_mean_reassociation, gf004_jit_hygiene,
         gf005_nondeterminism, gf006_signed_zero)

BY_CODE = {r.CODE: r for r in RULES}

__all__ = ["RULES", "BY_CODE"]
