"""GF002: implicit host<->device syncs in hot-path modules.

The streaming serving path promises zero hidden synchronization between
window submit and the post-drain flush (PR 7/8: telemetry reads device
arrays only after the stream drains; the window pass overlaps with
chunk prefetch).  ``.item()`` / ``jax.device_get`` block on the device
anywhere they appear; ``np.*`` / ``float()`` / ``int()`` on a TRACED
value silently devolve to a transfer + retrace hazard, so those are
flagged inside statically-detected traced scopes (jit / shard_map
wrapped defs).
"""
import ast

from repro.analysis.lint import dotted

CODE = "GF002"
TITLE = "implicit host sync on the serving hot path"
RATIONALE = ("PR 7/8: the fused window pass and its telemetry are "
             "sync-free until the stream drains; a stray .item()/"
             "np.asarray stalls the overlap the throughput numbers "
             "depend on.")

HOT = ("serving/pipeline.py", "serving/stream.py", "serving/guard.py",
       "cascade/engine.py", "data/request_source.py")

_SYNC_ATTRS = ("item", "block_until_ready")
_STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}


def applies(mod: str) -> bool:
    return mod in HOT


def _static_arg(node) -> bool:
    """Casts of static metadata (shapes, dims, constants) never sync."""
    if isinstance(node, ast.Constant):
        return True
    for n in ast.walk(node):
        if isinstance(n, ast.Attribute) and n.attr in _STATIC_ATTRS:
            return True
        if isinstance(n, ast.Call) and dotted(n.func) == "len":
            return True
    return False


def check(ctx):
    seen = set()
    # module-wide: unconditional device blocks
    for call in ctx.calls():
        f = call.func
        if isinstance(f, ast.Attribute) and f.attr in _SYNC_ATTRS \
                and not call.args:
            seen.add(id(call))
            yield (call.lineno, call.col_offset,
                   f"`.{f.attr}()` blocks on the device -- hot-path "
                   "modules must stay sync-free until the stream "
                   "drains")
        elif dotted(f) in ("jax.device_get", "device_get"):
            seen.add(id(call))
            yield (call.lineno, call.col_offset,
                   "`jax.device_get` forces a device->host transfer on "
                   "the hot path")
    # traced scopes: host-library calls and value casts
    for fdef in ctx.traced:
        for call in ast.walk(fdef):
            if not isinstance(call, ast.Call) or id(call) in seen:
                continue
            name = dotted(call.func)
            if not name:
                continue
            root = name.split(".", 1)[0]
            if root in ("np", "numpy", "onp"):
                yield (call.lineno, call.col_offset,
                       f"host `{name}` inside the traced fn "
                       f"`{fdef.name}` forces a transfer and breaks "
                       "tracing -- use jnp")
            elif name in ("float", "int", "bool") and call.args \
                    and not _static_arg(call.args[0]):
                yield (call.lineno, call.col_offset,
                       f"`{name}()` on a traced value inside "
                       f"`{fdef.name}` is a hidden host sync (only "
                       "static metadata like .shape may be cast)")
