"""AST lint engine behind greenflow-check.

The engine is deliberately boring: pure stdlib (ast + tokenize), no jax
import, so ``python -m repro.analysis src`` runs anywhere the repo
checks out.  Rules live one-per-module under ``repro.analysis.rules``;
each exports

    CODE        "GFxxx"
    TITLE       one-line summary (shown by --list-rules)
    RATIONALE   the PR history behind the rule (shown by --list-rules)
    applies(mod)   -> bool      mod is the repo-relative module path
                                ("serving/pipeline.py")
    check(ctx)     -> iterable of (line, col, message)

and the engine handles file walking, pragma suppression and reporting.

Suppression grammar (a finding is only suppressed with a WRITTEN
justification — an empty reason is itself a finding, GF000):

    x = jax.lax.psum(g, axis)  # gf: allow[GF001] training-only gradient

    # gf: allow[GF002,GF005] host replay boundary, windows are seeded
    arr = np.asarray(chunk)

A trailing pragma covers its own line; a standalone comment line covers
the next code line.  Pragmas that suppress nothing are reported (GF000)
so stale allowances cannot rot in place.
"""
from __future__ import annotations

import ast
import dataclasses
import io
import json
import os
import re
import tokenize
from pathlib import PurePath

META_RULE = "GF000"  # meta findings: malformed / unused pragmas

PRAGMA_RE = re.compile(
    r"#\s*gf:\s*allow\[(?P<codes>[A-Za-z0-9_,\s]*)\]\s*(?P<why>.*)$")


# ---------------------------------------------------------------------------
# Findings + pragmas
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Finding:
    rule: str
    path: str
    line: int
    col: int
    message: str
    suppressed: bool = False
    justification: str = ""

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    def format(self) -> str:
        tag = " (suppressed: %s)" % self.justification \
            if self.suppressed else ""
        return (f"{self.path}:{self.line}:{self.col}: "
                f"{self.rule} {self.message}{tag}")


@dataclasses.dataclass
class Pragma:
    line: int  # line the pragma comment sits on
    target: int | None  # line whose findings it suppresses
    codes: tuple
    justification: str
    used: set = dataclasses.field(default_factory=set)


def parse_pragmas(src: str) -> list[Pragma]:
    """Extract ``# gf: allow[...]`` pragmas via the tokenizer (so the
    grammar inside string literals is never misread as a pragma)."""
    pragmas: list[Pragma] = []
    code_lines: set[int] = set()
    try:
        tokens = list(tokenize.generate_tokens(io.StringIO(src).readline))
    except tokenize.TokenError:
        return []
    for tok in tokens:
        if tok.type in (tokenize.NAME, tokenize.NUMBER, tokenize.STRING,
                        tokenize.OP):
            code_lines.add(tok.start[0])
        if tok.type != tokenize.COMMENT:
            continue
        m = PRAGMA_RE.search(tok.string)
        if not m:
            continue
        codes = tuple(c.strip().upper() for c in m["codes"].split(",")
                      if c.strip())
        standalone = tok.string.strip() == tok.line.strip()
        pragmas.append(Pragma(
            line=tok.start[0],
            target=None if standalone else tok.start[0],
            codes=codes, justification=m["why"].strip()))
    # a standalone pragma covers the next line that carries code
    for p in pragmas:
        if p.target is None:
            later = [ln for ln in code_lines if ln > p.line]
            p.target = min(later) if later else None
    return pragmas


def _apply_pragmas(findings: list[Finding], pragmas: list[Pragma],
                   path: str) -> list[Finding]:
    for f in findings:
        for p in pragmas:
            if p.target == f.line and f.rule in p.codes:
                if not p.justification:
                    continue  # unjustified pragmas never suppress
                f.suppressed = True
                f.justification = p.justification
                p.used.add(f.rule)
                break
    meta: list[Finding] = []
    for p in pragmas:
        if not p.codes:
            meta.append(Finding(META_RULE, path, p.line, 0,
                                "gf: allow[] pragma names no rules"))
            continue
        if not p.justification:
            meta.append(Finding(
                META_RULE, path, p.line, 0,
                f"gf: allow[{','.join(p.codes)}] pragma carries no "
                "justification -- every suppression must say WHY"))
            continue
        stale = [c for c in p.codes if c not in p.used]
        if stale:
            meta.append(Finding(
                META_RULE, path, p.line, 0,
                f"gf: allow[{','.join(stale)}] suppresses nothing "
                "(stale pragma -- remove it or fix the rule id)"))
    return sorted(findings + meta, key=lambda f: (f.line, f.col, f.rule))


# ---------------------------------------------------------------------------
# Module context + shared AST helpers
# ---------------------------------------------------------------------------


def module_path(path: str) -> str:
    """Repo-relative module path used for rule scoping: the part after
    the last ``repro`` directory ("serving/pipeline.py"); files outside
    the package (benchmarks, tests, fixtures) keep their last two
    components."""
    parts = PurePath(path).parts
    if "repro" in parts:
        i = len(parts) - 1 - parts[::-1].index("repro")
        tail = parts[i + 1:]
        if tail:
            return "/".join(tail)
    return "/".join(parts[-2:]) if len(parts) > 1 else parts[0]


def dotted(node) -> str | None:
    """'jax.lax.psum' for an Attribute chain, 'psum' for a Name."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        base = dotted(node.value)
        return f"{base}.{node.attr}" if base else None
    return None


def _is_jit_name(name: str | None) -> bool:
    """Callables that produce traced/compiled functions: ``jax.jit``,
    local wrappers conventionally named ``*_jit``, ``shard_map``,
    ``pmap``."""
    if not name:
        return False
    if name == "jit" or name.endswith(".jit") or name.endswith("_jit"):
        return True
    last = name.rsplit(".", 1)[-1]
    return last in ("shard_map", "pmap")


def _decorator_is_jit(dec) -> bool:
    if _is_jit_name(dotted(dec)):
        return True
    if isinstance(dec, ast.Call):
        if _is_jit_name(dotted(dec.func)):
            return True
        # @partial(jax.jit, ...) / @functools.partial(jit, ...)
        fname = dotted(dec.func)
        if fname and fname.rsplit(".", 1)[-1] == "partial" and dec.args:
            return _is_jit_name(dotted(dec.args[0]))
    return False


def scope_statements(scope):
    """Statements belonging to ``scope`` (not descending into nested
    function/class scopes)."""
    stack = list(getattr(scope, "body", []))
    while stack:
        st = stack.pop()
        yield st
        if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef,
                           ast.ClassDef)):
            continue
        for child in ast.iter_child_nodes(st):
            if isinstance(child, ast.stmt):
                stack.append(child)


def traced_defs(tree) -> set:
    """Function defs the engine considers TRACED: decorated with a jit
    wrapper, or passed by name into a jit/shard_map/pmap call within an
    enclosing scope (the ``fn = shard_map(fn, ...); return jax.jit(fn)``
    builder idiom)."""
    traced: set = set()
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_decorator_is_jit(d) for d in node.decorator_list):
                traced.add(node)
    scopes = [n for n in ast.walk(tree)
              if isinstance(n, (ast.Module, ast.FunctionDef,
                                ast.AsyncFunctionDef, ast.ClassDef))]
    for scope in scopes:
        defs: dict = {}
        for st in scope_statements(scope):
            if isinstance(st, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defs.setdefault(st.name, []).append(st)
        if not defs:
            continue
        for n in ast.walk(scope):
            if isinstance(n, ast.Call) and _is_jit_name(dotted(n.func)):
                for a in n.args:
                    if isinstance(a, ast.Name) and a.id in defs:
                        traced.update(defs[a.id])
    return traced


@dataclasses.dataclass
class ModuleContext:
    """Everything a rule's ``check`` gets to look at."""

    tree: ast.Module
    src: str
    mod: str  # repo-relative module path ("serving/pipeline.py")
    path: str  # path as given (reporting only)
    _traced: set | None = None
    _parents: dict | None = None

    @property
    def traced(self) -> set:
        if self._traced is None:
            self._traced = traced_defs(self.tree)
        return self._traced

    @property
    def parents(self) -> dict:
        """child ast node -> parent node, for scope lookups."""
        if self._parents is None:
            self._parents = {c: p for p in ast.walk(self.tree)
                             for c in ast.iter_child_nodes(p)}
        return self._parents

    def enclosing_scope(self, node):
        cur = self.parents.get(node)
        while cur is not None and not isinstance(
                cur, (ast.FunctionDef, ast.AsyncFunctionDef, ast.Module)):
            cur = self.parents.get(cur)
        return cur if cur is not None else self.tree

    def calls(self):
        for n in ast.walk(self.tree):
            if isinstance(n, ast.Call):
                yield n


# ---------------------------------------------------------------------------
# Drivers
# ---------------------------------------------------------------------------


def all_rules() -> list:
    from repro.analysis.rules import RULES
    return list(RULES)


def lint_source(src: str, path: str, *, rules=None) -> list[Finding]:
    """Lint one module's source. ``path`` scopes the rules (see
    ``module_path``); fixtures pass a virtual path."""
    rules = all_rules() if rules is None else rules
    try:
        tree = ast.parse(src)
    except SyntaxError as e:
        return [Finding(META_RULE, path, e.lineno or 0, e.offset or 0,
                        f"syntax error: {e.msg}")]
    ctx = ModuleContext(tree=tree, src=src, mod=module_path(path),
                        path=path)
    findings = []
    for rule in rules:
        if not rule.applies(ctx.mod):
            continue
        for line, col, msg in rule.check(ctx):
            findings.append(Finding(rule.CODE, path, line, col, msg))
    return _apply_pragmas(findings, parse_pragmas(src), path)


def lint_file(path: str, *, rules=None) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        return lint_source(f.read(), path, rules=rules)


def iter_python_files(paths):
    for p in paths:
        if os.path.isfile(p):
            yield p
            continue
        for root, dirs, files in os.walk(p):
            dirs[:] = sorted(d for d in dirs
                             if not d.startswith(".")
                             and d != "__pycache__")
            for f in sorted(files):
                if f.endswith(".py"):
                    yield os.path.join(root, f)


def lint_paths(paths, *, rules=None) -> list[Finding]:
    findings: list[Finding] = []
    for p in iter_python_files(paths):
        findings.extend(lint_file(p, rules=rules))
    return findings


# ---------------------------------------------------------------------------
# Reporting
# ---------------------------------------------------------------------------


def summarize(findings: list[Finding]) -> dict:
    by_rule: dict = {}
    for f in findings:
        if not f.suppressed:
            by_rule[f.rule] = by_rule.get(f.rule, 0) + 1
    return {
        "findings": len(findings),
        "unsuppressed": sum(not f.suppressed for f in findings),
        "suppressed": sum(f.suppressed for f in findings),
        "by_rule": dict(sorted(by_rule.items())),
    }


def render_text(findings: list[Finding], *, show_suppressed=False) -> str:
    lines = [f.format() for f in findings
             if show_suppressed or not f.suppressed]
    s = summarize(findings)
    lines.append(f"greenflow-check: {s['unsuppressed']} finding(s), "
                 f"{s['suppressed']} suppressed")
    return "\n".join(lines)


def render_json(findings: list[Finding], *, audit: dict | None = None,
                ) -> str:
    doc = {"summary": summarize(findings),
           "findings": [f.to_dict() for f in findings]}
    if audit is not None:
        doc["jaxpr_audit"] = audit
    return json.dumps(doc, indent=2, sort_keys=True)
