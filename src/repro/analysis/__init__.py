"""greenflow-check: invariant-enforcing static analysis for the repo.

The serving stack's headline guarantees -- bitwise-deterministic
decisions across any host split, zero steady-state recompiles, no
hidden host<->device syncs, an allocation-free donated dual chain --
were each broken at least once by an innocent-looking diff (PRs 2, 4,
7, 9).  This package rejects those bug classes at lint time:

  GF001  raw ``lax.psum`` in serving/distributed code -- use
         ``distributed.sharding.ordered_psum`` (order-fixed all_gather;
         the bitwise cross-host guarantee, PR 9)
  GF002  implicit host syncs (``.item()``, ``jax.device_get``,
         ``np.*`` / ``float()`` inside traced scopes) in the hot-path
         modules (PR 7/8's overlap + telemetry invariants)
  GF003  ``jnp.mean`` in dual-price arithmetic (XLA strength-reduction
         reassociation; PR 4's K=1 bit-parity bug)
  GF004  jit hygiene: ``static_argnames`` naming nonexistent params
         (PR 2) and reads of donated buffers after a
         ``donate_argnums`` call (PR 7/9)
  GF005  unseeded nondeterminism (wall clocks, global RNG) in
         pure-window code -- timing goes through the injectable
         ``clock``, randomness through (seed, t) (PR 8/9)
  GF006  ``-0.0`` canonicalization via ``+ 0.0`` -- XLA folds the add;
         use ``jnp.where`` (PR 7)

Usage::

    PYTHONPATH=src python -m repro.analysis [paths ...]
        # lint (default paths: src); exit 1 on unsuppressed findings
    python -m repro.analysis --format json --out report.json src
    python -m repro.analysis --rules GF001,GF004 src/repro/serving
    python -m repro.analysis --list-rules
    python -m repro.analysis --jaxpr-audit plain,geotenants
        # trace the fused serve_window pass and assert: no f64, no
        # host callbacks, declared donations honored, bounded
        # transfer count

Suppressions are inline and MUST carry a written justification::

    x = lax.psum(g, ax)  # gf: allow[GF001] training-only gradient path

An empty justification or a pragma that suppresses nothing is itself a
finding (GF000).  The AST layer is pure stdlib; jax is only imported
by the ``--jaxpr-audit`` layer (``repro.analysis.jaxpr_audit``).
"""
from repro.analysis.lint import (Finding, lint_file, lint_paths,
                                 lint_source, render_json, render_text,
                                 summarize)

__all__ = ["Finding", "lint_file", "lint_paths", "lint_source",
           "render_json", "render_text", "summarize"]
