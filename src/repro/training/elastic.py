"""Elastic scaling + failure handling.

The failure model at 1000+ nodes: hosts disappear (preemption, hardware),
the job controller re-forms a smaller (or larger) mesh and relaunches.
SPMD/JAX handles this as RESHARD-ON-RESTORE, not in-band recovery:

  1. Trainer checkpoints atomically every N steps (training/checkpoint.py)
     and on SIGTERM (graceful eviction).
  2. On relaunch the controller calls ``remesh_restore`` with the NEW mesh;
     every leaf is device_put against its PartitionSpec on that mesh -
     the specs are mesh-shape-agnostic (axis NAMES, not sizes).
  3. The data pipeline seeks to the restored step (deterministic batches:
     no replay, no skew between hosts).

Straggler posture (documented, partially simulatable on one host):
  * synchronous SPMD absorbs micro-stragglers at every collective;
  * static shapes everywhere (padded sampler budgets, bucketed n_k) make
    step time data-independent - the main source of macro-stragglers in
    recsys/GNN workloads is eliminated by construction;
  * persistent macro-stragglers are handled by eviction + this restore
    path, which is the production-standard answer (borg/k8s).
"""
from __future__ import annotations

from dataclasses import dataclass

from repro.training import checkpoint as ckpt_lib


@dataclass
class ElasticEvent:
    kind: str  # "shrink" | "grow" | "restart"
    old_shape: tuple
    new_shape: tuple
    step: int


class ElasticController:
    """Forms meshes, restores state across mesh changes, logs events."""

    def __init__(self, axis_names=("data", "model")):
        self.axis_names = tuple(axis_names)
        self.events: list[ElasticEvent] = []

    def make_mesh(self, shape: tuple):
        from repro.distributed.compat import make_mesh
        return make_mesh(tuple(shape), self.axis_names)

    def remesh_restore(self, ckpt_dir: str, target_state, shardings,
                       old_shape: tuple, new_shape: tuple):
        """Restore the latest checkpoint onto a new mesh shape.

        ``shardings`` is a PartitionSpec pytree matching ``target_state``;
        axis names must exist in both meshes (sizes may differ).
        """
        new_mesh = self.make_mesh(new_shape)
        state, manifest = ckpt_lib.restore(
            ckpt_dir, target_state, mesh=new_mesh, shardings=shardings)
        n_old, n_new = _n(old_shape), _n(new_shape)
        kind = ("shrink" if n_new < n_old
                else "grow" if n_new > n_old else "reshape")
        self.events.append(ElasticEvent(
            kind=kind,
            old_shape=tuple(old_shape), new_shape=tuple(new_shape),
            step=manifest["step"]))
        return state, new_mesh, manifest


def _n(shape):
    out = 1
    for s in shape:
        out *= s
    return out
