"""Training substrate: optimizers, schedules, trainer, checkpoints."""
