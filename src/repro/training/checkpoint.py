"""Fault-tolerant checkpointing.

Properties needed at 1000-node scale, implemented here:

  * ATOMIC: state is written to ``step_XXXX.tmp/`` then renamed - a
    preempted save never corrupts the latest checkpoint;
  * SELF-DESCRIBING: a manifest carries the tree structure, shapes,
    dtypes and the PartitionSpec of every leaf - restore does not need
    the model code to guess shardings;
  * ELASTIC: ``restore(..., mesh=new_mesh, shardings=...)`` re-lays the
    same global arrays out on a *different* mesh (N->M data shards) -
    this is the node-failure / elastic-rescale path (tested in
    tests/test_checkpoint.py by round-tripping across mesh shapes);
  * GC: ``keep`` most recent checkpoints are retained.
"""
from __future__ import annotations

import json
import os
import shutil
import tempfile

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                       for p in path)
        out.append((key, leaf))
    return out, treedef


def save(ckpt_dir: str, step: int, state, *, keep: int = 3,
         extra_meta: dict | None = None) -> str:
    """Atomically write ``state`` (any pytree) as checkpoint ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:010d}")
    tmp = tempfile.mkdtemp(prefix=f".step_{step:010d}.tmp.",
                           dir=ckpt_dir)
    try:
        leaves, _ = _flatten_with_paths(state)
        manifest = {"step": step, "leaves": [], "extra": extra_meta or {}}
        arrays = {}
        for i, (key, leaf) in enumerate(leaves):
            arr = np.asarray(jax.device_get(leaf))
            name = f"leaf_{i:05d}"
            arrays[name] = arr
            manifest["leaves"].append(
                {"key": key, "name": name, "shape": list(arr.shape),
                 "dtype": str(arr.dtype)})
        np.savez(os.path.join(tmp, "arrays.npz"), **arrays)
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.rename(tmp, final)  # atomic commit
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def latest_step(ckpt_dir: str) -> int | None:
    if not os.path.isdir(ckpt_dir):
        return None
    steps = [int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
             if d.startswith("step_") and not d.endswith(".tmp")]
    return max(steps) if steps else None


def restore(ckpt_dir: str, target, *, step: int | None = None,
            mesh=None, shardings=None):
    """Restore into the structure of ``target``.

    With ``mesh`` + ``shardings`` (a pytree of PartitionSpec matching
    ``target``) each leaf is device_put with its NamedSharding - this is
    how a checkpoint taken on one mesh is resurrected on another (elastic
    restart after node loss).
    """
    step = step if step is not None else latest_step(ckpt_dir)
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:010d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    data = np.load(os.path.join(path, "arrays.npz"))

    leaves, treedef = _flatten_with_paths(target)
    by_key = {e["key"]: e for e in manifest["leaves"]}
    spec_leaves = None
    if shardings is not None:
        spec_flat, _ = jax.tree_util.tree_flatten(
            shardings, is_leaf=lambda x: isinstance(x, jax.sharding.PartitionSpec))
        spec_leaves = spec_flat

    out = []
    for i, (key, leaf) in enumerate(leaves):
        if key not in by_key:
            raise KeyError(f"checkpoint missing leaf {key!r}")
        arr = data[by_key[key]["name"]]
        want_dtype = np.asarray(leaf).dtype if hasattr(leaf, "dtype") else arr.dtype
        arr = arr.astype(want_dtype, copy=False)
        if mesh is not None and spec_leaves is not None:
            ns = jax.sharding.NamedSharding(mesh, spec_leaves[i])
            out.append(jax.device_put(arr, ns))
        else:
            out.append(arr)
    return jax.tree_util.tree_unflatten(treedef, out), manifest


def _gc(ckpt_dir: str, keep: int):
    steps = sorted(int(d.split("_")[1]) for d in os.listdir(ckpt_dir)
                   if d.startswith("step_") and not d.endswith(".tmp"))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:010d}"),
                      ignore_errors=True)
